"""End-to-end driver: train a language model THROUGH the GreenFaaS fleet
layer — placement by Cluster MHRA over a heterogeneous TPU fleet (simulated
endpoints), real JAX training on this host, checkpoint/restart on an
injected endpoint failure, straggler detection from the online profiles.

    PYTHONPATH=src python examples/fleet_train.py [--steps 60]

(The model defaults to a reduced config so the example runs in ~a minute
on one CPU; pass --preset 100m for the ~100M-parameter variant.)
"""
import argparse
import tempfile

from repro.core.endpoint import tpu_fleet
from repro.fleet.manager import FleetJob, FleetManager
from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--preset", default="small", choices=["small", "100m"])
    args = ap.parse_args()

    # --- 1. GreenFaaS decides WHERE the job runs -------------------------
    mgr = FleetManager(tpu_fleet(), "benchmarks/results/dryrun", alpha=0.5)
    job = FleetJob(id="lm-pretrain", arch="granite-3-2b", shape="train_4k",
                   steps=args.steps, checkpoint_bytes=5e9)
    schedule = mgr.place([job])
    target = schedule.assignments[job.id]
    print(f"[fleet] Cluster MHRA placed {job.id} on '{target}' "
          f"(E={schedule.energy_j/1e3:.0f} kJ est, C_max={schedule.makespan_s:.0f} s est)")

    # --- 2. real training with checkpoint/restart ------------------------
    dims = None
    if args.preset == "100m":
        dims = dict(n_layers=8, d_model=768, n_heads=12, n_kv_heads=4,
                    head_dim=64, d_ff=3072, vocab=32000)
    ckpt = tempfile.mkdtemp(prefix="fleet_ckpt_")

    observed = []

    def on_step(i, loss, dt):
        # feed measured step time back into the GreenFaaS profiles
        replace = mgr.observe_step(job, target, dt, energy_j=dt * 100.0)
        observed.append(dt)
        if replace:
            print(f"[fleet] straggler flagged at step {i} — would re-place")

    half = args.steps // 2
    print(f"[fleet] training to step {half}, then simulating endpoint failure")
    train(arch=job.arch, reduced=True, steps=half, batch=8, seq=128,
          checkpoint_dir=ckpt, checkpoint_every=10, on_step=on_step,
          model_dims=dims, log_every=20)

    # --- 3. inject failure: endpoint dies; re-place and RESUME -----------
    mgr.endpoint_leave(target)
    new_schedule = mgr.place([job])
    new_target = new_schedule.assignments[job.id]
    print(f"[fleet] endpoint '{target}' FAILED -> re-placed on '{new_target}', "
          f"resuming from checkpoint")
    _, losses = train(arch=job.arch, reduced=True, steps=args.steps, batch=8,
                      seq=128, checkpoint_dir=ckpt, resume=True,
                      on_step=on_step, model_dims=dims, log_every=20)
    print(f"[fleet] done: loss {losses[0] if losses else float('nan'):.3f} -> "
          f"{losses[-1]:.3f}; events: {mgr.events}")


if __name__ == "__main__":
    main()
