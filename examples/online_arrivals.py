"""GreenFaaS online arrivals demo: tasks stream in over several arrival
windows; the engine places each window against the *live* endpoint
timelines and feeds monitored energy back into the profile store, so the
placement mix shifts as profiles accumulate mid-workload.

    PYTHONPATH=src python examples/online_arrivals.py
"""
from repro.core.endpoint import table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.scheduler import TaskSpec
from repro.core.testbed import SEBS_FUNCTIONS, TestbedSim

N_WINDOWS = 4
TASKS_PER_WINDOW = 140


def main() -> None:
    endpoints = table1_testbed()
    backend = TestbedSim(endpoints, seed=0)
    engine = OnlineEngine(
        endpoints,
        backend,
        policy="mhra",          # any name from available_policies()
        alpha=0.2,              # favor runtime (paper Fig. 6 trade-off)
        window_s=30.0,          # arrival-window batcher
        max_batch=512,
        monitoring=True,        # learn from attributed energy, not truth
    )

    print(f"{'window':>6} {'tasks':>6} {'sched_ms':>9} {'profiles':>9}  placements")
    for w in range(N_WINDOWS):
        for i in range(TASKS_PER_WINDOW):
            engine.submit(
                TaskSpec(id=f"w{w}t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)])
            )
        res = engine.flush()
        confident = sum(1 for n, _, _ in engine.store.stats().values() if n > 0)
        placements = ", ".join(
            f"{ep}:{n}" for ep, n in sorted(res.placements.items())
        )
        print(f"{res.index:>6} {len(res.tasks):>6} "
              f"{res.scheduling_s * 1e3:>9.1f} {confident:>9}  {placements}")

    s = engine.summary()
    print(f"\n{s.tasks} tasks over {s.windows} windows")
    print(f"cumulative makespan : {s.makespan_s:8.1f} s")
    print(f"scheduled energy    : {s.energy_j / 1e3:8.1f} kJ "
          f"(attributed to tasks: {s.attributed_j / 1e3:.1f} kJ)")
    print(f"total scheduling    : {s.scheduling_s * 1e3:8.1f} ms "
          f"({s.scheduling_s / s.tasks * 1e3:.2f} ms/task)")
    print("\nWindow 0 spreads tasks for exploration; once window-0 records")
    print("make per-endpoint profiles confident, later windows shift the")
    print("mix toward the endpoints measured best for each function.")


if __name__ == "__main__":
    main()
