"""Serve a model fleet through GreenFaaS: inference job streams (prefill +
decode batches for different archs) are placed across heterogeneous pods by
Cluster MHRA using dry-run-derived cost profiles; real batched decoding
runs on this host for the selected job.

    PYTHONPATH=src python examples/fleet_serve.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))

from collections import Counter

from repro.core.endpoint import tpu_fleet
from repro.fleet.manager import FleetJob, FleetManager
from repro.launch.serve import serve_batch


def main() -> None:
    mgr = FleetManager(tpu_fleet(), "benchmarks/results/dryrun", alpha=0.3)

    # a mixed serving wave: chat decode, long-doc prefill, batch scoring
    jobs = []
    for i in range(6):
        jobs.append(FleetJob(id=f"chat{i}", arch="granite-3-2b",
                             shape="decode_32k", steps=200))
    for i in range(3):
        jobs.append(FleetJob(id=f"doc{i}", arch="qwen3-14b",
                             shape="prefill_32k", steps=50))
    for i in range(2):
        jobs.append(FleetJob(id=f"score{i}", arch="zamba2-2.7b",
                             shape="decode_32k", steps=400))

    schedule = mgr.place(jobs)
    print("fleet placement (Cluster MHRA over dry-run cost profiles):")
    for job in jobs:
        print(f"  {job.id:8s} {job.arch:16s} {job.shape:12s} -> "
              f"{schedule.assignments[job.id]}")
    dist = Counter(schedule.assignments.values())
    print(f"per-endpoint load: {dict(dist)}")
    print(f"estimated makespan {schedule.makespan_s:.0f} s, "
          f"energy {schedule.energy_j/1e3:.0f} kJ\n")

    # run one placed job for real (reduced config on the host devices)
    job = jobs[0]
    print(f"running {job.id} ({job.arch}) locally, batched decode:")
    serve_batch(arch=job.arch, reduced=True, batch=4, prompt_len=32,
                gen_tokens=16)


if __name__ == "__main__":
    main()
