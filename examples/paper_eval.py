"""Paper-faithful end-to-end evaluation: the comparison tables the
GreenFaaS claims rest on, reproduced from one command.

Runs the same trace under every policy plus per-endpoint single-site
baselines and reports EDP + GPS-UP (Greenup/Speedup/Powerup) against the
best single site:

1. **Synthetic EDP workload** (§IV-B.1 / Table V): mixed
   compute/memory/IO SeBS-style functions, Poisson arrivals, Table-I
   testbed.  Gate: MHRA's EDP <= the best single-site baseline's.
2. **Molecular-design DAG** (§IV-B.2 / Fig. 9): dock -> simulate ->
   train -> infer with data dependencies through the online engine's
   ready-set.  Gates: every DAG edge honored in the executed records, and
   ``engine="delta"`` / ``engine="soa"`` produce identical assignments.

Results are persisted to ``BENCH_eval.json`` and rendered to
``reports/eval.html`` via ``repro.core.report``.

    PYTHONPATH=src python examples/paper_eval.py           # medium sizes
    PYTHONPATH=src python examples/paper_eval.py --tiny    # CI smoke
    PYTHONPATH=src python examples/paper_eval.py --full    # paper sizes
"""
from __future__ import annotations

import argparse
import sys
import time

from repro.core.evaluate import evaluate_trace, run_policy, verify_dag_order
from repro.core.report import eval_html_report, eval_text_report, write_bench_json
from repro.workloads import moldesign_dag_workload, synthetic_edp_workload

SIZES = {
    # name: (synthetic n_tasks, dag (waves, docks, sims, infers))
    "tiny": (56, (2, 8, 8, 12)),
    "medium": (448, (3, 24, 24, 48)),
    "full": (1792, (4, 48, 48, 96)),
}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true", help="paper sizes (1792 tasks)")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_eval.json")
    ap.add_argument("--html", default="reports/eval.html")
    args = ap.parse_args(argv)
    size = "tiny" if args.tiny else "full" if args.full else "medium"
    n_syn, (waves, docks, sims, infers) = SIZES[size]
    t0 = time.perf_counter()

    # --- 1. synthetic EDP workload ------------------------------------
    syn = synthetic_edp_workload(n_tasks=n_syn, seed=args.seed)
    syn_res = evaluate_trace(syn, alpha=args.alpha, seed=args.seed)
    print(eval_text_report(syn_res))
    mhra = syn_res.row("mhra")
    sites = syn_res.single_site_rows()
    best_site = min(sites, key=lambda r: r.edp)
    worst_site = max(sites, key=lambda r: r.edp)
    edp_vs_best = mhra.edp / best_site.edp
    print(f"\nMHRA EDP vs best single site ({best_site.policy}): "
          f"{edp_vs_best:.2f}x   vs worst ({worst_site.policy}): "
          f"{mhra.edp / worst_site.edp:.2f}x  (paper: 0.55x on the "
          f"full workload)")
    assert mhra.edp <= best_site.edp * (1 + 1e-9), (
        f"MHRA EDP {mhra.edp:.3e} exceeds best single-site "
        f"{best_site.policy} {best_site.edp:.3e}"
    )
    assert mhra.edp < worst_site.edp, "MHRA must beat the worst single site"

    # --- 2. molecular-design DAG --------------------------------------
    dag = moldesign_dag_workload(
        waves=waves, docks_per_wave=docks, sims_per_wave=sims,
        infers_per_wave=infers, seed=args.seed,
    )
    dag_res = evaluate_trace(dag, alpha=0.3, seed=args.seed)
    print()
    print(eval_text_report(dag_res))

    delta_run, delta_windows = run_policy(
        dag, "mhra", engine="delta", alpha=0.3, seed=args.seed,
        return_windows=True,
    )
    soa_run = run_policy(dag, "mhra", engine="soa", alpha=0.3, seed=args.seed)
    edges = verify_dag_order(delta_windows)
    assert delta_run.assignments == soa_run.assignments, (
        "delta and soa engines diverged on the DAG workload"
    )
    print(f"\nDAG: {edges} dependency edges honored; delta/soa engines "
          f"agree on all {len(delta_run.assignments)} assignments "
          f"({delta_run.windows} windows)")

    # --- persist + render ---------------------------------------------
    payload = write_bench_json(
        [syn_res, dag_res], path=args.out,
        extra={
            "size": size,
            "dag_edges_checked": edges,
            "dag_engine_parity": True,
            "mhra_edp_vs_best_site": edp_vs_best,
        },
    )
    eval_html_report([syn_res, dag_res], args.html)
    print(f"\nwrote {args.out} and {args.html} "
          f"({time.perf_counter() - t0:.1f}s)")
    return payload


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
