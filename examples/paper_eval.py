"""Paper-faithful end-to-end evaluation: the comparison tables the
GreenFaaS claims rest on, reproduced from one command.

Runs the same trace under every policy plus per-endpoint single-site
baselines and reports EDP + GPS-UP (Greenup/Speedup/Powerup) against the
best single site:

1. **Synthetic EDP workload** (§IV-B.1 / Table V): mixed
   compute/memory/IO SeBS-style functions, Poisson arrivals, Table-I
   testbed.  Gate: MHRA's EDP <= the best single-site baseline's.
2. **Molecular-design DAG** (§IV-B.2 / Fig. 9): dock -> simulate ->
   train -> infer with data dependencies through the online engine's
   ready-set.  Gates: every DAG edge honored in the executed records
   (under the myopic *and* the lookahead policy), ``engine="delta"`` /
   ``engine="soa"`` assignment-identical for both policies, and —
   at medium/full sizes — ``lookahead_mhra`` (DAG-aware rank-weighted
   scoring + data-gravity credits over the planning graph) strictly
   beats myopic ``mhra`` on EDP.  Rows carry the critical-path speedup
   (CP lower bound / makespan) and EDP-vs-mhra columns.
3. **Carbon scenario** (``--carbon``): the diurnal synthetic workload
   spread over one grid-intensity "day" with per-endpoint carbon traces.
   Gates: ``carbon_mhra`` (carbon-weighted objective + bounded temporal
   deferral) emits *strictly less* gCO2 than plain MHRA at a makespan
   within ``MAKESPAN_BOUND``; delta/soa stay assignment-identical under
   carbon weighting.
4. **Chaos scenario** (``--faults``): the synthetic workload on a
   warm-pool fleet under a seeded endpoint-churn script (plus straggler
   inflation + speculative re-execution).  Gates: an *empty* fault trace
   is a bitwise no-op (identical assignments and energy to a fault-free
   run, goodput 1.0); under churn both fault-aware and fault-oblivious
   MHRA finish everything (goodput 1.0, retries bounded), the oblivious
   baseline burns real re-execution energy, and fault-aware MHRA wins
   strictly on goodput-per-megajoule; delta/soa stay
   assignment-identical under the alive mask + warm-pool weights.

5. **Multi-tenant scenario** (``--multiuser``): a Zipf user population
   (100k simulated principals) submitting bursty per-user campaigns,
   with a per-user energy-budget ledger + shed admission control armed
   on the fair row.  Gates: ``fair_mhra`` shows *strictly lower*
   per-user EDP dispersion (CoV down, Jain index up) than plain MHRA at
   a global EDP within ``MU_EDP_BAND``; every shed task is recorded
   (goodput accounts for exactly the shed count); the deferring variant
   drops nothing (goodput 1.0); delta/soa stay assignment-identical
   with the fairness register + admission armed.

6. **Geo-distributed scenario** (``--geo``): the synthetic mix streamed
   at a three-region federation (per-region carbon grids, measured-style
   WAN links, caller locality), replayed under the A/B/C protocol —
   fixed region (A) vs caller region (B) vs the carbon/WAN-aware agent
   (C) on the *same* trace.  Gates: the agent emits *strictly less* gCO2
   than both baselines at an EDP no worse than either, with makespan
   inside ``GEO_MAKESPAN_BAND``; a single all-endpoint region is a
   bitwise no-op vs ``regions=None``; delta/soa stay
   assignment-identical with the region layer armed.

Results are persisted to ``BENCH_eval.json`` and rendered to
``reports/eval.html`` via ``repro.core.report``.  Runnable bare from the
repo root (no PYTHONPATH needed):

    python examples/paper_eval.py                # medium sizes
    python examples/paper_eval.py --tiny --carbon --faults --multiuser --geo
    python examples/paper_eval.py --full --carbon --faults  # paper sizes
"""
from __future__ import annotations

import argparse
import dataclasses
import pathlib
import sys
import time

try:
    import repro  # noqa: F401
except ModuleNotFoundError:  # bare run from a checkout: add src/ ourselves
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core.evaluate import (
    EvalResult, evaluate_trace, gpsup, run_policy, verify_dag_order,
)
from repro.core.faults import FaultTrace
from repro.core.region import RegionRouter, RegionSpec
from repro.core.report import eval_html_report, eval_text_report, write_bench_json
from repro.core.fairness import FairShare
from repro.workloads import (
    add_failover,
    churn_fault_trace,
    geo_edp_workload,
    moldesign_dag_workload,
    multiuser_edp_workload,
    synthetic_edp_workload,
    table1_carbon_signal,
    with_warm_pool,
)

SIZES = {
    # name: (synthetic n_tasks, dag (waves, docks, sims, infers))
    "tiny": (56, (2, 8, 8, 12)),
    "medium": (448, (3, 24, 24, 48)),
    "full": (1792, (4, 48, 48, 96)),
}

CARBON_PERIOD_S = 600.0     # compressed grid "day" (matches diurnal arrivals)
DEFER_HORIZON_S = 120.0     # how far carbon_mhra may shift work in time
MAKESPAN_BOUND = 1.25       # carbon_mhra makespan <= bound * plain MHRA's
# deadline slack factors U(lo, hi) x fleet-mean runtime past the earliest
# plausible completion — generous enough that misses measure scheduling
# quality, and that the carbon deferral queue keeps real slack to spend
DEADLINE_SLACK = (8.0, 40.0)

# chaos scenario (--faults): target dead fraction per churned endpoint,
# straggler mix, and the speculative re-execution trigger.  The always-on
# desktop — the small-task magnet — is deliberately *not* protected, so a
# fault-oblivious policy keeps feeding a dead endpoint; "ic" never fails,
# keeping the fleet placeable at all times.
FAULT_CHURN = 0.10
FAULT_CHURNED = ("desktop",)   # outages hit the always-on home node — the
                               # fleet's placement magnet and data home, where
                               # blind re-dispatch hurts most; batch sites
                               # already absorb delay through their queues
FAULT_ARRIVAL_SLOWDOWN = 4.0   # chaos runs at service load (shallow queues):
                               # at saturation every policy's backlog rides
                               # into outages identically and the scenario
                               # measures queueing, not fault handling
FAULT_STRAGGLER_P = 0.08
FAULT_STRAGGLER_X = 4.0
SPEC_FACTOR = 3.0

# multi-tenant scenario (--multiuser): one campaign shape across sizes —
# only the task count scales, so tiny smoke and paper-size runs exercise
# the same contention regime.  The budget is sized so a handful of
# heavy Zipf-head tenants overdraw within a couple of bursts while the
# long tail (a task or two each) never accrues debt.
MU_SIZES = {"tiny": 256, "medium": 512, "full": 1792}
MU_USERS = 100_000          # simulated principal universe (Zipf-sampled)
MU_BURST = 32               # tasks per per-user burst
MU_RATE_HZ = 50.0           # intra-burst submission rate
MU_GAP_S = 45.0             # gap between a user's bursts
MU_SPAN_S = 180.0           # campaign-start spread across users
MU_BUDGET_J = 150.0         # per-user energy budget per ledger window
MU_WINDOW_S = 30.0          # ledger replenish window
MU_MU = 0.5                 # advantage-tax strength on over-budget users
MU_EDP_BAND = 1.05          # fair row's global EDP <= band x plain MHRA

# geo scenario (--geo): the A/B/C protocol replays one trace under three
# router modes; the agent must win on carbon without losing the race.
# The geo workload streams at moderate load by default, so makespan is
# arrival-dominated and near-identical across modes — the band only has
# to absorb tail-task placement jitter.
GEO_SIZES = {"tiny": 56, "medium": 448, "full": 1792}
GEO_MAKESPAN_BAND = 1.05    # agent makespan <= band x best baseline's


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--tiny", action="store_true", help="CI smoke sizes")
    ap.add_argument("--full", action="store_true", help="paper sizes (1792 tasks)")
    ap.add_argument("--carbon", action="store_true",
                    help="run the carbon-aware scenario (gCO2 + deferral gates)")
    ap.add_argument("--faults", action="store_true",
                    help="run the chaos scenario (churn/goodput/reexec gates)")
    ap.add_argument("--multiuser", action="store_true",
                    help="run the multi-tenant scenario (fairness gates)")
    ap.add_argument("--geo", action="store_true",
                    help="run the geo-distributed scenario (A/B/C gates)")
    ap.add_argument("--alpha", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_eval.json")
    ap.add_argument("--html", default="reports/eval.html")
    args = ap.parse_args(argv)
    size = "tiny" if args.tiny else "full" if args.full else "medium"
    n_syn, (waves, docks, sims, infers) = SIZES[size]
    t0 = time.perf_counter()

    # --- 1. synthetic EDP workload ------------------------------------
    syn = synthetic_edp_workload(n_tasks=n_syn, seed=args.seed,
                                 deadline_slack=DEADLINE_SLACK)
    syn_res = evaluate_trace(syn, alpha=args.alpha, seed=args.seed)
    print(eval_text_report(syn_res))
    mhra = syn_res.row("mhra")
    sites = syn_res.single_site_rows()
    best_site = min(sites, key=lambda r: r.edp)
    worst_site = max(sites, key=lambda r: r.edp)
    edp_vs_best = mhra.edp / best_site.edp
    print(f"\nMHRA EDP vs best single site ({best_site.policy}): "
          f"{edp_vs_best:.2f}x   vs worst ({worst_site.policy}): "
          f"{mhra.edp / worst_site.edp:.2f}x  (paper: 0.55x on the "
          f"full workload)")
    assert mhra.edp <= best_site.edp * (1 + 1e-9), (
        f"MHRA EDP {mhra.edp:.3e} exceeds best single-site "
        f"{best_site.policy} {best_site.edp:.3e}"
    )
    assert mhra.edp < worst_site.edp, "MHRA must beat the worst single site"
    # engine parity: the fused jax scan must place every synthetic task
    # exactly where the soa greedy does
    syn_soa = run_policy(syn, "mhra", engine="soa", alpha=args.alpha,
                         seed=args.seed)
    syn_jax = run_policy(syn, "mhra", engine="jax", alpha=args.alpha,
                         seed=args.seed)
    assert syn_soa.assignments == syn_jax.assignments, (
        "soa and jax engines diverged on the synthetic workload"
    )
    print(f"synthetic engine parity: soa/jax agree on all "
          f"{len(syn_jax.assignments)} assignments")

    # --- 2. molecular-design DAG --------------------------------------
    dag = moldesign_dag_workload(
        waves=waves, docks_per_wave=docks, sims_per_wave=sims,
        infers_per_wave=infers, seed=args.seed,
        deadline_slack=DEADLINE_SLACK,
    )
    dag_res = evaluate_trace(
        dag, policies=("mhra", "cluster_mhra", "lookahead_mhra",
                       "round_robin"),
        alpha=0.3, seed=args.seed,
    )
    print()
    print(eval_text_report(dag_res))

    delta_run, delta_windows = run_policy(
        dag, "mhra", engine="delta", alpha=0.3, seed=args.seed,
        return_windows=True,
    )
    soa_run = run_policy(dag, "mhra", engine="soa", alpha=0.3, seed=args.seed)
    edges = verify_dag_order(delta_windows)
    assert delta_run.assignments == soa_run.assignments, (
        "delta and soa engines diverged on the DAG workload"
    )
    look_delta, look_windows = run_policy(
        dag, "lookahead_mhra", engine="delta", alpha=0.3, seed=args.seed,
        return_windows=True,
    )
    look_soa = run_policy(dag, "lookahead_mhra", engine="soa", alpha=0.3,
                          seed=args.seed)
    look_edges = verify_dag_order(look_windows)
    assert look_delta.assignments == look_soa.assignments, (
        "delta and soa engines diverged under lookahead scoring"
    )
    jax_run = run_policy(dag, "mhra", engine="jax", alpha=0.3,
                         seed=args.seed)
    assert jax_run.assignments == soa_run.assignments, (
        "soa and jax engines diverged on the DAG workload"
    )
    look_jax = run_policy(dag, "lookahead_mhra", engine="jax", alpha=0.3,
                          seed=args.seed)
    assert look_jax.assignments == look_soa.assignments, (
        "soa and jax engines diverged under lookahead scoring"
    )
    print(f"\nDAG: {edges} dependency edges honored ({look_edges} under "
          f"lookahead); delta/soa/jax engines agree on all "
          f"{len(delta_run.assignments)} assignments for both policies")

    look_row = dag_res.row("lookahead_mhra")
    myopic_row = dag_res.row("mhra")
    look_ratio = look_row.edp / myopic_row.edp
    print(f"lookahead_mhra EDP {look_ratio:.3f}x myopic MHRA "
          f"(cp-speedup {look_row.cp_speedup:.3f} vs "
          f"{myopic_row.cp_speedup:.3f})")
    if size != "tiny":
        # the planning graph pays off once stages are wide enough to
        # overlap; at smoke size the DAG is too small to matter
        assert look_row.edp < myopic_row.edp, (
            f"lookahead_mhra EDP {look_row.edp:.3e} not strictly below "
            f"myopic MHRA {myopic_row.edp:.3e}"
        )

    # --- 3. carbon-aware scenario (--carbon) --------------------------
    results = [syn_res, dag_res]
    extra = {
        "size": size,
        "dag_edges_checked": edges,
        "dag_engine_parity": True,
        "jax_engine_parity": True,
        "mhra_edp_vs_best_site": edp_vs_best,
        "lookahead_engine_parity": True,
        "lookahead_edp_vs_mhra": look_ratio,
        "lookahead_cp_speedup": look_row.cp_speedup,
        "dag_deadline_miss_rate": myopic_row.deadline_miss_rate,
    }
    if args.carbon:
        # diurnal arrivals stretched over at least ~one grid "day" so
        # windows hit both the dirty ramp and the clean trough; the rate
        # cap keeps endpoint utilization moderate at paper size (larger
        # sizes span more "days" instead of saturating the fleet, which
        # would leave no spare capacity in the clean windows)
        peak_hz = min(n_syn / 300.0, 1.5)
        car = synthetic_edp_workload(
            n_tasks=n_syn, arrival="diurnal", seed=args.seed,
            period_s=CARBON_PERIOD_S, peak_rate_hz=peak_hz,
            trough_rate_hz=peak_hz / 16.0,
            deadline_slack=DEADLINE_SLACK,
        )
        sig = table1_carbon_signal(seed=args.seed, period_s=CARBON_PERIOD_S)
        car_res = evaluate_trace(
            car, policies=("mhra", "cluster_mhra", "carbon_mhra", "round_robin"),
            carbon=sig, defer_horizon_s=DEFER_HORIZON_S,
            alpha=args.alpha, seed=args.seed,
        )
        print()
        print(eval_text_report(car_res))
        plain = car_res.row("mhra")
        cm = car_res.row("carbon_mhra")
        g_ratio = cm.carbon_g / plain.carbon_g
        ms_ratio = cm.makespan_s / plain.makespan_s
        print(f"\ncarbon_mhra gCO2 {cm.carbon_g:.2f} vs MHRA "
              f"{plain.carbon_g:.2f} ({g_ratio:.3f}x, {cm.deferred} tasks "
              f"deferred); makespan {ms_ratio:.3f}x (bound "
              f"{MAKESPAN_BOUND:.2f}x)")
        assert cm.carbon_g < plain.carbon_g, (
            f"carbon_mhra gCO2 {cm.carbon_g:.3f} not strictly below plain "
            f"MHRA {plain.carbon_g:.3f}"
        )
        assert cm.makespan_s <= plain.makespan_s * MAKESPAN_BOUND, (
            f"carbon_mhra makespan {cm.makespan_s:.1f}s exceeds "
            f"{MAKESPAN_BOUND}x plain MHRA's {plain.makespan_s:.1f}s"
        )
        # engine parity must survive carbon weighting + deferral
        cm_delta = run_policy(car, "carbon_mhra", engine="delta",
                              alpha=args.alpha, seed=args.seed, carbon=sig,
                              defer_horizon_s=DEFER_HORIZON_S)
        cm_soa = run_policy(car, "carbon_mhra", engine="soa",
                            alpha=args.alpha, seed=args.seed, carbon=sig,
                            defer_horizon_s=DEFER_HORIZON_S)
        assert cm_delta.assignments == cm_soa.assignments, (
            "delta and soa engines diverged under carbon weighting"
        )
        cm_jax = run_policy(car, "carbon_mhra", engine="jax",
                            alpha=args.alpha, seed=args.seed, carbon=sig,
                            defer_horizon_s=DEFER_HORIZON_S)
        assert cm_jax.assignments == cm_soa.assignments, (
            "soa and jax engines diverged under carbon weighting"
        )
        print(f"carbon engine parity: delta/soa/jax agree on all "
              f"{len(cm_delta.assignments)} assignments")
        results.append(car_res)
        extra.update({
            "carbon_gco2_ratio": g_ratio,
            "carbon_makespan_ratio": ms_ratio,
            "carbon_deferred": cm.deferred,
            "carbon_engine_parity": True,
            "carbon_deadline_miss_rate": cm.deadline_miss_rate,
        })

    # --- 4. chaos scenario (--faults) ---------------------------------
    if args.faults:
        # gate 1: an empty fault trace must be a bitwise no-op
        base = run_policy(syn, "mhra", engine="delta", alpha=args.alpha,
                          seed=args.seed)
        noop = run_policy(syn, "mhra", engine="delta", alpha=args.alpha,
                          seed=args.seed, faults=FaultTrace.empty())
        assert noop.assignments == base.assignments, (
            "empty fault trace changed placements"
        )
        assert noop.energy_j == base.energy_j, (
            f"empty fault trace changed energy: {noop.energy_j!r} vs "
            f"{base.energy_j!r}"
        )
        assert noop.goodput == 1.0 and noop.failures == 0
        print("\nfault no-op gate: empty trace bitwise-identical to a "
              "fault-free run (goodput 1.0)")

        # chaos trace: same workload on a warm-pool fleet plus an
        # always-on failover twin of the desktop ("login"); every other
        # endpoint churns, with outages scripted inside the fault-free
        # run's actual busy span.  A fault-aware policy fails over to the
        # login node for a small premium; a fault-oblivious one keeps
        # re-dispatching into the outage and re-bills each attempt.
        ch_eps, ch_prof = add_failover(with_warm_pool(syn.endpoints),
                                       syn.profiles)
        cha = dataclasses.replace(
            syn, name=syn.name + "_chaos",
            endpoints=ch_eps, profiles=ch_prof,
            arrivals=syn.arrivals * FAULT_ARRIVAL_SLOWDOWN,
        )
        # script outages inside the chaos trace's own fault-free busy span
        ch_base = run_policy(cha, "mhra", engine="delta", alpha=args.alpha,
                             seed=args.seed)
        horizon = float(ch_base.sim_makespan_s)
        # longer-than-trivial outages: fault-aware failover pays a one-time
        # staging cost (the io dataset gets cached at the failover site)
        # while blind re-dispatch keeps burning idle span for the whole
        # outage — short blips would hide that asymmetry
        mttr = min(max(horizon / 2.5, 60.0), 300.0)
        ft = churn_fault_trace(
            [e.name for e in cha.endpoints], horizon,
            churn=FAULT_CHURN, mttr_s=mttr, seed=args.seed,
            protect=[e.name for e in cha.endpoints
                     if e.name not in FAULT_CHURNED],
            straggler_p=FAULT_STRAGGLER_P,
            straggler_factor=FAULT_STRAGGLER_X,
        )
        aware = run_policy(cha, "mhra", engine="delta", alpha=args.alpha,
                           seed=args.seed, faults=ft, fault_aware=True,
                           spec_factor=SPEC_FACTOR)
        obliv = run_policy(cha, "mhra", engine="delta", alpha=args.alpha,
                           seed=args.seed, faults=ft, fault_aware=False,
                           spec_factor=SPEC_FACTOR)
        aware.policy = "mhra_fault_aware"
        obliv.policy = "mhra_fault_oblivious"
        for r in (aware, obliv):
            g, s_, u = gpsup(obliv.energy_j, obliv.makespan_s,
                             r.energy_j, r.makespan_s)
            r.greenup, r.speedup, r.powerup = g, s_, u
        flt_res = EvalResult(
            workload=cha.name, n_tasks=len(cha), alpha=args.alpha,
            rows=[aware, obliv], baseline="mhra_fault_oblivious",
        )
        print()
        print(eval_text_report(flt_res))
        gpj_ratio = (aware.goodput_per_mj / obliv.goodput_per_mj
                     if obliv.goodput_per_mj > 0 else float("inf"))
        print(f"\nchaos ({FAULT_CHURN:.0%} churn, mttr {mttr:.0f}s): "
              f"fault-aware gp/MJ {aware.goodput_per_mj:.2f} vs oblivious "
              f"{obliv.goodput_per_mj:.2f} ({gpj_ratio:.3f}x); oblivious "
              f"wasted {obliv.reexec_j / 1e3:.2f} kJ on {obliv.failures} "
              f"kills, aware {aware.reexec_j / 1e3:.2f} kJ on "
              f"{aware.failures}")
        assert aware.goodput == 1.0, (
            f"fault-aware goodput {aware.goodput:.3f} != 1.0 "
            f"(lost tasks under churn)"
        )
        assert obliv.goodput == 1.0, (
            f"fault-oblivious goodput {obliv.goodput:.3f} != 1.0 "
            f"(retry budget exhausted)"
        )
        assert obliv.reexec_j > 0.0, (
            "chaos trace produced no re-execution energy: churn never "
            "caught an in-flight or misplaced task"
        )
        assert aware.goodput_per_mj > obliv.goodput_per_mj, (
            f"fault-aware MHRA gp/MJ {aware.goodput_per_mj:.3f} not "
            f"strictly above oblivious {obliv.goodput_per_mj:.3f}"
        )
        # engine parity must survive the alive mask + warm-pool weights
        aware_soa = run_policy(cha, "mhra", engine="soa", alpha=args.alpha,
                               seed=args.seed, faults=ft, fault_aware=True,
                               spec_factor=SPEC_FACTOR)
        assert aware.assignments == aware_soa.assignments, (
            "delta and soa engines diverged under the fault mask"
        )
        aware_jax = run_policy(cha, "mhra", engine="jax", alpha=args.alpha,
                               seed=args.seed, faults=ft, fault_aware=True,
                               spec_factor=SPEC_FACTOR)
        assert aware_jax.assignments == aware_soa.assignments, (
            "soa and jax engines diverged under the fault mask"
        )
        print(f"fault engine parity: delta/soa/jax agree on all "
              f"{len(aware.assignments)} assignments")
        results.append(flt_res)
        extra.update({
            "fault_noop_parity": True,
            "fault_engine_parity": True,
            "fault_churn": FAULT_CHURN,
            "fault_mttr_s": mttr,
            "fault_goodput_aware": aware.goodput,
            "fault_goodput_oblivious": obliv.goodput,
            "fault_gpj_ratio": gpj_ratio,
            "fault_reexec_j_aware": aware.reexec_j,
            "fault_reexec_j_oblivious": obliv.reexec_j,
            "fault_cold_starts_aware": aware.cold_starts,
            "fault_spec_launched": aware.spec_launched,
        })

    # --- 5. multi-tenant scenario (--multiuser) -----------------------
    if args.multiuser:
        mu_n = MU_SIZES[size]
        mu = multiuser_edp_workload(
            n_tasks=mu_n, n_users=MU_USERS, seed=args.seed,
            burst_size=MU_BURST, burst_rate_hz=MU_RATE_HZ,
            gap_s=MU_GAP_S, campaign_span_s=MU_SPAN_S,
        )
        share = FairShare(budget_j=MU_BUDGET_J, window_s=MU_WINDOW_S,
                          mu=MU_MU)
        plain = run_policy(mu, "mhra", alpha=args.alpha, seed=args.seed)
        fair = run_policy(mu, "mhra", alpha=args.alpha, seed=args.seed,
                          fairness=share, admission="shed",
                          label="fair_mhra")
        defer = run_policy(mu, "mhra", alpha=args.alpha, seed=args.seed,
                           fairness=share, admission="defer",
                           label="fair_mhra_defer")
        for r in (fair, defer):
            g, s_, u = gpsup(plain.energy_j, plain.makespan_s,
                             r.energy_j, r.makespan_s)
            r.greenup, r.speedup, r.powerup = g, s_, u
        mu_res = EvalResult(
            workload=mu.name, n_tasks=mu_n, alpha=args.alpha,
            rows=[plain, fair, defer], baseline="mhra",
        )
        print()
        print(eval_text_report(mu_res))
        edp_band = fair.edp / plain.edp
        print(f"\nmultiuser ({mu.meta['users_active']} active tenants of "
              f"{MU_USERS}, top share {mu.meta['top_user_share']:.0%}): "
              f"fair_mhra EDP CoV {fair.user_edp_cov:.3f} vs plain "
              f"{plain.user_edp_cov:.3f}, Jain {fair.jain_index:.3f} vs "
              f"{plain.jain_index:.3f}, global EDP {edp_band:.3f}x "
              f"(band {MU_EDP_BAND:.2f}x), {fair.shed} shed")
        assert fair.user_edp_cov < plain.user_edp_cov, (
            f"fair_mhra per-user EDP CoV {fair.user_edp_cov:.4f} not "
            f"strictly below plain MHRA's {plain.user_edp_cov:.4f}"
        )
        assert fair.jain_index > plain.jain_index, (
            f"fair_mhra Jain index {fair.jain_index:.4f} not strictly "
            f"above plain MHRA's {plain.jain_index:.4f}"
        )
        assert edp_band <= MU_EDP_BAND, (
            f"fair_mhra global EDP {edp_band:.3f}x plain MHRA exceeds "
            f"the {MU_EDP_BAND:.2f}x band"
        )
        # shed accounting: every rejected task is recorded, none vanish
        assert fair.shed > 0, "fair_mhra shed nothing: admission never engaged"
        assert abs(fair.goodput - (1.0 - fair.shed / mu_n)) < 1e-9, (
            f"shed accounting leak: goodput {fair.goodput:.6f} vs "
            f"{fair.shed} shed of {mu_n}"
        )
        # the deferring variant trades latency, never tasks
        assert defer.shed == 0 and defer.goodput == 1.0, (
            f"defer admission dropped work: shed={defer.shed} "
            f"goodput={defer.goodput:.3f}"
        )
        # engine parity must survive the fairness register + admission
        fair_soa = run_policy(mu, "mhra", engine="soa", alpha=args.alpha,
                              seed=args.seed, fairness=share,
                              admission="shed", label="fair_mhra")
        assert fair.assignments == fair_soa.assignments, (
            "delta and soa engines diverged under fairness weighting"
        )
        fair_jax = run_policy(mu, "mhra", engine="jax", alpha=args.alpha,
                              seed=args.seed, fairness=share,
                              admission="shed", label="fair_mhra")
        assert fair_jax.assignments == fair_soa.assignments, (
            "soa and jax engines diverged under fairness weighting"
        )
        print(f"fairness engine parity: delta/soa/jax agree on all "
              f"{len(fair.assignments)} assignments")
        results.append(mu_res)
        extra.update({
            "multiuser_fair_gate": True,
            "multiuser_engine_parity": True,
            "multiuser_users_active": mu.meta["users_active"],
            "multiuser_top_user_share": mu.meta["top_user_share"],
            "multiuser_jain_plain": plain.jain_index,
            "multiuser_jain_fair": fair.jain_index,
            "multiuser_cov_plain": plain.user_edp_cov,
            "multiuser_cov_fair": fair.user_edp_cov,
            "multiuser_edp_band": edp_band,
            "multiuser_shed": fair.shed,
            "multiuser_deferred": defer.admission_deferred,
        })

    # --- 6. geo-distributed scenario (--geo) --------------------------
    if args.geo:
        geo = geo_edp_workload(n_tasks=GEO_SIZES[size], seed=args.seed)
        specs = geo.meta["region_specs"]
        gsig = geo.meta["carbon_signal"]

        def geo_run(mode, engine="delta"):
            # fresh router per run: modes share zero routing state, only
            # the trace objects (the A/B/C contract)
            router = RegionRouter(specs, mode=mode, home=specs[0].name)
            return run_policy(geo, "mhra", engine=engine, alpha=args.alpha,
                              seed=args.seed, carbon=gsig, regions=router,
                              label=f"geo_{mode}")

        fixed = geo_run("fixed")      # A: everything to the home region
        caller = geo_run("caller")    # B: everything to the caller's region
        agnt = geo_run("agent")       # C: carbon + WAN + congestion score
        for r in (caller, agnt):
            g, s_, u = gpsup(fixed.energy_j, fixed.makespan_s,
                             r.energy_j, r.makespan_s)
            r.greenup, r.speedup, r.powerup = g, s_, u
        geo_res = EvalResult(
            workload=geo.name, n_tasks=len(geo), alpha=args.alpha,
            rows=[fixed, caller, agnt], baseline="geo_fixed",
        )
        print()
        print(eval_text_report(geo_res))
        g_vs_fixed = agnt.carbon_g / fixed.carbon_g
        g_vs_caller = agnt.carbon_g / caller.carbon_g
        edp_vs_fixed = agnt.edp / fixed.edp
        edp_vs_caller = agnt.edp / caller.edp
        mk_best = min(fixed.makespan_s, caller.makespan_s)
        mk_band = agnt.makespan_s / mk_best
        print(f"\ngeo A/B/C ({len(specs)} regions): agent gCO2 "
              f"{agnt.carbon_g:.3f} vs fixed {fixed.carbon_g:.3f} "
              f"({g_vs_fixed:.3f}x) / caller {caller.carbon_g:.3f} "
              f"({g_vs_caller:.3f}x); EDP {edp_vs_fixed:.3f}x fixed, "
              f"{edp_vs_caller:.3f}x caller; makespan {mk_band:.3f}x best "
              f"baseline (band {GEO_MAKESPAN_BAND:.2f}x); WAN "
              f"{agnt.wan_j / 1e3:.3f} kJ, egress "
              f"{agnt.egress_bytes / 1e9:.3f} GB")
        assert agnt.carbon_g < fixed.carbon_g, (
            f"agent gCO2 {agnt.carbon_g:.3f} not strictly below "
            f"fixed-region baseline {fixed.carbon_g:.3f}"
        )
        assert agnt.carbon_g < caller.carbon_g, (
            f"agent gCO2 {agnt.carbon_g:.3f} not strictly below "
            f"caller-region baseline {caller.carbon_g:.3f}"
        )
        assert agnt.edp <= fixed.edp and agnt.edp <= caller.edp, (
            f"agent EDP {agnt.edp:.3e} worse than a baseline "
            f"(fixed {fixed.edp:.3e}, caller {caller.edp:.3e})"
        )
        assert mk_band <= GEO_MAKESPAN_BAND, (
            f"agent makespan {agnt.makespan_s:.1f}s exceeds "
            f"{GEO_MAKESPAN_BAND:.2f}x best baseline {mk_best:.1f}s"
        )
        # gate: a single all-endpoint region is a bitwise no-op — the
        # router's mask collapses to None and every engine path is
        # untouched (no WAN, no egress, identical placements + energy)
        solo = [RegionSpec("global",
                           tuple(e.name for e in geo.endpoints))]
        base = run_policy(geo, "mhra", engine="delta", alpha=args.alpha,
                          seed=args.seed, carbon=gsig)
        noop = run_policy(geo, "mhra", engine="delta", alpha=args.alpha,
                          seed=args.seed, carbon=gsig, regions=solo)
        assert noop.assignments == base.assignments, (
            "single-region layer changed placements"
        )
        assert noop.energy_j == base.energy_j, (
            f"single-region layer changed energy: {noop.energy_j!r} vs "
            f"{base.energy_j!r}"
        )
        assert noop.wan_j == 0.0 and noop.egress_bytes == 0.0
        print("geo no-op gate: single-region fleet bitwise-identical to "
              "regions=None (zero WAN joules)")
        # engine parity must survive the region mask + WAN delays
        agnt_soa = geo_run("agent", engine="soa")
        assert agnt.assignments == agnt_soa.assignments, (
            "delta and soa engines diverged under the region layer"
        )
        agnt_jax = geo_run("agent", engine="jax")
        assert agnt_jax.assignments == agnt_soa.assignments, (
            "soa and jax engines diverged under the region layer"
        )
        print(f"geo engine parity: delta/soa/jax agree on all "
              f"{len(agnt.assignments)} assignments")
        results.append(geo_res)
        extra.update({
            "geo_regions": len(specs),
            "geo_gco2_vs_fixed": g_vs_fixed,
            "geo_gco2_vs_caller": g_vs_caller,
            "geo_edp_vs_fixed": edp_vs_fixed,
            "geo_edp_vs_caller": edp_vs_caller,
            "geo_makespan_band": mk_band,
            "geo_engine_parity": True,
            "geo_single_region_noop": True,
            "geo_wan_kj_agent": agnt.wan_j / 1e3,
            "geo_egress_gb_agent": agnt.egress_bytes / 1e9,
        })

    # --- persist + render ---------------------------------------------
    payload = write_bench_json(results, path=args.out, extra=extra)
    eval_html_report(results, args.html)
    print(f"\nwrote {args.out} and {args.html} "
          f"({time.perf_counter() - t0:.1f}s)")
    return payload


if __name__ == "__main__":
    sys.exit(0 if main() else 1)
