"""GreenFaaS quickstart: monitor, attribute, and schedule a task batch
across the paper's four-machine testbed — then print the energy report.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core.endpoint import table1_testbed
from repro.core.executor import GreenFaaSExecutor
from repro.core.report import text_report
from repro.core.scheduler import TaskSpec
from repro.core.testbed import SEBS_FUNCTIONS, TestbedSim


def main() -> None:
    endpoints = table1_testbed()
    backend = TestbedSim(endpoints, seed=0)

    # alpha trades energy (1.0) against runtime (0.0) — paper Fig. 6;
    # strategy is any registered policy name (repro.core.available_policies())
    executor = GreenFaaSExecutor(
        endpoints, backend, alpha=0.2, strategy="cluster_mhra"
    )
    # seed online profiles (the paper builds them from prior monitoring)
    executor.warmup(list(SEBS_FUNCTIONS), per_endpoint=2)

    tasks = [
        TaskSpec(id=f"t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)],
                 inputs=(("desktop", 1, 100e6, True),))
        for i in range(200)
    ]
    result = executor.run_batch(tasks)

    print(f"makespan      : {result.makespan_s:8.1f} s")
    print(f"energy        : {result.measured_energy_j / 1e3:8.1f} kJ "
          f"(attributed to tasks: {result.attributed_energy_j / 1e3:.1f} kJ)")
    print(f"transfer      : {result.transfer_j / 1e3:8.2f} kJ")
    print(f"scheduling in : {result.scheduling_s * 1e3:8.1f} ms "
          f"({result.scheduling_s / len(tasks) * 1e3:.2f} ms/task)")
    print(f"EDP           : {result.edp():8.3e}")
    print()
    print(text_report(executor.db, user="user0"))


if __name__ == "__main__":
    main()
