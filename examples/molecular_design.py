"""Molecular-design active-learning workflow (paper §IV-B.2 / Fig. 9),
with REAL JAX compute for the ML stages: the surrogate model is trained
and evaluated in JAX while GreenFaaS schedules every wave across machines.

The search: find x maximizing an (expensive, simulated) 'ionization
energy' f(x).  Each wave: quantum-chemistry simulations (sim-executed
tasks) -> surrogate training (real JAX) -> batched inference (real JAX)
-> pick next candidates.

    PYTHONPATH=src python examples/molecular_design.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))  # benchmarks/

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.molecular_design import MOLDESIGN_PROFILES, SIGS, _endpoints
from repro.core.executor import GreenFaaSExecutor
from repro.core.scheduler import TaskSpec
from repro.core.testbed import TestbedSim


def true_property(x):  # the 'quantum chemistry' ground truth
    return np.sin(3 * x[..., 0]) * np.cos(2 * x[..., 1]) + 0.5 * x[..., 2]


def init_mlp(rng, dims=(8, 64, 64, 1)):
    params = []
    for i, (a, b) in enumerate(zip(dims, dims[1:])):
        k1, rng = jax.random.split(rng)
        params.append((jax.random.normal(k1, (a, b)) / jnp.sqrt(a), jnp.zeros(b)))
    return params


def mlp(params, x):
    for w, b in params[:-1]:
        x = jax.nn.tanh(x @ w + b)
    w, b = params[-1]
    return (x @ w + b)[..., 0]


@jax.jit
def train_steps(params, X, y, lr=1e-2, steps=200):
    def loss_fn(p):
        return jnp.mean((mlp(p, X) - y) ** 2)

    def body(p, _):
        g = jax.grad(loss_fn)(p)
        p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        return p, loss_fn(p)

    params, losses = jax.lax.scan(body, params, jnp.arange(steps))
    return params, losses[-1]


def main(waves: int = 4, sims_per_wave: int = 48, pool: int = 4096) -> None:
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    endpoints = _endpoints()
    sim = TestbedSim(endpoints, profiles=MOLDESIGN_PROFILES, signatures=SIGS, seed=0)
    ex = GreenFaaSExecutor(endpoints, sim, alpha=0.3, strategy="cluster_mhra")
    ex.warmup(list(MOLDESIGN_PROFILES), per_endpoint=2)

    candidates = rng.uniform(-1, 1, size=(pool, 8))
    X_known = candidates[:sims_per_wave]
    y_known = true_property(X_known)
    params = init_mlp(key)
    tid, total_rt, total_e = 0, 0.0, 0.0
    best = float(y_known.max())

    for w in range(waves):
        # --- schedule this wave through GreenFaaS (sim time/energy) ---
        wave = [TaskSpec(id=f"s{tid + i}", fn="simulate") for i in range(sims_per_wave)]
        wave += [TaskSpec(id=f"t{tid}", fn="train"),
                 TaskSpec(id=f"i{tid}", fn="infer")]
        tid += len(wave)
        res = ex.run_batch(wave)
        total_rt += res.makespan_s
        total_e += res.measured_energy_j

        # --- real ML compute for train + infer stages ---
        params, mse = train_steps(
            params, jnp.asarray(X_known, jnp.float32), jnp.asarray(y_known, jnp.float32)
        )
        preds = mlp(params, jnp.asarray(candidates, jnp.float32))
        pick = np.asarray(jnp.argsort(-preds)[:sims_per_wave])
        X_new = candidates[pick]
        y_new = true_property(X_new)  # 'simulation' results
        X_known = np.concatenate([X_known, X_new])
        y_known = np.concatenate([y_known, y_new])
        best = max(best, float(y_new.max()))
        print(f"wave {w}: surrogate mse={float(mse):.4f}  best={best:.3f}  "
              f"wave_time={res.makespan_s:.1f}s  wave_energy={res.measured_energy_j/1e3:.1f}kJ")

    print(f"\ntotal (GreenFaaS cluster_mhra): {total_rt:.1f} s, {total_e/1e3:.1f} kJ")
    sched = res.schedule.assignments
    from collections import Counter

    print("last-wave placement:", dict(Counter(sched.values())))
    print(f"best molecule property found: {best:.3f} "
          f"(theoretical max ~{true_property(np.array([[0.52, 0.0, 1.0]+[0]*5]))[0]+0.5:.2f})")


if __name__ == "__main__":
    main()
