"""Molecular-design active-learning workflow (paper §IV-B.2 / Fig. 9) as
a real DAG through the online engine, with REAL JAX compute for the ML
stages.

Each wave of the campaign is a dependency graph

    dock -> simulate -> train -> infer -> (next wave's dock)

submitted to :class:`OnlineEngine` *up front*: the engine's ready-set
holds every task until its parents complete, sets its ready floor to the
latest parent completion, and bills the parent-to-child data transfers
from the endpoints that produced them.  GreenFaaS places each released
stage across {desktop, ic, faster}; meanwhile the surrogate model is
genuinely trained and evaluated in JAX to pick the next candidates (the
'simulation' ground truth is an analytic ionization-energy stand-in).

    PYTHONPATH=src python examples/molecular_design.py
"""
from collections import Counter

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.engine import OnlineEngine
from repro.core.evaluate import verify_dag_order, warm_store
from repro.core.testbed import TestbedSim
from repro.workloads import moldesign_dag_workload


def true_property(x):  # the 'quantum chemistry' ground truth
    return np.sin(3 * x[..., 0]) * np.cos(2 * x[..., 1]) + 0.5 * x[..., 2]


def init_mlp(rng, dims=(8, 64, 64, 1)):
    params = []
    for a, b in zip(dims, dims[1:]):
        k1, rng = jax.random.split(rng)
        params.append((jax.random.normal(k1, (a, b)) / jnp.sqrt(a), jnp.zeros(b)))
    return params


def mlp(params, x):
    for w, b in params[:-1]:
        x = jax.nn.tanh(x @ w + b)
    w, b = params[-1]
    return (x @ w + b)[..., 0]


@jax.jit
def train_steps(params, X, y, lr=1e-2, steps=200):
    def loss_fn(p):
        return jnp.mean((mlp(p, X) - y) ** 2)

    def body(p, _):
        g = jax.grad(loss_fn)(p)
        p = jax.tree.map(lambda a, b: a - lr * b, p, g)
        return p, loss_fn(p)

    params, losses = jax.lax.scan(body, params, jnp.arange(steps))
    return params, losses[-1]


def main(waves: int = 4, sims_per_wave: int = 48, pool: int = 4096) -> None:
    rng = np.random.default_rng(0)
    key = jax.random.PRNGKey(0)
    trace = moldesign_dag_workload(
        waves=waves, docks_per_wave=sims_per_wave,
        sims_per_wave=sims_per_wave, infers_per_wave=2 * sims_per_wave,
    )
    sim = TestbedSim(trace.endpoints, profiles=trace.profiles,
                     signatures=trace.signatures, seed=0)
    engine = OnlineEngine(
        trace.endpoints, sim, policy="cluster_mhra", alpha=0.3,
        window_s=5.0, max_batch=512, store=warm_store(sim, trace),
        monitoring=True,
    )

    # submit the whole campaign DAG; the ready-set releases wave by wave
    for arrival, task in zip(trace.arrivals, trace.tasks):
        engine.tick(float(arrival))
        engine.submit(task, when=float(arrival))
    windows = engine.drain()
    edges = verify_dag_order(windows)

    # --- the real ML loop the DAG models: JAX surrogate over waves -----
    candidates = rng.uniform(-1, 1, size=(pool, 8))
    X_known = candidates[:sims_per_wave]
    y_known = true_property(X_known)
    params = init_mlp(key)
    best = float(y_known.max())
    for w in range(waves):
        params, mse = train_steps(
            params, jnp.asarray(X_known, jnp.float32), jnp.asarray(y_known, jnp.float32)
        )
        preds = mlp(params, jnp.asarray(candidates, jnp.float32))
        pick = np.asarray(jnp.argsort(-preds)[:sims_per_wave])
        X_new = candidates[pick]
        y_new = true_property(X_new)  # 'simulation' results
        X_known = np.concatenate([X_known, X_new])
        y_known = np.concatenate([y_known, y_new])
        best = max(best, float(y_new.max()))
        wave_ids = set(trace.meta["wave_ids"][w])
        wave_windows = [
            win for win in windows
            if any(t.id in wave_ids for t in win.tasks)
        ]
        wave_e = sum(win.attributed_j for win in wave_windows)
        print(f"wave {w}: surrogate mse={float(mse):.4f}  best={best:.3f}  "
              f"attributed wave energy={wave_e / 1e3:.1f} kJ")

    s = engine.summary()
    placements = Counter(
        ep for win in windows for ep in win.assignments.values()
    )
    print(f"\n{s.tasks} tasks / {s.windows} windows / {edges} DAG edges honored")
    print(f"campaign (cluster_mhra): {s.makespan_s:.1f} s, "
          f"{s.energy_j / 1e3:.1f} kJ scheduled "
          f"({s.attributed_j / 1e3:.1f} kJ attributed to tasks)")
    print("placements:", dict(placements))
    print(f"best molecule property found: {best:.3f} "
          f"(theoretical max ~{true_property(np.array([[0.52, 0.0, 1.0]+[0]*5]))[0]+0.5:.2f})")


if __name__ == "__main__":
    main()
