"""Workload generators + DAG scheduling: reproducible traces, arrival
processes, ready-set dependency handling, and delta/soa engine parity on
dependent workloads."""
import numpy as np
import pytest

from repro.core.endpoint import EndpointSpec, table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.executor import GreenFaaSExecutor
from repro.core.scheduler import TaskSpec
from repro.core.testbed import TestbedSim
from repro.workloads import (
    FUNCTION_CLASSES,
    WorkloadTrace,
    bursty_arrivals,
    diurnal_arrivals,
    make_arrivals,
    moldesign_dag_workload,
    poisson_arrivals,
    synthetic_edp_workload,
)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", [
    ("poisson", {"rate_hz": 4.0}),
    ("bursty", {}),
    ("diurnal", {}),
])
def test_arrivals_reproducible_and_sorted(kind, kw):
    a = make_arrivals(kind, 300, seed=7, **kw)
    b = make_arrivals(kind, 300, seed=7, **kw)
    c = make_arrivals(kind, 300, seed=8, **kw)
    assert len(a) == 300
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)
    assert np.all(a >= 0)


def test_poisson_rate_controls_span():
    fast = poisson_arrivals(1000, rate_hz=100.0, seed=0)
    slow = poisson_arrivals(1000, rate_hz=1.0, seed=0)
    assert slow[-1] > 10 * fast[-1]


def test_bursty_has_gaps():
    a = bursty_arrivals(128, burst_size=32, burst_rate_hz=100.0, gap_s=60.0, seed=0)
    gaps = np.diff(a)
    assert gaps.max() > 5.0          # inter-burst idle
    assert np.median(gaps) < 0.2     # dense inside bursts


def test_diurnal_rate_varies():
    a = diurnal_arrivals(2000, period_s=100.0, peak_rate_hz=20.0,
                         trough_rate_hz=1.0, seed=0)
    # arrivals per period-phase bucket should swing peak vs trough
    phase = (a % 100.0) / 100.0
    peak_n = np.sum((phase > 0.1) & (phase < 0.4))    # sin > 0 region
    trough_n = np.sum((phase > 0.6) & (phase < 0.9))  # sin < 0 region
    assert peak_n > 2 * trough_n


def test_unknown_arrival_kind():
    with pytest.raises(ValueError, match="unknown arrival"):
        make_arrivals("constant", 10)


# ---------------------------------------------------------------------------
# trace container
# ---------------------------------------------------------------------------

def _tiny_trace(tasks, arrivals=None):
    eps = table1_testbed()
    if arrivals is None:
        arrivals = np.arange(len(tasks), dtype=float)
    from repro.core.testbed import BASE_PROFILES, FN_SIGNATURES
    return WorkloadTrace("t", tasks, arrivals, eps, BASE_PROFILES, FN_SIGNATURES)


def test_trace_validates_duplicate_ids():
    with pytest.raises(ValueError, match="duplicate"):
        _tiny_trace([TaskSpec(id="a", fn="graph_bfs"),
                     TaskSpec(id="a", fn="graph_bfs")])


def test_trace_validates_topological_deps():
    with pytest.raises(ValueError, match="depends on"):
        _tiny_trace([TaskSpec(id="a", fn="graph_bfs", deps=("b",)),
                     TaskSpec(id="b", fn="graph_bfs")])


def test_trace_validates_sorted_arrivals():
    with pytest.raises(ValueError, match="not sorted"):
        _tiny_trace([TaskSpec(id="a", fn="graph_bfs"),
                     TaskSpec(id="b", fn="graph_bfs")],
                    arrivals=np.array([2.0, 1.0]))


# ---------------------------------------------------------------------------
# synthetic workload
# ---------------------------------------------------------------------------

def test_synthetic_workload_mix_and_reproducibility():
    t1 = synthetic_edp_workload(n_tasks=200, seed=5)
    t2 = synthetic_edp_workload(n_tasks=200, seed=5)
    assert [t.id for t in t1.tasks] == [t.id for t in t2.tasks]
    assert [t.fn for t in t1.tasks] == [t.fn for t in t2.tasks]
    assert np.array_equal(t1.arrivals, t2.arrivals)
    assert sum(t1.meta["classes"].values()) == 200
    # io-class tasks stage data from home; others are input-free
    io_fns = set(FUNCTION_CLASSES["io"])
    for task in t1.tasks:
        if task.fn in io_fns:
            assert task.inputs and task.inputs[0][0] == "desktop"
            assert any(shared for *_, shared in task.inputs)
        else:
            assert not task.inputs


def test_synthetic_workload_rejects_bad_args():
    with pytest.raises(ValueError):
        synthetic_edp_workload(n_tasks=0)
    with pytest.raises(ValueError):
        synthetic_edp_workload(n_tasks=8, class_mix=(1.0, -1.0, 1.0))
    with pytest.raises(ValueError):
        synthetic_edp_workload(n_tasks=8, home="nowhere")


# ---------------------------------------------------------------------------
# molecular-design DAG workload + engine dependency handling
# ---------------------------------------------------------------------------

def test_moldesign_dag_structure():
    t = moldesign_dag_workload(waves=2, docks_per_wave=4, sims_per_wave=4,
                               infers_per_wave=6)
    by_id = {task.id: task for task in t.tasks}
    # wave-0 docks are roots; wave-1 docks depend on wave-0 infers
    assert by_id["d0_0"].deps == ()
    assert all(d.startswith("i0_") for d in by_id["d1_0"].deps)
    # train fans in over every simulate of its wave
    assert set(by_id["t0"].deps) == {f"s0_{j}" for j in range(4)}
    assert by_id["i0_0"].deps == ("t0",)
    assert len(t.meta["wave_ids"]) == 2


def _run_dag(engine_name, trace, alpha=0.3):
    sim = TestbedSim(trace.endpoints, profiles=trace.profiles,
                     signatures=trace.signatures, seed=0, runtime_noise=0.0)
    eng = OnlineEngine(trace.endpoints, sim, policy="mhra", alpha=alpha,
                       window_s=5.0, max_batch=512, monitoring=False,
                       engine=engine_name)
    windows = trace.replay_into(eng)
    return eng, windows


def test_dag_dependencies_honored_and_engine_parity():
    trace = moldesign_dag_workload(waves=2, docks_per_wave=6, sims_per_wave=6,
                                   infers_per_wave=8)
    runs = {}
    for engine_name in ("delta", "soa"):
        eng, windows = _run_dag(engine_name, trace)
        recs = {r.task_id: r for w in windows for r in w.sim.records}
        assert len(recs) == len(trace)
        for task in trace.tasks:
            for dep in task.deps:
                assert recs[task.id].t_start >= recs[dep].t_end, (
                    engine_name, task.id, dep
                )
        runs[engine_name] = {
            tid: ep for w in windows for tid, ep in w.assignments.items()
        }
    assert runs["delta"] == runs["soa"]


def test_dag_child_gets_parent_endpoint_transfer_input():
    """A promoted child's inputs read dep_bytes from the endpoint that
    produced each parent."""
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0, runtime_noise=0.0)
    eng = OnlineEngine(eps, sim, policy="mhra", monitoring=False,
                       window_s=5.0, max_batch=10**6)
    eng.submit(TaskSpec(id="p", fn="graph_bfs"))
    eng.flush()
    parent_ep, parent_end = eng.completed["p"]
    eng.submit(TaskSpec(id="c", fn="thumbnail", deps=("p",), dep_bytes=5e6))
    eng.drain()
    child = next(t for w in eng.windows for t in w.tasks if t.id == "c")
    assert (parent_ep, 1, 5e6, False) in child.inputs
    assert child.not_before >= parent_end


def test_drain_deadlock_raises():
    eps = table1_testbed()
    eng = OnlineEngine(eps, TestbedSim(eps, seed=0), policy="mhra",
                       monitoring=False)
    eng.submit(TaskSpec(id="orphan", fn="graph_bfs", deps=("never_submitted",)))
    with pytest.raises(RuntimeError, match="deadlock"):
        eng.drain()


def test_batch_executor_rejects_dag_tasks():
    eps = table1_testbed()
    ex = GreenFaaSExecutor(eps, TestbedSim(eps, seed=0), strategy="mhra")
    with pytest.raises(ValueError, match="OnlineEngine"):
        ex.run_batch([TaskSpec(id="a", fn="graph_bfs"),
                      TaskSpec(id="b", fn="graph_bfs", deps=("a",))])


def test_not_before_floors_planned_and_simulated_starts():
    """not_before floors both the planner timeline and the simulated
    dispatch, even on an idle endpoint."""
    eps = [EndpointSpec("a", cores=2, idle_power_w=10.0, tdp_w=100.0,
                        queue_delay_s=0.0, has_batch_scheduler=False)]
    profiles = {"f": {"a": (2.0, 1.0)}}
    sim = TestbedSim(eps, profiles=profiles, seed=0, runtime_noise=0.0)
    eng = OnlineEngine(eps, sim, policy="mhra", monitoring=False)
    eng.submit(TaskSpec(id="t", fn="f", not_before=123.0))
    res = eng.flush()
    start, _ = res.schedule.timeline["t"]
    assert start >= 123.0
    assert res.sim.records[0].t_start >= 123.0


# ---------------------------------------------------------------------------
# deadline distributions
# ---------------------------------------------------------------------------


def test_apply_deadline_slack_flat_hand_checked():
    from repro.workloads import apply_deadline_slack

    profiles = {"f": {"a": (2.0, 1.0), "b": (4.0, 1.0)}}   # mean rt = 3.0
    tasks = [TaskSpec(id="t0", fn="f"), TaskSpec(id="t1", fn="f")]
    arrivals = np.array([1.0, 5.0])
    out = apply_deadline_slack(tasks, arrivals, profiles, (2.0, 4.0), seed=0)
    for t, arr in zip(out, arrivals):
        # deadline = arrival + rt_mean + U(2,4)*rt_mean
        assert arr + 3.0 + 2.0 * 3.0 <= t.deadline <= arr + 3.0 + 4.0 * 3.0
    # seeded: same inputs, same deadlines
    again = apply_deadline_slack(tasks, arrivals, profiles, (2.0, 4.0), seed=0)
    assert [t.deadline for t in again] == [t.deadline for t in out]
    with pytest.raises(ValueError, match="slack_range"):
        apply_deadline_slack(tasks, arrivals, profiles, (3.0, 1.0))


def test_apply_deadline_slack_respects_ancestor_chains():
    from repro.workloads import apply_deadline_slack

    profiles = {"f": {"a": (10.0, 1.0)}}
    tasks = [
        TaskSpec(id="p", fn="f"),
        TaskSpec(id="k", fn="f", deps=("p",)),
        TaskSpec(id="g", fn="f", deps=("k",)),
    ]
    arrivals = np.array([0.0, 0.0, 0.0])
    out = apply_deadline_slack(tasks, arrivals, profiles, (0.0, 0.0), seed=0)
    # zero slack -> deadline == earliest plausible completion of the chain
    assert [t.deadline for t in out] == [10.0, 20.0, 30.0]


def test_generators_set_deadlines_without_changing_placement():
    plain = synthetic_edp_workload(n_tasks=32, seed=0)
    dated = synthetic_edp_workload(n_tasks=32, seed=0,
                                   deadline_slack=(4.0, 8.0))
    assert all(t.deadline == np.inf for t in plain.tasks)
    assert all(t.deadline < np.inf for t in dated.tasks)
    # deadlines never steer placement
    from repro.core.evaluate import run_policy
    a = run_policy(plain, "mhra", seed=0)
    b = run_policy(dated, "mhra", seed=0)
    assert a.assignments == b.assignments
    assert b.deadline_total == 32
    dag = moldesign_dag_workload(waves=2, docks_per_wave=4, sims_per_wave=4,
                                 infers_per_wave=6, deadline_slack=(4.0, 8.0))
    assert all(t.deadline < np.inf for t in dag.tasks)


def test_deadline_miss_rate_counts_late_completions():
    from repro.core.evaluate import run_policy

    # one slow always-on endpoint; second task queues behind the first and
    # blows its (tight) deadline
    eps = [EndpointSpec("a", cores=1, idle_power_w=1.0, tdp_w=10.0,
                        queue_delay_s=0.0, has_batch_scheduler=False)]
    profiles = {"f": {"a": (10.0, 1.0)}}
    tasks = [
        TaskSpec(id="t0", fn="f", deadline=11.0),
        TaskSpec(id="t1", fn="f", deadline=11.0),   # will end ~20s: miss
    ]
    trace = WorkloadTrace(
        name="misses", tasks=tasks, arrivals=np.array([0.0, 0.0]),
        endpoints=eps, profiles=profiles, signatures={"f": np.ones(4)},
    )
    r = run_policy(trace, "mhra", seed=0)
    assert (r.deadline_misses, r.deadline_total) == (1, 2)
    assert r.deadline_miss_rate == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# WfCommons importer
# ---------------------------------------------------------------------------


def test_wfcommons_sample_loads_and_validates():
    from repro.workloads import load_wfcommons_sample

    tr = load_wfcommons_sample()
    assert len(tr) == 19
    assert tr.functions == sorted([
        "mProject", "mDiffFit", "mConcatFit", "mBgModel", "mBackground",
        "mImgtbl", "mAdd", "mViewer",
    ])
    # submission order is topological (validate() raised otherwise) and
    # dep payloads come from the matched parent output files
    by_id = {t.id: t for t in tr.tasks}
    viewer = by_id["mViewer_00000001"]
    assert viewer.deps == ("mAdd_00000001",)
    assert viewer.dep_bytes == pytest.approx(1.6e7)     # mosaic.fits
    diff = by_id["mDiffFit_00000001"]
    assert len(diff.deps) == 2
    assert diff.dep_bytes == pytest.approx(8.0e6 / 2)   # two p*.fits / 2
    # every function has a per-endpoint profile the sim can execute
    for fn in tr.functions:
        assert set(tr.profiles[fn]) == {e.name for e in tr.endpoints}


def test_wfcommons_sample_runs_through_engine_and_lookahead():
    from repro.core.evaluate import run_policy, verify_dag_order
    from repro.workloads import load_wfcommons_sample

    tr = load_wfcommons_sample(deadline_slack=(8.0, 16.0))
    d, w = run_policy(tr, "lookahead_mhra", engine="delta", alpha=0.3,
                      seed=0, return_windows=True)
    s = run_policy(tr, "lookahead_mhra", engine="soa", alpha=0.3, seed=0)
    assert verify_dag_order(w) == 37
    assert d.assignments == s.assignments
    assert d.deadline_total == 19


def test_wfcommons_rejects_cycles_and_missing_runtimes(tmp_path):
    import json

    from repro.workloads import load_wfcommons

    cyc = {"workflow": {"tasks": [
        {"name": "a", "runtimeInSeconds": 1.0, "parents": ["b"]},
        {"name": "b", "runtimeInSeconds": 1.0, "parents": ["a"]},
    ]}}
    p = tmp_path / "cyc.json"
    p.write_text(json.dumps(cyc))
    with pytest.raises(ValueError, match="cycle"):
        load_wfcommons(p)
    bad = {"workflow": {"tasks": [{"name": "a", "parents": []}]}}
    p2 = tmp_path / "bad.json"
    p2.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="runtime"):
        load_wfcommons(p2)


def test_wfcommons_derives_parents_from_children(tmp_path):
    import json

    from repro.workloads import load_wfcommons

    doc = {"workflow": {"jobs": [
        {"name": "up_001", "runtime": 2.0, "children": ["down_001"],
         "files": [{"link": "output", "name": "o.dat", "sizeInBytes": 5e6}]},
        {"name": "down_001", "runtime": 1.0,
         "files": [{"link": "input", "name": "o.dat", "sizeInBytes": 5e6}]},
    ]}}
    p = tmp_path / "legacy.json"
    p.write_text(json.dumps(doc))
    tr = load_wfcommons(p)
    child = next(t for t in tr.tasks if t.id == "down_001")
    assert child.deps == ("up_001",)
    assert child.dep_bytes == pytest.approx(5e6)
    assert child.fn == "down"                      # instance suffix stripped


def test_wfcommons_control_only_edges_stay_free(tmp_path):
    """Recorded file data with no parent-produced inputs means the edge
    really carries nothing — no phantom default payload."""
    import json

    from repro.workloads import load_wfcommons

    doc = {"workflow": {"tasks": [
        {"name": "gate_001", "runtimeInSeconds": 1.0, "parents": [],
         "files": [{"link": "output", "name": "log.txt", "sizeInBytes": 10}]},
        {"name": "work_001", "runtimeInSeconds": 2.0, "parents": ["gate_001"],
         "files": [{"link": "input", "name": "external.dat",
                    "sizeInBytes": 1e9}]},
        {"name": "blind_001", "runtimeInSeconds": 2.0, "parents": ["gate_001"]},
    ]}}
    p = tmp_path / "ctl.json"
    p.write_text(json.dumps(doc))
    tr = load_wfcommons(p, default_dep_bytes=7e5)
    by_id = {t.id: t for t in tr.tasks}
    assert by_id["work_001"].dep_bytes == 0.0      # data recorded, none pulled
    assert by_id["blind_001"].dep_bytes == 7e5     # no file data: fallback
