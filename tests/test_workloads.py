"""Workload generators + DAG scheduling: reproducible traces, arrival
processes, ready-set dependency handling, and delta/soa engine parity on
dependent workloads."""
import numpy as np
import pytest

from repro.core.endpoint import EndpointSpec, table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.executor import GreenFaaSExecutor
from repro.core.scheduler import TaskSpec
from repro.core.testbed import TestbedSim
from repro.workloads import (
    FUNCTION_CLASSES,
    WorkloadTrace,
    bursty_arrivals,
    diurnal_arrivals,
    make_arrivals,
    moldesign_dag_workload,
    poisson_arrivals,
    synthetic_edp_workload,
)


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind,kw", [
    ("poisson", {"rate_hz": 4.0}),
    ("bursty", {}),
    ("diurnal", {}),
])
def test_arrivals_reproducible_and_sorted(kind, kw):
    a = make_arrivals(kind, 300, seed=7, **kw)
    b = make_arrivals(kind, 300, seed=7, **kw)
    c = make_arrivals(kind, 300, seed=8, **kw)
    assert len(a) == 300
    assert np.array_equal(a, b)
    assert not np.array_equal(a, c)
    assert np.all(np.diff(a) >= 0)
    assert np.all(a >= 0)


def test_poisson_rate_controls_span():
    fast = poisson_arrivals(1000, rate_hz=100.0, seed=0)
    slow = poisson_arrivals(1000, rate_hz=1.0, seed=0)
    assert slow[-1] > 10 * fast[-1]


def test_bursty_has_gaps():
    a = bursty_arrivals(128, burst_size=32, burst_rate_hz=100.0, gap_s=60.0, seed=0)
    gaps = np.diff(a)
    assert gaps.max() > 5.0          # inter-burst idle
    assert np.median(gaps) < 0.2     # dense inside bursts


def test_diurnal_rate_varies():
    a = diurnal_arrivals(2000, period_s=100.0, peak_rate_hz=20.0,
                         trough_rate_hz=1.0, seed=0)
    # arrivals per period-phase bucket should swing peak vs trough
    phase = (a % 100.0) / 100.0
    peak_n = np.sum((phase > 0.1) & (phase < 0.4))    # sin > 0 region
    trough_n = np.sum((phase > 0.6) & (phase < 0.9))  # sin < 0 region
    assert peak_n > 2 * trough_n


def test_unknown_arrival_kind():
    with pytest.raises(ValueError, match="unknown arrival"):
        make_arrivals("constant", 10)


# ---------------------------------------------------------------------------
# trace container
# ---------------------------------------------------------------------------

def _tiny_trace(tasks, arrivals=None):
    eps = table1_testbed()
    if arrivals is None:
        arrivals = np.arange(len(tasks), dtype=float)
    from repro.core.testbed import BASE_PROFILES, FN_SIGNATURES
    return WorkloadTrace("t", tasks, arrivals, eps, BASE_PROFILES, FN_SIGNATURES)


def test_trace_validates_duplicate_ids():
    with pytest.raises(ValueError, match="duplicate"):
        _tiny_trace([TaskSpec(id="a", fn="graph_bfs"),
                     TaskSpec(id="a", fn="graph_bfs")])


def test_trace_validates_topological_deps():
    with pytest.raises(ValueError, match="depends on"):
        _tiny_trace([TaskSpec(id="a", fn="graph_bfs", deps=("b",)),
                     TaskSpec(id="b", fn="graph_bfs")])


def test_trace_validates_sorted_arrivals():
    with pytest.raises(ValueError, match="not sorted"):
        _tiny_trace([TaskSpec(id="a", fn="graph_bfs"),
                     TaskSpec(id="b", fn="graph_bfs")],
                    arrivals=np.array([2.0, 1.0]))


# ---------------------------------------------------------------------------
# synthetic workload
# ---------------------------------------------------------------------------

def test_synthetic_workload_mix_and_reproducibility():
    t1 = synthetic_edp_workload(n_tasks=200, seed=5)
    t2 = synthetic_edp_workload(n_tasks=200, seed=5)
    assert [t.id for t in t1.tasks] == [t.id for t in t2.tasks]
    assert [t.fn for t in t1.tasks] == [t.fn for t in t2.tasks]
    assert np.array_equal(t1.arrivals, t2.arrivals)
    assert sum(t1.meta["classes"].values()) == 200
    # io-class tasks stage data from home; others are input-free
    io_fns = set(FUNCTION_CLASSES["io"])
    for task in t1.tasks:
        if task.fn in io_fns:
            assert task.inputs and task.inputs[0][0] == "desktop"
            assert any(shared for *_, shared in task.inputs)
        else:
            assert not task.inputs


def test_synthetic_workload_rejects_bad_args():
    with pytest.raises(ValueError):
        synthetic_edp_workload(n_tasks=0)
    with pytest.raises(ValueError):
        synthetic_edp_workload(n_tasks=8, class_mix=(1.0, -1.0, 1.0))
    with pytest.raises(ValueError):
        synthetic_edp_workload(n_tasks=8, home="nowhere")


# ---------------------------------------------------------------------------
# molecular-design DAG workload + engine dependency handling
# ---------------------------------------------------------------------------

def test_moldesign_dag_structure():
    t = moldesign_dag_workload(waves=2, docks_per_wave=4, sims_per_wave=4,
                               infers_per_wave=6)
    by_id = {task.id: task for task in t.tasks}
    # wave-0 docks are roots; wave-1 docks depend on wave-0 infers
    assert by_id["d0_0"].deps == ()
    assert all(d.startswith("i0_") for d in by_id["d1_0"].deps)
    # train fans in over every simulate of its wave
    assert set(by_id["t0"].deps) == {f"s0_{j}" for j in range(4)}
    assert by_id["i0_0"].deps == ("t0",)
    assert len(t.meta["wave_ids"]) == 2


def _run_dag(engine_name, trace, alpha=0.3):
    sim = TestbedSim(trace.endpoints, profiles=trace.profiles,
                     signatures=trace.signatures, seed=0, runtime_noise=0.0)
    eng = OnlineEngine(trace.endpoints, sim, policy="mhra", alpha=alpha,
                       window_s=5.0, max_batch=512, monitoring=False,
                       engine=engine_name)
    windows = trace.replay_into(eng)
    return eng, windows


def test_dag_dependencies_honored_and_engine_parity():
    trace = moldesign_dag_workload(waves=2, docks_per_wave=6, sims_per_wave=6,
                                   infers_per_wave=8)
    runs = {}
    for engine_name in ("delta", "soa"):
        eng, windows = _run_dag(engine_name, trace)
        recs = {r.task_id: r for w in windows for r in w.sim.records}
        assert len(recs) == len(trace)
        for task in trace.tasks:
            for dep in task.deps:
                assert recs[task.id].t_start >= recs[dep].t_end, (
                    engine_name, task.id, dep
                )
        runs[engine_name] = {
            tid: ep for w in windows for tid, ep in w.assignments.items()
        }
    assert runs["delta"] == runs["soa"]


def test_dag_child_gets_parent_endpoint_transfer_input():
    """A promoted child's inputs read dep_bytes from the endpoint that
    produced each parent."""
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0, runtime_noise=0.0)
    eng = OnlineEngine(eps, sim, policy="mhra", monitoring=False,
                       window_s=5.0, max_batch=10**6)
    eng.submit(TaskSpec(id="p", fn="graph_bfs"))
    eng.flush()
    parent_ep, parent_end = eng.completed["p"]
    eng.submit(TaskSpec(id="c", fn="thumbnail", deps=("p",), dep_bytes=5e6))
    eng.drain()
    child = next(t for w in eng.windows for t in w.tasks if t.id == "c")
    assert (parent_ep, 1, 5e6, False) in child.inputs
    assert child.not_before >= parent_end


def test_drain_deadlock_raises():
    eps = table1_testbed()
    eng = OnlineEngine(eps, TestbedSim(eps, seed=0), policy="mhra",
                       monitoring=False)
    eng.submit(TaskSpec(id="orphan", fn="graph_bfs", deps=("never_submitted",)))
    with pytest.raises(RuntimeError, match="deadlock"):
        eng.drain()


def test_batch_executor_rejects_dag_tasks():
    eps = table1_testbed()
    ex = GreenFaaSExecutor(eps, TestbedSim(eps, seed=0), strategy="mhra")
    with pytest.raises(ValueError, match="OnlineEngine"):
        ex.run_batch([TaskSpec(id="a", fn="graph_bfs"),
                      TaskSpec(id="b", fn="graph_bfs", deps=("a",))])


def test_not_before_floors_planned_and_simulated_starts():
    """not_before floors both the planner timeline and the simulated
    dispatch, even on an idle endpoint."""
    eps = [EndpointSpec("a", cores=2, idle_power_w=10.0, tdp_w=100.0,
                        queue_delay_s=0.0, has_batch_scheduler=False)]
    profiles = {"f": {"a": (2.0, 1.0)}}
    sim = TestbedSim(eps, profiles=profiles, seed=0, runtime_noise=0.0)
    eng = OnlineEngine(eps, sim, policy="mhra", monitoring=False)
    eng.submit(TaskSpec(id="t", fn="f", not_before=123.0))
    res = eng.flush()
    start, _ = res.schedule.timeline["t"]
    assert start >= 123.0
    assert res.sim.records[0].t_start >= 123.0
