"""report.py: golden text report, HTML well-formedness + escaping, EDP
arithmetic, and the evaluation-report renderers."""
from html.parser import HTMLParser

import pytest

from repro.core.counters import TaskRecord
from repro.core.database import TaskDB
from repro.core.evaluate import EvalResult, PolicyRun
from repro.core.report import (
    eval_html_report,
    eval_text_report,
    html_report,
    summary_metrics,
    text_report,
)

VOID_TAGS = {"br", "hr", "img", "meta", "link", "input"}


class _BalanceChecker(HTMLParser):
    def __init__(self):
        super().__init__()
        self.stack = []
        self.errors = []

    def handle_starttag(self, tag, attrs):
        if tag not in VOID_TAGS:
            self.stack.append(tag)

    def handle_endtag(self, tag):
        if not self.stack or self.stack[-1] != tag:
            self.errors.append(f"unbalanced </{tag}> (stack: {self.stack[-3:]})")
        else:
            self.stack.pop()


def assert_well_formed(html: str) -> None:
    """Check tag balance over the <body>...</body> region (the doctype
    prologue and <html>/<head> wrapper span the f-string seams)."""
    body = html[html.index("<body>"):html.index("</body>") + len("</body>")]
    p = _BalanceChecker()
    p.feed(body)
    assert not p.errors, p.errors
    assert not p.stack, f"unclosed tags: {p.stack}"


def _db() -> TaskDB:
    """Two endpoints, hand-computable numbers.

    ep_a: tasks span [0, 10], attributed 1000 J + 500 J, node 3000 J
          -> EDP_a = 3000 * 10 = 30000 J*s
    ep_b: task spans [5, 25], attributed 2000 J, node 8000 J
          -> EDP_b = 8000 * 20 = 160000 J*s
    makespan = 25 - 0 = 25 s; node total 11 kJ -> EDP 275000 J*s
    """
    db = TaskDB()
    db.add(TaskRecord("t0", "fn_x", "ep_a", 1, 0.0, 4.0,
                      energy_j=1000.0, node_energy_j=1800.0))
    db.add(TaskRecord("t1", "fn_y", "ep_a", 1, 4.0, 10.0,
                      energy_j=500.0, node_energy_j=1200.0))
    db.add(TaskRecord("t2", "fn_x", "ep_b", 2, 5.0, 25.0,
                      energy_j=2000.0, node_energy_j=8000.0, user="eve"))
    return db


def test_summary_metrics_hand_computed():
    m = summary_metrics(_db())
    assert m["task_energy_j"] == pytest.approx(3500.0)
    assert m["node_energy_j"] == pytest.approx(11000.0)
    assert m["makespan_s"] == pytest.approx(25.0)
    assert m["task_edp_js"] == pytest.approx(3500.0 * 25.0)
    assert m["node_edp_js"] == pytest.approx(11000.0 * 25.0)


def test_text_report_golden():
    txt = text_report(_db(), user="eve")
    lines = txt.splitlines()
    assert lines[0] == "GreenFaaS energy report"
    assert lines[2] == f"{'endpoint':<12}{'tasks kJ':>12}{'node kJ':>12}{'EDP kJ*s':>12}"
    # per-endpoint EDP: node kJ x busy span
    assert lines[3] == f"{'ep_a':<12}{1.50:>12.2f}{3.00:>12.2f}{30.0:>12.1f}"
    assert lines[4] == f"{'ep_b':<12}{2.00:>12.2f}{8.00:>12.2f}{160.0:>12.1f}"
    assert lines[5] == f"{'total':<12}{3.50:>12.2f}{11.00:>12.2f}{275.0:>12.1f}"
    assert "makespan: 25.0 s" in txt
    assert "user eve:" in txt
    assert "fn_x" in txt and "fn_y" in txt


def test_text_report_empty_db():
    txt = text_report(TaskDB())
    assert "GreenFaaS energy report" in txt
    assert "makespan: 0.0 s" in txt


def test_html_report_well_formed_and_has_edp(tmp_path):
    html = html_report(_db(), tmp_path / "r.html", user="eve")
    assert_well_formed(html)
    assert "EDP" in html
    assert "30.0" in html and "160.0" in html  # per-endpoint EDP kJ*s
    assert (tmp_path / "r.html").read_text() == html


def test_html_report_escapes_hostile_names(tmp_path):
    db = TaskDB()
    db.add(TaskRecord("t0", "<script>alert(1)</script>", "ep<b>bold</b>",
                      1, 0.0, 1.0, energy_j=1.0, node_energy_j=2.0,
                      user="<img src=x>"))
    html = html_report(db, tmp_path / "r.html", user="<img src=x>")
    assert "<script>" not in html
    assert "<b>bold</b>" not in html
    assert "<img" not in html
    assert "&lt;script&gt;" in html
    assert "ep&lt;b&gt;" in html


def _eval_result() -> EvalResult:
    rows = [
        PolicyRun(policy="site:a&b", engine="delta", energy_j=2000.0,
                  makespan_s=10.0, transfer_j=0.0, scheduling_s=0.0,
                  sim_makespan_s=11.0, attributed_j=0.0, windows=1,
                  tasks=4, per_endpoint_j={}, placements={},
                  greenup=1.0, speedup=1.0, powerup=1.0),
        PolicyRun(policy="mhra", engine="delta", energy_j=1000.0,
                  makespan_s=8.0, transfer_j=0.0, scheduling_s=0.0,
                  sim_makespan_s=9.0, attributed_j=0.0, windows=1,
                  tasks=4, per_endpoint_j={}, placements={},
                  greenup=2.0, speedup=1.25, powerup=1.6),
    ]
    return EvalResult(workload="<wl>", n_tasks=4, alpha=0.5, rows=rows,
                      baseline="site:a&b")


def test_eval_text_report_table():
    txt = eval_text_report(_eval_result())
    assert "workload: <wl>" in txt
    assert "GPS-UP baseline: site:a&b" in txt
    mhra_line = next(line for line in txt.splitlines() if line.startswith("mhra"))
    # energy 1 kJ, makespan 8 s, EDP 8000 J*s = 8.0 kJ*s, G/S/U
    for val in ("1.0", "8.0", "2.00", "1.25", "1.60"):
        assert val in mhra_line, (val, mhra_line)


def test_eval_html_report_escapes_and_well_formed(tmp_path):
    html = eval_html_report(_eval_result(), tmp_path / "eval.html")
    assert_well_formed(html)
    assert "&lt;wl&gt;" in html
    assert "<wl>" not in html
    assert "site:a&amp;b" in html


def test_eval_report_fairness_columns_conditional(tmp_path):
    """users / jain / EDP-cov / shed / adm-d columns appear exactly when
    some row carries fairness annotations, and render the golden values."""
    plain = _eval_result()
    txt = eval_text_report(plain)
    for col in ("jain", "EDP-cov", "shed", "adm-d"):
        assert col not in txt, col

    annotated = _eval_result()
    base, fair = annotated.rows
    base.users = 12
    fair.users = 12
    fair.jain_index = 0.875
    fair.user_edp_cov = 0.321
    fair.shed = 7
    fair.admission_deferred = 3
    txt = eval_text_report(annotated)
    for col in ("users", "jain", "EDP-cov", "shed", "adm-d"):
        assert col in txt, col
    mhra_line = next(l for l in txt.splitlines() if l.startswith("mhra"))
    assert "0.875" in mhra_line
    assert "0.321" in mhra_line
    assert "     7" in mhra_line and "     3" in mhra_line
    # the un-annotated baseline renders nan, not garbage, in jain/cov
    base_line = next(l for l in txt.splitlines() if l.startswith("site"))
    assert "nan" in base_line

    html = eval_html_report(annotated, tmp_path / "eval.html")
    assert_well_formed(html)
    for col in ("users", "jain", "EDP-cov", "shed", "adm-d"):
        assert f"<th>{col}</th>" in html, col


def test_text_report_user_section_and_hostile_user_text():
    """The per-user section renders for any user= arg; text output is not
    HTML so hostile names pass through verbatim (escaping is the HTML
    renderer's job, pinned below)."""
    db = TaskDB()
    db.add(TaskRecord("t0", "fn_x", "ep_a", 1, 0.0, 4.0,
                      energy_j=10.0, node_energy_j=20.0,
                      user="<img src=x>"))
    txt = text_report(db, user="<img src=x>")
    assert "user <img src=x>:" in txt


def test_eval_html_report_fairness_escapes_hostile_policy_label(tmp_path):
    """Fairness rows are labelled by user-controlled policy strings
    (label= passthrough); the HTML renderer must escape them even with
    the fairness columns active."""
    res = _eval_result()
    res.rows[1].policy = "fair<script>alert(1)</script>"
    res.rows[1].jain_index = 0.9
    res.rows[1].user_edp_cov = 0.1
    res.rows[1].shed = 2
    html = eval_html_report(res, tmp_path / "eval.html")
    assert_well_formed(html)
    assert "<script>" not in html
    assert "fair&lt;script&gt;" in html
    assert "<th>jain</th>" in html


def test_eval_report_dag_deadline_columns_conditional(tmp_path):
    """cp-su / EDP-vs-mhra / miss% columns appear exactly when rows carry
    the annotations."""
    plain = _eval_result()
    txt = eval_text_report(plain)
    assert "cp-su" not in txt and "EDP/mhra" not in txt and "miss%" not in txt

    annotated = _eval_result()
    for r in annotated.rows:
        r.cp_speedup = 0.5
        r.edp_vs_mhra = 1.25
        r.deadline_total = 10
        r.deadline_misses = 3
    txt = eval_text_report(annotated)
    assert "cp-su" in txt and "EDP/mhra" in txt and "miss%" in txt
    mhra_line = next(l for l in txt.splitlines() if l.startswith("mhra"))
    assert "0.50" in mhra_line        # cp-su
    assert "1.250" in mhra_line       # EDP/mhra
    assert "30.0" in mhra_line        # miss%

    html = eval_html_report(annotated, tmp_path / "eval.html")
    assert_well_formed(html)
    assert "cp-su" in html and "miss%" in html
