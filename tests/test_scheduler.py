"""Scheduler unit + property tests (MHRA, Cluster MHRA, clustering)."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.clustering import agglomerative_cluster
from repro.core.endpoint import table1_testbed
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import (
    HEURISTICS,
    TaskSpec,
    cluster_mhra,
    mhra,
    round_robin,
    single_site,
)
from repro.core.transfer import TransferModel


def _setup(n_fns=3, n_tasks=60, seed=0):
    eps = table1_testbed()
    store = TaskProfileStore(eps)
    rng = np.random.default_rng(seed)
    fns = [f"fn{i}" for i in range(n_fns)]
    for fn in fns:
        for ep in eps:
            rt = float(rng.uniform(1, 20))
            en = float(rng.uniform(5, 200))
            for _ in range(3):
                store.record(fn, ep.name, rt, en)
    tasks = [TaskSpec(id=f"t{i}", fn=fns[i % n_fns]) for i in range(n_tasks)]
    return tasks, eps, store, TransferModel(eps)


def test_schedule_covers_all_tasks():
    tasks, eps, store, tm = _setup()
    for strat in (mhra, cluster_mhra):
        s = strat(tasks, eps, store, tm, alpha=0.5)
        assert set(s.assignments) == {t.id for t in tasks}
        names = {e.name for e in eps}
        assert set(s.assignments.values()) <= names


def test_alpha_tradeoff_direction():
    """Higher alpha must not increase energy; lower alpha must not increase
    makespan (paper Fig. 6 trend)."""
    tasks, eps, store, tm = _setup(n_tasks=120)
    s_energy = cluster_mhra(tasks, eps, store, tm, alpha=1.0)
    s_fast = cluster_mhra(tasks, eps, store, tm, alpha=0.0)
    assert s_energy.energy_j <= s_fast.energy_j * 1.001
    assert s_fast.makespan_s <= s_energy.makespan_s * 1.001


def test_cluster_mhra_beats_or_matches_single_sites_on_objective():
    tasks, eps, store, tm = _setup(n_tasks=100, seed=3)
    cm = cluster_mhra(tasks, eps, store, tm, alpha=0.5)
    for ep in eps:
        ss = single_site(tasks, eps, store, tm, ep.name)
        # compare with the same normalizers via EDP as a proxy
        assert cm.edp() <= ss.edp() * 1.05, ep.name


def test_round_robin_balances_counts():
    tasks, eps, store, tm = _setup(n_tasks=80)
    s = round_robin(tasks, eps, store, tm)
    counts = {e.name: 0 for e in eps}
    for v in s.assignments.values():
        counts[v] += 1
    assert max(counts.values()) - min(counts.values()) <= 1


def test_mhra_all_heuristics_evaluated():
    tasks, eps, store, tm = _setup(n_tasks=40)
    best = mhra(tasks, eps, store, tm, alpha=0.5)
    assert best.heuristic in HEURISTICS


def test_cluster_mhra_fewer_decisions_faster():
    """Cluster MHRA must be materially faster than MHRA (Table IV)."""
    import time

    tasks, eps, store, tm = _setup(n_tasks=512)
    t0 = time.perf_counter()
    mhra(tasks, eps, store, tm, alpha=0.5)
    t_m = time.perf_counter() - t0
    t0 = time.perf_counter()
    cluster_mhra(tasks, eps, store, tm, alpha=0.5)
    t_c = time.perf_counter() - t0
    assert t_c < t_m, (t_c, t_m)


# ---------------------------------------------------------------------------
# clustering properties
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 120),
    k=st.integers(2, 6),
    cap=st.floats(10.0, 5000.0),
    seed=st.integers(0, 100),
)
def test_clustering_is_a_partition(n, k, cap, seed):
    rng = np.random.default_rng(seed)
    feats = rng.uniform(0, 10, size=(n, k))
    energies = rng.uniform(1, 50, size=n)
    clusters = agglomerative_cluster(feats, energies, cap)
    flat = sorted(i for c in clusters for i in c)
    assert flat == list(range(n))  # exact partition, no loss, no dupes
    for c in clusters:
        assert len(c) >= 1


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 100), seed=st.integers(0, 50))
def test_clustering_respects_energy_cap(n, seed):
    rng = np.random.default_rng(seed)
    feats = np.repeat(rng.uniform(0, 1, size=(3, 4)), (n + 2) // 3, axis=0)[:n]
    energies = rng.uniform(1, 10, size=n)
    cap = 30.0
    clusters = agglomerative_cluster(feats, energies, cap)
    for c in clusters:
        if len(c) > 1:
            # multi-task clusters exceed the cap by at most one task's energy
            assert energies[c].sum() <= cap + energies[c].max() + 1e-9


def test_identical_tasks_cluster_together():
    feats = np.ones((30, 4))
    energies = np.full(30, 1.0)
    clusters = agglomerative_cluster(feats, energies, energy_cap=1000.0)
    assert len(clusters) == 1 and len(clusters[0]) == 30


def test_distinct_tasks_stay_apart():
    feats = np.array([[0.0, 0, 0, 0]] * 10 + [[100.0, 100, 100, 100]] * 10)
    energies = np.full(20, 1.0)
    clusters = agglomerative_cluster(feats, energies, energy_cap=1000.0)
    for c in clusters:
        groups = {i < 10 for i in c}
        assert len(groups) == 1  # never mixes the two populations


def test_transfer_energy_affects_placement():
    """A task with huge input data at one endpoint should prefer staying."""
    eps = table1_testbed()
    store = TaskProfileStore(eps)
    for ep in eps:
        store.record("fn", ep.name, 5.0, 50.0)  # identical everywhere
    tm = TransferModel(eps)
    tasks = [
        TaskSpec(id=f"t{i}", fn="fn", inputs=(("faster", 1, 500e9, False),))
        for i in range(8)
    ]
    s = cluster_mhra(tasks, eps, store, tm, alpha=1.0)
    assert set(s.assignments.values()) == {"faster"}
