"""Per-architecture smoke tests (reduced configs) + consistency checks."""
import jax
import jax.numpy as jnp
import pytest

from repro.distributed.sharding import NULL_CTX
from repro.models import encdec, lm
from repro.models.registry import ARCH_IDS, get_api, get_config

RNG = jax.random.PRNGKey(0)


def _batch(api, b=2, s=32):
    cfg = api.cfg
    out = {
        "tokens": jax.random.randint(RNG, (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(RNG, (b, s), 0, cfg.vocab),
    }
    if cfg.family == "encdec":
        out["frames"] = jax.random.normal(RNG, (b, cfg.enc_len, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        out["vision_embeds"] = jax.random.normal(
            RNG, (b, cfg.n_vision_tokens, cfg.d_model), jnp.float32
        )
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    """One forward + loss per arch: correct shapes, finite values."""
    api = get_api(arch, reduced=True)
    params = api.init(RNG)
    batch = _batch(api)
    loss, metrics = jax.jit(lambda p, b: api.loss(p, b, shd=NULL_CTX))(params, batch)
    assert jnp.isfinite(loss), arch
    assert float(loss) > 0
    # one gradient step moves the loss
    grads = jax.grad(lambda p: api.loss(p, batch, shd=NULL_CTX)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert gnorm > 0, f"{arch}: zero gradients"
    assert all(jnp.all(jnp.isfinite(g)) for g in jax.tree.leaves(grads)), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode_shapes(arch):
    api = get_api(arch, reduced=True)
    cfg = api.cfg
    params = api.init(RNG)
    b, s = 2, 16
    batch = {k: v for k, v in _batch(api, b, s).items() if k != "labels"}
    logits, cache = api.prefill(params, batch, shd=NULL_CTX)
    assert logits.shape[0] == b
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    cache = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, 4)] + [(0, 0)] * (a.ndim - 3))
        if a.ndim >= 3 and a.shape[2] == s else a,
        cache,
    )
    lg, cache2 = api.decode_step(
        params, batch["tokens"][:, :1], cache, jnp.int32(s), shd=NULL_CTX
    )
    assert lg.shape[:2] == (b, 1)
    assert jnp.all(jnp.isfinite(lg.astype(jnp.float32)))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize(
    "arch",
    ["granite-3-2b", "qwen3-14b", "llama4-scout-17b-a16e",
     "falcon-mamba-7b", "zamba2-2.7b", "whisper-tiny"],
)
def test_decode_matches_full_forward(arch):
    """Logits from prefill+decode must match a full forward at position s.

    MoE: capacity raised so no tokens drop — capacity dropping is batch-
    composition-dependent by design, so prefill vs decode routing would
    legitimately differ at tight capacity."""
    import dataclasses

    from repro.models.registry import build_api

    api = get_api(arch, reduced=True)
    if api.cfg.n_experts:
        api = build_api(dataclasses.replace(api.cfg, capacity_factor=8.0))
    cfg = api.cfg
    params = api.init(jax.random.PRNGKey(1))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0, cfg.vocab)
    pre_in = {"tokens": toks[:, :s]}
    if cfg.family == "encdec":
        frames = jax.random.normal(RNG, (b, cfg.enc_len, cfg.d_model), jnp.float32)
        pre_in["frames"] = frames
        enc = encdec.encode(params, cfg, frames, shd=NULL_CTX)
        full = encdec.decode_train(params, cfg, toks, enc, shd=NULL_CTX)
    else:
        full, _, _ = lm.lm_forward(params, cfg, toks, shd=NULL_CTX, remat=False)
    _, cache = api.prefill(params, pre_in, shd=NULL_CTX)
    cache = jax.tree.map(
        lambda a: jnp.pad(a, [(0, 0), (0, 0), (0, 4)] + [(0, 0)] * (a.ndim - 3))
        if a.ndim >= 3 and a.shape[2] == s else a,
        cache,
    )
    got, _ = api.decode_step(params, toks[:, s:s + 1], cache, jnp.int32(s), shd=NULL_CTX)
    err = jnp.max(jnp.abs(full[:, s].astype(jnp.float32) - got[:, 0].astype(jnp.float32)))
    assert float(err) < 0.05, f"{arch}: decode/forward mismatch {err}"


def test_vocab_padding_masked():
    """Padded vocab rows must never receive probability mass in the loss."""
    from repro.models.common import cross_entropy_loss, pad_vocab

    vocab = 500
    vp = pad_vocab(vocab)
    assert vp >= vocab and vp % 256 == 0
    logits = jnp.zeros((2, 4, vp))
    # huge logit on a padded slot must not change the loss
    poisoned = logits.at[..., vocab + 1].set(100.0)
    labels = jnp.zeros((2, 4), jnp.int32)
    a = cross_entropy_loss(logits, labels, vocab)
    bb = cross_entropy_loss(poisoned, labels, vocab)
    assert abs(float(a) - float(bb)) < 1e-4


def test_loss_decreases_training():
    """A tiny model must learn the synthetic structured stream."""
    from repro.launch.train import train

    _, losses = train(
        arch="granite-3-2b", reduced=True, steps=30, batch=8, seq=64, lr=5e-3,
        log_every=1000,
    )
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_shape_cells_skip_policy():
    """long_500k only for sub-quadratic archs; all archs expose >=3 cells."""
    from repro.models.registry import shape_cells

    for arch in ARCH_IDS:
        cells = shape_cells(arch)
        cfg = get_config(arch)
        assert ("long_500k" in cells) == cfg.sub_quadratic, arch
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)


def test_param_counts_match_scale():
    """Full-config param counts are in the right ballpark per arch name."""
    expected = {
        "qwen3-14b": (13e9, 17e9),
        "granite-3-2b": (2e9, 3.5e9),
        "starcoder2-7b": (6e9, 8.5e9),
        "deepseek-67b": (60e9, 72e9),
        "falcon-mamba-7b": (6e9, 8.5e9),
        "zamba2-2.7b": (2e9, 3.6e9),
        "internvl2-26b": (18e9, 24e9),  # LM backbone only (ViT stubbed)
        "moonshot-v1-16b-a3b": (25e9, 30e9),  # cfg-as-given (64e x 1408)
        "llama4-scout-17b-a16e": (95e9, 115e9),  # total incl experts
        "whisper-tiny": (25e6, 80e6),
    }
    for arch, (lo, hi) in expected.items():
        n = get_api(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
