"""Planning graph (DAGView), lookahead_mhra engine parity, epoch-batched
promotion, and the SoA memoization-hit regression guard."""
import numpy as np
import pytest

from repro.core import scheduler as sched
from repro.core.dag import DAGView, LookaheadWeights
from repro.core.endpoint import table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.evaluate import (
    critical_path_bound_s,
    run_policy,
    verify_dag_order,
)
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import (
    MEMO_STATS,
    TaskSpec,
    mhra,
    reset_memo_stats,
)
from repro.core.testbed import SEBS_FUNCTIONS, TestbedSim
from repro.core.transfer import E_INC_J_PER_BYTE, TransferModel
from repro.workloads import moldesign_dag_workload


# ---------------------------------------------------------------------------
# DAGView rank / mass hand-checks
# ---------------------------------------------------------------------------

RT = {"fa": 2.0, "fb": 3.0, "fc": 1.0, "fd": 4.0}


def _diamond() -> DAGView:
    r"""a(2) -> b(3) [10 B], a -> c(1) [5 B], b -> d(4) [7 B], c -> d [7 B].

    d pulls dep_bytes=7 from *each* parent, so both b->d and c->d edges
    weigh 7.
    """
    dag = DAGView(runtime=RT.__getitem__)
    dag.add_task(TaskSpec(id="a", fn="fa"))
    dag.add_task(TaskSpec(id="b", fn="fb", deps=("a",), dep_bytes=10.0))
    dag.add_task(TaskSpec(id="c", fn="fc", deps=("a",), dep_bytes=5.0))
    dag.add_task(TaskSpec(id="d", fn="fd", deps=("b", "c"), dep_bytes=7.0))
    return dag


def test_dagview_up_ranks_hand_checked():
    dag = _diamond()
    assert dag.up_rank("d") == 4.0
    assert dag.up_rank("b") == 3.0 + 4.0
    assert dag.up_rank("c") == 1.0 + 4.0
    assert dag.up_rank("a") == 2.0 + 7.0          # through the b chain
    assert dag.rank_scale == 9.0
    assert dag.up_rest("a") == 7.0
    assert dag.up_rest("d") == 0.0                 # sink


def test_dagview_down_ranks_hand_checked():
    dag = _diamond()
    assert dag.down_rank("a") == 0.0
    assert dag.down_rank("b") == 2.0
    assert dag.down_rank("c") == 2.0
    assert dag.down_rank("d") == 5.0               # a(2) + b(3)


def test_dagview_mass_and_out_bytes_hand_checked():
    dag = _diamond()
    # path-weighted: a sees b's edge+subtree (10+7) and c's (5+7)
    assert dag.desc_bytes("a") == 29.0
    assert dag.desc_bytes("b") == 7.0
    assert dag.desc_bytes("d") == 0.0
    assert dag.out_bytes("a") == 15.0
    assert dag.out_bytes("b") == 7.0
    assert dag.out_bytes("d") == 0.0


def test_dagview_incremental_and_producers():
    dag = DAGView(runtime=lambda fn: 1.0)
    dag.add_task(TaskSpec(id="p", fn="f"))
    assert not dag.has_edges()
    assert dag.up_rank("p") == 1.0
    dag.add_task(TaskSpec(id="k", fn="f", deps=("p",), dep_bytes=3.0))
    dag.add_task(TaskSpec(id="k", fn="f", deps=("p",), dep_bytes=3.0))  # idempotent
    assert dag.n_edges == 1
    assert dag.up_rank("p") == 2.0                 # rank refreshed lazily
    assert dag.producer("p") is None
    assert dag.children("p") == (("k", 3.0),)
    dag.complete("p", "ic", 12.5)
    # completion retires the node from the rank graph immediately (it can
    # never be a live node's descendant); the producer record survives
    assert dag.producer("p") == ("ic", 12.5)
    assert "p" not in dag
    assert dag.retired == 1 and dag.drain_retired() == ["p"]
    assert dag.children("p") == ()


def test_lookahead_weights_snapshot():
    dag = _diamond()
    eps = table1_testbed()
    tm = TransferModel(eps)
    tasks = [TaskSpec(id="a", fn="fa"), TaskSpec(id="d", fn="fd")]
    lw = LookaheadWeights.from_dag(dag, tasks, eps, tm, lam=2.0)
    assert lw is not None and lw.lam == 2.0
    assert lw.tail_w["a"] == pytest.approx(7.0 / 9.0)
    assert lw.tail_w["d"] == 0.0
    assert lw.out_j["a"] == pytest.approx(15.0 * E_INC_J_PER_BYTE)
    assert len(lw.hops_mean) == len(eps)
    # desktop: mean of hops to theta/ic/faster
    hops = [tm.hops("desktop", n) for n in ("theta", "ic", "faster")]
    assert lw.hops_mean[0] == pytest.approx(sum(hops) / 3.0)


def test_lookahead_weights_collapse_to_none_without_structure():
    dag = DAGView()
    eps = table1_testbed()
    tm = TransferModel(eps)
    flat = [TaskSpec(id="x", fn="f")]
    dag.add_task(flat[0])
    assert LookaheadWeights.from_dag(dag, flat, eps, tm) is None
    # sink-only batches on a real DAG collapse too
    diamond = _diamond()
    sinks = [TaskSpec(id="d", fn="fd", deps=("b", "c"))]
    assert LookaheadWeights.from_dag(diamond, sinks, eps, tm) is None


# ---------------------------------------------------------------------------
# Engine parity under lookahead scoring
# ---------------------------------------------------------------------------


def _store(eps):
    store = TaskProfileStore(eps)
    sim = TestbedSim(eps, seed=0)
    for fn in SEBS_FUNCTIONS:
        for ep in eps:
            rt, w, _ = sim.task_truth(fn, ep.name)
            for _ in range(3):
                store.record(fn, ep.name, rt, rt * w)
    return store


def _batch_lookahead(n=96):
    """A flat batch + a DAGView that assigns it downstream structure, so
    every engine scores real rank/gravity terms in batch mode."""
    eps = table1_testbed()
    tm = TransferModel(eps)
    store = _store(eps)
    dag = DAGView(runtime=lambda fn: 5.0)
    tasks = []
    for i in range(n):
        t = TaskSpec(id=f"t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)])
        tasks.append(t)
        dag.add_task(t)
        # every third task gets a heavy waiting child; depth varies
        if i % 3 == 0:
            dag.add_task(TaskSpec(id=f"c{i}", fn="graph_bfs",
                                  deps=(t.id,), dep_bytes=(1 + i % 5) * 1e6))
        if i % 9 == 0:
            dag.add_task(TaskSpec(id=f"g{i}", fn="thumbnail",
                                  deps=(f"c{i}",), dep_bytes=2e6))
    lw = LookaheadWeights.from_dag(dag, tasks, eps, tm, lam=1.0)
    assert lw is not None
    return tasks, eps, store, tm, lw


def test_clone_delta_bitwise_parity_under_lookahead():
    tasks, eps, store, tm, lw = _batch_lookahead()
    d = mhra(tasks, eps, store, tm, alpha=0.4, lookahead=lw, engine="delta")
    c = mhra(tasks, eps, store, tm, alpha=0.4, lookahead=lw, engine="clone")
    assert d.assignments == c.assignments
    assert d.objective == c.objective              # bitwise
    assert d.energy_j == c.energy_j
    assert d.makespan_s == c.makespan_s


def test_delta_soa_parity_under_lookahead_batch():
    tasks, eps, store, tm, lw = _batch_lookahead()
    d = mhra(tasks, eps, store, tm, alpha=0.4, lookahead=lw, engine="delta")
    s = mhra(tasks, eps, store, tm, alpha=0.4, lookahead=lw, engine="soa")
    assert d.assignments == s.assignments
    assert np.isclose(d.objective, s.objective, rtol=1e-12, atol=0.0)


def test_lookahead_weight_validation():
    tasks, eps, store, tm, lw = _batch_lookahead(n=8)
    bad = LookaheadWeights(lw.tail_w, lw.out_j, lw.hops_mean[:2], lw.lam)
    with pytest.raises(ValueError, match="lookahead weights cover"):
        mhra(tasks, eps, store, tm, lookahead=bad)
    with pytest.raises(ValueError, match="lam"):
        LookaheadWeights({}, {}, (0.0,), lam=-1.0)


def test_delta_soa_parity_under_lookahead_online_dag():
    trace = moldesign_dag_workload(waves=2, docks_per_wave=6, sims_per_wave=6,
                                   infers_per_wave=8)
    d, dw = run_policy(trace, "lookahead_mhra", engine="delta", alpha=0.3,
                       seed=0, return_windows=True)
    s = run_policy(trace, "lookahead_mhra", engine="soa", alpha=0.3, seed=0)
    assert d.assignments == s.assignments
    assert verify_dag_order(dw) > 0


def test_lookahead_degrades_to_mhra_on_flat_workloads():
    """No DAG structure -> identical placements and objective to mhra."""
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0)
    flat = [TaskSpec(id=f"f{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)])
            for i in range(40)]
    outs = {}
    for pol in ("mhra", "lookahead_mhra"):
        eng = OnlineEngine(table1_testbed(), TestbedSim(eps, seed=0),
                           policy=pol, monitoring=False, max_batch=10**6)
        eng.submit_many(flat)
        res = eng.flush()
        outs[pol] = (res.assignments, res.schedule.objective)
    assert outs["mhra"] == outs["lookahead_mhra"]


# ---------------------------------------------------------------------------
# Epoch-batched promotion
# ---------------------------------------------------------------------------


def _wide_stage_tasks(stages=3, width=48):
    tasks = []
    for s in range(stages):
        fn = SEBS_FUNCTIONS[s % len(SEBS_FUNCTIONS)]
        for j in range(width):
            deps = (f"s{s - 1}_{(j + 1) % width}",) if s else ()
            tasks.append(TaskSpec(id=f"s{s}_{j}", fn=fn, deps=deps))
    return tasks


def _drain_wide(engine_name, promotion, stages=3, width=48):
    eps = table1_testbed()
    eng = OnlineEngine(eps, None, policy="mhra", monitoring=False,
                       max_batch=10**9, engine=engine_name,
                       promotion=promotion, store=_store(eps))
    eng.submit_many(_wide_stage_tasks(stages, width), when=0.0)
    eng.drain()
    return eng


def test_epoch_promotion_shares_one_floor_per_stage():
    eng = _drain_wide("delta", "epoch")
    # every promoted stage carries exactly one distinct not_before
    for w in eng.windows[1:]:
        floors = {t.not_before for t in w.tasks}
        assert len(floors) == 1
        # and it is the stage's completion epoch: >= every parent's end
        floor = floors.pop()
        for t in w.tasks:
            for p in t.deps:
                assert floor >= eng.completed[p][1]


def test_exact_promotion_keeps_tight_per_child_floors():
    eng = _drain_wide("delta", "exact")
    saw_distinct = False
    for w in eng.windows[1:]:
        for t in w.tasks:
            assert t.not_before == max(eng.completed[p][1] for p in t.deps)
        if len({t.not_before for t in w.tasks}) > 1:
            saw_distinct = True
    assert saw_distinct, "workload too degenerate to distinguish the modes"


def test_epoch_vs_exact_assignment_parity_on_moldesign():
    trace = moldesign_dag_workload(waves=2, docks_per_wave=8, sims_per_wave=8,
                                   infers_per_wave=12)
    for pol in ("mhra", "lookahead_mhra"):
        ep = run_policy(trace, pol, alpha=0.3, seed=0, promotion="epoch")
        ex = run_policy(trace, pol, alpha=0.3, seed=0, promotion="exact")
        assert ep.assignments == ex.assignments, pol


def test_promotion_mode_validated():
    with pytest.raises(ValueError, match="promotion"):
        OnlineEngine(table1_testbed(), None, promotion="eager")


# ---------------------------------------------------------------------------
# SoA run-memoization counter regression (the epoch fast path's receipts)
# ---------------------------------------------------------------------------


def test_epoch_promotion_restores_soa_memoization():
    stages, width = 3, 48
    n_heur = len(sched.HEURISTICS)
    reset_memo_stats()
    _drain_wide("soa", "epoch", stages, width)
    epoch = dict(MEMO_STATS)
    reset_memo_stats()
    _drain_wide("soa", "exact", stages, width)
    exact = dict(MEMO_STATS)
    # epoch: each stage is one window of identical (fn, inputs, floor)
    # tasks -> exactly one full pass per (stage, heuristic)
    assert epoch["misses"] == stages * n_heur
    assert epoch["hits"] == (stages * width - stages) * n_heur
    # exact: distinct per-child floors fragment the runs
    assert exact["misses"] > epoch["misses"]
    assert exact["hits"] < epoch["hits"]


def test_memo_stats_reset():
    reset_memo_stats()
    assert MEMO_STATS == {"hits": 0, "misses": 0}


# ---------------------------------------------------------------------------
# Evaluation annotations
# ---------------------------------------------------------------------------


def test_critical_path_bound_hand_checked():
    trace = moldesign_dag_workload(waves=1, docks_per_wave=2, sims_per_wave=2,
                                   infers_per_wave=2, submit_rate_hz=1e9)
    # all arrivals ~0; fastest: dock 0.8 (faster), simulate 2.5 (faster),
    # train 8.0 (desktop), infer 0.6 (faster)
    assert critical_path_bound_s(trace) == pytest.approx(
        0.8 + 2.5 + 8.0 + 0.6, abs=1e-6
    )


def test_cp_speedup_reported_and_bounded():
    trace = moldesign_dag_workload(waves=2, docks_per_wave=6, sims_per_wave=6,
                                   infers_per_wave=8)
    r = run_policy(trace, "mhra", alpha=0.3, seed=0)
    assert r.cp_speedup is not None
    assert 0.0 < r.cp_speedup <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# Producer-aware gravity (hops_task)
# ---------------------------------------------------------------------------


class _PinnedStore:
    """predict() stub whose argmin-energy endpoint is pinned per fn."""

    def __init__(self, best):
        self.best = best   # fn -> endpoint name

    def predict(self, fn, ep_name):
        import types
        e = 1.0 if ep_name == self.best.get(fn) else 2.0
        return types.SimpleNamespace(energy_j=e, runtime_s=1.0, observed=True)


def test_producer_aware_hops_task_hand_checked():
    dag = _diamond()
    eps = table1_testbed()
    tm = TransferModel(eps)
    names = [e.name for e in eps]
    tasks = [TaskSpec(id=i, fn=f"f{i}") for i in "abcd"]
    best = {"fb": "theta", "fc": "ic", "fd": "faster"}
    lw = LookaheadWeights.from_dag(dag, tasks, eps, tm, lam=1.0,
                                   store=_PinnedStore(best),
                                   producer_aware=True)
    ht = lw.hops_task
    assert ht is not None
    # d has no children -> no vector (its gravity weight is zero anyway)
    assert set(ht) == {"a", "b", "c"}
    for e, nm in enumerate(names):
        # a: 10 B to b (predicted theta) + 5 B to c (predicted ic)
        exp_a = (10.0 * tm.hops(nm, "theta") + 5.0 * tm.hops(nm, "ic")) / 15.0
        assert ht["a"][e] == pytest.approx(exp_a)
        # b and c: all 7 B flow to d (predicted faster)
        assert ht["b"][e] == pytest.approx(tm.hops(nm, "faster"))
        assert ht["c"][e] == pytest.approx(tm.hops(nm, "faster"))
    # default / store-less builds stay inert (hops_task never set)
    assert LookaheadWeights.from_dag(dag, tasks, eps, tm).hops_task is None
    assert LookaheadWeights.from_dag(
        dag, tasks, eps, tm, producer_aware=True).hops_task is None


def test_producer_aware_engine_parity_all_engines():
    """clone/delta/soa/jax place a producer-aware batch identically."""
    eps = table1_testbed()
    store = _store(eps)
    tm = TransferModel(eps)
    dag = DAGView(runtime=lambda fn: 1.0)
    batch = []
    # stage-1 producers (the placeable batch: singleton units) ...
    for i in range(24):
        t = TaskSpec(id=f"p{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)])
        dag.add_task(t)
        batch.append(t)
    # ... with stage-2/3 consumers still parked in the planning graph
    for j in range(36):
        dag.add_task(TaskSpec(
            id=f"c{j}", fn=SEBS_FUNCTIONS[(j + 3) % len(SEBS_FUNCTIONS)],
            deps=(f"p{j % 24}",), dep_bytes=float(1000 + 40 * j)))
    for j in range(6):
        dag.add_task(TaskSpec(
            id=f"g{j}", fn=SEBS_FUNCTIONS[j % len(SEBS_FUNCTIONS)],
            deps=(f"c{j}",), dep_bytes=512.0))
    lw = LookaheadWeights.from_dag(dag, batch, eps, tm, lam=1.5,
                                   store=store, producer_aware=True)
    assert lw is not None and lw.hops_task
    # the predicted-consumer vectors genuinely leave the fleet mean
    assert any(tuple(v) != tuple(lw.hops_mean)
               for v in lw.hops_task.values())
    runs = {}
    for engine in ("clone", "delta", "soa", "jax"):
        s = mhra(batch, eps, store, tm, 0.3, engine=engine, lookahead=lw)
        runs[engine] = (s.assignments, s.heuristic)
    assert runs["clone"] == runs["delta"] == runs["soa"] == runs["jax"]
