"""Import hypothesis if available; otherwise provide shims so that only
the property-based tests skip instead of the whole module failing at
collection (hypothesis is an optional [test] extra, see pyproject.toml).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategies.<name>(...) call at module import time."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def settings(*args, **kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)
        return deco
