"""SoA-engine parity suite: engine="soa" must reproduce engine="delta"
assignments exactly and objectives to rtol=1e-12 (bitwise in practice) on
the Table-V workload shape and on scaled federated fleets, batch and
online."""
import numpy as np
import pytest

from repro.core.endpoint import scaled_testbed, table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.policy import get_policy
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import (
    SchedulerState,
    SoAState,
    TaskSpec,
    cluster_mhra,
    mhra,
    round_robin,
)
from repro.core.testbed import BASE_PROFILES, SEBS_FUNCTIONS, TestbedSim
from repro.core.transfer import TransferModel

PARITY_RTOL = 1e-12


def _setup(n_per=24, with_inputs=True, replicas=1):
    eps = scaled_testbed(replicas)
    store = TaskProfileStore(eps)
    for fn in SEBS_FUNCTIONS:
        for ep in eps:
            base, _, k = ep.name.partition("_")
            rt, w = BASE_PROFILES[fn][base]
            # replica k runs (1 + 0.02k)x faster (scaled_testbed perf_scale)
            rt = rt / (1.0 + 0.02 * int(k or 0))
            for _ in range(3):
                store.record(fn, ep.name, rt, rt * w)
    inputs = ((eps[0].name, 1, 200e6, True),) if with_inputs else ()
    tasks = [
        TaskSpec(id=f"t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)],
                 inputs=inputs)
        for i in range(n_per * len(SEBS_FUNCTIONS))
    ]
    return tasks, eps, store, TransferModel(eps)


def _assert_parity(a, b):
    assert a.assignments == b.assignments
    assert a.objective == pytest.approx(b.objective, rel=PARITY_RTOL)
    assert a.energy_j == pytest.approx(b.energy_j, rel=PARITY_RTOL)
    assert a.makespan_s == pytest.approx(b.makespan_s, rel=PARITY_RTOL)
    assert a.transfer_j == pytest.approx(b.transfer_j, rel=PARITY_RTOL, abs=0)
    assert a.heuristic == b.heuristic


@pytest.mark.parametrize("alpha", [0.0, 0.2, 0.5, 1.0])
@pytest.mark.parametrize("strategy", [mhra, cluster_mhra])
def test_soa_matches_delta_table5(strategy, alpha):
    tasks, eps, store, tm = _setup(n_per=24)
    a = strategy(tasks, eps, store, tm, alpha=alpha, engine="soa")
    b = strategy(tasks, eps, store, tm, alpha=alpha, engine="delta")
    _assert_parity(a, b)


def test_soa_matches_delta_without_inputs():
    tasks, eps, store, tm = _setup(n_per=24, with_inputs=False)
    a = mhra(tasks, eps, store, tm, alpha=0.5, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=0.5, engine="delta")
    _assert_parity(a, b)


@pytest.mark.parametrize("replicas", [2, 4])
def test_soa_matches_delta_on_scaled_fleet(replicas):
    tasks, eps, store, tm = _setup(n_per=16, replicas=replicas)
    assert len(eps) == 4 * replicas
    a = mhra(tasks, eps, store, tm, alpha=0.5, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=0.5, engine="delta")
    _assert_parity(a, b)


def test_soa_transitively_matches_seed_clone_engine():
    tasks, eps, store, tm = _setup(n_per=16)
    a = mhra(tasks, eps, store, tm, alpha=0.5, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=0.5, engine="clone")
    assert a.assignments == b.assignments
    assert a.objective == pytest.approx(b.objective, rel=PARITY_RTOL)


# ---------------------------------------------------------------------------
# online mode: SoA state carried across arrival windows
# ---------------------------------------------------------------------------


def _online(engine, policy="mhra"):
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0)
    eng = OnlineEngine(eps, sim, policy=policy, alpha=0.2, monitoring=False,
                       window_s=30.0, max_batch=10**6, engine=engine)
    out = []
    for w in range(3):
        eng.submit_many([
            TaskSpec(id=f"w{w}t{i}", fn=SEBS_FUNCTIONS[i % 7])
            for i in range(70)
        ])
        res = eng.flush()
        out.append((res.assignments, res.schedule.energy_j,
                    res.schedule.makespan_s))
    return out, eng


@pytest.mark.parametrize("policy", ["mhra", "cluster_mhra", "round_robin"])
def test_online_soa_state_matches_delta_state(policy):
    a, eng_a = _online(None, policy)      # delta + heap-backed state
    b, eng_b = _online("soa", policy)     # soa + SoA-backed state
    assert isinstance(eng_a.state, SchedulerState)
    assert isinstance(eng_b.state, SoAState)
    for (asg_a, e_a, c_a), (asg_b, e_b, c_b) in zip(a, b):
        assert asg_a == asg_b
        assert e_a == pytest.approx(e_b, rel=PARITY_RTOL)
        assert c_a == pytest.approx(c_b, rel=PARITY_RTOL)
    assert eng_a.state.metrics() == pytest.approx(
        eng_b.state.metrics(), rel=PARITY_RTOL)


def test_online_engine_param_builds_soa_policy():
    eps = table1_testbed()
    eng = OnlineEngine(eps, policy="mhra", engine="soa")
    assert eng.policy.engine == "soa"
    assert isinstance(eng.state, SoAState)
    # default engine is "auto": no live state until the first window
    # reveals its size, then the crossover fixes the layout for life
    eng2 = OnlineEngine(eps, policy="mhra")
    assert eng2.engine == "auto"
    assert eng2.policy.engine == "auto"
    assert eng2.state is None
    eng3 = OnlineEngine(eps, policy="mhra", engine="delta")
    assert isinstance(eng3.state, SchedulerState)


def test_online_engine_rejects_clone_engine():
    """clone cannot place against a live state — fail at construction,
    not at the first flush."""
    eps = table1_testbed()
    with pytest.raises(ValueError, match="clone"):
        OnlineEngine(eps, policy="mhra", engine="clone")
    with pytest.raises(ValueError, match="clone"):
        OnlineEngine(eps, policy=get_policy("mhra", engine="clone"))


# ---------------------------------------------------------------------------
# SoAState unit behavior
# ---------------------------------------------------------------------------


def test_soa_state_heap_round_trip():
    tasks, eps, store, tm = _setup(n_per=4)
    heap = SchedulerState(eps, tm)
    mhra(tasks, eps, store, tm, alpha=0.5, engine="delta", state=heap)
    soa = SoAState.from_heap(heap)
    assert soa.metrics() == heap.metrics()
    back = SchedulerState(eps, tm)
    soa.write_back(back)
    assert back.metrics() == heap.metrics()
    assert {k: sorted(v) for k, v in back.slots.items()} == \
           {k: sorted(v) for k, v in heap.slots.items()}
    assert back.timeline == heap.timeline


def test_soa_state_advance_to():
    eps = table1_testbed()
    s = SoAState(eps, TransferModel(eps))
    s.advance_to(12.5)
    assert float(s.free.min()) == 12.5
    assert np.all(s.slot_mins() == 12.5)


def test_delta_engine_accepts_soa_live_state():
    """mhra(engine="delta") over a SoA-backed live state must behave like
    the same placement over a heap-backed state (the conversion branch)."""
    tasks, eps, store, tm = _setup(n_per=8)
    heap = SchedulerState(eps, tm)
    soa = SoAState(eps, tm)
    a = mhra(tasks, eps, store, tm, alpha=0.5, engine="delta", state=heap)
    b = mhra(tasks, eps, store, tm, alpha=0.5, engine="delta", state=soa)
    assert a.assignments == b.assignments
    assert a.objective == b.objective
    assert heap.metrics() == soa.metrics()


def test_fixed_assignment_on_soa_state():
    tasks, eps, store, tm = _setup(n_per=4, with_inputs=False)
    a = round_robin(tasks, eps, store, tm, state=SchedulerState(eps, tm))
    b = round_robin(tasks, eps, store, tm, state=SoAState(eps, tm))
    assert a.assignments == b.assignments
    assert a.energy_j == b.energy_j
    assert a.makespan_s == b.makespan_s


def test_policy_registry_soa_round_trip():
    p = get_policy("mhra", engine="soa")
    assert p.engine == "soa"
    p = get_policy("cluster_mhra", engine="soa")
    assert p.engine == "soa"
    with pytest.raises(ValueError, match="engine"):
        get_policy("mhra", engine="bogus")
    with pytest.raises(ValueError):
        mhra([], table1_testbed(), TaskProfileStore([]), None, engine="bogus")
