"""Policy registry + delta-engine parity tests.

The delta-evaluation greedy must produce *identical* assignments and
objective values to the seed clone-per-candidate greedy — same inputs,
same seed — for every built-in policy on the Table-V workload shape.
"""
import numpy as np
import pytest

from repro.core.endpoint import table1_testbed
from repro.core.executor import GreenFaaSExecutor
from repro.core.policy import (
    PlacementPolicy,
    PolicyContext,
    available_policies,
    get_policy,
    register_policy,
)
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import (
    SchedulerState,
    TaskSpec,
    cluster_mhra,
    mhra,
    round_robin,
    single_site,
)
from repro.core.testbed import BASE_PROFILES, SEBS_FUNCTIONS, TestbedSim
from repro.core.transfer import TransferModel


def _table5_setup(n_per=64, with_inputs=True):
    """The paper's Table-V workload shape: n_per invocations of each of the
    7 SeBS functions, inputs on desktop (shared/cacheable)."""
    eps = table1_testbed()
    store = TaskProfileStore(eps)
    for fn in SEBS_FUNCTIONS:
        for ep in eps:
            rt, w = BASE_PROFILES[fn][ep.name]
            for _ in range(3):
                store.record(fn, ep.name, rt, rt * w)
    inputs = (("desktop", 1, 200e6, True),) if with_inputs else ()
    tasks = [
        TaskSpec(id=f"t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)],
                 inputs=inputs)
        for i in range(n_per * len(SEBS_FUNCTIONS))
    ]
    return tasks, eps, store, TransferModel(eps)


# ---------------------------------------------------------------------------
# delta vs clone parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alpha", [0.0, 0.2, 0.5, 1.0])
@pytest.mark.parametrize("strategy", [mhra, cluster_mhra])
def test_delta_engine_matches_clone_engine(strategy, alpha):
    tasks, eps, store, tm = _table5_setup(n_per=32)
    a = strategy(tasks, eps, store, tm, alpha=alpha, engine="delta")
    b = strategy(tasks, eps, store, tm, alpha=alpha, engine="clone")
    assert a.assignments == b.assignments
    assert a.objective == b.objective          # bitwise, not approx
    assert a.energy_j == b.energy_j
    assert a.makespan_s == b.makespan_s
    assert a.transfer_j == b.transfer_j
    assert a.heuristic == b.heuristic


def test_delta_engine_matches_clone_without_inputs():
    tasks, eps, store, tm = _table5_setup(n_per=32, with_inputs=False)
    a = mhra(tasks, eps, store, tm, alpha=0.5, engine="delta")
    b = mhra(tasks, eps, store, tm, alpha=0.5, engine="clone")
    assert a.assignments == b.assignments
    assert a.objective == b.objective


def test_policy_parity_all_four_on_table5():
    """Every registered built-in policy: the policy object (delta engine)
    must reproduce the legacy function entry points exactly."""
    tasks, eps, store, tm = _table5_setup(n_per=24)
    ctx = PolicyContext(eps, store, tm, alpha=0.5)

    legacy = {
        "mhra": mhra(tasks, eps, store, tm, alpha=0.5, engine="clone"),
        "cluster_mhra": cluster_mhra(tasks, eps, store, tm, alpha=0.5,
                                     engine="clone"),
        "round_robin": round_robin(tasks, eps, store, tm),
        "single_site": single_site(tasks, eps, store, tm, "ic"),
    }
    for name, expect in legacy.items():
        policy = get_policy(name, site="ic") if name == "single_site" else get_policy(name)
        got = policy.place(tasks, ctx)
        assert got.assignments == expect.assignments, name
        assert got.energy_j == expect.energy_j, name
        assert got.makespan_s == expect.makespan_s, name
        if not np.isnan(expect.objective):
            assert got.objective == expect.objective, name


def test_unused_mhra_state_arg_rejected_on_clone():
    tasks, eps, store, tm = _table5_setup(n_per=2)
    with pytest.raises(ValueError):
        mhra(tasks, eps, store, tm, engine="clone",
             state=SchedulerState(eps, tm))
    with pytest.raises(ValueError):
        mhra(tasks, eps, store, tm, engine="nope")
    with pytest.raises(ValueError, match="heuristic"):
        mhra(tasks, eps, store, tm, heuristics=())


# ---------------------------------------------------------------------------
# registry round-trips
# ---------------------------------------------------------------------------


def test_registry_has_builtin_policies():
    assert {"mhra", "cluster_mhra", "round_robin", "single_site"} <= set(
        available_policies()
    )


def test_registry_round_trip():
    p = get_policy("cluster_mhra", max_cluster_size=12)
    assert p.name == "cluster_mhra"
    assert p.max_cluster_size == 12


def test_register_custom_policy():
    @register_policy
    class FirstEndpointPolicy(PlacementPolicy):
        name = "first_endpoint_test"

        def place(self, tasks, ctx, state=None):
            from repro.core.scheduler import fixed_assignment
            first = ctx.endpoints[0].name
            return fixed_assignment(
                tasks, ctx.endpoints, ctx.store, ctx.transfer,
                lambda i, t: first, state=state,
            )

    tasks, eps, store, tm = _table5_setup(n_per=2)
    p = get_policy("first_endpoint_test")
    s = p.place(tasks, PolicyContext(eps, store, tm))
    assert set(s.assignments.values()) == {eps[0].name}


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown policy"):
        get_policy("not_a_policy")


def test_unnamed_policy_rejected():
    with pytest.raises(ValueError, match="name"):
        @register_policy
        class Nameless(PlacementPolicy):
            def place(self, tasks, ctx, state=None):
                raise NotImplementedError


def test_single_site_requires_site():
    with pytest.raises(ValueError, match="site"):
        get_policy("single_site")
    tasks, eps, store, tm = _table5_setup(n_per=2)
    with pytest.raises(ValueError, match="single_site"):
        single_site(tasks, eps, store, tm, "nonexistent")


def test_executor_validates_single_site():
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0)
    with pytest.raises(ValueError, match="single_site"):
        GreenFaaSExecutor(eps, sim, strategy="single_site", site=None)
    with pytest.raises(ValueError, match="single_site"):
        GreenFaaSExecutor(eps, sim, strategy="single_site", site="no_such_ep")
    ex = GreenFaaSExecutor(eps, sim, strategy="single_site", site="desktop")
    assert ex.policy.site == "desktop"


def test_executor_accepts_policy_instance():
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0)
    ex = GreenFaaSExecutor(eps, sim, policy=get_policy("round_robin"))
    assert ex.policy.name == "round_robin"
