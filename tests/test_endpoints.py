"""Endpoint model, predictor fallbacks, mesh construction, input specs."""
import jax
import pytest

from repro.core.endpoint import EndpointSpec, table1_testbed, tpu_fleet
from repro.core.predictor import TaskProfileStore
from repro.models.registry import ARCH_IDS, SHAPES, get_config, input_specs, shape_cells


def test_table1_matches_paper():
    eps = {e.name: e for e in table1_testbed()}
    assert eps["desktop"].cores == 16 and eps["desktop"].idle_power_w == 6.51
    assert eps["theta"].cores == 64 and eps["theta"].idle_power_w == 110.0
    assert eps["ic"].cores == 48 and eps["ic"].idle_power_w == 136.0
    assert eps["faster"].cores == 64 and eps["faster"].idle_power_w == 205.0
    # desktop is always-on: no startup energy to amortize (paper §III-F)
    assert eps["desktop"].startup_energy_j == 0.0
    assert eps["faster"].startup_energy_j > 0


def test_tpu_fleet_heterogeneous():
    eps = tpu_fleet()
    names = {e.name for e in eps}
    assert {"pod0", "pod1", "slice0", "oldpod"} <= names
    slice0 = next(e for e in eps if e.name == "slice0")
    assert not slice0.has_batch_scheduler  # the 'desktop' analogue
    old = next(e for e in eps if e.name == "oldpod")
    assert old.peak_flops < next(e for e in eps if e.name == "pod0").peak_flops


def test_hop_counts_symmetric_defaults():
    eps = table1_testbed()
    desktop = eps[0]
    assert desktop.hop_count(desktop) == 0
    assert desktop.hop_count("theta") == 10
    assert desktop.hop_count("unknown-site") > 0  # default


def test_predictor_cold_start_fallbacks():
    eps = table1_testbed()
    store = TaskProfileStore(eps)
    # never seen anywhere -> exploration prior, not confident
    p = store.predict("newfn", "desktop")
    assert not p.confident and p.runtime_s > 0
    # seen on one endpoint -> perf-scaled estimate elsewhere, not confident
    store.record("newfn", "desktop", 10.0, 100.0)
    q = store.predict("newfn", "faster")
    assert not q.confident
    assert q.runtime_s < 10.0  # faster has higher perf_scale than desktop
    # seen here -> confident
    r = store.predict("newfn", "desktop")
    assert r.confident and r.runtime_s == pytest.approx(10.0)


def test_predictor_drift_sigma():
    store = TaskProfileStore()
    for x in (10.0, 10.1, 9.9, 10.05, 9.95):
        store.record("fn", "ep", x, 1.0)
    assert store.drift_sigma("fn", "ep", 10.0) < 1.0
    assert store.drift_sigma("fn", "ep", 15.0) > 3.0


def test_input_specs_shapes_per_cell():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in shape_cells(arch):
            seq, gb, kind = SHAPES[cell]
            specs = input_specs(cfg, cell)
            if kind == "train":
                assert specs["tokens"].shape == (gb, seq)
                assert specs["labels"].shape == (gb, seq)
            elif kind == "prefill":
                assert specs["tokens"].shape == (gb, seq)
            else:
                assert specs["tokens"].shape == (gb, 1)
                assert "cache" in specs
                # seq-indexed cache buffers carry the context length
                leaves = jax.tree.leaves(specs["cache"])
                assert any(seq in l.shape for l in leaves) or cfg.family == "ssm"


def test_frontend_stubs_in_specs():
    whisper = input_specs(get_config("whisper-tiny"), "train_4k")
    assert whisper["frames"].shape == (256, 1500, 384)  # precomputed frames
    vlm = input_specs(get_config("internvl2-26b"), "train_4k")
    assert vlm["vision_embeds"].shape == (256, 256, 6144)  # patch embeds


def test_serve_rule_policy():
    import os

    from repro.distributed.sharding import serve_rule_overrides
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    small = get_config("granite-3-2b")
    big = get_config("deepseek-67b")
    moe = get_config("moonshot-v1-16b-a3b")
    # single-device host mesh: model axis = 1 -> weights never fit threshold
    # logic still returns a dict without raising
    assert isinstance(serve_rule_overrides(small, mesh, int(2.6e9), int(1e9)), dict)
    # MoE always excluded (measured regression)
    assert serve_rule_overrides(moe, mesh, int(1e6), 0) == {}
