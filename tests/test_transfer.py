"""Transfer model: hop energy, caching, time regression."""
import numpy as np
import pytest

from repro.core.endpoint import table1_testbed
from repro.core.transfer import E_INC_J_PER_BYTE, TransferModel, TransferRequest


@pytest.fixture
def tm():
    return TransferModel(table1_testbed())


def test_same_site_free(tm):
    r = TransferRequest("desktop", "desktop", 1, 1e9)
    assert tm.energy_j(r) == 0.0
    assert tm.hops("desktop", "desktop") == 0


def test_energy_scales_with_bytes_and_hops(tm):
    r1 = TransferRequest("desktop", "ic", 1, 1e9)
    r2 = TransferRequest("desktop", "ic", 1, 2e9)
    assert tm.energy_j(r2) == pytest.approx(2 * tm.energy_j(r1))
    # theta is more hops from desktop than ic is
    r3 = TransferRequest("desktop", "theta", 1, 1e9)
    assert tm.energy_j(r3) > tm.energy_j(r1)


def test_hpc_sites_add_dtn_fs_hops(tm):
    # desktop (no DTN) -> ic (DTN+FS): 2 extra hops over the raw path
    base = tm.eps["desktop"].hop_count("ic")
    assert tm.hops("desktop", "ic") == base + 2
    assert tm.hops("ic", "theta") == tm.eps["ic"].hop_count("theta") + 4


def test_shared_files_cached(tm):
    r = TransferRequest("desktop", "faster", 1, 1e9, shared=True)
    e1 = tm.energy_j(r)
    assert e1 > 0
    tm.mark_cached(r)
    assert tm.energy_j(r) == 0.0


def test_time_regression_learns(tm):
    rng = np.random.default_rng(0)
    # ground truth: 1.0 s + 0.002 s/file + 0.08 s/GB
    for _ in range(200):
        nf = int(rng.integers(1, 200))
        nb = float(rng.uniform(1e8, 5e10))
        tm.observe(nf, nb, 1.0 + 0.002 * nf + 0.08 * nb / 1e9 + rng.normal(0, 0.01))
    pred = tm.predict_seconds(100, 10e9)
    assert pred == pytest.approx(1.0 + 0.2 + 0.8, rel=0.1)


def test_batch_cost_groups_by_pair(tm):
    reqs = [
        TransferRequest("desktop", "ic", 1, 1e9),
        TransferRequest("desktop", "ic", 1, 1e9),
        TransferRequest("desktop", "faster", 1, 1e9),
    ]
    secs, joules = tm.batch_cost(reqs)
    assert joules == pytest.approx(
        2 * tm.energy_j(reqs[0]) + tm.energy_j(reqs[2])
    )
    assert secs > 0


def test_e_inc_constant_matches_formula():
    assert E_INC_J_PER_BYTE == pytest.approx(4000.0 / 100e9 * 8)
