"""End-to-end system tests: the full GreenFaaS pipeline on the simulated
Table-I testbed, fleet fault tolerance, and the energy report."""
import numpy as np
import pytest

from repro.core.database import TaskDB
from repro.core.endpoint import table1_testbed, tpu_fleet
from repro.core.executor import GreenFaaSExecutor
from repro.core.report import html_report, text_report
from repro.core.scheduler import TaskSpec
from repro.core.testbed import SEBS_FUNCTIONS, TestbedSim


def _workload(n_per=24):
    tasks = []
    i = 0
    for fn in SEBS_FUNCTIONS:
        for _ in range(n_per):
            tasks.append(
                TaskSpec(id=f"t{i}", fn=fn, inputs=(("desktop", 1, 50e6, True),))
            )
            i += 1
    return tasks


def _run(strategy, alpha=0.5, site=None, n_per=24, seed=1):
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=seed)
    ex = GreenFaaSExecutor(eps, sim, alpha=alpha, strategy=strategy, site=site)
    ex.warmup(list(SEBS_FUNCTIONS), per_endpoint=2)
    return ex, ex.run_batch(_workload(n_per))


def test_pipeline_end_to_end_cluster_mhra():
    ex, res = _run("cluster_mhra", alpha=0.5)
    assert res.makespan_s > 0
    assert res.measured_energy_j > 0
    # attribution produced per-task energies for every task
    recs = [r for r in ex.db.records]
    assert len(recs) == len(_workload())
    assert all(r.energy_j is not None and r.energy_j >= 0 for r in recs)
    # measured (monitor) energy within 25% of simulator ground truth
    truth = res.sim.true_energy_j
    assert res.measured_energy_j == pytest.approx(truth, rel=0.25)


def test_cluster_mhra_dominates_round_robin_on_edp():
    _, rr = _run("round_robin")
    _, cm = _run("cluster_mhra", alpha=0.2)
    assert cm.edp() < rr.edp()


def test_alpha_one_matches_single_cheapest_site():
    """Paper: alpha=1.0 reproduces the all-desktop schedule."""
    _, cm = _run("cluster_mhra", alpha=1.0)
    _, ds = _run("single_site", site="desktop")
    assert cm.measured_energy_j == pytest.approx(ds.measured_energy_j, rel=0.1)


def test_online_profiles_converge():
    """After a batch, the store's predictions approximate the sim truth."""
    ex, _ = _run("round_robin")
    sim = ex.backend
    for fn in SEBS_FUNCTIONS[:3]:
        for ep in ["desktop", "faster"]:
            if ex.store.n_obs(fn, ep) == 0:
                continue
            pred = ex.store.predict(fn, ep)
            rt_true, w_true, _ = sim.task_truth(fn, ep)
            assert pred.runtime_s == pytest.approx(rt_true, rel=0.3), (fn, ep)


def test_energy_report(tmp_path):
    ex, _ = _run("cluster_mhra", n_per=8)
    txt = text_report(ex.db, user="user0")
    assert "GreenFaaS energy report" in txt
    assert any(fn in txt for fn in SEBS_FUNCTIONS)
    html = html_report(ex.db, tmp_path / "report.html")
    assert (tmp_path / "report.html").exists()
    assert "endpoint energy usage" in html


def test_db_roundtrip(tmp_path):
    ex, _ = _run("round_robin", n_per=4)
    ex.db.path = tmp_path / "db.json"
    ex.db.save()
    db2 = TaskDB(tmp_path / "db.json")
    assert len(db2.records) == len(ex.db.records)
    assert db2.energy_by_endpoint().keys() == ex.db.energy_by_endpoint().keys()


# ---------------------------------------------------------------------------
# fleet fault tolerance
# ---------------------------------------------------------------------------


def _fleet_mgr(tmp_path):
    import json

    from repro.fleet.manager import FleetManager

    d = tmp_path / "dryrun"
    d.mkdir()
    (d / "a__train_4k__single.json").write_text(json.dumps({
        "arch": "granite-3-2b", "shape": "train_4k", "n_devices": 256,
        "extrapolated": {"flops_extrap": 1e14, "bytes_extrap": 1e12,
                         "coll_bytes_extrap": 1e10},
    }))
    return FleetManager(tpu_fleet(), d)


def test_fleet_placement_and_heartbeats(tmp_path):
    from repro.fleet.manager import FleetJob, HEARTBEAT_TIMEOUT_S

    mgr = _fleet_mgr(tmp_path)
    jobs = [FleetJob(id=f"j{i}", arch="granite-3-2b", shape="train_4k") for i in range(6)]
    s = mgr.place(jobs)
    assert set(s.assignments) == {j.id for j in jobs}
    # endpoint misses heartbeats -> marked down -> placement avoids it
    t0 = 1000.0
    for name in mgr.endpoints:
        mgr.heartbeat(name, now=t0)
    mgr.heartbeat("pod0", now=t0)  # pod0 then goes silent
    for name in mgr.endpoints:
        if name != "pod0":
            mgr.heartbeat(name, now=t0 + HEARTBEAT_TIMEOUT_S + 5)
    down = mgr.check_health(now=t0 + HEARTBEAT_TIMEOUT_S + 5)
    assert down == ["pod0"]
    s2 = mgr.place(jobs)
    assert "pod0" not in set(s2.assignments.values())


def test_fleet_straggler_detection(tmp_path):
    from repro.fleet.manager import FleetJob

    mgr = _fleet_mgr(tmp_path)
    job = FleetJob(id="j0", arch="granite-3-2b", shape="train_4k")
    rng = np.random.default_rng(0)
    for _ in range(10):
        mgr.observe_step(job, "pod0", seconds=1.0 + rng.normal(0, 0.01), energy_j=100.0)
    assert not mgr.observe_step(job, "pod0", seconds=1.01, energy_j=100.0)
    assert mgr.observe_step(job, "pod0", seconds=5.0, energy_j=100.0)  # 3sigma+
    assert any("straggler" in e for e in mgr.events)


def test_fleet_elastic_join_leave(tmp_path):
    from repro.core.endpoint import EndpointSpec
    from repro.fleet.manager import FleetJob

    mgr = _fleet_mgr(tmp_path)
    jobs = [FleetJob(id=f"j{i}", arch="granite-3-2b", shape="train_4k") for i in range(4)]
    mgr.endpoint_leave("pod1")
    s = mgr.place(jobs)
    assert "pod1" not in set(s.assignments.values())
    mgr.endpoint_join(EndpointSpec(
        "pod9", cores=512, idle_power_w=80 * 512, tdp_w=250 * 512,
        queue_delay_s=60.0, chips=512, peak_flops=197e12, hbm_bw=819e9,
        ici_bw=50e9,
    ))
    assert "pod9" in {e.name for e in mgr.live_endpoints()}
