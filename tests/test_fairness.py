"""Fairness-layer tests: FairShare validation, the deficit-counter
ledger's settle/bank/debt semantics, FairnessWeights snapshots, the
engine's shed/defer admission control, the multiuser workload generator,
and the fairness=None bitwise-identity guarantee."""
import dataclasses

import numpy as np
import pytest

from repro.core.endpoint import table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.fairness import FairnessLedger, FairnessWeights, FairShare
from repro.core.scheduler import TaskSpec
from repro.core.testbed import SEBS_FUNCTIONS, TestbedSim
from repro.workloads import multiuser_edp_workload, zipf_user_ranks

# ---------------------------------------------------------------------------
# FairShare / FairnessLedger
# ---------------------------------------------------------------------------


def test_fairshare_validation():
    with pytest.raises(ValueError, match="budget_j"):
        FairShare(budget_j=0.0)
    with pytest.raises(ValueError, match="window_s"):
        FairShare(budget_j=1.0, window_s=-1.0)
    with pytest.raises(ValueError, match="mu"):
        FairShare(budget_j=1.0, mu=-0.5)
    with pytest.raises(ValueError, match="budget_g"):
        FairShare(budget_j=1.0, budget_g=0.0)
    with pytest.raises(ValueError, match="debt_cap"):
        FairShare(budget_j=1.0, debt_cap=0.0)
    with pytest.raises(ValueError, match="bank_windows"):
        FairShare(budget_j=1.0, bank_windows=-1.0)
    with pytest.raises(ValueError, match="weights"):
        FairShare(budget_j=1.0, weights={"u": 0.0})


def test_ledger_new_users_start_with_full_bank():
    led = FairShare(budget_j=100.0, window_s=10.0, bank_windows=2.0).ledger()
    assert led.credit_j("fresh") == 200.0
    assert led.debt("fresh") == 0.0
    assert led.users() == ["fresh"]


def test_ledger_charge_and_replenish():
    led = FairShare(budget_j=100.0, window_s=10.0).ledger()
    led.charge("u", 250.0)          # bank 100 -> -150: 1.5 windows behind
    assert led.credit_j("u") == -150.0
    assert led.debt("u") == pytest.approx(1.5)
    led.advance(10.0)               # one replenish: +100
    assert led.credit_j("u") == -50.0
    assert led.debt("u") == pytest.approx(0.5)
    led.advance(25.0)               # epoch 2: back in credit, bank-capped
    assert led.credit_j("u") == 50.0
    assert led.debt("u") == 0.0
    led.advance(90.0)               # idle epochs cap at the bank
    assert led.credit_j("u") == 100.0


def test_ledger_advance_is_monotone():
    led = FairShare(budget_j=10.0, window_s=10.0).ledger()
    assert led.advance(55.0) == 5
    assert led.advance(20.0) == 5   # stale clock never rolls back
    assert led.next_replenish(55.0) == 60.0
    assert led.next_replenish(60.0) == 70.0


def test_ledger_debt_is_capped():
    led = FairShare(budget_j=10.0, window_s=10.0, debt_cap=3.0).ledger()
    led.charge("u", 1e6)
    assert led.debt("u") == 3.0


def test_ledger_share_weights_scale_budget():
    led = FairShare(budget_j=100.0, window_s=10.0,
                    weights={"big": 2.0}).ledger()
    led.charge("big", 250.0)
    led.charge("small", 250.0)
    # big banks 200 and earns 200/window; small banks/earns 100
    assert led.credit_j("big") == -50.0
    assert led.debt("big") == pytest.approx(50.0 / 200.0)
    assert led.debt("small") == pytest.approx(150.0 / 100.0)


def test_ledger_carbon_component_adds_to_debt():
    led = FairShare(budget_j=100.0, window_s=10.0, budget_g=10.0).ledger()
    assert led.tracks_carbon
    led.charge("u", 150.0, carbon_g=15.0)
    # half a window behind on energy + half a window behind on carbon
    assert led.debt("u") == pytest.approx(0.5 + 0.5)


def test_fairness_weights_from_ledger():
    led = FairShare(budget_j=100.0, window_s=10.0, mu=0.7).ledger()
    led.charge("hog", 350.0)
    tasks = [TaskSpec(id="a", fn="graph_bfs", user="hog"),
             TaskSpec(id="b", fn="graph_bfs", user="saint")]
    w = FairnessWeights.from_ledger(led, tasks)
    assert w is not None and w.mu == 0.7
    assert set(w.debt) == {"hog"}          # debt-free users never appear
    assert w.debt["hog"] == pytest.approx(2.5)
    # all submitting users debt-free -> None (hot path untouched)
    assert FairnessWeights.from_ledger(
        led, [TaskSpec(id="c", fn="graph_bfs", user="saint")]) is None
    # mu == 0 -> None even with debt on the books
    assert FairnessWeights.from_ledger(led, tasks, mu=0.0) is None


def test_fairness_weights_validation():
    with pytest.raises(ValueError, match="mu"):
        FairnessWeights(debt={"u": 1.0}, mu=-1.0)
    with pytest.raises(ValueError, match="positive"):
        FairnessWeights(debt={"u": 0.0})


# ---------------------------------------------------------------------------
# OnlineEngine admission control
# ---------------------------------------------------------------------------


def _engine(**kw):
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0)
    kw = {"window_s": 30.0, "max_batch": 10**6, "monitoring": False,
          "alpha": 0.2, "policy": "mhra"} | kw
    return OnlineEngine(eps, sim, **kw)


def _burst(w, user, n):
    return [TaskSpec(id=f"{user}w{w}t{i}", fn=SEBS_FUNCTIONS[i % 7],
                     user=user) for i in range(n)]


def test_engine_admission_validation():
    with pytest.raises(ValueError, match="admission"):
        _engine(fairness=FairShare(budget_j=1.0), admission="bogus")
    with pytest.raises(ValueError, match="fairness"):
        _engine(admission="shed")        # admission needs a ledger
    with pytest.raises(ValueError, match="admission_debt"):
        _engine(fairness=FairShare(budget_j=1.0), admission="shed",
                admission_debt=0.0)


def test_shed_admission_rejects_over_budget_work():
    """An over-budget user's later bursts are shed, recorded, and counted
    in the summary; a debt-free user sails through untouched."""
    eng = _engine(fairness=FairShare(budget_j=50.0, window_s=30.0, mu=0.0),
                  admission="shed")
    for w in range(4):
        eng.submit_many(_burst(w, "hog", 40) + _burst(w, "saint", 2))
        eng.tick((w + 1) * 30.0)
    eng.drain()
    s = eng.summary()
    assert s.shed > 0
    assert len(eng.shed) == s.shed == len(eng.shed_ids)
    assert all(t.user == "hog" for t in eng.shed)       # saint never shed
    assert s.goodput == pytest.approx(1.0 - s.shed / (4 * 42))
    # shed tasks are queryable, not silently dropped
    assert {t.id for t in eng.shed} == eng.shed_ids


def test_defer_admission_delays_but_never_drops():
    shed_free = FairShare(budget_j=50.0, window_s=30.0, mu=0.0)
    eng = _engine(fairness=shed_free, admission="defer",
                  admission_max_defer=4)
    for w in range(4):
        eng.submit_many(_burst(w, "hog", 40) + _burst(w, "saint", 2))
        eng.tick((w + 1) * 30.0)
    eng.drain()
    s = eng.summary()
    assert s.shed == 0
    assert s.admission_deferred > 0
    assert s.goodput == 1.0                 # latency traded, tasks kept
    assert s.tasks == 4 * 42


def test_admission_defer_cap_prevents_starvation():
    """A permanently over-budget user is admitted after
    admission_max_defer deferrals rather than parked forever."""
    eng = _engine(fairness=FairShare(budget_j=1.0, window_s=30.0, mu=0.0),
                  admission="defer", admission_max_defer=2)
    for w in range(6):
        eng.submit_many(_burst(w, "hog", 30))
        eng.tick((w + 1) * 30.0)
    eng.drain()
    s = eng.summary()
    assert s.goodput == 1.0
    assert s.tasks == 6 * 30


def test_ledger_charges_follow_execution():
    eng = _engine(fairness=FairShare(budget_j=1e-3, window_s=1e6, mu=0.0))
    eng.submit_many(_burst(0, "hog", 10))
    eng.tick(30.0)
    eng.drain()
    led = eng.fairness
    assert isinstance(led, FairnessLedger)
    assert led.credit_j("hog") < 0.0        # real joules were billed
    assert led.debt("hog") > 0.0


def test_fairness_none_is_bitwise_identity():
    """fairness=None leaves every engine summary and placement exactly as
    the seed engine produced them (scheduling_s is wall-clock and the
    only legitimately varying field)."""
    def run(**kw):
        eng = _engine(**kw)
        asg = {}
        for w in range(3):
            eng.submit_many(_burst(w, "u", 50))
            res = eng.flush()
            asg.update(res.assignments)
        eng.drain()
        d = dataclasses.asdict(eng.summary())
        d.pop("scheduling_s")
        return asg, d
    base = run()
    plain = run(fairness=None)
    assert base == plain


# ---------------------------------------------------------------------------
# multiuser workload generator
# ---------------------------------------------------------------------------


def test_zipf_user_ranks_range_and_determinism():
    r1 = zipf_user_ranks(500, 1000, 1.3, np.random.default_rng(7))
    r2 = zipf_user_ranks(500, 1000, 1.3, np.random.default_rng(7))
    assert np.array_equal(r1, r2)
    assert r1.min() >= 1 and r1.max() <= 1000
    # Zipf head: rank 1 dominates
    assert (r1 == 1).sum() > (r1 == 2).sum() > 0
    with pytest.raises(ValueError, match="zipf_s"):
        zipf_user_ranks(10, 100, 1.0, np.random.default_rng(0))


def test_multiuser_workload_shape_and_determinism():
    t1 = multiuser_edp_workload(n_tasks=200, n_users=10_000, seed=5)
    t2 = multiuser_edp_workload(n_tasks=200, n_users=10_000, seed=5)
    assert [t.id for t in t1.tasks] == [t.id for t in t2.tasks]
    assert [t.user for t in t1.tasks] == [t.user for t in t2.tasks]
    assert np.array_equal(t1.arrivals, t2.arrivals)
    assert len(t1.tasks) == 200
    assert np.all(np.diff(t1.arrivals) >= 0.0)      # sorted submission order
    users = {t.user for t in t1.tasks}
    assert t1.meta["users_active"] == len(users)
    assert 0.0 < t1.meta["top_user_share"] <= 1.0
    assert t1.meta["users_universe"] == 10_000
    # a 1M universe costs nothing: only active users materialize
    big = multiuser_edp_workload(n_tasks=64, n_users=1_000_000, seed=5)
    assert big.meta["users_active"] <= 64


def test_multiuser_workload_validation():
    with pytest.raises(ValueError, match="n_tasks"):
        multiuser_edp_workload(n_tasks=0)
    with pytest.raises(ValueError, match="n_users"):
        multiuser_edp_workload(n_tasks=10, n_users=1)
    with pytest.raises(ValueError, match="class_mix"):
        multiuser_edp_workload(n_tasks=10, class_mix=(1.0, -0.1, 0.1))
    with pytest.raises(ValueError, match="campaign_span_s"):
        multiuser_edp_workload(n_tasks=10, campaign_span_s=-1.0)
    with pytest.raises(ValueError, match="home"):
        multiuser_edp_workload(n_tasks=10, home="nonsense")
