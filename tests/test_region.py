"""Region layer: spec/link hand-checks, router scoring + tie-breaks,
the endpoint-mask collapse, single-region bitwise inertness on all three
engines, multi-region delta/soa parity, and caller-locality WAN billing
(exact joules, per-destination shared cache, not_before delays)."""
import dataclasses

import pytest

from repro.core import scheduler as sched
from repro.core.carbon import CarbonIntensitySignal, CarbonTrace
from repro.core.engine import OnlineEngine
from repro.core.evaluate import run_policy, warm_store
from repro.core.region import (
    DEFAULT_WAN_BW_BPS,
    DEFAULT_WAN_J_PER_BYTE,
    DEFAULT_WAN_LATENCY_S,
    INVOKE_BYTES,
    RegionRouter,
    RegionSpec,
    task_payload_bytes,
    task_shared_inputs,
)
from repro.core.scheduler import TaskSpec
from repro.core.testbed import TestbedSim
from repro.core.transfer import TransferModel
from repro.workloads import geo_edp_workload, synthetic_edp_workload


# ---------------------------------------------------------------------------
# RegionSpec: validation + the WAN link model
# ---------------------------------------------------------------------------

def test_region_spec_validation():
    with pytest.raises(ValueError, match="no endpoints"):
        RegionSpec("r", ())
    with pytest.raises(ValueError, match="duplicate endpoints"):
        RegionSpec("r", ("a", "a"))
    with pytest.raises(ValueError, match="capacity"):
        RegionSpec("r", ("a",), capacity=-1)
    with pytest.raises(ValueError, match="wan_bw_bps"):
        RegionSpec("r", ("a",), wan_bw_bps={"s": 0.0})
    with pytest.raises(ValueError, match="wan_latency_s"):
        RegionSpec("r", ("a",), wan_latency_s={"s": -0.1})


def test_wan_link_model_hand_computed():
    r = RegionSpec("r", ("a",), wan_bw_bps={"s": 1e6},
                   wan_latency_s={"s": 0.5}, wan_j_per_byte={"s": 2e-7})
    # same-region transfers are free by construction
    assert r.wan_delay_s("r", 1e9) == 0.0
    assert r.wan_joules("r", 1e9) == 0.0
    # explicit link: latency + serialization, bytes x J/B
    assert r.wan_delay_s("s", 2e6) == pytest.approx(0.5 + 2.0)
    assert r.wan_joules("s", 2e6) == pytest.approx(0.4)
    # unlisted pair: module defaults
    assert r.wan_delay_s("t", 1.25e9) == pytest.approx(
        DEFAULT_WAN_LATENCY_S + 1.25e9 / DEFAULT_WAN_BW_BPS)
    assert r.wan_joules("t", 1e6) == pytest.approx(
        1e6 * DEFAULT_WAN_J_PER_BYTE)


def test_task_payload_helpers():
    t = TaskSpec(id="t", fn="f", inputs=(
        ("home", 1, 1e6, False),
        ("home", 4, 5e6, True),
    ))
    # invocation payload + private bytes; shared datasets billed apart
    assert task_payload_bytes(t) == pytest.approx(INVOKE_BYTES + 1e6)
    assert task_shared_inputs(t) == [("home", 5e6)]
    bare = TaskSpec(id="b", fn="f")
    assert task_payload_bytes(bare) == pytest.approx(INVOKE_BYTES)
    assert task_shared_inputs(bare) == []


# ---------------------------------------------------------------------------
# RegionRouter: construction, modes, scoring
# ---------------------------------------------------------------------------

def _two_regions():
    ra = RegionSpec("ra", ("a1", "a2"), callers=("alice",))
    rb = RegionSpec("rb", ("b1",), callers=("bob",))
    return ra, rb


def test_router_validation():
    ra, rb = _two_regions()
    with pytest.raises(ValueError, match="at least one region"):
        RegionRouter([])
    with pytest.raises(ValueError, match="duplicate region"):
        RegionRouter([ra, dataclasses.replace(ra, endpoints=("x",))])
    with pytest.raises(ValueError, match="in both"):
        RegionRouter([ra, RegionSpec("rc", ("a1",))])
    with pytest.raises(ValueError, match="homed in both"):
        RegionRouter([ra, RegionSpec("rc", ("c1",), callers=("alice",))])
    with pytest.raises(ValueError, match="unknown router mode"):
        RegionRouter([ra, rb], mode="nearest")
    with pytest.raises(ValueError, match="home region"):
        RegionRouter([ra, rb], home="nowhere")
    with pytest.raises(ValueError, match="beta_queue"):
        RegionRouter([ra, rb], beta_queue=-1.0)
    with pytest.raises(ValueError, match="rt_scale"):
        RegionRouter([ra, rb], rt_scale=0.0)


def test_fixed_and_caller_modes_route_by_locality():
    ra, rb = _two_regions()
    fixed = RegionRouter([ra, rb], mode="fixed", home="rb")
    assert fixed.route("alice", 1e6, 0.0) == ("ra", "rb")
    assert fixed.route("bob", 1e6, 0.0) == ("rb", "rb")
    # unlisted callers are homed in the router's home region
    assert fixed.route("nobody", 1e6, 0.0) == ("rb", "rb")
    caller = RegionRouter([ra, rb], mode="caller")
    assert caller.route("alice", 1e6, 0.0) == ("ra", "ra")
    assert caller.route("bob", 1e6, 0.0) == ("rb", "rb")
    assert caller.region_of("b1") == "rb"
    with pytest.raises(KeyError):
        caller.region_of("nope")


def test_agent_score_hand_computed():
    ra = RegionSpec("ra", ("a1",), wan_j_per_byte={"rb": 2e-7},
                    callers=("alice",))
    rb = RegionSpec("rb", ("b1",))
    # flat 360 g/kWh = 1e-4 g/J in ra, 3.6e-4 g/J in rb
    sig = CarbonIntensitySignal({
        "ra": CarbonTrace([0.0, 10.0], [360.0, 360.0]),
        "rb": CarbonTrace([0.0, 10.0], [1080.0, 1080.0]),
    })
    r = RegionRouter([ra, rb], mode="agent", carbon=sig, beta_queue=2.0)
    # local: no WAN term; remote: bytes x J/B rides the compute estimate
    assert r.score("ra", "ra", 1e6, 50.0, 0.0) == pytest.approx(50.0 * 1e-4)
    assert r.score("ra", "rb", 1e6, 50.0, 0.0) == pytest.approx(
        (50.0 + 0.2) * 3e-4)
    # congestion inflates multiplicatively through beta_queue
    assert r.score("ra", "ra", 1e6, 50.0, 0.0, congestion=0.5) == (
        pytest.approx(50.0 * 1e-4 * 2.0))
    # dirty-but-idle rb loses to clean ra on these numbers
    src, dst = r.route("alice", 1e6, 0.0,
                       energy={"ra": 50.0, "rb": 50.0})
    assert (src, dst) == ("ra", "ra")
    # ...until local congestion makes the WAN hop worth it
    src, dst = r.route("alice", 1e6, 0.0,
                       energy={"ra": 50.0, "rb": 50.0},
                       congestion={"ra": 2.0, "rb": 0.0})
    assert (src, dst) == ("ra", "rb")


def test_agent_tie_break_first_region_wins():
    ra, rb = _two_regions()
    # no carbon signal, equal energy, no congestion: all scores equal,
    # the strict-< scan keeps the first region in construction order
    r = RegionRouter([ra, rb], mode="agent")
    assert r.route("bob", 0.0, 0.0, energy={"ra": 1.0, "rb": 1.0}) == (
        "rb", "ra")
    rev = RegionRouter([rb, ra], mode="agent")
    assert rev.route("bob", 0.0, 0.0, energy={"ra": 1.0, "rb": 1.0}) == (
        "rb", "rb")


def test_endpoint_mask_collapses_when_fleet_covered():
    ra, rb = _two_regions()
    r = RegionRouter([ra, rb])
    eps = ["a1", "a2", "b1"]
    assert r.endpoint_mask("ra", eps) == (True, True, False)
    assert r.endpoint_mask("rb", eps) == (False, False, True)
    # one region covering the whole fleet: mask collapses to None — the
    # engines' "no mask" fast path, bitwise inertness by construction
    solo = RegionRouter([RegionSpec("all", ("a1", "a2", "b1"))])
    assert solo.endpoint_mask("all", eps) is None


# ---------------------------------------------------------------------------
# Single-region bitwise inertness on all three engines
# ---------------------------------------------------------------------------

def test_single_region_noop_delta_and_soa():
    trace = synthetic_edp_workload(n_tasks=32, seed=0)
    solo = [RegionSpec("global", tuple(e.name for e in trace.endpoints))]
    for engine in ("delta", "soa"):
        base = run_policy(trace, "mhra", engine=engine, seed=0)
        noop = run_policy(trace, "mhra", engine=engine, seed=0,
                          regions=solo)
        assert noop.assignments == base.assignments
        assert noop.energy_j == base.energy_j
        assert noop.makespan_s == base.makespan_s
        assert noop.wan_j == 0.0 and noop.egress_bytes == 0.0
        assert noop.regions == 1 and base.regions == 0


def test_single_region_noop_clone_engine():
    trace = synthetic_edp_workload(n_tasks=24, seed=0)
    sim = TestbedSim(trace.endpoints, profiles=trace.profiles,
                     signatures=trace.signatures, seed=0)
    store = warm_store(sim, trace)
    transfer = TransferModel(trace.endpoints)
    solo = RegionRouter(
        [RegionSpec("global", tuple(e.name for e in trace.endpoints))]
    )
    mask = solo.endpoint_mask("global", trace.endpoints)
    assert mask is None
    base = sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5,
                      engine="clone")
    again = sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5,
                       engine="clone", alive=mask)
    assert base.assignments == again.assignments
    assert base.objective == again.objective


def test_multi_region_delta_soa_parity():
    geo = geo_edp_workload(n_tasks=48, seed=0)
    specs = geo.meta["region_specs"]
    sig = geo.meta["carbon_signal"]
    for mode in ("caller", "agent"):
        runs = {}
        for engine in ("delta", "soa"):
            router = RegionRouter(specs, mode=mode, home=specs[0].name)
            runs[engine] = run_policy(geo, "mhra", engine=engine, seed=0,
                                      carbon=sig, regions=router)
        assert runs["delta"].assignments == runs["soa"].assignments, mode
        assert runs["delta"].regions == len(specs)


# ---------------------------------------------------------------------------
# Caller-locality WAN billing through the engine
# ---------------------------------------------------------------------------

def _micro_engine(mode="fixed", home="rb"):
    eps = synthetic_edp_workload(n_tasks=1).endpoints
    ra = RegionSpec("ra", ("desktop", "theta"), callers=("alice",),
                    wan_bw_bps={"rb": 1e6}, wan_latency_s={"rb": 0.5},
                    wan_j_per_byte={"rb": 2e-7})
    rb = RegionSpec("rb", ("ic", "faster"), callers=("bob",))
    router = RegionRouter([ra, rb], mode=mode, home=home)
    eng = OnlineEngine(eps, None, window_s=5.0, max_batch=512,
                       regions=router)
    return eng


def test_engine_validates_region_fleet_coverage():
    eps = synthetic_edp_workload(n_tasks=1).endpoints
    with pytest.raises(ValueError, match="desktop"):
        OnlineEngine(eps, None, regions=[
            RegionSpec("r", ("theta", "ic", "faster"))])
    with pytest.raises(ValueError, match="ghost"):
        OnlineEngine(eps, None, regions=[
            RegionSpec("r", ("desktop", "theta", "ic", "faster", "ghost"))])


def test_cross_region_wan_billing_hand_computed():
    eng = _micro_engine()     # fixed mode, home=rb: alice's work crosses
    inputs = (("desktop", 1, 1e6, False), ("desktop", 2, 5e6, True))
    eng.submit(TaskSpec(id="t0", fn="graph_bfs", user="alice",
                        inputs=inputs), when=0.0)
    w = eng.flush()
    assert w is not None and len(w.tasks) == 1
    # first crossing bills payload + private + the shared dataset
    bill0 = INVOKE_BYTES + 1e6 + 5e6
    assert eng.egress_bytes == pytest.approx(bill0)
    assert eng.wan_j == pytest.approx(bill0 * 2e-7)
    assert eng.wan_events == [
        (0.0, "ra", "rb", pytest.approx(bill0), pytest.approx(bill0 * 2e-7))
    ]
    # the WAN delay pushes the task past link latency + serialization
    (t0,) = w.tasks
    assert t0.not_before == pytest.approx(0.5 + bill0 / 1e6)
    assert w.schedule.timeline["t0"][0] >= t0.not_before - 1e-9
    assert w.schedule.assignments["t0"] in ("ic", "faster")

    # same shared dataset again: cached per destination region — only
    # the invocation payload + private bytes cross the WAN
    eng.submit(TaskSpec(id="t1", fn="graph_bfs", user="alice",
                        inputs=inputs), when=10.0)
    eng.flush()
    bill1 = INVOKE_BYTES + 1e6
    assert eng.egress_bytes == pytest.approx(bill0 + bill1)
    assert eng.wan_j == pytest.approx((bill0 + bill1) * 2e-7)

    # a caller homed in the destination region never touches the WAN
    eng.submit(TaskSpec(id="t2", fn="graph_bfs", user="bob",
                        inputs=inputs), when=20.0)
    w2 = eng.flush()
    assert eng.egress_bytes == pytest.approx(bill0 + bill1)
    assert len(eng.wan_events) == 2
    (t2,) = w2.tasks
    assert t2.not_before == 0.0
    assert eng.summary().wan_j == pytest.approx(eng.wan_j)
    assert eng.summary().regions == 2
    assert eng.region_tasks == {"rb": 3}


def test_caller_mode_keeps_work_local_and_wan_free():
    eng = _micro_engine(mode="caller")
    inputs = (("desktop", 1, 1e6, False),)
    eng.submit(TaskSpec(id="t0", fn="graph_bfs", user="alice",
                        inputs=inputs), when=0.0)
    w = eng.flush()
    assert eng.wan_j == 0.0 and eng.egress_bytes == 0.0
    assert w.schedule.assignments["t0"] in ("desktop", "theta")
    assert eng.region_tasks == {"ra": 1}
