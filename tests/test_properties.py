"""Property-based engine-parity suite (hypothesis-driven when the
optional [test] extra is installed; each property skips cleanly
otherwise via tests/_hypothesis_compat).

The three greedy engines are parity-locked by contract: clone <-> delta
mirror the same float sequence bitwise, delta <-> soa agree on every
assignment with objectives inside rtol=1e-12.  The example-based suites
(test_scheduler / test_soa_engine) pin that contract on the paper's
fleets; these properties fuzz it over random fleets, random profile
tables, random batches, and every optional scoring register — fairness
debts, carbon rates, warm-pool penalties, and the alive mask — toggled
independently, because historically it is the *interaction* of registers
that breaks mirrored float sequences, not any register alone.

Fleet-size caps are load-bearing: the clone engine computes fleet means
with ``np.mean`` over a Python list while delta/soa read SoA-table rows,
and numpy's pairwise summation only matches sequential summation
bitwise below 8 addends — so clone-comparing properties draw fleets of
2-7 endpoints.  delta <-> soa parity carries no such caveat and is
fuzzed on fleets up to 12.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.carbon import CarbonWeights
from repro.core.endpoint import scaled_testbed
from repro.core.fairness import FairnessWeights
from repro.core.faults import WarmWeights
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import TaskSpec, cluster_mhra, mhra
from repro.core.testbed import SEBS_FUNCTIONS
from repro.core.transfer import TransferModel

PARITY_RTOL = 1e-12
USERS = ("alice", "bob", "carol", "dan", "eve")


def _fleet(rng, n_eps, n_tasks, io_share):
    """Random fleet slice + random profile table + random batch.

    Endpoints come from ``scaled_testbed`` (3 replicas = 12 endpoints)
    so transfer paths and per-endpoint power models are realistic;
    profiles are freshly drawn per property example, so the greedy
    cost surface is different every run.
    """
    eps = scaled_testbed(3)[:n_eps]
    store = TaskProfileStore(eps)
    for fn in SEBS_FUNCTIONS:
        for ep in eps:
            rt = float(rng.uniform(0.5, 30.0))
            e = rt * float(rng.uniform(5.0, 200.0))
            for _ in range(2):
                store.record(fn, ep.name, rt, e)
    inputs = ((eps[0].name, 1, 150e6, True),)
    tasks = [
        TaskSpec(
            id=f"t{i}",
            fn=SEBS_FUNCTIONS[int(rng.integers(len(SEBS_FUNCTIONS)))],
            inputs=inputs if rng.random() < io_share else (),
            user=USERS[int(rng.integers(len(USERS)))],
        )
        for i in range(n_tasks)
    ]
    return tasks, eps, store, TransferModel(eps)


def _registers(rng, n_eps, with_fair, with_carbon, with_warm, with_alive):
    """Independent random scoring registers for one property example."""
    fairness = carbon = warm = alive = None
    if with_fair:
        n_debt = int(rng.integers(1, len(USERS) + 1))
        debtors = rng.choice(len(USERS), size=n_debt, replace=False)
        fairness = FairnessWeights(
            debt={USERS[i]: float(rng.uniform(0.1, 8.0)) for i in debtors},
            mu=float(rng.uniform(0.05, 2.0)),
        )
    if with_carbon:
        carbon = CarbonWeights(
            rates=tuple(float(rng.uniform(0.0, 1e-3)) for _ in range(n_eps)),
            gamma=float(rng.uniform(0.1, 2.0)),
        )
    if with_warm:
        warm = WarmWeights(
            cold_j=tuple(float(rng.uniform(0.0, 50.0)) for _ in range(n_eps)),
            cold_s=tuple(float(rng.uniform(0.0, 5.0)) for _ in range(n_eps)),
        )
    if with_alive:
        mask = rng.random(n_eps) < 0.7
        mask[int(rng.integers(n_eps))] = True   # never kill the whole fleet
        alive = tuple(bool(b) for b in mask)
    return fairness, carbon, warm, alive


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_eps=st.integers(2, 7),
    n_tasks=st.integers(1, 48),
    alpha=st.sampled_from([0.0, 0.2, 0.5, 0.8, 1.0]),
    with_fair=st.booleans(),
    with_carbon=st.booleans(),
    with_warm=st.booleans(),
    with_alive=st.booleans(),
)
def test_clone_delta_bitwise_parity(seed, n_eps, n_tasks, alpha, with_fair,
                                    with_carbon, with_warm, with_alive):
    """clone and delta walk the same float sequence: same assignments,
    bitwise-equal objective/energy/makespan, any register combination."""
    rng = np.random.default_rng(seed)
    tasks, eps, store, tm = _fleet(rng, n_eps, n_tasks, io_share=0.3)
    regs = _registers(rng, n_eps, with_fair, with_carbon, with_warm,
                      with_alive)
    fairness, carbon, warm, alive = regs
    a = mhra(tasks, eps, store, tm, alpha=alpha, engine="clone",
             carbon=carbon, alive=alive, warm=warm, fairness=fairness)
    b = mhra(tasks, eps, store, tm, alpha=alpha, engine="delta",
             carbon=carbon, alive=alive, warm=warm, fairness=fairness)
    assert a.assignments == b.assignments
    assert a.objective == b.objective          # bitwise, not approx
    assert a.energy_j == b.energy_j
    assert a.makespan_s == b.makespan_s
    assert a.heuristic == b.heuristic


@settings(max_examples=40, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_eps=st.integers(2, 12),
    n_tasks=st.integers(1, 64),
    alpha=st.sampled_from([0.0, 0.2, 0.5, 0.8, 1.0]),
    with_fair=st.booleans(),
    with_carbon=st.booleans(),
    with_warm=st.booleans(),
    with_alive=st.booleans(),
)
def test_delta_soa_assignment_parity(seed, n_eps, n_tasks, alpha, with_fair,
                                     with_carbon, with_warm, with_alive):
    """soa reproduces delta's assignments exactly (objectives to
    rtol=1e-12) on fleets past the clone engine's pairwise-summation
    cap, any register combination."""
    rng = np.random.default_rng(seed)
    tasks, eps, store, tm = _fleet(rng, n_eps, n_tasks, io_share=0.3)
    regs = _registers(rng, n_eps, with_fair, with_carbon, with_warm,
                      with_alive)
    fairness, carbon, warm, alive = regs
    a = mhra(tasks, eps, store, tm, alpha=alpha, engine="delta",
             carbon=carbon, alive=alive, warm=warm, fairness=fairness)
    b = mhra(tasks, eps, store, tm, alpha=alpha, engine="soa",
             carbon=carbon, alive=alive, warm=warm, fairness=fairness)
    assert a.assignments == b.assignments
    assert a.objective == pytest.approx(b.objective, rel=PARITY_RTOL)
    assert a.energy_j == pytest.approx(b.energy_j, rel=PARITY_RTOL)
    assert a.makespan_s == pytest.approx(b.makespan_s, rel=PARITY_RTOL)
    assert a.heuristic == b.heuristic


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_eps=st.integers(2, 7),
    n_tasks=st.integers(1, 48),
    with_fair=st.booleans(),
    with_alive=st.booleans(),
)
def test_cluster_mhra_three_engine_parity(seed, n_eps, n_tasks, with_fair,
                                          with_alive):
    """Algorithm 1's per-cluster greedy inherits the same parity lock:
    all three engines agree through the clustering layer too."""
    rng = np.random.default_rng(seed)
    tasks, eps, store, tm = _fleet(rng, n_eps, n_tasks, io_share=0.3)
    fairness, _, _, alive = _registers(rng, n_eps, with_fair, False, False,
                                       with_alive)
    runs = {
        engine: cluster_mhra(tasks, eps, store, tm, alpha=0.5,
                             max_cluster_size=16, engine=engine,
                             alive=alive, fairness=fairness)
        for engine in ("clone", "delta", "soa")
    }
    assert runs["clone"].assignments == runs["delta"].assignments
    assert runs["delta"].assignments == runs["soa"].assignments
    assert runs["clone"].objective == runs["delta"].objective
    assert runs["delta"].objective == pytest.approx(
        runs["soa"].objective, rel=PARITY_RTOL)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_eps=st.integers(2, 10),
    n_tasks=st.integers(1, 48),
)
def test_zero_debt_fairness_is_identity(seed, n_eps, n_tasks):
    """A fairness register whose debts never match a submitting user is
    bitwise-invisible: same assignments and objective as no register at
    all, on both mirrored engines."""
    rng = np.random.default_rng(seed)
    tasks, eps, store, tm = _fleet(rng, n_eps, n_tasks, io_share=0.3)
    ghost = FairnessWeights(debt={"nobody-submits-this": 3.0}, mu=1.5)
    for engine in ("delta", "soa"):
        bare = mhra(tasks, eps, store, tm, alpha=0.5, engine=engine)
        taxed = mhra(tasks, eps, store, tm, alpha=0.5, engine=engine,
                     fairness=ghost)
        assert bare.assignments == taxed.assignments
        assert bare.objective == taxed.objective
        assert bare.energy_j == taxed.energy_j


def _jax_ready() -> bool:
    try:
        import repro.kernels.placement.ops  # noqa: F401
        return True
    except Exception:
        return False


@pytest.mark.skipif(not _jax_ready(), reason="jax placement backend "
                    "unavailable (no jax in this environment)")
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_eps=st.integers(2, 12),
    n_tasks=st.integers(1, 64),
    alpha=st.sampled_from([0.0, 0.2, 0.5, 0.8, 1.0]),
    with_fair=st.booleans(),
    with_carbon=st.booleans(),
    with_warm=st.booleans(),
    with_alive=st.booleans(),
)
def test_soa_jax_bitwise_parity(seed, n_eps, n_tasks, alpha, with_fair,
                                with_carbon, with_warm, with_alive):
    """The fused jax scan replays soa's float sequence double for double:
    same assignments AND bitwise-equal objective/energy/makespan, any
    register combination.  (Compile cost is amortized by the pow-2 shape
    buckets — 15 examples share a handful of traced programs.)"""
    rng = np.random.default_rng(seed)
    tasks, eps, store, tm = _fleet(rng, n_eps, n_tasks, io_share=0.3)
    regs = _registers(rng, n_eps, with_fair, with_carbon, with_warm,
                      with_alive)
    fairness, carbon, warm, alive = regs
    a = mhra(tasks, eps, store, tm, alpha=alpha, engine="soa",
             carbon=carbon, alive=alive, warm=warm, fairness=fairness)
    b = mhra(tasks, eps, store, tm, alpha=alpha, engine="jax",
             carbon=carbon, alive=alive, warm=warm, fairness=fairness)
    assert a.assignments == b.assignments
    assert a.objective == b.objective          # bitwise, not approx
    assert a.energy_j == b.energy_j
    assert a.makespan_s == b.makespan_s
    assert a.heuristic == b.heuristic
