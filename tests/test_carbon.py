"""Carbon subsystem: trace interpolation/integration (hand-computed),
seeded constructors, JSON round-trips, carbon-weighted engine parity
(clone/delta/soa), the evaluation footprint, and the online engine's
bounded deferral queue (slack, DAG interplay, drain termination)."""
import collections
import json
import types

import numpy as np
import pytest

from repro.core.carbon import (
    CarbonIntensitySignal,
    CarbonTrace,
    CarbonWeights,
    J_PER_KWH,
)
from repro.core.counters import TaskRecord
from repro.core.endpoint import EndpointSpec, RELEASE_OVERHEAD_S
from repro.core.engine import OnlineEngine
from repro.core import scheduler as sched
from repro.core.evaluate import (
    carbon_footprint_g,
    evaluate_trace,
    run_policy,
    verify_dag_order,
    warm_store,
)
from repro.core.report import eval_text_report
from repro.core.scheduler import TaskSpec
from repro.core.testbed import TestbedSim
from repro.core.transfer import TransferModel
from repro.workloads import (
    moldesign_dag_workload,
    synthetic_edp_workload,
    table1_carbon_signal,
)


# ---------------------------------------------------------------------------
# CarbonTrace arithmetic
# ---------------------------------------------------------------------------

def _tent():
    # 100 -> 300 -> 100 over [0, 200]
    return CarbonTrace([0.0, 100.0, 200.0], [100.0, 300.0, 100.0])


def test_trace_interpolation_and_clamping():
    tr = _tent()
    assert tr.at(0.0) == 100.0
    assert tr.at(50.0) == 200.0
    assert tr.at(100.0) == 300.0
    assert tr.at(150.0) == 200.0
    # outside the breakpoints: clamp to edge values
    assert tr.at(-10.0) == 100.0
    assert tr.at(1e6) == 100.0


def test_trace_integral_hand_computed():
    tr = _tent()
    # full tent: two trapezoids of (100+300)/2 * 100
    assert tr.integral(0.0, 200.0) == pytest.approx(40_000.0)
    assert tr.mean(0.0, 200.0) == pytest.approx(200.0)
    # straddling the apex: (200+300)/2*50 + (300+200)/2*50
    assert tr.integral(50.0, 150.0) == pytest.approx(25_000.0)
    assert tr.mean(50.0, 150.0) == pytest.approx(250.0)
    # degenerate interval: point value
    assert tr.mean(70.0, 70.0) == pytest.approx(tr.at(70.0))
    assert tr.integral(70.0, 70.0) == 0.0


def test_periodic_trace_wraps_point_and_integral():
    tr = CarbonTrace([0.0, 100.0, 200.0], [100.0, 300.0, 100.0],
                     period_s=200.0)
    assert tr.at(250.0) == pytest.approx(tr.at(50.0))
    assert tr.at(-50.0) == pytest.approx(tr.at(150.0))
    # [150, 250] wraps: 150..200 gives (200+100)/2*50, 0..50 gives
    # (100+200)/2*50
    assert tr.integral(150.0, 250.0) == pytest.approx(15_000.0)
    # whole periods accumulate exactly
    assert tr.integral(0.0, 600.0) == pytest.approx(3 * 40_000.0)


def test_trace_rate_units():
    tr = CarbonTrace([0.0, 10.0], [360.0, 360.0])
    assert tr.rate(5.0) == pytest.approx(360.0 / J_PER_KWH)
    assert tr.mean_rate(0.0, 10.0) == pytest.approx(1e-4)
    assert tr.integral_rate(0.0, 10.0) == pytest.approx(1e-3)


def test_trace_validation():
    with pytest.raises(ValueError, match="sorted"):
        CarbonTrace([1.0, 0.0], [1.0, 1.0])
    with pytest.raises(ValueError, match="negative"):
        CarbonTrace([0.0, 1.0], [1.0, -1.0])
    with pytest.raises(ValueError, match="equal-length"):
        CarbonTrace([0.0, 1.0], [1.0])
    with pytest.raises(ValueError, match=r"\[0, 10"):
        CarbonTrace([0.0, 20.0], [1.0, 1.0], period_s=10.0)


# ---------------------------------------------------------------------------
# Signal: constructors, seeding, lookup, persistence
# ---------------------------------------------------------------------------

def test_diurnal_seeding_deterministic_and_distinct():
    a = CarbonIntensitySignal.diurnal(["x", "y"], period_s=600.0, seed=7)
    b = CarbonIntensitySignal.diurnal(["x", "y"], period_s=600.0, seed=7)
    c = CarbonIntensitySignal.diurnal(["x", "y"], period_s=600.0, seed=8)
    ts = np.linspace(0, 600, 13)
    for name in ("x", "y"):
        np.testing.assert_array_equal(a.traces[name].at(ts),
                                      b.traces[name].at(ts))
    assert not np.allclose(a.traces["x"].at(ts), c.traces["x"].at(ts))
    # regions draw different profiles from one seed
    assert not np.allclose(a.traces["x"].at(ts), a.traces["y"].at(ts))


def test_step_signal_levels_and_periodicity():
    sig = CarbonIntensitySignal.step(["r"], period_s=100.0, seed=0)
    tr = sig.traces["r"]
    assert tr.period_s == 100.0
    vals = np.asarray(tr.at(np.linspace(0, 100, 401)), dtype=float)
    assert vals.min() >= 80.0 - 1e-9
    assert vals.max() <= 700.0 + 1e-9
    assert vals.max() > vals.min() * 2  # a real plateau exists


def test_signal_region_mapping_and_default():
    tr = CarbonTrace([0.0, 1.0], [100.0, 100.0])
    lo = CarbonTrace([0.0, 1.0], [10.0, 10.0])
    sig = CarbonIntensitySignal({"de": tr, "default": lo},
                                regions={"ep1": "de"})
    assert sig.intensity("ep1", 0.0) == 100.0     # mapped region
    assert sig.intensity("de", 0.0) == 100.0      # name == region
    assert sig.intensity("elsewhere", 0.0) == 10.0  # default fallback
    with pytest.raises(ValueError, match="unknown region"):
        CarbonIntensitySignal({"de": tr}, regions={"ep": "nope"})


def test_signal_json_roundtrip(tmp_path):
    sig = table1_carbon_signal(seed=3, period_s=600.0)
    path = tmp_path / "carbon.json"
    payload = sig.to_json(path)
    assert json.loads(path.read_text()) == json.loads(json.dumps(payload))
    loaded = CarbonIntensitySignal.from_json(path)
    ts = np.linspace(0, 1200, 25)
    for name in sig.traces:
        np.testing.assert_allclose(loaded.traces[name].at(ts),
                                   sig.traces[name].at(ts))
        assert loaded.traces[name].period_s == sig.traces[name].period_s


def test_argmin_fleet_mean_finds_exact_trough():
    # two tents with troughs at different times; fleet mean minimized at a
    # breakpoint of one of them
    a = CarbonTrace([0.0, 50.0, 100.0], [300.0, 100.0, 300.0])
    b = CarbonTrace([0.0, 60.0, 100.0], [200.0, 120.0, 200.0])
    sig = CarbonIntensitySignal({"a": a, "b": b})
    t, v = sig.argmin_fleet_mean(["a", "b"], 0.0, 100.0)
    # candidates are breakpoints {0, 50, 60, 100}: mean at 50 is
    # (100 + 133.33)/2 ~ 116.7, at 60 it is (140+120)/2 = 130
    assert t == 50.0
    assert v == pytest.approx((100.0 + (120.0 + 2 / 3 * 80.0 * 0.25)) / 2.0,
                              rel=1e-3)


def test_grams_and_weights():
    tr = CarbonTrace([0.0, 10.0], [360.0, 360.0])
    sig = CarbonIntensitySignal({"default": tr})
    # 3.6e6 J at a constant 360 g/kWh = 360 g
    assert sig.grams("any", J_PER_KWH, 0.0, 10.0) == pytest.approx(360.0)
    w = CarbonWeights.from_signal(sig, ["e1", "e2"], 5.0, gamma=2.0)
    assert w.rates == (1e-4, 1e-4)
    assert w.gamma == 2.0
    with pytest.raises(ValueError, match="negative"):
        CarbonWeights((-1.0,), 1.0)
    with pytest.raises(ValueError, match="gamma"):
        CarbonWeights((1.0,), -1.0)


# ---------------------------------------------------------------------------
# Carbon-weighted engine parity + steering
# ---------------------------------------------------------------------------

def _warm_setup(n=96, seed=0):
    trace = synthetic_edp_workload(n_tasks=n, seed=seed)
    sim = TestbedSim(trace.endpoints, profiles=trace.profiles,
                     signatures=trace.signatures, seed=seed)
    store = warm_store(sim, trace)
    return trace, store, TransferModel(trace.endpoints)


def test_engine_parity_under_carbon_weights():
    trace, store, transfer = _warm_setup()
    sig = table1_carbon_signal(seed=0, period_s=600.0)
    cw = CarbonWeights.from_signal(sig, trace.endpoints, 150.0)
    d = sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5,
                   engine="delta", carbon=cw)
    c = sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5,
                   engine="clone", carbon=cw)
    s = sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5,
                   engine="soa", carbon=cw)
    # delta mirrors clone's float ops exactly, carbon included
    assert c.assignments == d.assignments
    assert c.objective == d.objective
    assert c.carbon_g == d.carbon_g
    # soa regroups for vectorization: identical assignments, rtol objective
    assert s.assignments == d.assignments
    assert s.objective == pytest.approx(d.objective, rel=1e-12)
    assert s.carbon_g == pytest.approx(d.carbon_g, rel=1e-12)
    assert d.carbon_g > 0.0
    assert d.cdp() == pytest.approx(d.carbon_g * d.makespan_s)


def test_cluster_mhra_parity_under_carbon_weights():
    trace, store, transfer = _warm_setup(n=64)
    cw = CarbonWeights((2e-4, 5e-5, 8e-5, 3e-4))
    d = sched.cluster_mhra(trace.tasks, trace.endpoints, store, transfer,
                           0.5, engine="delta", carbon=cw)
    s = sched.cluster_mhra(trace.tasks, trace.endpoints, store, transfer,
                           0.5, engine="soa", carbon=cw)
    assert d.assignments == s.assignments
    assert s.objective == pytest.approx(d.objective, rel=1e-12)


def test_carbon_none_is_bitwise_unchanged():
    trace, store, transfer = _warm_setup(n=48)
    base = sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5)
    again = sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5,
                       carbon=None)
    assert base.assignments == again.assignments
    assert base.objective == again.objective
    assert again.carbon_g is None


def test_carbon_weights_steer_placement_off_dirty_endpoint():
    # alpha=0.1 favors makespan, so plain MHRA spreads beyond desktop
    trace, store, transfer = _warm_setup(n=256)
    alpha = 0.1
    plain = sched.mhra(trace.tasks, trace.endpoints, store, transfer, alpha)
    counts = collections.Counter(plain.assignments.values())
    target = max((k for k in counts if k != "desktop"), key=lambda k: counts[k])
    assert counts[target] > 0
    # make that endpoint's grid filthy, everyone else's nearly free
    rates = tuple(1.0 if e.name == target else 1e-6
                  for e in trace.endpoints)
    # gamma=0 scores carbon without letting it steer: plain placement,
    # but the schedule reports its gCO2 under these rates
    plain_scored = sched.mhra(trace.tasks, trace.endpoints, store, transfer,
                              alpha, carbon=CarbonWeights(rates, gamma=0.0))
    assert plain_scored.assignments == plain.assignments
    dirty = sched.mhra(trace.tasks, trace.endpoints, store, transfer, alpha,
                       carbon=CarbonWeights(rates, gamma=4.0))
    dirty_counts = collections.Counter(dirty.assignments.values())
    assert dirty_counts[target] < counts[target]
    # the steered schedule's carbon under these rates beats plain's
    assert dirty.carbon_g < plain_scored.carbon_g


def test_mhra_rejects_mismatched_carbon_weights():
    trace, store, transfer = _warm_setup(n=8)
    with pytest.raises(ValueError, match="carbon weights cover"):
        sched.mhra(trace.tasks, trace.endpoints, store, transfer, 0.5,
                   carbon=CarbonWeights((1e-4,)))


# ---------------------------------------------------------------------------
# Evaluation-side footprint
# ---------------------------------------------------------------------------

def test_carbon_footprint_hand_computed():
    always_on = EndpointSpec("d", cores=2, idle_power_w=10.0, tdp_w=100.0,
                             queue_delay_s=0.0, has_batch_scheduler=False)
    batch = EndpointSpec("b", cores=2, idle_power_w=100.0, tdp_w=200.0,
                         queue_delay_s=5.0)
    tr = CarbonTrace([0.0, 100.0], [360.0, 360.0])   # flat 1e-4 g/J
    sig = CarbonIntensitySignal({"default": tr})
    recs = [
        TaskRecord("t1", "f", "d", 1, 0.0, 10.0, energy_j=50.0),
        TaskRecord("t2", "f", "b", 1, 2.0, 6.0, energy_j=20.0),
    ]
    windows = [types.SimpleNamespace(sim=types.SimpleNamespace(records=recs))]
    g = carbon_footprint_g(sig, [always_on, batch], windows)
    expected = (
        10.0 * 10.0 * 1e-4                                    # d idle, c_max=10
        + 100.0 * (6.0 - 2.0) * 1e-4                          # b idle span
        + 100.0 * (5.0 + RELEASE_OVERHEAD_S) * 1e-4           # b startup
        + 50.0 * 1e-4 + 20.0 * 1e-4                           # task dyn
    )
    assert g == pytest.approx(expected)
    # transfer billed at fleet-mean rate over the makespan
    g2 = carbon_footprint_g(sig, [always_on, batch], windows,
                            transfer_j=1000.0)
    assert g2 == pytest.approx(expected + 1000.0 * 1e-4)
    # no executed records -> zero footprint
    assert carbon_footprint_g(sig, [always_on], []) == 0.0


# ---------------------------------------------------------------------------
# Deferral queue (temporal shifting)
# ---------------------------------------------------------------------------

def _cliff_signal(high=500.0, low=100.0, drop_at=40.0):
    """Dirty grid until ``drop_at``, clean after — every window before the
    cliff wants to defer past it."""
    tr = CarbonTrace([0.0, drop_at, drop_at + 1.0, 10_000.0],
                     [high, high, low, low])
    return CarbonIntensitySignal({"default": tr})


def _engine(sig, eps=None, **kw):
    eps = eps or synthetic_edp_workload(n_tasks=1).endpoints
    kw.setdefault("policy", "carbon_mhra")
    kw.setdefault("window_s", 5.0)
    kw.setdefault("max_batch", 512)
    return OnlineEngine(eps, None, carbon=sig, **kw)


def test_deferral_requires_signal():
    eps = synthetic_edp_workload(n_tasks=1).endpoints
    with pytest.raises(ValueError, match="carbon signal"):
        OnlineEngine(eps, None, defer_horizon_s=60.0)


def test_deferral_shifts_tasks_and_sets_not_before():
    eng = _engine(_cliff_signal(), defer_horizon_s=100.0)
    for i in range(4):
        eng.submit(TaskSpec(id=f"t{i}", fn="graph_bfs"), when=0.0)
    assert eng.flush() is None          # whole window deferred
    assert len(eng.deferred) == 4
    assert not eng.pending
    windows = eng.drain()
    assert windows, "deferred tasks must eventually run"
    assert not eng.deferred and not eng.pending
    release = 41.0                      # the post-cliff breakpoint
    for w in windows:
        for t in w.tasks:
            assert t.not_before >= release
            start, _ = w.schedule.timeline[t.id]
            assert start >= release
    assert eng.summary().deferred == 4


def test_deferral_queue_is_bounded_and_defers_once():
    eng = _engine(_cliff_signal(), defer_horizon_s=100.0, defer_max=2)
    for i in range(5):
        eng.submit(TaskSpec(id=f"t{i}", fn="graph_bfs"), when=0.0)
    w = eng.flush()
    # 2 deferred (queue bound), 3 placed immediately
    assert len(eng.deferred) == 2
    assert w is not None and len(w.tasks) == 3
    eng.drain()
    # released tasks carry the defer-once mark and never re-enter the queue
    assert len(eng._deferred_ids) == 2
    assert not eng.deferred


def test_deferral_respects_deadline_slack():
    eng = _engine(_cliff_signal(), defer_horizon_s=100.0)
    tight = TaskSpec(id="tight", fn="graph_bfs", deadline=5.0)
    slack = TaskSpec(id="slack", fn="graph_bfs", deadline=1e6)
    eng.submit(tight, when=0.0)
    eng.submit(slack, when=0.0)
    w = eng.flush()
    # the no-slack task runs now; the slack task waits for the clean window
    assert w is not None and [t.id for t in w.tasks] == ["tight"]
    assert [t.id for _, _, t in eng.deferred] == ["slack"]
    eng.drain()


def test_deferral_no_defer_when_grid_only_gets_dirtier():
    # rising intensity: min over the horizon is "now", so nothing defers
    tr = CarbonTrace([0.0, 1000.0], [100.0, 900.0])
    eng = _engine(CarbonIntensitySignal({"default": tr}),
                  defer_horizon_s=100.0)
    eng.submit(TaskSpec(id="t0", fn="graph_bfs"), when=0.0)
    w = eng.flush()
    assert w is not None and len(w.tasks) == 1
    assert not eng.deferred


def test_deferral_dag_interplay_keeps_edges_honored():
    dag = moldesign_dag_workload(waves=2, docks_per_wave=6, sims_per_wave=6,
                                 infers_per_wave=8, seed=0)
    sig = table1_carbon_signal(seed=0, period_s=600.0)
    run, windows = run_policy(dag, "carbon_mhra", alpha=0.3, carbon=sig,
                              defer_horizon_s=120.0, return_windows=True)
    edges = verify_dag_order(windows)
    assert edges > 0
    assert run.carbon_g is not None and run.carbon_g > 0


def test_deferral_drain_terminates_with_sim_backend():
    trace = synthetic_edp_workload(n_tasks=24, seed=0)
    run = run_policy(trace, "carbon_mhra", carbon=_cliff_signal(),
                     defer_horizon_s=100.0)
    assert run.tasks == 24
    assert run.deferred > 0             # the cliff made deferral fire


# ---------------------------------------------------------------------------
# Evaluation integration + report rendering
# ---------------------------------------------------------------------------

def test_evaluate_trace_carbon_rows_and_payload():
    trace = synthetic_edp_workload(n_tasks=48, arrival="diurnal", seed=0,
                                   period_s=600.0, peak_rate_hz=0.16,
                                   trough_rate_hz=0.01)
    sig = table1_carbon_signal(seed=0, period_s=600.0)
    res = evaluate_trace(trace, policies=("mhra", "carbon_mhra"),
                         include_single_sites=False, carbon=sig,
                         defer_horizon_s=120.0)
    for r in res.rows:
        assert r.carbon_g is not None and r.carbon_g > 0
        assert r.cdp == pytest.approx(r.carbon_g * r.makespan_s)
    payload = res.to_payload()
    row = payload["rows"][0]
    assert row["carbon_g"] == res.rows[0].carbon_g
    assert row["cdp"] == res.rows[0].cdp
    # carbon-blind runs keep None columns
    res2 = evaluate_trace(trace, policies=("mhra",),
                          include_single_sites=False)
    assert res2.rows[0].carbon_g is None
    assert res2.rows[0].cdp is None


def test_eval_text_report_carbon_columns_conditional():
    trace = synthetic_edp_workload(n_tasks=24, seed=0)
    plain = evaluate_trace(trace, policies=("mhra",),
                           include_single_sites=False)
    assert "gCO2" not in eval_text_report(plain)
    sig = table1_carbon_signal(seed=0, period_s=600.0)
    carbon = evaluate_trace(trace, policies=("mhra",),
                            include_single_sites=False, carbon=sig)
    txt = eval_text_report(carbon)
    assert "gCO2" in txt and "CDP" in txt


# ---------------------------------------------------------------------------
# Forecast noise: signal-at-decision vs signal-at-billing
# ---------------------------------------------------------------------------


def test_forecast_noise_seeded_and_validated():
    sig = table1_carbon_signal(seed=0, period_s=600.0)
    assert sig.with_forecast_noise(0.0) is sig          # identity, no copy
    a = sig.with_forecast_noise(0.3, seed=7)
    b = sig.with_forecast_noise(0.3, seed=7)
    c = sig.with_forecast_noise(0.3, seed=8)
    names = sorted(sig.traces)
    for n in names:
        assert np.array_equal(a.traces[n].gco2_per_kwh,
                              b.traces[n].gco2_per_kwh)
        assert a.traces[n].period_s == sig.traces[n].period_s
        assert np.all(a.traces[n].gco2_per_kwh >= 1.0)  # validity floor
    assert any(
        not np.array_equal(a.traces[n].gco2_per_kwh, c.traces[n].gco2_per_kwh)
        for n in names
    )
    assert any(
        not np.array_equal(a.traces[n].gco2_per_kwh,
                           sig.traces[n].gco2_per_kwh)
        for n in names
    )
    with pytest.raises(ValueError, match="sigma"):
        sig.with_forecast_noise(-0.1)


def test_deferral_margin_widens_with_forecast_sigma():
    # shallow cliff: 400 -> 300 (25% drop) clears the default 5% margin
    # but not a sigma-widened one
    sig = _cliff_signal(high=400.0, low=300.0)
    eng = _engine(sig, defer_horizon_s=100.0)
    eng.submit(TaskSpec(id="t0", fn="graph_bfs"), when=0.0)
    assert eng.flush() is None and len(eng.deferred) == 1
    eng.drain()

    noisy = _cliff_signal(high=400.0, low=300.0)
    noisy.forecast_sigma = 0.5      # margin 0.05 + 1.0 * 0.5 = 0.55
    eng2 = _engine(noisy, defer_horizon_s=100.0)
    eng2.submit(TaskSpec(id="t0", fn="graph_bfs"), when=0.0)
    w = eng2.flush()
    assert w is not None and len(w.tasks) == 1 and not eng2.deferred

    # defer_sigma_k=0 switches the hedge off: sigma is ignored and the
    # original margin expression decides — bitwise-inert knob
    eng3 = _engine(noisy, defer_horizon_s=100.0, defer_sigma_k=0.0)
    eng3.submit(TaskSpec(id="t0", fn="graph_bfs"), when=0.0)
    assert eng3.flush() is None and len(eng3.deferred) == 1
    eng3.drain()

    with pytest.raises(ValueError, match="defer_sigma_k"):
        _engine(sig, defer_horizon_s=100.0, defer_sigma_k=-1.0)


def test_noisy_forecasts_defer_less_aggressively():
    """End to end: the same cliff that parks work under a trusted
    forecast parks none once the forecast's sigma widens the margin
    past the cliff's depth."""
    trace = synthetic_edp_workload(n_tasks=24, seed=0)
    sig = _cliff_signal()
    clean = run_policy(trace, "carbon_mhra", carbon=sig,
                       defer_horizon_s=100.0)
    assert clean.deferred > 0
    noisy = run_policy(trace, "carbon_mhra", carbon=sig,
                       carbon_forecast=sig.with_forecast_noise(1.0, seed=7),
                       defer_horizon_s=100.0)
    assert noisy.deferred < clean.deferred


def test_deferral_gains_shrink_with_forecast_noise():
    """The deferral queue trusts the *forecast*; billing integrates the
    true signal.  With a perfect forecast deferral cuts gCO2; with a wild
    one it shifts work into hours that only looked clean."""
    n = 56
    peak = min(n / 300.0, 1.5)
    car = synthetic_edp_workload(
        n_tasks=n, arrival="diurnal", seed=0, period_s=600.0,
        peak_rate_hz=peak, trough_rate_hz=peak / 16.0,
    )
    sig = table1_carbon_signal(seed=0, period_s=600.0)
    plain = run_policy(car, "mhra", seed=0, carbon=sig)
    ratios = {}
    for sigma in (0.0, 2.0):
        fc = sig.with_forecast_noise(sigma, seed=7)
        cm = run_policy(car, "carbon_mhra", seed=0, carbon=sig,
                        carbon_forecast=fc, defer_horizon_s=120.0)
        ratios[sigma] = cm.carbon_g / plain.carbon_g
    assert ratios[0.0] < 1.0                      # clean forecast helps
    assert ratios[0.0] < ratios[2.0]              # noise erodes the gain
