import os

# Tests see the single real CPU device — the 512-device override is ONLY for
# the dry-run launcher (see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
