"""Fault-tolerance suite: FaultTrace semantics, chaos-script generators,
warm-pool weights, engine parity under fleet mutation (clone/delta
bitwise + delta/soa assignment parity with alive masks and warm weights,
batch and mid-stream online), retry-to-completion goodput, permanent
failures + drain deadlock diagnostics, cold starts, stragglers +
speculative re-execution, and TaskDB truncated-tail recovery."""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.counters import TaskRecord
from repro.core.database import TaskDB
from repro.core.endpoint import EndpointSpec, table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.evaluate import run_policy, warm_store
from repro.core.faults import FaultTrace, WarmWeights
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import TaskSpec, mhra
from repro.core.testbed import BASE_PROFILES, SEBS_FUNCTIONS, TestbedSim
from repro.core.transfer import TransferModel
from repro.workloads import (
    add_failover,
    churn_fault_trace,
    synthetic_edp_workload,
    with_warm_pool,
)

PARITY_RTOL = 1e-12


# ---------------------------------------------------------------------------
# FaultTrace semantics
# ---------------------------------------------------------------------------

def _trace(**kw):
    kw.setdefault("down", {"theta": ((10.0, 20.0), (30.0, 40.0))})
    return FaultTrace(**kw)


def test_is_up_half_open_semantics():
    ft = _trace()
    assert ft.is_up("theta", 9.999)
    assert not ft.is_up("theta", 10.0)       # dead at d0
    assert not ft.is_up("theta", 19.999)
    assert ft.is_up("theta", 20.0)           # up again at exactly d1
    assert ft.is_up("theta", 25.0)
    assert not ft.is_up("theta", 35.0)
    # endpoints absent from the mapping are always up
    assert ft.is_up("desktop", 15.0)


def test_down_overlap_finds_first_overlap():
    ft = _trace()
    assert ft.down_overlap("theta", 0.0, 10.0) is None   # half-open miss
    assert ft.down_overlap("theta", 0.0, 10.01) == (10.0, 20.0)
    assert ft.down_overlap("theta", 15.0, 16.0) == (10.0, 20.0)
    assert ft.down_overlap("theta", 20.0, 30.0) is None
    assert ft.down_overlap("theta", 25.0, 100.0) == (30.0, 40.0)
    assert ft.down_overlap("desktop", 0.0, 1e9) is None


def test_next_up_chains_contiguous_intervals():
    ft = FaultTrace(down={"ic": ((5.0, 10.0), (10.0, 15.0), (20.0, 25.0))})
    assert ft.next_up("ic", 0.0) == 0.0      # already up
    assert ft.next_up("ic", 5.0) == 15.0     # rides through the contiguous pair
    assert ft.next_up("ic", 22.0) == 25.0
    assert ft.next_up("desktop", 7.0) == 7.0


def test_join_leave_vocabulary():
    # joining at 50 = down over [0, 50); leaving at 100 = down forever after
    ft = FaultTrace(down={"late": ((0.0, 50.0),),
                          "gone": ((100.0, float("inf")),)})
    assert not ft.is_up("late", 0.0) and ft.is_up("late", 50.0)
    assert ft.is_up("gone", 99.0) and not ft.is_up("gone", 1e12)
    assert ft.next_up("gone", 100.0) == float("inf")


def test_trace_validation():
    with pytest.raises(ValueError, match="d0 < d1"):
        FaultTrace(down={"x": ((5.0, 5.0),)})
    with pytest.raises(ValueError, match="overlap"):
        FaultTrace(down={"x": ((0.0, 10.0), (5.0, 15.0))})
    with pytest.raises(ValueError, match="straggler_p"):
        FaultTrace(straggler_p=1.5)
    with pytest.raises(ValueError, match="straggler_factor"):
        FaultTrace(straggler_p=0.5, straggler_factor=0.5)


def test_empty_trace_is_falsy_and_inert():
    ft = FaultTrace.empty()
    assert not ft
    assert ft.is_up("anything", 0.0)
    assert ft.straggle_factor("t0") == 1.0
    assert _trace()  # a trace with outages is truthy
    assert FaultTrace(straggler_p=0.1)  # stragglers alone are truthy


def test_straggle_factor_is_a_pure_hash():
    ft = FaultTrace(straggler_p=0.5, straggler_factor=3.0, seed=7)
    draws = {tid: ft.straggle_factor(tid) for tid in (f"t{i}" for i in range(64))}
    # deterministic across instances with the same seed
    ft2 = FaultTrace(straggler_p=0.5, straggler_factor=3.0, seed=7)
    assert all(ft2.straggle_factor(t) == f for t, f in draws.items())
    # roughly half straggle at p=0.5, and values are exactly {1, factor}
    assert set(draws.values()) == {1.0, 3.0}
    n = sum(1 for f in draws.values() if f == 3.0)
    assert 16 <= n <= 48
    # p=1 straggles everything; p=0 nothing
    assert FaultTrace(straggler_p=1.0).straggle_factor("t0") == 3.0
    assert FaultTrace(straggler_p=0.0).straggle_factor("t0") == 1.0


# ---------------------------------------------------------------------------
# chaos generators
# ---------------------------------------------------------------------------

def test_churn_trace_is_seeded_and_bounded():
    names = [e.name for e in table1_testbed()]
    a = churn_fault_trace(names, 1000.0, churn=0.2, mttr_s=50.0, seed=3,
                          protect=("desktop",))
    b = churn_fault_trace(names, 1000.0, churn=0.2, mttr_s=50.0, seed=3,
                          protect=("desktop",))
    assert a.down == b.down
    assert "desktop" not in a.down          # protected endpoints never fail
    assert set(a.down) <= set(names)
    for name, ivs in a.down.items():
        first = ivs[0][0]
        assert 0.05 * 1000.0 <= first < 0.45 * 1000.0   # mid-stream start
        for d0, d1 in ivs:
            assert 25.0 <= d1 - d0 <= 200.0             # [mttr/2, 4*mttr]
    # a different seed scripts different outages
    c = churn_fault_trace(names, 1000.0, churn=0.2, mttr_s=50.0, seed=4,
                          protect=("desktop",))
    assert c.down != a.down


def test_churn_trace_validation_and_zero_churn():
    with pytest.raises(ValueError, match="horizon"):
        churn_fault_trace(["a"], 0.0)
    with pytest.raises(ValueError, match="churn"):
        churn_fault_trace(["a"], 10.0, churn=1.0)
    with pytest.raises(ValueError, match="mttr"):
        churn_fault_trace(["a"], 10.0, mttr_s=0.0)
    assert not churn_fault_trace(["a", "b"], 100.0, churn=0.0).down


def test_with_warm_pool_and_add_failover():
    eps = with_warm_pool(table1_testbed(), cold_start_s=1.0,
                         cold_start_j=25.0, keepalive_s=30.0,
                         only=("desktop",))
    by = {e.name: e for e in eps}
    assert by["desktop"].cold_start_j == 25.0
    assert by["theta"].cold_start_j == 0.0          # untouched outside `only`
    eps2, prof = add_failover(eps, BASE_PROFILES, rt_factor=1.1)
    by2 = {e.name: e for e in eps2}
    twin, src = by2["login"], by2["desktop"]
    assert twin.always_on and twin.idle_power_w > src.idle_power_w
    for fn in prof:
        rt, w = prof[fn]["desktop"]
        assert prof[fn]["login"] == (rt * 1.1, w)   # strictly dominated
    assert "login" not in BASE_PROFILES[SEBS_FUNCTIONS[0]]  # input untouched
    with pytest.raises(ValueError, match="dominate"):
        add_failover(eps, BASE_PROFILES, rt_factor=0.9)
    with pytest.raises(ValueError, match="unknown"):
        add_failover(eps, BASE_PROFILES, clone_of="nope")


# ---------------------------------------------------------------------------
# warm-pool weights
# ---------------------------------------------------------------------------

def test_warm_weights_none_without_cold_costs():
    from repro.core.scheduler import SchedulerState
    eps = table1_testbed()
    st = SchedulerState(eps, TransferModel(eps))
    assert WarmWeights.from_state(eps, st, 0.0) is None


def test_warm_weights_full_penalty_on_fresh_state():
    from repro.core.scheduler import SchedulerState
    eps = with_warm_pool(table1_testbed(), cold_start_s=2.0, cold_start_j=50.0)
    st = SchedulerState(eps, TransferModel(eps))
    w = WarmWeights.from_state(eps, st, 0.0)
    # never-used endpoints: every slot cold, full penalty everywhere
    assert w.cold_j == tuple(50.0 for _ in eps)
    assert w.cold_s == tuple(2.0 for _ in eps)
    with pytest.raises(ValueError, match="mismatch"):
        WarmWeights(cold_j=(1.0,), cold_s=(1.0, 2.0))


# ---------------------------------------------------------------------------
# engine parity under fleet mutation
# ---------------------------------------------------------------------------

def _batch_setup(n_per=12):
    eps = table1_testbed()
    store = TaskProfileStore(eps)
    for fn in SEBS_FUNCTIONS:
        for ep in eps:
            rt, w = BASE_PROFILES[fn][ep.name]
            for _ in range(3):
                store.record(fn, ep.name, rt, rt * w)
    tasks = [
        TaskSpec(id=f"t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)])
        for i in range(n_per * len(SEBS_FUNCTIONS))
    ]
    return tasks, eps, store, TransferModel(eps)


@pytest.mark.parametrize("alive", [
    (True, False, True, True),
    (False, True, True, False),
])
def test_batch_parity_under_alive_mask(alive):
    tasks, eps, store, tm = _batch_setup()
    runs = {
        eng: mhra(tasks, eps, store, tm, alpha=0.5, engine=eng, alive=alive)
        for eng in ("clone", "delta", "soa")
    }
    # dead endpoints never receive work
    dead = {eps[i].name for i, a in enumerate(alive) if not a}
    for s in runs.values():
        assert not dead & set(s.assignments.values())
    # clone/delta bitwise; soa assignment-identical with tight objectives
    assert runs["clone"].assignments == runs["delta"].assignments
    assert runs["clone"].objective == runs["delta"].objective
    assert runs["clone"].energy_j == runs["delta"].energy_j
    assert runs["delta"].assignments == runs["soa"].assignments
    assert runs["soa"].objective == pytest.approx(
        runs["delta"].objective, rel=PARITY_RTOL)


def test_batch_parity_under_warm_weights():
    tasks, eps, store, tm = _batch_setup()
    warm = WarmWeights(cold_j=(0.0, 80.0, 40.0, 120.0),
                       cold_s=(0.0, 3.0, 1.5, 5.0))
    runs = {
        eng: mhra(tasks, eps, store, tm, alpha=0.5, engine=eng, warm=warm)
        for eng in ("clone", "delta", "soa")
    }
    assert runs["clone"].assignments == runs["delta"].assignments
    assert runs["clone"].objective == runs["delta"].objective
    assert runs["delta"].assignments == runs["soa"].assignments
    assert runs["soa"].objective == pytest.approx(
        runs["delta"].objective, rel=PARITY_RTOL)


def test_alive_mask_edge_cases():
    tasks, eps, store, tm = _batch_setup(n_per=2)
    with pytest.raises(ValueError, match="alive mask"):
        mhra(tasks, eps, store, tm, alive=(True,))
    with pytest.raises(ValueError, match="every endpoint"):
        mhra(tasks, eps, store, tm, alive=(False,) * len(eps))
    # an all-True mask is normalized away: bitwise-identical to no mask
    a = mhra(tasks, eps, store, tm, engine="delta")
    b = mhra(tasks, eps, store, tm, engine="delta", alive=(True,) * len(eps))
    assert a.assignments == b.assignments and a.objective == b.objective


def _chaos_run(engine, fault_aware=True, faults=None, n_tasks=40, **kw):
    syn = synthetic_edp_workload(n_tasks=n_tasks, seed=0)
    return run_policy(syn, "mhra", engine=engine, seed=0, faults=faults,
                      fault_aware=fault_aware, **kw)


def test_online_delta_soa_parity_under_midstream_churn():
    # desktop fails mid-stream and recovers: the alive mask + warm weights
    # must not break delta/soa assignment parity across the fail/recover
    ft = FaultTrace(down={"desktop": ((2.0, 30.0),)})
    a = _chaos_run("delta", faults=ft)
    b = _chaos_run("soa", faults=ft)
    assert a.assignments == b.assignments
    assert a.failures == b.failures and a.retries == b.retries


def test_faults_none_and_empty_trace_are_bitwise_noops():
    base = _chaos_run("delta")
    none = _chaos_run("delta", faults=None)
    empty = _chaos_run("delta", faults=FaultTrace.empty())
    for r in (none, empty):
        assert r.assignments == base.assignments
        assert r.energy_j == base.energy_j
        assert r.makespan_s == base.makespan_s
        assert r.goodput == 1.0 and r.failures == 0 and r.cold_starts == 0


def test_retry_to_completion_goodput():
    # an outage that catches in-flight work: every kill is retried to
    # completion, partial energy is billed as re-execution overhead
    ft = FaultTrace(down={"desktop": ((2.0, 40.0),)})
    r = _chaos_run("delta", faults=ft)
    assert r.failures > 0 and r.retries == r.failures
    assert r.goodput == 1.0
    assert r.reexec_j > 0.0          # partial energy of in-flight kills
    assert r.mean_recovery_s is not None and r.mean_recovery_s > 0.0


def test_fault_oblivious_keeps_retry_path():
    ft = FaultTrace(down={"desktop": ((2.0, 40.0),)})
    r = _chaos_run("delta", faults=ft, fault_aware=False)
    assert r.failures > 0 and r.goodput == 1.0


def test_prune_parity_under_churn():
    # DAGView retirement pruning must not change behavior when failed
    # tasks re-enter the stream after pruning already retired their window
    syn = synthetic_edp_workload(n_tasks=40, seed=0)
    ft = FaultTrace(down={"desktop": ((2.0, 30.0),)})
    outs = {}
    for prune in (True, False):
        sim = TestbedSim(syn.endpoints, profiles=syn.profiles,
                         signatures=syn.signatures, seed=0,
                         runtime_noise=0.0, faults=ft)
        eng = OnlineEngine(syn.endpoints, sim, policy="mhra", engine="delta",
                           store=warm_store(sim, syn), monitoring=False,
                           window_s=5.0, faults=ft, prune=prune)
        syn.replay_into(eng)
        s = eng.summary()
        outs[prune] = (s.completed, s.failures, s.retries,
                       eng.state.metrics())
    assert outs[True] == outs[False]


# ---------------------------------------------------------------------------
# permanent failures + drain diagnostics
# ---------------------------------------------------------------------------

def _engine(eps=None, faults=None, **kw):
    eps = eps or table1_testbed()
    sim = TestbedSim(eps, seed=0, runtime_noise=0.0, faults=faults)
    syn = synthetic_edp_workload(n_tasks=1, seed=0)  # just for warm_store fns
    syn = dataclasses.replace(syn, endpoints=eps)
    return OnlineEngine(eps, sim, policy="mhra", engine="delta",
                        store=warm_store(sim, syn), monitoring=False,
                        window_s=5.0, faults=faults, **kw)


def test_retry_cap_exhaustion_is_a_permanent_failure():
    # desktop is the only endpoint and it leaves the fleet forever ->
    # every endpoint down and none recovers: placement must refuse
    eps = [e for e in table1_testbed() if e.name == "desktop"]
    ft = FaultTrace(down={"desktop": ((1.0, float("inf")),)})
    eng = _engine(eps=eps, faults=ft)
    eng.submit(TaskSpec(id="a", fn="graph_bfs"), when=2.0)
    with pytest.raises(RuntimeError, match="none recovers"):
        eng.drain()


def test_permanent_failure_cascades_instead_of_deadlocking():
    # the whole fleet is down for the entire retry budget and the engine
    # is fault-blind: the parent exhausts its attempts, lands in
    # failed_permanently, and the child is cascaded instead of
    # deadlocking drain()
    eps = table1_testbed()
    ft = FaultTrace(down={e.name: ((0.5, 1e7),) for e in eps})
    eng = _engine(eps=eps, faults=ft, retry_cap=1, retry_backoff_s=0.5,
                  fault_aware=False)
    eng.submit(TaskSpec(id="p", fn="graph_bfs"), when=0.0)
    eng.submit(TaskSpec(id="c", fn="graph_bfs", deps=("p",)), when=0.0)
    eng.drain()                              # must terminate, not deadlock
    assert eng.failed_permanently == {"p", "c"}
    assert eng.summary().goodput == 0.0


def test_drain_diagnoses_never_submitted_parent():
    eng = _engine()
    eng.submit(TaskSpec(id="orphan", fn="graph_bfs", deps=("ghost",)),
               when=0.0)
    with pytest.raises(RuntimeError, match=r"ghost \(never submitted\)"):
        eng.drain()
    # the summary still reports the orphan as submitted-but-incomplete
    assert eng.summary().goodput < 1.0


def test_cascade_marks_children_failed():
    # force a permanent failure via an endpoint that is down for the whole
    # bounded retry budget but comes back later (so placement succeeds)
    eps = [e for e in table1_testbed() if e.name == "desktop"]
    ft = FaultTrace(down={"desktop": ((1.0, 1e6),)})
    sim = TestbedSim(eps, seed=0, runtime_noise=0.0, faults=ft)
    syn = dataclasses.replace(synthetic_edp_workload(n_tasks=1, seed=0),
                              endpoints=eps)
    eng = OnlineEngine(eps, sim, policy="mhra", engine="delta",
                       store=warm_store(sim, syn), monitoring=False,
                       window_s=5.0, faults=ft, fault_aware=False,
                       retry_cap=2, retry_backoff_s=1.0)
    eng.submit(TaskSpec(id="p", fn="graph_bfs"), when=0.0)
    eng.submit(TaskSpec(id="c", fn="graph_bfs", deps=("p",)), when=0.0)
    eng.drain()
    s = eng.summary()
    assert "p" in eng.failed_permanently and "c" in eng.failed_permanently
    assert s.permanent_failures == 2
    assert s.goodput == 0.0


# ---------------------------------------------------------------------------
# cold starts and stragglers in the sim
# ---------------------------------------------------------------------------

def _one_core_desktop(**warm_kw):
    """A single-slot always-on endpoint so warm/cold slot reuse is
    deterministic (multi-slot heaps hand fresh — cold — slots to early
    tasks)."""
    desk = next(e for e in table1_testbed() if e.name == "desktop")
    eps = [dataclasses.replace(desk, cores=1)]
    return with_warm_pool(eps, **warm_kw) if warm_kw else eps


def test_cold_start_latency_energy_and_keepalive():
    eps = _one_core_desktop(cold_start_s=2.0, cold_start_j=50.0,
                            keepalive_s=10.0)
    sim = TestbedSim(eps, seed=0, runtime_noise=0.0)
    warm_sim = TestbedSim(_one_core_desktop(), seed=0, runtime_noise=0.0)
    # first dispatch: cold (never-used slot) -> latency + energy billed
    res1 = sim.execute_window({"a": "desktop"},
                              [TaskSpec(id="a", fn="graph_bfs")], now=0.0)
    ref = warm_sim.execute_window({"a": "desktop"},
                                  [TaskSpec(id="a", fn="graph_bfs")], now=0.0)
    assert res1.cold_starts == 1 and res1.cold_j == 50.0
    rec1, ref1 = res1.records[0], ref.records[0]
    assert rec1.t_start == pytest.approx(ref1.t_start + 2.0)  # spin-up delay
    assert rec1.runtime == pytest.approx(ref1.runtime)        # run unchanged
    # immediate reuse of the same (only) slot: warm
    res2 = sim.execute_window({"b": "desktop"},
                              [TaskSpec(id="b", fn="graph_bfs")],
                              now=rec1.t_end)
    assert res2.cold_starts == 0 and res2.cold_j == 0.0
    # idle past keep-alive: cold again
    res3 = sim.execute_window({"c": "desktop"},
                              [TaskSpec(id="c", fn="graph_bfs")],
                              now=res2.records[0].t_end + 11.0)
    assert res3.cold_starts == 1


def test_default_fleet_has_no_cold_starts():
    sim = TestbedSim(table1_testbed(), seed=0, runtime_noise=0.0)
    res = sim.execute_window({"a": "desktop"},
                             [TaskSpec(id="a", fn="graph_bfs")], now=0.0)
    assert res.cold_starts == 0 and res.cold_j == 0.0


def test_straggler_inflation_is_deterministic():
    base = TestbedSim(table1_testbed(), seed=0, runtime_noise=0.0)
    slow = TestbedSim(table1_testbed(), seed=0, runtime_noise=0.0,
                      faults=FaultTrace(straggler_p=1.0, straggler_factor=4.0))
    t = TaskSpec(id="s", fn="graph_bfs")
    r0 = base.execute_window({"s": "desktop"}, [t], now=0.0).records[0]
    r1 = slow.execute_window({"s": "desktop"}, [t], now=0.0).records[0]
    assert r1.runtime == pytest.approx(4.0 * r0.runtime)


def test_speculative_reexecution_completes_with_overhead():
    # every task straggles 4x; spec_factor=2 arms a backup for each; the
    # backup straggles identically (hash includes the @spec id) or wins —
    # either way every task completes once and overhead is billed
    ft = FaultTrace(straggler_p=1.0, straggler_factor=4.0)
    r = _chaos_run("delta", faults=ft, spec_factor=2.0, n_tasks=20)
    assert r.spec_launched > 0
    assert r.goodput == 1.0
    assert r.reexec_j > 0.0                  # loser replicas billed
    assert r.spec_launched >= r.spec_wins


def test_spec_factor_validation():
    with pytest.raises(ValueError, match="spec_factor"):
        OnlineEngine(table1_testbed(), policy="mhra", spec_factor=1.0)


# ---------------------------------------------------------------------------
# TaskDB truncated-tail recovery
# ---------------------------------------------------------------------------

def _rec(i):
    return TaskRecord(task_id=f"t{i}", fn="f", endpoint="desktop",
                      worker_pid=100 + i, t_start=float(i),
                      t_end=float(i) + 1.0, energy_j=5.0)


def test_truncated_trailing_line_is_skipped_with_warning(tmp_path):
    p = tmp_path / "db.jsonl"
    db = TaskDB(str(p))
    db.extend([_rec(i) for i in range(3)])
    db.save()
    # simulate a crash mid-append: chop the last line in half
    text = p.read_text()
    p.write_text(text[: len(text) - 30])
    with pytest.warns(RuntimeWarning, match="truncated trailing"):
        db2 = TaskDB(str(p))
    assert len(db2.records) == 2
    assert db2.truncated == 1
    assert [r.task_id for r in db2.records] == ["t0", "t1"]
    # next save rewrites the file clean; a fresh load sees no damage
    db2.save()
    db3 = TaskDB(str(p))
    assert db3.truncated == 0 and len(db3.records) == 2


def test_midfile_corruption_still_raises(tmp_path):
    p = tmp_path / "db.jsonl"
    db = TaskDB(str(p))
    db.extend([_rec(i) for i in range(3)])
    db.save()
    lines = p.read_text().splitlines()
    lines[1] = lines[1][:10]                 # corrupt a non-trailing line
    p.write_text("\n".join(lines) + "\n")
    with pytest.raises(json.JSONDecodeError):
        TaskDB(str(p))


def test_intact_file_reports_zero_truncated(tmp_path):
    p = tmp_path / "db.jsonl"
    db = TaskDB(str(p))
    db.add(_rec(0))
    db.save()
    assert TaskDB(str(p)).truncated == 0
