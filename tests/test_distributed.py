"""Sharding rules, checkpointing, data pipeline, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.data.pipeline import SyntheticTokens
from repro.distributed.sharding import DEFAULT_RULES, ShardCtx, ctx_for
from repro.launch.mesh import make_host_mesh
from repro.models.registry import get_config


def test_spec_no_axis_reuse():
    """One mesh axis may appear at most once per PartitionSpec."""
    mesh = make_host_mesh()
    ctx = ShardCtx(mesh=mesh)
    spec = ctx.spec(("batch", "act_seq", "mlp"))
    used = [a for part in spec if part for a in (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))


def test_divisibility_guard_drops_uneven_axes():
    mesh = make_host_mesh()  # (1, 1) on one CPU — everything divides
    ctx = ShardCtx(mesh=mesh)
    sh = ctx.sharding_for_shape(("vocab", "embed"), (51865, 384))
    assert sh is not None  # simply must not raise


def test_seq_cp_overrides():
    mesh = make_host_mesh()
    cfg = get_config("qwen3-14b")  # 40 heads -> seq_cp on any 16-way axis
    assert cfg.resolve_attn_strategy(16) == "seq_cp"
    assert get_config("deepseek-67b").resolve_attn_strategy(16) == "head_tp"
    ctx = ctx_for(cfg, mesh)
    assert isinstance(ctx, ShardCtx)


def test_rules_cover_all_logical_axes_used_by_models():
    needed = {"batch", "embed", "mlp", "heads", "kv_heads", "vocab", "experts",
              "ssm_inner", "state", "layers", "seq", "act_seq", "kv_seq"}
    assert needed <= set(DEFAULT_RULES)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.manager import restore_checkpoint, save_checkpoint

    state = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2, 2), jnp.bfloat16), "step": jnp.int32(7)},
    }
    save_checkpoint(state, tmp_path, step=5)
    out = restore_checkpoint(state, tmp_path)
    for x, y in zip(jax.tree.leaves(state), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))


def test_checkpoint_latest_and_resume_semantics(tmp_path):
    from repro.checkpoint.manager import latest_step, save_checkpoint

    state = {"a": jnp.zeros(3)}
    save_checkpoint(state, tmp_path, step=10)
    save_checkpoint(state, tmp_path, step=20)
    assert latest_step(tmp_path) == 20


def test_async_checkpointer(tmp_path):
    from repro.checkpoint.manager import AsyncCheckpointer, latest_step

    ck = AsyncCheckpointer(tmp_path)
    ck.save({"a": jnp.ones(4)}, step=1)
    ck.save({"a": jnp.ones(4) * 2}, step=2)  # waits for the first
    ck.wait()
    assert latest_step(tmp_path) == 2


def test_train_resume_continues_not_restarts(tmp_path):
    """Resumed run must pick up optimizer step count (lr schedule state)."""
    from repro.launch.train import train

    d = tmp_path / "ck"
    train(arch="granite-3-2b", steps=6, batch=2, seq=32, checkpoint_dir=str(d),
          checkpoint_every=3, log_every=100)
    state2, _ = train(arch="granite-3-2b", steps=8, batch=2, seq=32,
                      checkpoint_dir=str(d), resume=True, log_every=100)
    assert int(state2["opt"]["step"]) == 8


def test_elastic_restore_across_meshes(tmp_path):
    """A checkpoint written under one sharding restores under another."""
    from repro.checkpoint.manager import restore_checkpoint, save_checkpoint

    mesh = make_host_mesh()
    x = jax.device_put(
        jnp.arange(16.0).reshape(4, 4),
        jax.sharding.NamedSharding(mesh, P("data", None)),
    )
    save_checkpoint({"w": x}, tmp_path, step=1)
    # restore replicated (different "mesh")
    out = restore_checkpoint({"w": jnp.zeros((4, 4))}, tmp_path)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(16.0).reshape(4, 4))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_determinism():
    a = SyntheticTokens(1000, 32, 8, seed=3).batch_at(7)
    b = SyntheticTokens(1000, 32, 8, seed=3).batch_at(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(1000, 32, 8, seed=4).batch_at(7)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_labels_are_shifted_tokens():
    d = SyntheticTokens(1000, 32, 4, seed=0).batch_at(0)
    # labels[t] is the next token of tokens[t] by construction
    assert d["tokens"].shape == d["labels"].shape == (4, 32)


def test_data_sharding_partitions_batch():
    full = SyntheticTokens(1000, 16, 8, seed=0, shard=0, num_shards=1).batch_at(3)
    s0 = SyntheticTokens(1000, 16, 8, seed=0, shard=0, num_shards=2).batch_at(3)
    s1 = SyntheticTokens(1000, 16, 8, seed=0, shard=1, num_shards=2).batch_at(3)
    assert s0["tokens"].shape[0] == s1["tokens"].shape[0] == 4
    assert not np.array_equal(s0["tokens"], s1["tokens"])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e3))
def test_quantize_bounded_error(seed, scale):
    from repro.fleet.compression import dequantize, quantize

    g = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize(g)
    err = jnp.max(jnp.abs(dequantize(q, s) - g))
    assert float(err) <= float(s) * 0.5 + 1e-9


def test_error_feedback_reduces_bias():
    """With error feedback, the mean compressed gradient converges to the
    true mean (unbiased over steps)."""
    from repro.fleet.compression import compress_tree, dequantize, init_error

    g_true = {"w": jnp.array([0.001, -0.002, 0.0005, 1.0])}
    err = init_error(g_true)
    acc = jnp.zeros(4)
    n = 50
    for _ in range(n):
        q, s, err = compress_tree(g_true, err)
        acc = acc + dequantize(q["w"], s["w"])
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g_true["w"]),
                               rtol=0.05, atol=1e-4)


def test_grad_accumulation_matches_full_batch():
    """microbatched train step == full-batch step (same grads, fp32 acc)."""
    from repro.distributed.sharding import NULL_CTX
    from repro.distributed.steps import build_train_step, init_train_state
    from repro.models.registry import get_api
    from repro.optim.adamw import AdamWConfig

    api = get_api("granite-3-2b", reduced=True)
    state = init_train_state(api, jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, api.cfg.vocab),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 0, api.cfg.vocab),
    }
    cfg = AdamWConfig(lr=1e-3)
    s1, m1 = build_train_step(api, cfg, NULL_CTX, microbatches=1)(state, batch)
    s2, m2 = build_train_step(api, cfg, NULL_CTX, microbatches=2)(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-3)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   atol=5e-4, rtol=5e-3)
