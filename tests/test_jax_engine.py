"""jax-engine parity suite: engine="jax" must reproduce engine="soa"
assignments AND objectives bitwise — the fused scan replays the SoA
float sequence double for double — batch and online, under every scoring
register, falling back to soa on windows the fused path can't express
(clustered units, multi-input tasks)."""
import numpy as np
import pytest

pops = pytest.importorskip(
    "repro.kernels.placement.ops",
    reason="jax placement backend unavailable (no jax in this environment)",
)

from repro.core import scheduler as sched  # noqa: E402
from repro.core.carbon import CarbonWeights  # noqa: E402
from repro.core.dag import LookaheadWeights  # noqa: E402
from repro.core.endpoint import scaled_testbed, table1_testbed  # noqa: E402
from repro.core.engine import OnlineEngine  # noqa: E402
from repro.core.fairness import FairnessWeights  # noqa: E402
from repro.core.faults import WarmWeights  # noqa: E402
from repro.core.policy import get_policy  # noqa: E402
from repro.core.predictor import TaskProfileStore  # noqa: E402
from repro.core.scheduler import (  # noqa: E402
    SoAState,
    TaskSpec,
    auto_engine,
    cluster_mhra,
    mhra,
)
from repro.core.testbed import BASE_PROFILES, SEBS_FUNCTIONS, TestbedSim  # noqa: E402
from repro.core.transfer import TransferModel  # noqa: E402


def _setup(n_per=12, with_inputs=True, replicas=1):
    eps = scaled_testbed(replicas)
    store = TaskProfileStore(eps)
    for fn in SEBS_FUNCTIONS:
        for ep in eps:
            base, _, k = ep.name.partition("_")
            rt, w = BASE_PROFILES[fn][base]
            rt = rt / (1.0 + 0.02 * int(k or 0))
            for _ in range(3):
                store.record(fn, ep.name, rt, rt * w)
    inputs = ((eps[0].name, 1, 200e6, True),) if with_inputs else ()
    tasks = [
        TaskSpec(id=f"t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)],
                 inputs=inputs)
        for i in range(n_per * len(SEBS_FUNCTIONS))
    ]
    return tasks, eps, store, TransferModel(eps)


def _assert_bitwise(a, b):
    assert a.assignments == b.assignments
    assert a.objective == b.objective          # bitwise, not approx
    assert a.energy_j == b.energy_j
    assert a.makespan_s == b.makespan_s
    assert a.transfer_j == b.transfer_j
    assert a.heuristic == b.heuristic
    assert a.timeline == b.timeline


# ---------------------------------------------------------------------------
# batch parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("alpha", [0.0, 0.2, 0.5, 1.0])
def test_jax_matches_soa_table5(alpha):
    tasks, eps, store, tm = _setup(n_per=12)
    a = mhra(tasks, eps, store, tm, alpha=alpha, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=alpha, engine="jax")
    _assert_bitwise(a, b)


def test_jax_matches_soa_scaled_fleet():
    tasks, eps, store, tm = _setup(n_per=8, replicas=3)   # 12 endpoints
    a = mhra(tasks, eps, store, tm, alpha=0.3, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=0.3, engine="jax")
    _assert_bitwise(a, b)


def test_jax_matches_soa_all_registers():
    """carbon + fairness + warm + alive + lookahead + not_before, armed
    together: the interaction of registers is what historically breaks
    mirrored float sequences."""
    tasks, eps, store, tm = _setup(n_per=6)
    n_ep = len(eps)
    rng = np.random.default_rng(0)
    tasks = [
        TaskSpec(id=t.id, fn=t.fn, inputs=t.inputs,
                 not_before=float(rng.uniform(0.0, 20.0)),
                 user=("alice", "bob")[i % 2])
        for i, t in enumerate(tasks)
    ]
    carbon = CarbonWeights(
        rates=tuple(float(rng.uniform(0.0, 1e-3)) for _ in range(n_ep)),
        gamma=0.7,
    )
    fairness = FairnessWeights(debt={"bob": 2.5}, mu=0.6)
    warm = WarmWeights(
        cold_j=tuple(float(rng.uniform(0.0, 40.0)) for _ in range(n_ep)),
        cold_s=tuple(float(rng.uniform(0.0, 4.0)) for _ in range(n_ep)),
    )
    alive = tuple(i != 1 for i in range(n_ep))
    lw = LookaheadWeights(
        tail_w={t.id: float(rng.uniform(0.0, 1.0)) for t in tasks[::2]},
        out_j={t.id: float(rng.uniform(0.0, 50.0)) for t in tasks[::3]},
        hops_mean=tuple(float(rng.uniform(0.5, 3.0)) for _ in range(n_ep)),
        lam=0.8,
    )
    kw = dict(carbon=carbon, fairness=fairness, warm=warm, alive=alive,
              lookahead=lw)
    a = mhra(tasks, eps, store, tm, alpha=0.4, engine="soa", **kw)
    b = mhra(tasks, eps, store, tm, alpha=0.4, engine="jax", **kw)
    _assert_bitwise(a, b)


# ---------------------------------------------------------------------------
# fallback paths (fused scan can't express the window -> soa, which is
# parity-locked already)
# ---------------------------------------------------------------------------


def test_jax_falls_back_on_multi_input_tasks():
    tasks, eps, store, tm = _setup(n_per=4)
    inputs = ((eps[0].name, 1, 100e6, True), (eps[1].name, 1, 50e6, False))
    tasks = [TaskSpec(id=t.id, fn=t.fn, inputs=inputs) for t in tasks]
    a = mhra(tasks, eps, store, tm, alpha=0.5, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=0.5, engine="jax")
    _assert_bitwise(a, b)


def test_jax_falls_back_on_clustered_units():
    tasks, eps, store, tm = _setup(n_per=6)
    a = cluster_mhra(tasks, eps, store, tm, alpha=0.5, max_cluster_size=16,
                     engine="soa")
    b = cluster_mhra(tasks, eps, store, tm, alpha=0.5, max_cluster_size=16,
                     engine="jax")
    assert a.assignments == b.assignments
    assert a.objective == b.objective


def test_jax_empty_window():
    _, eps, store, tm = _setup(n_per=1)
    a = mhra([], eps, store, tm, alpha=0.5, engine="soa")
    b = mhra([], eps, store, tm, alpha=0.5, engine="jax")
    assert a.assignments == b.assignments == {}


# ---------------------------------------------------------------------------
# online mode: jax scan over a live SoA state, windows of varying size
# ---------------------------------------------------------------------------


def _online(engine):
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=0)
    eng = OnlineEngine(eps, sim, policy="mhra", alpha=0.2, monitoring=False,
                       window_s=30.0, max_batch=10**6, engine=engine)
    out = []
    for w, n in enumerate((70, 3, 41)):   # deep, tiny, medium windows
        eng.submit_many([
            TaskSpec(id=f"w{w}t{i}", fn=SEBS_FUNCTIONS[i % 7])
            for i in range(n)
        ])
        res = eng.flush()
        out.append((res.assignments, res.schedule.energy_j,
                    res.schedule.makespan_s))
    return out, eng


def test_online_jax_state_matches_soa_state():
    a, eng_a = _online("soa")
    b, eng_b = _online("jax")
    assert isinstance(eng_a.state, SoAState)
    assert isinstance(eng_b.state, SoAState)
    for (asg_a, e_a, c_a), (asg_b, e_b, c_b) in zip(a, b):
        assert asg_a == asg_b
        assert e_a == e_b
        assert c_a == c_b
    assert eng_a.state.metrics() == eng_b.state.metrics()
    # input-staging cache must round-trip through the scan identically
    assert eng_a.state.cached == eng_b.state.cached


def test_online_engine_param_builds_jax_policy():
    eps = table1_testbed()
    eng = OnlineEngine(eps, policy="mhra", engine="jax")
    assert eng.policy.engine == "jax"
    assert isinstance(eng.state, SoAState)
    assert get_policy("mhra", engine="jax").engine == "jax"


# ---------------------------------------------------------------------------
# auto crossover
# ---------------------------------------------------------------------------


def test_auto_engine_jax_tier():
    me, mc = sched.AUTO_JAX_MIN_ENDPOINTS, sched.AUTO_JAX_MIN_CELLS
    assert auto_engine(me, mc // me) == "jax"
    assert auto_engine(me, mc // me - 1) == "soa"          # cells short
    assert auto_engine(me - 1, 10 ** 9) == "soa"           # fleet short
    # streaming mode (window size unknown) never escalates to jax
    assert auto_engine(10 ** 4) == "soa"


def test_auto_engine_jax_requires_importable_backend(monkeypatch):
    monkeypatch.setattr(sched, "_JAX_OK", False)
    me, mc = sched.AUTO_JAX_MIN_ENDPOINTS, sched.AUTO_JAX_MIN_CELLS
    assert auto_engine(me, mc // me) == "soa"


def test_auto_batch_escalates_to_jax_and_matches_soa(monkeypatch):
    """engine="auto" above the jax crossover routes to the fused scan and
    stays bitwise-identical to an explicit soa run.  The calibrated
    thresholds need thousands of tasks, so drop them to the fixture size
    — the routing logic is what's under test, the calibration is pinned
    by test_auto_engine_jax_tier."""
    tasks, eps, store, tm = _setup(n_per=3, with_inputs=False, replicas=2)
    monkeypatch.setattr(sched, "AUTO_JAX_MIN_ENDPOINTS", len(eps))
    monkeypatch.setattr(sched, "AUTO_JAX_MIN_CELLS", len(eps) * len(tasks))
    assert auto_engine(len(eps), len(tasks)) == "jax"
    a = mhra(tasks, eps, store, tm, alpha=0.5, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=0.5, engine="auto")
    _assert_bitwise(a, b)


# ---------------------------------------------------------------------------
# backend override plumbing (satellite: REPRO_PLACEMENT_BACKEND)
# ---------------------------------------------------------------------------


def test_placement_backend_env_override(monkeypatch):
    from repro.kernels import dispatch
    monkeypatch.setenv("REPRO_PLACEMENT_BACKEND", "ref")
    assert dispatch.placement_backend() == "ref"
    assert not dispatch.placement_use_pallas()
    monkeypatch.setenv("REPRO_PLACEMENT_BACKEND", "xla")
    assert dispatch.placement_backend() == "xla"
    monkeypatch.setenv("REPRO_PLACEMENT_BACKEND", "pallas")
    import jax
    if jax.default_backend() != "tpu":
        # off-TPU the kernel path coerces to interpret mode so CI can
        # still execute the Pallas body
        assert dispatch.placement_backend() == "pallas_interpret"
        assert dispatch.placement_interpret()
    monkeypatch.delenv("REPRO_PLACEMENT_BACKEND")
    assert dispatch.placement_backend() in ("pallas", "xla")


def test_jax_matches_soa_under_pallas_interpret(monkeypatch):
    """The tiled Pallas score+argmin kernel (interpret mode on CPU) is
    parity-locked too, not just the fused-XLA path."""
    monkeypatch.setenv("REPRO_PLACEMENT_BACKEND", "pallas")
    tasks, eps, store, tm = _setup(n_per=4)
    a = mhra(tasks, eps, store, tm, alpha=0.3, engine="soa")
    b = mhra(tasks, eps, store, tm, alpha=0.3, engine="jax")
    _assert_bitwise(a, b)
