"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
+ hypothesis property tests on the flash-attention invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.selective_scan.kernel import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref
from repro.kernels.ssd.kernel import ssd
from repro.kernels.ssd.ref import ssd_preweighted_ref, ssd_ref

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
FLASH_CASES = [
    # (b, sq, sk, h, kv, d, causal, dtype)
    (2, 128, 128, 4, 2, 64, True, jnp.float32),
    (1, 256, 256, 8, 8, 128, True, jnp.float32),
    (2, 128, 256, 2, 1, 64, False, jnp.float32),
    (1, 128, 128, 4, 4, 128, True, jnp.bfloat16),
    (1, 384, 384, 6, 6, 64, True, jnp.float32),   # whisper-like MHA
    (2, 128, 128, 4, 1, 80, True, jnp.float32),   # zamba-like head_dim 80
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    b, sq, sk, h, kv, d, causal, dtype = case
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@settings(max_examples=10, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64]),
    mult=st.integers(1, 3),
    h=st.sampled_from([2, 4]),
    causal=st.booleans(),
)
def test_flash_attention_block_invariance(bq, bk, mult, h, causal):
    """Output must not depend on block decomposition (property)."""
    sq = bq * mult
    sk = max(128, sq)  # causal sq > sk leaves fully-masked rows (undefined)
    ks = jax.random.split(jax.random.PRNGKey(bq * 7 + bk), 3)
    q = jax.random.normal(ks[0], (1, sq, h, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, sk, h, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, sk, h, 64), jnp.float32)
    a = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=min(bk, sk), interpret=True)
    b_ = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------
DECODE_CASES = [
    (2, 256, 4, 2, 64, 64, jnp.float32),
    (1, 512, 8, 1, 128, 128, jnp.float32),
    (3, 128, 4, 4, 64, 64, jnp.bfloat16),
    (1, 256, 8, 8, 80, 128, jnp.float32),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_ref(case):
    b, S, h, kv, d, bk, dtype = case
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, S, kv, d), dtype)
    vc = jax.random.normal(ks[2], (b, S, kv, d), dtype)
    lens = jnp.arange(1, b + 1) * (S // (b + 1)) + 3
    out = decode_attention(q, kc, vc, lens.astype(jnp.int32), block_k=bk, interpret=True)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_decode_attention_ignores_stale_cache_tail():
    """Garbage past cache_len must not affect the result (masking property)."""
    b, S, h, d = 1, 128, 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, S, h, d))
    vc = jax.random.normal(ks[2], (b, S, h, d))
    lens = jnp.array([40], jnp.int32)
    a = decode_attention(q, kc, vc, lens, block_k=32, interpret=True)
    kc2 = kc.at[:, 40:].set(1e4)
    vc2 = vc.at[:, 40:].set(-1e4)
    b_ = decode_attention(q, kc2, vc2, lens, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


# --------------------------------------------------------------------------
# selective scan (mamba1)
# --------------------------------------------------------------------------
SCAN_CASES = [
    (2, 64, 128, 16, 64, 32, jnp.float32),
    (1, 128, 64, 8, 64, 64, jnp.float32),
    (1, 64, 256, 16, 128, 32, jnp.float32),
]


@pytest.mark.parametrize("case", SCAN_CASES)
def test_selective_scan_matches_ref(case):
    b, L, d, n, bd, ch, dtype = case
    ks = jax.random.split(RNG, 6)
    x = jax.random.normal(ks[0], (b, L, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, d)) * 0.5 - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    D = jax.random.normal(ks[5], (d,))
    out = selective_scan(x, dt, A, B, C, D, block_d=bd, chunk=ch, interpret=True)
    ref = selective_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_selective_scan_chunk_invariance():
    """Chunk size must not change the result (state carry property)."""
    b, L, d, n = 1, 128, 64, 8
    ks = jax.random.split(RNG, 6)
    x = jax.random.normal(ks[0], (b, L, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, d)) * 0.3)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    D = jnp.zeros((d,))
    o32 = selective_scan(x, dt, A, B, C, D, block_d=64, chunk=32, interpret=True)
    o128 = selective_scan(x, dt, A, B, C, D, block_d=64, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o128), atol=1e-5)


# --------------------------------------------------------------------------
# ssd (mamba2)
# --------------------------------------------------------------------------
SSD_CASES = [
    (2, 64, 4, 64, 32, 32, jnp.float32),
    (1, 128, 2, 64, 64, 64, jnp.float32),
    (1, 128, 8, 128, 64, 32, jnp.float32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_matches_ref(case):
    b, L, nh, hd, n, ch, dtype = case
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (b, L, nh, hd), dtype)
    dt = jax.random.normal(ks[1], (b, L, nh)) * 0.5
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    dtf = jax.nn.softplus(dt)
    A = -jnp.exp(A_log)
    y, S = ssd(xh * dtf[..., None], dtf * A, B, C, chunk=ch, interpret=True)
    yr, Sr = ssd_ref(xh, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr), atol=5e-4, rtol=5e-3)


def test_ssd_xla_chunked_matches_sequential():
    """models/ssm.ssd_chunked (the XLA path) vs the sequential oracle."""
    from repro.models.ssm import ssd_chunked

    b, L, nh, hd, n = 2, 96, 4, 32, 16
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (b, L, nh, hd))
    dt = jax.random.normal(ks[1], (b, L, nh)) * 0.5
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    y, S = ssd_chunked(xh, dt, A_log, B, C, chunk=32)
    yr, Sr = ssd_ref(xh, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr), atol=5e-4, rtol=5e-3)


def test_preweighted_ref_consistent():
    b, L, nh, hd, n = 1, 32, 2, 16, 8
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (b, L, nh, hd))
    dt = jax.random.normal(ks[1], (b, L, nh)) * 0.5
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    dtf = jax.nn.softplus(dt)
    y1, S1 = ssd_preweighted_ref(xh * dtf[..., None], dtf * -jnp.exp(A_log), B, C)
    y2, S2 = ssd_ref(xh, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)


# ---------------------------------------------------------------------------
# placement score+argmin (the engine="jax" building blocks)
# ---------------------------------------------------------------------------


def _placement_case(seed, n):
    rng = np.random.default_rng(seed)
    kw = dict(
        e_base=rng.uniform(0.0, 5e4, n),
        nl=rng.uniform(0.0, 300.0, n),
        g_base=rng.uniform(0.0, 10.0, n),
        lk=rng.uniform(0.0, 3.0, n),
        fw=rng.uniform(0.0, 2.0, n),
        wt=rng.uniform(0.0, 1.0, n),
        alive=rng.random(n) < 0.8,
        c_cur=float(rng.uniform(0.0, 200.0)),
        idle_on_sum=float(rng.uniform(0.0, 500.0)),
        a1=float(rng.uniform(0.0, 1e-4)),
        b1=float(rng.uniform(0.0, 1e-2)),
        g1=float(rng.uniform(0.0, 1.0)),
        w_idle_on=float(rng.uniform(0.0, 1e-3)),
    )
    kw["alive"][int(rng.integers(n))] = True   # never a dead fleet
    return kw


@pytest.mark.parametrize("seed,n", [(0, 4), (1, 12), (2, 128), (3, 200)])
def test_placement_score_backends_bitwise(seed, n, monkeypatch):
    """ref (NumPy oracle) and xla produce bitwise-equal objectives and the
    identical first-min argmin.  The pallas-interpret leg is compiled as
    one program, where XLA:CPU may contract mul+add chains into FMAs —
    its scores are held to 1-ulp instead (the engine only consumes its
    *argmin*; every committed register is recomputed from the bitwise
    mirrors, so engine parity is unaffected)."""
    from repro.kernels.placement import ops as pops
    kw = _placement_case(seed, n)
    outs = {}
    for be in ("ref", "xla", "pallas"):
        monkeypatch.setenv("REPRO_PLACEMENT_BACKEND", be)
        obj, idx = pops.score_fleet(**kw)
        outs[be] = (np.asarray(obj), int(idx))
    np.testing.assert_array_equal(outs["ref"][0], outs["xla"][0])
    assert outs["ref"][1] == outs["xla"][1]
    np.testing.assert_allclose(outs["ref"][0], outs["pallas"][0], rtol=5e-15)
    assert outs["ref"][1] == outs["pallas"][1]
    # first-min tie-breaking matches np.argmin on the masked objective
    masked = np.where(kw["alive"], outs["ref"][0], np.inf)
    assert outs["ref"][1] == int(np.argmin(masked))


def test_placement_score_first_min_ties():
    """Equal scores across lanes (and across Pallas tiles) resolve to the
    lowest index, like np.argmin."""
    from repro.kernels.placement import ops as pops
    n = 256   # two 128-lane tiles
    kw = _placement_case(7, n)
    for k in ("e_base", "nl", "g_base", "lk", "fw", "wt"):
        kw[k] = np.zeros(n)
    kw["alive"] = np.ones(n, dtype=bool)
    import os
    prev = os.environ.get("REPRO_PLACEMENT_BACKEND")
    for be in ("ref", "xla", "pallas"):
        os.environ["REPRO_PLACEMENT_BACKEND"] = be
        try:
            _, idx = pops.score_fleet(**kw)
            assert int(idx) == 0, be
        finally:
            if prev is None:
                os.environ.pop("REPRO_PLACEMENT_BACKEND", None)
            else:
                os.environ["REPRO_PLACEMENT_BACKEND"] = prev


@pytest.mark.parametrize("n", [0, 1, 5, 7, 8, 9, 64, 127, 128, 129, 1000])
def test_placement_pairwise_sum_matches_numpy_bitwise(n):
    from repro.kernels.placement.ref import pairwise_sum
    rng = np.random.default_rng(n)
    x = rng.uniform(-1e6, 1e6, max(n, 1) + 3)
    assert pairwise_sum(x, n) == float(np.sum(x[:n]))
    assert pairwise_sum(x, n, base=2) == float(np.sum(x[2:2 + n]))


def test_placement_shape_buckets():
    from repro.kernels.placement import ops as pops
    assert [pops.bucket_pow2(v) for v in (1, 2, 3, 9, 64, 65)] == \
        [1, 2, 4, 16, 64, 128]
    assert pops.bucket_pow2(3, minimum=8) == 8
