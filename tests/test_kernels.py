"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode
+ hypothesis property tests on the flash-attention invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention.kernel import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.selective_scan.kernel import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref
from repro.kernels.ssd.kernel import ssd
from repro.kernels.ssd.ref import ssd_preweighted_ref, ssd_ref

RNG = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
FLASH_CASES = [
    # (b, sq, sk, h, kv, d, causal, dtype)
    (2, 128, 128, 4, 2, 64, True, jnp.float32),
    (1, 256, 256, 8, 8, 128, True, jnp.float32),
    (2, 128, 256, 2, 1, 64, False, jnp.float32),
    (1, 128, 128, 4, 4, 128, True, jnp.bfloat16),
    (1, 384, 384, 6, 6, 64, True, jnp.float32),   # whisper-like MHA
    (2, 128, 128, 4, 1, 80, True, jnp.float32),   # zamba-like head_dim 80
]


@pytest.mark.parametrize("case", FLASH_CASES)
def test_flash_attention_matches_ref(case):
    b, sq, sk, h, kv, d, causal, dtype = case
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), dtype)
    k = jax.random.normal(ks[1], (b, sk, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, sk, kv, d), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


@settings(max_examples=10, deadline=None)
@given(
    bq=st.sampled_from([32, 64, 128]),
    bk=st.sampled_from([32, 64]),
    mult=st.integers(1, 3),
    h=st.sampled_from([2, 4]),
    causal=st.booleans(),
)
def test_flash_attention_block_invariance(bq, bk, mult, h, causal):
    """Output must not depend on block decomposition (property)."""
    sq = bq * mult
    sk = max(128, sq)  # causal sq > sk leaves fully-masked rows (undefined)
    ks = jax.random.split(jax.random.PRNGKey(bq * 7 + bk), 3)
    q = jax.random.normal(ks[0], (1, sq, h, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, sk, h, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, sk, h, 64), jnp.float32)
    a = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=min(bk, sk), interpret=True)
    b_ = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=3e-5, rtol=3e-5)


# --------------------------------------------------------------------------
# decode attention
# --------------------------------------------------------------------------
DECODE_CASES = [
    (2, 256, 4, 2, 64, 64, jnp.float32),
    (1, 512, 8, 1, 128, 128, jnp.float32),
    (3, 128, 4, 4, 64, 64, jnp.bfloat16),
    (1, 256, 8, 8, 80, 128, jnp.float32),
]


@pytest.mark.parametrize("case", DECODE_CASES)
def test_decode_attention_matches_ref(case):
    b, S, h, kv, d, bk, dtype = case
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, S, kv, d), dtype)
    vc = jax.random.normal(ks[2], (b, S, kv, d), dtype)
    lens = jnp.arange(1, b + 1) * (S // (b + 1)) + 3
    out = decode_attention(q, kc, vc, lens.astype(jnp.int32), block_k=bk, interpret=True)
    ref = decode_attention_ref(q, kc, vc, lens)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **_tol(dtype)
    )


def test_decode_attention_ignores_stale_cache_tail():
    """Garbage past cache_len must not affect the result (masking property)."""
    b, S, h, d = 1, 128, 2, 64
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, 1, h, d))
    kc = jax.random.normal(ks[1], (b, S, h, d))
    vc = jax.random.normal(ks[2], (b, S, h, d))
    lens = jnp.array([40], jnp.int32)
    a = decode_attention(q, kc, vc, lens, block_k=32, interpret=True)
    kc2 = kc.at[:, 40:].set(1e4)
    vc2 = vc.at[:, 40:].set(-1e4)
    b_ = decode_attention(q, kc2, vc2, lens, block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=1e-6)


# --------------------------------------------------------------------------
# selective scan (mamba1)
# --------------------------------------------------------------------------
SCAN_CASES = [
    (2, 64, 128, 16, 64, 32, jnp.float32),
    (1, 128, 64, 8, 64, 64, jnp.float32),
    (1, 64, 256, 16, 128, 32, jnp.float32),
]


@pytest.mark.parametrize("case", SCAN_CASES)
def test_selective_scan_matches_ref(case):
    b, L, d, n, bd, ch, dtype = case
    ks = jax.random.split(RNG, 6)
    x = jax.random.normal(ks[0], (b, L, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, d)) * 0.5 - 1)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    D = jax.random.normal(ks[5], (d,))
    out = selective_scan(x, dt, A, B, C, D, block_d=bd, chunk=ch, interpret=True)
    ref = selective_scan_ref(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_selective_scan_chunk_invariance():
    """Chunk size must not change the result (state carry property)."""
    b, L, d, n = 1, 128, 64, 8
    ks = jax.random.split(RNG, 6)
    x = jax.random.normal(ks[0], (b, L, d))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, d)) * 0.3)
    A = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    D = jnp.zeros((d,))
    o32 = selective_scan(x, dt, A, B, C, D, block_d=64, chunk=32, interpret=True)
    o128 = selective_scan(x, dt, A, B, C, D, block_d=64, chunk=128, interpret=True)
    np.testing.assert_allclose(np.asarray(o32), np.asarray(o128), atol=1e-5)


# --------------------------------------------------------------------------
# ssd (mamba2)
# --------------------------------------------------------------------------
SSD_CASES = [
    (2, 64, 4, 64, 32, 32, jnp.float32),
    (1, 128, 2, 64, 64, 64, jnp.float32),
    (1, 128, 8, 128, 64, 32, jnp.float32),
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_matches_ref(case):
    b, L, nh, hd, n, ch, dtype = case
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (b, L, nh, hd), dtype)
    dt = jax.random.normal(ks[1], (b, L, nh)) * 0.5
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    dtf = jax.nn.softplus(dt)
    A = -jnp.exp(A_log)
    y, S = ssd(xh * dtf[..., None], dtf * A, B, C, chunk=ch, interpret=True)
    yr, Sr = ssd_ref(xh, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr), atol=5e-4, rtol=5e-3)


def test_ssd_xla_chunked_matches_sequential():
    """models/ssm.ssd_chunked (the XLA path) vs the sequential oracle."""
    from repro.models.ssm import ssd_chunked

    b, L, nh, hd, n = 2, 96, 4, 32, 16
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (b, L, nh, hd))
    dt = jax.random.normal(ks[1], (b, L, nh)) * 0.5
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    y, S = ssd_chunked(xh, dt, A_log, B, C, chunk=32)
    yr, Sr = ssd_ref(xh, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(S), np.asarray(Sr), atol=5e-4, rtol=5e-3)


def test_preweighted_ref_consistent():
    b, L, nh, hd, n = 1, 32, 2, 16, 8
    ks = jax.random.split(RNG, 5)
    xh = jax.random.normal(ks[0], (b, L, nh, hd))
    dt = jax.random.normal(ks[1], (b, L, nh)) * 0.5
    A_log = jax.random.normal(ks[2], (nh,)) * 0.3
    B = jax.random.normal(ks[3], (b, L, n))
    C = jax.random.normal(ks[4], (b, L, n))
    dtf = jax.nn.softplus(dt)
    y1, S1 = ssd_preweighted_ref(xh * dtf[..., None], dtf * -jnp.exp(A_log), B, C)
    y2, S2 = ssd_ref(xh, dt, A_log, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
