"""Power model fit, attribution correction factor, integration windows."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.counters import CounterSample, PowerSample, TaskRecord
from repro.core.power_model import EnergyAttributor, LinearPowerModel, _integrate


def test_fit_recovers_linear_model(rng):
    w_true = np.array([0.5, 0.3, 0.1, 0.05])
    b_true = 110.0
    m = LinearPowerModel()
    X = rng.uniform(0, 100, size=(500, 4))
    P = X @ w_true + b_true + rng.normal(0, 0.5, 500)
    m.observe_batch(X, P)
    np.testing.assert_allclose(m.weights, w_true, atol=0.05)
    assert abs(m.idle_b - b_true) < 2.0


def test_attribution_correction_factor_conserves_dynamic_power(rng):
    """Sum of attributed watts == measured dynamic watts (paper eq.)."""
    w = np.array([0.5, 0.3, 0.1, 0.05])
    m = LinearPowerModel()
    X = rng.uniform(0, 50, size=(200, 4))
    m.observe_batch(X, X @ w + 100.0)
    procs = {1: rng.uniform(0, 50, 4), 2: rng.uniform(0, 50, 4), 3: rng.uniform(0, 50, 4)}
    p_meas = 100.0 + sum(float(w @ x) for x in procs.values()) * 1.23  # unmodeled +23%
    attr = m.attribute(p_meas, procs)
    assert attr[1] > 0
    np.testing.assert_allclose(sum(attr.values()), p_meas - m.idle_b, rtol=1e-3)


def test_attribution_proportionality(rng):
    """A process with 2x the counters gets ~2x the watts."""
    w = np.array([1.0, 1.0, 1.0, 1.0])
    m = LinearPowerModel()
    X = rng.uniform(0, 50, size=(200, 4))
    m.observe_batch(X, X @ w + 10.0)
    base = np.array([10.0, 10, 10, 10])
    attr = m.attribute(10.0 + 3 * float(w @ base), {1: base, 2: 2 * base})
    assert attr[2] == pytest.approx(2 * attr[1], rel=0.05)


def test_integrate_linear_interpolation():
    series = [(0.0, 0.0, 0.0), (10.0, 10.0, 0.0)]
    # integral of ramp 0->10 over [0, 10] = 50; over [2.5, 7.5] = 25
    assert _integrate(series, 1, 0.0, 10.0) == pytest.approx(50.0)
    assert _integrate(series, 1, 2.5, 7.5) == pytest.approx(25.0)


@settings(max_examples=20, deadline=None)
@given(
    t0=st.floats(0.0, 5.0),
    dur=st.floats(0.1, 10.0),
    w=st.floats(0.1, 100.0),
)
def test_integrate_constant_power(t0, dur, w):
    series = [(float(t), w, w) for t in np.arange(0, 20, 1.0)]
    e = _integrate(series, 1, t0, t0 + dur)
    assert e == pytest.approx(w * dur, rel=1e-6)


def test_end_to_end_attribution_pipeline(rng):
    """Simulated node: model trained from the stream attributes task energy
    within 15% of ground truth."""
    w = np.array([0.4, 0.3, 0.2, 0.1])
    idle = 100.0
    model = LinearPowerModel()
    attr = EnergyAttributor(model)
    # two workers: pid 1 runs [5, 25) at 30 W, pid 2 runs [10, 30) at 50 W
    def rates(watts):
        base = rng.uniform(1, 2, 4)
        return base * watts / float(w @ base)

    r1, r2 = rates(30.0), rates(50.0)
    for t in np.arange(0.0, 35.0, 1.0):
        procs = {}
        p = idle
        if 5 <= t < 25:
            procs[1] = r1
            p += 30.0
        if 10 <= t < 30:
            procs[2] = r2
            p += 50.0
        attr.add_counters(CounterSample(t=float(t), procs=procs))
        attr.add_power(PowerSample(t=float(t), watts=p + rng.normal(0, 0.3)))
    attr.train_from_stream()
    rec1 = TaskRecord("a", "fn", "ep", 1, 5.0, 25.0)
    rec2 = TaskRecord("b", "fn", "ep", 2, 10.0, 30.0)
    e1 = attr.attribute_task(rec1).energy_j
    e2 = attr.attribute_task(rec2).energy_j
    assert e1 == pytest.approx(30.0 * 20, rel=0.15)
    assert e2 == pytest.approx(50.0 * 20, rel=0.15)


def test_monitor_stack_composes():
    from repro.core.monitor import CallbackMonitor, ConstantMonitor, StackedMonitor

    cpu = CallbackMonitor(lambda t: 50.0, noise_frac=0.0)
    gpu = CallbackMonitor(lambda t: 150.0, noise_frac=0.0)
    base = ConstantMonitor(25.0)
    node = StackedMonitor([cpu, gpu, base])
    assert node.read_watts(0.0) == pytest.approx(225.0)
