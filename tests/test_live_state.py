"""Live-state lifecycle: retire-at-completion pruning (DAGView), pruned
vs unpruned placement parity across engines, the O(live) memory bound,
timeline GC, rolling TaskDB/window compaction, adaptive engine
selection, and the tiny-DAG lookahead ``lam`` scaling regression."""
import numpy as np
import pytest

from repro.core.dag import DAGView, LookaheadWeights, structure_scale
from repro.core.database import TaskDB
from repro.core.counters import TaskRecord
from repro.core.endpoint import table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import (
    AUTO_SOA_MIN_CELLS,
    AUTO_SOA_MIN_ENDPOINTS,
    SchedulerState,
    SoAState,
    TaskSpec,
    auto_engine,
    mhra,
)
from repro.core.testbed import SEBS_FUNCTIONS, TestbedSim
from repro.core.transfer import TransferModel
from repro.workloads import moldesign_dag_workload


# ---------------------------------------------------------------------------
# DAGView retirement units
# ---------------------------------------------------------------------------


def _chain(n=3, prune=True):
    dag = DAGView(runtime=lambda fn: 1.0, prune=prune)
    for i in range(n):
        deps = (f"t{i - 1}",) if i else ()
        dag.add_task(TaskSpec(id=f"t{i}", fn="f", deps=deps, dep_bytes=7.0))
    return dag


def test_retire_at_completion_walks_down_the_chain():
    dag = _chain()
    assert len(dag) == 3 and dag.n_edges == 2
    dag.complete("t0", "ic", 1.0)
    # t0 leaves the rank graph at once, even though t1/t2 are still live
    assert "t0" not in dag and len(dag) == 2
    assert dag.retired == 1 and dag.n_edges == 1
    assert dag.producer("t0") == ("ic", 1.0)      # billing record survives
    dag.complete("t1", "theta", 2.0)
    dag.complete("t2", "theta", 3.0)
    assert len(dag) == 0 and dag.n_edges == 0 and dag.retired == 3
    assert dag.drain_retired() == ["t0", "t1", "t2"]
    assert dag.drain_retired() == []              # drained buffers clear


def test_prune_off_retires_nothing():
    dag = _chain(prune=False)
    for i, t in enumerate(("t0", "t1", "t2")):
        dag.complete(t, "ic", float(i))
    assert len(dag) == 3 and dag.retired == 0
    assert dag.n_edges == 2
    assert dag.drain_retired() == []


def test_edges_to_retired_parents_are_never_added():
    dag = _chain(n=2)
    dag.complete("t0", "ic", 1.0)
    dag.complete("t1", "ic", 2.0)
    # a straggler child naming a retired parent: no retained edge appears,
    # and its transfer inputs resolve from the producer record instead
    dag.add_task(TaskSpec(id="late", fn="f", deps=("t1",), dep_bytes=3.0))
    assert dag.n_edges == 0
    assert dag.up_rank("late") == 1.0             # no live structure above
    assert dag.producer("t1") == ("ic", 2.0)


def test_down_rank_counts_uncompleted_parents_only():
    for prune in (True, False):
        dag = _chain(prune=prune)
        assert dag.down_rank("t2") == 2.0
        dag.complete("t0", "ic", 1.0)
        # t0's output exists: t2's remaining upstream wait is t1 alone --
        # and the value is identical with pruning on or off
        assert dag.down_rank("t2") == 1.0, prune


def test_rank_scale_tracks_the_live_set():
    dag = _chain(n=3)
    assert dag.rank_scale == 3.0
    dag.complete("t0", "ic", 1.0)
    assert dag.rank_scale == 2.0                  # live chain is t1 -> t2
    dag.complete("t1", "ic", 2.0)
    assert dag.rank_scale == 1.0


# ---------------------------------------------------------------------------
# Pruned vs unpruned placement parity (the guarantee the engine relies on)
# ---------------------------------------------------------------------------


def _replay_moldesign(engine, prune):
    trace = moldesign_dag_workload(waves=3, docks_per_wave=6, sims_per_wave=6,
                                   infers_per_wave=8)
    sim = TestbedSim(trace.endpoints, profiles=trace.profiles,
                     signatures=trace.signatures, seed=0, runtime_noise=0.0)
    from repro.core.evaluate import warm_store

    eng = OnlineEngine(
        trace.endpoints, sim, policy="lookahead_mhra", alpha=0.3,
        window_s=5.0, max_batch=512, store=warm_store(sim, trace, n_obs=3),
        monitoring=False, engine=engine, prune=prune,
    )
    trace.replay_into(eng)
    assignments = {}
    for w in eng.windows:
        assignments.update(w.assignments)
    return eng, assignments


@pytest.mark.parametrize("engine", ["delta", "soa", "auto"])
def test_pruning_parity_on_moldesign_dag(engine):
    """Multi-epoch DAG campaign: assignments and final metrics must be
    bitwise identical with pruning on and off, for every live engine."""
    on, a_on = _replay_moldesign(engine, prune=True)
    off, a_off = _replay_moldesign(engine, prune=False)
    assert a_on == a_off
    assert on.state.metrics() == off.state.metrics()     # bitwise
    assert on.dag.retired > 0 and off.dag.retired == 0
    assert len(on.state.timeline) < len(off.state.timeline)


def _epoch_tasks(epoch, width, fns=SEBS_FUNCTIONS):
    prev = f"r{epoch - 1}" if epoch else None
    workers = [
        TaskSpec(id=f"e{epoch}_{j}", fn=fns[j % len(fns)],
                 deps=(prev,) if prev else (), dep_bytes=1e6)
        for j in range(width)
    ]
    reducer = TaskSpec(id=f"r{epoch}", fn=fns[epoch % len(fns)],
                       deps=tuple(w.id for w in workers), dep_bytes=1e6)
    return workers + [reducer]


def test_long_stream_stays_o_live():
    """Epoch-by-epoch synthetic stream: with pruning, the retained rank
    graph and the live-state timeline stay bounded by one epoch's frontier
    while everything-ever-submitted grows without bound."""
    width, epochs = 24, 12
    eps = table1_testbed()
    eng = OnlineEngine(eps, None, policy="lookahead_mhra", monitoring=False,
                       window_s=1e9, max_batch=10**9, engine="delta")
    max_live = max_timeline = 0
    for e in range(epochs):
        eng.submit_many(_epoch_tasks(e, width), when=float(e))
        eng.drain()
        max_live = max(max_live, len(eng.dag))
        max_timeline = max(max_timeline, len(eng.state.timeline))
    total = epochs * (width + 1)
    assert eng.summary().tasks == total
    assert eng.dag.retired == total
    # bound: one epoch's workers + reducer + the previous frontier
    assert max_live <= 2 * (width + 1)
    assert max_timeline <= 2 * (width + 1)
    assert len(eng.dag) == 0 and len(eng.state.timeline) == 0


# ---------------------------------------------------------------------------
# Timeline GC on the live states
# ---------------------------------------------------------------------------


def _placed_state(cls):
    eps = table1_testbed()
    tm = TransferModel(eps)
    store = TaskProfileStore(eps)
    sim = TestbedSim(eps, seed=0)
    for fn in SEBS_FUNCTIONS:
        for ep in eps:
            rt, w, _ = sim.task_truth(fn, ep.name)
            store.record(fn, ep.name, rt, rt * w)
    state = cls(eps, tm)
    tasks = [TaskSpec(id=f"t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)])
             for i in range(12)]
    mhra(tasks, eps, store, tm, state=state)
    return state


@pytest.mark.parametrize("cls", [SchedulerState, SoAState])
def test_drop_timeline_removes_only_named_tasks(cls):
    state = _placed_state(cls)
    before = state.metrics()
    assert len(state.timeline) == 12
    assert state.drop_timeline(["t0", "t5", "missing"]) == 2
    assert len(state.timeline) == 10
    assert "t0" not in state.timeline and "t5" not in state.timeline
    assert state.metrics() == before       # GC never touches the objective


# ---------------------------------------------------------------------------
# Rolling compaction: TaskDB record cap + engine window history cap
# ---------------------------------------------------------------------------


def _rec(i, ep="ic"):
    return TaskRecord(task_id=f"t{i}", fn="f", endpoint=ep, worker_pid=1,
                      t_start=float(i), t_end=float(i + 1), energy_j=2.0)


def test_taskdb_max_records_keeps_aggregates_exact():
    db = TaskDB(max_records=4)
    for i in range(10):
        db.add(_rec(i))
    assert len(db.records) == 4
    assert [r.task_id for r in db.records] == ["t6", "t7", "t8", "t9"]
    assert db.evicted == 6
    # aggregates are cumulative over everything ever added
    assert db.energy_by_endpoint() == {"ic": 20.0}
    with pytest.raises(ValueError, match="max_records"):
        TaskDB(max_records=0)


def test_taskdb_capped_save_appends_unsaved_tail(tmp_path):
    p = tmp_path / "db.jsonl"
    db = TaskDB(path=str(p), max_records=3)
    db.extend([_rec(i) for i in range(3)])
    db.save()
    db.add(_rec(3))                 # evicts t0 from memory, not from disk
    db.save()                       # appends only the unsaved tail (t3)
    loaded = TaskDB(path=str(p), max_records=3)
    assert loaded.evicted == 1      # 4 rows on disk, rolling window of 3
    assert [r.task_id for r in loaded.records] == ["t1", "t2", "t3"]
    assert loaded.energy_by_endpoint() == {"ic": 8.0}


def test_retain_windows_caps_history_but_not_summary():
    eps = table1_testbed()
    eng = OnlineEngine(eps, TestbedSim(eps, seed=0), policy="mhra",
                       monitoring=False, window_s=1e9, max_batch=10**9,
                       retain_windows=2)
    for w in range(5):
        eng.submit_many([TaskSpec(id=f"w{w}t{i}", fn="graph_bfs")
                         for i in range(6)])
        eng.flush()
    assert len(eng.windows) == 2
    assert [w.index for w in eng.windows] == [3, 4]
    s = eng.summary()
    assert s.windows == 5 and s.tasks == 30
    assert s.scheduling_s > 0 and s.attributed_j > 0


# ---------------------------------------------------------------------------
# Adaptive engine selection
# ---------------------------------------------------------------------------


def test_auto_engine_crossover():
    assert AUTO_SOA_MIN_ENDPOINTS == 16
    assert auto_engine(16) == "soa"               # large fleet: always soa
    assert auto_engine(32, 1) == "soa"
    assert auto_engine(4) == "delta"              # unknown window: heap
    assert auto_engine(4, AUTO_SOA_MIN_CELLS // 4) == "soa"
    assert auto_engine(4, AUTO_SOA_MIN_CELLS // 4 - 1) == "delta"
    assert auto_engine(8, 32) == "soa"            # 256 score cells
    assert auto_engine(8, 31) == "delta"


def test_online_engine_auto_resolves_at_first_flush():
    eps = table1_testbed()                        # 4 endpoints
    eng = OnlineEngine(eps, TestbedSim(eps, seed=0), monitoring=False,
                       window_s=1e9, max_batch=10**9)
    assert eng.engine == "auto" and eng.state is None
    eng.submit_many([TaskSpec(id=f"t{i}", fn="graph_bfs") for i in range(8)])
    eng.flush()                                   # 4 eps x 8 tasks < 256
    assert eng.engine == "delta"
    assert isinstance(eng.state, SchedulerState)

    eng2 = OnlineEngine(eps, TestbedSim(eps, seed=0), monitoring=False,
                        window_s=1e9, max_batch=10**9)
    eng2.submit_many([TaskSpec(id=f"t{i}", fn="graph_bfs")
                      for i in range(64)])
    eng2.flush()                                  # 4 eps x 64 tasks = 256
    assert eng2.engine == "soa"
    assert isinstance(eng2.state, SoAState)


# ---------------------------------------------------------------------------
# Tiny-DAG lookahead lam scaling (2-node regression)
# ---------------------------------------------------------------------------


def test_structure_scale_hand_checked():
    assert structure_scale(0, 0) == 0.0
    assert structure_scale(1, 64) == 0.0          # flat batch: no steering
    assert structure_scale(2, 1) == 0.25          # the 2-node chain
    assert structure_scale(2, 2) == 0.5
    assert structure_scale(3, 1) == 0.5
    assert structure_scale(3, 2) == 1.0           # any diamond or wider
    assert structure_scale(10, 64) == 1.0


def test_two_node_chain_scales_lam_down():
    """A live 2-node chain must steer at quarter strength: full-strength
    lam over-steered structureless graphs (the regression this pins)."""
    eps = table1_testbed()
    tm = TransferModel(eps)
    dag = DAGView(runtime=lambda fn: 2.0)
    parent = TaskSpec(id="p", fn="f")
    dag.add_task(parent)
    dag.add_task(TaskSpec(id="k", fn="f", deps=("p",), dep_bytes=1e6))
    lw = LookaheadWeights.from_dag(dag, [parent], eps, tm, lam=1.0)
    assert lw is not None
    assert lw.lam == pytest.approx(0.25)
    # and the weights themselves are untouched by the scaling
    assert lw.tail_w["p"] == pytest.approx(0.5)   # up_rest 2 / rank_scale 4


def test_diamond_keeps_full_strength_lam():
    eps = table1_testbed()
    tm = TransferModel(eps)
    dag = DAGView(runtime=lambda fn: 1.0)
    dag.add_task(TaskSpec(id="a", fn="f"))
    dag.add_task(TaskSpec(id="b", fn="f", deps=("a",), dep_bytes=1e6))
    dag.add_task(TaskSpec(id="c", fn="f", deps=("a",), dep_bytes=1e6))
    dag.add_task(TaskSpec(id="d", fn="f", deps=("b", "c"), dep_bytes=1e6))
    lw = LookaheadWeights.from_dag(
        dag, [TaskSpec(id="a", fn="f")], eps, tm, lam=0.8
    )
    assert lw is not None and lw.lam == pytest.approx(0.8)
