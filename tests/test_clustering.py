"""Direct unit tests for core/clustering.py (agglomerative_cluster):
determinism, singleton/empty edges, identical-profile bucketing, and cap
behavior — previously only covered indirectly through Cluster MHRA."""
import numpy as np

from repro.core.clustering import agglomerative_cluster


def _random_case(seed, n=40, k=4):
    rng = np.random.default_rng(seed)
    feats = rng.uniform(0, 10, size=(n, k))
    energies = rng.uniform(1, 20, size=n)
    return feats, energies


def test_deterministic_across_calls():
    feats, energies = _random_case(7)
    a = agglomerative_cluster(feats, energies, energy_cap=200.0)
    b = agglomerative_cluster(feats, energies, energy_cap=200.0)
    assert a == b
    # and input arrays are not mutated
    feats2, energies2 = _random_case(7)
    np.testing.assert_array_equal(feats, feats2)
    np.testing.assert_array_equal(energies, energies2)


def test_empty_input():
    assert agglomerative_cluster(np.empty((0, 4)), np.empty(0), 100.0) == []


def test_singleton_input():
    out = agglomerative_cluster(np.ones((1, 4)), np.array([5.0]), 100.0)
    assert out == [[0]]


def test_singleton_over_cap_still_scheduled():
    """A single task whose energy exceeds the cap must still appear."""
    out = agglomerative_cluster(np.ones((1, 4)), np.array([500.0]), 100.0)
    assert out == [[0]]


def test_all_identical_profiles_bucket_together():
    n = 24
    feats = np.full((n, 6), 3.14)
    energies = np.full(n, 1.0)
    out = agglomerative_cluster(feats, energies, energy_cap=1000.0)
    assert len(out) == 1
    assert sorted(out[0]) == list(range(n))


def test_identical_profiles_split_by_energy_cap():
    n = 30
    feats = np.ones((n, 4))
    energies = np.full(n, 10.0)
    out = agglomerative_cluster(feats, energies, energy_cap=35.0)
    flat = sorted(i for c in out for i in c)
    assert flat == list(range(n))
    for c in out:
        assert energies[c].sum() <= 35.0 + 1e-9


def test_max_cluster_size_cap():
    n = 50
    feats = np.ones((n, 4))
    energies = np.full(n, 0.1)
    out = agglomerative_cluster(feats, energies, energy_cap=1e9,
                                max_cluster_size=12)
    flat = sorted(i for c in out for i in c)
    assert flat == list(range(n))
    assert max(len(c) for c in out) <= 12


def test_zero_variance_feature_column_is_safe():
    """A constant feature column must not divide-by-zero the scaling."""
    rng = np.random.default_rng(0)
    feats = rng.uniform(0, 1, size=(10, 3))
    feats[:, 1] = 42.0
    out = agglomerative_cluster(feats, rng.uniform(1, 5, 10), 100.0)
    flat = sorted(i for c in out for i in c)
    assert flat == list(range(10))


def test_partition_property_random():
    for seed in range(5):
        feats, energies = _random_case(seed)
        out = agglomerative_cluster(feats, energies, energy_cap=100.0)
        flat = sorted(i for c in out for i in c)
        assert flat == list(range(len(feats)))
