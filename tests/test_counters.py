"""Counter-window merging: vectorized scalar/batch APIs, width inference,
and the batched window integrator backing the attribution pipeline."""
import numpy as np
import pytest

from repro.core.counters import (
    CounterSample,
    counter_width,
    merge_counter_windows,
    merge_counter_windows_batch,
)
from repro.core.power_model import _integrate, integrate_windows


def _reference_merge(samples, pid, t0, t1):
    """The pre-vectorization per-segment Python loop, kept as the oracle."""
    pts = [(s.t, s.procs.get(pid)) for s in samples
           if s.procs.get(pid) is not None]
    pts = [(t, v) for t, v in pts if t0 - 2.0 <= t <= t1 + 2.0]
    if not pts:
        return None
    if len(pts) == 1:
        return pts[0][1] * (t1 - t0)
    total = np.zeros_like(pts[0][1], dtype=float)
    for (ta, va), (tb, vb) in zip(pts, pts[1:]):
        lo, hi = max(ta, t0), min(tb, t1)
        if hi <= lo:
            continue
        fa = (lo - ta) / (tb - ta)
        fb = (hi - ta) / (tb - ta)
        total += 0.5 * ((va + (vb - va) * fa) + (va + (vb - va) * fb)) * (hi - lo)
    return total


def _stream(seed=0, n=40, k=4, pids=(1, 2)):
    rng = np.random.default_rng(seed)
    samples = []
    for i in range(n):
        procs = {}
        for pid in pids:
            if rng.uniform() < 0.8:
                procs[pid] = rng.uniform(0, 10, k)
        samples.append(CounterSample(t=float(i), procs=procs))
    return samples


def test_counter_width_inferred():
    assert counter_width(_stream(k=6)) == 6
    assert counter_width([CounterSample(t=0.0, procs={})]) == 0


def test_empty_window_infers_width_not_hardcoded_4():
    """Regression: the empty case used to return np.zeros(4) regardless of
    the stream's counter-vector width."""
    samples = _stream(k=6, pids=(1,))
    out = merge_counter_windows(samples, pid=99, t0=0.0, t1=5.0)
    assert out.shape == (6,)
    assert np.all(out == 0.0)


def test_constant_rates_integrate_to_rate_times_duration():
    k = 4
    v = np.array([2.0, 4.0, 6.0, 8.0])
    samples = [CounterSample(t=float(i), procs={1: v}) for i in range(20)]
    out = merge_counter_windows(samples, 1, 3.0, 9.0)
    np.testing.assert_allclose(out, v * 6.0, rtol=1e-12)


@pytest.mark.parametrize("seed", range(4))
def test_vectorized_merge_matches_reference(seed):
    samples = _stream(seed)
    rng = np.random.default_rng(100 + seed)
    for _ in range(10):
        t0 = float(rng.uniform(0, 30))
        t1 = t0 + float(rng.uniform(0.1, 10))
        for pid in (1, 2):
            ref = _reference_merge(samples, pid, t0, t1)
            got = merge_counter_windows(samples, pid, t0, t1)
            if ref is None:
                assert np.all(got == 0.0)
            else:
                np.testing.assert_allclose(got, ref, rtol=1e-9, atol=1e-9)


def test_batch_matches_scalar_on_dense_streams():
    """On gap-free streams (every pid present at every sample) the batch
    integrator and the scalar API agree to round-off."""
    k = 3
    rng = np.random.default_rng(5)
    samples = [
        CounterSample(t=float(i), procs={1: rng.uniform(0, 5, k),
                                         2: rng.uniform(0, 5, k)})
        for i in range(30)
    ]
    queries = [(1, 2.0, 7.5), (2, 0.5, 29.0), (1, 10.0, 11.0), (3, 0.0, 5.0)]
    got = merge_counter_windows_batch(samples, queries)
    assert got.shape == (4, k)
    for row, (pid, t0, t1) in zip(got, queries):
        np.testing.assert_allclose(
            row, merge_counter_windows(samples, pid, t0, t1),
            rtol=1e-9, atol=1e-9)
    assert np.all(got[3] == 0.0)        # unknown pid


def test_batch_empty_inputs():
    assert merge_counter_windows_batch([], []).shape == (0, 0)
    samples = _stream()
    assert merge_counter_windows_batch(samples, []).shape == (0, 4)


# ---------------------------------------------------------------------------
# integrate_windows (batched _integrate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(3))
def test_integrate_windows_matches_integrate(seed):
    rng = np.random.default_rng(seed)
    ts = np.sort(rng.uniform(0, 30, 25))
    vs = rng.uniform(0, 100, 25)
    series = [(t, v, v) for t, v in zip(ts, vs)]
    t0s = rng.uniform(-5, 28, 12)
    t1s = t0s + rng.uniform(-1, 10, 12)       # includes empty windows
    got = integrate_windows(ts, vs, t0s, t1s)
    for g, a, b in zip(got, t0s, t1s):
        assert g == pytest.approx(_integrate(series, 1, a, b), rel=1e-9,
                                  abs=1e-9)


def test_integrate_windows_matrix_columns():
    ts = np.arange(10.0)
    vals = np.stack([np.full(10, 2.0), np.arange(10.0)], axis=1)
    out = integrate_windows(ts, vals, np.array([0.0]), np.array([9.0]))
    assert out.shape == (1, 2)
    assert out[0, 0] == pytest.approx(18.0)
    assert out[0, 1] == pytest.approx(40.5)


def test_integrate_windows_extrapolates_edges_like_interp():
    ts = np.array([5.0, 6.0])
    vs = np.array([10.0, 20.0])
    series = [(5.0, 10.0, 0.0), (6.0, 20.0, 0.0)]
    # window straddles both ends of the span
    got = integrate_windows(ts, vs, np.array([0.0]), np.array([10.0]))[0]
    assert got == pytest.approx(_integrate(series, 1, 0.0, 10.0))
    # fully outside (left and right)
    assert integrate_windows(ts, vs, np.array([0.0]), np.array([2.0]))[0] \
        == pytest.approx(10.0 * 2.0)
    assert integrate_windows(ts, vs, np.array([8.0]), np.array([9.0]))[0] \
        == pytest.approx(20.0 * 1.0)


def test_integrate_windows_degenerate():
    assert integrate_windows(np.array([]), np.array([]),
                             np.array([0.0]), np.array([1.0]))[0] == 0.0
    out = integrate_windows(np.array([3.0]), np.array([7.0]),
                            np.array([1.0, 5.0]), np.array([3.0, 4.0]))
    assert out[0] == pytest.approx(14.0)     # single sample: rate * duration
    assert out[1] == 0.0                     # t1 <= t0: empty window
