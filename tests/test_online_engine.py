"""Online engine tests: arrival windows, live state carried across
windows, and the mid-workload learning loop (profiles from window k
steering placements in window k+1)."""
import numpy as np
import pytest

from repro.core.endpoint import table1_testbed
from repro.core.engine import OnlineEngine
from repro.core.scheduler import TaskSpec
from repro.core.testbed import SEBS_FUNCTIONS, TestbedSim


def _engine(policy="mhra", alpha=0.2, monitoring=True, seed=0, **kw):
    eps = table1_testbed()
    sim = TestbedSim(eps, seed=seed)
    kw = {"window_s": 30.0, "max_batch": 10**6, **kw}
    return OnlineEngine(
        eps, sim, policy=policy, alpha=alpha, monitoring=monitoring, **kw
    ), eps


def _window_tasks(w, n=140):
    return [
        TaskSpec(id=f"w{w}t{i}", fn=SEBS_FUNCTIONS[i % len(SEBS_FUNCTIONS)])
        for i in range(n)
    ]


def test_online_learning_shifts_placements_across_windows():
    """Profiles learned in window k must affect placements in window k+1:
    cold-start exploration spills tasks onto multiple endpoints, window 0's
    records make those profiles confident, and the window-1 mix shifts
    toward the measured-better endpoints.  monitoring=False keeps the run
    bitwise deterministic (the monitor jitter is seeded from PYTHONHASHSEED
    via hash(endpoint name), which varies across processes)."""
    eng, eps = _engine(monitoring=False)
    results = [
        (eng.submit_many(_window_tasks(w)), eng.flush())[1] for w in range(3)
    ]
    # every window placed all its tasks
    for w, res in enumerate(results):
        assert set(res.assignments) == {t.id for t in res.tasks}
        assert set(res.assignments.values()) <= {e.name for e in eps}

    # window 0 ran on >1 endpoint, so window 1 predictions had fresh
    # confident profiles that window 0's did not
    used0 = set(results[0].assignments.values())
    assert len(used0) > 1
    for ep in used0:
        for fn in SEBS_FUNCTIONS:
            if any(t.fn == fn for t in results[0].tasks):
                assert eng.store.n_obs(fn, ep) > 0

    # and the placement mix actually changed between windows
    assert results[0].placements != results[1].placements


def test_profiles_accumulate_between_windows():
    eng, _ = _engine(monitoring=False)
    counts = []
    for w in range(3):
        eng.submit_many(_window_tasks(w, n=56))
        eng.flush()
        counts.append(sum(n for n, _, _ in eng.store.stats().values()))
    assert counts[0] > 0
    assert counts[0] < counts[1] < counts[2]


def test_max_batch_triggers_flush():
    eng, _ = _engine(max_batch=8)
    fired = None
    for i in range(8):
        fired = eng.submit(TaskSpec(id=f"t{i}", fn="graph_bfs")) or fired
    assert fired is not None
    assert len(fired.tasks) == 8
    assert not eng.pending


def test_tick_fires_window_after_window_s():
    eng, _ = _engine()
    eng.submit(TaskSpec(id="t0", fn="graph_bfs"), when=0.0)
    assert eng.tick(10.0) is None          # window not yet elapsed
    res = eng.tick(31.0)
    assert res is not None and len(res.tasks) == 1


def test_flush_empty_is_noop():
    eng, _ = _engine()
    assert eng.flush() is None
    assert eng.drain() == []


def test_windows_share_live_state():
    """Later windows must see earlier windows' load: the cumulative
    makespan/energy are monotone and the state timeline covers all tasks."""
    eng, _ = _engine(monitoring=False)
    metrics = []
    for w in range(3):
        eng.submit_many(_window_tasks(w, n=56))
        res = eng.flush()
        e, c, _ = eng.state.metrics()
        metrics.append((e, c))
        assert res.schedule.energy_j == e      # schedule reports cumulative
    assert metrics[0][0] < metrics[1][0] < metrics[2][0]
    assert metrics[0][1] <= metrics[1][1] <= metrics[2][1]
    # live-state pruning: flat tasks retire as they complete, so the
    # timeline holds only live work (here: none) while the cumulative
    # metrics above still cover everything ever placed
    assert len(eng.state.timeline) == 0
    assert eng.dag.retired == 3 * 56


def test_prune_off_keeps_full_timeline():
    eng, _ = _engine(monitoring=False, prune=False)
    for w in range(3):
        eng.submit_many(_window_tasks(w, n=56))
        eng.flush()
    assert len(eng.state.timeline) == 3 * 56
    assert eng.dag.retired == 0


def test_stream_tasks_start_after_submission():
    """execute_window: a task submitted at window w cannot start before
    the window opened, and worker slots persist across windows."""
    eng, _ = _engine(monitoring=False)
    t_open = []
    for w in range(3):
        eng.submit_many(_window_tasks(w, n=24))
        res = eng.flush()
        t_open.append(res.submitted_at)
        for rec in res.sim.records:
            assert rec.t_start >= res.submitted_at
    assert t_open == sorted(t_open)
    assert t_open[1] > t_open[0]


def test_round_robin_policy_rotates_across_windows():
    eng, eps = _engine(policy="round_robin", monitoring=False)
    counts = {e.name: 0 for e in eps}
    for w in range(2):
        eng.submit_many(_window_tasks(w, n=6))
        res = eng.flush()
        for ep in res.assignments.values():
            counts[ep] += 1
    # 12 tasks over 4 endpoints with a carried offset -> perfectly balanced
    assert set(counts.values()) == {3}


def test_single_site_engine_requires_site():
    eps = table1_testbed()
    with pytest.raises(ValueError):
        OnlineEngine(eps, TestbedSim(eps, seed=0), policy="single_site")
    eng = OnlineEngine(
        eps, TestbedSim(eps, seed=0), policy="single_site", site="ic",
        monitoring=False,
    )
    eng.submit_many(_window_tasks(0, n=8))
    res = eng.flush()
    assert set(res.assignments.values()) == {"ic"}


def test_cluster_mhra_policy_online():
    eng, eps = _engine(policy="cluster_mhra", monitoring=False)
    eng.submit_many(_window_tasks(0, n=56))
    res = eng.flush()
    assert set(res.assignments) == {t.id for t in res.tasks}
    s = eng.summary()
    assert s.windows == 1 and s.tasks == 56
    assert s.energy_j > 0 and s.makespan_s > 0


def test_idle_gap_window_plans_in_the_present():
    """A window submitted after an idle gap must be *planned* after the gap
    too: the live state's slots advance to the window's arrival time, so
    the planner can't schedule starts in the past relative to dispatch."""
    eng, _ = _engine(monitoring=False)
    eng.submit_many(_window_tasks(0, n=8), when=0.0)
    r0 = eng.flush()
    end0 = max(e for _, e in (r0.schedule.timeline[t.id] for t in r0.tasks))
    gap_at = end0 + 400.0
    eng.submit_many(_window_tasks(1, n=8), when=gap_at)
    r1 = eng.flush()
    for t in r1.tasks:
        start, _ = r1.schedule.timeline[t.id]
        assert start >= gap_at, (t.id, start)          # planner view
    for rec in r1.sim.records:
        assert rec.t_start >= gap_at                    # simulated view


def test_execute_window_no_pid_overlap_after_gap():
    """Slot/pid bookkeeping across windows: a task arriving mid-gap must
    reuse the *freed* worker slot, never the pid of a still-running task
    (regression: matching on the clamped free time picked a busy slot)."""
    from repro.core.endpoint import EndpointSpec

    eps = [EndpointSpec("a", cores=2, idle_power_w=10.0, tdp_w=100.0,
                        queue_delay_s=0.0, has_batch_scheduler=False)]
    profiles = {"long": {"a": (100.0, 1.0)}, "short": {"a": (3.0, 1.0)}}
    sim = TestbedSim(eps, profiles=profiles, seed=0, runtime_noise=0.0)
    sim.begin_stream()
    w0 = [TaskSpec(id="t_long", fn="long"), TaskSpec(id="t_short", fn="short")]
    sim.execute_window({t.id: "a" for t in w0}, w0, now=0.0)
    w1 = [TaskSpec(id="t_late", fn="short")]
    res = sim.execute_window({t.id: "a" for t in w1}, w1, now=95.0)
    late = res.records[0]
    assert late.t_start >= 95.0
    # the long task (pid of slot 0 or 1) is still running at 95-100; the
    # late task must have taken the other slot's pid
    long_iv = [iv for iv in sim._stream["intervals"]["a"] if iv[1] > 99.0]
    assert long_iv, "long task should still be tracked"
    assert late.worker_pid != long_iv[0][3]


def test_attribution_feeds_energy_records():
    eng, _ = _engine(monitoring=True)
    eng.submit_many(_window_tasks(0, n=28))
    res = eng.flush()
    assert res.attributed_j > 0
    assert len(eng.db.records) == 28
    assert all(r.energy_j is not None and r.energy_j >= 0 for r in eng.db.records)
