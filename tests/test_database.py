"""TaskDB: incremental aggregates and append-only JSONL persistence."""
import dataclasses
import json

from repro.core.counters import TaskRecord
from repro.core.database import TaskDB


def _rec(i, ep="desktop", fn="graph_bfs", user="user0", energy=1.5,
         node=3.0):
    return TaskRecord(
        task_id=f"t{i}", fn=fn, endpoint=ep, worker_pid=1000 + i,
        t_start=float(i), t_end=float(i) + 2.0,
        energy_j=energy, node_energy_j=node, user=user,
    )


def _brute_force_energy_by_endpoint(db):
    out = {}
    for r in db.records:
        out[r.endpoint] = out.get(r.endpoint, 0.0) + (r.energy_j or 0.0)
    return out


def test_incremental_aggregates_match_brute_force():
    db = TaskDB()
    for i in range(10):
        db.add(_rec(i, ep="desktop" if i % 2 else "theta",
                    fn="graph_bfs" if i % 3 else "thumbnail",
                    user=f"user{i % 2}", energy=float(i)))
    assert db.energy_by_endpoint() == _brute_force_energy_by_endpoint(db)
    total_u = sum(sum(db.energy_by_user(f"user{u}").values()) for u in (0, 1))
    assert total_u == sum(r.energy_j for r in db.records)
    assert db.node_energy_by_endpoint()["desktop"] == sum(
        r.node_energy_j for r in db.records if r.endpoint == "desktop")


def test_by_function_averages():
    db = TaskDB()
    db.extend([_rec(0, energy=2.0), _rec(1, energy=4.0)])
    db.add(_rec(2, energy=None))          # unattributed: excluded
    assert db.by_function() == {"graph_bfs": {"desktop": 3.0}}


def test_extend_indexes_like_add():
    a, b = TaskDB(), TaskDB()
    recs = [_rec(i) for i in range(5)]
    for r in recs:
        a.add(r)
    b.extend(recs)
    assert a.energy_by_endpoint() == b.energy_by_endpoint()
    assert a.by_function() == b.by_function()


def test_reindex_after_mutation():
    db = TaskDB()
    db.add(_rec(0, energy=1.0))
    db.records[0].energy_j = 10.0
    db.reindex()
    assert db.energy_by_endpoint() == {"desktop": 10.0}


def test_jsonl_roundtrip(tmp_path):
    db = TaskDB(tmp_path / "db.jsonl")
    db.extend([_rec(i) for i in range(4)])
    db.save()
    text = (tmp_path / "db.jsonl").read_text()
    assert len(text.strip().splitlines()) == 4      # one JSON object per line
    db2 = TaskDB(tmp_path / "db.jsonl")
    assert [r.task_id for r in db2.records] == [r.task_id for r in db.records]
    assert db2.energy_by_endpoint() == db.energy_by_endpoint()


def test_save_appends_only_new_records(tmp_path):
    db = TaskDB(tmp_path / "db.jsonl")
    db.extend([_rec(i) for i in range(3)])
    db.save()
    first = (tmp_path / "db.jsonl").read_text()
    db.add(_rec(3))
    db.save()
    text = (tmp_path / "db.jsonl").read_text()
    assert text.startswith(first)                   # prior bytes untouched
    assert len(text.strip().splitlines()) == 4
    db.save()                                       # no new records: no-op
    assert (tmp_path / "db.jsonl").read_text() == text


def test_legacy_json_array_load_and_upgrade(tmp_path):
    recs = [_rec(i) for i in range(3)]
    legacy = tmp_path / "db.json"
    legacy.write_text(json.dumps([dataclasses.asdict(r) for r in recs]))
    db = TaskDB(legacy)
    assert len(db.records) == 3
    assert db.energy_by_endpoint() == {"desktop": 4.5}
    db.add(_rec(3))
    db.save()                                       # upgrades to JSONL
    lines = legacy.read_text().strip().splitlines()
    assert len(lines) == 4
    assert all(json.loads(ln)["task_id"].startswith("t") for ln in lines)
    db2 = TaskDB(legacy)
    assert len(db2.records) == 4
