"""TaskDB: incremental aggregates and append-only JSONL persistence."""
import dataclasses
import json

from repro.core.counters import TaskRecord
from repro.core.database import TaskDB


def _rec(i, ep="desktop", fn="graph_bfs", user="user0", energy=1.5,
         node=3.0):
    return TaskRecord(
        task_id=f"t{i}", fn=fn, endpoint=ep, worker_pid=1000 + i,
        t_start=float(i), t_end=float(i) + 2.0,
        energy_j=energy, node_energy_j=node, user=user,
    )


def _brute_force_energy_by_endpoint(db):
    out = {}
    for r in db.records:
        out[r.endpoint] = out.get(r.endpoint, 0.0) + (r.energy_j or 0.0)
    return out


def test_incremental_aggregates_match_brute_force():
    db = TaskDB()
    for i in range(10):
        db.add(_rec(i, ep="desktop" if i % 2 else "theta",
                    fn="graph_bfs" if i % 3 else "thumbnail",
                    user=f"user{i % 2}", energy=float(i)))
    assert db.energy_by_endpoint() == _brute_force_energy_by_endpoint(db)
    total_u = sum(sum(db.energy_by_user(f"user{u}").values()) for u in (0, 1))
    assert total_u == sum(r.energy_j for r in db.records)
    assert db.node_energy_by_endpoint()["desktop"] == sum(
        r.node_energy_j for r in db.records if r.endpoint == "desktop")


def test_by_function_averages():
    db = TaskDB()
    db.extend([_rec(0, energy=2.0), _rec(1, energy=4.0)])
    db.add(_rec(2, energy=None))          # unattributed: excluded
    assert db.by_function() == {"graph_bfs": {"desktop": 3.0}}


def test_extend_indexes_like_add():
    a, b = TaskDB(), TaskDB()
    recs = [_rec(i) for i in range(5)]
    for r in recs:
        a.add(r)
    b.extend(recs)
    assert a.energy_by_endpoint() == b.energy_by_endpoint()
    assert a.by_function() == b.by_function()


def test_reindex_after_mutation():
    db = TaskDB()
    db.add(_rec(0, energy=1.0))
    db.records[0].energy_j = 10.0
    db.reindex()
    assert db.energy_by_endpoint() == {"desktop": 10.0}


def test_users_enumeration_sorted():
    db = TaskDB()
    for i, u in enumerate(["zoe", "abe", "zoe", "mia"]):
        db.add(_rec(i, user=u))
    assert db.users() == ["abe", "mia", "zoe"]
    assert TaskDB().users() == []


def test_per_user_span_and_edp_hand_computed():
    db = TaskDB()
    # alice: spans [0, 2] and [5, 7] -> span 7 s, energy 2 + 4 = 6 J
    db.add(_rec(0, user="alice", energy=2.0))
    db.add(_rec(5, user="alice", energy=4.0))
    # bob: one record [3, 5] -> span 2 s, energy 1.5 J
    db.add(_rec(3, user="bob"))
    assert db.span_by_user() == {"alice": (0.0, 7.0), "bob": (3.0, 5.0)}
    edp = db.edp_by_user()
    assert edp["alice"] == 6.0 * 7.0
    assert edp["bob"] == 1.5 * 2.0


def test_user_stats_fields():
    db = TaskDB()
    db.add(_rec(0, user="alice", energy=2.0))
    db.add(_rec(5, user="alice", energy=4.0))
    s = db.user_stats()["alice"]
    assert s == {"energy_j": 6.0, "busy_s": 4.0, "tasks": 2.0,
                 "span_s": 7.0, "edp": 42.0}


def test_user_aggregates_survive_compaction():
    """Per-user aggregates are cumulative: evicting raw rows under
    max_records must not change them."""
    full, capped = TaskDB(), TaskDB(max_records=4)
    for i in range(20):
        r = _rec(i, user=f"user{i % 3}", energy=float(i + 1))
        full.add(r)
        capped.add(_rec(i, user=f"user{i % 3}", energy=float(i + 1)))
    assert capped.evicted == 16 and len(capped.records) == 4
    assert capped.users() == full.users() == ["user0", "user1", "user2"]
    assert capped.span_by_user() == full.span_by_user()
    assert capped.edp_by_user() == full.edp_by_user()
    assert capped.user_stats() == full.user_stats()
    # reindex is documented as unbounded-only: it forgets evicted rows
    capped.reindex()
    assert capped.user_stats() != full.user_stats()


def test_jsonl_roundtrip(tmp_path):
    db = TaskDB(tmp_path / "db.jsonl")
    db.extend([_rec(i) for i in range(4)])
    db.save()
    text = (tmp_path / "db.jsonl").read_text()
    assert len(text.strip().splitlines()) == 4      # one JSON object per line
    db2 = TaskDB(tmp_path / "db.jsonl")
    assert [r.task_id for r in db2.records] == [r.task_id for r in db.records]
    assert db2.energy_by_endpoint() == db.energy_by_endpoint()


def test_save_appends_only_new_records(tmp_path):
    db = TaskDB(tmp_path / "db.jsonl")
    db.extend([_rec(i) for i in range(3)])
    db.save()
    first = (tmp_path / "db.jsonl").read_text()
    db.add(_rec(3))
    db.save()
    text = (tmp_path / "db.jsonl").read_text()
    assert text.startswith(first)                   # prior bytes untouched
    assert len(text.strip().splitlines()) == 4
    db.save()                                       # no new records: no-op
    assert (tmp_path / "db.jsonl").read_text() == text


def test_legacy_json_array_load_and_upgrade(tmp_path):
    recs = [_rec(i) for i in range(3)]
    legacy = tmp_path / "db.json"
    legacy.write_text(json.dumps([dataclasses.asdict(r) for r in recs]))
    db = TaskDB(legacy)
    assert len(db.records) == 3
    assert db.energy_by_endpoint() == {"desktop": 4.5}
    db.add(_rec(3))
    db.save()                                       # upgrades to JSONL
    lines = legacy.read_text().strip().splitlines()
    assert len(lines) == 4
    assert all(json.loads(ln)["task_id"].startswith("t") for ln in lines)
    db2 = TaskDB(legacy)
    assert len(db2.records) == 4
