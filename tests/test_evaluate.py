"""Evaluation harness: EDP/GPS-UP arithmetic, baseline selection,
determinism, per-endpoint energy accounting, and BENCH_eval.json
persistence."""
import json

import numpy as np
import pytest

from repro.core.evaluate import (
    EvalResult,
    PolicyRun,
    evaluate_trace,
    gpsup,
    per_endpoint_energy,
    run_policy,
    verify_dag_order,
    warm_store,
)
from repro.core.report import write_bench_json
from repro.core.scheduler import SchedulerState, SoAState, TaskSpec
from repro.core.testbed import TestbedSim
from repro.core.transfer import TransferModel
from repro.workloads import moldesign_dag_workload, synthetic_edp_workload


def _tiny_synthetic(n=48, seed=0):
    return synthetic_edp_workload(n_tasks=n, seed=seed)


def test_gpsup_hand_computed():
    # base: 100 J in 10 s (10 W); new: 50 J in 5 s (10 W)
    g, s, u = gpsup(100.0, 10.0, 50.0, 5.0)
    assert g == pytest.approx(2.0)
    assert s == pytest.approx(2.0)
    assert u == pytest.approx(1.0)
    # powerup: base 10 W vs new 25 W
    g, s, u = gpsup(100.0, 10.0, 50.0, 2.0)
    assert u == pytest.approx(10.0 / 25.0)


def test_policy_run_edp_is_product():
    r = PolicyRun(
        policy="x", engine="delta", energy_j=123.5, makespan_s=7.25,
        transfer_j=0.0, scheduling_s=0.0, sim_makespan_s=0.0,
        attributed_j=0.0, windows=1, tasks=1, per_endpoint_j={},
        placements={},
    )
    assert r.edp == 123.5 * 7.25
    assert r.power_w == pytest.approx(123.5 / 7.25)


def test_run_policy_deterministic():
    trace = _tiny_synthetic()
    a = run_policy(trace, "mhra")
    b = run_policy(trace, "mhra")
    assert a.assignments == b.assignments
    assert a.energy_j == b.energy_j
    assert a.makespan_s == b.makespan_s


def test_evaluate_trace_rows_and_baseline():
    trace = _tiny_synthetic()
    res = evaluate_trace(trace)
    labels = [r.policy for r in res.rows]
    for ep in trace.endpoints:
        assert f"site:{ep.name}" in labels
    for p in ("mhra", "cluster_mhra", "round_robin"):
        assert p in labels
    sites = res.single_site_rows()
    best = min(sites, key=lambda r: r.edp)
    assert res.baseline == best.policy
    # baseline row's GPS-UP ratios are exactly 1
    for r in res.rows:
        if r.policy == res.baseline:
            assert r.greenup == pytest.approx(1.0)
            assert r.speedup == pytest.approx(1.0)
            assert r.powerup == pytest.approx(1.0)
        # powerup consistency: U = G/S
        assert r.powerup == pytest.approx(r.greenup / r.speedup)
    # the paper's bar: MHRA EDP no worse than the best single site
    assert res.row("mhra").edp <= best.edp * (1 + 1e-9)


def test_per_endpoint_energy_sums_to_metrics_total():
    trace = _tiny_synthetic()
    _, windows = run_policy(trace, "mhra", return_windows=True)
    # rebuild state both ways via a fresh run to inspect the live state
    sim = TestbedSim(trace.endpoints, profiles=trace.profiles,
                     signatures=trace.signatures, seed=0, runtime_noise=0.0)
    from repro.core.engine import OnlineEngine
    eng = OnlineEngine(trace.endpoints, sim, policy="mhra",
                       store=warm_store(sim, trace), monitoring=False,
                       window_s=5.0, max_batch=512)
    trace.replay_into(eng)
    per_ep = per_endpoint_energy(eng.state)
    e_tot, _, _ = eng.state.metrics()
    assert sum(per_ep.values()) == pytest.approx(e_tot, rel=1e-12)


def test_per_endpoint_energy_heap_and_soa_agree():
    eps = _tiny_synthetic().endpoints
    transfer = TransferModel(eps)
    heap = SchedulerState(eps, transfer)
    store = TestbedSim(eps, seed=0)
    from repro.core.predictor import TaskProfileStore
    ps = TaskProfileStore(eps)
    ps.record("graph_bfs", "desktop", 4.0, 8.0)
    preds = {"t0": ps.predict("graph_bfs", "desktop")}
    heap.assign([TaskSpec(id="t0", fn="graph_bfs")], eps[0], preds)
    soa = SoAState.from_heap(heap)
    assert per_endpoint_energy(heap) == per_endpoint_energy(soa)


def test_verify_dag_order_counts_edges_and_detects_violation():
    trace = moldesign_dag_workload(waves=1, docks_per_wave=3,
                                   sims_per_wave=3, infers_per_wave=4)
    _, windows = run_policy(trace, "mhra", alpha=0.3, return_windows=True)
    edges = verify_dag_order(windows)
    # 3 sims with 1 dock parent each + train fan-in 3 + 4 infers
    assert edges == 3 + 3 + 4
    # corrupt one record: the checker must catch it
    windows[-1].sim.records[-1].t_start = -1.0
    tid = windows[-1].sim.records[-1].task_id
    deps_of = {t.id: t.deps for w in windows for t in w.tasks}
    if deps_of[tid]:
        with pytest.raises(AssertionError, match="DAG violation"):
            verify_dag_order(windows)


def test_eval_result_payload_roundtrip(tmp_path):
    trace = _tiny_synthetic()
    res = evaluate_trace(trace, policies=("mhra",))
    out = tmp_path / "BENCH_eval.json"
    payload = write_bench_json(res, path=out, extra={"size": "test"})
    loaded = json.loads(out.read_text())
    assert loaded == json.loads(json.dumps(payload))  # JSON-serializable
    assert loaded["size"] == "test"
    wl = loaded["workloads"][0]
    assert wl["workload"] == trace.name
    row = next(r for r in wl["rows"] if r["policy"] == "mhra")
    assert row["edp"] == pytest.approx(row["energy_j"] * row["makespan_s"])
    assert "assignments" not in row
    assert set(row["per_endpoint_j"]) >= {e.name for e in trace.endpoints}


def test_evaluate_without_single_sites_uses_first_policy_baseline():
    trace = _tiny_synthetic()
    res = evaluate_trace(trace, policies=("round_robin", "mhra"),
                         include_single_sites=False)
    assert res.baseline == "round_robin"
    rr = res.row("round_robin")
    assert rr.greenup == pytest.approx(1.0)
