"""benchmarks/diff_eval.py: regression orientation per metric, warn/fail
thresholds, new/removed rows, markdown rendering, and the CLI exit code."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.diff_eval import (  # noqa: E402
    FAIL,
    OK,
    WARN,
    diff_payloads,
    main,
    render_markdown,
)


def _payload(edp=100.0, greenup=1.0, carbon_g=None, policy="mhra",
             workload="synthetic"):
    row = {"policy": policy, "edp": edp, "greenup": greenup,
           "speedup": 1.0, "powerup": 1.0, "carbon_g": carbon_g,
           "cdp": None}
    return {"workloads": [{"workload": workload, "rows": [row]}]}


def test_unchanged_metrics_are_ok():
    rows, worst = diff_payloads(_payload(), _payload())
    assert worst == OK
    assert all(r.status == OK for r in rows)
    # carbon_g None on both sides: not compared
    assert all(r.metric != "carbon_g" for r in rows)


def test_edp_regression_direction_and_thresholds():
    prev = _payload(edp=100.0)
    # 5% higher EDP = worse -> WARN at the 2/10 defaults
    rows, worst = diff_payloads(prev, _payload(edp=105.0))
    assert worst == WARN
    (edp_row,) = [r for r in rows if r.metric == "edp"]
    assert edp_row.regression_pct == pytest.approx(5.0)
    # 15% higher -> FAIL
    _, worst = diff_payloads(prev, _payload(edp=115.0))
    assert worst == FAIL
    # 15% *lower* EDP is an improvement -> OK (negative regression)
    rows, worst = diff_payloads(prev, _payload(edp=85.0))
    assert worst == OK
    (edp_row,) = [r for r in rows if r.metric == "edp"]
    assert edp_row.regression_pct == pytest.approx(-15.0)


def test_gpsup_regression_is_inverted():
    # greenup *dropping* 20% is the regression
    rows, worst = diff_payloads(_payload(greenup=1.0), _payload(greenup=0.8))
    assert worst == FAIL
    (g,) = [r for r in rows if r.metric == "greenup"]
    assert g.regression_pct == pytest.approx(20.0)
    # greenup rising is an improvement
    _, worst = diff_payloads(_payload(greenup=1.0), _payload(greenup=1.3))
    assert worst == OK


def test_carbon_metric_compared_when_present():
    rows, worst = diff_payloads(_payload(carbon_g=10.0),
                                _payload(carbon_g=11.2))
    (c,) = [r for r in rows if r.metric == "carbon_g"]
    assert c.regression_pct == pytest.approx(12.0)
    assert worst == FAIL


def test_new_and_removed_rows_never_fail():
    prev = _payload(policy="mhra")
    curr = {"workloads": [{"workload": "synthetic", "rows": [
        {"policy": "mhra", "edp": 100.0, "greenup": 1.0, "speedup": 1.0,
         "powerup": 1.0},
        {"policy": "carbon_mhra", "edp": 90.0, "greenup": 1.1, "speedup": 1.0,
         "powerup": 1.1},
    ]}]}
    rows, worst = diff_payloads(prev, curr)
    assert worst == OK
    assert any(r.policy == "carbon_mhra" and r.status == "new" for r in rows)
    # removed policy likewise only annotates
    rows, worst = diff_payloads(curr, prev)
    assert worst == OK
    assert any(r.policy == "carbon_mhra" and r.status == "removed"
               for r in rows)
    # whole new workload
    rows, worst = diff_payloads(prev, _payload(workload="dag"))
    assert worst == OK
    assert {r.status for r in rows} >= {"new", "removed"}


def test_thresholds_validated():
    with pytest.raises(ValueError, match="warn_pct"):
        diff_payloads(_payload(), _payload(), warn_pct=20.0, fail_pct=10.0)


def test_render_markdown_table():
    rows, worst = diff_payloads(_payload(edp=100.0), _payload(edp=105.0))
    md = render_markdown(rows, worst, 2.0, 10.0)
    assert "| workload | policy | metric |" in md
    assert "WARN" in md and "synthetic" in md and "edp" in md
    assert "+5.00%" in md


def test_cli_exit_codes_and_summary(tmp_path):
    prev, curr = tmp_path / "prev.json", tmp_path / "curr.json"
    prev.write_text(json.dumps(_payload(edp=100.0)))
    summary = tmp_path / "summary.md"
    # OK run exits 0 and appends the table
    curr.write_text(json.dumps(_payload(edp=101.0)))
    assert main([str(prev), str(curr), "--summary", str(summary)]) == 0
    assert "Evaluation trend" in summary.read_text()
    # >10% regression exits 1
    curr.write_text(json.dumps(_payload(edp=120.0)))
    assert main([str(prev), str(curr)]) == 1
