"""benchmarks/diff_eval.py: regression orientation per metric, warn/fail
thresholds, new/removed rows, markdown rendering, and the CLI exit code."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks.diff_eval import (  # noqa: E402
    FAIL,
    OK,
    WARN,
    diff_payloads,
    main,
    render_markdown,
)


def _payload(edp=100.0, greenup=1.0, carbon_g=None, policy="mhra",
             workload="synthetic"):
    row = {"policy": policy, "edp": edp, "greenup": greenup,
           "speedup": 1.0, "powerup": 1.0, "carbon_g": carbon_g,
           "cdp": None}
    return {"workloads": [{"workload": workload, "rows": [row]}]}


def test_unchanged_metrics_are_ok():
    rows, worst = diff_payloads(_payload(), _payload())
    assert worst == OK
    assert all(r.status == OK for r in rows)
    # carbon_g None on both sides: not compared
    assert all(r.metric != "carbon_g" for r in rows)


def test_edp_regression_direction_and_thresholds():
    prev = _payload(edp=100.0)
    # 5% higher EDP = worse -> WARN at the 2/10 defaults
    rows, worst = diff_payloads(prev, _payload(edp=105.0))
    assert worst == WARN
    (edp_row,) = [r for r in rows if r.metric == "edp"]
    assert edp_row.regression_pct == pytest.approx(5.0)
    # 15% higher -> FAIL
    _, worst = diff_payloads(prev, _payload(edp=115.0))
    assert worst == FAIL
    # 15% *lower* EDP is an improvement -> OK (negative regression)
    rows, worst = diff_payloads(prev, _payload(edp=85.0))
    assert worst == OK
    (edp_row,) = [r for r in rows if r.metric == "edp"]
    assert edp_row.regression_pct == pytest.approx(-15.0)


def test_gpsup_regression_is_inverted():
    # greenup *dropping* 20% is the regression
    rows, worst = diff_payloads(_payload(greenup=1.0), _payload(greenup=0.8))
    assert worst == FAIL
    (g,) = [r for r in rows if r.metric == "greenup"]
    assert g.regression_pct == pytest.approx(20.0)
    # greenup rising is an improvement
    _, worst = diff_payloads(_payload(greenup=1.0), _payload(greenup=1.3))
    assert worst == OK


def test_carbon_metric_compared_when_present():
    rows, worst = diff_payloads(_payload(carbon_g=10.0),
                                _payload(carbon_g=11.2))
    (c,) = [r for r in rows if r.metric == "carbon_g"]
    assert c.regression_pct == pytest.approx(12.0)
    assert worst == FAIL


def test_new_and_removed_rows_never_fail():
    prev = _payload(policy="mhra")
    curr = {"workloads": [{"workload": "synthetic", "rows": [
        {"policy": "mhra", "edp": 100.0, "greenup": 1.0, "speedup": 1.0,
         "powerup": 1.0},
        {"policy": "carbon_mhra", "edp": 90.0, "greenup": 1.1, "speedup": 1.0,
         "powerup": 1.1},
    ]}]}
    rows, worst = diff_payloads(prev, curr)
    assert worst == OK
    assert any(r.policy == "carbon_mhra" and r.status == "new" for r in rows)
    # removed policy likewise only annotates
    rows, worst = diff_payloads(curr, prev)
    assert worst == OK
    assert any(r.policy == "carbon_mhra" and r.status == "removed"
               for r in rows)
    # whole new workload
    rows, worst = diff_payloads(prev, _payload(workload="dag"))
    assert worst == OK
    assert {r.status for r in rows} >= {"new", "removed"}


def test_thresholds_validated():
    with pytest.raises(ValueError, match="warn_pct"):
        diff_payloads(_payload(), _payload(), warn_pct=20.0, fail_pct=10.0)


def test_render_markdown_table():
    rows, worst = diff_payloads(_payload(edp=100.0), _payload(edp=105.0))
    md = render_markdown(rows, worst, 2.0, 10.0)
    assert "| workload | policy | metric |" in md
    assert "WARN" in md and "synthetic" in md and "edp" in md
    assert "+5.00%" in md


def test_cli_exit_codes_and_summary(tmp_path):
    prev, curr = tmp_path / "prev.json", tmp_path / "curr.json"
    prev.write_text(json.dumps(_payload(edp=100.0)))
    summary = tmp_path / "summary.md"
    # OK run exits 0 and appends the table
    curr.write_text(json.dumps(_payload(edp=101.0)))
    assert main([str(prev), str(curr), "--summary", str(summary)]) == 0
    assert "Evaluation trend" in summary.read_text()
    # >10% regression exits 1
    curr.write_text(json.dumps(_payload(edp=120.0)))
    assert main([str(prev), str(curr)]) == 1


# ---------------------------------------------------------------------------
# rolling history (slow-drift detection)
# ---------------------------------------------------------------------------

from benchmarks.diff_eval import (  # noqa: E402
    history_baseline,
    snapshot,
    update_history,
)


def test_snapshot_keeps_only_compared_metrics():
    snap = snapshot(_payload(edp=100.0, carbon_g=5.0), meta={"label": "x"})
    row = snap["workloads"]["synthetic"]["mhra"]
    assert row == {"edp": 100.0, "greenup": 1.0, "speedup": 1.0,
                   "powerup": 1.0, "carbon_g": 5.0}
    assert snap["meta"] == {"label": "x"}


def test_history_baseline_is_per_metric_median():
    hist = None
    for edp in (100.0, 110.0, 400.0):      # median robust to the outlier
        hist = update_history(hist, _payload(edp=edp))
    base = history_baseline(hist)
    row = base["workloads"][0]["rows"][0]
    assert row["edp"] == 110.0
    assert history_baseline({"entries": []}) is None


def test_update_history_prunes_oldest_first():
    hist = None
    for edp in range(8):
        hist = update_history(hist, _payload(edp=float(edp)), keep=3)
    edps = [e["workloads"]["synthetic"]["mhra"]["edp"]
            for e in hist["entries"]]
    assert edps == [5.0, 6.0, 7.0]
    with pytest.raises(ValueError, match="keep"):
        update_history(None, _payload(), keep=0)


def test_slow_drift_trips_against_rolling_median(tmp_path):
    """+1.5%/run never trips a previous-run diff (inside the 2% warn
    band) but accumulates past the rolling median's warn threshold."""
    hist = None
    edp = 100.0
    for _ in range(4):
        hist = update_history(hist, _payload(edp=edp))
        edp *= 1.015
    # pairwise vs the immediately previous run: still OK
    rows, worst = diff_payloads(_payload(edp=edp / 1.015), _payload(edp=edp))
    assert worst == OK
    # vs the rolling median: the drift is visible
    rows, worst = diff_payloads(history_baseline(hist), _payload(edp=edp))
    assert worst == WARN


def test_cli_history_mode_creates_then_diffs(tmp_path):
    hist = tmp_path / "hist.json"
    curr = tmp_path / "curr.json"
    curr.write_text(json.dumps(_payload(edp=100.0)))
    # first run: no baseline yet, history created with one entry
    assert main([str(curr), "--history", str(hist), "--meta", "r1"]) == 0
    h = json.loads(hist.read_text())
    assert len(h["entries"]) == 1
    assert h["entries"][0]["meta"] == {"label": "r1"}
    # second run with a >10% EDP regression: fails against the median
    curr.write_text(json.dumps(_payload(edp=120.0)))
    summary = tmp_path / "sum.md"
    assert main([str(curr), "--history", str(hist),
                 "--summary", str(summary)]) == 1
    assert "rolling median of 1 run(s)" in summary.read_text()
    assert len(json.loads(hist.read_text())["entries"]) == 2


def test_cli_history_mode_argument_validation(tmp_path):
    with pytest.raises(SystemExit):
        main(["a.json", "b.json", "--history", "h.json"])
    with pytest.raises(SystemExit):
        main(["only_one.json"])
