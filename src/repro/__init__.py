"""repro — GreenFaaS (CS.DC 2024) on JAX/TPU.

Public API:
    repro.core       — the paper: monitoring, attribution, Cluster MHRA
    repro.models     — 10-architecture substrate
    repro.kernels    — Pallas TPU kernels (flash attn, decode, scan, ssd)
    repro.fleet      — GreenFaaS <-> TPU fleet integration
    repro.launch     — mesh / dryrun / train / serve entry points
"""
__version__ = "1.0.0"
