"""Batched serving driver: prefill a prompt batch, decode N tokens.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ctx_for
from repro.launch.mesh import make_host_mesh
from repro.models.lm import extend_cache
from repro.models.registry import get_api


def serve_batch(
    arch: str = "granite-3-2b",
    reduced: bool = True,
    batch: int = 4,
    prompt_len: int = 64,
    gen_tokens: int = 32,
    seed: int = 0,
    greedy: bool = True,
):
    api = get_api(arch, reduced=reduced)
    cfg = api.cfg
    mesh = make_host_mesh()
    ctx = ctx_for(cfg, mesh)
    rng = jax.random.PRNGKey(seed)
    params = api.init(rng)

    prompts = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab)
    pre_in = {"tokens": prompts}
    if cfg.family == "encdec":
        pre_in["frames"] = jax.random.normal(
            rng, (batch, cfg.enc_len, cfg.d_model), jnp.bfloat16
        )
    if cfg.family == "vlm":
        pre_in["vision_embeds"] = jax.random.normal(
            rng, (batch, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
        )

    prefill = jax.jit(lambda p, b: api.prefill(p, b, shd=ctx))
    decode = jax.jit(lambda p, t, c, pos: api.decode_step(p, t, c, pos, shd=ctx))

    with mesh:
        t0 = time.perf_counter()
        logits, cache = prefill(params, pre_in)
        max_len = prompt_len + gen_tokens
        cache = {
            k: (jnp.pad(v, [(0, 0), (0, 0), (0, gen_tokens)] + [(0, 0)] * (v.ndim - 3))
                if k in ("k", "v", "shared_k", "shared_v") else v)
            for k, v in cache.items()
        }
        t_prefill = time.perf_counter() - t0
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.perf_counter()
        for i in range(gen_tokens - 1):
            logits, cache = decode(params, tok, cache, jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits[:, 0], -1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    tps = batch * (gen_tokens - 1) / max(t_decode, 1e-9)
    print(
        f"[serve {arch}] prefill {prompt_len} toks x{batch}: {t_prefill*1e3:.0f} ms; "
        f"decode {gen_tokens} toks: {t_decode*1e3:.0f} ms ({tps:.1f} tok/s)"
    )
    return np.asarray(gen), t_prefill, t_decode


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    serve_batch(
        arch=args.arch, reduced=not args.full, batch=args.batch,
        prompt_len=args.prompt_len, gen_tokens=args.gen,
    )


if __name__ == "__main__":
    main()
