"""End-to-end training driver (runs on real local devices).

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --reduced --steps 50 --batch 8 --seq 256 --checkpoint /tmp/ckpt

Features exercised here are the production ones: sharded train step (jit +
NamedShardings over the host mesh), deterministic restart-safe data
pipeline, atomic/async checkpointing, resume, straggler-aware step timing
hooks (fed to the GreenFaaS profile store by the fleet driver).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint.manager import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data.pipeline import SyntheticTokens
from repro.distributed.sharding import ctx_for, param_shardings
from repro.distributed.steps import build_train_step, init_train_state, train_state_axes
from repro.launch.mesh import make_host_mesh
from repro.models.registry import get_api
from repro.optim.adamw import AdamWConfig


def train(
    arch: str = "granite-3-2b",
    reduced: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq: int = 256,
    lr: float = 3e-3,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 25,
    resume: bool = False,
    microbatches: int = 1,
    seed: int = 0,
    log_every: int = 10,
    model_dims: dict | None = None,
    on_step=None,
):
    api = get_api(arch, reduced=reduced)
    if model_dims:
        api = dataclasses.replace(api, cfg=dataclasses.replace(api.cfg, **model_dims))
        from repro.models.registry import build_api

        api = build_api(api.cfg)
    mesh = make_host_mesh()
    ctx = ctx_for(api.cfg, mesh)

    data = SyntheticTokens(api.cfg.vocab, seq, batch, seed=seed)
    opt_cfg = AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5 + 1), total_steps=steps)
    step_fn = build_train_step(api, opt_cfg, ctx, microbatches=microbatches)

    state_sh = {
        "params": param_shardings(ctx, api.specs()),
        "opt": {
            "m": param_shardings(ctx, api.specs()),
            "v": param_shardings(ctx, api.specs()),
            "step": ctx.sharding_for_shape((), ()),
        },
    }
    jit_step = jax.jit(step_fn, in_shardings=(state_sh, None), donate_argnums=0)

    state = init_train_state(api, jax.random.PRNGKey(seed))
    start_step = 0
    ckpt = None
    if checkpoint_dir:
        ckpt = AsyncCheckpointer(checkpoint_dir)
        if resume and latest_step(checkpoint_dir) is not None:
            start_step = latest_step(checkpoint_dir)
            state = restore_checkpoint(state, checkpoint_dir, shardings=state_sh)
            print(f"[train] resumed from step {start_step}")

    losses = []
    with mesh:
        for i in range(start_step, steps):
            b = data.batch_at(i)
            t0 = time.perf_counter()
            state, metrics = jit_step(state, b)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            losses.append(loss)
            if on_step:
                on_step(i, loss, dt)
            if i % log_every == 0 or i == steps - 1:
                print(f"[train {arch}] step {i:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            if ckpt and (i + 1) % checkpoint_every == 0:
                ckpt.save(state, i + 1)
    if ckpt:
        ckpt.save(state, steps)
        ckpt.wait()
    return state, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--full", action="store_true", help="full (non-reduced) config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    _, losses = train(
        arch=args.arch, reduced=not args.full, steps=args.steps,
        batch=args.batch, seq=args.seq, lr=args.lr,
        checkpoint_dir=args.checkpoint, resume=args.resume,
        microbatches=args.microbatches,
    )
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
