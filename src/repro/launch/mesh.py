"""Production meshes.  A function, not a constant: importing this module
must never touch jax device state (the dry-run sets XLA_FLAGS first)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import math

    n = math.prod(shape)
    devs = jax.devices()
    if len(devs) == n:
        return jax.make_mesh(shape, axes)
    # dry-run: 512 host devices present; single-pod mesh uses the first 256
    import numpy as np

    return jax.sharding.Mesh(np.asarray(devs[:n]).reshape(shape), axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests/examples on CPU)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
