import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (arch x shape) cell on the
# production meshes and record memory/cost/collective analysis.
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
#         --shape train_4k --mesh single
#
# Outputs one JSON blob per cell under benchmarks/results/dryrun/.
# The XLA_FLAGS line above MUST run before any jax import (device count is
# locked at first backend init) — hence its position at the very top.

import argparse
import json
import pathlib
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ShardCtx, ctx_for, param_shardings
from repro.distributed.steps import (
    abstract_train_state,
    build_decode_step,
    build_prefill_step,
    build_train_step,
    train_state_axes,
)
from repro.launch.mesh import make_production_mesh
from repro.models.common import is_spec
from repro.models.registry import SHAPES, get_api, get_config, input_specs, shape_cells
from repro.optim.adamw import AdamWConfig

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"

COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\([^)]*\)|[a-z0-9_\[\],{}/ ]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)\b",
)
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|f64|s64|c64)\[([0-9,]*)\]")

DTYPE_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
    "s8": 1, "u8": 1, "pred": 1, "f64": 8, "s64": 8, "c64": 8,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo: str) -> dict:
    """Sum result-shape bytes per collective kind from post-SPMD HLO (these
    are per-device local shapes)."""
    out: dict[str, dict] = {}
    for line in hlo.splitlines():
        line = line.strip()
        m = re.search(
            r"= *(\(?[a-z0-9_\[\],{} ]+\)?) (all-reduce|all-gather|"
            r"reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(", line
        )
        if not m or (m.group(3) == "-done"):
            continue
        kind = m.group(2)
        nbytes = _shape_bytes(m.group(1))
        d = out.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += nbytes
    return out


def _axes_shardings(ctx: ShardCtx, axes_tree, abstract_tree):
    return jax.tree.map(
        lambda axes, ab: ctx.sharding_for_shape(axes, ab.shape),
        axes_tree,
        abstract_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    verbose=True,
    rule_overrides: dict | None = None,
    batch_axes: tuple | None = None,
    unroll: bool = False,
    depth: int | None = None,
    remat_policy: str = "nothing",
    moe_group: int | None = None,
    cfg_overrides: dict | None = None,
    microbatches: int = 1,
) -> dict:
    """rule_overrides / batch_axes / depth support §Perf hillclimb variants:
    override logical->mesh rules, activation batch sharding, or lower a
    shallow unrolled variant for exact cost accounting."""
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    if depth is not None:
        kw = {"n_layers": depth}
        if cfg.family == "encdec":
            kw["n_enc_layers"] = depth
        cfg = _dc.replace(cfg, **kw)
    from repro.models.registry import build_api

    api = build_api(cfg) if depth is not None else get_api(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = ctx_for(cfg, mesh, rule_overrides=rule_overrides)
    ctx = _dc.replace(
        ctx, unroll_inner=unroll, remat_policy=remat_policy, moe_group=moe_group
    )
    if batch_axes is None:
        batch_axes = ("batch",)
    seq, gb, kind = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)

    t0 = time.time()
    if kind == "train":
        state = abstract_train_state(api)
        st_axes = train_state_axes(api)
        state_sh = {
            "params": param_shardings(ctx, api.specs()),
            "opt": {
                "m": param_shardings(ctx, api.specs()),
                "v": param_shardings(ctx, api.specs()),
                "step": ctx.sharding_for_shape((), ()),
            },
        }
        batch_sh = {
            k: ctx.sharding_for_shape(
                batch_axes + (None,) * (len(v.shape) - len(batch_axes)), v.shape
            )
            for k, v in specs.items()
        }
        step = build_train_step(api, AdamWConfig(), ctx, microbatches=microbatches)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh)
            ).lower(state, specs)
    elif kind == "prefill":
        params = _bf16(api.abstract())
        params_sh = param_shardings(ctx, api.specs())
        batch_sh = {
            k: ctx.sharding_for_shape(
                batch_axes + (None,) * (len(v.shape) - len(batch_axes)), v.shape
            )
            for k, v in specs.items()
        }
        step = build_prefill_step(api, ctx)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_sh, batch_sh)
            ).lower(params, specs)
    else:  # decode
        # serve-mode sharding policy (§Perf iterations 3-4): weights stay
        # resident (embed dim replicated) when they + cache fit in HBM
        if rule_overrides is None:
            from repro.distributed.sharding import serve_rule_overrides

            cache_bytes = sum(
                int(__import__("numpy").prod(a.shape)) * a.dtype.itemsize
                for a in jax.tree.leaves(specs["cache"])
            )
            sro = serve_rule_overrides(cfg, mesh, api.n_params(), cache_bytes)
            if sro:
                ctx = _dc.replace(ctx, overrides={**ctx.overrides, **sro})
        params = _bf16(api.abstract())
        params_sh = param_shardings(ctx, api.specs())
        cache_ax = api.cache_axes()
        cache_sh = {
            k: jax.tree.map(
                lambda ab, a=cache_ax[k]: ctx.sharding_for_shape(a, ab.shape),
                specs["cache"][k],
            )
            for k in specs["cache"]
        }
        tok_sh = ctx.sharding_for_shape(batch_axes + (None,), specs["tokens"].shape)
        pos_sh = ctx.sharding_for_shape((), ())
        step = build_decode_step(api, ctx)
        with mesh:
            lowered = jax.jit(
                step, in_shardings=(params_sh, tok_sh, cache_sh, pos_sh)
            ).lower(params, specs["tokens"], specs["cache"], specs["pos"])
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    mem_d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            mem_d[f] = int(getattr(mem, f))
        except Exception:
            pass
    coll = parse_collectives(compiled.as_text())
    n_dev = mesh.devices.size
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        "n_devices": int(n_dev),
        "kind": kind,
        "seq": seq,
        "global_batch": gb,
        "flops_per_device": float(cost.get("flops", -1.0)) if cost else -1.0,
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1.0)) if cost else -1.0,
        "memory": mem_d,
        "collectives": coll,
        "collective_bytes_per_device": int(sum(v["bytes"] for v in coll.values())),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "n_params": api.n_params(),
    }
    if verbose:
        print(json.dumps({k: v for k, v in result.items() if k != "memory"}, indent=1))
        print("memory:", mem_d)
    return result


def _bf16(tree):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
        ),
        tree,
    )


# ---------------------------------------------------------------------------
# Cost extrapolation.
#
# XLA's cost_analysis counts while-loop bodies ONCE, so a rolled layer scan
# underreports FLOPs/collective bytes by ~n_layers.  We therefore lower two
# SHALLOW, FULLY-UNROLLED depth variants (L1, L2) of each cell and fit
#   cost(L) = a + b*L
# exactly (per-layer structure is homogeneous), then evaluate at the real
# depth.  The full-depth rolled compile remains the official artifact (and
# provides the memory analysis, which is trip-count independent).
# ---------------------------------------------------------------------------


def _depth_variants(cfg):
    import dataclasses

    if cfg.family == "hybrid":
        e = cfg.shared_attn_every
        return [
            (dataclasses.replace(cfg, n_layers=e), e),
            (dataclasses.replace(cfg, n_layers=2 * e), 2 * e),
        ]
    if cfg.family == "encdec":
        return [
            (dataclasses.replace(cfg, n_layers=1, n_enc_layers=1), 1),
            (dataclasses.replace(cfg, n_layers=2, n_enc_layers=2), 2),
        ]
    import dataclasses as dc

    return [
        (dc.replace(cfg, n_layers=1), 1),
        (dc.replace(cfg, n_layers=2), 2),
    ]


def _lower_for_cost(cfg, shape_name: str, multi_pod: bool):
    """Lower+compile one unrolled shallow variant; return metric dict."""
    import dataclasses

    from repro.models.registry import build_api

    api = build_api(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = dataclasses.replace(ctx_for(cfg, mesh), unroll_inner=True)
    seq, gb, kind = SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    if kind == "train":
        state = abstract_train_state(api)
        state_sh = {
            "params": param_shardings(ctx, api.specs()),
            "opt": {
                "m": param_shardings(ctx, api.specs()),
                "v": param_shardings(ctx, api.specs()),
                "step": ctx.sharding_for_shape((), ()),
            },
        }
        batch_sh = {
            k: ctx.sharding_for_shape(("batch",) + (None,) * (len(v.shape) - 1), v.shape)
            for k, v in specs.items()
        }
        step = build_train_step(api, AdamWConfig(), ctx)
        with mesh:
            compiled = jax.jit(step, in_shardings=(state_sh, batch_sh)).lower(
                state, specs
            ).compile()
    elif kind == "prefill":
        params = _bf16(api.abstract())
        params_sh = param_shardings(ctx, api.specs())
        batch_sh = {
            k: ctx.sharding_for_shape(("batch",) + (None,) * (len(v.shape) - 1), v.shape)
            for k, v in specs.items()
        }
        step = build_prefill_step(api, ctx)
        with mesh:
            compiled = jax.jit(step, in_shardings=(params_sh, batch_sh)).lower(
                params, specs
            ).compile()
    else:
        params = _bf16(api.abstract())
        params_sh = param_shardings(ctx, api.specs())
        cache_ax = api.cache_axes()
        cache_sh = {
            k: jax.tree.map(
                lambda ab, a=cache_ax[k]: ctx.sharding_for_shape(a, ab.shape),
                specs["cache"][k],
            )
            for k in specs["cache"]
        }
        tok_sh = ctx.sharding_for_shape(("batch", None), specs["tokens"].shape)
        step = build_decode_step(api, ctx)
        with mesh:
            compiled = jax.jit(
                step,
                in_shardings=(params_sh, tok_sh, cache_sh, ctx.sharding_for_shape((), ())),
            ).lower(params, specs["tokens"], specs["cache"], specs["pos"]).compile()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(sum(v["bytes"] for v in coll.values())),
        "coll": coll,
    }


def extrapolate_cost(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    (cfg1, l1), (cfg2, l2) = _depth_variants(cfg)
    m1 = _lower_for_cost(cfg1, shape_name, multi_pod)
    m2 = _lower_for_cost(cfg2, shape_name, multi_pod)
    L = cfg.n_layers
    out = {}
    for key in ("flops", "bytes", "coll_bytes"):
        slope = (m2[key] - m1[key]) / (l2 - l1)
        out[key + "_extrap"] = m1[key] + slope * (L - l1)
    # per-kind collective extrapolation
    kinds = set(m1["coll"]) | set(m2["coll"])
    out["coll_extrap"] = {}
    for kd in kinds:
        b1 = m1["coll"].get(kd, {"bytes": 0})["bytes"]
        b2 = m2["coll"].get(kd, {"bytes": 0})["bytes"]
        out["coll_extrap"][kd] = b1 + (b2 - b1) / (l2 - l1) * (L - l1)
    out["depths"] = (l1, l2)
    out["raw"] = {"l1": m1, "l2": m2}
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--extrap-multi", action="store_true",
                    help="also run cost extrapolation on the multi-pod mesh")
    args = ap.parse_args()

    from repro.models.registry import ARCH_IDS

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    failures = []
    for arch in archs:
        cells = shape_cells(arch) if args.shape == "all" else [args.shape]
        for shape in cells:
            meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                fp = outdir / f"{tag}.json"
                if fp.exists():
                    print(f"[skip] {tag}")
                    continue
                print(f"[dryrun] {tag}", flush=True)
                try:
                    res = lower_cell(arch, shape, mp)
                    if not mp or args.extrap_multi:
                        # roofline table is single-pod; extrapolate there
                        res["extrapolated"] = extrapolate_cost(arch, shape, mp)
                    fp.write_text(json.dumps(res, indent=1))
                except Exception as e:  # a failure here is a bug in the system
                    failures.append((tag, repr(e)))
                    print(f"[FAIL] {tag}: {e!r}", file=sys.stderr, flush=True)
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e[:200])
        sys.exit(1)
    print("\nall cells OK")


if __name__ == "__main__":
    main()
