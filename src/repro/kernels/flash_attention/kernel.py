"""Pallas TPU flash attention (blocked online softmax, causal + GQA).

Grid: (batch, heads, q_blocks, kv_blocks) — kv innermost so the f32
accumulators live in VMEM scratch across kv iterations.  Causal blocks
entirely above the diagonal are skipped (no FLOPs, no loads).

Block sizes default to (block_q=256, block_k=256) with head_dim padded to
the 128-lane MXU requirement by construction (all assigned archs use
head_dim in {64, 80, 128}; 80 pads to 128 transparently via BlockSpec).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, causal: bool, scale: float, block_q: int, block_k: int, nk: int,
    q_offset: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # skip kv blocks entirely above the causal diagonal
    run = True
    if causal:
        # bottom-right alignment: query row i attends keys <= i + q_offset
        run = ki * block_k <= qi * block_q + block_q - 1 + q_offset

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (bq, bk)
        if causal:
            qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, s.shape, 0
            )
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *, causal: bool = True, block_q: int = 256, block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """q: (b, sq, h, d); k/v: (b, sk, kv, d), h % kv == 0 -> (b, sq, h, d)."""
    b, sq, h, d = q.shape
    _, sk, kvh, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    # causal with sq > sk would leave fully-masked query rows (undefined)
    assert not causal or sq <= sk, (sq, sk)
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = d ** -0.5

    qT = jnp.swapaxes(q, 1, 2)  # (b, h, sq, d)
    kT = jnp.swapaxes(k, 1, 2)  # (b, kv, sk, d)
    vT = jnp.swapaxes(v, 1, 2)

    grid = (b, h, nq, nk)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k, nk=nk, q_offset=sk - sq,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0),
            ),
            pl.BlockSpec(
                (1, 1, block_k, d),
                lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            # f32 VMEM accumulators persisted across the kv grid dimension
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qT, kT, vT)
    return jnp.swapaxes(out, 1, 2)
