"""jit'd public op: flash attention with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.flash_attention import kernel, ref


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, block_q=256, block_k=256):
    if dispatch.use_pallas() and q.shape[1] % min(block_q, q.shape[1]) == 0:
        return kernel.flash_attention(
            q, k, v, causal=causal, block_q=block_q, block_k=block_k,
            interpret=dispatch.interpret(),
        )
    return ref.attention_ref(q, k, v, causal=causal)
