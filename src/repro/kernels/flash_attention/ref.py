"""Pure-jnp oracle for blocked causal/GQA flash attention."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """q: (b, sq, h, d); k/v: (b, sk, kv, d) with h % kv == 0 -> (b, sq, h, d)."""
    b, sq, h, d = q.shape
    kv = k.shape[2]
    if kv != h:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if causal:
        sk = k.shape[1]
        mask = jnp.arange(sq)[:, None] + (sk - sq) >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
