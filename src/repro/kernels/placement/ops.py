"""jax ``engine="jax"`` placement backend: fused window greedy.

``greedy_window`` runs one arrival window's whole greedy placement — all
ordering heuristics at once — as a jit-compiled ``lax.scan`` over the
window's tasks, vmapped across heuristics.  Each scan step scores the
full candidate fleet as one fused vector pass over the SoA engine's
carry registers and commits via a first-min argmin, reproducing
``_greedy_soa``'s float sequences double for double:

- The per-step objective is *recomputed* from carried registers
  (``e_base``/``nl``/term registers + the frozen run basis) instead of
  selectively refreshed; the two are bitwise-identical lane by lane
  (multiplication commutes bitwise and the per-element op order matches
  both the SoA miss pass and its scalar refresh paths — see ``ref.py``).
- Run memoization is emulated with host-precomputed ``new_run`` flags:
  on a run boundary the basis scalars (``const`` sums, the transfer
  baseline) refresh — using :func:`ref.pairwise_sum` so the in-scan sum
  matches ``np.sum``'s association bitwise — and stay frozen within the
  run, exactly like the SoA engine's memo basis.
- Disabled term registers (carbon/lookahead/fairness/warm) enter as
  zeros with zero weights; ``+0.0`` is bitwise-inert here, so one traced
  program covers every register combination — no per-flag recompiles.

Shapes are padded: endpoints and cores to power-of-two buckets (lanes to
a 128 multiple under the Pallas backend), tasks and input signatures to
power-of-two buckets, so a campaign compiles at most ``log2`` variants
per axis.  ``x64`` is scoped to every placement entry point (the whole
parity contract is float64) without flipping the process-global flag —
sibling kernels trace float32 and must keep doing so in the same
process.  Pad endpoint lanes carry all-zero slots with ``first=inf`` and
``alive=False`` (finite scores, masked to ``+inf`` before the argmin),
so no ``inf - inf`` NaN can poison a decision.
"""
from __future__ import annotations

import time

import jax
from jax.experimental import enable_x64

import functools

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.kernels import dispatch
from repro.kernels.placement import kernel as _kernel
from repro.kernels.placement import ref as _ref

#: JIT compile accounting: ``greedy_window`` times the first call of each
#: (shape, backend) signature — compile + one execution — so benchmark
#: harnesses can report compile cost separately and keep warm percentiles
#: clean of first-flush compiles.  Cumulative; reset with
#: :func:`reset_compile_stats`.
COMPILE_STATS = {"compiles": 0, "seconds": 0.0}

_seen_signatures: set[tuple] = set()


def reset_compile_stats() -> None:
    COMPILE_STATS["compiles"] = 0
    COMPILE_STATS["seconds"] = 0.0
    _seen_signatures.clear()


def bucket_pow2(n: int, minimum: int = 1) -> int:
    """Smallest power of two >= max(n, minimum)."""
    b = max(int(minimum), 1)
    n = max(int(n), 1)
    while b < n:
        b <<= 1
    return b


def lane_bucket(n_ep: int) -> int:
    """Padded endpoint-lane count: power-of-two bucket, widened to a
    128-lane multiple when the Pallas score kernel is active (its tile)."""
    if dispatch.placement_use_pallas():
        return ((max(n_ep, 1) + 127) // 128) * 128
    return bucket_pow2(n_ep)


def score_fleet(e_base, nl, g_base, lk, fw, wt, alive, c_cur,
                idle_on_sum, a1, b1, g1, w_idle_on):
    """Standalone fused score+argmin over one candidate fleet.

    Dispatches on :func:`repro.kernels.dispatch.placement_backend`:
    ``ref`` (NumPy oracle), ``xla`` (pure jnp), or ``pallas`` /
    ``pallas_interpret`` (tiled kernel).  Returns ``(obj, argmin)`` with
    ``obj`` over the true (unpadded) fleet.  The in-scan twin of this op
    is traced inside :func:`greedy_window`; this entry point exists for
    tests and for scoring outside a jit context.
    """
    be = dispatch.placement_backend()
    if be != "ref":
        # the parity contract is float64: scope x64 to this call instead
        # of flipping the process-global flag (other kernels trace f32)
        with enable_x64():
            return _score_fleet_jax(
                e_base, nl, g_base, lk, fw, wt, alive, c_cur,
                idle_on_sum, a1, b1, g1, w_idle_on, be,
            )
    return _ref.score_fleet(
            np.asarray(e_base, dtype=np.float64),
            np.asarray(nl, dtype=np.float64),
            np.asarray(g_base, dtype=np.float64),
            np.asarray(lk, dtype=np.float64),
            np.asarray(fw, dtype=np.float64),
            np.asarray(wt, dtype=np.float64),
            np.asarray(alive, dtype=bool),
            float(c_cur), float(idle_on_sum), float(a1), float(b1),
            float(g1), float(w_idle_on),
        )


def _score_fleet_jax(e_base, nl, g_base, lk, fw, wt, alive, c_cur,
                     idle_on_sum, a1, b1, g1, w_idle_on, be):
    n = len(e_base)
    if be in ("pallas", "pallas_interpret"):
        lanes = ((n + 127) // 128) * 128
        pad = lanes - n

        def p(v, fill=0.0):
            return jnp.pad(jnp.asarray(v, dtype=jnp.float64), (0, pad),
                           constant_values=fill)

        scalars = jnp.array(
            [c_cur, idle_on_sum, a1, b1, g1, w_idle_on], dtype=jnp.float64
        )
        alive_f = p(jnp.asarray(alive, dtype=jnp.float64))
        obj, _, idx = _kernel.score_fleet(
            scalars, p(e_base), p(nl), p(g_base), p(lk), p(fw), p(wt),
            alive_f, interpret=(be == "pallas_interpret"),
        )
        return np.asarray(obj)[:n], int(idx)
    obj = _score_lanes(
        jnp.asarray(e_base, dtype=jnp.float64),
        jnp.asarray(nl, dtype=jnp.float64),
        jnp.asarray(g_base, dtype=jnp.float64),
        jnp.asarray(lk, dtype=jnp.float64),
        jnp.asarray(fw, dtype=jnp.float64),
        jnp.asarray(wt, dtype=jnp.float64),
        jnp.asarray(alive, dtype=bool),
        c_cur, idle_on_sum, a1, b1, g1, w_idle_on,
    )
    return np.asarray(obj), int(jnp.argmin(obj))


def _score_lanes(e_base, nl, g_base, lk, fw, wt, alive, c_cur,
                 idle_on_sum, a1, b1, g1, w_idle_on):
    """The fused objective, pure jnp — op order mirrors ``ref.score_fleet``."""
    c2 = jnp.maximum(nl, c_cur)
    e_s = idle_on_sum * c2 + e_base
    obj = a1 * e_s + b1 * c2
    obj = obj + g1 * (w_idle_on * c2 + g_base)
    obj = obj + lk
    obj = obj + fw
    obj = obj + wt
    return jnp.where(alive, obj, jnp.inf)


@functools.partial(jax.jit, static_argnames=("n_ep", "use_kernel",
                                             "interpret"))
def _greedy_scan(consts, init, xs, *, n_ep, use_kernel, interpret):
    """vmapped-over-heuristics scan; see ``greedy_window`` for the layout.

    ``n_ep`` (the *true* fleet size) is static: the run-basis scalars are
    summed over exactly the first ``n_ep`` lanes with numpy's pairwise
    association, unrolled at trace time.
    """
    sc = consts["scalars"]
    a1, b1, g1 = sc["a1"], sc["b1"], sc["g1"]
    idle_on_sum, w_idle_on = sc["idle_on_sum"], sc["w_idle_on"]
    lam_b1, lam_a1 = sc["lam_b1"], sc["lam_a1"]
    alpha, sf1, sf2 = sc["alpha"], sc["sf1"], sc["sf2"]
    f_beta, f_mu = sc["f_beta"], sc["f_mu"]
    idle_bt, su_bt, qd = consts["idle_bt"], consts["su_bt"], consts["qd"]
    rates, wt = consts["rates"], consts["wt"]
    alive_m = consts["alive"]
    rt_tab, en_tab = consts["rt_tab"], consts["en_tab"]
    fen_tab, frt_tab = consts["fen_tab"], consts["frt_tab"]
    add_tab, hv_tab = consts["add_tab"], consts["hv_tab"]

    def step(carry, x):
        # per-endpoint registers ride stacked ((6, E) commit-updated, (5, E)
        # run-basis) so the commit is two column scatters / two column
        # gathers instead of ~20 per-register dynamic ops — storage layout
        # only, every double is the one the unstacked carry would hold
        (base_regs, slots, run_regs, staged, c_cur, tj, c_sum_b, tj_b,
         cg_sum_b) = carry
        mins, first, last, dyn, const, const_g = base_regs
        sig = x["sig"]
        st_row = staged[sig]
        # per-task (E,) rows are gathered from small constant tables
        # instead of streamed as (H, T, E) xs — same doubles, a fraction
        # of the memory traffic on deep windows
        ti = x["ti"]
        add_row = add_tab[sig]
        hv_row = hv_tab[x["hv_id"]]
        rt_row, en_row = rt_tab[ti], en_tab[ti]
        ready_s = x["ready_s"]
        shared_s = x["shared_s"]
        eff_add = jnp.where(st_row, 0.0, add_row)
        eff_ready = jnp.where(st_row, 0.0, ready_s) + qd
        nb = x["nb"]

        # ---- full vectorized pass (the SoA miss pass, op for op);
        # selected into the carry only on run boundaries -------------------
        c_sum_f = _ref.pairwise_sum(const, n_ep)
        cg_sum_f = _ref.pairwise_sum(const_g, n_ep)
        static = c_sum_f - const
        static_g = cg_sum_f - const_g
        start = jnp.maximum(mins, eff_ready)
        start = jnp.maximum(start, nb)   # bitwise no-op when nb <= 0
        end = start + rt_row
        nf = jnp.minimum(first, start)
        nl = jnp.maximum(last, end)
        nd = dyn + en_row
        span = (nl - nf) * idle_bt + su_bt
        e_base_f = static + nd
        e_base_f = e_base_f + span
        e_base_f = e_base_f + eff_add
        e_base_f = e_base_f + tj
        g_base_f = (span + nd) * rates + static_g
        lk_c1 = lam_b1 * x["u_tw"]
        lk_c2 = lam_a1 * x["u_oj"]
        lk_f = end * lk_c1 + hv_row * lk_c2
        dj = fen_tab[ti] - en_row
        fjv = jnp.where(dj <= 0.0, 0.0, dj * x["u_fd"])
        ds = frt_tab[ti] - rt_row
        fsv = jnp.where(ds <= 0.0, 0.0, ds * x["u_fd"])
        fjv = fjv * alpha / sf1
        fsv = fsv * f_beta / sf2
        fw_f = (fjv + fsv) * f_mu

        new_run = x["new_run"]
        run_regs = jnp.where(
            new_run,
            jnp.stack([e_base_f, nl, g_base_f, lk_f, fw_f]),
            run_regs,
        )
        e_base, nl_r, g_base_r, lk_r, fw_r = run_regs
        c_sum_b = jnp.where(new_run, c_sum_f, c_sum_b)
        cg_sum_b = jnp.where(new_run, cg_sum_f, cg_sum_b)
        tj_b = jnp.where(new_run, tj, tj_b)

        # ---- fused score + first-min argmin ------------------------------
        if use_kernel:
            scalars = jnp.stack(
                [c_cur, idle_on_sum, a1, b1, g1, w_idle_on]
            )
            alive_f = alive_m.astype(jnp.float64)
            _, _, ei = _kernel.score_fleet(
                scalars, e_base, nl_r, g_base_r, lk_r, fw_r, wt, alive_f,
                interpret=interpret,
            )
        else:
            obj = _score_lanes(e_base, nl_r, g_base_r, lk_r, fw_r, wt,
                               alive_m, c_cur, idle_on_sum, a1, b1, g1,
                               w_idle_on)
            ei = jnp.argmin(obj)

        # ---- commit: the SoA scalar commit, with a refresh of the
        # committed lane against the frozen run basis.  Every scatter
        # value is gated on ``valid`` (pad steps write the old value back
        # bitwise) — an O(1) guard per scatter instead of a full
        # carry-tree where-select, whose O(E*C) slots copy per step
        # dominated the scan on deep windows ------------------------------
        valid = x["valid"]

        def sel(new_v, old_v):
            return jnp.where(valid, new_v, old_v)

        ready_e = eff_ready[ei]
        tj2 = sel(tj + eff_add[ei], tj)
        staged_e2 = st_row[ei] | shared_s
        staged2 = staged.at[sig, ei].set(sel(staged_e2, st_row[ei]))
        bcol = base_regs[:, ei]       # one gather for all six registers
        mins_e, first_e, last_e, dyn_e, const_e, const_g_e = bcol
        start_v = jnp.maximum(mins_e, ready_e)
        start_v = jnp.maximum(start_v, nb)
        end_v = start_v + rt_row[ei]
        nf_v = jnp.minimum(start_v, first_e)
        nl_v = jnp.maximum(end_v, last_e)
        nd_v = dyn_e + en_row[ei]
        row = slots[ei]
        k = jnp.argmin(row)           # first min slot, like list.index(min)
        row2 = row.at[k].set(end_v)
        m2 = jnp.min(row2)
        slots2 = slots.at[ei, k].set(sel(end_v, row[k]))
        c_e = (nl_v - nf_v) * idle_bt[ei] + su_bt[ei] + nd_v
        cg_e = rates[ei] * c_e
        base_regs2 = base_regs.at[:, ei].set(
            sel(jnp.stack([m2, nf_v, nl_v, nd_v, c_e, cg_e]), bcol)
        )
        ready2 = jnp.where(staged_e2, 0.0, ready_s) + qd[ei]
        s2 = jnp.maximum(m2, ready2)
        s2 = jnp.maximum(s2, nb)
        e2 = s2 + rt_row[ei]
        nf2 = jnp.minimum(s2, nf_v)
        nl2 = jnp.maximum(e2, nl_v)
        e_b = (c_sum_b - c_e) + (nd_v + en_row[ei])
        e_b = e_b + ((nl2 - nf2) * idle_bt[ei] + su_bt[ei])
        e_b = e_b + jnp.where(staged_e2, 0.0, add_row[ei])
        e_b = e_b + tj_b
        g_b = (cg_sum_b - cg_e) + rates[ei] * (
            ((nl2 - nf2) * idle_bt[ei] + su_bt[ei])
            + (nd_v + en_row[ei])
        )
        lk_e = e2 * lk_c1 + hv_row[ei] * lk_c2
        # fw_r (row 4) is per-run, never refreshed by a commit
        run_regs2 = run_regs.at[:4, ei].set(
            sel(jnp.stack([e_b, nl2, g_b, lk_e]), run_regs[:4, ei])
        )
        c_cur2 = sel(jnp.maximum(c_cur, end_v), c_cur)

        carry_out = (
            base_regs2, slots2, run_regs2, staged2, c_cur2,
            tj2, c_sum_b, tj_b, cg_sum_b,
        )
        ys = (ei.astype(jnp.int32), start_v, end_v)
        return carry_out, ys

    def run_one(init_h, xs_h):
        (mins, slots, first, last, dyn, const, const_g, e_base, nl_r,
         g_base_r, lk_r, fw_r, staged, c_cur, tj, c_sum_b, tj_b,
         cg_sum_b) = init_h
        carry0 = (
            jnp.stack([mins, first, last, dyn, const, const_g]), slots,
            jnp.stack([e_base, nl_r, g_base_r, lk_r, fw_r]), staged,
            c_cur, tj, c_sum_b, tj_b, cg_sum_b,
        )
        # unroll a few steps per scan iteration: XLA:CPU's per-iteration
        # dispatch overhead dominates on deep windows, and unrolling keeps
        # the op sequence (hence every double) identical
        carry_f, ys = lax.scan(step, carry0, xs_h, unroll=4)
        b, slots_f, r, staged_f, c_cur_f, tj_f, csb, tjb, cgb = carry_f
        return (
            b[0], slots_f, b[1], b[2], b[3], b[4], b[5],
            r[0], r[1], r[2], r[3], r[4], staged_f, c_cur_f, tj_f,
            csb, tjb, cgb,
        ), ys

    return jax.vmap(run_one)(init, xs)


def greedy_window(n_ep: int, consts: dict, init: dict, xs: dict):
    """Run the fused greedy over one window for every ordering heuristic.

    ``consts``: per-fleet constants (padded lanes; see ``_greedy_scan``),
    plus the per-input-signature transfer table.  ``init``: carry seeds
    with a leading heuristic axis.  ``xs``: per-task streams, shape
    ``(H, T_pad, ...)``, permuted per heuristic.  Returns
    ``(final_carry, (ei, start, end))`` as numpy arrays, and maintains
    :data:`COMPILE_STATS` (first call per shape signature is counted —
    and timed — as a compile).
    """
    use_kernel = dispatch.placement_use_pallas()
    interpret = dispatch.placement_interpret()
    sig = (
        n_ep, use_kernel, interpret,
        tuple(sorted((k, np.shape(v)) for k, v in xs.items())),
        tuple(sorted((k, np.shape(v)) for k, v in init.items())),
        tuple(sorted((k, np.shape(v)) for k, v in consts.items()
                     if k != "scalars")),
    )
    t0 = None
    if sig not in _seen_signatures:
        _seen_signatures.add(sig)
        t0 = time.perf_counter()
    # x64 is scoped to the placement scan (trace + execute) rather than
    # enabled process-wide: the parity contract is float64, but sibling
    # kernels in this package trace float32 and must stay untouched
    with enable_x64():
        jxs = jax.tree_util.tree_map(jnp.asarray, xs)
        jinit = jax.tree_util.tree_map(jnp.asarray, init)
        jconsts = jax.tree_util.tree_map(jnp.asarray, consts)
        carry, ys = _greedy_scan(jconsts, _as_tuple_carry(jinit), jxs,
                                 n_ep=n_ep, use_kernel=use_kernel,
                                 interpret=interpret)
        carry = jax.block_until_ready(carry)
    if t0 is not None:
        COMPILE_STATS["compiles"] += 1
        COMPILE_STATS["seconds"] += time.perf_counter() - t0
    names = ("mins", "slots", "first", "last", "dyn", "const", "const_g",
             "e_base", "nl_r", "g_base_r", "lk_r", "fw_r", "staged",
             "c_cur", "tj", "c_sum_b", "tj_b", "cg_sum_b")
    out = {k: np.asarray(v) for k, v in zip(names, carry)}
    ei, start, end = (np.asarray(v) for v in ys)
    return out, (ei, start, end)


def _as_tuple_carry(init: dict):
    return (
        init["mins"], init["slots"], init["first"], init["last"],
        init["dyn"], init["const"], init["const_g"], init["e_base"],
        init["nl_r"], init["g_base_r"], init["lk_r"], init["fw_r"],
        init["staged"], init["c_cur"], init["tj"], init["c_sum_b"],
        init["tj_b"], init["cg_sum_b"],
    )
