"""NumPy oracle for the fused placement score+argmin pass.

This is ``_greedy_soa``'s candidate-scoring math extracted into a pure
function of the engine's carry registers.  The SoA engine keeps a cached
objective vector and refreshes entries selectively (committed lane, or
every lane when C_max advances); this oracle instead *recomputes* every
lane's score from the same registers.  The two are bitwise-identical
lane by lane: multiplication commutes bitwise, and the per-element
operation order here matches both the SoA miss pass and its scalar
refresh paths exactly (see the parity notes in
``docs/ARCHITECTURE.md``).  The jax engine's scan step, the Pallas
kernel, and the XLA path all implement this op sequence.

Every term register is always present; disabled registers are passed as
zeros with zero scalar weights.  Adding ``+0.0`` is bitwise-inert here
(no score is ever ``-0.0``: the makespan term ``b1*c2`` is ``>= +0.0``),
so the single unconditional op sequence reproduces the SoA engine's
conditional term adds double for double.
"""
from __future__ import annotations

import numpy as np


def pairwise_sum(x, n: int, base: int = 0):
    """``np.sum(x[base:base+n])`` with numpy's exact pairwise association.

    The SoA engine freezes its run basis with ``float(const.sum())``; the
    jax engine recomputes that scalar inside the scan, so it must
    reproduce numpy's summation tree bitwise.  This replicates numpy's
    ``pairwise_sum`` (sequential under 8 elements, 8-way unrolled blocks
    to 128, halved recursion above) and works on any indexable — numpy
    arrays here, traced jax values when called at trace time with static
    ``n``.  Asserted bitwise-equal to ``np.sum`` in
    ``tests/test_kernels.py``.
    """
    if n < 8:
        res = 0.0
        for i in range(n):
            res = res + x[base + i]
        return res
    if n <= 128:
        r = [x[base + j] for j in range(8)]
        i = 8
        while i < n - (n % 8):
            for j in range(8):
                r[j] = r[j] + x[base + i + j]
            i += 8
        res = ((r[0] + r[1]) + (r[2] + r[3])) + ((r[4] + r[5]) + (r[6] + r[7]))
        while i < n:
            res = res + x[base + i]
            i += 1
        return res
    n2 = n // 2
    n2 -= n2 % 8
    return pairwise_sum(x, n2, base) + pairwise_sum(x, n - n2, base + n2)


def score_fleet(
    e_base: np.ndarray,
    nl: np.ndarray,
    g_base: np.ndarray,
    lk: np.ndarray,
    fw: np.ndarray,
    wt: np.ndarray,
    alive: np.ndarray,
    c_cur: float,
    idle_on_sum: float,
    a1: float,
    b1: float,
    g1: float,
    w_idle_on: float,
) -> tuple[np.ndarray, int]:
    """Score every candidate endpoint, return ``(obj, first-min argmin)``.

    Registers (all per-endpoint vectors over the padded fleet):

    - ``e_base``: candidate energy minus its C_max-dependent terms —
      ``static + nd + span_term (+ transfer add) + tj_basis``
    - ``nl``: candidate new last-end (the makespan the lane would post)
    - ``g_base``/``lk``/``fw``/``wt``: carbon, lookahead, fairness-tax
      and warm-pool term registers (zeros when the run is term-free)
    - ``alive``: liveness mask — dead and pad lanes score ``+inf``

    Scalars: ``c_cur`` the committed C_max, ``idle_on_sum`` the total
    always-on idle draw, ``a1 = alpha/SF1``, ``b1 = (1-alpha)/SF2``,
    ``g1 = gamma/SF3``, ``w_idle_on`` the rate-weighted always-on idle
    draw.
    """
    c2 = np.maximum(nl, c_cur)
    e_s = idle_on_sum * c2 + e_base
    obj = a1 * e_s + b1 * c2
    obj = obj + g1 * (w_idle_on * c2 + g_base)
    obj = obj + lk
    obj = obj + fw
    obj = obj + wt
    obj = np.where(alive, obj, np.inf)
    return obj, int(np.argmin(obj))
