"""Pallas tiled score+argmin for the placement pass.

One grid step scores a 128-lane tile of candidate endpoints with the
fused objective (same op order as ``ref.score_fleet``) and folds it into
a running first-min (value, index) pair held in the scalar outputs —
TPU grids execute sequentially, so the strict ``<`` update preserves
``np.argmin``'s first-occurrence tie-breaking across tiles, and the
masked-iota reduction preserves it within a tile.  ``interpret=True``
emulates the kernel on CPU (the CI path; see
``dispatch.placement_backend``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE_TILE = 128


def _score_kernel(sc_ref, e_base_ref, nl_ref, g_base_ref, lk_ref, fw_ref,
                  wt_ref, alive_ref, obj_ref, min_ref, idx_ref):
    j = pl.program_id(0)
    c_cur = sc_ref[0]
    idle_on_sum = sc_ref[1]
    a1 = sc_ref[2]
    b1 = sc_ref[3]
    g1 = sc_ref[4]
    w_idle_on = sc_ref[5]
    nl = nl_ref[...]
    c2 = jnp.maximum(nl, c_cur)
    e_s = idle_on_sum * c2 + e_base_ref[...]
    obj = a1 * e_s + b1 * c2
    obj = obj + g1 * (w_idle_on * c2 + g_base_ref[...])
    obj = obj + lk_ref[...]
    obj = obj + fw_ref[...]
    obj = obj + wt_ref[...]
    obj = jnp.where(alive_ref[...] != 0.0, obj, jnp.inf)
    obj_ref[...] = obj
    t_min = jnp.min(obj)
    # first-min within the tile: smallest lane index attaining the min
    lanes = jax.lax.broadcasted_iota(jnp.int32, obj.shape, 1)
    t_idx = jnp.min(jnp.where(obj == t_min, lanes, LANE_TILE))
    t_idx = t_idx + j * LANE_TILE

    @pl.when(j == 0)
    def _init():
        min_ref[0, 0] = t_min
        idx_ref[0, 0] = t_idx

    @pl.when(j > 0)
    def _fold():
        better = t_min < min_ref[0, 0]   # strict: earlier tile wins ties
        min_ref[0, 0] = jnp.where(better, t_min, min_ref[0, 0])
        idx_ref[0, 0] = jnp.where(better, t_idx, idx_ref[0, 0])


def score_fleet(scalars, e_base, nl, g_base, lk, fw, wt, alive_f, *,
                interpret: bool = False):
    """Tiled fused score+argmin over ``lanes`` candidate endpoints.

    ``scalars`` is the packed ``(6,)`` float64 vector ``[c_cur,
    idle_on_sum, a1, b1, g1, w_idle_on]`` (SMEM); the registers are
    ``(lanes,)`` float64 with ``lanes`` a multiple of 128; ``alive_f`` is
    the liveness mask as floats (0.0 = dead/pad).  Returns ``(obj,
    min_val, min_idx)`` — ``obj`` shaped ``(lanes,)``, the scalars 0-d.
    """
    (lanes,) = e_base.shape
    assert lanes % LANE_TILE == 0, lanes
    grid = (lanes // LANE_TILE,)

    def vec():
        return pl.BlockSpec((1, LANE_TILE), lambda j: (0, j))

    def scalar_out():
        return pl.BlockSpec((1, 1), lambda j: (0, 0))

    obj, mn, idx = pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            vec(), vec(), vec(), vec(), vec(), vec(), vec(),
        ],
        out_specs=(vec(), scalar_out(), scalar_out()),
        out_shape=(
            jax.ShapeDtypeStruct((1, lanes), e_base.dtype),
            jax.ShapeDtypeStruct((1, 1), e_base.dtype),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ),
        interpret=interpret,
    )(scalars, e_base[None, :], nl[None, :], g_base[None, :], lk[None, :],
      fw[None, :], wt[None, :], alive_f[None, :])
    return obj[0], mn[0, 0], idx[0, 0]
