"""Fused placement scoring for the ``engine="jax"`` greedy backend.

kernel/ref/ops layout matching the repo's other accelerator kernels:

- ``ref.py``    — NumPy oracle of the per-step fused score+argmin pass,
  extracted verbatim from ``_greedy_soa``'s vector math so parity with
  the SoA engine is structural, not coincidental.
- ``kernel.py`` — Pallas tiled score+argmin (interpret-mode on CPU).
- ``ops.py``    — backend dispatch plus the jit-compiled ``lax.scan``
  greedy over a whole arrival window.
"""
