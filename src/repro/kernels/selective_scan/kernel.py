"""Pallas TPU Mamba1 selective scan.

Grid: (batch, d_blocks, seq_chunks) — seq innermost; the SSM state
(block_d, n) stays resident in VMEM scratch across chunks, so HBM traffic
is exactly one read of (x, dt, B, C) and one write of y per token: the
kernel is memory-bound by design and the block_d tile keeps the VPU lanes
full (block_d x n elementwise ops per token).

The recurrence over tokens inside a chunk uses an in-VMEM fori_loop —
the TPU adaptation of the CUDA kernel's per-thread scan (no shared-memory
banking analogue needed; VMEM is software-managed).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(
    x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, y_ref,
    h_ref,
    *, chunk: int, n: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    A = A_ref[...].astype(jnp.float32)          # (bd, n)
    D = D_ref[...].astype(jnp.float32)          # (1, bd)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)          # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)        # (bd,)
        Bt = B_ref[0, t, :].astype(jnp.float32)          # (n,)
        Ct = C_ref[0, t, :].astype(jnp.float32)          # (n,)
        a = jnp.exp(dtt[:, None] * A)                    # (bd, n)
        h = a * h + (dtt * xt)[:, None] * Bt[None, :]
        y = jnp.sum(h * Ct[None, :], axis=1) + D[0] * xt
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        return h

    h_ref[...] = jax.lax.fori_loop(0, chunk, step, h_ref[...])


def selective_scan(
    x, dt, A, B, C, D, *, block_d: int = 512, chunk: int = 128,
    interpret: bool = False,
):
    """x/dt: (b, L, d); A: (d, n); B/C: (b, L, n); D: (d,) -> (b, L, d)."""
    b, L, d = x.shape
    n = A.shape[1]
    block_d = min(block_d, d)
    chunk = min(chunk, L)
    assert d % block_d == 0 and L % chunk == 0
    nd, nc = d // block_d, L // chunk
    D2 = D.reshape(1, d)

    grid = (b, nd, nc)
    out = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk, n=n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((block_d, n), lambda bi, di, ci: (di, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
            pl.BlockSpec((1, block_d), lambda bi, di, ci: (0, di)),
        ],
        out_specs=pl.BlockSpec(
            (1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)
        ),
        out_shape=jax.ShapeDtypeStruct((b, L, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D2)
    return out
