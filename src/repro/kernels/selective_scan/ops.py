"""jit'd public op: Mamba1 selective scan with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.selective_scan import kernel, ref


@functools.partial(jax.jit, static_argnames=("block_d", "chunk"))
def selective_scan(x, dt, A, B, C, D, *, block_d=512, chunk=128):
    ok = x.shape[2] % min(block_d, x.shape[2]) == 0 and \
         x.shape[1] % min(chunk, x.shape[1]) == 0
    if dispatch.use_pallas() and ok:
        return kernel.selective_scan(
            x, dt, A, B, C, D, block_d=block_d, chunk=chunk,
            interpret=dispatch.interpret(),
        )
    return ref.selective_scan_ref(x, dt, A, B, C, D)
