"""Oracle for the Mamba1 selective scan (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x, dt, A, B, C, D):
    """x/dt: (b, L, d); A: (d, n); B/C: (b, L, n); D: (d,) -> (b, L, d).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;  y_t = (h_t C_t) + D x_t
    """
    b, L, d = x.shape
    n = A.shape[1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    a = jnp.exp(dtf[..., None] * A)                       # (b, L, d, n)
    bu = (dtf * xf)[..., None] * B.astype(jnp.float32)[:, :, None, :]

    def step(h, t):
        h = a[:, t] * h + bu[:, t]
        y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32)[:, t])
        return h, y

    h0 = jnp.zeros((b, d, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0, jnp.arange(L))
    y = jnp.moveaxis(ys, 0, 1) + xf * D
    return y.astype(x.dtype)
