"""jit'd public op: Mamba2 SSD with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.ssd import kernel, ref


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_op(xdt, loga, B, C, *, chunk=128):
    """Pre-weighted form: xdt = x*dt, loga = dt*A (see kernel docstring)."""
    if dispatch.use_pallas() and xdt.shape[1] % min(chunk, xdt.shape[1]) == 0:
        return kernel.ssd(xdt, loga, B, C, chunk=chunk,
                          interpret=dispatch.interpret())
    return ref.ssd_preweighted_ref(xdt, loga, B, C)
