"""Pallas TPU Mamba2 SSD (state-space dual), chunked matmul form.

Grid: (batch, heads, chunks) — chunks innermost; the inter-chunk state
S (n x hd) is VMEM-resident across chunks.  Per chunk everything is a
matmul sized to the MXU (chunk=128, n=64, hd=64..128):

    G   = C B^T                (Q x Q, via n contraction)
    M   = G * exp(cum_t-cum_s) * tril
    y   = M @ (dt*x)  +  (C * exp(cum)) @ S
    S   = exp(total) S + B^T @ (dt*x * exp(total-cum))

This is the TPU-native rethink of the Mamba2 CUDA kernel: instead of
warp-level scans, the recurrence is blocked into MXU matmuls with a tiny
sequential chunk loop — the part a systolic array cannot parallelize.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, lg_ref, B_ref, C_ref, y_ref, state_ref,
    S_ref,
    *, chunk: int, nc: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        S_ref[...] = jnp.zeros_like(S_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (Q, hd) already dt-weighted
    lg = lg_ref[0, 0].astype(jnp.float32)        # (1, Q) log-decay per token
    B = B_ref[0].astype(jnp.float32)             # (Q, n)
    C = C_ref[0].astype(jnp.float32)             # (Q, n)

    cum = jnp.cumsum(lg[0])                      # (Q,)
    total = cum[-1]
    # intra-chunk quadratic term
    G = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (Q, Q)
    L = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, G.shape, 0)
        >= jax.lax.broadcasted_iota(jnp.int32, G.shape, 1)
    )
    M = jnp.where(tri, G * jnp.exp(L), 0.0)
    y = jax.lax.dot_general(
        M, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (Q, hd)
    # inter-chunk: entering state contribution
    Cw = C * jnp.exp(cum)[:, None]
    y = y + jax.lax.dot_general(
        Cw, S_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # state update
    xw = x * jnp.exp(total - cum)[:, None]
    S_new = jax.lax.dot_general(
        B, xw, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                             # (n, hd)
    S_ref[...] = jnp.exp(total) * S_ref[...] + S_new
    y_ref[0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _done():
        state_ref[0, 0] = S_ref[...]


def ssd(
    xdt, loga, B, C, *, chunk: int = 128, interpret: bool = False,
):
    """xdt: (b, L, nh, hd) = dt-weighted inputs; loga: (b, L, nh) = dt*A;
    B/C: (b, L, n).  Returns (y (b, L, nh, hd), state (b, nh, n, hd))."""
    b, L, nh, hd = xdt.shape
    n = B.shape[-1]
    chunk = min(chunk, L)
    assert L % chunk == 0
    nc = L // chunk

    xT = jnp.transpose(xdt, (0, 2, 1, 3))        # (b, nh, L, hd)
    lgT = jnp.transpose(loga, (0, 2, 1))[:, :, None, :]  # (b, nh, 1, L)

    grid = (b, nh, nc)
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk, nc=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bi, hi, ci: (bi, hi, 0, ci)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, hd), lambda bi, hi, ci: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, n, hd), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, L, hd), xdt.dtype),
            jax.ShapeDtypeStruct((b, nh, n, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, hd), jnp.float32)],
        interpret=interpret,
    )(xT, lgT, B, C)
    return jnp.transpose(y, (0, 2, 1, 3)), state
