"""Oracle for the Mamba2 SSD recurrence (sequential, per-head scalar A)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xh, dt, A_log, B, C, h0=None):
    """xh: (b, L, nh, hd); dt: (b, L, nh); A_log: (nh,); B/C: (b, L, n)
    -> (y (b, L, nh, hd), final_state (b, nh, n, hd)).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t . h_t
    """
    b, L, nh, hd = xh.shape
    n = B.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))
    dtf = jax.nn.softplus(dt.astype(jnp.float32))
    xf = xh.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t] * A)                           # (b, nh)
        bx = jnp.einsum(
            "bn,bhd->bhnd", B[:, t].astype(jnp.float32),
            xf[:, t] * dtf[:, t][..., None],
        )
        h = a[..., None, None] * h + bx
        y = jnp.einsum("bn,bhnd->bhd", C[:, t].astype(jnp.float32), h)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, nh, n, hd), jnp.float32)
    h, ys = jax.lax.scan(step, h0, jnp.arange(L))
    return jnp.moveaxis(ys, 0, 1).astype(xh.dtype), h


def ssd_preweighted_ref(xdt, loga, B, C, h0=None):
    """Sequential oracle on the pre-weighted inputs the kernel consumes:
    xdt = x*dt, loga = dt*A (both already softplus'd/negated upstream)."""
    b, L, nh, hd = xdt.shape
    n = B.shape[-1]
    xf = xdt.astype(jnp.float32)
    lg = loga.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(lg[:, t])                                 # (b, nh)
        bx = jnp.einsum("bn,bhd->bhnd", B[:, t].astype(jnp.float32), xf[:, t])
        h = a[..., None, None] * h + bx
        y = jnp.einsum("bn,bhnd->bhd", C[:, t].astype(jnp.float32), h)
        return h, y

    if h0 is None:
        h0 = jnp.zeros((b, nh, n, hd), jnp.float32)
    h, ys = jax.lax.scan(step, h0, jnp.arange(L))
    return jnp.moveaxis(ys, 0, 1).astype(xdt.dtype), h
