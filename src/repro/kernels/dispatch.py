"""Kernel backend selection.

"pallas"            — real TPU lowering (target hardware)
"pallas_interpret"  — kernel body emulated on CPU (tests)
"xla"               — chunked pure-jnp path (CPU dry-run / fallback)

Default: pallas on TPU, xla elsewhere; override with REPRO_KERNEL_BACKEND.
"""
from __future__ import annotations

import os

import jax

_VALID = ("pallas", "pallas_interpret", "xla")


def backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        assert env in _VALID, env
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def use_pallas() -> bool:
    return backend() in ("pallas", "pallas_interpret")


def interpret() -> bool:
    return backend() == "pallas_interpret"
