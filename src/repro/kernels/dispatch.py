"""Kernel backend selection.

"pallas"            — real TPU lowering (target hardware)
"pallas_interpret"  — kernel body emulated on CPU (tests)
"xla"               — chunked pure-jnp path (CPU dry-run / fallback)

Default: pallas on TPU, xla elsewhere; override with REPRO_KERNEL_BACKEND.
"""
from __future__ import annotations

import os

import jax

_VALID = ("pallas", "pallas_interpret", "xla")


def backend() -> str:
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        assert env in _VALID, env
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def use_pallas() -> bool:
    return backend() in ("pallas", "pallas_interpret")


def interpret() -> bool:
    return backend() == "pallas_interpret"


_P_VALID = ("pallas", "pallas_interpret", "xla", "ref")


def placement_backend() -> str:
    """Backend for the placement score+argmin pass.

    Honors REPRO_PLACEMENT_BACKEND=pallas|xla|ref; "pallas" off-TPU is
    coerced to interpret mode so the kernel path stays testable in CI.
    Falls back to the generic kernel backend() default when unset.
    """
    env = os.environ.get("REPRO_PLACEMENT_BACKEND")
    if env:
        assert env in _P_VALID, env
        if env == "pallas" and jax.default_backend() != "tpu":
            return "pallas_interpret"
        return env
    return backend()


def placement_use_pallas() -> bool:
    return placement_backend() in ("pallas", "pallas_interpret")


def placement_interpret() -> bool:
    return placement_backend() == "pallas_interpret"
