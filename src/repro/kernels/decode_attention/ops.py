"""jit'd public op: flash-decode with backend dispatch."""
from __future__ import annotations

import functools

import jax

from repro.kernels import dispatch
from repro.kernels.decode_attention import kernel, ref


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k_cache, v_cache, cache_len, *, block_k=512):
    if dispatch.use_pallas() and k_cache.shape[1] % min(block_k, k_cache.shape[1]) == 0:
        return kernel.decode_attention(
            q, k_cache, v_cache, cache_len, block_k=block_k,
            interpret=dispatch.interpret(),
        )
    return ref.decode_attention_ref(q, k_cache, v_cache, cache_len)
