"""Oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """q: (b, 1, h, d); caches: (b, S, kv, d); cache_len: (b,) -> (b, 1, h, d)."""
    b, _, h, d = q.shape
    S, kv = k_cache.shape[1], k_cache.shape[2]
    if kv != h:
        k_cache = jnp.repeat(k_cache, h // kv, axis=2)
        v_cache = jnp.repeat(v_cache, h // kv, axis=2)
    s = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * (d ** -0.5)
    valid = jnp.arange(S)[None, None, None, :] < cache_len[:, None, None, None]
    s = jnp.where(valid, s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.sum(p, -1, keepdims=True)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v_cache.astype(jnp.float32))
    return o.astype(q.dtype)
