"""Pallas TPU flash-decode: one query token vs a long KV cache.

Grid: (batch, kv_heads, kv_blocks) — the group of q heads sharing a kv
head (GQA) is processed together as a (group, d) q tile, so the MXU sees
a (group x d) @ (d x block_k) matmul per block.  Online-softmax partials
(m, l, acc) live in VMEM scratch across kv blocks; `cache_len` masks the
unwritten cache tail (scalar-prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # scalar-prefetch (b,)
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale: float, block_k: int, nk: int,
):
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cache_len = len_ref[bi]
    # skip blocks entirely beyond the live cache
    @pl.when(ki * block_k < cache_len)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (group, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (group, block_k)
        kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < cache_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, -1, keepdims=True)
        m_ref[...] = m_new
        v = v_ref[0, 0].astype(jnp.float32)             # (block_k, d)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(
            o_ref.dtype
        )


def decode_attention(
    q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, cache_len: jax.Array,
    *, block_k: int = 512, interpret: bool = False,
) -> jax.Array:
    """q: (b, 1, h, d); caches: (b, S, kv, d); cache_len: (b,) int32."""
    b, one, h, d = q.shape
    S, kvh = k_cache.shape[1], k_cache.shape[2]
    group = h // kvh
    block_k = min(block_k, S)
    assert S % block_k == 0
    nk = S // block_k
    scale = d ** -0.5

    qg = q.reshape(b, kvh, group, d)                     # (b, kv, group, d)
    kT = jnp.swapaxes(k_cache, 1, 2)                     # (b, kv, S, d)
    vT = jnp.swapaxes(v_cache, 1, 2)

    grid = (b, kvh, nk)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_k=block_k, nk=nk),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, group, d), lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, ki, lens: (bi, hi, ki, 0)),
                pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, ki, lens: (bi, hi, ki, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, group, d), lambda bi, hi, ki, lens: (bi, hi, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((group, d), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, d), q.dtype),
        interpret=interpret,
    )(cache_len.astype(jnp.int32), qg, kT, vT)
    return out.reshape(b, 1, h, d)
