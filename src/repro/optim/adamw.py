"""AdamW with global-norm clipping and schedules — pure pytree functions.

Moments inherit the parameter shardings (FSDP+TP), so optimizer state is
fully sharded; the update is elementwise and needs no extra collectives
beyond the gradient reduction itself.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> dict:
    zeros = lambda t: jax.tree.map(jnp.zeros_like, t)  # noqa: E731
    return {"m": zeros(params), "v": zeros(params), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    cfg: AdamWConfig, grads: Any, params: Any, state: dict
) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
        return p - lr * delta, m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": lr,
    }
