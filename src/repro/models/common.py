"""Shared model substrate: param specs, logical axes, norms, RoPE.

Params are described by ParamSpec trees so the same definition serves
three uses: real initialization (tests/examples), abstract shapes
(multi-pod dry-run, no allocation), and logical-axis shardings.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Logical axes.  Physical mapping lives in distributed/sharding.py.
# ---------------------------------------------------------------------------
# "layers"   — stacked scan dimension (never sharded)
# "embed"    — d_model rows of weight matrices      -> FSDP ("data")
# "mlp"      — ffn hidden                           -> TP ("model")
# "heads"    — query heads                          -> TP ("model") when divisible
# "kv_heads" — kv heads (GQA, usually < TP degree)  -> replicated
# "vocab"    — embedding/vocab rows                 -> TP ("model")
# "experts"  — MoE experts                          -> EP ("model")
# "ssm_inner"— mamba inner channels                 -> TP ("model")
# "state"    — ssm state dim                        -> replicated
# scalars / norm scales: ("embed",) or (None,)


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    init: str = "normal"          # normal | zeros | ones | embed | small
    dtype: Any = jnp.float32
    fan_in: int | None = None     # overrides scale for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


ParamTree = Mapping[str, Any]  # nested dict of ParamSpec / arrays


def _init_leaf(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    fan_in = spec.fan_in
    if fan_in is None:
        fan_in = spec.shape[0] if len(spec.shape) > 1 else spec.shape[-1]
    if spec.init == "embed":
        scale = 1.0
    elif spec.init == "small":
        scale = 0.02
    else:
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs: ParamTree, rng: jax.Array) -> ParamTree:
    """Materialize a ParamSpec tree into real arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_params(specs: ParamTree) -> ParamTree:
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def param_axes(specs: ParamTree) -> ParamTree:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_count(specs: ParamTree) -> int:
    return sum(
        int(np.prod(s.shape))
        for s in jax.tree.leaves(specs, is_leaf=is_spec)
    )


def stacked(spec: ParamSpec, n_layers: int) -> ParamSpec:
    """Stack a per-layer spec along a leading scan axis."""
    return dataclasses.replace(
        spec, shape=(n_layers,) + spec.shape, axes=("layers",) + spec.axes
    )


def stack_tree(tree: ParamTree, n_layers: int) -> ParamTree:
    return jax.tree.map(lambda s: stacked(s, n_layers), tree, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Numerics
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # (..., seq, half)
    angles = angles[..., :, None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_pos_emb(seq: int, dim: int, offset=0) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    half = dim // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def cross_entropy_loss(
    logits: jax.Array, labels: jax.Array, true_vocab: int
) -> jax.Array:
    """Mean CE over positions with label >= 0. Padded vocab entries masked.

    Written to stay efficient when the vocab dim is sharded: the label
    logit is extracted with an iota-mask-sum (partial + all-reduce under
    GSPMD) instead of take_along_axis (which would gather full logits).
    """
    logits = logits.astype(jnp.float32)
    vocab = logits.shape[-1]
    viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    if vocab > true_vocab:
        logits = jnp.where(viota < true_vocab, logits, -1e30)
    m = jnp.max(logits, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
    sel = viota == jnp.clip(labels, 0)[..., None]
    ll = jnp.sum(jnp.where(sel, logits, 0.0), axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
