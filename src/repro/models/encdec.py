"""Encoder-decoder LM (whisper-tiny backbone).

The audio frontend is a STUB per the assignment: `input_specs()` provides
precomputed frame embeddings (b, enc_len, d_model) — the conv feature
extractor is out of scope. LayerNorm + GELU + sinusoidal positions, MHA.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models import attention as attn
from repro.models.common import (
    ParamSpec,
    cross_entropy_loss,
    layer_norm,
    pad_vocab,
    sinusoidal_pos_emb,
    stack_tree,
)
from repro.models.config import ArchConfig
from repro.models.mlp import mlp_apply, mlp_specs

COMPUTE_DTYPE = jnp.bfloat16


def _ur(shd):
    return True if shd.unroll_inner else 1


def _ln_spec(d):
    return {
        "scale": ParamSpec((d,), ("embed",), init="ones"),
        "bias": ParamSpec((d,), ("embed",), init="zeros"),
    }


def _ln(p, x, eps):
    return layer_norm(x, p["scale"], p["bias"], eps)


def _enc_layer_specs(cfg: ArchConfig):
    return {
        "ln1": _ln_spec(cfg.d_model),
        "attn": attn.attn_specs(cfg),
        "ln2": _ln_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def _dec_layer_specs(cfg: ArchConfig):
    return {
        "ln1": _ln_spec(cfg.d_model),
        "self_attn": attn.attn_specs(cfg),
        "ln2": _ln_spec(cfg.d_model),
        "cross_attn": attn.attn_specs(cfg),
        "ln3": _ln_spec(cfg.d_model),
        "mlp": mlp_specs(cfg),
    }


def encdec_specs(cfg: ArchConfig) -> dict[str, Any]:
    vp = pad_vocab(cfg.vocab)
    d = cfg.d_model
    return {
        "embed": ParamSpec((vp, d), ("vocab", "embed"), init="embed"),
        "enc_layers": stack_tree(_enc_layer_specs(cfg), cfg.n_enc_layers),
        "enc_ln": _ln_spec(d),
        "dec_layers": stack_tree(_dec_layer_specs(cfg), cfg.n_layers),
        "dec_ln": _ln_spec(d),
        "unembed": ParamSpec((d, vp), ("embed", "vocab")),
    }


def encode(params, cfg: ArchConfig, frames, *, shd: ShardCtx = NULL_CTX):
    """frames: (b, enc_len, d) stub embeddings -> (b, enc_len, d)."""
    x = frames.astype(COMPUTE_DTYPE)
    x = x + sinusoidal_pos_emb(x.shape[1], cfg.d_model).astype(COMPUTE_DTYPE)
    x = shd.act(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])[None, :]

    def body(x, pl):
        h = _ln(pl["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(pl["attn"], h, cfg, positions, shd, use_rope=False)
        o = attn.chunked_attention(q, k, v, causal=False, shd=shd)
        x = x + attn.attn_output(pl["attn"], o, x.dtype)
        h = _ln(pl["ln2"], x, cfg.norm_eps)
        x = x + mlp_apply(pl["mlp"], h, cfg, shd)
        return x, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=_ur(shd))
    return _ln(params["enc_ln"], x, cfg.norm_eps)


def _cross_kv(pl_cross, enc_out, cfg, shd):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, pl_cross["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, pl_cross["wv"].astype(enc_out.dtype))
    return k, v


def decode_train(params, cfg: ArchConfig, tokens, enc_out, *, shd: ShardCtx = NULL_CTX):
    """Teacher-forced decoder forward -> logits (b, s, vp)."""
    b, s = tokens.shape
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    x = x + sinusoidal_pos_emb(s, cfg.d_model).astype(COMPUTE_DTYPE)
    x = shd.act(x, "batch", "act_seq", None)
    positions = jnp.arange(s)[None, :]

    def body(x, pl):
        h = _ln(pl["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(pl["self_attn"], h, cfg, positions, shd, use_rope=False)
        o = attn.chunked_attention(q, k, v, causal=True, shd=shd)
        x = x + attn.attn_output(pl["self_attn"], o, x.dtype)
        h = _ln(pl["ln2"], x, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, pl["cross_attn"]["wq"].astype(h.dtype))
        ck, cv = _cross_kv(pl["cross_attn"], enc_out, cfg, shd)
        o = attn.chunked_attention(q, ck, cv, causal=False, shd=shd)
        x = x + attn.attn_output(pl["cross_attn"], o, x.dtype)
        h = _ln(pl["ln3"], x, cfg.norm_eps)
        x = x + mlp_apply(pl["mlp"], h, cfg, shd)
        return x, None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=_ur(shd))
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    return shd.act(logits, "batch", None, "vocab")


def encdec_loss(params, cfg: ArchConfig, batch, *, shd: ShardCtx = NULL_CTX, remat=True):
    enc_out = encode(params, cfg, batch["frames"], shd=shd)
    logits = decode_train(params, cfg, batch["tokens"], enc_out, shd=shd)
    loss = cross_entropy_loss(logits, batch["labels"], cfg.vocab)
    return loss, {"ce": loss, "aux": jnp.zeros(())}


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=COMPUTE_DTYPE):
    L, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((L, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((L, batch, max_len, kv, hd), dtype),
        "cross_k": jnp.zeros((L, batch, cfg.enc_len, kv, hd), dtype),
        "cross_v": jnp.zeros((L, batch, cfg.enc_len, kv, hd), dtype),
    }


def cache_axes(cfg: ArchConfig):
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    cx = ("layers", "batch", None, "kv_heads", None)
    return {"k": ax, "v": ax, "cross_k": cx, "cross_v": cx}


def encdec_prefill(
    params, cfg: ArchConfig, frames, tokens, *, shd: ShardCtx = NULL_CTX
):
    """Encode audio + teacher-forced prompt; returns (last logits, cache)."""
    enc_out = encode(params, cfg, frames, shd=shd)
    b, s = tokens.shape
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    x = x + sinusoidal_pos_emb(s, cfg.d_model).astype(COMPUTE_DTYPE)
    positions = jnp.arange(s)[None, :]

    def body(x, pl):
        h = _ln(pl["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(pl["self_attn"], h, cfg, positions, shd, use_rope=False)
        o = attn.chunked_attention(q, k, v, causal=True, shd=shd)
        x = x + attn.attn_output(pl["self_attn"], o, x.dtype)
        h = _ln(pl["ln2"], x, cfg.norm_eps)
        q2 = jnp.einsum("bsd,dhk->bshk", h, pl["cross_attn"]["wq"].astype(h.dtype))
        ck, cv = _cross_kv(pl["cross_attn"], enc_out, cfg, shd)
        o = attn.chunked_attention(q2, ck, cv, causal=False, shd=shd)
        x = x + attn.attn_output(pl["cross_attn"], o, x.dtype)
        h = _ln(pl["ln3"], x, cfg.norm_eps)
        x = x + mlp_apply(pl["mlp"], h, cfg, shd)
        return x, (k.astype(COMPUTE_DTYPE), v.astype(COMPUTE_DTYPE),
                   ck.astype(COMPUTE_DTYPE), cv.astype(COMPUTE_DTYPE))

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["dec_layers"], unroll=_ur(shd))
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs}
    axes = cache_axes(cfg)
    cache = {k: shd.act(v, *axes[k]) for k, v in cache.items()}
    return logits[:, -1], cache


def encdec_decode_step(
    params, cfg: ArchConfig, tokens, cache, pos, *, shd: ShardCtx = NULL_CTX
):
    pos = jnp.asarray(pos, jnp.int32)
    b = tokens.shape[0]
    x = params["embed"].astype(COMPUTE_DTYPE)[tokens]
    x = x + sinusoidal_pos_emb(1, cfg.d_model, offset=pos).astype(COMPUTE_DTYPE)

    def body(x, layer):
        pl, kc, vc, ck, cv = layer
        h = _ln(pl["ln1"], x, cfg.norm_eps)
        q, k, v = attn.project_qkv(
            pl["self_attn"], h, cfg, pos[None, None], shd, use_rope=False
        )
        from repro.models.lm import _cache_update

        kc = _cache_update(kc, k, pos)
        vc = _cache_update(vc, v, pos)
        cache_len = jnp.full((b,), pos + 1, jnp.int32)
        o = attn.decode_attention(q, kc, vc, cache_len, shd=shd)
        x = x + attn.attn_output(pl["self_attn"], o, x.dtype)
        h = _ln(pl["ln2"], x, cfg.norm_eps)
        q2 = jnp.einsum("bsd,dhk->bshk", h, pl["cross_attn"]["wq"].astype(h.dtype))
        enc_len = jnp.full((b,), ck.shape[1], jnp.int32)
        o = attn.decode_attention(q2, ck, cv, enc_len, shd=shd)
        x = x + attn.attn_output(pl["cross_attn"], o, x.dtype)
        h = _ln(pl["ln3"], x, cfg.norm_eps)
        x = x + mlp_apply(pl["mlp"], h, cfg, shd)
        return x, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]), unroll=_ur(shd)
    )
    x = _ln(params["dec_ln"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    new_cache = dict(cache, k=ks, v=vs)
    return logits, new_cache
