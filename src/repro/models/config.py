"""Architecture configuration."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba) ---
    ssm_state: int = 0
    d_inner: int = 0                  # 0 -> 2 * d_model
    conv_width: int = 4
    dt_rank: int = 0                  # 0 -> ceil(d_model / 16)
    ssm_head_dim: int = 64            # mamba2 only
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0        # apply shared attn block every N layers
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_len: int = 1500               # fixed audio-frame count (stub frontend)
    # --- vlm ---
    n_vision_tokens: int = 0
    # --- flags ---
    qk_norm: bool = False
    rope_theta: float = 1e4
    pos_emb: str = "rope"             # rope | sinusoidal
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    mlp_act: str = "swiglu"           # swiglu | gelu
    attention: str = "full"           # full | none
    norm_eps: float = 1e-5
    sub_quadratic: bool = False       # eligible for long_500k
    # --- distribution hints ---
    attn_strategy: str = "auto"       # auto | head_tp | seq_cp

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def inner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dtrank(self) -> int:
        return self.dt_rank or -(-self.d_model // 16)

    def resolve_attn_strategy(self, model_axis: int) -> str:
        if self.attn_strategy != "auto":
            return self.attn_strategy
        if self.n_heads and self.n_heads % model_axis == 0:
            return "head_tp"
        return "seq_cp"

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 2 if self.shared_attn_every == 0 else 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            d_inner=128 if self.inner else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            dt_rank=8 if self.ssm_state else 0,
            ssm_head_dim=16,
            shared_attn_every=2 if self.shared_attn_every else 0,
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_len=32 if self.n_enc_layers else 1500,
            n_vision_tokens=min(self.n_vision_tokens, 8),
        )
