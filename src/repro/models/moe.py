"""Top-k MoE with capacity-based einsum dispatch (GShard-style) + EP.

Dispatch/combine use one-hot einsums whose contraction length is bounded
by grouping the sequence into `group_size` chunks: dispatch FLOPs scale as
2*cf*group_size/(3*d_ff) of the expert FLOPs, so the group size is a
first-class performance knob (see EXPERIMENTS.md §Perf).
Experts are sharded over the "model" mesh axis (expert parallelism).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.common import ParamSpec
from repro.models.config import ArchConfig


def moe_specs(cfg: ArchConfig):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("embed", None), init="small"),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "wo": ParamSpec((e, f, d), ("experts", "mlp", "embed"), fan_in=f),
    }


def default_group_size(cfg: ArchConfig, seq: int) -> int:
    """Pick a dispatch group so dispatch+combine ~<=30% of expert FLOPs."""
    target = max(128, int(0.45 * cfg.d_ff / cfg.capacity_factor))
    g = 1
    while g * 2 <= min(seq, target):
        g *= 2
    return g


def moe_apply(
    p,
    x: jax.Array,
    cfg: ArchConfig,
    shd: ShardCtx = NULL_CTX,
    group_size: int | None = None,
):
    """x: (b, s, d) -> (out (b, s, d), aux_loss scalar)."""
    b, s, d = x.shape
    e, k, cf = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    dt = x.dtype

    g = group_size or default_group_size(cfg, s)
    g = min(g, s)
    if s % g != 0:
        g = s
    ng = s // g
    cap = max(k, int(-(-cf * g * k // e)))

    xg = x.reshape(b * ng, g, d)
    logits = jnp.einsum(
        "tsd,de->tse", xg, p["router"].astype(dt), preferred_element_type=jnp.float32
    )
    gates = jax.nn.softmax(logits, axis=-1)  # (T, g, e) fp32
    topv, topi = jax.lax.top_k(gates, k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch): e * sum_e mean(frac) * mean(prob)
    me = jnp.mean(gates, axis=(0, 1))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(topi, e, dtype=jnp.float32), axis=2), axis=(0, 1)
    ) / k
    aux = e * jnp.sum(me * ce)

    dispatch = jnp.zeros((b * ng, g, e, cap), dt)
    combine = jnp.zeros((b * ng, g, e, cap), jnp.float32)
    counts = jnp.zeros((b * ng, 1, e), jnp.int32)
    for i in range(k):
        mask = jax.nn.one_hot(topi[..., i], e, dtype=jnp.int32)  # (T, g, e)
        pos = jnp.cumsum(mask, axis=1) - 1 + counts
        keep = (pos < cap) & (mask > 0)
        counts = counts + jnp.sum(mask, axis=1, keepdims=True)
        oh = jax.nn.one_hot(jnp.where(keep, pos, -1), cap, dtype=jnp.float32)
        d_i = mask[..., None].astype(jnp.float32) * oh
        dispatch = dispatch + d_i.astype(dt)
        combine = combine + d_i * topv[..., i][..., None, None]

    dispatch = shd.act(dispatch, "batch", None, "experts", None)
    xe = jnp.einsum("tsec,tsd->etcd", dispatch, xg)  # (e, T, cap, d)
    xe = shd.act(xe, "experts", "batch", None, None)
    hi = jnp.einsum("etcd,edf->etcf", xe, p["wi"].astype(dt))
    hg = jnp.einsum("etcd,edf->etcf", xe, p["wg"].astype(dt))
    ye = jnp.einsum("etcf,efd->etcd", jax.nn.silu(hg) * hi, p["wo"].astype(dt))
    ye = shd.act(ye, "experts", "batch", None, None)
    out = jnp.einsum("tsec,etcd->tsd", combine.astype(dt), ye)
    return out.reshape(b, s, d), aux
