"""GQA attention: params, chunked train/prefill path, decode path.

The chunked XLA path (scan over query chunks, online softmax handled by
full-row softmax per chunk) mirrors the memory behaviour of the Pallas
flash kernel so dry-run memory analysis is realistic.  kernels/ops.py
switches to the Pallas kernels on TPU.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.common import ParamSpec, rms_norm, rope
from repro.models.config import ArchConfig

NEG_INF = -1e30


def attn_specs(cfg: ArchConfig, cross: bool = False) -> dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    specs = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((h, hd, d), ("heads", None, "embed"), fan_in=h * hd),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec((hd,), (None,), init="zeros")
        specs["k_norm"] = ParamSpec((hd,), (None,), init="zeros")
    return specs


def project_qkv(p, x, cfg: ArchConfig, positions, shd: ShardCtx, use_rope=True):
    """x: (b, s, d) -> q (b, s, h, hd), k/v (b, s, kv, hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # "seq" vs "heads" mapping is strategy-dependent (ShardCtx.overrides):
    #   head_tp: heads->model, seq replicated (Megatron TP)
    #   seq_cp : seq->model, heads replicated (context parallelism)
    q = shd.act(q, "batch", "seq", "heads", None)
    k = shd.act(k, "batch", "seq", "kv_heads", None)
    v = shd.act(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(b, s, kv, hd) -> (b, s, h, hd) by repeating kv groups."""
    kv = k.shape[-2]
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=-2)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    q_chunk: int = 512,
    shd: ShardCtx = NULL_CTX,
) -> jax.Array:
    """Memory-bounded attention: scan over q chunks, full kv rows per chunk.

    q: (b, sq, h, hd); k/v: (b, sk, h_kv, hd).  Returns (b, sq, h, hd).
    """
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    from repro.kernels import dispatch

    if dispatch.use_pallas() and shd.mesh is None and sq % 128 == 0 and sk % 128 == 0:
        from repro.kernels.flash_attention.ops import flash_attention

        out = flash_attention(q, k, v, causal=causal)
        return shd.act(out, "batch", "seq", "heads", None)
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = hd ** -0.5
    q_chunk = min(q_chunk, sq)
    n_chunks = sq // q_chunk if sq % q_chunk == 0 else 1
    if sq % q_chunk != 0:
        q_chunk = sq

    kT = jnp.swapaxes(k, 1, 2)  # (b, h, sk, hd)
    vT = jnp.swapaxes(v, 1, 2)

    def one_chunk(ci, qc):
        # qc: (b, q_chunk, h, hd)
        qcT = jnp.swapaxes(qc, 1, 2)  # (b, h, qc, hd)
        scores = jnp.einsum(
            "bhqk,bhsk->bhqs", qcT, kT, preferred_element_type=jnp.float32
        ) * scale
        if causal:
            qpos = ci * q_chunk + jnp.arange(q_chunk)
            kpos = jnp.arange(sk)
            mask = qpos[:, None] >= kpos[None, :]
            scores = jnp.where(mask[None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhqs,bhsk->bhqk", probs, vT)
        return jnp.swapaxes(out, 1, 2)  # (b, qc, h, hd)

    if n_chunks == 1:
        return one_chunk(0, q)

    qs = q.reshape(b, n_chunks, q_chunk, h, hd)

    def body(_, ci):
        return None, one_chunk(ci, qs[:, ci])

    _, outs = jax.lax.scan(
        body, None, jnp.arange(n_chunks),
        unroll=n_chunks if (shd.unroll_inner and n_chunks <= 64) else 1,
    )
    # outs: (n_chunks, b, q_chunk, h, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)
    return shd.act(out, "batch", "seq", "heads", None)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cache_len: jax.Array,
    *,
    shd: ShardCtx = NULL_CTX,
) -> jax.Array:
    """Single-token attention against a (b, S, kv, hd) cache.

    The cache is annotated kv_seq->model; XLA turns the softmax reductions
    into small all-reduces (flash-decode pattern).
    """
    b, one, h, hd = q.shape
    k_cache = shd.act(k_cache, "batch", "kv_seq", "kv_heads", None)
    v_cache = shd.act(v_cache, "batch", "kv_seq", "kv_heads", None)
    from repro.kernels import dispatch

    if dispatch.use_pallas() and shd.mesh is None and k_cache.shape[1] % 128 == 0:
        from repro.kernels.decode_attention.ops import decode_attention as dec_op

        return dec_op(q, k_cache, v_cache, cache_len)
    # Grouped GQA einsum — NO kv expansion (a jnp.repeat here would move
    # group x the cache bytes through HBM every step; §Perf iteration 5).
    kv = k_cache.shape[2]
    g = h // kv
    S = k_cache.shape[1]
    qg = q.reshape(b, kv, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    # Pin the flash-decode dataflow: scores/probs stay sharded along the
    # cache seq dim (partial softmax per shard + small all-reduces).  Without
    # this, head-TP weights back-propagate a heads sharding into the einsums
    # and GSPMD reshards the whole cache seq->heads every layer (§Perf it.3).
    scores = shd.act(scores, "batch", None, None, "kv_seq")
    valid = jnp.arange(S)[None, None, None, :] < cache_len[:, None, None, None]
    scores = jnp.where(valid, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    probs = shd.act(probs, "batch", None, None, "kv_seq")
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    out = shd.act(out, "batch", None, None, None)
    return out.reshape(b, 1, h, hd)


def attn_output(p, o: jax.Array, x_dtype) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x_dtype))
