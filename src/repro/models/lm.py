"""Decoder-only LM assembly for dense / MoE / SSM / hybrid / VLM families.

One scan-over-layers spine; per-family layer bodies.  Entry points:
  lm_loss        — training loss (next-token CE + MoE aux)
  lm_prefill     — forward over a prompt -> (last logits, caches)
  lm_decode_step — single-token step against caches

Zamba2 (hybrid) groups the layer scan as (n_apps, every) so the shared
attention block runs exactly once per group (no wasted compute in HLO).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    ParamSpec,
    cross_entropy_loss,
    pad_vocab,
    rms_norm,
    sinusoidal_pos_emb,
    stack_tree,
)
from repro.models.config import ArchConfig
from repro.models.mlp import mlp_apply, mlp_specs

COMPUTE_DTYPE = jnp.bfloat16


def _ur(shd):
    """Layer scans unroll during dry-run cost lowering (see dryrun.py)."""
    return True if shd.unroll_inner else 1


def _remat_policy(shd):
    if shd.remat_policy == "dots":
        return jax.checkpoint_policies.checkpoint_dots
    return jax.checkpoint_policies.nothing_saveable


def _norm_spec(d):
    return ParamSpec((d,), ("embed",), init="zeros")


def _layer_specs(cfg: ArchConfig) -> dict[str, Any]:
    d = cfg.d_model
    if cfg.family in ("dense", "vlm"):
        return {
            "ln1": _norm_spec(d),
            "attn": attn.attn_specs(cfg),
            "ln2": _norm_spec(d),
            "mlp": mlp_specs(cfg),
        }
    if cfg.family == "moe":
        return {
            "ln1": _norm_spec(d),
            "attn": attn.attn_specs(cfg),
            "ln2": _norm_spec(d),
            "moe": moe_mod.moe_specs(cfg),
        }
    if cfg.family == "ssm":
        return {"ln": _norm_spec(d), "mamba": ssm_mod.mamba1_specs(cfg)}
    if cfg.family == "hybrid":
        return {"ln": _norm_spec(d), "mamba": ssm_mod.mamba2_specs(cfg)}
    raise ValueError(cfg.family)


def _wide_cfg(cfg: ArchConfig) -> ArchConfig:
    """Zamba2 shared block sees concat(h, x0): attention input width 2d."""
    return dataclasses.replace(cfg, d_model=2 * cfg.d_model, head_dim=cfg.hd)


def _shared_block_specs(cfg: ArchConfig) -> dict[str, Any]:
    d = cfg.d_model
    specs = attn.attn_specs(_wide_cfg(cfg))
    # output projection maps back to d (residual width), not 2d
    specs["wo"] = ParamSpec(
        (cfg.n_heads, cfg.hd, d), ("heads", None, "embed"), fan_in=cfg.n_heads * cfg.hd
    )
    return {
        "ln1": ParamSpec((2 * d,), ("embed",), init="zeros"),
        "attn": specs,
        "ln2": _norm_spec(d),
        "mlp": mlp_specs(cfg),
    }


def lm_specs(cfg: ArchConfig) -> dict[str, Any]:
    d = cfg.d_model
    vp = pad_vocab(cfg.vocab)
    specs: dict[str, Any] = {
        "embed": ParamSpec((vp, d), ("vocab", "embed"), init="embed"),
        "final_norm": _norm_spec(d),
        "unembed": ParamSpec((d, vp), ("embed", "vocab")),
        "layers": stack_tree(_layer_specs(cfg), cfg.n_layers),
    }
    if cfg.shared_attn_every:
        assert cfg.n_layers % cfg.shared_attn_every == 0
        specs["shared"] = _shared_block_specs(cfg)
    return specs


def n_shared_apps(cfg: ArchConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every if cfg.shared_attn_every else 0


# ---------------------------------------------------------------------------
# layer bodies (full-sequence). Each returns (x, aux, cache_entry_or_None)
# ---------------------------------------------------------------------------


def _attn_block(pl, x, cfg, positions, shd, collect):
    h = rms_norm(x, pl["ln1"], cfg.norm_eps)
    q, k, v = attn.project_qkv(pl["attn"], h, cfg, positions, shd)
    o = attn.chunked_attention(q, k, v, causal=True, shd=shd)
    x = x + attn.attn_output(pl["attn"], o, x.dtype)
    return x, ((k.astype(COMPUTE_DTYPE), v.astype(COMPUTE_DTYPE)) if collect else None)


def _dense_layer(pl, x, cfg, positions, shd, collect):
    x, kv = _attn_block(pl, x, cfg, positions, shd, collect)
    h = rms_norm(x, pl["ln2"], cfg.norm_eps)
    x = x + mlp_apply(pl["mlp"], h, cfg, shd)
    return x, jnp.zeros((), jnp.float32), kv


def _moe_layer(pl, x, cfg, positions, shd, collect):
    x, kv = _attn_block(pl, x, cfg, positions, shd, collect)
    h = rms_norm(x, pl["ln2"], cfg.norm_eps)
    out, aux = moe_mod.moe_apply(pl["moe"], h, cfg, shd, group_size=shd.moe_group)
    return x + out, aux, kv


def _ssm_layer(pl, x, cfg, positions, shd, collect):
    h = rms_norm(x, pl["ln"], cfg.norm_eps)
    out, state = ssm_mod.mamba1_apply(pl["mamba"], h, cfg, shd, return_cache=collect)
    return x + out, jnp.zeros((), jnp.float32), state


def _hybrid_layer(pl, x, cfg, positions, shd, collect):
    h = rms_norm(x, pl["ln"], cfg.norm_eps)
    out, state = ssm_mod.mamba2_apply(pl["mamba"], h, cfg, shd, return_cache=collect)
    return x + out, jnp.zeros((), jnp.float32), state


_LAYER_FNS = {
    "dense": _dense_layer,
    "vlm": _dense_layer,
    "moe": _moe_layer,
    "ssm": _ssm_layer,
    "hybrid": _hybrid_layer,
}


def _shared_block(ps, x, x0, cfg, positions, shd, collect):
    cat = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(cat, ps["ln1"], cfg.norm_eps)
    q, k, v = attn.project_qkv(ps["attn"], h, _wide_cfg(cfg), positions, shd)
    o = attn.chunked_attention(q, k, v, causal=True, shd=shd)
    x = x + attn.attn_output(ps["attn"], o, x.dtype)
    h2 = rms_norm(x, ps["ln2"], cfg.norm_eps)
    x = x + mlp_apply(ps["mlp"], h2, cfg, shd)
    kv = (k.astype(COMPUTE_DTYPE), v.astype(COMPUTE_DTYPE)) if collect else None
    return x, kv


# ---------------------------------------------------------------------------
# full forward
# ---------------------------------------------------------------------------


def embed_tokens(params, cfg, tokens, shd, vision_embeds=None, pos_offset=0):
    emb = params["embed"].astype(COMPUTE_DTYPE)
    x = emb[tokens]  # (b, s, d)
    if cfg.family == "vlm" and vision_embeds is not None:
        nv = vision_embeds.shape[1]
        x = jnp.concatenate([vision_embeds.astype(COMPUTE_DTYPE), x[:, nv:]], axis=1)
    if cfg.pos_emb == "sinusoidal":
        x = x + sinusoidal_pos_emb(x.shape[1], cfg.d_model, pos_offset).astype(
            COMPUTE_DTYPE
        )
    return shd.act(x, "batch", "act_seq", None)


def _group_layers(layers, n_apps, every):
    return jax.tree.map(
        lambda a: a.reshape((n_apps, every) + a.shape[1:]), layers
    )


def lm_forward(
    params,
    cfg: ArchConfig,
    tokens: jax.Array,
    *,
    shd: ShardCtx = NULL_CTX,
    vision_embeds=None,
    remat: bool = True,
    collect_cache: bool = False,
):
    """Returns (logits, aux, cache_stack_or_None)."""
    b, s = tokens.shape
    positions = jnp.arange(s)[None, :]
    x = embed_tokens(params, cfg, tokens, shd, vision_embeds)
    x0 = x
    layer_fn = _LAYER_FNS[cfg.family]
    every = cfg.shared_attn_every

    def layer_body(carry, pl):
        x, aux = carry
        x, aux_i, entry = layer_fn(pl, x, cfg, positions, shd, collect_cache)
        x = shd.act(x, "batch", "act_seq", None)
        return (x, aux + aux_i), entry

    if remat:
        layer_body = jax.checkpoint(layer_body, policy=_remat_policy(shd))

    carry = (x, jnp.zeros((), jnp.float32))
    shared_kvs = None
    if every:
        grouped = _group_layers(params["layers"], n_shared_apps(cfg), every)

        def group_body(carry, gl):
            x, aux = carry
            x, skv = _shared_block(
                params["shared"], x, x0, cfg, positions, shd, collect_cache
            )
            (x, aux), entries = jax.lax.scan(layer_body, (x, aux), gl, unroll=_ur(shd))
            return (x, aux), (entries, skv)

        if remat:
            group_body = jax.checkpoint(group_body, policy=_remat_policy(shd))
        carry, (entries, shared_kvs) = jax.lax.scan(group_body, carry, grouped, unroll=_ur(shd))
        if collect_cache:
            entries = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), entries
            )
    else:
        carry, entries = jax.lax.scan(layer_body, carry, params["layers"], unroll=_ur(shd))
    x, aux = carry
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    # vocab->model (NOT act_seq): keeps fp32 logits + CE fully vocab-sharded
    logits = shd.act(logits, "batch", None, "vocab")
    cache = (entries, shared_kvs) if collect_cache else None
    return logits, aux, cache


def lm_loss(
    params, cfg: ArchConfig, batch: dict, *, shd: ShardCtx = NULL_CTX, remat=True
):
    logits, aux, _ = lm_forward(
        params,
        cfg,
        batch["tokens"],
        shd=shd,
        vision_embeds=batch.get("vision_embeds"),
        remat=remat,
    )
    loss = cross_entropy_loss(logits, batch["labels"], cfg.vocab)
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving: caches, prefill, decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=COMPUTE_DTYPE):
    L, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    if cfg.family in ("dense", "vlm", "moe"):
        return {
            "k": jnp.zeros((L, batch, max_len, kv, hd), dtype),
            "v": jnp.zeros((L, batch, max_len, kv, hd), dtype),
        }
    if cfg.family == "ssm":
        c = ssm_mod.mamba1_init_cache(cfg, batch, dtype)
        return {k: jnp.zeros((L,) + v.shape, v.dtype) for k, v in c.items()}
    if cfg.family == "hybrid":
        c = ssm_mod.mamba2_init_cache(cfg, batch, dtype)
        base = {k: jnp.zeros((L,) + v.shape, v.dtype) for k, v in c.items()}
        napp = n_shared_apps(cfg)
        base["shared_k"] = jnp.zeros((napp, batch, max_len, kv, hd), dtype)
        base["shared_v"] = jnp.zeros((napp, batch, max_len, kv, hd), dtype)
        return base
    raise ValueError(cfg.family)


def cache_axes(cfg: ArchConfig):
    if cfg.family in ("dense", "vlm", "moe"):
        ax = ("layers", "batch", "kv_seq", "kv_heads", None)
        return {"k": ax, "v": ax}
    if cfg.family == "ssm":
        return {
            "conv": ("layers", "batch", None, "ssm_inner"),
            "h": ("layers", "batch", "ssm_inner", "state"),
        }
    if cfg.family == "hybrid":
        return {
            "conv": ("layers", "batch", None, None),
            "h": ("layers", "batch", "heads", "state", None),
            "shared_k": ("layers", "batch", "kv_seq", "kv_heads", None),
            "shared_v": ("layers", "batch", "kv_seq", "kv_heads", None),
        }
    raise ValueError(cfg.family)


def _cache_update(cache, new, pos):
    """Write one token at `pos` into a (b, S, kv, hd) cache.

    Uses an iota-select, NOT dynamic_update_slice: a dynamic-position
    update into a seq-sharded dim makes GSPMD gather/rewrite the whole
    cache per layer (measured: 2.3 GB/layer on deepseek decode — see
    EXPERIMENTS.md §Perf iteration 2).  The select is local per shard."""
    S = cache.shape[1]
    sel = (jax.lax.broadcasted_iota(jnp.int32, (1, S, 1, 1), 1) == pos)
    return jnp.where(sel, new.astype(cache.dtype), cache)


def _decode_attn(pl_attn, x_norm, cfg_like, kc, vc, pos, shd, qk_cfg):
    q, k, v = attn.project_qkv(pl_attn, x_norm, qk_cfg, pos[None, None], shd)
    kc = _cache_update(kc, k, pos)
    vc = _cache_update(vc, v, pos)
    cache_len = jnp.full((q.shape[0],), pos + 1, jnp.int32)
    o = attn.decode_attention(q, kc, vc, cache_len, shd=shd)
    return attn.attn_output(pl_attn, o, x_norm.dtype), kc, vc


def lm_decode_step(
    params, cfg: ArchConfig, tokens, cache, pos, *, shd: ShardCtx = NULL_CTX
):
    """tokens: (b, 1) int32; pos: scalar int32 -> (logits (b,1,V), new_cache)."""
    pos = jnp.asarray(pos, jnp.int32)
    x = embed_tokens(params, cfg, tokens, shd, pos_offset=pos)
    x0 = x
    every = cfg.shared_attn_every

    if cfg.family in ("dense", "vlm", "moe"):

        def body(x, layer):
            pl, kc, vc = layer
            h = rms_norm(x, pl["ln1"], cfg.norm_eps)
            out, kc, vc = _decode_attn(pl["attn"], h, cfg, kc, vc, pos, shd, cfg)
            x = x + out
            h = rms_norm(x, pl["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                ff, _ = moe_mod.moe_apply(pl["moe"], h, cfg, shd)
            else:
                ff = mlp_apply(pl["mlp"], h, cfg, shd)
            return x + ff, (kc, vc)

        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]), unroll=_ur(shd))
        new_cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":

        def body(x, layer):
            pl, conv, h = layer
            hh = rms_norm(x, pl["ln"], cfg.norm_eps)
            out, c2 = ssm_mod.mamba1_decode_step(
                pl["mamba"], hh, {"conv": conv, "h": h}, cfg, shd
            )
            return x + out, (c2["conv"], c2["h"])

        x, (convs, hs) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["h"]), unroll=_ur(shd)
        )
        new_cache = {"conv": convs, "h": hs}

    elif cfg.family == "hybrid":
        napp = n_shared_apps(cfg)
        grouped = _group_layers(params["layers"], napp, every)
        gconv = jax.tree.map(
            lambda a: a.reshape((napp, every) + a.shape[1:]), cache["conv"]
        )
        gh = jax.tree.map(lambda a: a.reshape((napp, every) + a.shape[1:]), cache["h"])

        def mamba_body(x, layer):
            pl, conv, h = layer
            hh = rms_norm(x, pl["ln"], cfg.norm_eps)
            out, c2 = ssm_mod.mamba2_decode_step(
                pl["mamba"], hh, {"conv": conv, "h": h}, cfg, shd
            )
            return x + out, (c2["conv"], c2["h"])

        def group_body(x, layer):
            gl, conv, h, kc, vc = layer
            cat = jnp.concatenate([x, x0], axis=-1)
            hh = rms_norm(cat, params["shared"]["ln1"], cfg.norm_eps)
            out, kc, vc = _decode_attn(
                params["shared"]["attn"], hh, cfg, kc, vc, pos, shd, _wide_cfg(cfg)
            )
            x = x + out
            h2 = rms_norm(x, params["shared"]["ln2"], cfg.norm_eps)
            x = x + mlp_apply(params["shared"]["mlp"], h2, cfg, shd)
            x, (convs, hs) = jax.lax.scan(mamba_body, x, (gl, conv, h), unroll=_ur(shd))
            return x, (convs, hs, kc, vc)

        x, (convs, hs, sk, sv) = jax.lax.scan(
            group_body, x, (grouped, gconv, gh, cache["shared_k"], cache["shared_v"]),
            unroll=_ur(shd),
        )
        new_cache = {
            "conv": convs.reshape((cfg.n_layers,) + convs.shape[2:]),
            "h": hs.reshape((cfg.n_layers,) + hs.shape[2:]),
            "shared_k": sk,
            "shared_v": sv,
        }
    else:
        raise ValueError(cfg.family)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(x.dtype))
    logits = shd.act(logits, "batch", None, "vocab")
    return logits, new_cache


def lm_prefill(
    params,
    cfg: ArchConfig,
    tokens,
    *,
    shd: ShardCtx = NULL_CTX,
    vision_embeds=None,
    max_len: int | None = None,
):
    """Forward over a prompt; returns (last-position logits, cache at len s)."""
    b, s = tokens.shape
    logits, _, collected = lm_forward(
        params, cfg, tokens, shd=shd, vision_embeds=vision_embeds,
        remat=False, collect_cache=True,
    )
    entries, shared_kvs = collected
    if cfg.family in ("dense", "vlm", "moe"):
        ks, vs = entries
        cache = {"k": ks, "v": vs}
    elif cfg.family == "ssm":
        cache = {"conv": entries["conv"], "h": entries["h"]}
    elif cfg.family == "hybrid":
        sk, sv = shared_kvs
        cache = {
            "conv": entries["conv"],
            "h": entries["h"],
            "shared_k": sk,
            "shared_v": sv,
        }
    else:
        raise ValueError(cfg.family)
    if max_len is not None and max_len > s:
        cache = extend_cache(cfg, cache, max_len)
    axes = cache_axes(cfg)
    cache = {k: shd.act(v, *axes[k]) for k, v in cache.items()}
    return logits[:, -1], cache


def extend_cache(cfg: ArchConfig, cache: dict, max_len: int) -> dict:
    """Pad seq-indexed cache buffers out to max_len (for decode after prefill)."""
    out = {}
    for name, arr in cache.items():
        if name in ("k", "v", "shared_k", "shared_v"):
            pad = max_len - arr.shape[2]
            arr = jnp.pad(arr, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        out[name] = arr
    return out
