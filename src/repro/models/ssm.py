"""State-space blocks: Mamba1 selective scan + Mamba2 SSD (chunked).

XLA paths are chunked so (a) memory stays bounded, (b) FLOPs appear
honestly in HLO (associative scan / matmuls, no opaque while-loop bodies),
mirroring what the Pallas kernels do in VMEM on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.common import ParamSpec, rms_norm
from repro.models.config import ArchConfig

# ---------------------------------------------------------------------------
# Mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------


def mamba1_specs(cfg: ArchConfig):
    d, din, st, r, w = cfg.d_model, cfg.inner, cfg.ssm_state, cfg.dtrank, cfg.conv_width
    return {
        "in_proj": ParamSpec((d, 2 * din), ("embed", "ssm_inner")),
        "conv_w": ParamSpec((din, w), ("ssm_inner", None), init="small"),
        "conv_b": ParamSpec((din,), ("ssm_inner",), init="zeros"),
        "x_proj": ParamSpec((din, r + 2 * st), ("ssm_inner", None)),
        "dt_w": ParamSpec((r, din), (None, "ssm_inner")),
        "dt_b": ParamSpec((din,), ("ssm_inner",), init="small"),
        "A_log": ParamSpec((din, st), ("ssm_inner", "state"), init="small"),
        "D": ParamSpec((din,), ("ssm_inner",), init="ones"),
        "out_proj": ParamSpec((din, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (b, s, c); w: (c, width). state: (b, width-1, c)."""
    width = w.shape[-1]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1]] * w[None, None, :, width - 1 - i]
        for i in range(width)
    )
    new_state = xp[:, -(width - 1) :] if width > 1 else pad
    return out + b, new_state


def _ssm_coeffs(p, xc, cfg: ArchConfig):
    """xc: (b, L, din) -> a, bu (b, L, din, st), C (b, L, st)."""
    r, st = cfg.dtrank, cfg.ssm_state
    dbc = jnp.einsum("blc,cr->blr", xc, p["x_proj"].astype(xc.dtype))
    dt, B, C = jnp.split(dbc, [r, r + st], axis=-1)
    dt = jnp.einsum("blr,rc->blc", dt, p["dt_w"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_b"])  # (b, L, din)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (din, st)
    a = jnp.exp(dt[..., None] * A)  # (b, L, din, st)
    bu = (dt * xc.astype(jnp.float32))[..., None] * B.astype(jnp.float32)[:, :, None, :]
    return a, bu, C


def mamba1_apply(
    p,
    x,
    cfg: ArchConfig,
    shd: ShardCtx = NULL_CTX,
    chunk: int = 128,
    return_cache: bool = False,
):
    """Full-sequence Mamba1 block. x: (b, s, d) -> ((b, s, d), cache|None)."""
    b, s, d = x.shape
    dt_ = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shd.act(xin, "batch", None, "ssm_inner")
    xc, _ = _causal_conv(xin, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_))
    xc = jax.nn.silu(xc)

    from repro.kernels import dispatch

    if dispatch.use_pallas() and shd.mesh is None and not return_cache and s % 128 == 0:
        r, st = cfg.dtrank, cfg.ssm_state
        dbc = jnp.einsum("blc,cr->blr", xc, p["x_proj"].astype(dt_))
        dtv, B, C = jnp.split(dbc, [r, r + st], axis=-1)
        dtv = jnp.einsum("blr,rc->blc", dtv, p["dt_w"].astype(dt_))
        dtv = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_b"])
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        from repro.kernels.selective_scan.ops import selective_scan as scan_op

        y = scan_op(
            xc.astype(jnp.float32), dtv, A,
            B.astype(jnp.float32), C.astype(jnp.float32), p["D"],
        )
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
        y = shd.act(y, "batch", None, "ssm_inner")
        return jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(dt_)), None

    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    xcs = xc.reshape(b, nc, chunk, -1)

    def assoc(left, right):
        al, bl = left
        ar, br = right
        return al * ar, ar * bl + br

    def body(h, xi):
        a, bu, C = _ssm_coeffs(p, xi, cfg)
        a_cum, b_cum = jax.lax.associative_scan(assoc, (a, bu), axis=1)
        h_all = b_cum + a_cum * h[:, None]  # (b, chunk, din, st)
        y = jnp.einsum("blcs,bls->blc", h_all, C.astype(jnp.float32))
        return h_all[:, -1], y

    h0 = jnp.zeros((b, xc.shape[-1], cfg.ssm_state), jnp.float32)
    xcs_t = jnp.moveaxis(xcs, 1, 0)  # (nc, b, chunk, din)
    # cost-lowering unroll capped: the scan body is <1% of layer FLOPs
    # (projections dominate), so leaving long scans rolled costs <1% accuracy
    # but avoids pathological CPU compile times at 32k+ sequence lengths.
    h_final, ys = jax.lax.scan(
        body, h0, xcs_t, unroll=nc if (shd.unroll_inner and nc <= 16) else 1
    )
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, -1)
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    y = shd.act(y, "batch", None, "ssm_inner")
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(dt_))
    if not return_cache:
        return out, None
    w = cfg.conv_width
    cache = {"conv": xin[:, -(w - 1):].astype(dt_), "h": h_final}
    return out, cache


def mamba1_init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.inner), dtype),
        "h": jnp.zeros((batch, cfg.inner, cfg.ssm_state), jnp.float32),
    }


def mamba1_decode_step(p, x, cache, cfg: ArchConfig, shd: ShardCtx = NULL_CTX):
    """x: (b, 1, d) -> (y (b, 1, d), new cache)."""
    dt_ = x.dtype
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(
        xin, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_), cache["conv"]
    )
    xc = jax.nn.silu(xc)
    a, bu, C = _ssm_coeffs(p, xc, cfg)
    h = a[:, 0] * cache["h"] + bu[:, 0]
    y = jnp.einsum("bcs,bs->bc", h, C[:, 0].astype(jnp.float32))[:, None]
    y = y + xc.astype(jnp.float32) * p["D"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_)
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(dt_))
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "h": h}


# ---------------------------------------------------------------------------
# Mamba2 / SSD (zamba2)
# ---------------------------------------------------------------------------


def mamba2_specs(cfg: ArchConfig):
    d, din, st = cfg.d_model, cfg.inner, cfg.ssm_state
    nh = din // cfg.ssm_head_dim
    g = 1  # B/C groups
    return {
        "wz": ParamSpec((d, din), ("embed", "ssm_inner")),
        "wx": ParamSpec((d, din), ("embed", "ssm_inner")),
        "wB": ParamSpec((d, g * st), ("embed", None)),
        "wC": ParamSpec((d, g * st), ("embed", None)),
        "wdt": ParamSpec((d, nh), ("embed", "heads")),
        "conv_x": ParamSpec((din, cfg.conv_width), ("ssm_inner", None), init="small"),
        "conv_B": ParamSpec((g * st, cfg.conv_width), (None, None), init="small"),
        "conv_C": ParamSpec((g * st, cfg.conv_width), (None, None), init="small"),
        "conv_b": ParamSpec((din + 2 * g * st,), (None,), init="zeros"),
        "A_log": ParamSpec((nh,), ("heads",), init="small"),
        "dt_bias": ParamSpec((nh,), ("heads",), init="small"),
        "D": ParamSpec((nh,), ("heads",), init="ones"),
        "norm": ParamSpec((din,), ("ssm_inner",), init="zeros"),
        "out_proj": ParamSpec((din, d), ("ssm_inner", "embed")),
    }


def _mamba2_project(p, x, cfg: ArchConfig):
    dt_ = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(dt_))
    xi = jnp.einsum("bsd,de->bse", x, p["wx"].astype(dt_))
    B = jnp.einsum("bsd,de->bse", x, p["wB"].astype(dt_))
    C = jnp.einsum("bsd,de->bse", x, p["wC"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(dt_))
    return z, xi, B, C, dt


def _mamba2_conv(p, xi, B, C, state=None):
    din, st = xi.shape[-1], B.shape[-1]
    xbc = jnp.concatenate([xi, B, C], axis=-1)
    w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=0)
    out, new_state = _causal_conv(xbc, w.astype(xi.dtype), p["conv_b"].astype(xi.dtype), state)
    out = jax.nn.silu(out)
    return out[..., :din], out[..., din : din + st], out[..., din + st :], new_state


def ssd_chunked(xh, dt, A_log, B, C, chunk: int = 128, h0=None, unroll: bool = False):
    """Chunked state-space-dual. xh: (b, s, nh, hd); dt: (b, s, nh);
    B/C: (b, s, st). Returns (y, final_state (b, nh, st, hd))."""
    b, s, nh, hd = xh.shape
    st = B.shape[-1]
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s
    nc = s // chunk
    A = -jnp.exp(A_log.astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    loga = dt * A  # (b, s, nh) log decay per token
    xw = xh.astype(jnp.float32) * dt[..., None]  # dt-weighted input

    lg = loga.reshape(b, nc, chunk, nh)
    xc = xw.reshape(b, nc, chunk, nh, hd)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, st)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, st)

    def body(S, args):
        lgi, xi, Bi, Ci = args  # (b,chunk,nh), (b,chunk,nh,hd), (b,chunk,st)
        cum = jnp.cumsum(lgi, axis=1)  # (b, chunk, nh)
        # intra-chunk: G[t,s] = C_t.B_s * exp(cum_t - cum_s) for t>=s
        Gts = jnp.einsum("bts,bus->btu", Ci, Bi)  # (b, t, u) state contraction
        L = cum[:, :, None, :] - cum[:, None, :, :]  # (b, t, u, nh)
        tri = (jnp.arange(chunk)[:, None] >= jnp.arange(chunk)[None, :])
        # mask BEFORE exp: exp of a large positive masked entry would be inf
        # and inf*0 = nan in the backward pass
        L = jnp.where(tri[None, :, :, None], L, -1e30)
        M = jnp.exp(L) * Gts[..., None]
        y_intra = jnp.einsum("btuh,buhd->bthd", M, xi)
        # inter-chunk: contribution of entering state
        y_inter = jnp.einsum(
            "bts,bth,bhsd->bthd", Ci, jnp.exp(cum), S
        )
        # new state: S' = exp(total) S + sum_u exp(total - cum_u) B_u x_u
        total = cum[:, -1]  # (b, nh)
        decay = jnp.exp(total[:, None, :] - cum)  # (b, u, nh)
        S_new = jnp.einsum("bus,buh,buhd->bhsd", Bi, decay, xi)
        S_new = S_new + jnp.exp(total)[..., None, None] * S
        return S_new, y_intra + y_inter

    if h0 is None:
        h0 = jnp.zeros((b, nh, st, hd), jnp.float32)
    args = (
        jnp.moveaxis(lg, 1, 0),
        jnp.moveaxis(xc, 1, 0),
        jnp.moveaxis(Bc, 1, 0),
        jnp.moveaxis(Cc, 1, 0),
    )
    # unroll capped at 16 chunks: body is ~4% of layer FLOPs (see ssm.py
    # mamba1 note); keeps 32k/500k cost compiles tractable on one CPU core
    S, ys = jax.lax.scan(body, h0, args, unroll=nc if (unroll and nc <= 16) else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, nh, hd)
    return y, S


def mamba2_apply(
    p,
    x,
    cfg: ArchConfig,
    shd: ShardCtx = NULL_CTX,
    chunk: int = 128,
    return_cache: bool = False,
):
    b, s, d = x.shape
    dt_ = x.dtype
    nh = cfg.inner // cfg.ssm_head_dim
    z, xi, B, C, dt = _mamba2_project(p, x, cfg)
    xi = shd.act(xi, "batch", None, "ssm_inner")
    xcv, Bcv, Ccv, _ = _mamba2_conv(p, xi, B, C)
    xh = xcv.reshape(b, s, nh, cfg.ssm_head_dim)
    from repro.kernels import dispatch

    if dispatch.use_pallas() and shd.mesh is None and s % 128 == 0:
        from repro.kernels.ssd.ops import ssd_op

        dtf = jax.nn.softplus((dt + p["dt_bias"]).astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y, S = ssd_op(
            xh.astype(jnp.float32) * dtf[..., None], dtf * A,
            Bcv.astype(jnp.float32), Ccv.astype(jnp.float32), chunk=chunk,
        )
    else:
        y, S = ssd_chunked(
            xh, dt + p["dt_bias"], p["A_log"], Bcv, Ccv, chunk,
            unroll=shd.unroll_inner,
        )
    y = y + xh.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(b, s, -1)
    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_), p["norm"], cfg.norm_eps
    )
    y = shd.act(y, "batch", None, "ssm_inner")
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(dt_))
    if not return_cache:
        return out, None
    w = cfg.conv_width
    xbc_tail = jnp.concatenate([xi, B, C], axis=-1)[:, -(w - 1):].astype(dt_)
    return out, {"conv": xbc_tail, "h": S}


def mamba2_init_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    nh = cfg.inner // cfg.ssm_head_dim
    st = cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.inner + 2 * st), dtype),
        "h": jnp.zeros((batch, nh, st, cfg.ssm_head_dim), jnp.float32),
    }


def mamba2_decode_step(p, x, cache, cfg: ArchConfig, shd: ShardCtx = NULL_CTX):
    b = x.shape[0]
    dt_ = x.dtype
    nh = cfg.inner // cfg.ssm_head_dim
    z, xi, B, C, dt = _mamba2_project(p, x, cfg)
    xcv, Bcv, Ccv, conv_state = _mamba2_conv(p, xi, B, C, cache["conv"])
    xh = xcv.reshape(b, 1, nh, cfg.ssm_head_dim).astype(jnp.float32)
    dtv = jax.nn.softplus((dt + p["dt_bias"]).astype(jnp.float32))[:, 0]  # (b, nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dtv * A)  # (b, nh)
    Bx = jnp.einsum("bs,bhd->bhsd", Bcv[:, 0].astype(jnp.float32), xh[:, 0] * dtv[..., None])
    h = a[..., None, None] * cache["h"] + Bx
    y = jnp.einsum("bs,bhsd->bhd", Ccv[:, 0].astype(jnp.float32), h)[:, None]
    y = y + xh * p["D"][:, None]
    y = y.reshape(b, 1, -1)
    y = rms_norm(
        (y * jax.nn.silu(z.astype(jnp.float32))).astype(dt_), p["norm"], cfg.norm_eps
    )
    out = jnp.einsum("bsc,cd->bsd", y, p["out_proj"].astype(dt_))
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "h": h}
