"""Dense feed-forward blocks (SwiGLU / GELU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import NULL_CTX, ShardCtx
from repro.models.common import ParamSpec
from repro.models.config import ArchConfig


def mlp_specs(cfg: ArchConfig, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_act == "swiglu":
        return {
            "wi": ParamSpec((d, f), ("embed", "mlp")),
            "wg": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "bi": ParamSpec((f,), ("mlp",), init="zeros"),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
        "bo": ParamSpec((d,), ("embed",), init="zeros"),
    }


def mlp_apply(p, x: jax.Array, cfg: ArchConfig, shd: ShardCtx = NULL_CTX):
    dt = x.dtype
    if cfg.mlp_act == "swiglu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt))
        g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
        h = jax.nn.silu(g) * h
        h = shd.act(h, "batch", None, "mlp")
        return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt))
    h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(dt)) + p["bi"].astype(dt)
    h = jax.nn.gelu(h)
    h = shd.act(h, "batch", None, "mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(dt)) + p["bo"].astype(dt)
