"""Uniform model API over all families + shape-cell input specs."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, lm
from repro.models.common import abstract_params, init_params, param_axes, param_count
from repro.models.config import ArchConfig

ARCH_IDS = [
    "whisper-tiny",
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "qwen3-14b",
    "granite-3-2b",
    "starcoder2-7b",
    "deepseek-67b",
    "zamba2-2.7b",
    "internvl2-26b",
    "falcon-mamba-7b",
]

# (seq_len, global_batch, kind)
SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def get_config(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_")
    )
    return mod.CONFIG


def shape_cells(arch_id: str) -> list[str]:
    """Shape cells that lower for this arch (long_500k only if sub-quadratic)."""
    cfg = get_config(arch_id)
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells


@dataclasses.dataclass(frozen=True)
class ModelAPI:
    cfg: ArchConfig
    specs: Callable[[], Any]
    loss: Callable[..., Any]            # (params, batch, *, shd)
    prefill: Callable[..., Any]         # (params, batch, *, shd)
    decode_step: Callable[..., Any]     # (params, tokens, cache, pos, *, shd)
    init_cache: Callable[..., Any]      # (batch, max_len)
    cache_axes: Callable[[], Any]

    def init(self, rng):
        return init_params(self.specs(), rng)

    def abstract(self):
        return abstract_params(self.specs())

    def axes(self):
        return param_axes(self.specs())

    def n_params(self) -> int:
        return param_count(self.specs())


def build_api(cfg: ArchConfig) -> ModelAPI:
    if cfg.family == "encdec":
        return ModelAPI(
            cfg=cfg,
            specs=lambda: encdec.encdec_specs(cfg),
            loss=lambda params, batch, *, shd: encdec.encdec_loss(
                params, cfg, batch, shd=shd
            ),
            prefill=lambda params, batch, *, shd: encdec.encdec_prefill(
                params, cfg, batch["frames"], batch["tokens"], shd=shd
            ),
            decode_step=lambda params, tokens, cache, pos, *, shd: (
                encdec.encdec_decode_step(params, cfg, tokens, cache, pos, shd=shd)
            ),
            init_cache=lambda batch, max_len: encdec.init_cache(cfg, batch, max_len),
            cache_axes=lambda: encdec.cache_axes(cfg),
        )
    return ModelAPI(
        cfg=cfg,
        specs=lambda: lm.lm_specs(cfg),
        loss=lambda params, batch, *, shd: lm.lm_loss(params, cfg, batch, shd=shd),
        prefill=lambda params, batch, *, shd: lm.lm_prefill(
            params, cfg, batch["tokens"], shd=shd,
            vision_embeds=batch.get("vision_embeds"),
        ),
        decode_step=lambda params, tokens, cache, pos, *, shd: lm.lm_decode_step(
            params, cfg, tokens, cache, pos, shd=shd
        ),
        init_cache=lambda batch, max_len: lm.init_cache(cfg, batch, max_len),
        cache_axes=lambda: lm.cache_axes(cfg),
    )


def get_api(arch_id: str, reduced: bool = False) -> ModelAPI:
    cfg = get_config(arch_id)
    if reduced:
        cfg = cfg.reduced()
    return build_api(cfg)


# ---------------------------------------------------------------------------
# input specs per shape cell (ShapeDtypeStructs — no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    """Abstract inputs for a cell.  For decode cells, the KV/SSM cache of
    length seq_len is part of the inputs (it is state, not weights)."""
    seq, gb, kind = SHAPES[shape_name]
    i32 = jnp.int32
    if kind == "train":
        batch = {
            "tokens": jax.ShapeDtypeStruct((gb, seq), i32),
            "labels": jax.ShapeDtypeStruct((gb, seq), i32),
        }
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (gb, cfg.enc_len, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
            )
        return batch
    if kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((gb, seq), i32)}
        if cfg.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (gb, cfg.enc_len, cfg.d_model), jnp.bfloat16
            )
        if cfg.family == "vlm":
            batch["vision_embeds"] = jax.ShapeDtypeStruct(
                (gb, cfg.n_vision_tokens, cfg.d_model), jnp.bfloat16
            )
        return batch
    # decode: one new token against a cache of length seq
    api = build_api(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(gb, seq))
    return {
        "tokens": jax.ShapeDtypeStruct((gb, 1), i32),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), i32),
    }
