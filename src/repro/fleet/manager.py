"""Fleet manager: GreenFaaS scheduling + fault tolerance for TPU pods.

This is the integration layer the paper's §VI-B ("hierarchical scheduling")
sketches: GreenFaaS decides *which pod* runs each job; XLA owns placement
within a pod.  Job cost profiles come from the dry-run artifacts
(benchmarks/results/dryrun/*.json) turned into per-endpoint predictions via
each endpoint's roofline; online monitoring then corrects them — the same
predict -> place -> measure -> learn loop as the CPU testbed.

Fault tolerance:
  * heartbeats        — endpoints report step progress; missed beats =>
                        endpoint marked down, its jobs resubmitted
  * straggler watch   — a job whose s/step drifts > k sigma from its profile
                        (predictor.drift_sigma) is re-placed (checkpoint
                        restart on another endpoint)
  * elastic scaling   — endpoint join/leave triggers re-placement of queued
                        work; running jobs restore checkpoints onto the new
                        mesh (checkpoint/manager.py is mesh-agnostic)
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable

import numpy as np

from repro.core.endpoint import EndpointSpec
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import TaskSpec, cluster_mhra
from repro.core.transfer import TransferModel

HEARTBEAT_TIMEOUT_S = 60.0
STRAGGLER_SIGMA = 3.0


@dataclasses.dataclass
class FleetJob:
    id: str
    arch: str
    shape: str            # train_4k / prefill_32k / ...
    steps: int = 100
    checkpoint_bytes: float = 0.0
    src_endpoint: str = "pod0"

    @property
    def fn(self) -> str:
        return f"{self.arch}:{self.shape}"


def load_dryrun_costs(results_dir: str | pathlib.Path) -> dict[str, dict]:
    """fn-id -> per-device {flops, bytes, coll_bytes} from the dry-run."""
    out = {}
    for fp in pathlib.Path(results_dir).glob("*__single.json"):
        d = json.loads(fp.read_text())
        ex = d.get("extrapolated", {})
        out[f"{d['arch']}:{d['shape']}"] = {
            "flops": ex.get("flops_extrap", d.get("flops_per_device", 0.0)),
            "bytes": ex.get("bytes_extrap", d.get("bytes_accessed_per_device", 0.0)),
            "coll_bytes": ex.get("coll_bytes_extrap", d.get("collective_bytes_per_device", 0.0)),
            "n_devices": d.get("n_devices", 256),
        }
    return out


def predict_step_seconds(cost: dict, ep: EndpointSpec) -> float:
    """Roofline-style per-step estimate on an endpoint's hardware. The
    dry-run numbers are per-device on 256 chips; rescale to ep.chips."""
    scale = cost["n_devices"] / max(ep.chips, 1)
    t_compute = cost["flops"] * scale / ep.peak_flops
    t_mem = cost["bytes"] * scale / ep.hbm_bw
    t_coll = cost["coll_bytes"] * scale / ep.ici_bw
    return max(t_compute, t_mem, t_coll)


def predict_step_energy(cost: dict, ep: EndpointSpec, t_step: float) -> float:
    """Energy per step: idle + utilization-scaled dynamic power (the fleet
    simulator's 'true' coefficients differ — GreenFaaS re-learns online)."""
    scale = cost["n_devices"] / max(ep.chips, 1)
    util = min(cost["flops"] * scale / ep.peak_flops / max(t_step, 1e-9), 1.0)
    watts = ep.idle_power_w + (ep.tdp_w - ep.idle_power_w) * (0.3 + 0.7 * util)
    return watts * t_step


class FleetManager:
    def __init__(
        self,
        endpoints: list[EndpointSpec],
        dryrun_dir: str | pathlib.Path,
        alpha: float = 0.5,
    ):
        self.endpoints = {e.name: e for e in endpoints}
        self.costs = load_dryrun_costs(dryrun_dir)
        self.alpha = alpha
        self.store = TaskProfileStore(endpoints)
        self.transfer = TransferModel(endpoints)
        self.last_heartbeat: dict[str, float] = {e.name: time.time() for e in endpoints}
        self.down: set[str] = set()
        self.events: list[str] = []

    # --- profile seeding from the dry-run ---------------------------------
    def seed_profiles(self, jobs: list[FleetJob]) -> None:
        for job in jobs:
            cost = self.costs.get(job.fn)
            if cost is None:
                continue
            for ep in self.endpoints.values():
                t = predict_step_seconds(cost, ep) * job.steps
                e = predict_step_energy(cost, ep, predict_step_seconds(cost, ep)) * job.steps
                if self.store.n_obs(job.fn, ep.name) == 0:
                    self.store.record(job.fn, ep.name, t, e)

    # --- scheduling --------------------------------------------------------
    def live_endpoints(self) -> list[EndpointSpec]:
        return [e for n, e in self.endpoints.items() if n not in self.down]

    def place(self, jobs: list[FleetJob]):
        self.seed_profiles(jobs)
        tasks = [
            TaskSpec(
                id=j.id, fn=j.fn,
                inputs=((j.src_endpoint, 1, j.checkpoint_bytes, False),)
                if j.checkpoint_bytes else (),
            )
            for j in jobs
        ]
        return cluster_mhra(
            tasks, self.live_endpoints(), self.store, self.transfer, self.alpha
        )

    # --- fault tolerance ----------------------------------------------------
    def heartbeat(self, endpoint: str, now: float | None = None) -> None:
        self.last_heartbeat[endpoint] = now if now is not None else time.time()

    def check_health(self, now: float | None = None) -> list[str]:
        """Returns newly-down endpoints (jobs there must be resubmitted)."""
        now = now if now is not None else time.time()
        newly = []
        for name, t in self.last_heartbeat.items():
            if name not in self.down and now - t > HEARTBEAT_TIMEOUT_S:
                self.down.add(name)
                newly.append(name)
                self.events.append(f"endpoint {name} DOWN (missed heartbeat)")
        return newly

    def endpoint_join(self, spec: EndpointSpec) -> None:
        self.endpoints[spec.name] = spec
        self.last_heartbeat[spec.name] = time.time()
        self.down.discard(spec.name)
        self.events.append(f"endpoint {spec.name} JOINED ({spec.chips} chips)")

    def endpoint_leave(self, name: str) -> None:
        self.down.add(name)
        self.events.append(f"endpoint {name} LEFT (drain requested)")

    def observe_step(
        self, job: FleetJob, endpoint: str, seconds: float, energy_j: float
    ) -> bool:
        """Record a measured step; returns True if the job should be
        re-placed (straggler)."""
        sigma = self.store.drift_sigma(job.fn, endpoint, seconds)
        self.store.record(job.fn, endpoint, seconds, energy_j)
        if sigma > STRAGGLER_SIGMA:
            self.events.append(
                f"straggler: {job.id} on {endpoint} ({sigma:.1f} sigma) -> re-place"
            )
            return True
        return False
