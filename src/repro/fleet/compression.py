"""Gradient compression for pod-crossing (DCN) reductions.

int8 quantization with per-leaf scale + error feedback: the pod axis
all-reduce moves 4x fewer bytes (fp32 -> int8), and the residual is
carried into the next step so the compression is unbiased over time.
Used by the trainer when `compress_dcn=True` and the mesh has a pod axis.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any, error: Any):
    """Returns (quantized tree, scales tree, new error-feedback tree)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        q, s = quantize(g)
        err = g - dequantize(q, s)
        return q, s, err

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (
        tdef.unflatten([o[0] for o in out]),
        tdef.unflatten([o[1] for o in out]),
        tdef.unflatten([o[2] for o in out]),
    )


def psum_compressed(grads: Any, error: Any, axis_name: str):
    """shard_map-side compressed all-reduce over `axis_name` (e.g. "pod").
    int8 payload is summed in int32 (safe for pod counts < 2^23)."""
    q, s, err = compress_tree(grads, error)
    q32 = jax.tree.map(lambda x: x.astype(jnp.int32), q)
    q_sum = jax.tree.map(lambda x: jax.lax.psum(x, axis_name), q32)
    s_max = jax.tree.map(lambda x: jax.lax.pmax(x, axis_name), s)
    n = jax.lax.psum(1, axis_name)
    avg = jax.tree.map(
        lambda qq, ss: qq.astype(jnp.float32) * ss / n, q_sum, s_max
    )
    return avg, err


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
