"""falcon-mamba-7b [arXiv:2410.05355]: pure Mamba1, attention-free."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024, ssm_state=16, d_inner=8192,
    attention="none", sub_quadratic=True,
)
