"""starcoder2-7b [arXiv:2402.19173]: dense GQA, RoPE."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4, head_dim=128,
    d_ff=18432, vocab=49152, rope_theta=1e5, mlp_act="gelu",
    attn_strategy="seq_cp",  # 36 heads not divisible by model axis 16
)
