"""qwen3-14b [hf:Qwen/Qwen3-14B]: dense GQA with qk_norm."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=17408, vocab=151936, qk_norm=True, rope_theta=1e6,
    attn_strategy="seq_cp",  # 40 heads not divisible by model axis 16
)
