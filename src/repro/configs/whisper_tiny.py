"""whisper-tiny [arXiv:2212.04356]: enc-dec, conv frontend stubbed."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, n_enc_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, enc_len=1500,
    pos_emb="sinusoidal", norm="layernorm", mlp_act="gelu",
    attn_strategy="seq_cp",
)
