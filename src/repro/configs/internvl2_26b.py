"""internvl2-26b [arXiv:2404.16821]: InternLM2-20B backbone; ViT stubbed."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16384, vocab=92553, n_vision_tokens=256, rope_theta=1e6,
)
