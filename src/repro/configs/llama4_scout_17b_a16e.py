"""llama4-scout-17b-a16e [hf:meta-llama/Llama-4-Scout-17B-16E]: MoE 16e top-1."""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048, n_experts=16, top_k=1,
    attn_strategy="seq_cp",  # 40 heads not divisible by model axis 16
)
