"""MHRA + Cluster MHRA schedulers (paper §III-F, Algorithm 1) and the
Round-Robin / single-site baselines evaluated in Table V.

Objective:  O = alpha * E_tot/SF1 + (1-alpha) * C_max/SF2
  E_tot = sum_n [ idle_power * allocated-span(+startup) + sum dyn task E ]
          + transfer energy;  desktop-style endpoints charge idle over the
          whole workflow span (paper: power drawn whether or not tasks run).
  SF1/SF2 = pessimistic all-on-one-machine estimates.

Three greedy engines share the same arithmetic:

  * ``engine="delta"`` (default) scores a candidate endpoint by previewing
    only the *change* it makes to the live state — peek/copy that one
    endpoint's slot heap, delta the idle-span and dynamic-energy terms —
    then commits only the winner.  O(endpoints * log cores) per decision.
  * ``engine="soa"`` lays the state out as structure-of-arrays
    (:class:`SoAState`: one flat float64 array of core free-times with
    per-endpoint offsets plus vector registers) and scores a unit against
    *every* endpoint in a handful of vectorized passes, with run
    memoization making most decisions O(1) scalar work.  Fastest at large
    fleets / task counts; see :func:`_greedy_soa`.
  * ``engine="clone"`` is the original clone-per-candidate greedy kept as
    the reference implementation for parity tests and the overhead
    benchmark.  O(endpoints^2 * cores) copies per decision.

delta and clone perform bitwise-identical floating-point operations, so
they produce identical assignments and objective values
(``tests/test_policy_engine.py``).  soa regroups the candidate-score sum
for vectorization (~1 ulp), which can only reorder *exact ties* — broken
identically by both engines — so assignments match delta exactly and
reported objectives are bitwise-equal in practice, asserted to
rtol=1e-12 (``tests/test_soa_engine.py``).  The delta and soa engines
also accept a live state so the online engine (``repro.core.engine``)
can place arrival windows against the timeline carried over from
previous windows.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Sequence

import numpy as np

from repro.core.carbon import CarbonWeights
from repro.core.clustering import agglomerative_cluster
from repro.core.dag import LookaheadWeights
from repro.core.endpoint import EndpointSpec
from repro.core.fairness import FairnessWeights
from repro.core.faults import WarmWeights
from repro.core.predictor import Prediction, TaskProfileStore
from repro.core.transfer import E_INC_J_PER_BYTE, TransferModel

#: Run-memoization counters for the SoA greedy (``_greedy_soa``): a "hit"
#: is a unit scored by reusing the previous unit's vectorized pass (the
#: O(1) fast path), a "miss" is a full vectorized scoring pass.  Promoted
#: DAG children share one ``not_before`` per completion epoch precisely so
#: wide stages stay inside one run — the epoch is threaded into the memo
#: key through that field.  Cumulative across calls; reset with
#: :func:`reset_memo_stats`.
MEMO_STATS = {"hits": 0, "misses": 0}


def reset_memo_stats() -> None:
    MEMO_STATS["hits"] = 0
    MEMO_STATS["misses"] = 0


#: Calibrated ``engine="auto"`` crossover (measured on the scaled SeBS
#: testbed, min-of-30 timings, post constant-factor shave): soa beats
#: delta at *every* batch size from 16 endpoints up (0.98x at the n=4
#: worst case, >1.2x elsewhere); below 16 endpoints its per-call array
#: setup needs endpoints*tasks score cells to amortize — measured break-
#: even at 4 eps x 64 tasks and 8 eps x 32 tasks, i.e. ~256 cells.
AUTO_SOA_MIN_ENDPOINTS = 16
AUTO_SOA_MIN_CELLS = 256

#: ``engine="jax"`` crossover (measured on the scaled SeBS testbed, warm
#: timings with the one-off JIT compile accounted separately — see
#: BENCH_scheduler.json): the fused lax.scan greedy beats soa once the
#: window is deep enough to amortize host array prep and device
#: round-trips — measured from 8 endpoints at 8k-task windows (2^16
#: score cells; jax 0.18s vs soa 0.30s there, and the margin only grows
#: with the fleet).  Smaller windows stay on soa; tiny fleets never
#: switch (the vector passes don't pay for the scan's fixed overhead).
AUTO_JAX_MIN_ENDPOINTS = 8
AUTO_JAX_MIN_CELLS = 1 << 16

_JAX_OK: bool | None = None


def _jax_available() -> bool:
    """Lazy probe: is the jax placement backend importable?  ``auto``
    must never resolve to an engine that cannot run."""
    global _JAX_OK
    if _JAX_OK is None:
        try:
            import repro.kernels.placement.ops  # noqa: F401
            _JAX_OK = True
        except Exception:
            _JAX_OK = False
    return _JAX_OK


def auto_engine(n_endpoints: int, n_tasks: int | None = None) -> str:
    """Resolve ``engine="auto"`` to a concrete greedy backend.

    Fleet-size/window-size crossover: ``soa`` needs enough endpoints for
    its vectorized candidate passes to beat delta's python loop, and (in
    batch mode, where ``n_tasks`` is known) enough score cells to
    amortize its per-call array setup.  ``n_tasks=None`` (streaming:
    window sizes are unknown up front) decides on fleet size alone,
    conservatively — delta is never worse than soa by much at small
    fleets, while soa's setup can triple a tiny window's latency.  Above
    the jax crossover (large fleet *and* a deep window to scan over) the
    fused ``engine="jax"`` backend takes over — batch-size-aware only,
    and only when jax is importable."""
    if (n_tasks is not None and n_endpoints >= AUTO_JAX_MIN_ENDPOINTS
            and n_endpoints * n_tasks >= AUTO_JAX_MIN_CELLS
            and _jax_available()):
        return "jax"
    if n_endpoints >= AUTO_SOA_MIN_ENDPOINTS:
        return "soa"
    if n_tasks is None:
        return "delta"
    return "soa" if n_endpoints * n_tasks >= AUTO_SOA_MIN_CELLS else "delta"


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One task submission.

    ``inputs`` are transfer templates ``(src, n_files, total_bytes,
    shared)`` — src is an endpoint name; shared inputs are cached per
    destination endpoint.  ``deps``/``dep_bytes`` describe DAG edges: the
    task may not start before every parent task id in ``deps`` has
    completed, and it pulls ``dep_bytes`` bytes from each parent's
    *producing endpoint* (the online engine rewrites these into concrete
    ``inputs`` entries once the parents' placements are known).
    ``not_before`` is the resolved ready floor in seconds — every engine
    clamps the task's start time to it.  Instances are frozen; the engine
    promotes a dependent task by building a ``dataclasses.replace`` copy.
    """
    id: str
    fn: str
    inputs: tuple = ()          # tuple of TransferRequest templates (src, files, bytes, shared)
    user: str = "user0"
    deps: tuple = ()            # parent task ids; placeable only once all complete
    dep_bytes: float = 0.0      # bytes pulled from each parent's endpoint
    not_before: float = 0.0     # earliest start (s); set when deps resolve
    deadline: float = float("inf")  # latest completion (s); bounds carbon deferral


@dataclasses.dataclass
class Schedule:
    assignments: dict[str, str]
    objective: float
    energy_j: float
    makespan_s: float
    transfer_j: float
    heuristic: str = ""
    timeline: dict[str, tuple[float, float]] = dataclasses.field(default_factory=dict)
    carbon_g: float | None = None   # scoring-time gCO2 estimate (carbon runs)

    def edp(self) -> float:
        return self.energy_j * self.makespan_s

    def w_ed2p(self) -> float:
        return self.energy_j * self.makespan_s ** 2

    def cdp(self) -> float | None:
        """Carbon-delay product gCO2*s (None outside carbon-aware runs)."""
        if self.carbon_g is None:
            return None
        return self.carbon_g * self.makespan_s


HEURISTICS = (
    "shortest_runtime_first",
    "longest_runtime_first",
    "highest_energy_first",
    "lowest_energy_first",
)


class SchedulerState:
    """Incremental greedy-scheduling state over endpoint timelines.

    Carried across arrival windows by the online engine.  The legacy clone
    engine evaluates candidates with :meth:`clone` + :meth:`assign` +
    :meth:`metrics`; the delta greedy (:func:`_greedy_delta`) unpacks this
    state into flat lists and performs the *same float operations* inline
    — any edit to assign()/metrics() arithmetic must be mirrored there to
    preserve the engines' bitwise parity.
    """

    def __init__(self, endpoints: Sequence[EndpointSpec], transfer: TransferModel):
        self.eps = list(endpoints)
        self.transfer = transfer
        self.slots = {e.name: [0.0] * e.cores for e in endpoints}  # min-heaps
        for h in self.slots.values():
            heapq.heapify(h)
        self.first_start = {e.name: None for e in endpoints}
        self.last_end = {e.name: 0.0 for e in endpoints}
        self.dyn_energy = {e.name: 0.0 for e in endpoints}
        self.transfer_j = 0.0
        self.cached: set[tuple[str, str]] = set()
        self.timeline: dict[str, tuple[float, float]] = {}

    def clone(self, keep_timeline: bool = False) -> "SchedulerState":
        s = SchedulerState.__new__(SchedulerState)
        s.eps, s.transfer = self.eps, self.transfer
        s.slots = {k: list(v) for k, v in self.slots.items()}
        s.first_start = dict(self.first_start)
        s.last_end = dict(self.last_end)
        s.dyn_energy = dict(self.dyn_energy)
        s.transfer_j = self.transfer_j
        s.cached = set(self.cached)
        # candidate previews don't need task-level timelines; scratch states
        # that may become the live state (multi-heuristic search) do
        s.timeline = dict(self.timeline) if keep_timeline else {}
        return s

    def advance_to(self, now: float) -> None:
        """Raise every worker slot's free time to at least ``now`` — the
        online engine calls this when an arrival window opens after an idle
        gap, so placement previews can't schedule starts in the past
        (mirroring the testbed's ``max(slot, now)`` dispatch rule)."""
        for h in self.slots.values():
            changed = False
            for i, v in enumerate(h):
                if v < now:
                    h[i] = now
                    changed = True
            if changed:
                heapq.heapify(h)

    def replace_with(self, other: "SchedulerState") -> None:
        """Adopt another state's contents in place (winner of a heuristic
        search replacing the live online state)."""
        self.slots = other.slots
        self.first_start = other.first_start
        self.last_end = other.last_end
        self.dyn_energy = other.dyn_energy
        self.transfer_j = other.transfer_j
        self.cached = other.cached
        self.timeline = other.timeline

    def drop_timeline(self, task_ids) -> int:
        """Retire finished tasks' timeline entries (live-state pruning:
        the online engine drops a task once it has completed, so per-window
        timeline snapshots and heuristic-search clones stay O(live) instead
        of O(total-ever-placed)).  Scoring never reads the timeline, so
        this cannot affect placement parity.  Returns the count dropped."""
        pop = self.timeline.pop
        n = 0
        for tid in task_ids:
            if pop(tid, None) is not None:
                n += 1
        return n

    # -- transfer bookkeeping shared by assign() and preview() -------------
    def _transfer_delta(self, unit, name: str):
        """(transfer_j_after, ready_s, cache_keys_added) for placing this
        unit's inputs on endpoint ``name`` — no state mutation."""
        return _unit_transfer_delta(
            self.transfer, self.cached, self.transfer_j, unit, name
        )

    def assign(
        self,
        unit: Sequence[TaskSpec],
        ep: EndpointSpec,
        preds: dict[str, Prediction],
        record_timeline: bool = False,
    ) -> None:
        name = ep.name
        transfer_j, ready, new_cached = self._transfer_delta(unit, name)
        self.transfer_j = transfer_j
        self.cached.update(new_cached)
        if ep.has_batch_scheduler:
            ready += ep.queue_delay_s
        slots = self.slots[name]
        for t in unit:
            p = preds[t.id]
            start = max(heapq.heappop(slots), ready)
            if start < t.not_before:
                start = t.not_before
            end = start + p.runtime_s
            heapq.heappush(slots, end)
            if self.first_start[name] is None or start < self.first_start[name]:
                self.first_start[name] = start
            self.last_end[name] = max(self.last_end[name], end)
            self.dyn_energy[name] += p.energy_j
            if record_timeline:
                self.timeline[t.id] = (start, end)

    def metrics(self) -> tuple[float, float, float]:
        """(E_tot, C_max, transfer_j)."""
        c_max = max([v for v in self.last_end.values()] + [0.0])
        e_tot = self.transfer_j
        for ep in self.eps:
            n = ep.name
            if self.first_start[n] is None:
                if not ep.has_batch_scheduler:
                    # always-on endpoint idles through the workflow regardless
                    e_tot += ep.idle_power_w * c_max
                continue
            if ep.has_batch_scheduler:
                span = self.last_end[n] - self.first_start[n]
                e_tot += ep.idle_power_w * span + ep.startup_energy_j
            else:
                e_tot += ep.idle_power_w * c_max
            e_tot += self.dyn_energy[n]
        return e_tot, c_max, self.transfer_j


def _unit_transfer_delta(transfer, cached, transfer_j, unit, name):
    """(transfer_j_after, ready_s, cache_keys_added) for placing ``unit``'s
    inputs on endpoint ``name`` — pure function of the cache contents,
    shared by the heap- and SoA-backed states."""
    t_bytes, t_files = 0.0, 0
    new_cached: list[tuple[str, str]] = []
    for t in unit:
        for src, n_files, nbytes, shared in t.inputs:
            if src == name:
                continue
            key = (name, f"{src}:{n_files}:{nbytes}")
            if shared and (key in cached or key in new_cached):
                continue
            if shared:
                new_cached.append(key)
            transfer_j += transfer.hops(src, name) * nbytes * E_INC_J_PER_BYTE
            t_bytes += nbytes
            t_files += n_files
    ready = transfer.predict_seconds(t_files, t_bytes)
    return transfer_j, ready, new_cached


# kept as an alias: pre-refactor code and tests referred to _State
_State = SchedulerState


class SoAState:
    """Structure-of-arrays scheduling state: the third engine backend.

    Same semantics as :class:`SchedulerState`, different layout: core
    free-times live in ONE flat float64 array segmented by per-endpoint
    ``offsets``, and the per-endpoint registers (``first``/``last``/
    ``dyn``) are vectors, so the SoA greedy (:func:`_greedy_soa`) scores a
    unit against *every* endpoint in a handful of vectorized passes
    instead of a Python loop over candidates.

    ``first_start[i] == np.inf`` encodes the heap state's ``None``
    ("endpoint never used").  A heap pop-min + push(end) becomes
    "overwrite the argmin slot with end" — identical multiset evolution,
    so ``assign``/``metrics`` produce bitwise-identical floats to the
    heap-backed state given the same placement sequence.

    Units: ``free``/``first``/``last`` are seconds, ``dyn``/``transfer_j``
    joules; ``metrics()`` returns ``(E_tot J, C_max s, transfer J)``.
    ``assign`` mutates in place (including the task-start clamp to
    ``TaskSpec.not_before``); ``clone`` deep-copies the arrays but shares
    the immutable endpoint/transfer objects; ``replace_with`` adopts
    another state's arrays *by reference*.  No randomness anywhere in the
    scheduling state — determinism comes for free.
    """

    def __init__(self, endpoints: Sequence[EndpointSpec], transfer: TransferModel):
        self.eps = list(endpoints)
        self.transfer = transfer
        self.names = [e.name for e in self.eps]
        self.ep_index = {n: i for i, n in enumerate(self.names)}
        cores = np.array([e.cores for e in self.eps], dtype=np.intp)
        self.offsets = np.zeros(len(self.eps) + 1, dtype=np.intp)
        np.cumsum(cores, out=self.offsets[1:])
        self.free = np.zeros(int(self.offsets[-1]))      # flat core free-times
        self.first = np.full(len(self.eps), np.inf)      # inf == never used
        self.last = np.zeros(len(self.eps))
        self.dyn = np.zeros(len(self.eps))
        self.transfer_j = 0.0
        self.cached: set[tuple[str, str]] = set()
        self.timeline: dict[str, tuple[float, float]] = {}

    # -- layout helpers ----------------------------------------------------
    def slot_view(self, ei: int) -> np.ndarray:
        """Writable view of endpoint ``ei``'s core free-times."""
        return self.free[self.offsets[ei]:self.offsets[ei + 1]]

    def slot_mins(self) -> np.ndarray:
        """Per-endpoint min free-time in one reduceat pass."""
        return np.minimum.reduceat(self.free, self.offsets[:-1])

    # -- SchedulerState-compatible surface ---------------------------------
    def clone(self, keep_timeline: bool = False) -> "SoAState":
        s = SoAState.__new__(SoAState)
        s.eps, s.transfer = self.eps, self.transfer
        s.names, s.ep_index, s.offsets = self.names, self.ep_index, self.offsets
        s.free = self.free.copy()
        s.first = self.first.copy()
        s.last = self.last.copy()
        s.dyn = self.dyn.copy()
        s.transfer_j = self.transfer_j
        s.cached = set(self.cached)
        s.timeline = dict(self.timeline) if keep_timeline else {}
        return s

    def replace_with(self, other: "SoAState") -> None:
        self.free = other.free
        self.first = other.first
        self.last = other.last
        self.dyn = other.dyn
        self.transfer_j = other.transfer_j
        self.cached = other.cached
        self.timeline = other.timeline

    def drop_timeline(self, task_ids) -> int:
        """Same contract as :meth:`SchedulerState.drop_timeline`."""
        pop = self.timeline.pop
        n = 0
        for tid in task_ids:
            if pop(tid, None) is not None:
                n += 1
        return n

    def advance_to(self, now: float) -> None:
        """Vectorized twin of SchedulerState.advance_to: raise every core's
        free time to at least ``now``."""
        np.maximum(self.free, now, out=self.free)

    def _transfer_delta(self, unit, name: str):
        return _unit_transfer_delta(
            self.transfer, self.cached, self.transfer_j, unit, name
        )

    def assign(
        self,
        unit: Sequence[TaskSpec],
        ep: EndpointSpec,
        preds: dict[str, Prediction],
        record_timeline: bool = False,
    ) -> None:
        ei = self.ep_index[ep.name]
        transfer_j, ready, new_cached = self._transfer_delta(unit, ep.name)
        self.transfer_j = transfer_j
        self.cached.update(new_cached)
        if ep.has_batch_scheduler:
            ready += ep.queue_delay_s
        slots = self.slot_view(ei)
        first = self.first[ei]
        last = self.last[ei]
        dyn = self.dyn[ei]
        for t in unit:
            p = preds[t.id]
            k = int(np.argmin(slots))
            start = slots[k]
            if start < ready:
                start = ready
            if start < t.not_before:
                start = t.not_before
            end = start + p.runtime_s
            slots[k] = end
            if start < first:
                first = start
            if end > last:
                last = end
            dyn += p.energy_j
            if record_timeline:
                self.timeline[t.id] = (start, end)
        self.first[ei] = first
        self.last[ei] = last
        self.dyn[ei] = dyn

    def metrics(self) -> tuple[float, float, float]:
        """(E_tot, C_max, transfer_j) — same accumulation order as
        SchedulerState.metrics, reading the vector registers."""
        c_max = max(float(self.last.max(initial=0.0)), 0.0)
        e_tot = self.transfer_j
        for ei, ep in enumerate(self.eps):
            if self.first[ei] == np.inf:
                if not ep.has_batch_scheduler:
                    e_tot += ep.idle_power_w * c_max
                continue
            if ep.has_batch_scheduler:
                span = float(self.last[ei]) - float(self.first[ei])
                e_tot += ep.idle_power_w * span + ep.startup_energy_j
            else:
                e_tot += ep.idle_power_w * c_max
            e_tot += float(self.dyn[ei])
        return e_tot, c_max, self.transfer_j

    # -- interop with the heap-backed state --------------------------------
    @classmethod
    def from_heap(cls, state: SchedulerState) -> "SoAState":
        s = cls(state.eps, state.transfer)
        for ei, name in enumerate(s.names):
            s.slot_view(ei)[:] = state.slots[name]
            f = state.first_start[name]
            s.first[ei] = np.inf if f is None else f
            s.last[ei] = state.last_end[name]
            s.dyn[ei] = state.dyn_energy[name]
        s.transfer_j = state.transfer_j
        s.cached = set(state.cached)
        s.timeline = dict(state.timeline)
        return s

    def write_back(self, state: SchedulerState) -> None:
        """Adopt this SoA state's contents into a heap-backed state."""
        for ei, name in enumerate(self.names):
            h = self.slot_view(ei).tolist()
            heapq.heapify(h)
            state.slots[name] = h
            f = float(self.first[ei])
            state.first_start[name] = None if f == np.inf else f
            state.last_end[name] = float(self.last[ei])
            state.dyn_energy[name] = float(self.dyn[ei])
        state.transfer_j = self.transfer_j
        state.cached = self.cached
        state.timeline = self.timeline


def _carbon_terms_g(eps, first, last, dyn, rates, c_max) -> float:
    """Carbon-adjusted endpoint energy in gCO2: each endpoint's share of
    E_tot (idle span / always-on idle + startup + dynamic) weighted by its
    g/J rate.  Transfer energy is excluded — its grid locus is ambiguous;
    the evaluation-side footprint bills it at the fleet-mean rate.

    The per-endpoint float expressions here are mirrored verbatim by the
    delta greedy's candidate loop, so the clone and delta engines stay
    bitwise-identical under carbon weighting too.
    """
    g = 0.0
    for j, ep in enumerate(eps):
        w = rates[j]
        f = first[j]
        if f is None:
            if not ep.has_batch_scheduler:
                g += w * (ep.idle_power_w * c_max)
            continue
        if ep.has_batch_scheduler:
            g += w * (ep.idle_power_w * (last[j] - f) + ep.startup_energy_j
                      + dyn[j])
        else:
            g += w * (ep.idle_power_w * c_max + dyn[j])
    return g


def state_carbon_g(state, rates) -> float:
    """gCO2 of a committed scheduling state under per-endpoint g/J
    ``rates`` (aligned with ``state.eps``); works on both the heap- and
    SoA-backed layouts.  See :func:`_carbon_terms_g` for the accounting."""
    if isinstance(state, SoAState):
        c_max = max(float(state.last.max(initial=0.0)), 0.0)
        first = [None if state.first[i] == np.inf else float(state.first[i])
                 for i in range(len(state.eps))]
        last = [float(v) for v in state.last]
        dyn = [float(v) for v in state.dyn]
    else:
        c_max = max([v for v in state.last_end.values()] + [0.0])
        names = [e.name for e in state.eps]
        first = [state.first_start[n] for n in names]
        last = [state.last_end[n] for n in names]
        dyn = [state.dyn_energy[n] for n in names]
    return _carbon_terms_g(state.eps, first, last, dyn, rates, c_max)


class PredictionTable:
    """Per-(task, endpoint) predictions as numpy arrays + flat lists.

    ``store.predict`` depends only on (fn, endpoint), so predictions are
    computed once per unique pair instead of once per task — at 1792 tasks
    over 7 functions that is ~256x fewer predictor calls than the nested
    dicts the clone engine builds.
    """

    def __init__(self, tasks, endpoints, store: TaskProfileStore):
        self.tasks = list(tasks)
        self.endpoints = list(endpoints)
        self.index = {t.id: i for i, t in enumerate(self.tasks)}
        cache: dict[tuple[str, str], Prediction] = {}
        n_ep = len(self.endpoints)
        # one predict per unique (fn, endpoint), expanded to tasks by
        # fancy indexing — same float values task-by-task
        fn_col: dict[str, int] = {}
        fn_ids = np.empty(len(self.tasks), dtype=np.intp)
        for ti, t in enumerate(self.tasks):
            c = fn_col.get(t.fn)
            if c is None:
                c = fn_col[t.fn] = len(fn_col)
            fn_ids[ti] = c
        base_rt = np.empty((n_ep, len(fn_col)))
        base_en = np.empty((n_ep, len(fn_col)))
        for ei, ep in enumerate(self.endpoints):
            for fn, c in fn_col.items():
                p = cache[(fn, ep.name)] = store.predict(fn, ep.name)
                base_rt[ei, c] = p.runtime_s
                base_en[ei, c] = p.energy_j
        self.rt = base_rt[:, fn_ids]
        self.en = base_en[:, fn_ids]
        self._cache = cache
        # python-float rows for the hot greedy loop (numpy scalar indexing
        # is ~5x slower than list indexing in CPython)
        self.rt_rows = self.rt.tolist()
        self.en_rows = self.en.tolist()
        # endpoint-mean predictions used by the ordering heuristics; the
        # axis-0 reduce performs the same sequential adds as the clone
        # engine's per-task np.mean over an endpoint list
        self.rt_mean = self.rt.mean(axis=0)
        self.en_mean = self.en.mean(axis=0)
        self._rtT: np.ndarray | None = None
        self._enT: np.ndarray | None = None

    def transposed(self) -> tuple[np.ndarray, np.ndarray]:
        """(n_tasks, n_ep) C-contiguous views for the SoA greedy: row
        ``ti`` is task ti's prediction across all endpoints (one slice, no
        per-candidate indexing).  Built on first use so the delta/clone
        paths don't pay for it."""
        if self._rtT is None:
            self._rtT = np.ascontiguousarray(self.rt.T)
            self._enT = np.ascontiguousarray(self.en.T)
        return self._rtT, self._enT

    def per_ep(self) -> dict[str, dict[str, Prediction]]:
        """Nested-dict view matching ``_predict_all`` for legacy callers."""
        return {
            ep.name: {t.id: self._cache[(t.fn, ep.name)] for t in self.tasks}
            for ep in self.endpoints
        }


def _unit_stats(unit, preds):
    rt = float(np.mean([preds[t.id].runtime_s for t in unit]))
    en = float(np.mean([preds[t.id].energy_j for t in unit]))
    return rt * len(unit), en * len(unit)


def _sort_units(units, key: str, preds):
    stats = [_unit_stats(u, preds) for u in units]
    if key == "shortest_runtime_first":
        order = np.argsort([s[0] for s in stats])
    elif key == "longest_runtime_first":
        order = np.argsort([-s[0] for s in stats])
    elif key == "highest_energy_first":
        order = np.argsort([-s[1] for s in stats])
    elif key == "lowest_energy_first":
        order = np.argsort([s[1] for s in stats])
    else:
        raise ValueError(key)
    return [units[i] for i in order]


def _sort_order(key: str, table: PredictionTable, unit_indices) -> np.ndarray:
    """Permutation ordering units by the heuristic ``key`` — the ordering
    :func:`_sort_units` produces, computed from the vectorized mean arrays.

    For singleton units the stat is the mean itself (mean of one element
    times one is the identity bitwise), so no per-unit np.mean calls.
    """
    rt_mean, en_mean = table.rt_mean, table.en_mean
    if all(len(ii) == 1 for ii in unit_indices):
        flat = [ii[0] for ii in unit_indices]
        rt_stat = rt_mean[flat]
        en_stat = en_mean[flat]
    else:
        rt_stat = np.empty(len(unit_indices))
        en_stat = np.empty(len(unit_indices))
        for k, ii in enumerate(unit_indices):
            m = len(ii)
            rt_stat[k] = float(np.mean(rt_mean[ii])) * m
            en_stat[k] = float(np.mean(en_mean[ii])) * m
    if key == "shortest_runtime_first":
        return np.argsort(rt_stat)
    if key == "longest_runtime_first":
        return np.argsort(-rt_stat)
    if key == "highest_energy_first":
        return np.argsort(-en_stat)
    if key == "lowest_energy_first":
        return np.argsort(en_stat)
    raise ValueError(key)


def _sort_units_fast(units, key: str, table: PredictionTable, unit_indices):
    """Same ordering as _sort_units from the vectorized mean arrays."""
    return [units[i] for i in _sort_order(key, table, unit_indices)]


def _predict_all(tasks, endpoints, store: TaskProfileStore):
    return {
        ep.name: {t.id: store.predict(t.fn, ep.name) for t in tasks}
        for ep in endpoints
    }


def _normalizers(tasks, endpoints, per_ep, transfer, carbon=None
                 ) -> tuple[float, float, float]:
    """SF1/SF2: pessimistic all-on-one-endpoint estimates (exact seed
    arithmetic — sequential accumulation keeps engine parity bitwise).
    With ``carbon`` given, SF3 is the matching pessimistic carbon estimate
    (all tasks on the endpoint, weighted by its own g/J rate)."""
    sf1 = sf2 = sf3 = 0.0
    for j, ep in enumerate(endpoints):
        st = SchedulerState([ep], transfer)
        st.assign(list(tasks), ep, per_ep[ep.name])
        e, c, _ = st.metrics()
        sf1, sf2 = max(sf1, e), max(sf2, c)
        if carbon is not None:
            sf3 = max(sf3, state_carbon_g(st, (carbon.rates[j],)))
    return max(sf1, 1e-9), max(sf2, 1e-9), max(sf3, 1e-9)


def _normalizers_fast(tasks, endpoints, table: PredictionTable, transfer,
                      carbon=None) -> tuple[float, float, float]:
    """Same SF1/SF2 values as :func:`_normalizers` (operation-identical
    float sequence) computed from the prediction table's flat rows instead
    of nested Prediction dicts."""
    heappop, heappush = heapq.heappop, heapq.heappush
    n = len(tasks)
    nbs = [t.not_before for t in tasks]
    sf1 = sf2 = sf3 = 0.0
    for ei, ep in enumerate(endpoints):
        name = ep.name
        # transfer delta of the whole workload as one unit, fresh cache
        tj, t_bytes, t_files = 0.0, 0.0, 0
        seen: set[tuple[str, str]] = set()
        for t in tasks:
            for src, n_files, nbytes, shared in t.inputs:
                if src == name:
                    continue
                key = (name, f"{src}:{n_files}:{nbytes}")
                if shared and key in seen:
                    continue
                if shared:
                    seen.add(key)
                tj += transfer.hops(src, name) * nbytes * E_INC_J_PER_BYTE
                t_bytes += nbytes
                t_files += n_files
        ready = transfer.predict_seconds(t_files, t_bytes)
        if ep.has_batch_scheduler:
            ready += ep.queue_delay_s
        row_rt, row_en = table.rt_rows[ei], table.en_rows[ei]
        slots = [0.0] * ep.cores
        heapq.heapify(slots)
        first = None
        last = 0.0
        dyn = 0.0
        for i in range(n):
            start = heappop(slots)
            if start < ready:
                start = ready
            if start < nbs[i]:
                start = nbs[i]
            end = start + row_rt[i]
            heappush(slots, end)
            if first is None or start < first:
                first = start
            if end > last:
                last = end
            dyn += row_en[i]
        # single-endpoint metrics(), same accumulation order
        c = last if last > 0.0 else 0.0
        e = tj
        if first is None:
            if not ep.has_batch_scheduler:
                e += ep.idle_power_w * c
        else:
            if ep.has_batch_scheduler:
                e += ep.idle_power_w * (last - first) + ep.startup_energy_j
            else:
                e += ep.idle_power_w * c
            e += dyn
        sf1, sf2 = max(sf1, e), max(sf2, c)
        if carbon is not None:
            # single-endpoint _carbon_terms_g, same expression grouping
            w = carbon.rates[ei]
            if first is None:
                g = w * (ep.idle_power_w * c) if not ep.has_batch_scheduler else 0.0
            elif ep.has_batch_scheduler:
                g = w * (ep.idle_power_w * (last - first)
                         + ep.startup_energy_j + dyn)
            else:
                g = w * (ep.idle_power_w * c + dyn)
            sf3 = max(sf3, g)
    return max(sf1, 1e-9), max(sf2, 1e-9), max(sf3, 1e-9)


def _warm_terms(warm: WarmWeights, alpha: float, sf1: float, sf2: float):
    """Per-endpoint warm-pool penalty added (last) to every candidate
    score: expected cold-start energy and latency normalized like the base
    objective terms.  Computed once per greedy call from the frozen
    :class:`WarmWeights` snapshot, so the three engines add the *same*
    doubles and the SoA run-memoization key is untouched (the penalty is
    constant within a call)."""
    return [
        alpha * cj / sf1 + (1 - alpha) * cs / sf2
        for cj, cs in zip(warm.cold_j, warm.cold_s)
    ]


def mhra(
    tasks: Sequence[TaskSpec],
    endpoints: Sequence[EndpointSpec],
    store: TaskProfileStore,
    transfer: TransferModel,
    alpha: float = 0.5,
    heuristics: Sequence[str] = HEURISTICS,
    clusters: list[list[int]] | None = None,
    engine: str = "delta",
    state: SchedulerState | None = None,
    carbon: CarbonWeights | None = None,
    lookahead: LookaheadWeights | None = None,
    alive: Sequence[bool] | None = None,
    warm: WarmWeights | None = None,
    fairness: FairnessWeights | None = None,
) -> Schedule:
    """Multi-Heuristic Resource Allocation. With clusters given, this is
    Cluster MHRA's greedy stage (one decision per cluster).

    ``state`` (delta/soa engines) places against a live timeline carried
    across arrival windows; the winning heuristic's result is committed
    into it.  ``carbon`` adds a third objective term
    ``gamma * G/SF3`` where G is the carbon-adjusted endpoint energy
    (gCO2) under the snapshot's per-endpoint g/J rates — all three
    engines score it, and ``carbon=None`` (the default) leaves every
    code path bitwise-identical to the carbon-free build.  ``lookahead``
    (a :class:`~repro.core.dag.LookaheadWeights` snapshot) adds the
    DAG-aware shaping term to every *candidate* score — rank-weighted
    finish times plus data-gravity transfer credits — in all three
    engines with the same clone/delta bitwise guarantee; the *reported*
    ``Schedule.objective`` stays the unshaped base objective (E, C are
    real; the shaping term prices hypothetical future placements).
    ``alive`` (per-endpoint booleans) masks dead endpoints out of
    candidate scoring — alive candidates' float sequences are untouched,
    so masking preserves clone/delta bitwise parity; an all-True mask is
    normalized to None (the unmodified hot path).  ``warm`` (a
    :class:`~repro.core.faults.WarmWeights` snapshot) adds a per-endpoint
    expected cold-start penalty as the final term of every candidate
    score — one extra SoA vector register.  ``fairness`` (a
    :class:`~repro.core.fairness.FairnessWeights` snapshot) adds the
    weighted-fair **advantage tax**: each task of an in-debt user is
    charged ``mu * debt`` times the advantage the candidate offers over
    the fleet-mean prediction (``relu(mean - predicted)``, energy and
    runtime terms SF-normalized like the base objective), steering
    over-budget users off premium endpoints.  All three engines add the
    same doubles (clone/delta bitwise, SoA one extra vector register
    whose per-task debt joins the run-memoization key); debt-free tasks
    — and ``fairness=None`` — leave every float sequence untouched.
    """
    if not heuristics:
        raise ValueError("mhra requires at least one ordering heuristic")
    if carbon is not None and len(carbon.rates) != len(endpoints):
        raise ValueError(
            f"carbon weights cover {len(carbon.rates)} endpoints but the "
            f"fleet has {len(endpoints)}"
        )
    if lookahead is not None and len(lookahead.hops_mean) != len(endpoints):
        raise ValueError(
            f"lookahead weights cover {len(lookahead.hops_mean)} endpoints "
            f"but the fleet has {len(endpoints)}"
        )
    if alive is not None:
        alive = tuple(bool(a) for a in alive)
        if len(alive) != len(endpoints):
            raise ValueError(
                f"alive mask covers {len(alive)} endpoints but the fleet "
                f"has {len(endpoints)}"
            )
        if not any(alive):
            raise ValueError("alive mask excludes every endpoint")
        if all(alive):
            alive = None   # no-op mask: keep the unmodified hot path
    if warm is not None and len(warm.cold_j) != len(endpoints):
        raise ValueError(
            f"warm weights cover {len(warm.cold_j)} endpoints but the "
            f"fleet has {len(endpoints)}"
        )
    if fairness is not None and (not fairness.debt or fairness.mu == 0.0):
        fairness = None   # no-op snapshot: keep the unmodified hot path
    if engine == "clone":
        if state is not None:
            raise ValueError("engine='clone' does not support live state")
        return _mhra_clone(tasks, endpoints, store, transfer, alpha,
                           heuristics, clusters, carbon, lookahead,
                           alive, warm, fairness)
    if engine == "auto":
        if state is not None:
            # online mode: match the live state's layout so no window ever
            # pays a from_heap/write_back conversion round-trip.  SoA-backed
            # states may still escalate to the jax scan per window — it
            # reads/writes the SoA layout directly, so the escalation is
            # conversion-free and reverts to soa on small windows.
            if isinstance(state, SoAState):
                engine = auto_engine(len(endpoints), len(tasks))
                if engine == "delta":
                    engine = "soa"
            else:
                engine = "delta"
        else:
            engine = auto_engine(len(endpoints), len(tasks))
    if engine not in ("delta", "soa", "jax"):
        raise ValueError(f"unknown engine {engine!r}")

    tasks = list(tasks)
    table = PredictionTable(tasks, endpoints, store)
    if clusters is None:
        units = [[t] for t in tasks]
    else:
        units = [[tasks[i] for i in c] for c in clusters]
    sf1, sf2, sf3 = _normalizers_fast(tasks, endpoints, table, transfer, carbon)

    unit_indices = [[table.index[t.id] for t in u] for u in units]
    if engine == "jax":
        return _mhra_jax(units, unit_indices, endpoints, table, transfer,
                         alpha, heuristics, sf1, sf2, state, carbon, sf3,
                         lookahead, alive, warm, fairness)
    if engine == "soa":
        return _mhra_soa(units, unit_indices, endpoints, table, transfer,
                         alpha, heuristics, sf1, sf2, state, carbon, sf3,
                         lookahead, alive, warm, fairness)
    soa_live: SoAState | None = None
    if isinstance(state, SoAState):
        # delta engine over a SoA-backed live state: run on a heap view,
        # adopt the result back into the SoA arrays
        soa_live, state = state, SchedulerState(endpoints, transfer)
        soa_live.write_back(state)
    best: Schedule | None = None
    best_state: SchedulerState | None = None
    for h in heuristics:
        ordered = _sort_units_fast(units, h, table, unit_indices)
        sched, end_state = _greedy_delta(
            ordered, endpoints, table, transfer, alpha, sf1, sf2, h, state,
            carbon, sf3, lookahead, alive, warm, fairness,
        )
        if best is None or sched.objective < best.objective:
            best, best_state = sched, end_state
    if state is not None:
        state.replace_with(best_state)
        # the winner's timeline IS the live timeline now; snapshot it so
        # the returned Schedule survives later windows' mutations (losing
        # heuristics' schedules never get copied — one O(live) copy per
        # call instead of one per heuristic)
        best.timeline = dict(best.timeline)
    if soa_live is not None:
        soa_live.replace_with(SoAState.from_heap(state))
    return best


def _mhra_soa(units, unit_indices, endpoints, table, transfer, alpha,
              heuristics, sf1, sf2, state, carbon=None, sf3=1.0,
              lookahead=None, alive=None, warm=None, fairness=None):
    """SoA-engine heuristic search: run :func:`_greedy_soa` per ordering
    heuristic, commit the winner into ``state`` (heap- or SoA-backed)."""
    heap_state: SchedulerState | None = None
    if isinstance(state, SchedulerState):
        heap_state, state = state, SoAState.from_heap(state)
    best: Schedule | None = None
    best_state: SoAState | None = None
    for h in heuristics:
        order = _sort_order(h, table, unit_indices)
        ordered = [units[i] for i in order]
        ordered_idx = [unit_indices[i] for i in order]
        sched, end_state = _greedy_soa(
            ordered, ordered_idx, endpoints, table, transfer, alpha,
            sf1, sf2, h, state, carbon, sf3, lookahead, alive, warm,
            fairness,
        )
        if best is None or sched.objective < best.objective:
            best, best_state = sched, end_state
    if heap_state is not None:
        best_state.write_back(heap_state)
        best.timeline = dict(best.timeline)
    elif state is not None:
        state.replace_with(best_state)
        best.timeline = dict(best.timeline)
    return best


def _mhra_jax(units, unit_indices, endpoints, table, transfer, alpha,
              heuristics, sf1, sf2, state, carbon=None, sf3=1.0,
              lookahead=None, alive=None, warm=None, fairness=None):
    """jax-engine heuristic search: one fused ``lax.scan`` greedy per
    window (all heuristics vmapped into a single device call), committing
    the winner into ``state`` exactly like :func:`_mhra_soa`.

    Parity-locked to the SoA engine: the scan reproduces ``_greedy_soa``'s
    float sequences double for double (see ``repro.kernels.placement``),
    the winning objective is recomputed from ``SoAState.metrics()`` on the
    final registers — the same authoritative accumulation soa reports —
    and first-min argmins break ties like ``np.argmin``.  Windows the fast
    path can't express (clustered units, multi-input tasks — e.g. DAG
    join stages whose promoted children carry several parent transfers)
    fall back to :func:`_mhra_soa`, which is assignment-identical by the
    existing contract.  The live ``SoAState`` is read into device arrays
    at the window boundary and only the winner's registers are written
    back — no per-decision host/device chatter.
    """
    if (not units) or any(len(u) != 1 or len(u[0].inputs) > 1 for u in units):
        return _mhra_soa(units, unit_indices, endpoints, table, transfer,
                         alpha, heuristics, sf1, sf2, state, carbon, sf3,
                         lookahead, alive, warm, fairness)
    try:
        from repro.kernels.placement import ops as pops
    except Exception:
        return _mhra_soa(units, unit_indices, endpoints, table, transfer,
                         alpha, heuristics, sf1, sf2, state, carbon, sf3,
                         lookahead, alive, warm, fairness)

    heap_state: SchedulerState | None = None
    if isinstance(state, SchedulerState):
        heap_state, state = state, SoAState.from_heap(state)
    base = state if state is not None else SoAState(endpoints, transfer)
    n_ep = len(endpoints)
    names = base.names

    # per-endpoint constants — same host numpy expressions as _greedy_soa,
    # so every scalar entering the scan is the same double
    idle = np.array([ep.idle_power_w for ep in endpoints])
    bt_mask = np.array([ep.has_batch_scheduler for ep in endpoints])
    su = np.array([ep.startup_energy_j for ep in endpoints])
    qd_vec = np.where(bt_mask, [ep.queue_delay_s for ep in endpoints], 0.0)
    idle_bt = np.where(bt_mask, idle, 0.0)
    su_bt = np.where(bt_mask, su, 0.0)
    idle_on_sum = float(idle[~bt_mask].sum())
    c_cur0 = float(max(base.last.max(initial=0.0), 0.0))
    used = base.first < np.inf
    span0 = np.where(used, base.last - base.first, 0.0)
    const0 = np.where(bt_mask & used, idle * span0 + su, 0.0) + base.dyn
    a1 = alpha / sf1
    b1 = (1.0 - alpha) / sf2
    if carbon is not None:
        rates_v = np.asarray(carbon.rates, dtype=float)
        g1 = carbon.gamma / sf3
        w_idle_on = float((rates_v * idle)[~bt_mask].sum())
    else:
        rates_v = np.zeros(n_ep)
        g1 = 0.0
        w_idle_on = 0.0
    const_g0 = rates_v * const0
    if lookahead is not None:
        lk_tail, lk_out = lookahead.tail_w, lookahead.out_j
        lk_ht = lookahead.hops_task
        hm_vec = np.asarray(lookahead.hops_mean, dtype=float)
        lam = lookahead.lam
    else:
        lk_tail = lk_out = lk_ht = None
        hm_vec = np.zeros(n_ep)
        lam = 0.0
    lam_b1 = lam * b1   # lk_c1 = (lam*b1)*u_tw, soa's left-assoc grouping
    lam_a1 = lam * a1
    fdebt = fairness.debt if fairness is not None else None
    f_mu = fairness.mu if fairness is not None else 0.0
    f_beta = 1.0 - alpha
    wt_v = (np.asarray(_warm_terms(warm, alpha, sf1, sf2))
            if warm is not None else np.zeros(n_ep))
    alive_v = (np.ones(n_ep, dtype=bool) if alive is None
               else np.asarray(alive, dtype=bool))

    # padded shapes: endpoint lanes / cores / tasks / input signatures
    E = pops.lane_bucket(n_ep)
    C = pops.bucket_pow2(max(ep.cores for ep in endpoints))
    n_units = len(units)
    T = pops.bucket_pow2(n_units)
    H = len(heuristics)

    def padv(v, fill=0.0):
        out = np.full(E, fill, dtype=float)
        out[:n_ep] = v
        return out

    # per-input-signature transfer table (slot 0 = the no-input dummy row:
    # zero adds, zero ready, staged everywhere — bitwise-inert)
    sig_index: dict[tuple, int] = {}
    add_rows = [np.zeros(E)]
    ready_list = [0.0]
    shared_list = [False]
    staged_rows = [np.ones(E, dtype=bool)]
    keys_list: list[list] = [[None] * n_ep]
    for u in units:
        t0 = u[0]
        if not t0.inputs:
            continue
        inp = t0.inputs[0]
        if inp in sig_index:
            continue
        src, n_files, nbytes, shared = inp
        ks = f"{src}:{n_files}:{nbytes}"
        keys = [None if n == src else (n, ks) for n in names]
        add = np.array([
            0.0 if k is None
            else transfer.hops(src, n) * nbytes * E_INC_J_PER_BYTE
            for n, k in zip(names, keys)
        ])
        staged = np.array([
            k is None or (shared and k in base.cached) for k in keys
        ])
        sig_index[inp] = len(add_rows)
        add_rows.append(padv(add))
        ready_list.append(transfer.predict_seconds(n_files, nbytes))
        shared_list.append(bool(shared))
        staged_rows.append(np.concatenate(
            [staged, np.ones(E - n_ep, dtype=bool)]))
        keys_list.append(keys)
    n_sigs = len(add_rows)
    S = pops.bucket_pow2(n_sigs)
    staged0 = np.ones((S, E), dtype=bool)
    staged0[:n_sigs] = np.stack(staged_rows)

    # carry seeds from the live state (pad lanes: fresh-endpoint registers
    # with zero slots — finite scores, masked dead before the argmin)
    slots0 = np.full((E, C), np.inf)
    slots0[n_ep:] = 0.0
    for ei in range(n_ep):
        sv = base.slot_view(ei)
        slots0[ei, :len(sv)] = sv
    mins0 = slots0.min(axis=1)
    first0 = padv(base.first, fill=np.inf)
    last0 = padv(base.last)
    dyn0 = padv(base.dyn)

    hm_p = padv(hm_vec)
    rtT, enT = table.transposed()
    en_mean, rt_mean = table.en_mean, table.rt_mean

    def tile(a):
        return np.broadcast_to(a, (H,) + a.shape).copy()

    xs = {
        "ti": np.zeros((H, T), dtype=np.int32),
        "hv_id": np.zeros((H, T), dtype=np.int32),
        "sig": np.zeros((H, T), dtype=np.int32),
        "ready_s": np.zeros((H, T)),
        "shared_s": np.zeros((H, T), dtype=bool),
        "nb": np.zeros((H, T)),
        "new_run": np.zeros((H, T), dtype=bool),
        "u_tw": np.zeros((H, T)),
        "u_oj": np.zeros((H, T)),
        "u_fd": np.zeros((H, T)),
        "valid": np.zeros((H, T), dtype=bool),
    }
    # one pass over the units computes every order-independent per-task
    # quantity; each heuristic then just permutes the shared arrays with
    # fancy indexing (the ordering is the only thing heuristics change)
    ti_all = np.fromiter((ui[0] for ui in unit_indices), dtype=np.intp,
                         count=n_units)
    nb_all = np.empty(n_units)
    sig_all = np.zeros(n_units, dtype=np.int32)
    u_tw_all = np.zeros(n_units)
    u_oj_all = np.zeros(n_units)
    u_fd_all = np.zeros(n_units)
    gid_all = np.empty(n_units, dtype=np.int64)
    key_ids: dict = {}
    # hop-vector table: row 0 is the fleet mean; producer-aware tasks get
    # their own (deduplicated) rows, indexed per task by ``hv_id``
    hv_rows = [hm_p]
    hv_ids: dict = {}
    hv_id_all = np.zeros(n_units, dtype=np.int32)
    tasks0 = [u[0] for u in units]
    if lk_tail is None and fdebt is None:
        # common case (no lookahead, no fairness): tight listcomp path —
        # the same (fn, inputs, not_before) run keys, far fewer dispatches
        key_list = [(t.fn, t.inputs, t.not_before) for t in tasks0]
        nb_all[:] = [k[2] for k in key_list]
        kid = key_ids.setdefault
        gid_all[:] = [kid(k, len(key_ids)) for k in key_list]
        if sig_index:
            sidx = sig_index.get
            sig_all[:] = [sidx(t.inputs[0], 0) if t.inputs else 0
                          for t in tasks0]
    else:
        for i, t0 in enumerate(tasks0):
            nb0 = t0.not_before
            nb_all[i] = nb0
            if lk_tail is not None:
                u_tw = lk_tail.get(t0.id, 0.0)
                u_oj = lk_out.get(t0.id, 0.0)
                u_tw_all[i] = u_tw
                u_oj_all[i] = u_oj
                key = (t0.fn, t0.inputs, nb0, u_tw, u_oj)
                if lk_ht is not None:
                    # same run-key split as the SoA engine: tasks with
                    # different consumer-hop vectors never share a run
                    hv_t = lk_ht.get(t0.id)
                    key = key + (hv_t,)
                    if hv_t is not None:
                        hid = hv_ids.get(hv_t)
                        if hid is None:
                            hid = hv_ids[hv_t] = len(hv_rows)
                            hv_rows.append(padv(np.asarray(hv_t)))
                        hv_id_all[i] = hid
            else:
                key = (t0.fn, t0.inputs, nb0)
            if fdebt is not None:
                u_fd = fdebt.get(t0.user, 0.0)
                u_fd_all[i] = u_fd
                key = key + (u_fd,)
            if t0.inputs:
                sig_all[i] = sig_index[t0.inputs[0]]
            gid_all[i] = key_ids.setdefault(key, len(key_ids))
    ready_arr = np.asarray(ready_list)
    shared_arr = np.asarray(shared_list, dtype=bool)

    orders: list[np.ndarray] = []
    memo_misses = 0
    for hi, h in enumerate(heuristics):
        order = np.asarray(_sort_order(h, table, unit_indices),
                           dtype=np.intp)
        orders.append(order)
        xs["ti"][hi, :n_units] = ti_all[order]
        xs["hv_id"][hi, :n_units] = hv_id_all[order]
        xs["valid"][hi, :n_units] = True
        g = gid_all[order]
        nr = xs["new_run"][hi, :n_units]
        nr[0] = True
        np.not_equal(g[1:], g[:-1], out=nr[1:])
        memo_misses += int(nr.sum())
        s = sig_all[order]
        xs["sig"][hi, :n_units] = s
        xs["ready_s"][hi, :n_units] = ready_arr[s]
        xs["shared_s"][hi, :n_units] = shared_arr[s]
        xs["nb"][hi, :n_units] = nb_all[order]
        xs["u_tw"][hi, :n_units] = u_tw_all[order]
        xs["u_oj"][hi, :n_units] = u_oj_all[order]
        xs["u_fd"][hi, :n_units] = u_fd_all[order]
    MEMO_STATS["misses"] += memo_misses
    MEMO_STATS["hits"] += H * n_units - memo_misses

    # per-task (E,) rows enter the scan as gathers into these small
    # constant tables (profile rows / transfer signatures / hop vectors)
    # rather than as (H, T, E) streams — same doubles, ~E× less traffic
    P = pops.bucket_pow2(rtT.shape[0], minimum=1)
    rt_tab = np.zeros((P, E))
    en_tab = np.zeros((P, E))
    rt_tab[:rtT.shape[0], :n_ep] = rtT
    en_tab[:enT.shape[0], :n_ep] = enT
    fen_tab = np.zeros(P)
    frt_tab = np.zeros(P)
    fen_tab[:len(en_mean)] = en_mean
    frt_tab[:len(rt_mean)] = rt_mean
    add_tab = np.zeros((S, E))
    add_tab[:n_sigs] = np.stack(add_rows)
    V = pops.bucket_pow2(len(hv_rows))
    hv_tab = np.zeros((V, E))
    hv_tab[:len(hv_rows)] = np.stack(hv_rows)

    f64 = np.float64
    consts = {
        "idle_bt": padv(idle_bt),
        "su_bt": padv(su_bt),
        "qd": padv(qd_vec),
        "rates": padv(rates_v),
        "wt": padv(wt_v),
        "alive": np.concatenate([alive_v, np.zeros(E - n_ep, dtype=bool)]),
        "rt_tab": rt_tab, "en_tab": en_tab,
        "fen_tab": fen_tab, "frt_tab": frt_tab,
        "add_tab": add_tab, "hv_tab": hv_tab,
        "scalars": {
            "a1": f64(a1), "b1": f64(b1), "g1": f64(g1),
            "idle_on_sum": f64(idle_on_sum), "w_idle_on": f64(w_idle_on),
            "lam_b1": f64(lam_b1), "lam_a1": f64(lam_a1),
            "alpha": f64(alpha), "sf1": f64(sf1), "sf2": f64(sf2),
            "f_beta": f64(f_beta), "f_mu": f64(f_mu),
        },
    }
    init = {
        "mins": tile(mins0), "slots": tile(slots0), "first": tile(first0),
        "last": tile(last0), "dyn": tile(dyn0), "const": tile(padv(const0)),
        "const_g": tile(padv(const_g0)),
        "e_base": np.zeros((H, E)), "nl_r": np.zeros((H, E)),
        "g_base_r": np.zeros((H, E)), "lk_r": np.zeros((H, E)),
        "fw_r": np.zeros((H, E)), "staged": tile(staged0),
        "c_cur": np.full(H, c_cur0), "tj": np.full(H, base.transfer_j),
        "c_sum_b": np.zeros(H), "tj_b": np.zeros(H),
        "cg_sum_b": np.zeros(H),
    }

    out, (ei_y, s_y, e_y) = pops.greedy_window(n_ep, consts, init, xs)

    # winner: objective recomputed from SoAState.metrics() per heuristic —
    # the same authoritative float sequence _greedy_soa reports
    best_hi = -1
    best_obj = None
    best_rec = None
    for hi, h in enumerate(heuristics):
        st_h = base.clone(keep_timeline=False)
        free, offsets = st_h.free, st_h.offsets
        for ei in range(n_ep):
            cores = offsets[ei + 1] - offsets[ei]
            free[offsets[ei]:offsets[ei + 1]] = out["slots"][hi, ei, :cores]
        st_h.first = out["first"][hi, :n_ep].copy()
        st_h.last = out["last"][hi, :n_ep].copy()
        st_h.dyn = out["dyn"][hi, :n_ep].copy()
        st_h.transfer_j = float(out["tj"][hi])
        e_tot, c_max, tjv = st_h.metrics()
        obj_f = alpha * e_tot / sf1 + (1 - alpha) * c_max / sf2
        carbon_g = None
        if carbon is not None:
            carbon_g = state_carbon_g(st_h, carbon.rates)
            obj_f = obj_f + carbon.gamma * carbon_g / sf3
        if best_obj is None or obj_f < best_obj:
            best_hi, best_obj = hi, obj_f
            best_rec = (st_h, obj_f, e_tot, c_max, tjv, carbon_g)

    st_w, obj_f, e_tot, c_max, tjv, carbon_g = best_rec
    h_name = heuristics[best_hi]
    assignments: dict[str, str] = {}
    timeline = dict(base.timeline)
    for t0, ei_v, s_v, e_v in zip(
        (units[i][0] for i in orders[best_hi]), ei_y[best_hi, :n_units],
        s_y[best_hi, :n_units], e_y[best_hi, :n_units],
    ):
        assignments[t0.id] = names[int(ei_v)]
        timeline[t0.id] = (float(s_v), float(e_v))
    st_w.timeline = timeline
    st_w.cached = set(base.cached)
    staged_out = out["staged"][best_hi]
    for si in range(1, n_sigs):
        if not shared_list[si]:
            continue
        row0, rowf, keys = staged_rows[si], staged_out[si], keys_list[si]
        for ei in range(n_ep):
            if rowf[ei] and not row0[ei] and keys[ei] is not None:
                st_w.cached.add(keys[ei])
    sched = Schedule(assignments, obj_f, e_tot, c_max, tjv, h_name,
                     timeline, carbon_g=carbon_g)
    if heap_state is not None:
        st_w.write_back(heap_state)
        sched.timeline = dict(sched.timeline)
    elif state is not None:
        state.replace_with(st_w)
        sched.timeline = dict(sched.timeline)
    return sched


def _greedy_delta(
    units, endpoints, table: PredictionTable, transfer, alpha, sf1, sf2,
    heuristic, base_state: SchedulerState | None = None,
    carbon: CarbonWeights | None = None, sf3: float = 1.0,
    lookahead: LookaheadWeights | None = None,
    alive: tuple | None = None, warm: WarmWeights | None = None,
    fairness: FairnessWeights | None = None,
) -> tuple[Schedule, SchedulerState]:
    """Delta-evaluation greedy: score each candidate endpoint from the
    *change* it makes (peek the slot heap, delta the idle-span / dynamic
    energy / transfer terms) and commit only the winner.

    Every floating-point operation mirrors the clone engine's
    state.assign() + state.metrics() sequence, so objectives (and hence
    assignments) are bitwise identical; the savings are structural — no
    per-candidate copies of every heap, dict, and cache set.  Running
    C_max and per-endpoint span terms are maintained incrementally (exact:
    max() never rounds, and the span term is recomputed from the same
    operands the metrics loop would use).
    """
    state = (
        base_state.clone(keep_timeline=True)
        if base_state is not None
        else SchedulerState(endpoints, transfer)
    )
    n_ep = len(endpoints)
    names = [ep.name for ep in endpoints]
    eps_r = range(n_ep)
    # unpack live state into index-parallel lists for the hot loop
    slots = [state.slots[n] for n in names]
    first = [state.first_start[n] for n in names]
    last = [state.last_end[n] for n in names]
    dyn = [state.dyn_energy[n] for n in names]
    cached = state.cached
    timeline = state.timeline
    transfer_j = state.transfer_j
    # per-endpoint constants
    idle = [ep.idle_power_w for ep in endpoints]
    bt = [ep.has_batch_scheduler for ep in endpoints]
    su = [ep.startup_energy_j for ep in endpoints]
    qd = [ep.queue_delay_s if ep.has_batch_scheduler else 0.0 for ep in endpoints]
    # running C_max (max never rounds: equals max over the last_end values)
    c_cur = 0.0
    for v in last:
        if v > c_cur:
            c_cur = v
    # per-endpoint idle-span terms, recomputed only on commit — the same
    # float expression metrics() evaluates per candidate in the clone engine
    sterm = [
        idle[j] * (last[j] - first[j]) + su[j]
        if (bt[j] and first[j] is not None) else 0.0
        for j in eps_r
    ]
    mins = [h[0] for h in slots]  # heap peeks, refreshed on commit
    rates = carbon.rates if carbon is not None else None
    gamma = carbon.gamma if carbon is not None else 0.0
    lw = lookahead
    if lw is not None:
        lk_tail, lk_out, lk_hm, lam = lw.tail_w, lw.out_j, lw.hops_mean, lw.lam
        lk_ht = lw.hops_task    # producer-aware per-task hop vectors (or None)
    wt = _warm_terms(warm, alpha, sf1, sf2) if warm is not None else None
    fw = fairness
    if fw is not None:
        fdebt = fw.debt
        f_mu = fw.mu
        # fleet-mean predictions: the same doubles the clone engine's
        # per-task np.mean over an endpoint list produces (see
        # PredictionTable.rt_mean)
        frt_mean = table.rt_mean.tolist()
        fen_mean = table.en_mean.tolist()
    idx = table.index
    rt_rows, en_rows = table.rt_rows, table.en_rows
    hops = transfer.hops
    predict_seconds = transfer.predict_seconds
    beta = 1 - alpha
    heappop, heappush, heapreplace = heapq.heappop, heapq.heappush, heapq.heapreplace
    inf = np.inf
    assignments: dict[str, str] = {}
    # per-input caches shared across candidates: the "src:files:bytes" key
    # string, per-endpoint key tuples, hop counts, and transfer-time
    # predictions are all pure functions of their inputs
    key_cache: dict[tuple, str] = {}
    inp_info: dict[tuple, tuple] = {}
    hop_cache: dict[tuple[str, str], float] = {}
    ready_cache: dict[tuple, float] = {}

    for unit in units:
        single = len(unit) == 1
        single_inp = None
        if single:
            t0 = unit[0]
            ti = idx[t0.id]
            nb0 = t0.not_before
            no_inputs = not t0.inputs
            if not no_inputs and len(t0.inputs) == 1:
                inp = t0.inputs[0]
                single_inp = inp_info.get(inp)
                if single_inp is None:
                    src, n_files, nbytes, shared = inp
                    ks = f"{src}:{n_files}:{nbytes}"
                    single_inp = inp_info[inp] = (
                        src, n_files, nbytes, shared,
                        # per-endpoint cache key; None where src == endpoint
                        [None if names[j] == src else (names[j], ks)
                         for j in eps_r],
                    )
        else:
            no_inputs = all(not t.inputs for t in unit)
        if not no_inputs and single_inp is None:
            prep = []
            for t in unit:
                for inp in t.inputs:
                    ks = key_cache.get(inp)
                    if ks is None:
                        src, n_files, nbytes, shared = inp
                        ks = key_cache[inp] = f"{src}:{n_files}:{nbytes}"
                    prep.append((inp[0], ks, inp[1], inp[2], inp[3]))
        if lw is not None:
            if single:
                u_tw = lk_tail.get(t0.id, 0.0)
                u_oj = lk_out.get(t0.id, 0.0)
                if lk_ht is not None:
                    hv_u = lk_ht.get(t0.id, lk_hm)
            else:
                u_oj = 0.0
                for t in unit:
                    u_oj += lk_out.get(t.id, 0.0)
                if lk_ht is not None:
                    lk_rows = [(lk_out.get(t.id, 0.0),
                                lk_ht.get(t.id, lk_hm)) for t in unit]
        if fw is not None:
            if single:
                u_fd = fdebt.get(t0.user, 0.0)
            else:
                u_fidx = [(idx[t.id], fdebt.get(t.user, 0.0)) for t in unit]
        best_obj = inf
        best = None
        for ei in eps_r:
            if alive is not None and not alive[ei]:
                continue   # dead endpoint: masked out of candidate scoring
            # --- transfer delta -------------------------------------------
            if no_inputs:
                tj = transfer_j
                ready = qd[ei]
                new_keys = ()
            elif single_inp is not None:
                src, n_files, nbytes, shared, keys4 = single_inp
                key = keys4[ei]
                if key is None or (shared and key in cached):
                    # local input, or shared data already staged here:
                    # no transfer — identical to the no-input case
                    tj = transfer_j
                    ready = qd[ei]
                    new_keys = ()
                else:
                    new_keys = (key,) if shared else ()
                    h = hop_cache.get(key)
                    if h is None:
                        h = hop_cache[key] = hops(src, names[ei])
                    tj = transfer_j + h * nbytes * E_INC_J_PER_BYTE
                    ready = ready_cache.get(key)
                    if ready is None:
                        ready = ready_cache[key] = predict_seconds(n_files, nbytes)
                    ready = ready + qd[ei]
            else:
                name = names[ei]
                tj = transfer_j
                t_bytes, t_files = 0.0, 0
                new_keys = []
                for src, ks, n_files, nbytes, shared in prep:
                    if src == name:
                        continue
                    key = (name, ks)
                    if shared and (key in cached or key in new_keys):
                        continue
                    if shared:
                        new_keys.append(key)
                    h = hop_cache.get(key)
                    if h is None:
                        h = hop_cache[key] = hops(src, name)
                    tj += h * nbytes * E_INC_J_PER_BYTE
                    t_bytes += nbytes
                    t_files += n_files
                if t_files:
                    rk = (t_files, t_bytes)
                    ready = ready_cache.get(rk)
                    if ready is None:
                        ready = ready_cache[rk] = predict_seconds(t_files, t_bytes)
                    ready = ready + qd[ei]
                else:
                    ready = qd[ei]
            # --- simulate the placement -----------------------------------
            if single:
                s0 = mins[ei]
                start = s0 if s0 >= ready else ready
                if start < nb0:
                    start = nb0
                end = start + rt_rows[ei][ti]
                f = first[ei]
                nf = start if (f is None or start < f) else f
                l = last[ei]
                nl = end if end > l else l
                nd = dyn[ei] + en_rows[ei][ti]
                heap = None
                entries = (t0.id, start, end)
            else:
                heap = list(slots[ei])
                row_rt, row_en = rt_rows[ei], en_rows[ei]
                nf = first[ei]
                nl = last[ei]
                nd = dyn[ei]
                entries = []
                for t in unit:
                    tix = idx[t.id]
                    start = heappop(heap)
                    if start < ready:
                        start = ready
                    if start < t.not_before:
                        start = t.not_before
                    end = start + row_rt[tix]
                    heappush(heap, end)
                    if nf is None or start < nf:
                        nf = start
                    if end > nl:
                        nl = end
                    nd = nd + row_en[tix]
                    entries.append((t.id, start, end))
            # --- objective, same accumulation order as metrics() ----------
            c = nl if nl > c_cur else c_cur
            e = tj
            if rates is None:
                for j in eps_r:
                    if j == ei:
                        if bt[ei]:
                            e += idle[ei] * (nl - nf) + su[ei]
                        else:
                            e += idle[ei] * c
                        e += nd
                    elif bt[j]:
                        if first[j] is not None:
                            e += sterm[j]
                            e += dyn[j]
                    else:
                        e += idle[j] * c
                        if first[j] is not None:
                            e += dyn[j]
                obj = alpha * e / sf1 + beta * c / sf2
            else:
                # carbon twin: accumulate gCO2 beside e with the exact
                # per-endpoint expressions of _carbon_terms_g
                g = 0.0
                for j in eps_r:
                    if j == ei:
                        if bt[ei]:
                            e += idle[ei] * (nl - nf) + su[ei]
                            e += nd
                            g += rates[ei] * (idle[ei] * (nl - nf) + su[ei]
                                              + nd)
                        else:
                            e += idle[ei] * c
                            e += nd
                            g += rates[ei] * (idle[ei] * c + nd)
                    elif bt[j]:
                        if first[j] is not None:
                            e += sterm[j]
                            e += dyn[j]
                            g += rates[j] * (sterm[j] + dyn[j])
                    else:
                        e += idle[j] * c
                        if first[j] is not None:
                            e += dyn[j]
                            g += rates[j] * (idle[j] * c + dyn[j])
                        else:
                            g += rates[j] * (idle[j] * c)
                obj = alpha * e / sf1 + beta * c / sf2 + gamma * g / sf3
            if lw is not None:
                # DAG-aware shaping: rank-weighted finish times + the
                # gravity of shipping this unit's outputs off-endpoint.
                # Same float expression as the clone engine's loop.
                if single:
                    lk_tail_sum = u_tw * end
                else:
                    lk_tail_sum = 0.0
                    for _tid, _s, _e in entries:
                        lk_tail_sum += lk_tail.get(_tid, 0.0) * _e
                if lk_ht is None:
                    grav = u_oj * lk_hm[ei]
                elif single:
                    grav = u_oj * hv_u[ei]
                else:
                    # producer-aware: each task's bytes priced at *its*
                    # predicted-consumer hop vector
                    grav = 0.0
                    for _oj, _hv in lk_rows:
                        grav += _oj * _hv[ei]
                obj = obj + lam * (alpha * grav / sf1
                                   + beta * lk_tail_sum / sf2)
            if fw is not None:
                # advantage tax: each in-debt task pays mu*debt times the
                # advantage this endpoint offers over the fleet-mean
                # prediction.  Same float expression as the clone engine's
                # loop (and re-grouped elementwise by the SoA register).
                f_j = 0.0
                f_s = 0.0
                if single:
                    if u_fd != 0.0:
                        adv_j = fen_mean[ti] - en_rows[ei][ti]
                        if adv_j > 0.0:
                            f_j += u_fd * adv_j
                        adv_s = frt_mean[ti] - rt_rows[ei][ti]
                        if adv_s > 0.0:
                            f_s += u_fd * adv_s
                else:
                    for tix, d in u_fidx:
                        if d != 0.0:
                            adv_j = fen_mean[tix] - row_en[tix]
                            if adv_j > 0.0:
                                f_j += d * adv_j
                            adv_s = frt_mean[tix] - row_rt[tix]
                            if adv_s > 0.0:
                                f_s += d * adv_s
                obj = obj + f_mu * (alpha * f_j / sf1 + beta * f_s / sf2)
            if wt is not None:
                obj = obj + wt[ei]
            if obj < best_obj:
                best_obj = obj
                best = (ei, tj, new_keys, heap, entries, nf, nl, nd)
        # --- commit the winner --------------------------------------------
        if best is None:
            raise RuntimeError(
                "no live endpoint available for placement (alive mask "
                "excludes the whole fleet)"
            )
        ei, tj, new_keys, heap, entries, nf, nl, nd = best
        transfer_j = tj
        if new_keys:
            cached.update(new_keys)
        if heap is None:
            tid, start, end = entries
            heapreplace(slots[ei], end)
            timeline[tid] = (start, end)
            assignments[tid] = names[ei]
        else:
            slots[ei] = heap
            name = names[ei]
            for tid, start, end in entries:
                timeline[tid] = (start, end)
                assignments[tid] = name
        mins[ei] = slots[ei][0]
        first[ei] = nf
        last[ei] = nl
        dyn[ei] = nd
        if nl > c_cur:
            c_cur = nl
        if bt[ei]:
            sterm[ei] = idle[ei] * (nl - nf) + su[ei]

    # write the loop-local state back into the SchedulerState
    for ei in eps_r:
        n = names[ei]
        state.slots[n] = slots[ei]
        state.first_start[n] = first[ei]
        state.last_end[n] = last[ei]
        state.dyn_energy[n] = dyn[ei]
    state.transfer_j = transfer_j
    e, c, tj = state.metrics()
    obj = alpha * e / sf1 + (1 - alpha) * c / sf2
    carbon_g = None
    if rates is not None:
        carbon_g = state_carbon_g(state, rates)
        obj = obj + gamma * carbon_g / sf3
    # the timeline is passed by reference: mhra() snapshots the winning
    # heuristic's copy once, iff a live state adopts it
    sched = Schedule(assignments, obj, e, c, tj, heuristic,
                     state.timeline, carbon_g=carbon_g)
    return sched, state


def _greedy_soa(
    units, unit_indices, endpoints, table: PredictionTable, transfer,
    alpha, sf1, sf2, heuristic, base_state: SoAState | None = None,
    carbon: CarbonWeights | None = None, sf3: float = 1.0,
    lookahead: LookaheadWeights | None = None,
    alive: tuple | None = None, warm: WarmWeights | None = None,
    fairness: FairnessWeights | None = None,
) -> tuple[Schedule, SoAState]:
    """Structure-of-arrays greedy: score a unit against *every* endpoint in
    a fixed handful of vectorized passes instead of a Python loop over
    candidates.

    The per-candidate objective is algebraically identical to the delta
    engine's but regrouped for vectorization::

        e(i) = transfer_j(i) + (C - const_i) + IDLE_ON * c(i) + self(i)

    where ``C = sum_j const_j`` collects every endpoint's standing
    contribution (span term + dynamic energy for batch endpoints, dynamic
    energy for always-on ones), ``IDLE_ON`` is the total always-on idle
    draw (each always-on endpoint charges ``idle * C_max`` whichever
    candidate wins), and ``self(i)`` is candidate i's refreshed span/dyn
    term.  The regrouped sum can differ from the delta engine's sequential
    accumulation by ~1 ulp, so objectives agree to ``rtol << 1e-12`` and
    argmin decisions only diverge on exact ties — which both engines break
    identically (first index).  The *final* objective is recomputed from
    ``state.metrics()``, whose float sequence matches the heap state's
    exactly, so equal assignments imply bitwise-equal reported objectives.

    Slot peeks come from a per-endpoint ``mins`` register over the state's
    flat free-time array; a commit overwrites the argmin slot (same
    multiset evolution as heap pop+push) and refreshes only that
    endpoint's min.
    """
    state = (
        base_state.clone(keep_timeline=True)
        if base_state is not None
        else SoAState(endpoints, transfer)
    )
    n_ep = len(endpoints)
    names = state.names
    eps_r = range(n_ep)
    free = state.free
    offsets = state.offsets
    first, last, dyn = state.first, state.last, state.dyn
    cached = state.cached
    timeline = state.timeline
    transfer_j = state.transfer_j
    mins = state.slot_mins()

    # per-endpoint constants
    idle = np.array([ep.idle_power_w for ep in endpoints])
    bt_mask = np.array([ep.has_batch_scheduler for ep in endpoints])
    su = np.array([ep.startup_energy_j for ep in endpoints])
    qd_vec = np.where(bt_mask, [ep.queue_delay_s for ep in endpoints], 0.0)
    idle_bt = np.where(bt_mask, idle, 0.0)
    su_bt = np.where(bt_mask, su, 0.0)
    idle_on_sum = float(idle[~bt_mask].sum())

    c_cur = float(max(last.max(initial=0.0), 0.0))
    # standing per-endpoint objective contributions (see docstring)
    used = first < np.inf
    span = np.where(used, last - first, 0.0)
    const = np.where(bt_mask & used, idle * span + su, 0.0) + dyn
    static = const.sum() - const

    # python-float mirrors of every register the singleton fast path reads
    # scalar-by-scalar: a numpy scalar index costs ~5x a list index, and at
    # small fleets those constant factors dominate per-decision latency
    # (the 4-endpoint soa-vs-delta regression).  The arrays stay
    # authoritative for the vectorized passes; commits dual-write.  Values
    # are the same float64 doubles either way, so parity is untouched.
    mins_l = mins.tolist()
    first_l = first.tolist()
    last_l = last.tolist()
    dyn_l = dyn.tolist()
    const_l = const.tolist()
    qd_l = qd_vec.tolist()
    idle_bt_l = idle_bt.tolist()
    su_bt_l = su_bt.tolist()
    bt_l = bt_mask.tolist()
    # per-endpoint slot lists are authoritative during this call (python
    # min/index replace np.argmin/np.min reductions on tiny arrays); the
    # flat free array is rebuilt once at the end
    slots_l = [free[offsets[j]:offsets[j + 1]].tolist() for j in eps_r]
    run_rt_l = run_en_l = None
    nl_l = e_base_l = obj_l = g_base_l = lk_l = None

    rtT, enT = table.transposed()
    a1 = alpha / sf1
    b1 = (1.0 - alpha) / sf2
    # carbon term: one extra vector register (const_g = rates*const) and a
    # weighted always-on idle sum; everything else reuses the e machinery
    if carbon is not None:
        rates_v = np.asarray(carbon.rates, dtype=float)
        g1 = carbon.gamma / sf3
        w_idle_on = float((rates_v * idle)[~bt_mask].sum())
        const_g = rates_v * const
        static_g = const_g.sum() - const_g
        g_base = np.empty(n_ep)
        gbuf = np.empty(n_ep)
        rates_l = rates_v.tolist()
        const_g_l = const_g.tolist()
    else:
        rates_v = None
    # lookahead term: one extra vector register computed per run basis —
    # lk = lam*b1*tail_w*end + lam*a1*out_j*hops_mean.  Both factors are
    # part of the run key, so within a run only the committed endpoint's
    # entry needs the scalar refresh (its candidate end moved).
    if lookahead is not None:
        lk_tail = lookahead.tail_w
        lk_out = lookahead.out_j
        hm_vec = np.asarray(lookahead.hops_mean, dtype=float)
        hm_l = hm_vec.tolist()
        lam = lookahead.lam
        lk = np.empty(n_ep)
        lk_tailv = np.empty(n_ep)
        lk_c1 = lk_c2 = 0.0
        u_tw = u_oj = 0.0
        # producer-aware gravity: per-run hop vector (fleet mean unless the
        # task carries its own predicted-consumer vector); the per-task
        # choice joins the memo key so runs never mix vectors
        lk_ht = lookahead.hops_task
        run_hv = hm_vec
        run_hv_l = hm_l
    else:
        lk = None
        lk_ht = None
    # warm-pool term: one extra vector register, constant over the whole
    # call (the WarmWeights snapshot is per-placement-call), added as the
    # final term of every candidate score — same doubles as the delta
    # engine's `obj + wt[ei]`.
    if warm is not None:
        wt_l = _warm_terms(warm, alpha, sf1, sf2)
        wt_v = np.asarray(wt_l)
    else:
        wt_l = wt_v = None
    # fairness term: one extra vector register per run (the advantage tax
    # depends only on the run's predictions and the task's user-debt, so
    # it is constant within a run and the per-task debt joins the memo
    # key).  The elementwise op sequence mirrors the delta engine's
    # scalar accumulation — multiplication commutes bitwise, so the
    # register holds the *same doubles*, not a ~1ulp regroup.
    if fairness is not None:
        fdebt = fairness.debt
        f_mu = fairness.mu
        f_beta = 1.0 - alpha
        frt_mean = table.rt_mean
        fen_mean = table.en_mean
        fw_v = np.zeros(n_ep)
        fjv = np.empty(n_ep)
        fsv = np.empty(n_ep)
        fbuf = np.empty(n_ep)
        fw_l = fw_v.tolist()
        u_fd = 0.0
    else:
        fdebt = fw_l = None
    # dead-endpoint mask: applied *after* every term add so masked entries
    # stay +inf across memo hits (the commit/C_max refreshes below only
    # touch live endpoints); the run memo key is untouched — the mask is
    # constant for the whole call
    if alive is not None:
        alive_l = list(alive)
        dead_idx = np.flatnonzero(~np.asarray(alive, dtype=bool))
    else:
        alive_l = dead_idx = None
    memo_hits = memo_misses = 0
    assignments: dict[str, str] = {}
    # preallocated per-unit buffers
    start = np.empty(n_ep)
    end = np.empty(n_ep)
    nf = np.empty(n_ep)
    nl = np.empty(n_ep)
    nd = np.empty(n_ep)
    c = np.empty(n_ep)
    e = np.empty(n_ep)
    e_base = np.empty(n_ep)   # per-candidate score minus its C_max terms
    obj = np.empty(n_ep)
    tmp = np.empty(n_ep)
    # per-input-signature transfer vectors (single-input singleton units):
    # staged[j] => placing on j transfers nothing (local data, or a shared
    # key already cached); eff_* are the staged-aware add/ready vectors
    sig_cache: dict[tuple, dict] = {}

    def _sig(inp):
        rec = sig_cache.get(inp)
        if rec is None:
            src, n_files, nbytes, shared = inp
            ks = f"{src}:{n_files}:{nbytes}"
            keys = [None if n == src else (n, ks) for n in names]
            add = np.array([
                0.0 if k is None else transfer.hops(src, n) * nbytes * E_INC_J_PER_BYTE
                for n, k in zip(names, keys)
            ])
            ready = transfer.predict_seconds(n_files, nbytes)
            staged = np.array([
                k is None or (shared and k in cached) for k in keys
            ])
            rec = sig_cache[inp] = {
                "keys": keys, "add": add, "ready": ready, "shared": shared,
                "staged": staged,
                "eff_add": np.where(staged, 0.0, add),
                "eff_ready": np.where(staged, 0.0, ready) + qd_vec,
            }
            # python-float mirrors for the scalar commit path (kept in
            # sync with the arrays at every staging update)
            rec["eff_add_l"] = rec["eff_add"].tolist()
            rec["eff_ready_l"] = rec["eff_ready"].tolist()
        return rec

    # --- run memoization over the sorted unit stream ----------------------
    # Sorting makes identical (fn, inputs) singletons consecutive, and a
    # commit touches exactly one endpoint's registers.  Within such a run,
    # every other candidate's score is stale only by a *uniform* shift
    # (the committed endpoint's standing-term delta + any transfer energy
    # are charged to every candidate alike), so the argmin is unchanged:
    # only the committed endpoint's entry needs a scalar refresh, computed
    # against the run's basis (C_sum_b, tj_b) so comparisons stay exact.
    # A commit that raises C_max shifts candidates *non*-uniformly (each
    # candidate's own makespan term saturates differently), so that — or
    # any general-path unit — forces a fresh vectorized pass.
    run_key = None
    need_full = True
    c_sum_b = tj_b = cg_sum_b = 0.0
    run_rec: dict | None = None
    run_rt = run_en = None
    for unit, uidx in zip(units, unit_indices):
        if len(unit) == 1 and len(unit[0].inputs) <= 1:
            # ---- fast path: singleton unit, zero or one input ------------
            t0 = unit[0]
            ti = uidx[0]
            nb0 = t0.not_before
            # not_before is part of the run identity: tasks with different
            # ready floors score differently even with equal (fn, inputs)
            # — epoch-batched DAG promotion exists to keep a wide stage's
            # floors equal so its children coalesce into one run.  Under
            # lookahead the per-task rank/gravity weights join the key.
            if lk is None:
                key = (t0.fn, t0.inputs, nb0)
            else:
                u_tw = lk_tail.get(t0.id, 0.0)
                u_oj = lk_out.get(t0.id, 0.0)
                key = (t0.fn, t0.inputs, nb0, u_tw, u_oj)
                if lk_ht is not None:
                    # tasks with different consumer-hop vectors must not
                    # share a run (the gravity register differs)
                    hv_t = lk_ht.get(t0.id)
                    key = key + (hv_t,)
            if fdebt is not None:
                # tasks taxed differently must not share a run
                u_fd = fdebt.get(t0.user, 0.0)
                key = key + (u_fd,)
            if need_full or key != run_key:
                memo_misses += 1
                run_key = key
                run_rec = rec = _sig(t0.inputs[0]) if t0.inputs else None
                run_rt = rtT[ti]
                run_en = enT[ti]
                c_sum_b = float(const.sum())
                np.subtract(c_sum_b, const, out=static)
                if rates_v is not None:
                    cg_sum_b = float(const_g.sum())
                    np.subtract(cg_sum_b, const_g, out=static_g)
                tj_b = transfer_j
                if rec is None:
                    np.maximum(mins, qd_vec, out=start)
                else:
                    np.maximum(mins, rec["eff_ready"], out=start)
                if nb0 > 0.0:
                    np.maximum(start, nb0, out=start)
                np.add(start, run_rt, out=end)
                np.minimum(first, start, out=nf)
                np.maximum(last, end, out=nl)
                np.add(dyn, run_en, out=nd)
                np.maximum(nl, c_cur, out=c)
                # candidate span/dyn term: idle*(nl-nf)+su batch, 0 else
                np.subtract(nl, nf, out=tmp)
                np.multiply(tmp, idle_bt, out=tmp)
                np.add(tmp, su_bt, out=tmp)
                # e_base: everything except the C_max-dependent terms, so
                # a later C_max advance only refreshes c and recombines
                np.add(static, nd, out=e_base)
                np.add(e_base, tmp, out=e_base)
                if rec is not None:
                    np.add(e_base, rec["eff_add"], out=e_base)
                np.add(e_base, tj_b, out=e_base)
                if rates_v is not None:
                    # carbon base: static_g + rates*(span term + dyn);
                    # tmp still holds the span terms here
                    np.add(tmp, nd, out=gbuf)
                    np.multiply(gbuf, rates_v, out=gbuf)
                    np.add(gbuf, static_g, out=g_base)
                np.multiply(c, idle_on_sum, out=e)
                np.add(e, e_base, out=e)
                np.multiply(e, a1, out=obj)
                np.multiply(c, b1, out=tmp)
                np.add(obj, tmp, out=obj)
                if rates_v is not None:
                    np.multiply(c, w_idle_on, out=gbuf)
                    np.add(gbuf, g_base, out=gbuf)
                    np.multiply(gbuf, g1, out=gbuf)
                    np.add(obj, gbuf, out=obj)
                if lk is not None:
                    if lk_ht is not None:
                        if hv_t is None:
                            run_hv, run_hv_l = hm_vec, hm_l
                        else:
                            run_hv = np.asarray(hv_t, dtype=float)
                            run_hv_l = run_hv.tolist()
                    lk_c1 = lam * b1 * u_tw
                    lk_c2 = lam * a1 * u_oj
                    np.multiply(end, lk_c1, out=lk)
                    np.multiply(run_hv, lk_c2, out=tmp)
                    np.add(lk, tmp, out=lk)
                    np.add(obj, lk, out=obj)
                if fdebt is not None:
                    if u_fd != 0.0:
                        # elementwise the delta scalar loop: debt-scaled
                        # relu(mean - predicted), alpha/beta-weighted,
                        # SF-normalized, times mu
                        np.subtract(fen_mean[ti], run_en, out=fbuf)
                        np.multiply(fbuf, u_fd, out=fjv)
                        fjv[fbuf <= 0.0] = 0.0
                        np.subtract(frt_mean[ti], run_rt, out=fbuf)
                        np.multiply(fbuf, u_fd, out=fsv)
                        fsv[fbuf <= 0.0] = 0.0
                        np.multiply(fjv, alpha, out=fjv)
                        np.divide(fjv, sf1, out=fjv)
                        np.multiply(fsv, f_beta, out=fsv)
                        np.divide(fsv, sf2, out=fsv)
                        np.add(fjv, fsv, out=fw_v)
                        np.multiply(fw_v, f_mu, out=fw_v)
                    else:
                        # debt-free user: the delta engine still adds the
                        # (zero) term, so mirror the add exactly
                        fw_v.fill(0.0)
                    np.add(obj, fw_v, out=obj)
                if wt_v is not None:
                    np.add(obj, wt_v, out=obj)
                if dead_idx is not None:
                    obj[dead_idx] = np.inf
                # refresh the scalar mirrors the hit/commit path works on
                # (arrays go stale between misses; nothing vectorized
                # reads nl/e_base/obj/lk/g_base until the next full pass
                # overwrites them)
                run_rt_l = run_rt.tolist()
                run_en_l = run_en.tolist()
                nl_l = nl.tolist()
                e_base_l = e_base.tolist()
                obj_l = obj.tolist()
                if rates_v is not None:
                    g_base_l = g_base.tolist()
                if lk is not None:
                    lk_l = lk.tolist()
                if fdebt is not None:
                    fw_l = fw_v.tolist()
                need_full = False
            else:
                memo_hits += 1
                rec = run_rec
            ei = obj_l.index(min(obj_l))   # first-min, like np.argmin
            # ---- commit: same scalar float ops as the vectorized pass,
            # read from the python mirrors (identical doubles) ------------
            if rec is None:
                ready_e = qd_l[ei]
            else:
                ready_e = rec["eff_ready_l"][ei]
                transfer_j += rec["eff_add_l"][ei]
                if rec["shared"] and not rec["staged"][ei]:
                    cached.add(rec["keys"][ei])
                    rec["staged"][ei] = True
                    rec["eff_add"][ei] = 0.0
                    rec["eff_add_l"][ei] = 0.0
                    rec["eff_ready"][ei] = qd_l[ei]
                    rec["eff_ready_l"][ei] = qd_l[ei]
            m_e = mins_l[ei]
            start_v = m_e if m_e >= ready_e else ready_e
            if start_v < nb0:
                start_v = nb0
            end_v = start_v + run_rt_l[ei]
            f_e = first_l[ei]
            nf_v = start_v if start_v < f_e else f_e
            l_e = last_l[ei]
            nl_v = end_v if end_v > l_e else l_e
            nd_v = dyn_l[ei] + run_en_l[ei]
            # heap pop-min+push as "overwrite the first min slot": the
            # mins register *is* the slot min, so list.index finds the
            # same slot np.argmin would
            sl_l = slots_l[ei]
            sl_l[sl_l.index(m_e)] = end_v
            m2 = min(sl_l)
            mins[ei] = m2
            mins_l[ei] = m2
            first[ei] = nf_v
            first_l[ei] = nf_v
            last[ei] = nl_v
            last_l[ei] = nl_v
            dyn[ei] = nd_v
            dyn_l[ei] = nd_v
            c_e = (
                (nl_v - nf_v) * idle_bt_l[ei] + su_bt_l[ei] + nd_v
                if bt_l[ei] else nd_v
            )
            const[ei] = c_e
            const_l[ei] = c_e
            if rates_v is not None:
                cg_e = rates_l[ei] * c_e
                const_g[ei] = cg_e
                const_g_l[ei] = cg_e
            # refresh this endpoint's next-task row on the run's basis
            # (same scalar float op order as the vectorized pass)
            ready2 = rec["eff_ready_l"][ei] if rec is not None else ready_e
            s2 = m2 if m2 >= ready2 else ready2
            if s2 < nb0:
                s2 = nb0
            e2 = s2 + run_rt_l[ei]
            nf2 = s2 if s2 < nf_v else nf_v
            nl2 = e2 if e2 > nl_v else nl_v
            nl_l[ei] = nl2
            e_b = (c_sum_b - c_e) + (nd_v + run_en_l[ei])
            e_b = e_b + ((nl2 - nf2) * idle_bt_l[ei] + su_bt_l[ei])
            if rec is not None:
                e_b = e_b + rec["eff_add_l"][ei]
            e_b = e_b + tj_b
            e_base_l[ei] = e_b
            if rates_v is not None:
                g_b = (cg_sum_b - cg_e) + rates_l[ei] * (
                    ((nl2 - nf2) * idle_bt_l[ei] + su_bt_l[ei])
                    + (nd_v + run_en_l[ei])
                )
                g_base_l[ei] = g_b
            if lk is not None:
                # same scalar op order as the vectorized lk pass
                lk_e = e2 * lk_c1 + run_hv_l[ei] * lk_c2
                lk_l[ei] = lk_e
            if end_v > c_cur:
                # C_max advanced: refresh every candidate's makespan terms
                # from the cached e_base (the rest of the score is intact).
                # Scalar loop over the mirrors, element-for-element the
                # ops the vectorized refresh performed — identical floats.
                c_cur = end_v
                for j in eps_r:
                    if alive_l is not None and not alive_l[j]:
                        continue   # dead: leave its score at +inf
                    c2 = nl_l[j]
                    if c2 < c_cur:
                        c2 = c_cur
                    e_s = idle_on_sum * c2 + e_base_l[j]
                    if rates_v is None:
                        o_v = a1 * e_s + b1 * c2
                    else:
                        o_v = (a1 * e_s + b1 * c2
                               + g1 * (w_idle_on * c2 + g_base_l[j]))
                    if lk is not None:
                        o_v = o_v + lk_l[j]
                    if fw_l is not None:
                        # run-constant: predictions and user-debt don't
                        # move on commit
                        o_v = o_v + fw_l[j]
                    if wt_l is not None:
                        o_v = o_v + wt_l[j]
                    obj_l[j] = o_v
            else:
                c2 = nl2 if nl2 > c_cur else c_cur
                e_s = idle_on_sum * c2 + e_b
                if rates_v is None:
                    o_v = a1 * e_s + b1 * c2
                else:
                    o_v = (a1 * e_s + b1 * c2
                           + g1 * (w_idle_on * c2 + g_b))
                if lk is not None:
                    o_v = o_v + lk_e
                if fw_l is not None:
                    o_v = o_v + fw_l[ei]
                if wt_l is not None:
                    o_v = o_v + wt_l[ei]
                obj_l[ei] = o_v
            timeline[t0.id] = (start_v, end_v)
            assignments[t0.id] = names[ei]
            continue
        # ---- general path: clustered / multi-input units -----------------
        run_key = None
        need_full = True
        memo_misses += 1
        np.subtract(const.sum(), const, out=static)
        if rates_v is not None:
            np.subtract(const_g.sum(), const_g, out=static_g)
        heappop, heappush = heapq.heappop, heapq.heappush
        tjv = np.empty(n_ep)
        cand = []
        for ei in eps_r:
            tj_e, ready_e, new_keys = _unit_transfer_delta(
                transfer, cached, transfer_j, unit, names[ei]
            )
            ready_e += qd_vec[ei]
            heap = list(slots_l[ei])   # authoritative slots (see init)
            heapq.heapify(heap)
            f_e = first[ei]
            l_e = last[ei]
            d_e = dyn[ei]
            tl_e = 0.0
            fj_e = fs_e = 0.0
            entries = []
            for t, tix in zip(unit, uidx):
                s_v = heappop(heap)
                if s_v < ready_e:
                    s_v = ready_e
                if s_v < t.not_before:
                    s_v = t.not_before
                e_v = s_v + rtT[tix, ei]
                heappush(heap, e_v)
                if s_v < f_e:
                    f_e = s_v
                if e_v > l_e:
                    l_e = e_v
                d_e = d_e + enT[tix, ei]
                if lk is not None:
                    tl_e += lk_tail.get(t.id, 0.0) * e_v
                if fdebt is not None:
                    # same scalar accumulation as the delta general path
                    d = fdebt.get(t.user, 0.0)
                    if d != 0.0:
                        adv_j = fen_mean[tix] - enT[tix, ei]
                        if adv_j > 0.0:
                            fj_e += d * adv_j
                        adv_s = frt_mean[tix] - rtT[tix, ei]
                        if adv_s > 0.0:
                            fs_e += d * adv_s
                entries.append((t.id, s_v, e_v))
            tjv[ei] = tj_e
            nf[ei] = f_e
            nl[ei] = l_e
            nd[ei] = d_e
            if lk is not None:
                lk_tailv[ei] = tl_e
            if fdebt is not None:
                fjv[ei] = fj_e
                fsv[ei] = fs_e
            cand.append((heap, entries, new_keys))
        np.maximum(nl, c_cur, out=c)
        np.subtract(nl, nf, out=tmp)
        np.multiply(tmp, idle_bt, out=tmp)
        np.add(tmp, su_bt, out=tmp)
        if rates_v is not None:
            np.add(tmp, nd, out=gbuf)
            np.multiply(gbuf, rates_v, out=gbuf)
            np.add(gbuf, static_g, out=g_base)
        np.multiply(c, idle_on_sum, out=e)
        np.add(e, static, out=e)
        np.add(e, nd, out=e)
        np.add(e, tmp, out=e)
        np.add(e, tjv, out=e)
        np.multiply(e, a1, out=obj)
        np.multiply(c, b1, out=tmp)
        np.add(obj, tmp, out=obj)
        if rates_v is not None:
            np.multiply(c, w_idle_on, out=gbuf)
            np.add(gbuf, g_base, out=gbuf)
            np.multiply(gbuf, g1, out=gbuf)
            np.add(obj, gbuf, out=obj)
        if lk is not None:
            u_oj = 0.0
            for t in unit:
                u_oj += lk_out.get(t.id, 0.0)
            np.multiply(lk_tailv, lam * b1, out=lk)
            if lk_ht is None:
                np.multiply(hm_vec, lam * a1 * u_oj, out=tmp)
            else:
                # producer-aware: gravity accumulates per task at each
                # task's own consumer-hop vector
                tmp.fill(0.0)
                for t in unit:
                    _oj = lk_out.get(t.id, 0.0)
                    if _oj != 0.0:
                        _hv = lk_ht.get(t.id)
                        np.add(tmp,
                               np.multiply(
                                   hm_vec if _hv is None
                                   else np.asarray(_hv, dtype=float),
                                   _oj),
                               out=tmp)
                np.multiply(tmp, lam * a1, out=tmp)
            np.add(lk, tmp, out=lk)
            np.add(obj, lk, out=obj)
        if fdebt is not None:
            np.multiply(fjv, alpha, out=fjv)
            np.divide(fjv, sf1, out=fjv)
            np.multiply(fsv, f_beta, out=fsv)
            np.divide(fsv, sf2, out=fsv)
            np.add(fjv, fsv, out=fbuf)
            np.multiply(fbuf, f_mu, out=fbuf)
            np.add(obj, fbuf, out=obj)
        if wt_v is not None:
            np.add(obj, wt_v, out=obj)
        if dead_idx is not None:
            obj[dead_idx] = np.inf
        ei = int(np.argmin(obj))
        heap, entries, new_keys = cand[ei]
        transfer_j = float(tjv[ei])
        cached.update(new_keys)
        if new_keys:
            for rec in sig_cache.values():  # invalidate staged views
                if rec["shared"]:
                    for j, k in enumerate(rec["keys"]):
                        if k in new_keys and not rec["staged"][j]:
                            rec["staged"][j] = True
                            rec["eff_add"][j] = 0.0
                            rec["eff_add_l"][j] = 0.0
                            rec["eff_ready"][j] = qd_vec[j]
                            rec["eff_ready_l"][j] = qd_l[j]
        slots_l[ei] = heap
        mins[ei] = heap[0]
        mins_l[ei] = heap[0]
        nf_v = float(nf[ei])
        nl_v = float(nl[ei])
        nd_v = float(nd[ei])
        first[ei] = nf_v
        first_l[ei] = nf_v
        last[ei] = nl_v
        last_l[ei] = nl_v
        dyn[ei] = nd_v
        dyn_l[ei] = nd_v
        if nl_v > c_cur:
            c_cur = nl_v
        c_e = (
            idle_bt_l[ei] * (nl_v - nf_v) + su_bt_l[ei] + nd_v
            if bt_l[ei] else nd_v
        )
        const[ei] = c_e
        const_l[ei] = c_e
        if rates_v is not None:
            cg_e = rates_l[ei] * c_e
            const_g[ei] = cg_e
            const_g_l[ei] = cg_e
        name = names[ei]
        for tid, s_v, e_v in entries:
            timeline[tid] = (s_v, e_v)
            assignments[tid] = name

    MEMO_STATS["hits"] += memo_hits
    MEMO_STATS["misses"] += memo_misses
    # the python slot lists were authoritative during the loop; restore the
    # flat free array (the state outlives this call)
    for j in eps_r:
        free[offsets[j]:offsets[j + 1]] = slots_l[j]
    state.transfer_j = transfer_j
    e_tot, c_max, tj = state.metrics()
    obj_f = alpha * e_tot / sf1 + (1 - alpha) * c_max / sf2
    carbon_g = None
    if carbon is not None:
        carbon_g = state_carbon_g(state, carbon.rates)
        obj_f = obj_f + carbon.gamma * carbon_g / sf3
    # timeline by reference; _mhra_soa snapshots the winner's once
    sched = Schedule(assignments, obj_f, e_tot, c_max, tj, heuristic,
                     state.timeline, carbon_g=carbon_g)
    return sched, state


# ---------------------------------------------------------------------------
# Reference clone-based engine (the seed implementation, kept verbatim for
# parity tests and benchmarks/scheduler_overhead.py)
# ---------------------------------------------------------------------------


def _mhra_clone(tasks, endpoints, store, transfer, alpha, heuristics, clusters,
                carbon=None, lookahead=None, alive=None, warm=None,
                fairness=None):
    per_ep = _predict_all(tasks, endpoints, store)
    if clusters is None:
        units = [[t] for t in tasks]
    else:
        units = [[tasks[i] for i in c] for c in clusters]
    best: Schedule | None = None
    for h in heuristics:
        # predictions used for ordering: endpoint-mean
        mean_preds = {
            t.id: Prediction(
                float(np.mean([per_ep[e.name][t.id].runtime_s for e in endpoints])),
                float(np.mean([per_ep[e.name][t.id].energy_j for e in endpoints])),
                True,
            )
            for t in tasks
        }
        ordered = _sort_units(units, h, mean_preds)
        sched = _greedy_multi_ep(
            ordered, endpoints, per_ep, transfer, alpha, tasks, h, carbon,
            lookahead, alive, warm, fairness,
        )
        if best is None or sched.objective < best.objective:
            best = sched
    return best


def _greedy_multi_ep(units, endpoints, per_ep, transfer, alpha, tasks,
                     heuristic, carbon=None, lookahead=None, alive=None,
                     warm=None, fairness=None):
    # SF normalizers from endpoint-specific predictions
    sf1, sf2, sf3 = _normalizers(tasks, endpoints, per_ep, transfer, carbon)
    wt = _warm_terms(warm, alpha, sf1, sf2) if warm is not None else None
    if fairness is not None:
        fdebt = fairness.debt
        # fleet-mean predictions per task; the delta/SoA engines read the
        # same doubles from PredictionTable.{rt,en}_mean
        fmean = {
            t.id: (
                float(np.mean([per_ep[e.name][t.id].energy_j for e in endpoints])),
                float(np.mean([per_ep[e.name][t.id].runtime_s for e in endpoints])),
            )
            for t in tasks
        }

    lk_ht = lookahead.hops_task if lookahead is not None else None
    state = SchedulerState(endpoints, transfer)
    assignments: dict[str, str] = {}
    for unit in units:
        u_oj = 0.0
        if lookahead is not None:
            for t in unit:
                u_oj += lookahead.out_j.get(t.id, 0.0)
            if lk_ht is not None:
                lk_rows = [(lookahead.out_j.get(t.id, 0.0),
                            lk_ht.get(t.id, lookahead.hops_mean))
                           for t in unit]
        best_obj, best_ep = np.inf, None
        for ei, ep in enumerate(endpoints):
            if alive is not None and not alive[ei]:
                continue   # dead endpoint: masked out of candidate scoring
            trial = state.clone()
            # candidate timelines start empty, so with lookahead on the
            # trial records exactly this unit's (start, end) pairs
            trial.assign(unit, ep, per_ep[ep.name],
                         record_timeline=lookahead is not None)
            e, c, _ = trial.metrics()
            obj = alpha * e / sf1 + (1 - alpha) * c / sf2
            if carbon is not None:
                obj = obj + carbon.gamma * state_carbon_g(trial, carbon.rates) / sf3
            if lookahead is not None:
                lk_tail_sum = 0.0
                for t in unit:
                    lk_tail_sum += (lookahead.tail_w.get(t.id, 0.0)
                                    * trial.timeline[t.id][1])
                if lk_ht is None:
                    grav = u_oj * lookahead.hops_mean[ei]
                else:
                    # producer-aware: price each task's bytes at the hop
                    # distance of its children's predicted endpoints
                    grav = 0.0
                    for _oj, _hv in lk_rows:
                        grav += _oj * _hv[ei]
                obj = obj + lookahead.lam * (
                    alpha * grav / sf1
                    + (1 - alpha) * lk_tail_sum / sf2
                )
            if fairness is not None:
                # advantage tax (see _greedy_delta: bitwise-identical
                # accumulation, same term position)
                f_j = 0.0
                f_s = 0.0
                for t in unit:
                    d = fdebt.get(t.user, 0.0)
                    if d != 0.0:
                        p = per_ep[ep.name][t.id]
                        m_j, m_s = fmean[t.id]
                        adv_j = m_j - p.energy_j
                        if adv_j > 0.0:
                            f_j += d * adv_j
                        adv_s = m_s - p.runtime_s
                        if adv_s > 0.0:
                            f_s += d * adv_s
                obj = obj + fairness.mu * (
                    alpha * f_j / sf1 + (1 - alpha) * f_s / sf2
                )
            if wt is not None:
                obj = obj + wt[ei]
            if obj < best_obj:
                best_obj, best_ep = obj, ep
        if best_ep is None:
            raise RuntimeError(
                "no live endpoint available for placement (alive mask "
                "excludes the whole fleet)"
            )
        state.assign(unit, best_ep, per_ep[best_ep.name], record_timeline=True)
        for t in unit:
            assignments[t.id] = best_ep.name
    e, c, tj = state.metrics()
    obj = alpha * e / sf1 + (1 - alpha) * c / sf2
    carbon_g = None
    if carbon is not None:
        carbon_g = state_carbon_g(state, carbon.rates)
        obj = obj + carbon.gamma * carbon_g / sf3
    return Schedule(assignments, obj, e, c, tj, heuristic, state.timeline,
                    carbon_g=carbon_g)


def compute_clusters(
    tasks, endpoints, table: PredictionTable, max_cluster_size: int = 40
) -> list[list[int]]:
    """Agglomerative clusters from the vectorized prediction table (same
    features/energies as the clone path's nested-dict construction)."""
    n_ep = len(endpoints)
    feats = np.empty((len(tasks), 2 * n_ep))
    for ei in range(n_ep):
        feats[:, 2 * ei] = table.rt[ei]
        feats[:, 2 * ei + 1] = table.en[ei]
    energies = table.en_mean
    cap = min(
        [ep.startup_energy_j for ep in endpoints if ep.has_batch_scheduler]
        or [np.inf]
    )
    return agglomerative_cluster(
        feats, energies, cap, max_cluster_size=max_cluster_size
    )


def cluster_mhra(
    tasks: Sequence[TaskSpec],
    endpoints: Sequence[EndpointSpec],
    store: TaskProfileStore,
    transfer: TransferModel,
    alpha: float = 0.5,
    heuristics: Sequence[str] = HEURISTICS,
    max_cluster_size: int = 40,
    engine: str = "delta",
    state: SchedulerState | None = None,
    carbon: CarbonWeights | None = None,
    lookahead: LookaheadWeights | None = None,
    alive: Sequence[bool] | None = None,
    warm: WarmWeights | None = None,
    fairness: FairnessWeights | None = None,
) -> Schedule:
    """Algorithm 1: agglomerative clustering + per-cluster greedy MHRA."""
    tasks = list(tasks)
    if engine == "clone":
        per_ep = _predict_all(tasks, endpoints, store)
        feats = np.array(
            [
                [v for ep in endpoints for v in (
                    per_ep[ep.name][t.id].runtime_s, per_ep[ep.name][t.id].energy_j
                )]
                for t in tasks
            ]
        )
        energies = np.array(
            [np.mean([per_ep[ep.name][t.id].energy_j for ep in endpoints]) for t in tasks]
        )
        cap = min(
            [ep.startup_energy_j for ep in endpoints if ep.has_batch_scheduler]
            or [np.inf]
        )
        clusters = agglomerative_cluster(
            feats, energies, cap, max_cluster_size=max_cluster_size
        )
        return mhra(tasks, endpoints, store, transfer, alpha, heuristics,
                    clusters, engine="clone", carbon=carbon,
                    lookahead=lookahead, alive=alive, warm=warm,
                    fairness=fairness)
    table = PredictionTable(tasks, endpoints, store)
    clusters = compute_clusters(tasks, endpoints, table, max_cluster_size)
    return mhra(tasks, endpoints, store, transfer, alpha, heuristics,
                clusters, engine=engine, state=state, carbon=carbon,
                lookahead=lookahead, alive=alive, warm=warm,
                fairness=fairness)


# ---------------------------------------------------------------------------
# Baselines (Table V rows)
# ---------------------------------------------------------------------------


def fixed_assignment(
    tasks, endpoints, store, transfer, pick: Callable[[int, TaskSpec], str],
    state: SchedulerState | None = None,
) -> Schedule:
    tasks = list(tasks)
    per_ep = PredictionTable(tasks, endpoints, store).per_ep()
    by_ep = {e.name: e for e in endpoints}
    state = state if state is not None else SchedulerState(endpoints, transfer)
    assignments = {}
    for i, t in enumerate(tasks):
        name = pick(i, t)
        state.assign([t], by_ep[name], per_ep[name], record_timeline=True)
        assignments[t.id] = name
    e, c, tj = state.metrics()
    return Schedule(assignments, np.nan, e, c, tj, "fixed", dict(state.timeline))


def round_robin(tasks, endpoints, store, transfer,
                state: SchedulerState | None = None, offset: int = 0) -> Schedule:
    names = [e.name for e in endpoints]
    return fixed_assignment(
        tasks, endpoints, store, transfer,
        lambda i, t: names[(i + offset) % len(names)], state=state,
    )


def single_site(tasks, endpoints, store, transfer, site: str,
                state: SchedulerState | None = None) -> Schedule:
    names = {e.name for e in endpoints}
    if site not in names:
        raise ValueError(
            f"single_site requires site to be one of {sorted(names)}, got {site!r}"
        )
    return fixed_assignment(tasks, endpoints, store, transfer,
                            lambda i, t: site, state=state)
