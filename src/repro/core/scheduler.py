"""MHRA + Cluster MHRA schedulers (paper §III-F, Algorithm 1) and the
Round-Robin / single-site baselines evaluated in Table V.

Objective:  O = alpha * E_tot/SF1 + (1-alpha) * C_max/SF2
  E_tot = sum_n [ idle_power * allocated-span(+startup) + sum dyn task E ]
          + transfer energy;  desktop-style endpoints charge idle over the
          whole workflow span (paper: power drawn whether or not tasks run).
  SF1/SF2 = pessimistic all-on-one-machine estimates.

Two greedy engines share the same arithmetic:

  * ``engine="delta"`` (default) scores a candidate endpoint by previewing
    only the *change* it makes to the live state — peek/copy that one
    endpoint's slot heap, delta the idle-span and dynamic-energy terms —
    then commits only the winner.  O(endpoints * log cores) per decision.
  * ``engine="clone"`` is the original clone-per-candidate greedy kept as
    the reference implementation for parity tests and the overhead
    benchmark.  O(endpoints^2 * cores) copies per decision.

Both engines perform bitwise-identical floating-point operations, so they
produce identical assignments and objective values; ``tests/
test_policy_engine.py`` asserts this.  The delta engine also accepts a
live ``SchedulerState`` so the online engine (``repro.core.engine``) can
place arrival windows against the timeline carried over from previous
windows.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Sequence

import numpy as np

from repro.core.clustering import agglomerative_cluster
from repro.core.endpoint import EndpointSpec
from repro.core.predictor import Prediction, TaskProfileStore
from repro.core.transfer import E_INC_J_PER_BYTE, TransferModel


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    id: str
    fn: str
    inputs: tuple = ()          # tuple of TransferRequest templates (src, files, bytes, shared)
    user: str = "user0"


@dataclasses.dataclass
class Schedule:
    assignments: dict[str, str]
    objective: float
    energy_j: float
    makespan_s: float
    transfer_j: float
    heuristic: str = ""
    timeline: dict[str, tuple[float, float]] = dataclasses.field(default_factory=dict)

    def edp(self) -> float:
        return self.energy_j * self.makespan_s

    def w_ed2p(self) -> float:
        return self.energy_j * self.makespan_s ** 2


HEURISTICS = (
    "shortest_runtime_first",
    "longest_runtime_first",
    "highest_energy_first",
    "lowest_energy_first",
)


class SchedulerState:
    """Incremental greedy-scheduling state over endpoint timelines.

    Carried across arrival windows by the online engine.  The legacy clone
    engine evaluates candidates with :meth:`clone` + :meth:`assign` +
    :meth:`metrics`; the delta greedy (:func:`_greedy_delta`) unpacks this
    state into flat lists and performs the *same float operations* inline
    — any edit to assign()/metrics() arithmetic must be mirrored there to
    preserve the engines' bitwise parity.
    """

    def __init__(self, endpoints: Sequence[EndpointSpec], transfer: TransferModel):
        self.eps = list(endpoints)
        self.transfer = transfer
        self.slots = {e.name: [0.0] * e.cores for e in endpoints}  # min-heaps
        for h in self.slots.values():
            heapq.heapify(h)
        self.first_start = {e.name: None for e in endpoints}
        self.last_end = {e.name: 0.0 for e in endpoints}
        self.dyn_energy = {e.name: 0.0 for e in endpoints}
        self.transfer_j = 0.0
        self.cached: set[tuple[str, str]] = set()
        self.timeline: dict[str, tuple[float, float]] = {}

    def clone(self, keep_timeline: bool = False) -> "SchedulerState":
        s = SchedulerState.__new__(SchedulerState)
        s.eps, s.transfer = self.eps, self.transfer
        s.slots = {k: list(v) for k, v in self.slots.items()}
        s.first_start = dict(self.first_start)
        s.last_end = dict(self.last_end)
        s.dyn_energy = dict(self.dyn_energy)
        s.transfer_j = self.transfer_j
        s.cached = set(self.cached)
        # candidate previews don't need task-level timelines; scratch states
        # that may become the live state (multi-heuristic search) do
        s.timeline = dict(self.timeline) if keep_timeline else {}
        return s

    def advance_to(self, now: float) -> None:
        """Raise every worker slot's free time to at least ``now`` — the
        online engine calls this when an arrival window opens after an idle
        gap, so placement previews can't schedule starts in the past
        (mirroring the testbed's ``max(slot, now)`` dispatch rule)."""
        for h in self.slots.values():
            changed = False
            for i, v in enumerate(h):
                if v < now:
                    h[i] = now
                    changed = True
            if changed:
                heapq.heapify(h)

    def replace_with(self, other: "SchedulerState") -> None:
        """Adopt another state's contents in place (winner of a heuristic
        search replacing the live online state)."""
        self.slots = other.slots
        self.first_start = other.first_start
        self.last_end = other.last_end
        self.dyn_energy = other.dyn_energy
        self.transfer_j = other.transfer_j
        self.cached = other.cached
        self.timeline = other.timeline

    # -- transfer bookkeeping shared by assign() and preview() -------------
    def _transfer_delta(self, unit, name: str):
        """(transfer_j_after, ready_s, cache_keys_added) for placing this
        unit's inputs on endpoint ``name`` — no state mutation."""
        transfer_j = self.transfer_j
        t_bytes, t_files = 0.0, 0
        new_cached: list[tuple[str, str]] = []
        for t in unit:
            for src, n_files, nbytes, shared in t.inputs:
                if src == name:
                    continue
                key = (name, f"{src}:{n_files}:{nbytes}")
                if shared and (key in self.cached or key in new_cached):
                    continue
                if shared:
                    new_cached.append(key)
                transfer_j += (
                    self.transfer.hops(src, name) * nbytes * E_INC_J_PER_BYTE
                )
                t_bytes += nbytes
                t_files += n_files
        ready = self.transfer.predict_seconds(t_files, t_bytes)
        return transfer_j, ready, new_cached

    def assign(
        self,
        unit: Sequence[TaskSpec],
        ep: EndpointSpec,
        preds: dict[str, Prediction],
        record_timeline: bool = False,
    ) -> None:
        name = ep.name
        transfer_j, ready, new_cached = self._transfer_delta(unit, name)
        self.transfer_j = transfer_j
        self.cached.update(new_cached)
        if ep.has_batch_scheduler:
            ready += ep.queue_delay_s
        slots = self.slots[name]
        for t in unit:
            p = preds[t.id]
            start = max(heapq.heappop(slots), ready)
            end = start + p.runtime_s
            heapq.heappush(slots, end)
            if self.first_start[name] is None or start < self.first_start[name]:
                self.first_start[name] = start
            self.last_end[name] = max(self.last_end[name], end)
            self.dyn_energy[name] += p.energy_j
            if record_timeline:
                self.timeline[t.id] = (start, end)

    def metrics(self) -> tuple[float, float, float]:
        """(E_tot, C_max, transfer_j)."""
        c_max = max([v for v in self.last_end.values()] + [0.0])
        e_tot = self.transfer_j
        for ep in self.eps:
            n = ep.name
            if self.first_start[n] is None:
                if not ep.has_batch_scheduler:
                    # always-on endpoint idles through the workflow regardless
                    e_tot += ep.idle_power_w * c_max
                continue
            if ep.has_batch_scheduler:
                span = self.last_end[n] - self.first_start[n]
                e_tot += ep.idle_power_w * span + ep.startup_energy_j
            else:
                e_tot += ep.idle_power_w * c_max
            e_tot += self.dyn_energy[n]
        return e_tot, c_max, self.transfer_j


# kept as an alias: pre-refactor code and tests referred to _State
_State = SchedulerState


class PredictionTable:
    """Per-(task, endpoint) predictions as numpy arrays + flat lists.

    ``store.predict`` depends only on (fn, endpoint), so predictions are
    computed once per unique pair instead of once per task — at 1792 tasks
    over 7 functions that is ~256x fewer predictor calls than the nested
    dicts the clone engine builds.
    """

    def __init__(self, tasks, endpoints, store: TaskProfileStore):
        self.tasks = list(tasks)
        self.endpoints = list(endpoints)
        self.index = {t.id: i for i, t in enumerate(self.tasks)}
        cache: dict[tuple[str, str], Prediction] = {}
        n_ep = len(self.endpoints)
        # one predict per unique (fn, endpoint), expanded to tasks by
        # fancy indexing — same float values task-by-task
        fn_col: dict[str, int] = {}
        fn_ids = np.empty(len(self.tasks), dtype=np.intp)
        for ti, t in enumerate(self.tasks):
            c = fn_col.get(t.fn)
            if c is None:
                c = fn_col[t.fn] = len(fn_col)
            fn_ids[ti] = c
        base_rt = np.empty((n_ep, len(fn_col)))
        base_en = np.empty((n_ep, len(fn_col)))
        for ei, ep in enumerate(self.endpoints):
            for fn, c in fn_col.items():
                p = cache[(fn, ep.name)] = store.predict(fn, ep.name)
                base_rt[ei, c] = p.runtime_s
                base_en[ei, c] = p.energy_j
        self.rt = base_rt[:, fn_ids]
        self.en = base_en[:, fn_ids]
        self._cache = cache
        # python-float rows for the hot greedy loop (numpy scalar indexing
        # is ~5x slower than list indexing in CPython)
        self.rt_rows = self.rt.tolist()
        self.en_rows = self.en.tolist()
        # endpoint-mean predictions used by the ordering heuristics; the
        # axis-0 reduce performs the same sequential adds as the clone
        # engine's per-task np.mean over an endpoint list
        self.rt_mean = self.rt.mean(axis=0)
        self.en_mean = self.en.mean(axis=0)

    def per_ep(self) -> dict[str, dict[str, Prediction]]:
        """Nested-dict view matching ``_predict_all`` for legacy callers."""
        return {
            ep.name: {t.id: self._cache[(t.fn, ep.name)] for t in self.tasks}
            for ep in self.endpoints
        }


def _unit_stats(unit, preds):
    rt = float(np.mean([preds[t.id].runtime_s for t in unit]))
    en = float(np.mean([preds[t.id].energy_j for t in unit]))
    return rt * len(unit), en * len(unit)


def _sort_units(units, key: str, preds):
    stats = [_unit_stats(u, preds) for u in units]
    if key == "shortest_runtime_first":
        order = np.argsort([s[0] for s in stats])
    elif key == "longest_runtime_first":
        order = np.argsort([-s[0] for s in stats])
    elif key == "highest_energy_first":
        order = np.argsort([-s[1] for s in stats])
    elif key == "lowest_energy_first":
        order = np.argsort([s[1] for s in stats])
    else:
        raise ValueError(key)
    return [units[i] for i in order]


def _sort_units_fast(units, key: str, table: PredictionTable, unit_indices):
    """Same ordering as _sort_units from the vectorized mean arrays.

    For singleton units the stat is the mean itself (mean of one element
    times one is the identity bitwise), so no per-unit np.mean calls.
    """
    rt_mean, en_mean = table.rt_mean, table.en_mean
    if all(len(ii) == 1 for ii in unit_indices):
        flat = [ii[0] for ii in unit_indices]
        rt_stat = rt_mean[flat]
        en_stat = en_mean[flat]
    else:
        rt_stat = np.empty(len(units))
        en_stat = np.empty(len(units))
        for k, ii in enumerate(unit_indices):
            m = len(ii)
            rt_stat[k] = float(np.mean(rt_mean[ii])) * m
            en_stat[k] = float(np.mean(en_mean[ii])) * m
    if key == "shortest_runtime_first":
        order = np.argsort(rt_stat)
    elif key == "longest_runtime_first":
        order = np.argsort(-rt_stat)
    elif key == "highest_energy_first":
        order = np.argsort(-en_stat)
    elif key == "lowest_energy_first":
        order = np.argsort(en_stat)
    else:
        raise ValueError(key)
    return [units[i] for i in order]


def _predict_all(tasks, endpoints, store: TaskProfileStore):
    return {
        ep.name: {t.id: store.predict(t.fn, ep.name) for t in tasks}
        for ep in endpoints
    }


def _normalizers(tasks, endpoints, per_ep, transfer) -> tuple[float, float]:
    """SF1/SF2: pessimistic all-on-one-endpoint estimates (exact seed
    arithmetic — sequential accumulation keeps engine parity bitwise)."""
    sf1 = sf2 = 0.0
    for ep in endpoints:
        st = SchedulerState([ep], transfer)
        st.assign(list(tasks), ep, per_ep[ep.name])
        e, c, _ = st.metrics()
        sf1, sf2 = max(sf1, e), max(sf2, c)
    return max(sf1, 1e-9), max(sf2, 1e-9)


def _normalizers_fast(tasks, endpoints, table: PredictionTable, transfer
                      ) -> tuple[float, float]:
    """Same SF1/SF2 values as :func:`_normalizers` (operation-identical
    float sequence) computed from the prediction table's flat rows instead
    of nested Prediction dicts."""
    heappop, heappush = heapq.heappop, heapq.heappush
    n = len(tasks)
    sf1 = sf2 = 0.0
    for ei, ep in enumerate(endpoints):
        name = ep.name
        # transfer delta of the whole workload as one unit, fresh cache
        tj, t_bytes, t_files = 0.0, 0.0, 0
        seen: set[tuple[str, str]] = set()
        for t in tasks:
            for src, n_files, nbytes, shared in t.inputs:
                if src == name:
                    continue
                key = (name, f"{src}:{n_files}:{nbytes}")
                if shared and key in seen:
                    continue
                if shared:
                    seen.add(key)
                tj += transfer.hops(src, name) * nbytes * E_INC_J_PER_BYTE
                t_bytes += nbytes
                t_files += n_files
        ready = transfer.predict_seconds(t_files, t_bytes)
        if ep.has_batch_scheduler:
            ready += ep.queue_delay_s
        row_rt, row_en = table.rt_rows[ei], table.en_rows[ei]
        slots = [0.0] * ep.cores
        heapq.heapify(slots)
        first = None
        last = 0.0
        dyn = 0.0
        for i in range(n):
            start = heappop(slots)
            if start < ready:
                start = ready
            end = start + row_rt[i]
            heappush(slots, end)
            if first is None or start < first:
                first = start
            if end > last:
                last = end
            dyn += row_en[i]
        # single-endpoint metrics(), same accumulation order
        c = last if last > 0.0 else 0.0
        e = tj
        if first is None:
            if not ep.has_batch_scheduler:
                e += ep.idle_power_w * c
        else:
            if ep.has_batch_scheduler:
                e += ep.idle_power_w * (last - first) + ep.startup_energy_j
            else:
                e += ep.idle_power_w * c
            e += dyn
        sf1, sf2 = max(sf1, e), max(sf2, c)
    return max(sf1, 1e-9), max(sf2, 1e-9)


def mhra(
    tasks: Sequence[TaskSpec],
    endpoints: Sequence[EndpointSpec],
    store: TaskProfileStore,
    transfer: TransferModel,
    alpha: float = 0.5,
    heuristics: Sequence[str] = HEURISTICS,
    clusters: list[list[int]] | None = None,
    engine: str = "delta",
    state: SchedulerState | None = None,
) -> Schedule:
    """Multi-Heuristic Resource Allocation. With clusters given, this is
    Cluster MHRA's greedy stage (one decision per cluster).

    ``state`` (delta engine only) places against a live timeline carried
    across arrival windows; the winning heuristic's result is committed
    into it.
    """
    if not heuristics:
        raise ValueError("mhra requires at least one ordering heuristic")
    if engine == "clone":
        if state is not None:
            raise ValueError("engine='clone' does not support live state")
        return _mhra_clone(tasks, endpoints, store, transfer, alpha,
                           heuristics, clusters)
    if engine != "delta":
        raise ValueError(f"unknown engine {engine!r}")

    tasks = list(tasks)
    table = PredictionTable(tasks, endpoints, store)
    if clusters is None:
        units = [[t] for t in tasks]
    else:
        units = [[tasks[i] for i in c] for c in clusters]
    sf1, sf2 = _normalizers_fast(tasks, endpoints, table, transfer)

    unit_indices = [[table.index[t.id] for t in u] for u in units]
    best: Schedule | None = None
    best_state: SchedulerState | None = None
    for h in heuristics:
        ordered = _sort_units_fast(units, h, table, unit_indices)
        sched, end_state = _greedy_delta(
            ordered, endpoints, table, transfer, alpha, sf1, sf2, h, state
        )
        if best is None or sched.objective < best.objective:
            best, best_state = sched, end_state
    if state is not None:
        state.replace_with(best_state)
    return best


def _greedy_delta(
    units, endpoints, table: PredictionTable, transfer, alpha, sf1, sf2,
    heuristic, base_state: SchedulerState | None = None,
) -> tuple[Schedule, SchedulerState]:
    """Delta-evaluation greedy: score each candidate endpoint from the
    *change* it makes (peek the slot heap, delta the idle-span / dynamic
    energy / transfer terms) and commit only the winner.

    Every floating-point operation mirrors the clone engine's
    state.assign() + state.metrics() sequence, so objectives (and hence
    assignments) are bitwise identical; the savings are structural — no
    per-candidate copies of every heap, dict, and cache set.  Running
    C_max and per-endpoint span terms are maintained incrementally (exact:
    max() never rounds, and the span term is recomputed from the same
    operands the metrics loop would use).
    """
    state = (
        base_state.clone(keep_timeline=True)
        if base_state is not None
        else SchedulerState(endpoints, transfer)
    )
    n_ep = len(endpoints)
    names = [ep.name for ep in endpoints]
    eps_r = range(n_ep)
    # unpack live state into index-parallel lists for the hot loop
    slots = [state.slots[n] for n in names]
    first = [state.first_start[n] for n in names]
    last = [state.last_end[n] for n in names]
    dyn = [state.dyn_energy[n] for n in names]
    cached = state.cached
    timeline = state.timeline
    transfer_j = state.transfer_j
    # per-endpoint constants
    idle = [ep.idle_power_w for ep in endpoints]
    bt = [ep.has_batch_scheduler for ep in endpoints]
    su = [ep.startup_energy_j for ep in endpoints]
    qd = [ep.queue_delay_s if ep.has_batch_scheduler else 0.0 for ep in endpoints]
    # running C_max (max never rounds: equals max over the last_end values)
    c_cur = 0.0
    for v in last:
        if v > c_cur:
            c_cur = v
    # per-endpoint idle-span terms, recomputed only on commit — the same
    # float expression metrics() evaluates per candidate in the clone engine
    sterm = [
        idle[j] * (last[j] - first[j]) + su[j]
        if (bt[j] and first[j] is not None) else 0.0
        for j in eps_r
    ]
    mins = [h[0] for h in slots]  # heap peeks, refreshed on commit
    idx = table.index
    rt_rows, en_rows = table.rt_rows, table.en_rows
    hops = transfer.hops
    predict_seconds = transfer.predict_seconds
    beta = 1 - alpha
    heappop, heappush, heapreplace = heapq.heappop, heapq.heappush, heapq.heapreplace
    inf = np.inf
    assignments: dict[str, str] = {}
    # per-input caches shared across candidates: the "src:files:bytes" key
    # string, per-endpoint key tuples, hop counts, and transfer-time
    # predictions are all pure functions of their inputs
    key_cache: dict[tuple, str] = {}
    inp_info: dict[tuple, tuple] = {}
    hop_cache: dict[tuple[str, str], float] = {}
    ready_cache: dict[tuple, float] = {}

    for unit in units:
        single = len(unit) == 1
        single_inp = None
        if single:
            t0 = unit[0]
            ti = idx[t0.id]
            no_inputs = not t0.inputs
            if not no_inputs and len(t0.inputs) == 1:
                inp = t0.inputs[0]
                single_inp = inp_info.get(inp)
                if single_inp is None:
                    src, n_files, nbytes, shared = inp
                    ks = f"{src}:{n_files}:{nbytes}"
                    single_inp = inp_info[inp] = (
                        src, n_files, nbytes, shared,
                        # per-endpoint cache key; None where src == endpoint
                        [None if names[j] == src else (names[j], ks)
                         for j in eps_r],
                    )
        else:
            no_inputs = all(not t.inputs for t in unit)
        if not no_inputs and single_inp is None:
            prep = []
            for t in unit:
                for inp in t.inputs:
                    ks = key_cache.get(inp)
                    if ks is None:
                        src, n_files, nbytes, shared = inp
                        ks = key_cache[inp] = f"{src}:{n_files}:{nbytes}"
                    prep.append((inp[0], ks, inp[1], inp[2], inp[3]))
        best_obj = inf
        best = None
        for ei in eps_r:
            # --- transfer delta -------------------------------------------
            if no_inputs:
                tj = transfer_j
                ready = qd[ei]
                new_keys = ()
            elif single_inp is not None:
                src, n_files, nbytes, shared, keys4 = single_inp
                key = keys4[ei]
                if key is None or (shared and key in cached):
                    # local input, or shared data already staged here:
                    # no transfer — identical to the no-input case
                    tj = transfer_j
                    ready = qd[ei]
                    new_keys = ()
                else:
                    new_keys = (key,) if shared else ()
                    h = hop_cache.get(key)
                    if h is None:
                        h = hop_cache[key] = hops(src, names[ei])
                    tj = transfer_j + h * nbytes * E_INC_J_PER_BYTE
                    ready = ready_cache.get(key)
                    if ready is None:
                        ready = ready_cache[key] = predict_seconds(n_files, nbytes)
                    ready = ready + qd[ei]
            else:
                name = names[ei]
                tj = transfer_j
                t_bytes, t_files = 0.0, 0
                new_keys = []
                for src, ks, n_files, nbytes, shared in prep:
                    if src == name:
                        continue
                    key = (name, ks)
                    if shared and (key in cached or key in new_keys):
                        continue
                    if shared:
                        new_keys.append(key)
                    h = hop_cache.get(key)
                    if h is None:
                        h = hop_cache[key] = hops(src, name)
                    tj += h * nbytes * E_INC_J_PER_BYTE
                    t_bytes += nbytes
                    t_files += n_files
                if t_files:
                    rk = (t_files, t_bytes)
                    ready = ready_cache.get(rk)
                    if ready is None:
                        ready = ready_cache[rk] = predict_seconds(t_files, t_bytes)
                    ready = ready + qd[ei]
                else:
                    ready = qd[ei]
            # --- simulate the placement -----------------------------------
            if single:
                s0 = mins[ei]
                start = s0 if s0 >= ready else ready
                end = start + rt_rows[ei][ti]
                f = first[ei]
                nf = start if (f is None or start < f) else f
                l = last[ei]
                nl = end if end > l else l
                nd = dyn[ei] + en_rows[ei][ti]
                heap = None
                entries = (t0.id, start, end)
            else:
                heap = list(slots[ei])
                row_rt, row_en = rt_rows[ei], en_rows[ei]
                nf = first[ei]
                nl = last[ei]
                nd = dyn[ei]
                entries = []
                for t in unit:
                    tix = idx[t.id]
                    start = heappop(heap)
                    if start < ready:
                        start = ready
                    end = start + row_rt[tix]
                    heappush(heap, end)
                    if nf is None or start < nf:
                        nf = start
                    if end > nl:
                        nl = end
                    nd = nd + row_en[tix]
                    entries.append((t.id, start, end))
            # --- objective, same accumulation order as metrics() ----------
            c = nl if nl > c_cur else c_cur
            e = tj
            for j in eps_r:
                if j == ei:
                    if bt[ei]:
                        e += idle[ei] * (nl - nf) + su[ei]
                    else:
                        e += idle[ei] * c
                    e += nd
                elif bt[j]:
                    if first[j] is not None:
                        e += sterm[j]
                        e += dyn[j]
                else:
                    e += idle[j] * c
                    if first[j] is not None:
                        e += dyn[j]
            obj = alpha * e / sf1 + beta * c / sf2
            if obj < best_obj:
                best_obj = obj
                best = (ei, tj, new_keys, heap, entries, nf, nl, nd)
        # --- commit the winner --------------------------------------------
        ei, tj, new_keys, heap, entries, nf, nl, nd = best
        transfer_j = tj
        if new_keys:
            cached.update(new_keys)
        if heap is None:
            tid, start, end = entries
            heapreplace(slots[ei], end)
            timeline[tid] = (start, end)
            assignments[tid] = names[ei]
        else:
            slots[ei] = heap
            name = names[ei]
            for tid, start, end in entries:
                timeline[tid] = (start, end)
                assignments[tid] = name
        mins[ei] = slots[ei][0]
        first[ei] = nf
        last[ei] = nl
        dyn[ei] = nd
        if nl > c_cur:
            c_cur = nl
        if bt[ei]:
            sterm[ei] = idle[ei] * (nl - nf) + su[ei]

    # write the loop-local state back into the SchedulerState
    for ei in eps_r:
        n = names[ei]
        state.slots[n] = slots[ei]
        state.first_start[n] = first[ei]
        state.last_end[n] = last[ei]
        state.dyn_energy[n] = dyn[ei]
    state.transfer_j = transfer_j
    e, c, tj = state.metrics()
    obj = alpha * e / sf1 + (1 - alpha) * c / sf2
    sched = Schedule(assignments, obj, e, c, tj, heuristic, dict(state.timeline))
    return sched, state


# ---------------------------------------------------------------------------
# Reference clone-based engine (the seed implementation, kept verbatim for
# parity tests and benchmarks/scheduler_overhead.py)
# ---------------------------------------------------------------------------


def _mhra_clone(tasks, endpoints, store, transfer, alpha, heuristics, clusters):
    per_ep = _predict_all(tasks, endpoints, store)
    if clusters is None:
        units = [[t] for t in tasks]
    else:
        units = [[tasks[i] for i in c] for c in clusters]
    best: Schedule | None = None
    for h in heuristics:
        # predictions used for ordering: endpoint-mean
        mean_preds = {
            t.id: Prediction(
                float(np.mean([per_ep[e.name][t.id].runtime_s for e in endpoints])),
                float(np.mean([per_ep[e.name][t.id].energy_j for e in endpoints])),
                True,
            )
            for t in tasks
        }
        ordered = _sort_units(units, h, mean_preds)
        sched = _greedy_multi_ep(
            ordered, endpoints, per_ep, transfer, alpha, tasks, h
        )
        if best is None or sched.objective < best.objective:
            best = sched
    return best


def _greedy_multi_ep(units, endpoints, per_ep, transfer, alpha, tasks, heuristic):
    # SF normalizers from endpoint-specific predictions
    sf1, sf2 = _normalizers(tasks, endpoints, per_ep, transfer)

    state = SchedulerState(endpoints, transfer)
    assignments: dict[str, str] = {}
    for unit in units:
        best_obj, best_ep = np.inf, None
        for ep in endpoints:
            trial = state.clone()
            trial.assign(unit, ep, per_ep[ep.name])
            e, c, _ = trial.metrics()
            obj = alpha * e / sf1 + (1 - alpha) * c / sf2
            if obj < best_obj:
                best_obj, best_ep = obj, ep
        state.assign(unit, best_ep, per_ep[best_ep.name], record_timeline=True)
        for t in unit:
            assignments[t.id] = best_ep.name
    e, c, tj = state.metrics()
    obj = alpha * e / sf1 + (1 - alpha) * c / sf2
    return Schedule(assignments, obj, e, c, tj, heuristic, state.timeline)


def compute_clusters(
    tasks, endpoints, table: PredictionTable, max_cluster_size: int = 40
) -> list[list[int]]:
    """Agglomerative clusters from the vectorized prediction table (same
    features/energies as the clone path's nested-dict construction)."""
    n_ep = len(endpoints)
    feats = np.empty((len(tasks), 2 * n_ep))
    for ei in range(n_ep):
        feats[:, 2 * ei] = table.rt[ei]
        feats[:, 2 * ei + 1] = table.en[ei]
    energies = table.en_mean
    cap = min(
        [ep.startup_energy_j for ep in endpoints if ep.has_batch_scheduler]
        or [np.inf]
    )
    return agglomerative_cluster(
        feats, energies, cap, max_cluster_size=max_cluster_size
    )


def cluster_mhra(
    tasks: Sequence[TaskSpec],
    endpoints: Sequence[EndpointSpec],
    store: TaskProfileStore,
    transfer: TransferModel,
    alpha: float = 0.5,
    heuristics: Sequence[str] = HEURISTICS,
    max_cluster_size: int = 40,
    engine: str = "delta",
    state: SchedulerState | None = None,
) -> Schedule:
    """Algorithm 1: agglomerative clustering + per-cluster greedy MHRA."""
    tasks = list(tasks)
    if engine == "clone":
        per_ep = _predict_all(tasks, endpoints, store)
        feats = np.array(
            [
                [v for ep in endpoints for v in (
                    per_ep[ep.name][t.id].runtime_s, per_ep[ep.name][t.id].energy_j
                )]
                for t in tasks
            ]
        )
        energies = np.array(
            [np.mean([per_ep[ep.name][t.id].energy_j for ep in endpoints]) for t in tasks]
        )
        cap = min(
            [ep.startup_energy_j for ep in endpoints if ep.has_batch_scheduler]
            or [np.inf]
        )
        clusters = agglomerative_cluster(
            feats, energies, cap, max_cluster_size=max_cluster_size
        )
        return mhra(tasks, endpoints, store, transfer, alpha, heuristics,
                    clusters, engine="clone")
    table = PredictionTable(tasks, endpoints, store)
    clusters = compute_clusters(tasks, endpoints, table, max_cluster_size)
    return mhra(tasks, endpoints, store, transfer, alpha, heuristics,
                clusters, engine="delta", state=state)


# ---------------------------------------------------------------------------
# Baselines (Table V rows)
# ---------------------------------------------------------------------------


def fixed_assignment(
    tasks, endpoints, store, transfer, pick: Callable[[int, TaskSpec], str],
    state: SchedulerState | None = None,
) -> Schedule:
    tasks = list(tasks)
    per_ep = PredictionTable(tasks, endpoints, store).per_ep()
    by_ep = {e.name: e for e in endpoints}
    state = state if state is not None else SchedulerState(endpoints, transfer)
    assignments = {}
    for i, t in enumerate(tasks):
        name = pick(i, t)
        state.assign([t], by_ep[name], per_ep[name], record_timeline=True)
        assignments[t.id] = name
    e, c, tj = state.metrics()
    return Schedule(assignments, np.nan, e, c, tj, "fixed", dict(state.timeline))


def round_robin(tasks, endpoints, store, transfer,
                state: SchedulerState | None = None, offset: int = 0) -> Schedule:
    names = [e.name for e in endpoints]
    return fixed_assignment(
        tasks, endpoints, store, transfer,
        lambda i, t: names[(i + offset) % len(names)], state=state,
    )


def single_site(tasks, endpoints, store, transfer, site: str,
                state: SchedulerState | None = None) -> Schedule:
    names = {e.name for e in endpoints}
    if site not in names:
        raise ValueError(
            f"single_site requires site to be one of {sorted(names)}, got {site!r}"
        )
    return fixed_assignment(tasks, endpoints, store, transfer,
                            lambda i, t: site, state=state)
