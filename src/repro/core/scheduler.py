"""MHRA + Cluster MHRA schedulers (paper §III-F, Algorithm 1) and the
Round-Robin / single-site baselines evaluated in Table V.

Objective:  O = alpha * E_tot/SF1 + (1-alpha) * C_max/SF2
  E_tot = sum_n [ idle_power * allocated-span(+startup) + sum dyn task E ]
          + transfer energy;  desktop-style endpoints charge idle over the
          whole workflow span (paper: power drawn whether or not tasks run).
  SF1/SF2 = pessimistic all-on-one-machine estimates.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Sequence

import numpy as np

from repro.core.clustering import agglomerative_cluster
from repro.core.endpoint import EndpointSpec
from repro.core.predictor import Prediction, TaskProfileStore
from repro.core.transfer import E_INC_J_PER_BYTE, TransferModel


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    id: str
    fn: str
    inputs: tuple = ()          # tuple of TransferRequest templates (src, files, bytes, shared)
    user: str = "user0"


@dataclasses.dataclass
class Schedule:
    assignments: dict[str, str]
    objective: float
    energy_j: float
    makespan_s: float
    transfer_j: float
    heuristic: str = ""
    timeline: dict[str, tuple[float, float]] = dataclasses.field(default_factory=dict)

    def edp(self) -> float:
        return self.energy_j * self.makespan_s

    def w_ed2p(self) -> float:
        return self.energy_j * self.makespan_s ** 2


HEURISTICS = (
    "shortest_runtime_first",
    "longest_runtime_first",
    "highest_energy_first",
    "lowest_energy_first",
)


class _State:
    """Incremental greedy-scheduling state over endpoint timelines."""

    def __init__(self, endpoints: Sequence[EndpointSpec], transfer: TransferModel):
        self.eps = list(endpoints)
        self.transfer = transfer
        self.slots = {e.name: [0.0] * e.cores for e in endpoints}  # min-heaps
        for h in self.slots.values():
            heapq.heapify(h)
        self.first_start = {e.name: None for e in endpoints}
        self.last_end = {e.name: 0.0 for e in endpoints}
        self.dyn_energy = {e.name: 0.0 for e in endpoints}
        self.transfer_j = 0.0
        self.cached: set[tuple[str, str]] = set()
        self.timeline: dict[str, tuple[float, float]] = {}

    def clone(self) -> "_State":
        s = _State.__new__(_State)
        s.eps, s.transfer = self.eps, self.transfer
        s.slots = {k: list(v) for k, v in self.slots.items()}
        s.first_start = dict(self.first_start)
        s.last_end = dict(self.last_end)
        s.dyn_energy = dict(self.dyn_energy)
        s.transfer_j = self.transfer_j
        s.cached = set(self.cached)
        s.timeline = {}  # previews don't need task-level timelines
        return s

    def assign(
        self,
        unit: Sequence[TaskSpec],
        ep: EndpointSpec,
        preds: dict[str, Prediction],
        record_timeline: bool = False,
    ) -> None:
        name = ep.name
        # transfers for this unit's inputs (batched; shared files cached)
        reqs, t_bytes, t_files = [], 0.0, 0
        for t in unit:
            for src, n_files, nbytes, shared in t.inputs:
                if src == name:
                    continue
                key = (name, f"{src}:{n_files}:{nbytes}")
                if shared and key in self.cached:
                    continue
                if shared:
                    self.cached.add(key)
                self.transfer_j += (
                    self.transfer.hops(src, name) * nbytes * E_INC_J_PER_BYTE
                )
                t_bytes += nbytes
                t_files += n_files
        ready = self.transfer.predict_seconds(t_files, t_bytes)
        if ep.has_batch_scheduler:
            ready += ep.queue_delay_s
        slots = self.slots[name]
        for t in unit:
            p = preds[t.id]
            start = max(heapq.heappop(slots), ready)
            end = start + p.runtime_s
            heapq.heappush(slots, end)
            if self.first_start[name] is None or start < self.first_start[name]:
                self.first_start[name] = start
            self.last_end[name] = max(self.last_end[name], end)
            self.dyn_energy[name] += p.energy_j
            if record_timeline:
                self.timeline[t.id] = (start, end)

    def metrics(self) -> tuple[float, float, float]:
        """(E_tot, C_max, transfer_j)."""
        c_max = max([v for v in self.last_end.values()] + [0.0])
        e_tot = self.transfer_j
        for ep in self.eps:
            n = ep.name
            if self.first_start[n] is None:
                if not ep.has_batch_scheduler:
                    # always-on endpoint idles through the workflow regardless
                    e_tot += ep.idle_power_w * c_max
                continue
            if ep.has_batch_scheduler:
                span = self.last_end[n] - self.first_start[n]
                e_tot += ep.idle_power_w * span + ep.startup_energy_j
            else:
                e_tot += ep.idle_power_w * c_max
            e_tot += self.dyn_energy[n]
        return e_tot, c_max, self.transfer_j


def _unit_stats(unit, endpoints, preds):
    rt = float(np.mean([preds[t.id].runtime_s for t in unit]))
    en = float(np.mean([preds[t.id].energy_j for t in unit]))
    return rt * len(unit), en * len(unit)


def _sort_units(units, key: str, endpoints, preds):
    stats = [_unit_stats(u, endpoints, preds) for u in units]
    if key == "shortest_runtime_first":
        order = np.argsort([s[0] for s in stats])
    elif key == "longest_runtime_first":
        order = np.argsort([-s[0] for s in stats])
    elif key == "highest_energy_first":
        order = np.argsort([-s[1] for s in stats])
    elif key == "lowest_energy_first":
        order = np.argsort([s[1] for s in stats])
    else:
        raise ValueError(key)
    return [units[i] for i in order]


def _predict_all(tasks, endpoints, store: TaskProfileStore):
    return {
        ep.name: {t.id: store.predict(t.fn, ep.name) for t in tasks}
        for ep in endpoints
    }


def mhra(
    tasks: Sequence[TaskSpec],
    endpoints: Sequence[EndpointSpec],
    store: TaskProfileStore,
    transfer: TransferModel,
    alpha: float = 0.5,
    heuristics: Sequence[str] = HEURISTICS,
    clusters: list[list[int]] | None = None,
) -> Schedule:
    """Multi-Heuristic Resource Allocation. With clusters given, this is
    Cluster MHRA's greedy stage (one decision per cluster)."""
    per_ep = _predict_all(tasks, endpoints, store)
    if clusters is None:
        units = [[t] for t in tasks]
    else:
        units = [[tasks[i] for i in c] for c in clusters]
    best: Schedule | None = None
    for h in heuristics:
        # predictions used for ordering: endpoint-mean
        mean_preds = {
            t.id: Prediction(
                float(np.mean([per_ep[e.name][t.id].runtime_s for e in endpoints])),
                float(np.mean([per_ep[e.name][t.id].energy_j for e in endpoints])),
                True,
            )
            for t in tasks
        }
        ordered = _sort_units(units, h, endpoints, mean_preds)
        sched = _greedy_multi_ep(
            ordered, endpoints, per_ep, transfer, alpha, tasks, h
        )
        if best is None or sched.objective < best.objective:
            best = sched
    return best


def _greedy_multi_ep(units, endpoints, per_ep, transfer, alpha, tasks, heuristic):
    # SF normalizers from endpoint-specific predictions
    sf1 = sf2 = 0.0
    for ep in endpoints:
        st = _State([ep], transfer)
        st.assign(list(tasks), ep, per_ep[ep.name])
        e, c, _ = st.metrics()
        sf1, sf2 = max(sf1, e), max(sf2, c)
    sf1, sf2 = max(sf1, 1e-9), max(sf2, 1e-9)

    state = _State(endpoints, transfer)
    assignments: dict[str, str] = {}
    for unit in units:
        best_obj, best_ep = np.inf, None
        for ep in endpoints:
            trial = state.clone()
            trial.assign(unit, ep, per_ep[ep.name])
            e, c, _ = trial.metrics()
            obj = alpha * e / sf1 + (1 - alpha) * c / sf2
            if obj < best_obj:
                best_obj, best_ep = obj, ep
        state.assign(unit, best_ep, per_ep[best_ep.name], record_timeline=True)
        for t in unit:
            assignments[t.id] = best_ep.name
    e, c, tj = state.metrics()
    obj = alpha * e / sf1 + (1 - alpha) * c / sf2
    return Schedule(assignments, obj, e, c, tj, heuristic, state.timeline)


def cluster_mhra(
    tasks: Sequence[TaskSpec],
    endpoints: Sequence[EndpointSpec],
    store: TaskProfileStore,
    transfer: TransferModel,
    alpha: float = 0.5,
    heuristics: Sequence[str] = HEURISTICS,
    max_cluster_size: int = 40,
) -> Schedule:
    """Algorithm 1: agglomerative clustering + per-cluster greedy MHRA."""
    per_ep = _predict_all(tasks, endpoints, store)
    feats = np.array(
        [
            [v for ep in endpoints for v in (
                per_ep[ep.name][t.id].runtime_s, per_ep[ep.name][t.id].energy_j
            )]
            for t in tasks
        ]
    )
    energies = np.array(
        [np.mean([per_ep[ep.name][t.id].energy_j for ep in endpoints]) for t in tasks]
    )
    cap = min(
        [ep.startup_energy_j for ep in endpoints if ep.has_batch_scheduler]
        or [np.inf]
    )
    clusters = agglomerative_cluster(
        feats, energies, cap, max_cluster_size=max_cluster_size
    )
    return mhra(tasks, endpoints, store, transfer, alpha, heuristics, clusters)


# ---------------------------------------------------------------------------
# Baselines (Table V rows)
# ---------------------------------------------------------------------------


def fixed_assignment(
    tasks, endpoints, store, transfer, pick: Callable[[int, TaskSpec], str]
) -> Schedule:
    per_ep = _predict_all(tasks, endpoints, store)
    by_ep = {e.name: e for e in endpoints}
    state = _State(endpoints, transfer)
    assignments = {}
    for i, t in enumerate(tasks):
        name = pick(i, t)
        state.assign([t], by_ep[name], per_ep[name], record_timeline=True)
        assignments[t.id] = name
    e, c, tj = state.metrics()
    return Schedule(assignments, np.nan, e, c, tj, "fixed", state.timeline)


def round_robin(tasks, endpoints, store, transfer) -> Schedule:
    names = [e.name for e in endpoints]
    return fixed_assignment(
        tasks, endpoints, store, transfer, lambda i, t: names[i % len(names)]
    )


def single_site(tasks, endpoints, store, transfer, site: str) -> Schedule:
    return fixed_assignment(tasks, endpoints, store, transfer, lambda i, t: site)
