"""Event-driven online scheduling engine (paper §III as a *service*).

GreenFaaS is an online system: tasks arrive continuously and every
placement decision must see up-to-date profiles.  This engine closes the
learn loop *mid-workload* instead of only across ``run_batch`` calls:

    submit(task) ──> pending queue
                      │  arrival-window batcher (window_s / max_batch)
                      ▼
    policy.place(window_tasks, ctx, state=live)   # delta evaluation
                      ▼
    backend.execute_window(...)                   # incremental sim
                      ▼
    attribute_window(...)  ──>  TaskProfileStore  # profiles update
                      │
                      └──> next window's predictions see them

The live :class:`SchedulerState` carries endpoint timelines, transfer
cache contents, and accumulated energy across windows, so objectives are
cumulative and placements account for load already committed.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Sequence

from repro.core.carbon import CarbonIntensitySignal
from repro.core.dag import DAGView
from repro.core.database import TaskDB
from repro.core.endpoint import EndpointSpec
from repro.core.executor import attribute_window
from repro.core.fairness import FairShare, FairnessLedger, FairnessWeights
from repro.core.faults import FaultTrace, WarmWeights
from repro.core.policy import PlacementPolicy, PolicyContext, get_policy
from repro.core.power_model import LinearPowerModel
from repro.core.predictor import TaskProfileStore
from repro.core.region import (
    RegionRouter, RegionSpec, task_payload_bytes, task_shared_inputs,
)
from repro.core.scheduler import (
    Schedule, SchedulerState, SoAState, TaskSpec, auto_engine,
)
from repro.core.testbed import SimResult, TestbedSim
from repro.core.transfer import TransferModel, TransferRequest


@dataclasses.dataclass
class WindowResult:
    """Outcome of one arrival window."""
    index: int
    submitted_at: float
    tasks: list[TaskSpec]
    schedule: Schedule               # objective/energy/makespan are cumulative
    assignments: dict[str, str]      # this window's tasks only
    scheduling_s: float
    sim: SimResult | None = None
    attributed_j: float = 0.0

    @property
    def placements(self) -> dict[str, int]:
        """endpoint -> task count for this window."""
        out: dict[str, int] = {}
        for ep in self.assignments.values():
            out[ep] = out.get(ep, 0) + 1
        return out


@dataclasses.dataclass
class EngineSummary:
    windows: int
    tasks: int
    objective: float
    energy_j: float          # scheduler-estimated cumulative E_tot
    makespan_s: float        # cumulative C_max
    transfer_j: float
    scheduling_s: float      # total time spent in placement decisions
    attributed_j: float
    deferred: int = 0        # tasks time-shifted by the carbon deferral queue
    # --- fault tolerance (all zero / 1.0 on fault-free runs) ---
    submitted: int = 0       # distinct task ids submitted
    completed: int = 0       # distinct task ids that reached completion
    goodput: float = 1.0     # completed / submitted
    failures: int = 0        # task executions killed by endpoint churn
    retries: int = 0         # re-placements of killed tasks
    permanent_failures: int = 0  # tasks dropped after exhausting retry_cap
    wasted_j: float = 0.0    # partial energy billed to killed executions
    cold_starts: int = 0     # cold worker spin-ups paid in the sim
    cold_j: float = 0.0      # startup energy billed to cold spin-ups
    spec_launched: int = 0   # speculative backups launched for stragglers
    spec_wins: int = 0       # backups that beat their straggling primary
    spec_wasted_j: float = 0.0   # energy of the losing copy of each pair
    mean_recovery_s: float | None = None  # first-failure -> completion
    # --- multi-tenant fairness (zero without fairness/admission) ---
    shed: int = 0            # over-budget tasks rejected by admission control
    admission_deferred: int = 0  # tasks delayed to a budget replenish
    # --- geo-distributed routing (zero without a region layer) ---
    regions: int = 0         # regions in the router (0 = no region layer)
    wan_j: float = 0.0       # WAN transfer energy billed to cross-region routes
    egress_bytes: float = 0.0    # bytes that crossed a region boundary


class OnlineEngine:
    """Streaming submission path over a live scheduler state.

    ``submit`` enqueues; a window fires when ``max_batch`` tasks are
    pending, when ``tick(now)`` sees ``window_s`` elapsed since the first
    pending arrival, or when ``flush``/``drain`` forces it.  Completed
    windows feed monitored task records back into the profile store, so
    profiles learned in window k steer placements in window k+1.

    **DAG workloads.**  A task whose ``deps`` name uncompleted parents is
    parked in ``waiting`` instead of ``pending``; when its last parent
    completes, the engine promotes it with ``not_before`` raised to a
    ready floor no earlier than every parent's completion (so no engine —
    and no simulated dispatch — can start it earlier) and with one
    transfer input per parent reading ``dep_bytes`` from the parent's
    *producing endpoint*.  ``promotion`` picks the floor granularity:

    - ``"epoch"`` (default): every task promoted by one pass shares a
      single floor — the latest parent completion across the whole
      promoted set (its *completion epoch*).  A wide DAG stage then
      releases children with identical ``not_before``, which keeps them
      inside one SoA run-memoization run (the floor is part of the memo
      key) and restores O(1) scoring on wide stages.
    - ``"exact"``: each child's floor is its own parents' latest
      completion — the tightest correct floor, at the cost of distinct
      floors fragmenting the SoA fast path.

    Both are conservative (a floor only grows), so DAG edges are honored
    either way.  ``drain`` keeps flushing until the whole DAG has run,
    and raises ``RuntimeError`` if tasks remain waiting with no
    completable parent (dependency cycle or a dep id that was never
    submitted).

    The engine also maintains a :class:`~repro.core.dag.DAGView` over
    everything submitted (``self.dag``): nodes/edges on submission,
    producer endpoints on completion.  Each window's
    :class:`PolicyContext` exposes it, so DAG-aware policies
    (``lookahead_mhra``) see critical-path ranks and data gravity for
    tasks that haven't even left the ready-set yet.

    **Units & mutation semantics.**  All energies are joules, times are
    seconds (reports divide by 1e3 for kJ).  ``submit``/``tick``/``flush``
    mutate the engine in place: the live state (``self.state``), profile
    store, task DB, and window list all accumulate across calls — create a
    fresh engine per experiment run.  Determinism: with a seeded
    ``TestbedSim`` backend and ``monitoring=False`` runs are bitwise
    reproducible; ``monitoring=True`` keeps placement deterministic but
    attributed energies depend on the sim's seeded monitor-noise draws.
    """

    def __init__(
        self,
        endpoints: Sequence[EndpointSpec],
        backend: TestbedSim | None = None,
        policy: str | PlacementPolicy = "mhra",
        alpha: float = 0.5,
        window_s: float = 1.0,
        max_batch: int = 256,
        store: TaskProfileStore | None = None,
        db: TaskDB | None = None,
        monitoring: bool = True,
        site: str | None = None,
        engine: str | None = "auto",
        carbon: CarbonIntensitySignal | None = None,
        defer_horizon_s: float = 0.0,
        defer_max: int = 256,
        defer_margin: float = 0.05,
        promotion: str = "epoch",
        prune: bool = True,
        retain_windows: int | None = None,
        faults: FaultTrace | None = None,
        fault_aware: bool = True,
        retry_cap: int = 6,
        retry_backoff_s: float = 15.0,
        spec_factor: float | None = None,
        fairness: FairShare | FairnessLedger | None = None,
        admission: str | None = None,
        admission_debt: float = 1.0,
        admission_max_defer: int = 8,
        regions: Sequence[RegionSpec] | RegionRouter | None = None,
        defer_sigma_k: float = 1.0,
    ):
        """``engine`` selects the scheduling backend for registry-name
        mhra/cluster_mhra/carbon_mhra policies ("delta" or "soa") and the
        live state's layout: "soa" carries a :class:`SoAState` (flat
        arrays) across windows, anything else the heap-backed
        :class:`SchedulerState`.  The default ``"auto"`` resolves the
        calibrated fleet-size/window-size crossover
        (:func:`~repro.core.scheduler.auto_engine`) when the first window
        flushes — using that window's actual size — and the layout then
        stays fixed for the engine's lifetime, so no window ever pays a
        cross-layout (``from_heap``/``write_back``) conversion.  With a
        policy *instance*, the state layout follows the instance's own
        ``engine`` attribute (an instance carrying ``"auto"`` defers the
        same way).  ``engine="clone"`` is rejected here: the clone engine
        cannot place against a live state, so every window would fail.

        ``prune`` (default on) retires finished subgraphs from the live
        :class:`~repro.core.dag.DAGView` and drops their timeline entries
        from the live state, keeping per-decision cost a function of
        *live* tasks instead of everything ever submitted.  Producer
        endpoints of retained frontier nodes survive retirement, so
        transfer billing for still-waiting children is unchanged —
        placements are bitwise-identical with pruning on or off.
        ``retain_windows`` caps the kept :class:`WindowResult` history
        (None = keep all); ``summary()`` aggregates stay exact either
        way, via running counters.

        ``carbon`` exposes a grid-intensity signal to carbon-aware
        policies (via the per-window :class:`PolicyContext`) and, with
        ``defer_horizon_s > 0``, arms **temporal shifting**: at each
        window the engine looks up to ``defer_horizon_s`` seconds ahead
        for the exact fleet-mean intensity minimum, and if it undercuts
        the current intensity by at least ``defer_margin`` (relative),
        deadline-slack tasks are parked in a bounded deferral queue
        (``defer_max`` entries) and re-enter the pending queue at that
        release time with ``not_before`` raised to it — the same ready
        floor the DAG ready-set uses, so engines and the simulator clamp
        their starts exactly as they do for promoted DAG children.  Each
        task defers at most once (no starvation), and ``drain`` advances
        the clock to the earliest release when only deferred work
        remains, so a drain can never deadlock on the queue.

        ``faults`` is the shared :class:`~repro.core.faults.FaultTrace`
        script (give the *same* trace to the backend sim).  The engine
        always reacts to failures it observes — killed executions re-enter
        the pending queue with exponential backoff (``retry_backoff_s *
        2**(attempt-1)`` via the ``not_before`` floor) up to ``retry_cap``
        attempts, after which the task lands in ``failed_permanently``.
        ``fault_aware`` controls only what placement *sees*: when True,
        each window's :class:`PolicyContext` carries an up/down mask
        snapshotted at the window-open time (dead endpoints excluded from
        candidate scoring; if the whole fleet is dark the window jumps to
        the earliest recovery) and a :class:`WarmWeights` expected
        cold-start penalty.  ``fault_aware=False`` is the chaos-eval
        baseline: same retries, but placement is blind to the trace.
        ``spec_factor`` (None = off) arms straggler mitigation: a task
        whose observed runtime exceeds ``spec_factor`` times its
        pre-update predicted runtime gets a speculative backup copy; the
        first finisher wins and the loser's energy is billed as
        speculation waste.  With ``faults=None`` (or an empty trace) and
        ``spec_factor=None`` every placement and simulation path is
        bitwise-identical to a fault-free engine.

        ``fairness`` (a :class:`~repro.core.fairness.FairShare` policy or
        a pre-built ledger) arms multi-tenant accounting: every executed
        record's energy (and carbon, when the share carries ``budget_g``
        and a carbon signal is attached) is charged to ``task.user``'s
        budget, and each window's :class:`PolicyContext` carries a
        :class:`~repro.core.fairness.FairnessWeights` debt snapshot that
        MHRA-family policies fold into placement as an advantage tax.
        ``admission`` escalates from *steering* to *gating*: at flush
        time a task whose user's debt is at least ``admission_debt``
        windows is ``"shed"`` (recorded in ``self.shed`` — never silently
        dropped; its DAG descendants shed with it at drain) or
        ``"defer"``-red to the next budget replenish, at most
        ``admission_max_defer`` times before it is admitted anyway (no
        starvation).  ``fairness=None`` (the default) keeps every
        placement bitwise-identical to a single-tenant engine.

        ``regions`` (a list of :class:`~repro.core.region.RegionSpec` or
        a pre-built :class:`~repro.core.region.RegionRouter`) arms the
        **geo-distributed region layer**: at each window, every task is
        first routed to a destination region (fixed / caller / agent
        mode — see the router docs), cross-region routes bill WAN
        transfer joules and raise the task's ``not_before`` by the WAN
        delay, and each region's group is then placed by the ordinary
        endpoint-level policy with the fleet narrowed to that region's
        endpoints via the alive mask.  Shared datasets cross the WAN
        once per destination region (cached, like the endpoint transfer
        model).  Every engine endpoint must belong to exactly one
        region.  ``regions=None`` — and a single region covering the
        whole fleet — keep every placement bitwise-identical to a
        region-free engine: the membership mask collapses to ``None``
        and no WAN event can fire, so clone/delta/soa parity is
        untouched.  A router built without its own carbon signal adopts
        the engine's ``carbon`` (the *decision* view; WAN grams are
        billed against the true signal by the evaluation harness).

        ``defer_sigma_k`` hedges temporal shifting against forecast
        error: the deferral margin becomes ``defer_margin +
        defer_sigma_k * carbon.forecast_sigma`` (capped at 1), so a
        noisy forecast must promise a proportionally deeper trough
        before the engine parks work for it.  Ground-truth signals
        (``forecast_sigma == 0``) leave the margin — and every
        deferral decision — exactly as before."""
        self.endpoints = list(endpoints)
        self.backend = backend
        if promotion not in ("epoch", "exact"):
            raise ValueError(
                f"promotion must be 'epoch' or 'exact', got {promotion!r}"
            )
        self.promotion = promotion
        if isinstance(policy, PlacementPolicy):
            self.policy = policy
        elif policy == "single_site":
            self.policy = get_policy(policy, site=site)
        elif engine is not None and policy in ("mhra", "cluster_mhra",
                                               "carbon_mhra",
                                               "lookahead_mhra"):
            self.policy = get_policy(policy, engine=engine)
        else:
            self.policy = get_policy(policy)
        pol_engine = getattr(self.policy, "engine", None)
        if engine is None or (engine == "auto"
                              and isinstance(policy, PlacementPolicy)):
            # a policy instance knows its engine; follow it (it may itself
            # carry "auto", which defers to the first window)
            self.engine = pol_engine if pol_engine is not None else "delta"
        elif engine == "auto" and pol_engine is None:
            # engine-less policies (round_robin, single_site) gain nothing
            # from the SoA layout; keep the heap default
            self.engine = "delta"
        else:
            self.engine = engine
        if self.engine == "clone":
            raise ValueError(
                "OnlineEngine requires a live-state engine ('delta', "
                "'soa' or 'jax'); engine='clone' cannot place against the "
                "state carried across arrival windows"
            )
        self.alpha = alpha
        self.window_s = window_s
        self.max_batch = max_batch
        self.store = store or TaskProfileStore(self.endpoints)
        self.transfer = TransferModel(self.endpoints)
        self.db = db or TaskDB()
        self.models = {e.name: LinearPowerModel() for e in self.endpoints}
        self.monitoring = monitoring
        if self.engine == "auto":
            # resolved at the first flush, when the window size is known;
            # self.engine then becomes the concrete choice
            self.state = None
        else:
            state_cls = (SoAState if self.engine in ("soa", "jax")
                         else SchedulerState)
            self.state = state_cls(self.endpoints, self.transfer)
        self.prune = prune
        self.retain_windows = retain_windows
        self.pending: list[TaskSpec] = []
        self.windows: list[WindowResult] = []
        # running aggregates so summary() stays exact under retain_windows
        self._n_windows = 0
        self._n_tasks = 0
        self._sched_s = 0.0
        self._attr_j = 0.0
        self.waiting: dict[str, TaskSpec] = {}       # id -> dep-blocked task
        self.completed: dict[str, tuple[str, float]] = {}  # id -> (ep, t_end)
        self.dag = DAGView(runtime=self._runtime_estimate, prune=prune)
        self.carbon = carbon
        if defer_horizon_s > 0.0 and carbon is None:
            raise ValueError("defer_horizon_s needs a carbon signal")
        if defer_sigma_k < 0.0:
            raise ValueError(
                f"defer_sigma_k must be non-negative, got {defer_sigma_k}"
            )
        self.defer_horizon_s = defer_horizon_s
        self.defer_max = defer_max
        self.defer_margin = defer_margin
        self.defer_sigma_k = defer_sigma_k
        if regions is None:
            self.router: RegionRouter | None = None
        else:
            router = (regions if isinstance(regions, RegionRouter)
                      else RegionRouter(regions))
            ep_names = {e.name for e in self.endpoints}
            assigned = set(router._region_of_ep)
            missing = sorted(ep_names - assigned)
            unknown = sorted(assigned - ep_names)
            if missing:
                raise ValueError(
                    f"endpoints in no region: {missing}; every engine "
                    f"endpoint must belong to exactly one region"
                )
            if unknown:
                raise ValueError(
                    f"regions list endpoints the engine does not have: "
                    f"{unknown}"
                )
            if router.carbon is None:
                router.carbon = carbon
            self.router = router
        by_name = {e.name: e for e in self.endpoints}
        self._region_capacity = (
            {
                r.name: float(r.capacity or
                              sum(by_name[m].cores for m in r.endpoints))
                for r in self.router.regions.values()
            }
            if self.router is not None else {}
        )
        self.wan_j = 0.0
        self.egress_bytes = 0.0
        #: (t, src_region, dst_region, bytes, joules) per cross-region route
        self.wan_events: list[tuple[float, str, str, float, float]] = []
        self.region_tasks: dict[str, int] = {}
        self._wan_cached: set[tuple[str, float, str]] = set()
        self.deferred: list[tuple[float, int, TaskSpec]] = []  # release heap
        self._deferred_ids: set[str] = set()         # defer-once guard
        self._defer_seq = itertools.count()
        self.faults = faults if faults else None   # empty trace -> fault-free
        self.fault_aware = fault_aware
        if retry_cap < 0:
            raise ValueError(f"retry_cap must be >= 0, got {retry_cap}")
        if retry_backoff_s < 0.0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {retry_backoff_s}"
            )
        if spec_factor is not None and spec_factor <= 1.0:
            raise ValueError(
                f"spec_factor must be > 1 (None disables), got {spec_factor}"
            )
        self.retry_cap = retry_cap
        self.retry_backoff_s = retry_backoff_s
        self.spec_factor = spec_factor
        if admission not in (None, "shed", "defer"):
            raise ValueError(
                f"admission must be None, 'shed', or 'defer', got {admission!r}"
            )
        if admission is not None and fairness is None:
            raise ValueError("admission control needs a fairness budget")
        if admission_debt <= 0.0:
            raise ValueError(
                f"admission_debt must be positive, got {admission_debt}"
            )
        if admission_max_defer < 0:
            raise ValueError(
                f"admission_max_defer must be >= 0, got {admission_max_defer}"
            )
        self.fairness = (
            fairness.ledger() if isinstance(fairness, FairShare) else fairness
        )
        self.admission = admission
        self.admission_debt = admission_debt
        self.admission_max_defer = admission_max_defer
        self.shed: list[TaskSpec] = []
        self.shed_ids: set[str] = set()
        self._adm_defer: dict[str, int] = {}   # id -> admission deferrals
        self.failed_permanently: set[str] = set()
        self._submitted_ids: set[str] = set()
        self._attempts: dict[str, int] = {}          # id -> failed attempts
        self._first_fail_at: dict[str, float] = {}   # id -> first kill time
        self._recovery_s: list[float] = []           # first-fail -> completion
        self._spec_primary: dict[str, object] = {}   # base id -> primary record
        self._spec_done: set[str] = set()            # never re-speculate
        self._failures = 0
        self._retries = 0
        self._wasted_j = 0.0
        self._cold_starts = 0
        self._cold_j = 0.0
        self._spec_launched = 0
        self._spec_wins = 0
        self._spec_wasted_j = 0.0
        self.clock = 0.0
        self._first_pending_at: float | None = None
        if backend is not None:
            backend.begin_stream()

    # ------------------------------------------------------------------
    def submit(self, task: TaskSpec, when: float | None = None) -> WindowResult | None:
        """Enqueue one task; returns a WindowResult if this submission
        filled the batch and triggered a window.  A task with unmet
        ``deps`` is parked until its parents complete (see class docs)."""
        when = self.clock if when is None else when
        self.clock = max(self.clock, when)
        self.dag.add_task(task)
        self._submitted_ids.add(task.id)
        if task.deps:
            if any(d not in self.completed for d in task.deps):
                self.waiting[task.id] = task
                return None
            task = self._resolve_deps(task)
        if self._first_pending_at is None:
            self._first_pending_at = when
        self.pending.append(task)
        if len(self.pending) >= self.max_batch:
            return self.flush()
        return None

    def _resolve_deps(self, task: TaskSpec, floor: float | None = None
                      ) -> TaskSpec:
        """Concretize a dep-bearing task whose parents have all completed:
        ready floor = latest parent completion (or the shared epoch
        ``floor``, when given — never earlier than the parents), plus one
        transfer input per parent pulling ``dep_bytes`` from the endpoint
        that produced it."""
        parents = [self.completed[d] for d in task.deps]
        not_before = max(end for _, end in parents)
        if floor is not None and floor > not_before:
            not_before = floor
        inputs = task.inputs
        if task.dep_bytes > 0.0:
            inputs = inputs + tuple(
                (ep, 1, task.dep_bytes, False) for ep, _ in parents
            )
        return dataclasses.replace(
            task, inputs=inputs, not_before=max(task.not_before, not_before)
        )

    def _promote_ready(self) -> int:
        """Move every waiting task whose parents have all completed into
        the pending queue; returns the number promoted.  In ``"epoch"``
        promotion mode the whole promoted set shares one ready floor —
        the latest parent completion across the set — so a wide stage's
        children carry identical ``not_before`` values and coalesce into
        one SoA memoization run."""
        ready = [
            t for t in self.waiting.values()
            if all(d in self.completed for d in t.deps)
        ]
        floor = None
        if self.promotion == "epoch" and ready:
            floor = max(
                self.completed[d][1] for t in ready for d in t.deps
            )
        for t in ready:
            del self.waiting[t.id]
            if self._first_pending_at is None:
                self._first_pending_at = self.clock
            self.pending.append(self._resolve_deps(t, floor=floor))
        return len(ready)

    def submit_many(self, tasks: Sequence[TaskSpec], when: float | None = None
                    ) -> list[WindowResult]:
        out = []
        for t in tasks:
            r = self.submit(t, when)
            if r is not None:
                out.append(r)
        return out

    def tick(self, now: float) -> WindowResult | None:
        """Advance the arrival clock; fire a window if one is due."""
        self.clock = max(self.clock, now)
        self._release_deferred(self.clock)
        if (
            self.pending
            and self._first_pending_at is not None
            and now - self._first_pending_at >= self.window_s
        ):
            return self.flush()
        return None

    # ------------------------------------------------------------------
    # carbon-aware temporal shifting (bounded deferral queue)
    def _release_deferred(self, now: float) -> int:
        """Move deferred tasks whose release time has arrived back into the
        pending queue with ``not_before`` raised to the release time."""
        n = 0
        while self.deferred and self.deferred[0][0] <= now:
            release, _, task = heapq.heappop(self.deferred)
            if self._first_pending_at is None:
                self._first_pending_at = release
            self.pending.append(dataclasses.replace(
                task, not_before=max(task.not_before, release)
            ))
            n += 1
        return n

    def _runtime_estimate(self, fn: str) -> float:
        """Fleet-mean predicted runtime — the slack check's cost model."""
        preds = [self.store.predict(fn, e.name) for e in self.endpoints]
        return sum(p.runtime_s for p in preds) / len(preds)

    def _split_deferrable(self, tasks: list[TaskSpec], now: float
                          ) -> list[TaskSpec]:
        """Park deadline-slack tasks for a cleaner-grid window; returns the
        tasks to place *now*.  No-op unless the exact fleet-mean intensity
        minimum within the horizon undercuts the current intensity by
        ``defer_margin`` and the bounded queue has room.  The margin
        widens with the signal's ``forecast_sigma`` (scaled by
        ``defer_sigma_k``): a noisy forecast's trough must look
        proportionally deeper before work is parked on its word."""
        if self.defer_max - len(self.deferred) <= 0:
            return tasks     # queue full: skip the signal scans entirely
        names = [e.name for e in self.endpoints]
        cur = self.carbon.fleet_mean_intensity(names, now)
        t_best, best = self.carbon.argmin_fleet_mean(
            names, now, now + self.defer_horizon_s
        )
        margin = self.defer_margin
        sigma = getattr(self.carbon, "forecast_sigma", 0.0)
        if sigma > 0.0 and self.defer_sigma_k > 0.0:
            margin = min(margin + self.defer_sigma_k * sigma, 1.0)
        if t_best <= now or best > (1.0 - margin) * cur:
            return tasks
        keep: list[TaskSpec] = []
        room = self.defer_max - len(self.deferred)
        rt_est: dict[str, float] = {}
        for t in tasks:
            if room <= 0 or t.id in self._deferred_ids:
                keep.append(t)
                continue
            if t.deadline != float("inf"):
                rt = rt_est.get(t.fn)
                if rt is None:
                    rt = rt_est[t.fn] = self._runtime_estimate(t.fn)
                if t_best + rt > t.deadline:
                    keep.append(t)      # no slack: deferral would miss it
                    continue
            heapq.heappush(self.deferred, (t_best, next(self._defer_seq), t))
            self._deferred_ids.add(t.id)
            room -= 1
        return keep

    # ------------------------------------------------------------------
    # geo-distributed region layer (router above the endpoint fleet)
    def _region_backlog(self, now: float) -> dict[str, float]:
        """Per-region congestion input: mean committed backlog seconds —
        how far each member endpoint's timeline extends past ``now``."""
        if isinstance(self.state, SoAState):
            last = {e.name: float(self.state.last[i])
                    for i, e in enumerate(self.endpoints)}
        else:
            last = self.state.last_end
        out = {}
        for r in self.router.names:
            members = self.router.regions[r].endpoints
            out[r] = sum(
                max(0.0, last.get(m, 0.0) - now) for m in members
            ) / len(members)
        return out

    def _region_energy_est(self, fn: str, region: str) -> float:
        """Region-mean predicted dynamic energy for ``fn`` (J) — the
        agent router's compute-cost term."""
        members = self.router.regions[region].endpoints
        preds = [self.store.predict(fn, m) for m in members]
        return sum(p.energy_j for p in preds) / len(preds)

    def _region_transfer_est(self, task: TaskSpec, region: str) -> float:
        """Endpoint-level transfer joules if ``task``'s inputs stage into
        ``region`` (hop-based, against a representative member endpoint,
        shared-dataset cache respected).  Without this term the router
        would see only the thin WAN energy and happily strand an IO
        task's dataset a dozen router hops from its compute."""
        if not task.inputs:
            return 0.0
        rep = self.router.regions[region].endpoints[0]
        total = 0.0
        for (src, n, b, shared) in task.inputs:
            total += self.transfer.energy_j(
                TransferRequest(src, rep, n, b, shared)
            )
        return total

    def _route_window(self, tasks: list[TaskSpec], now: float
                      ) -> list[tuple[str, list[TaskSpec]]]:
        """Route one window's tasks to destination regions, billing WAN
        energy/egress and raising cross-region tasks' ``not_before`` by
        the WAN delay.  Returns ``(region, tasks)`` groups in router
        order, submission order preserved within each group.  Shared
        datasets bill the WAN once per destination region (cached);
        private inputs and the invocation payload bill every time."""
        router = self.router
        agent = router.mode == "agent"
        backlog = self._region_backlog(now) if agent else None
        routed_n = dict.fromkeys(router.names, 0)
        e_cache: dict[str, dict[str, float]] = {}
        groups: dict[str, list[TaskSpec]] = {r: [] for r in router.names}
        for t in tasks:
            payload = task_payload_bytes(t)
            shared = task_shared_inputs(t)
            energy = congestion = None
            if agent:
                compute = e_cache.get(t.fn)
                if compute is None:
                    compute = e_cache[t.fn] = {
                        r: self._region_energy_est(t.fn, r)
                        for r in router.names
                    }
                energy = (
                    compute if not t.inputs else {
                        r: compute[r] + self._region_transfer_est(t, r)
                        for r in router.names
                    }
                )
                congestion = {
                    r: backlog[r] / router.rt_scale
                    + routed_n[r] / self._region_capacity[r]
                    for r in router.names
                }
            nbytes = payload + sum(b for _, b in shared)
            src, dst = router.route(t.user, nbytes, now,
                                    energy=energy, congestion=congestion)
            routed_n[dst] += 1
            if src != dst:
                bill = payload
                for key, b in shared:
                    ck = (key, b, dst)
                    if ck not in self._wan_cached:
                        self._wan_cached.add(ck)
                        bill += b
                j = router.regions[src].wan_joules(dst, bill)
                delay = router.regions[src].wan_delay_s(dst, bill)
                self.wan_j += j
                self.egress_bytes += bill
                self.wan_events.append((now, src, dst, bill, j))
                if delay > 0.0:
                    t = dataclasses.replace(
                        t, not_before=max(t.not_before, now + delay)
                    )
            self.region_tasks[dst] = self.region_tasks.get(dst, 0) + 1
            groups[dst].append(t)
        return [(r, groups[r]) for r in router.names if groups[r]]

    def _place_regions(
        self, tasks: list[TaskSpec], ctx: PolicyContext, now: float,
        alive: tuple[bool, ...] | None,
    ) -> tuple[list[TaskSpec], Schedule]:
        """Region-partitioned placement: route every task, then run the
        endpoint-level policy once per non-empty region with the fleet
        narrowed to that region's members through the alive mask.  One
        region covering the whole fleet degenerates to the exact
        unpartitioned call — the membership mask collapses to ``None``
        and the single group preserves task order — so placements stay
        bitwise-identical to a region-free engine.  Returns the (possibly
        WAN-delayed) tasks in placement order and the merged schedule
        (cumulative objective/energy/makespan from the final group's
        state metrics, assignments/timeline for this window's tasks)."""
        groups = self._route_window(tasks, now)
        routed: list[TaskSpec] = []
        merged_asg: dict[str, str] = {}
        merged_tl: dict[str, tuple[float, float]] = {}
        schedule = None
        for region, gtasks in groups:
            gmask = self.router.endpoint_mask(region, self.endpoints)
            if gmask is not None and alive is not None:
                both = tuple(m and a for m, a in zip(gmask, alive))
                # whole region dark: fall back to the fault mask alone
                gmask = both if any(both) else alive
            elif gmask is None:
                gmask = alive
            gctx = (ctx if gmask is ctx.alive
                    else dataclasses.replace(ctx, alive=gmask))
            schedule = self.policy.place(gtasks, gctx, state=self.state)
            for t in gtasks:
                merged_asg[t.id] = schedule.assignments[t.id]
                merged_tl[t.id] = schedule.timeline[t.id]
            routed.extend(gtasks)
        schedule = dataclasses.replace(
            schedule, assignments=merged_asg, timeline=merged_tl
        )
        return routed, schedule

    # ------------------------------------------------------------------
    def flush(self) -> WindowResult | None:
        """Place and dispatch all pending tasks as one window."""
        if not self.pending:
            return None
        tasks, self.pending = self.pending, []
        submitted_at = (
            self.clock if self._first_pending_at is None
            else self._first_pending_at
        )
        self._first_pending_at = None
        if self.carbon is not None and self.defer_horizon_s > 0.0:
            tasks = self._split_deferrable(tasks, submitted_at)
            if not tasks:
                return None     # whole window shifted to a cleaner grid
        if self.fairness is not None:
            self.fairness.advance(submitted_at)
            if self.admission is not None:
                tasks = self._admit(tasks, submitted_at)
                if not tasks:
                    return None     # whole window shed/deferred over budget

        if self.state is None:
            # engine="auto": first window — resolve the crossover on the
            # actual fleet and window size, then keep that layout for life
            self.engine = auto_engine(len(self.endpoints), len(tasks))
            state_cls = (SoAState if self.engine in ("soa", "jax")
                         else SchedulerState)
            self.state = state_cls(self.endpoints, self.transfer)
        alive = warm = None
        if self.fault_aware:
            if self.faults is not None:
                alive_l = [self.faults.is_up(e.name, submitted_at)
                           for e in self.endpoints]
                if not any(alive_l):
                    # whole fleet dark: open the window at the earliest
                    # recovery instead of placing onto dead endpoints
                    t_up = min(self.faults.next_up(e.name, submitted_at)
                               for e in self.endpoints)
                    if t_up == float("inf"):
                        raise RuntimeError(
                            "every endpoint is down and none recovers: "
                            "cannot place this window"
                        )
                    submitted_at = t_up
                    self.clock = max(self.clock, t_up)
                    alive_l = [self.faults.is_up(e.name, submitted_at)
                               for e in self.endpoints]
                if not all(alive_l):
                    alive = tuple(alive_l)
            # snapshot idle gaps before advance_to erases them
            warm = WarmWeights.from_state(
                self.endpoints, self.state, submitted_at, self.faults
            )
        fair_w = (
            FairnessWeights.from_ledger(self.fairness, tasks)
            if self.fairness is not None else None
        )
        ctx = PolicyContext(self.endpoints, self.store, self.transfer,
                            self.alpha, carbon=self.carbon, now=submitted_at,
                            dag=self.dag, alive=alive, warm=warm,
                            fairness=fair_w)
        # placement previews must not start tasks before this window opened
        self.state.advance_to(submitted_at)
        t0 = time.perf_counter()
        if self.router is None:
            schedule = self.policy.place(tasks, ctx, state=self.state)
        else:
            tasks, schedule = self._place_regions(
                tasks, ctx, submitted_at, alive
            )
        sched_s = time.perf_counter() - t0
        assignments = {t.id: schedule.assignments[t.id] for t in tasks}

        sim = None
        attributed = 0.0
        if self.backend is not None:
            sim = self.backend.execute_window(assignments, tasks, now=submitted_at)
            # straggler candidates are judged against *pre-update*
            # predictions, before _learn folds this window's runtimes in
            spec_new = self._spec_candidates(sim)
            attributed = self._learn(sim)
            # profile updates moved the runtime estimates under the ranks
            self.dag.invalidate()
            self.clock = max(self.clock, submitted_at + self.window_s)
            self._cold_starts += sim.cold_starts
            self._cold_j += sim.cold_j
            self._process_records(sim, {t.id: t for t in tasks}, spec_new)
        else:
            # planner-only mode: completion times from the schedule timeline
            for t in tasks:
                _, end = schedule.timeline[t.id]
                if self.fairness is not None:
                    # no execution records to bill: charge predicted energy
                    p = self.store.predict(t.fn, assignments[t.id])
                    g = 0.0
                    if self.fairness.tracks_carbon and self.carbon is not None:
                        g = p.energy_j * self.carbon.rate_g_per_j(
                            assignments[t.id], end
                        )
                    self.fairness.charge(t.user, p.energy_j, g)
                self.completed[t.id] = (assignments[t.id], end)
                self.dag.complete(t.id, assignments[t.id], end)
        # timeline GC: completions may have retired finished subgraphs from
        # the planning graph — their (start, end) records can never be read
        # again (scoring only consults endpoint registers; transfer billing
        # reads retained producer records), so the live state sheds them
        retired = self.dag.drain_retired()
        if retired:
            self.state.drop_timeline(retired)
        res = WindowResult(
            index=self._n_windows, submitted_at=submitted_at, tasks=tasks,
            schedule=schedule, assignments=assignments, scheduling_s=sched_s,
            sim=sim, attributed_j=attributed,
        )
        self._n_windows += 1
        self._n_tasks += len(tasks)
        self._sched_s += sched_s
        self._attr_j += attributed
        self.windows.append(res)
        if (self.retain_windows is not None
                and len(self.windows) > self.retain_windows):
            del self.windows[:len(self.windows) - self.retain_windows]
        self._promote_ready()
        return res

    # ------------------------------------------------------------------
    # multi-tenant admission control (budget gate at the window boundary)
    def _admit(self, tasks: list[TaskSpec], now: float) -> list[TaskSpec]:
        """Gate over-budget submissions: a task whose user's debt is at
        least ``admission_debt`` windows is shed (recorded) or deferred
        to the next budget replenish — at most ``admission_max_defer``
        times, after which it is admitted anyway so nothing starves."""
        led = self.fairness
        keep: list[TaskSpec] = []
        for t in tasks:
            if led.debt(t.user) < self.admission_debt:
                keep.append(t)
                continue
            if self.admission == "defer":
                n = self._adm_defer.get(t.id, 0)
                if n < self.admission_max_defer:
                    self._adm_defer[t.id] = n + 1
                    release = led.next_replenish(now)
                    heapq.heappush(
                        self.deferred, (release, next(self._defer_seq), t)
                    )
                    continue
                keep.append(t)   # defer budget spent: admit, never starve
                continue
            self.shed.append(t)
            self.shed_ids.add(t.id)
        return keep

    # ------------------------------------------------------------------
    # fault handling: retries, permanent failures, speculation
    def _requeue(self, task: TaskSpec) -> None:
        """Put a retry/backup copy straight into the pending queue (its
        ``not_before`` floor carries the backoff / launch delay)."""
        if self._first_pending_at is None:
            self._first_pending_at = self.clock
        self.pending.append(task)

    def _spec_candidates(self, sim: SimResult) -> dict[str, float]:
        """Successful records whose runtime blew past ``spec_factor x`` the
        pre-update prediction: base task id -> predicted runtime (s)."""
        if self.spec_factor is None:
            return {}
        out: dict[str, float] = {}
        for rec in sim.records:
            tid = rec.task_id
            if (rec.failed or tid.endswith("@spec") or tid in self._spec_done
                    or tid in self._spec_primary):
                continue
            pred = self.store.predict(rec.fn, rec.endpoint).runtime_s
            if pred > 0.0 and rec.runtime > self.spec_factor * pred:
                out[tid] = pred
        return out

    def _process_records(self, sim: SimResult, by_id: dict[str, TaskSpec],
                         spec_new: dict[str, float]) -> None:
        """Route one window's execution records: completions feed the DAG,
        kills re-enter the pending queue with exponential backoff (until
        ``retry_cap``), stragglers race a speculative backup copy."""
        led = self.fairness
        for rec in sim.records:
            if led is not None and rec.energy_j:
                # every execution bills its principal — failed attempts and
                # losing speculative copies burned real joules too
                g = 0.0
                if led.tracks_carbon and self.carbon is not None:
                    g = rec.energy_j * self.carbon.rate_g_per_j(
                        rec.endpoint, rec.t_end
                    )
                led.charge(rec.user, rec.energy_j, g)
            tid = rec.task_id
            if tid.endswith("@spec"):
                self._resolve_speculation(tid, rec)
                continue
            if rec.failed:
                self._failures += 1
                self._wasted_j += rec.energy_j or 0.0
                self._first_fail_at.setdefault(tid, rec.t_end)
                attempts = self._attempts.get(tid, 0) + 1
                self._attempts[tid] = attempts
                if attempts > self.retry_cap:
                    self.failed_permanently.add(tid)
                    self._first_fail_at.pop(tid, None)
                    continue
                self._retries += 1
                backoff = self.retry_backoff_s * (2.0 ** (attempts - 1))
                self._requeue(dataclasses.replace(
                    by_id[tid],
                    not_before=max(by_id[tid].not_before, rec.t_end + backoff),
                ))
                continue
            if tid in spec_new:
                # straggling primary: hold its completion, race a backup
                # (deps already concretized when the primary was placed)
                self._spec_primary[tid] = rec
                self._spec_done.add(tid)
                self._spec_launched += 1
                release = rec.t_start + self.spec_factor * spec_new[tid]
                self._requeue(dataclasses.replace(
                    by_id[tid], id=tid + "@spec", deps=(),
                    not_before=max(by_id[tid].not_before, release),
                ))
                continue
            if tid in self._first_fail_at:
                self._recovery_s.append(
                    rec.t_end - self._first_fail_at.pop(tid)
                )
            self.completed[tid] = (rec.endpoint, rec.t_end)
            self.dag.complete(tid, rec.endpoint, rec.t_end)

    def _resolve_speculation(self, spec_id: str, rec) -> None:
        """A backup copy finished (or died): the earlier finisher wins, the
        loser's energy is billed as speculation waste, and the base task
        completes at the winner's endpoint/time."""
        base = spec_id[: -len("@spec")]
        prim = self._spec_primary.pop(base)
        if rec.failed or prim.t_end <= rec.t_end:
            winner, loser = prim, rec
        else:
            winner, loser = rec, prim
            self._spec_wins += 1
        self._spec_wasted_j += loser.energy_j or 0.0
        self.completed[base] = (winner.endpoint, winner.t_end)
        self.dag.complete(base, winner.endpoint, winner.t_end)
        # the backup id never entered the planning graph, so retirement
        # can't shed its timeline entry — drop it explicitly
        self.state.drop_timeline([spec_id])

    def drain(self) -> list[WindowResult]:
        """Flush until nothing is pending, *waiting*, or deferred; returns
        all window results.  For DAG workloads this runs wave after wave as
        parents complete; for carbon deferrals it advances the clock to the
        next release time once only deferred work remains.  Raises
        ``RuntimeError`` if waiting tasks can never be promoted (dependency
        cycle or a parent that was never submitted)."""
        while True:
            self._release_deferred(self.clock)
            self.flush()
            while self.pending:
                self.flush()
            if not self.deferred:
                break
            # only time-shifted work remains: jump to its release
            self.clock = max(self.clock, self.deferred[0][0])
        # cascade: a child whose parent failed permanently (or was shed by
        # admission control) can never run — mark it likewise (goodput < 1)
        # instead of deadlocking the drain
        if (self.failed_permanently or self.shed_ids) and self.waiting:
            changed = True
            while changed:
                changed = False
                for tid, t in list(self.waiting.items()):
                    if any(d in self.failed_permanently for d in t.deps):
                        del self.waiting[tid]
                        self.failed_permanently.add(tid)
                        changed = True
                    elif any(d in self.shed_ids for d in t.deps):
                        del self.waiting[tid]
                        self.shed.append(t)
                        self.shed_ids.add(tid)
                        changed = True
        if self.waiting:
            def _why(dep: str) -> str:
                if dep in self.failed_permanently:
                    n = self._attempts.get(dep, 0)
                    return f"{dep} (failed permanently after {n} attempts)"
                if dep in self.shed_ids:
                    return f"{dep} (shed by admission control)"
                if dep not in self._submitted_ids:
                    return f"{dep} (never submitted)"
                return f"{dep} (still pending/in flight: possible cycle)"

            blocked = {
                tid: [_why(d) for d in t.deps if d not in self.completed]
                for tid, t in self.waiting.items()
            }
            raise RuntimeError(
                f"drain deadlock: {len(self.waiting)} task(s) still waiting "
                f"on unmet dependencies: "
                f"{dict(list(blocked.items())[:5])}"
            )
        return self.windows

    # ------------------------------------------------------------------
    def _learn(self, sim: SimResult) -> float:
        """Feed completed-task records back into the profile store.  Killed
        executions still get their (partial) energy billed and logged to
        the DB, but never enter the profile store: a truncated runtime is
        not a runtime observation."""
        if self.monitoring:
            _, attributed = attribute_window(sim, self.models, self.store, self.db)
            return attributed
        total = 0.0
        for rec in sim.records:
            _, w, _ = self.backend.task_truth(rec.fn, rec.endpoint)
            e = rec.runtime * w
            rec.energy_j = e
            if not rec.failed:
                self.store.record(rec.fn, rec.endpoint, rec.runtime, e)
            self.db.add(rec)
            total += e
        return total

    # ------------------------------------------------------------------
    def summary(self) -> EngineSummary:
        e, c, tj = (
            self.state.metrics() if self.state is not None else (0.0, 0.0, 0.0)
        )
        last = self.windows[-1].schedule.objective if self.windows else float("nan")
        n_sub = len(self._submitted_ids)
        n_done = sum(1 for tid in self.completed if tid in self._submitted_ids)
        return EngineSummary(
            windows=self._n_windows,
            tasks=self._n_tasks,
            objective=last,
            energy_j=e,
            makespan_s=c,
            transfer_j=tj,
            scheduling_s=self._sched_s,
            attributed_j=self._attr_j,
            deferred=len(self._deferred_ids),
            submitted=n_sub,
            completed=n_done,
            goodput=(n_done / n_sub) if n_sub else 1.0,
            failures=self._failures,
            retries=self._retries,
            permanent_failures=len(self.failed_permanently),
            wasted_j=self._wasted_j,
            cold_starts=self._cold_starts,
            cold_j=self._cold_j,
            spec_launched=self._spec_launched,
            spec_wins=self._spec_wins,
            spec_wasted_j=self._spec_wasted_j,
            mean_recovery_s=(
                sum(self._recovery_s) / len(self._recovery_s)
                if self._recovery_s else None
            ),
            shed=len(self.shed_ids),
            admission_deferred=len(self._adm_defer),
            regions=len(self.router.names) if self.router is not None else 0,
            wan_j=self.wan_j,
            egress_bytes=self.egress_bytes,
        )
