"""Event-driven online scheduling engine (paper §III as a *service*).

GreenFaaS is an online system: tasks arrive continuously and every
placement decision must see up-to-date profiles.  This engine closes the
learn loop *mid-workload* instead of only across ``run_batch`` calls:

    submit(task) ──> pending queue
                      │  arrival-window batcher (window_s / max_batch)
                      ▼
    policy.place(window_tasks, ctx, state=live)   # delta evaluation
                      ▼
    backend.execute_window(...)                   # incremental sim
                      ▼
    attribute_window(...)  ──>  TaskProfileStore  # profiles update
                      │
                      └──> next window's predictions see them

The live :class:`SchedulerState` carries endpoint timelines, transfer
cache contents, and accumulated energy across windows, so objectives are
cumulative and placements account for load already committed.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Sequence

from repro.core.carbon import CarbonIntensitySignal
from repro.core.dag import DAGView
from repro.core.database import TaskDB
from repro.core.endpoint import EndpointSpec
from repro.core.executor import attribute_window
from repro.core.policy import PlacementPolicy, PolicyContext, get_policy
from repro.core.power_model import LinearPowerModel
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import (
    Schedule, SchedulerState, SoAState, TaskSpec, auto_engine,
)
from repro.core.testbed import SimResult, TestbedSim
from repro.core.transfer import TransferModel


@dataclasses.dataclass
class WindowResult:
    """Outcome of one arrival window."""
    index: int
    submitted_at: float
    tasks: list[TaskSpec]
    schedule: Schedule               # objective/energy/makespan are cumulative
    assignments: dict[str, str]      # this window's tasks only
    scheduling_s: float
    sim: SimResult | None = None
    attributed_j: float = 0.0

    @property
    def placements(self) -> dict[str, int]:
        """endpoint -> task count for this window."""
        out: dict[str, int] = {}
        for ep in self.assignments.values():
            out[ep] = out.get(ep, 0) + 1
        return out


@dataclasses.dataclass
class EngineSummary:
    windows: int
    tasks: int
    objective: float
    energy_j: float          # scheduler-estimated cumulative E_tot
    makespan_s: float        # cumulative C_max
    transfer_j: float
    scheduling_s: float      # total time spent in placement decisions
    attributed_j: float
    deferred: int = 0        # tasks time-shifted by the carbon deferral queue


class OnlineEngine:
    """Streaming submission path over a live scheduler state.

    ``submit`` enqueues; a window fires when ``max_batch`` tasks are
    pending, when ``tick(now)`` sees ``window_s`` elapsed since the first
    pending arrival, or when ``flush``/``drain`` forces it.  Completed
    windows feed monitored task records back into the profile store, so
    profiles learned in window k steer placements in window k+1.

    **DAG workloads.**  A task whose ``deps`` name uncompleted parents is
    parked in ``waiting`` instead of ``pending``; when its last parent
    completes, the engine promotes it with ``not_before`` raised to a
    ready floor no earlier than every parent's completion (so no engine —
    and no simulated dispatch — can start it earlier) and with one
    transfer input per parent reading ``dep_bytes`` from the parent's
    *producing endpoint*.  ``promotion`` picks the floor granularity:

    - ``"epoch"`` (default): every task promoted by one pass shares a
      single floor — the latest parent completion across the whole
      promoted set (its *completion epoch*).  A wide DAG stage then
      releases children with identical ``not_before``, which keeps them
      inside one SoA run-memoization run (the floor is part of the memo
      key) and restores O(1) scoring on wide stages.
    - ``"exact"``: each child's floor is its own parents' latest
      completion — the tightest correct floor, at the cost of distinct
      floors fragmenting the SoA fast path.

    Both are conservative (a floor only grows), so DAG edges are honored
    either way.  ``drain`` keeps flushing until the whole DAG has run,
    and raises ``RuntimeError`` if tasks remain waiting with no
    completable parent (dependency cycle or a dep id that was never
    submitted).

    The engine also maintains a :class:`~repro.core.dag.DAGView` over
    everything submitted (``self.dag``): nodes/edges on submission,
    producer endpoints on completion.  Each window's
    :class:`PolicyContext` exposes it, so DAG-aware policies
    (``lookahead_mhra``) see critical-path ranks and data gravity for
    tasks that haven't even left the ready-set yet.

    **Units & mutation semantics.**  All energies are joules, times are
    seconds (reports divide by 1e3 for kJ).  ``submit``/``tick``/``flush``
    mutate the engine in place: the live state (``self.state``), profile
    store, task DB, and window list all accumulate across calls — create a
    fresh engine per experiment run.  Determinism: with a seeded
    ``TestbedSim`` backend and ``monitoring=False`` runs are bitwise
    reproducible; ``monitoring=True`` keeps placement deterministic but
    attributed energies depend on the sim's seeded monitor-noise draws.
    """

    def __init__(
        self,
        endpoints: Sequence[EndpointSpec],
        backend: TestbedSim | None = None,
        policy: str | PlacementPolicy = "mhra",
        alpha: float = 0.5,
        window_s: float = 1.0,
        max_batch: int = 256,
        store: TaskProfileStore | None = None,
        db: TaskDB | None = None,
        monitoring: bool = True,
        site: str | None = None,
        engine: str | None = "auto",
        carbon: CarbonIntensitySignal | None = None,
        defer_horizon_s: float = 0.0,
        defer_max: int = 256,
        defer_margin: float = 0.05,
        promotion: str = "epoch",
        prune: bool = True,
        retain_windows: int | None = None,
    ):
        """``engine`` selects the scheduling backend for registry-name
        mhra/cluster_mhra/carbon_mhra policies ("delta" or "soa") and the
        live state's layout: "soa" carries a :class:`SoAState` (flat
        arrays) across windows, anything else the heap-backed
        :class:`SchedulerState`.  The default ``"auto"`` resolves the
        calibrated fleet-size/window-size crossover
        (:func:`~repro.core.scheduler.auto_engine`) when the first window
        flushes — using that window's actual size — and the layout then
        stays fixed for the engine's lifetime, so no window ever pays a
        cross-layout (``from_heap``/``write_back``) conversion.  With a
        policy *instance*, the state layout follows the instance's own
        ``engine`` attribute (an instance carrying ``"auto"`` defers the
        same way).  ``engine="clone"`` is rejected here: the clone engine
        cannot place against a live state, so every window would fail.

        ``prune`` (default on) retires finished subgraphs from the live
        :class:`~repro.core.dag.DAGView` and drops their timeline entries
        from the live state, keeping per-decision cost a function of
        *live* tasks instead of everything ever submitted.  Producer
        endpoints of retained frontier nodes survive retirement, so
        transfer billing for still-waiting children is unchanged —
        placements are bitwise-identical with pruning on or off.
        ``retain_windows`` caps the kept :class:`WindowResult` history
        (None = keep all); ``summary()`` aggregates stay exact either
        way, via running counters.

        ``carbon`` exposes a grid-intensity signal to carbon-aware
        policies (via the per-window :class:`PolicyContext`) and, with
        ``defer_horizon_s > 0``, arms **temporal shifting**: at each
        window the engine looks up to ``defer_horizon_s`` seconds ahead
        for the exact fleet-mean intensity minimum, and if it undercuts
        the current intensity by at least ``defer_margin`` (relative),
        deadline-slack tasks are parked in a bounded deferral queue
        (``defer_max`` entries) and re-enter the pending queue at that
        release time with ``not_before`` raised to it — the same ready
        floor the DAG ready-set uses, so engines and the simulator clamp
        their starts exactly as they do for promoted DAG children.  Each
        task defers at most once (no starvation), and ``drain`` advances
        the clock to the earliest release when only deferred work
        remains, so a drain can never deadlock on the queue."""
        self.endpoints = list(endpoints)
        self.backend = backend
        if promotion not in ("epoch", "exact"):
            raise ValueError(
                f"promotion must be 'epoch' or 'exact', got {promotion!r}"
            )
        self.promotion = promotion
        if isinstance(policy, PlacementPolicy):
            self.policy = policy
        elif policy == "single_site":
            self.policy = get_policy(policy, site=site)
        elif engine is not None and policy in ("mhra", "cluster_mhra",
                                               "carbon_mhra",
                                               "lookahead_mhra"):
            self.policy = get_policy(policy, engine=engine)
        else:
            self.policy = get_policy(policy)
        pol_engine = getattr(self.policy, "engine", None)
        if engine is None or (engine == "auto"
                              and isinstance(policy, PlacementPolicy)):
            # a policy instance knows its engine; follow it (it may itself
            # carry "auto", which defers to the first window)
            self.engine = pol_engine if pol_engine is not None else "delta"
        elif engine == "auto" and pol_engine is None:
            # engine-less policies (round_robin, single_site) gain nothing
            # from the SoA layout; keep the heap default
            self.engine = "delta"
        else:
            self.engine = engine
        if self.engine == "clone":
            raise ValueError(
                "OnlineEngine requires a live-state engine ('delta' or "
                "'soa'); engine='clone' cannot place against the state "
                "carried across arrival windows"
            )
        self.alpha = alpha
        self.window_s = window_s
        self.max_batch = max_batch
        self.store = store or TaskProfileStore(self.endpoints)
        self.transfer = TransferModel(self.endpoints)
        self.db = db or TaskDB()
        self.models = {e.name: LinearPowerModel() for e in self.endpoints}
        self.monitoring = monitoring
        if self.engine == "auto":
            # resolved at the first flush, when the window size is known;
            # self.engine then becomes the concrete choice
            self.state = None
        else:
            state_cls = SoAState if self.engine == "soa" else SchedulerState
            self.state = state_cls(self.endpoints, self.transfer)
        self.prune = prune
        self.retain_windows = retain_windows
        self.pending: list[TaskSpec] = []
        self.windows: list[WindowResult] = []
        # running aggregates so summary() stays exact under retain_windows
        self._n_windows = 0
        self._n_tasks = 0
        self._sched_s = 0.0
        self._attr_j = 0.0
        self.waiting: dict[str, TaskSpec] = {}       # id -> dep-blocked task
        self.completed: dict[str, tuple[str, float]] = {}  # id -> (ep, t_end)
        self.dag = DAGView(runtime=self._runtime_estimate, prune=prune)
        self.carbon = carbon
        if defer_horizon_s > 0.0 and carbon is None:
            raise ValueError("defer_horizon_s needs a carbon signal")
        self.defer_horizon_s = defer_horizon_s
        self.defer_max = defer_max
        self.defer_margin = defer_margin
        self.deferred: list[tuple[float, int, TaskSpec]] = []  # release heap
        self._deferred_ids: set[str] = set()         # defer-once guard
        self._defer_seq = itertools.count()
        self.clock = 0.0
        self._first_pending_at: float | None = None
        if backend is not None:
            backend.begin_stream()

    # ------------------------------------------------------------------
    def submit(self, task: TaskSpec, when: float | None = None) -> WindowResult | None:
        """Enqueue one task; returns a WindowResult if this submission
        filled the batch and triggered a window.  A task with unmet
        ``deps`` is parked until its parents complete (see class docs)."""
        when = self.clock if when is None else when
        self.clock = max(self.clock, when)
        self.dag.add_task(task)
        if task.deps:
            if any(d not in self.completed for d in task.deps):
                self.waiting[task.id] = task
                return None
            task = self._resolve_deps(task)
        if self._first_pending_at is None:
            self._first_pending_at = when
        self.pending.append(task)
        if len(self.pending) >= self.max_batch:
            return self.flush()
        return None

    def _resolve_deps(self, task: TaskSpec, floor: float | None = None
                      ) -> TaskSpec:
        """Concretize a dep-bearing task whose parents have all completed:
        ready floor = latest parent completion (or the shared epoch
        ``floor``, when given — never earlier than the parents), plus one
        transfer input per parent pulling ``dep_bytes`` from the endpoint
        that produced it."""
        parents = [self.completed[d] for d in task.deps]
        not_before = max(end for _, end in parents)
        if floor is not None and floor > not_before:
            not_before = floor
        inputs = task.inputs
        if task.dep_bytes > 0.0:
            inputs = inputs + tuple(
                (ep, 1, task.dep_bytes, False) for ep, _ in parents
            )
        return dataclasses.replace(
            task, inputs=inputs, not_before=max(task.not_before, not_before)
        )

    def _promote_ready(self) -> int:
        """Move every waiting task whose parents have all completed into
        the pending queue; returns the number promoted.  In ``"epoch"``
        promotion mode the whole promoted set shares one ready floor —
        the latest parent completion across the set — so a wide stage's
        children carry identical ``not_before`` values and coalesce into
        one SoA memoization run."""
        ready = [
            t for t in self.waiting.values()
            if all(d in self.completed for d in t.deps)
        ]
        floor = None
        if self.promotion == "epoch" and ready:
            floor = max(
                self.completed[d][1] for t in ready for d in t.deps
            )
        for t in ready:
            del self.waiting[t.id]
            if self._first_pending_at is None:
                self._first_pending_at = self.clock
            self.pending.append(self._resolve_deps(t, floor=floor))
        return len(ready)

    def submit_many(self, tasks: Sequence[TaskSpec], when: float | None = None
                    ) -> list[WindowResult]:
        out = []
        for t in tasks:
            r = self.submit(t, when)
            if r is not None:
                out.append(r)
        return out

    def tick(self, now: float) -> WindowResult | None:
        """Advance the arrival clock; fire a window if one is due."""
        self.clock = max(self.clock, now)
        self._release_deferred(self.clock)
        if (
            self.pending
            and self._first_pending_at is not None
            and now - self._first_pending_at >= self.window_s
        ):
            return self.flush()
        return None

    # ------------------------------------------------------------------
    # carbon-aware temporal shifting (bounded deferral queue)
    def _release_deferred(self, now: float) -> int:
        """Move deferred tasks whose release time has arrived back into the
        pending queue with ``not_before`` raised to the release time."""
        n = 0
        while self.deferred and self.deferred[0][0] <= now:
            release, _, task = heapq.heappop(self.deferred)
            if self._first_pending_at is None:
                self._first_pending_at = release
            self.pending.append(dataclasses.replace(
                task, not_before=max(task.not_before, release)
            ))
            n += 1
        return n

    def _runtime_estimate(self, fn: str) -> float:
        """Fleet-mean predicted runtime — the slack check's cost model."""
        preds = [self.store.predict(fn, e.name) for e in self.endpoints]
        return sum(p.runtime_s for p in preds) / len(preds)

    def _split_deferrable(self, tasks: list[TaskSpec], now: float
                          ) -> list[TaskSpec]:
        """Park deadline-slack tasks for a cleaner-grid window; returns the
        tasks to place *now*.  No-op unless the exact fleet-mean intensity
        minimum within the horizon undercuts the current intensity by
        ``defer_margin`` and the bounded queue has room."""
        if self.defer_max - len(self.deferred) <= 0:
            return tasks     # queue full: skip the signal scans entirely
        names = [e.name for e in self.endpoints]
        cur = self.carbon.fleet_mean_intensity(names, now)
        t_best, best = self.carbon.argmin_fleet_mean(
            names, now, now + self.defer_horizon_s
        )
        if t_best <= now or best > (1.0 - self.defer_margin) * cur:
            return tasks
        keep: list[TaskSpec] = []
        room = self.defer_max - len(self.deferred)
        rt_est: dict[str, float] = {}
        for t in tasks:
            if room <= 0 or t.id in self._deferred_ids:
                keep.append(t)
                continue
            if t.deadline != float("inf"):
                rt = rt_est.get(t.fn)
                if rt is None:
                    rt = rt_est[t.fn] = self._runtime_estimate(t.fn)
                if t_best + rt > t.deadline:
                    keep.append(t)      # no slack: deferral would miss it
                    continue
            heapq.heappush(self.deferred, (t_best, next(self._defer_seq), t))
            self._deferred_ids.add(t.id)
            room -= 1
        return keep

    # ------------------------------------------------------------------
    def flush(self) -> WindowResult | None:
        """Place and dispatch all pending tasks as one window."""
        if not self.pending:
            return None
        tasks, self.pending = self.pending, []
        submitted_at = (
            self.clock if self._first_pending_at is None
            else self._first_pending_at
        )
        self._first_pending_at = None
        if self.carbon is not None and self.defer_horizon_s > 0.0:
            tasks = self._split_deferrable(tasks, submitted_at)
            if not tasks:
                return None     # whole window shifted to a cleaner grid

        ctx = PolicyContext(self.endpoints, self.store, self.transfer,
                            self.alpha, carbon=self.carbon, now=submitted_at,
                            dag=self.dag)
        if self.state is None:
            # engine="auto": first window — resolve the crossover on the
            # actual fleet and window size, then keep that layout for life
            self.engine = auto_engine(len(self.endpoints), len(tasks))
            state_cls = SoAState if self.engine == "soa" else SchedulerState
            self.state = state_cls(self.endpoints, self.transfer)
        # placement previews must not start tasks before this window opened
        self.state.advance_to(submitted_at)
        t0 = time.perf_counter()
        schedule = self.policy.place(tasks, ctx, state=self.state)
        sched_s = time.perf_counter() - t0
        assignments = {t.id: schedule.assignments[t.id] for t in tasks}

        sim = None
        attributed = 0.0
        if self.backend is not None:
            sim = self.backend.execute_window(assignments, tasks, now=submitted_at)
            attributed = self._learn(sim)
            # profile updates moved the runtime estimates under the ranks
            self.dag.invalidate()
            self.clock = max(self.clock, submitted_at + self.window_s)
            for rec in sim.records:
                self.completed[rec.task_id] = (rec.endpoint, rec.t_end)
                self.dag.complete(rec.task_id, rec.endpoint, rec.t_end)
        else:
            # planner-only mode: completion times from the schedule timeline
            for t in tasks:
                _, end = schedule.timeline[t.id]
                self.completed[t.id] = (assignments[t.id], end)
                self.dag.complete(t.id, assignments[t.id], end)
        # timeline GC: completions may have retired finished subgraphs from
        # the planning graph — their (start, end) records can never be read
        # again (scoring only consults endpoint registers; transfer billing
        # reads retained producer records), so the live state sheds them
        retired = self.dag.drain_retired()
        if retired:
            self.state.drop_timeline(retired)
        res = WindowResult(
            index=self._n_windows, submitted_at=submitted_at, tasks=tasks,
            schedule=schedule, assignments=assignments, scheduling_s=sched_s,
            sim=sim, attributed_j=attributed,
        )
        self._n_windows += 1
        self._n_tasks += len(tasks)
        self._sched_s += sched_s
        self._attr_j += attributed
        self.windows.append(res)
        if (self.retain_windows is not None
                and len(self.windows) > self.retain_windows):
            del self.windows[:len(self.windows) - self.retain_windows]
        self._promote_ready()
        return res

    def drain(self) -> list[WindowResult]:
        """Flush until nothing is pending, *waiting*, or deferred; returns
        all window results.  For DAG workloads this runs wave after wave as
        parents complete; for carbon deferrals it advances the clock to the
        next release time once only deferred work remains.  Raises
        ``RuntimeError`` if waiting tasks can never be promoted (dependency
        cycle or a parent that was never submitted)."""
        while True:
            self._release_deferred(self.clock)
            self.flush()
            while self.pending:
                self.flush()
            if not self.deferred:
                break
            # only time-shifted work remains: jump to its release
            self.clock = max(self.clock, self.deferred[0][0])
        if self.waiting:
            blocked = {
                tid: [d for d in t.deps if d not in self.completed]
                for tid, t in self.waiting.items()
            }
            raise RuntimeError(
                f"drain deadlock: {len(self.waiting)} task(s) still waiting "
                f"on unmet dependencies (cycle, or parents never submitted): "
                f"{dict(list(blocked.items())[:5])}"
            )
        return self.windows

    # ------------------------------------------------------------------
    def _learn(self, sim: SimResult) -> float:
        """Feed completed-task records back into the profile store."""
        if self.monitoring:
            _, attributed = attribute_window(sim, self.models, self.store, self.db)
            return attributed
        total = 0.0
        for rec in sim.records:
            _, w, _ = self.backend.task_truth(rec.fn, rec.endpoint)
            e = rec.runtime * w
            rec.energy_j = e
            self.store.record(rec.fn, rec.endpoint, rec.runtime, e)
            self.db.add(rec)
            total += e
        return total

    # ------------------------------------------------------------------
    def summary(self) -> EngineSummary:
        e, c, tj = (
            self.state.metrics() if self.state is not None else (0.0, 0.0, 0.0)
        )
        last = self.windows[-1].schedule.objective if self.windows else float("nan")
        return EngineSummary(
            windows=self._n_windows,
            tasks=self._n_tasks,
            objective=last,
            energy_j=e,
            makespan_s=c,
            transfer_j=tj,
            scheduling_s=self._sched_s,
            attributed_j=self._attr_j,
            deferred=len(self._deferred_ids),
        )
