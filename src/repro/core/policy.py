"""Pluggable placement policies behind one contract (paper Table V rows).

A :class:`PlacementPolicy` turns a batch of tasks (an arrival window, or a
whole workload) into endpoint assignments::

    schedule = policy.place(tasks, ctx)                 # batch mode
    schedule = policy.place(tasks, ctx, state=live)     # online mode

Online mode commits the placements into a live :class:`SchedulerState`
carried across arrival windows, so later windows see the timelines, cache
contents, and energy already accumulated by earlier ones.

Policies are registered by name so executors and the online engine accept
``policy="cluster_mhra"`` instead of hard-coded if/elif dispatch::

    @register_policy
    class MyPolicy(PlacementPolicy):
        name = "my_policy"
        def place(self, tasks, ctx, state=None): ...

    get_policy("my_policy")
"""
from __future__ import annotations

import abc
import dataclasses
from typing import ClassVar, Sequence

from repro.core import scheduler as sched
from repro.core.carbon import CarbonIntensitySignal, CarbonWeights
from repro.core.dag import DAGView, LookaheadWeights
from repro.core.endpoint import EndpointSpec
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import Schedule, SchedulerState, TaskSpec
from repro.core.transfer import TransferModel

ENGINES = ("delta", "clone", "soa", "jax", "auto")


def _check_engine(engine: str) -> str:
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; available: {ENGINES}")
    return engine


@dataclasses.dataclass
class PolicyContext:
    """Everything a policy needs besides the tasks themselves.

    ``store`` predictions and the scheduling objective are in seconds and
    joules; ``alpha`` weights energy vs makespan (``alpha=1`` is pure
    energy).  The context is read-mostly: policies may *query* the store
    and transfer model but must not record into them — learning is the
    engine/executor's job after execution.

    ``carbon``/``now`` describe the grid at the moment this batch is
    placed: carbon-aware policies snapshot per-endpoint g/J rates from
    the signal at ``now`` (the arrival-window open time).  Both are
    optional — carbon-blind policies ignore them.

    ``dag`` is the engine's live planning graph
    (:class:`~repro.core.dag.DAGView`): critical-path ranks, descendant
    dep-bytes mass, and producer endpoints over every *submitted* task —
    including ones still parked in the ready-set.  DAG-aware policies
    (``lookahead_mhra``) snapshot per-task weights from it; myopic
    policies never touch it and pay nothing for it.

    ``alive``/``warm`` carry the fault-aware engine's fleet snapshot at
    the window-open time: a per-endpoint up/down mask (dead endpoints are
    excluded from candidate scoring) and a
    :class:`~repro.core.faults.WarmWeights` expected-cold-start penalty.
    Both default to None — fault-oblivious runs and baseline policies
    never see them, keeping every scoring path bitwise-unchanged.

    ``fairness`` is the multi-tenant engine's per-window debt snapshot
    (:class:`~repro.core.fairness.FairnessWeights`): user -> windows of
    budget overdrawn, which MHRA-family policies fold into candidate
    scoring as an advantage tax.  None (always, when the engine has no
    fairness budget) keeps every scoring path bitwise-unchanged.
    """
    endpoints: Sequence[EndpointSpec]
    store: TaskProfileStore
    transfer: TransferModel
    alpha: float = 0.5
    carbon: CarbonIntensitySignal | None = None
    now: float = 0.0
    dag: DAGView | None = None
    alive: tuple | None = None
    warm: "object | None" = None   # WarmWeights snapshot (or None)
    fairness: "object | None" = None   # FairnessWeights snapshot (or None)


class PlacementPolicy(abc.ABC):
    """One placement decision: tasks -> endpoint assignments.

    Contract notes:

    - Policies receive only *placeable* tasks: the online engine resolves
      DAG dependencies first, so a dep-bearing task arrives with its
      ``not_before`` ready floor and parent-endpoint transfer inputs
      already concretized.  Every engine clamps task starts to
      ``TaskSpec.not_before`` — a policy never needs to reorder for
      dependencies.
    - ``place`` must assign *every* task it is given and return a
      :class:`Schedule` whose ``objective``/``energy_j``/``makespan_s``
      (joules / seconds) describe the *cumulative* state when ``state``
      is passed, not just this batch.
    - Policies must be deterministic given (tasks, ctx, state); any
      randomness belongs in workload generation, not placement.
    """

    name: ClassVar[str] = "abstract"

    @abc.abstractmethod
    def place(
        self,
        tasks: Sequence[TaskSpec],
        ctx: PolicyContext,
        state: SchedulerState | None = None,
    ) -> Schedule:
        """Place ``tasks``; with ``state`` given, commit into the live
        timeline (online mode, mutating ``state``) instead of starting
        from an empty one."""

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<{type(self).__name__} name={self.name!r}>"


_REGISTRY: dict[str, type[PlacementPolicy]] = {}


def register_policy(cls: type[PlacementPolicy]) -> type[PlacementPolicy]:
    """Class decorator: make a policy constructible via :func:`get_policy`."""
    name = getattr(cls, "name", None)
    if not name or name == "abstract":
        raise ValueError(f"{cls.__name__} must define a class-level name")
    _REGISTRY[name] = cls
    return cls


def available_policies() -> list[str]:
    return sorted(_REGISTRY)


def get_policy(name: str, **kwargs) -> PlacementPolicy:
    """Instantiate a registered policy by name (kwargs -> constructor)."""
    try:
        cls = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; available: {available_policies()}"
        ) from None
    return cls(**kwargs)


# ---------------------------------------------------------------------------
# Built-in policies
# ---------------------------------------------------------------------------


@register_policy
class MHRAPolicy(PlacementPolicy):
    """Multi-Heuristic Resource Allocation (paper §III-F).

    ``engine`` selects the greedy backend: ``delta`` (incremental,
    default), ``soa`` (structure-of-arrays, fastest at large fleets /
    task counts), ``clone`` (the seed reference), or ``auto`` (the
    calibrated fleet-size/window-size crossover — see
    :func:`~repro.core.scheduler.auto_engine`; in online mode it follows
    the live state's layout so no cross-layout conversion ever happens).
    """

    name = "mhra"

    def __init__(self, heuristics: Sequence[str] = sched.HEURISTICS,
                 engine: str = "delta"):
        self.heuristics = tuple(heuristics)
        self.engine = _check_engine(engine)

    def place(self, tasks, ctx, state=None):
        return sched.mhra(
            tasks, ctx.endpoints, ctx.store, ctx.transfer, ctx.alpha,
            self.heuristics, engine=self.engine, state=state,
            alive=ctx.alive, warm=ctx.warm, fairness=ctx.fairness,
        )


@register_policy
class CarbonMHRAPolicy(PlacementPolicy):
    """MHRA scoring carbon-adjusted energy: the greedy objective gains a
    ``gamma * gCO2/SF3`` term with per-endpoint g/J rates snapshotted
    from ``ctx.carbon`` at the window-open time, so placements chase
    low-carbon grids as intensities move.  Without a signal in the
    context it degrades to plain MHRA (same engine, no carbon term).
    Temporal shifting — deferring slack tasks to a cleaner window — is
    the online engine's job (``OnlineEngine(defer_horizon_s=...)``);
    this policy handles the *spatial* half.
    """

    name = "carbon_mhra"

    def __init__(self, heuristics: Sequence[str] = sched.HEURISTICS,
                 engine: str = "delta", gamma: float = 1.0):
        self.heuristics = tuple(heuristics)
        self.engine = _check_engine(engine)
        if gamma < 0:
            raise ValueError(f"gamma must be non-negative, got {gamma}")
        self.gamma = gamma

    def place(self, tasks, ctx, state=None):
        carbon = None
        if ctx.carbon is not None:
            carbon = CarbonWeights.from_signal(
                ctx.carbon, ctx.endpoints, ctx.now, self.gamma
            )
        return sched.mhra(
            tasks, ctx.endpoints, ctx.store, ctx.transfer, ctx.alpha,
            self.heuristics, engine=self.engine, state=state, carbon=carbon,
            alive=ctx.alive, warm=ctx.warm, fairness=ctx.fairness,
        )


@register_policy
class LookaheadMHRAPolicy(PlacementPolicy):
    """MHRA over the planning graph: candidates are scored with two extra
    DAG-aware terms snapshotted from ``ctx.dag`` —

    - **rank weighting**: each task's candidate finish time enters the
      objective weighted by its normalized downstream criticality
      (``up_rest / rank_scale``), so tasks with long dependent chains
      chase early finishes even where the myopic objective is
      indifferent;
    - **data gravity**: a task whose children will pull ``dep_bytes``
      from wherever it lands is charged the expected escape cost of that
      payload (``out_bytes * E_inc * mean hops from the candidate``),
      pre-positioning heavy producers on well-connected endpoints.

    ``lam`` scales both terms (0 = plain MHRA).  On a batch with no
    downstream structure — flat workloads, or the DAG's sink stage — the
    snapshot collapses to ``None`` and the placement is bit-identical to
    plain MHRA.  The reported ``Schedule.objective`` stays the unshaped
    base objective.
    """

    name = "lookahead_mhra"

    def __init__(self, heuristics: Sequence[str] = sched.HEURISTICS,
                 engine: str = "delta", lam: float = 1.0,
                 producer_aware: bool = False):
        self.heuristics = tuple(heuristics)
        self.engine = _check_engine(engine)
        if lam < 0:
            raise ValueError(f"lam must be non-negative, got {lam}")
        self.lam = lam
        # producer-aware gravity: weight each producer's outbound bytes by
        # the hop distance to its children's *predicted* endpoints instead
        # of the fleet mean (False keeps the fleet-mean build bit-exact)
        self.producer_aware = producer_aware

    def place(self, tasks, ctx, state=None):
        lookahead = None
        if ctx.dag is not None:
            lookahead = LookaheadWeights.from_dag(
                ctx.dag, tasks, ctx.endpoints, ctx.transfer, self.lam,
                store=ctx.store, producer_aware=self.producer_aware,
            )
        return sched.mhra(
            tasks, ctx.endpoints, ctx.store, ctx.transfer, ctx.alpha,
            self.heuristics, engine=self.engine, state=state,
            lookahead=lookahead, alive=ctx.alive, warm=ctx.warm,
            fairness=ctx.fairness,
        )


@register_policy
class ClusterMHRAPolicy(PlacementPolicy):
    """Algorithm 1: agglomerative clustering + per-cluster greedy MHRA."""

    name = "cluster_mhra"

    def __init__(self, heuristics: Sequence[str] = sched.HEURISTICS,
                 max_cluster_size: int = 40, engine: str = "delta"):
        self.heuristics = tuple(heuristics)
        self.max_cluster_size = max_cluster_size
        self.engine = _check_engine(engine)

    def place(self, tasks, ctx, state=None):
        return sched.cluster_mhra(
            tasks, ctx.endpoints, ctx.store, ctx.transfer, ctx.alpha,
            self.heuristics, self.max_cluster_size,
            engine=self.engine, state=state,
            alive=ctx.alive, warm=ctx.warm, fairness=ctx.fairness,
        )


@register_policy
class RoundRobinPolicy(PlacementPolicy):
    """Rotates through endpoints; the rotation continues across windows."""

    name = "round_robin"

    def __init__(self):
        self._offset = 0

    def place(self, tasks, ctx, state=None):
        s = sched.round_robin(
            tasks, ctx.endpoints, ctx.store, ctx.transfer,
            state=state, offset=self._offset,
        )
        self._offset = (self._offset + len(list(tasks))) % len(ctx.endpoints)
        return s


@register_policy
class SingleSitePolicy(PlacementPolicy):
    """Every task on one named endpoint (Table V per-machine rows)."""

    name = "single_site"

    def __init__(self, site: str | None = None):
        if not site:
            raise ValueError("single_site policy requires site=<endpoint name>")
        self.site = site

    def place(self, tasks, ctx, state=None):
        return sched.single_site(
            tasks, ctx.endpoints, ctx.store, ctx.transfer, self.site,
            state=state,
        )
