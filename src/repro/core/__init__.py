"""GreenFaaS core: the paper's pipeline (submit -> predict -> place ->
dispatch -> monitor -> attribute -> learn) as composable pieces.

- scheduler: MHRA / Cluster MHRA + baselines, delta-evaluation greedy
- policy:    pluggable placement policies registrable by name
- engine:    event-driven online engine (arrival windows, live state)
- executor:  batch executor over a pluggable backend
- testbed:   discrete-event simulator of the paper's Table-I testbed
"""
from repro.core.engine import EngineSummary, OnlineEngine, WindowResult
from repro.core.executor import BatchResult, GreenFaaSExecutor
from repro.core.region import RegionRouter, RegionSpec
from repro.core.policy import (
    PlacementPolicy,
    PolicyContext,
    available_policies,
    get_policy,
    register_policy,
)
from repro.core.scheduler import (
    HEURISTICS,
    Schedule,
    SchedulerState,
    TaskSpec,
    cluster_mhra,
    mhra,
    round_robin,
    single_site,
)

__all__ = [
    "BatchResult",
    "EngineSummary",
    "GreenFaaSExecutor",
    "HEURISTICS",
    "OnlineEngine",
    "PlacementPolicy",
    "PolicyContext",
    "RegionRouter",
    "RegionSpec",
    "Schedule",
    "SchedulerState",
    "TaskSpec",
    "WindowResult",
    "available_policies",
    "cluster_mhra",
    "get_policy",
    "mhra",
    "register_policy",
    "round_robin",
    "single_site",
]
