"""Online per-(function, endpoint) runtime/energy profiles (paper §III-F:
"predictions are an average of historical performance").

Cold start: if a function has never run on an endpoint, fall back to its
global per-core-second profile scaled by the endpoint's relative speed; if
the function has never run anywhere, use an exploration prior that spreads
probes across endpoints.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np


@dataclasses.dataclass
class RunningStat:
    n: int = 0
    mean: float = 0.0
    m2: float = 0.0

    def add(self, x: float) -> None:
        self.n += 1
        d = x - self.mean
        self.mean += d / self.n
        self.m2 += d * (x - self.mean)

    @property
    def std(self) -> float:
        return (self.m2 / self.n) ** 0.5 if self.n > 1 else 0.0


@dataclasses.dataclass(frozen=True)
class Prediction:
    runtime_s: float
    energy_j: float
    confident: bool  # False => exploration prior


class TaskProfileStore:
    def __init__(self, endpoints=None):
        self._rt = defaultdict(RunningStat)   # (fn, ep) -> runtime
        self._en = defaultdict(RunningStat)   # (fn, ep) -> dynamic energy
        self._eps: dict[str, float] = {
            e.name: e.perf_scale for e in (endpoints or [])
        }

    def record(self, fn: str, endpoint: str, runtime_s: float, energy_j: float):
        self._rt[(fn, endpoint)].add(runtime_s)
        self._en[(fn, endpoint)].add(energy_j)

    def n_obs(self, fn: str, endpoint: str) -> int:
        return self._rt[(fn, endpoint)].n

    def predict(self, fn: str, endpoint: str) -> Prediction:
        key = (fn, endpoint)
        if self._rt[key].n > 0:
            return Prediction(self._rt[key].mean, self._en[key].mean, True)
        # cross-endpoint fallback: average every observed endpoint's profile
        # scaled by relative speed (a single arbitrary observation would
        # bias the estimate toward whichever endpoint happened to run first)
        obs = [
            (ep, self._rt[(f, ep)].mean, self._en[(f, ep)].mean)
            for (f, ep) in self._rt
            if f == fn and self._rt[(f, ep)].n > 0
        ]
        if obs:
            s1 = max(self._eps.get(endpoint, 1.0), 1e-6)
            rts = [rt * self._eps.get(ep, 1.0) / s1 for ep, rt, _ in obs]
            ens = [en for _, _, en in obs]
            return Prediction(
                float(np.mean(rts)), float(np.mean(ens)), False
            )
        return Prediction(10.0, 100.0, False)  # exploration prior

    def drift_sigma(self, fn: str, endpoint: str, runtime_s: float) -> float:
        """How many sigmas a new observation is from the profile — the
        fleet layer uses this for straggler detection."""
        st = self._rt[(fn, endpoint)]
        if st.n < 3 or st.std <= 1e-9:
            return 0.0
        return abs(runtime_s - st.mean) / st.std

    def stats(self):
        return {
            f"{fn}@{ep}": (st.n, st.mean, self._en[(fn, ep)].mean)
            for (fn, ep), st in self._rt.items()
            if st.n
        }
