"""End-to-end evaluation harness: one trace, every policy, one table.

This is the apples-to-apples layer the paper's headline claims live on
(45% EDP reduction on the synthetic workload, 21%/63% energy/runtime on
the molecular-design pipeline): the *same* :class:`WorkloadTrace` is
replayed through a fresh :class:`OnlineEngine` per policy — identical
arrivals, identical simulator seed, identically warmed profiles — and the
scheduler-state metrics are compared on

- **EDP**  = E_tot * C_max  (J*s), the energy-delay product, plus
- **GPS-UP** ratios vs the best single-site baseline (Abdulsalam et al.,
  IGSC'15, as used by the serverless load-shifting protocol in SNIPPETS):
  Speedup S = T_base/T_new, Greenup G = E_base/E_new, and
  Powerup U = P_base/P_new with P = E/T.  G, S, U > 1 all mean "better
  than baseline"; EDP improvement = G*S.

Energies are joules, times seconds.  Runs default to ``monitoring=False``
so results are bitwise reproducible (the monitor-noise stream is consumed
only when attribution is on); per-run profile warmup records the
simulator's ground-truth profiles, mirroring the paper's "profiles from
prior monitoring runs" assumption identically for every policy.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.carbon import CarbonIntensitySignal
from repro.core.engine import OnlineEngine
from repro.core.endpoint import EndpointSpec
from repro.core.faults import FaultTrace
from repro.core.predictor import TaskProfileStore
from repro.core.scheduler import SchedulerState, SoAState
from repro.core.testbed import TestbedSim
from repro.workloads.trace import WorkloadTrace


@dataclasses.dataclass
class PolicyRun:
    """One (trace, policy) replay's metrics.  Energies J, times s."""
    policy: str
    engine: str
    energy_j: float              # scheduler-state E_tot + measured cold_j
    makespan_s: float            # cumulative scheduler-state C_max
    transfer_j: float
    scheduling_s: float          # wall time spent inside placement
    sim_makespan_s: float        # discrete-event sim clock at drain
    attributed_j: float          # monitor-attributed task energy (0 if off)
    windows: int
    tasks: int
    per_endpoint_j: dict[str, float]
    placements: dict[str, int]   # endpoint -> task count
    assignments: dict[str, str] = dataclasses.field(default_factory=dict, repr=False)
    greenup: float | None = None
    speedup: float | None = None
    powerup: float | None = None
    carbon_g: float | None = None    # time-integrated gCO2 (carbon runs only)
    deferred: int = 0                # tasks time-shifted by the deferral queue
    cp_speedup: float | None = None  # CP lower bound / makespan (<= 1)
    deadline_misses: int = 0         # finite-deadline tasks finishing late
    deadline_total: int = 0          # tasks carrying a finite deadline
    edp_vs_mhra: float | None = None # this row's EDP / the mhra row's EDP
    # --- chaos runs only (defaults = fault-free) ---
    faulty: bool = False             # run had a fault trace / speculation on
    goodput: float = 1.0             # completed / submitted task ids
    failures: int = 0                # executions killed by endpoint churn
    retries: int = 0                 # re-placements of killed tasks
    reexec_j: float = 0.0            # wasted partial + losing-copy energy
    cold_starts: int = 0             # cold worker spin-ups
    cold_j: float = 0.0              # startup energy of cold spin-ups
    spec_launched: int = 0           # speculative backups launched
    spec_wins: int = 0               # backups that beat their primary
    mean_recovery_s: float | None = None  # first kill -> completion
    # --- multi-tenant runs only (defaults = single-tenant) ---
    users: int = 0                   # distinct users with completed tasks
    jain_index: float | None = None  # Jain fairness over per-user EDP
    user_edp_cov: float | None = None   # CoV (dispersion) of per-user EDP
    shed: int = 0                    # tasks rejected by admission control
    admission_deferred: int = 0      # tasks delayed to a budget replenish
    # --- geo-distributed runs only (defaults = single-region) ---
    regions: int = 0                 # regions in the router (0 = no layer)
    wan_j: float = 0.0               # WAN transfer energy billed (in energy_j)
    egress_bytes: float = 0.0        # bytes that crossed a region boundary
    region_tasks: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def edp(self) -> float:
        """Energy-delay product E*T in J*s."""
        return self.energy_j * self.makespan_s

    @property
    def cdp(self) -> float | None:
        """Carbon-delay product gCO2*T in g*s (None outside carbon runs)."""
        if self.carbon_g is None:
            return None
        return self.carbon_g * self.makespan_s

    @property
    def power_w(self) -> float:
        return self.energy_j / self.makespan_s if self.makespan_s > 0 else 0.0

    @property
    def deadline_miss_rate(self) -> float | None:
        """Fraction of finite-deadline tasks completing past their
        deadline (None when the trace sets no deadlines)."""
        if self.deadline_total == 0:
            return None
        return self.deadline_misses / self.deadline_total

    @property
    def goodput_per_mj(self) -> float:
        """Completed-work fraction per megajoule — the chaos-eval headline
        metric: a policy that wastes energy re-running killed tasks scores
        lower even at equal goodput."""
        if self.energy_j <= 0:
            return 0.0
        return self.goodput / (self.energy_j / 1e6)

    @property
    def reexec_overhead(self) -> float:
        """Fraction of E_tot burned on killed partial executions and
        losing speculation copies."""
        if self.energy_j <= 0:
            return 0.0
        return self.reexec_j / self.energy_j


@dataclasses.dataclass
class EvalResult:
    """All policies' runs over one trace + the baseline annotation."""
    workload: str
    n_tasks: int
    alpha: float
    rows: list[PolicyRun]
    baseline: str                # policy label GPS-UP ratios are against

    def row(self, policy: str) -> PolicyRun:
        for r in self.rows:
            if r.policy == policy:
                return r
        raise KeyError(policy)

    def single_site_rows(self) -> list[PolicyRun]:
        return [r for r in self.rows if r.policy.startswith("site:")]

    def to_payload(self) -> dict:
        """JSON-ready dict (assignments dropped: id->endpoint maps scale
        with the trace and belong in the TaskDB, not the summary)."""
        rows = []
        for r in self.rows:
            d = dataclasses.asdict(r)
            d.pop("assignments")
            d["edp"] = r.edp
            d["power_w"] = r.power_w
            d["cdp"] = r.cdp
            d["deadline_miss_rate"] = r.deadline_miss_rate
            d["goodput_per_mj"] = r.goodput_per_mj
            d["reexec_overhead"] = r.reexec_overhead
            rows.append(d)
        return {
            "workload": self.workload,
            "n_tasks": self.n_tasks,
            "alpha": self.alpha,
            "baseline": self.baseline,
            "rows": rows,
        }


def gpsup(base_e: float, base_t: float, e: float, t: float
          ) -> tuple[float, float, float]:
    """(greenup, speedup, powerup) of (e, t) against (base_e, base_t)."""
    g = base_e / e if e > 0 else np.inf
    s = base_t / t if t > 0 else np.inf
    p_base = base_e / base_t if base_t > 0 else 0.0
    p_new = e / t if t > 0 else 0.0
    u = p_base / p_new if p_new > 0 else np.inf
    return g, s, u


def warm_store(sim: TestbedSim, trace: WorkloadTrace, n_obs: int = 3
               ) -> TaskProfileStore:
    """Profile store pre-warmed with the simulator's ground-truth
    per-(fn, endpoint) profiles — ``n_obs`` identical noise-free
    observations each, so every policy starts from the same confident
    predictions (the paper's prior-monitoring assumption)."""
    store = TaskProfileStore(trace.endpoints)
    for ep in trace.endpoints:
        for fn in trace.functions:
            rt, w, _ = sim.task_truth(fn, ep.name)
            for _ in range(n_obs):
                store.record(fn, ep.name, rt, rt * w)
    return store


def per_endpoint_energy(state) -> dict[str, float]:
    """Per-endpoint share of the scheduler-state E_tot (J): idle span (or
    always-on idle over C_max) + startup + dynamic energy, matching
    ``state.metrics()`` term by term; transfer energy is reported under
    the ``"_transfer"`` pseudo-endpoint."""
    _, c_max, transfer_j = state.metrics()
    out: dict[str, float] = {"_transfer": float(transfer_j)}
    if isinstance(state, SoAState):
        regs = [
            (ep, None if state.first[i] == np.inf else float(state.first[i]),
             float(state.last[i]), float(state.dyn[i]))
            for i, ep in enumerate(state.eps)
        ]
    else:
        regs = [
            (ep, state.first_start[ep.name], state.last_end[ep.name],
             state.dyn_energy[ep.name])
            for ep in state.eps
        ]
    for ep, first, last, dyn in regs:
        if first is None:
            out[ep.name] = ep.idle_power_w * c_max if not ep.has_batch_scheduler else 0.0
            continue
        if ep.has_batch_scheduler:
            e = ep.idle_power_w * (last - first) + ep.startup_energy_j
        else:
            e = ep.idle_power_w * c_max
        out[ep.name] = e + dyn
    return out


def carbon_footprint_g(
    signal: CarbonIntensitySignal,
    endpoints: Sequence[EndpointSpec],
    windows,
    transfer_j: float = 0.0,
) -> float:
    """Time-resolved gCO2 of an executed run: every energy term of the
    E_tot accounting integrated against the grid-intensity signal over
    the interval it was actually drawn in.

    - each task record's dynamic energy is spread uniformly over its
      simulated ``[t_start, t_end]`` and weighted by the endpoint's mean
      g/J over that interval;
    - batch endpoints charge idle power over their busy span
      ``[first start, last end]`` (exact piecewise integral) plus startup
      energy at the rate in effect when they came up;
    - always-on endpoints charge idle power over the whole makespan;
    - ``transfer_j`` (grid locus ambiguous) is billed at the fleet-mean
      rate over the makespan.

    This is the evaluation-side ground truth the scheduling-time
    snapshot estimate (``Schedule.carbon_g``) approximates.  Requires
    executed windows (sim records)."""
    recs = [rec for w in windows if w.sim is not None for rec in w.sim.records]
    if not recs:
        return 0.0
    c_max = max(r.t_end for r in recs)
    first: dict[str, float] = {}
    last: dict[str, float] = {}
    for r in recs:
        first[r.endpoint] = min(first.get(r.endpoint, np.inf), r.t_start)
        last[r.endpoint] = max(last.get(r.endpoint, 0.0), r.t_end)
    g = 0.0
    for ep in endpoints:
        if not ep.has_batch_scheduler:
            g += ep.idle_power_w * signal.integral_rate(ep.name, 0.0, c_max)
        elif ep.name in first:
            g += ep.idle_power_w * signal.integral_rate(
                ep.name, first[ep.name], last[ep.name]
            )
            g += ep.startup_energy_j * signal.rate_g_per_j(
                ep.name, first[ep.name]
            )
    for r in recs:
        g += (r.energy_j or 0.0) * signal.mean_rate(
            r.endpoint, r.t_start, r.t_end
        )
    if transfer_j:
        names = [e.name for e in endpoints]
        g += transfer_j * float(np.mean(
            [signal.mean_rate(n, 0.0, c_max) for n in names]
        ))
    return g


def verify_dag_order(windows) -> int:
    """Check the executed windows honored every DAG edge: no child's
    simulated start precedes any parent's simulated completion.  Returns
    the number of edges checked; raises ``AssertionError`` on violation.
    Requires a sim backend (windows must carry records).

    Fault-tolerant runs: killed (``failed``) executions are not
    completions and are skipped; a speculative ``<id>@spec`` backup
    folds into its base id, and the base completes at the *winner's*
    (earliest successful) end — exactly when the engine releases the
    children."""
    starts: dict[str, float] = {}
    ends: dict[str, float] = {}
    deps: dict[str, tuple] = {}
    for w in windows:
        for t in w.tasks:
            if not t.id.endswith("@spec"):
                deps[t.id] = t.deps
        if w.sim is None:
            raise ValueError("verify_dag_order needs executed windows")
        for rec in w.sim.records:
            if rec.failed:
                continue
            tid = rec.task_id
            base = tid[: -len("@spec")] if tid.endswith("@spec") else tid
            starts[base] = min(starts.get(base, np.inf), rec.t_start)
            ends[base] = min(ends.get(base, np.inf), rec.t_end)
    checked = 0
    for tid, parents in deps.items():
        if tid not in starts:
            continue     # never completed (permanently failed subtree)
        for p in parents:
            assert starts[tid] >= ends[p], (
                f"DAG violation: {tid} started {starts[tid]:.3f} before "
                f"parent {p} completed {ends[p]:.3f}"
            )
            checked += 1
    return checked


def critical_path_bound_s(trace: WorkloadTrace) -> float:
    """DAG critical-path lower bound on the makespan: every task on its
    fastest endpoint, unlimited cores, transfers and queues free — the
    earliest any schedule could possibly finish the trace.  For flat
    traces this degenerates to ``max(arrival + fastest runtime)``."""
    names = {e.name for e in trace.endpoints}
    rt_min = {
        fn: min(rt for m, (rt, _) in trace.profiles[fn].items() if m in names)
        for fn in trace.functions
    }
    done: dict[str, float] = {}
    best = 0.0
    for t, arr in zip(trace.tasks, trace.arrivals):
        ready = float(arr)
        for p in t.deps:
            if done[p] > ready:
                ready = done[p]
        end = ready + rt_min[t.fn]
        done[t.id] = end
        if end > best:
            best = end
    return best


def deadline_misses(trace: WorkloadTrace, windows) -> tuple[int, int]:
    """(missed, total) over the trace's finite-deadline tasks, judged on
    the *executed* records' completion times."""
    deadlines = {
        t.id: t.deadline for t in trace.tasks if t.deadline != np.inf
    }
    if not deadlines:
        return 0, 0
    missed = 0
    for w in windows:
        if w.sim is None:
            continue
        for rec in w.sim.records:
            if rec.failed:
                continue     # a kill is not a completion; the retry decides
            d = deadlines.get(rec.task_id)
            if d is not None and rec.t_end > d:
                missed += 1
    return missed, len(deadlines)


def jain_index(values: Sequence[float]) -> float | None:
    """Jain's fairness index ``(sum x)^2 / (n * sum x^2)`` over positive
    per-user loads: 1.0 = perfectly even, 1/n = one user carries all.
    For *cost*-like values (per-user EDP) read it the same way — higher
    means the burden is spread more evenly.  None on empty input."""
    x = np.asarray(list(values), dtype=float)
    if x.size == 0:
        return None
    sq = float((x * x).sum())
    if sq == 0.0:
        return 1.0
    return float(x.sum()) ** 2 / (x.size * sq)


def dispersion_cov(values: Sequence[float]) -> float | None:
    """Coefficient of variation (population std / mean) — the per-user
    EDP dispersion column.  None on empty input or zero mean."""
    x = np.asarray(list(values), dtype=float)
    if x.size == 0:
        return None
    m = float(x.mean())
    if m == 0.0:
        return None
    return float(x.std()) / m


def per_user_metrics(trace: WorkloadTrace, windows) -> dict[str, dict[str, float]]:
    """Per-user rollup from the *executed* records: ``tasks``,
    ``energy_j`` (sum), ``turnaround_s`` (mean completion - arrival), and
    ``edp`` = mean-energy-per-task * mean-turnaround — a per-user
    energy-delay product that is load-invariant, so a 400-task tenant and
    a 2-task tenant are comparable.  Kills and speculative backup copies
    are not completions and are skipped; shed tasks never produce records
    at all (their cost shows up in goodput, not here)."""
    arrival = {t.id: float(a) for t, a in zip(trace.tasks, trace.arrivals)}
    user_of = {t.id: t.user for t in trace.tasks}
    e_sum: dict[str, float] = {}
    t_sum: dict[str, float] = {}
    cnt: dict[str, int] = {}
    for w in windows:
        if w.sim is None:
            continue
        for rec in w.sim.records:
            tid = rec.task_id
            if rec.failed or tid.endswith("@spec") or tid not in arrival:
                continue
            u = user_of[tid]
            e_sum[u] = e_sum.get(u, 0.0) + (rec.energy_j or 0.0)
            t_sum[u] = t_sum.get(u, 0.0) + (rec.t_end - arrival[tid])
            cnt[u] = cnt.get(u, 0) + 1
    out: dict[str, dict[str, float]] = {}
    for u in sorted(cnt):
        n = cnt[u]
        mean_e = e_sum[u] / n
        mean_t = t_sum[u] / n
        out[u] = {
            "tasks": float(n),
            "energy_j": e_sum[u],
            "turnaround_s": mean_t,
            "edp": mean_e * mean_t,
        }
    return out


def run_policy(
    trace: WorkloadTrace,
    policy: str,
    site: str | None = None,
    engine: str = "delta",
    alpha: float = 0.5,
    seed: int = 0,
    window_s: float = 5.0,
    max_batch: int = 512,
    monitoring: bool = False,
    warm_obs: int = 3,
    runtime_noise: float = 0.0,
    return_windows: bool = False,
    carbon: CarbonIntensitySignal | None = None,
    defer_horizon_s: float = 0.0,
    defer_max: int = 256,
    defer_margin: float = 0.05,
    promotion: str = "epoch",
    carbon_forecast: CarbonIntensitySignal | None = None,
    faults: FaultTrace | None = None,
    fault_aware: bool = True,
    spec_factor: float | None = None,
    retry_cap: int = 6,
    retry_backoff_s: float = 15.0,
    fairness=None,
    admission: str | None = None,
    admission_debt: float = 1.0,
    admission_max_defer: int = 8,
    regions=None,
    defer_sigma_k: float = 1.0,
    label: str | None = None,
):
    """Replay ``trace`` under one policy and collect metrics.

    Builds a fresh seeded :class:`TestbedSim` from the trace's profiles
    and a fresh engine, so repeated calls are independent and
    deterministic.  ``runtime_noise=0`` keeps the sim's task runtimes at
    their profile means — policy comparisons then differ only by
    placement, not by noise-draw order.  Returns a :class:`PolicyRun`,
    or ``(PolicyRun, windows)`` with ``return_windows=True`` (for DAG
    verification against the executed records).

    With ``carbon`` given, the run's time-integrated gCO2 footprint is
    recorded on the row for *every* policy (carbon-blind ones included —
    that is the comparison), the signal is exposed to carbon-aware
    policies, and ``defer_horizon_s > 0`` arms the engine's temporal
    deferral queue.  ``carbon_forecast`` separates the signal *known at
    decision time* from the signal *billed at execution time*: the
    engine (placement + deferral) sees the forecast, while the footprint
    integrates the true ``carbon`` signal — so forecast error degrades
    deferral gains exactly as it would against a real grid.

    ``promotion`` selects the engine's DAG ready-floor granularity
    (``"epoch"``/``"exact"``, see :class:`OnlineEngine`); the row's
    ``cp_speedup`` annotates how close the executed makespan came to the
    trace's critical-path lower bound, and ``deadline_misses``/``_total``
    count finite-deadline tasks that completed late.

    ``faults`` injects the chaos script into *both* the simulator (kills,
    straggler inflation, cold starts) and the engine (retries; and with
    ``fault_aware=True``, dead-endpoint masking + warm-pool scoring).
    ``fault_aware=False`` keeps the retries but blinds placement — the
    chaos-eval baseline.  ``spec_factor`` arms speculative re-execution.

    ``fairness`` (a :class:`~repro.core.fairness.FairShare`) arms the
    engine's per-user budget ledger and the advantage-tax placement
    term; ``admission``/``admission_debt``/``admission_max_defer``
    additionally gate over-budget submissions (see
    :class:`OnlineEngine`).  Every run annotates per-user fairness
    columns (``users``, ``jain_index``, ``user_edp_cov``) when the trace
    is multi-tenant.  ``label`` renames the row — the fair-policy rows
    are plain policies with a fairness budget armed, so the relabel is
    what distinguishes ``fair_mhra`` from ``mhra`` in the table.

    ``regions`` (RegionSpec list or a pre-built
    :class:`~repro.core.region.RegionRouter`) arms the geo-distributed
    region layer (see :class:`OnlineEngine`): the row gains ``regions``/
    ``wan_j``/``egress_bytes``/``region_tasks``, WAN transfer energy is
    billed into ``energy_j`` (same convention as ``cold_j``), and with a
    carbon signal each WAN event's grams are billed against the
    *destination region's* true intensity at route time.  ``regions=None``
    and a single whole-fleet region keep every number bitwise-identical
    to a region-free run.  ``defer_sigma_k`` scales how much the deferral
    margin widens with the forecast signal's ``forecast_sigma``.
    """
    sim = TestbedSim(
        trace.endpoints, profiles=trace.profiles, signatures=trace.signatures,
        seed=seed, runtime_noise=runtime_noise, faults=faults,
    )
    store = warm_store(sim, trace, n_obs=warm_obs)
    greedy = ("mhra", "cluster_mhra", "carbon_mhra", "lookahead_mhra")
    eng = OnlineEngine(
        trace.endpoints, sim, policy=policy, alpha=alpha, window_s=window_s,
        max_batch=max_batch, store=store, monitoring=monitoring, site=site,
        engine=engine if policy in greedy else None,
        carbon=carbon_forecast if carbon_forecast is not None else carbon,
        defer_horizon_s=defer_horizon_s,
        defer_max=defer_max, defer_margin=defer_margin,
        promotion=promotion,
        faults=faults, fault_aware=fault_aware, spec_factor=spec_factor,
        retry_cap=retry_cap, retry_backoff_s=retry_backoff_s,
        fairness=fairness, admission=admission,
        admission_debt=admission_debt,
        admission_max_defer=admission_max_defer,
        regions=regions, defer_sigma_k=defer_sigma_k,
    )
    windows = trace.replay_into(eng)
    s = eng.summary()
    e_tot, c_max, transfer_j = eng.state.metrics()
    assignments: dict[str, str] = {}
    for w in windows:
        assignments.update(w.assignments)
    placements: dict[str, int] = {}
    for ep in assignments.values():
        placements[ep] = placements.get(ep, 0) + 1
    if label is None:
        label = f"site:{site}" if policy == "single_site" else policy
    # fixed-assignment policies use no greedy engine; don't mislabel them
    engine_label = engine if policy in greedy else "n/a"
    carbon_g = None
    if carbon is not None:
        carbon_g = carbon_footprint_g(
            carbon, trace.endpoints, windows, transfer_j=float(transfer_j)
        )
        # WAN grams bill against the *destination region's* true grid at
        # route time (region names resolve as trace keys in geo signals)
        for (t_route, _src, dst, _b, j) in eng.wan_events:
            carbon_g += j * carbon.rate_g_per_j(dst, t_route)
    missed, total = deadline_misses(trace, windows)
    cp_bound = critical_path_bound_s(trace)
    um = per_user_metrics(trace, windows)
    user_edps = [m["edp"] for m in um.values() if m["edp"] > 0.0]
    # bill the sim's measured cold-start energy on top of the scheduler
    # estimate: warm-pool dynamics burn real joules the placement-state
    # model never sees, and the warm-pool objective term is only
    # evaluable if the headline energy metric counts what it optimizes.
    # Fleets without warm-pool dynamics have cold_j == 0.0 exactly, so
    # every pre-existing comparison is bitwise unchanged.
    # WAN transfer energy follows the cold_j convention: measured extras
    # the placement-state model never sees, billed on the headline metric
    # (s.wan_j == 0.0 exactly without a multi-region router)
    run = PolicyRun(
        policy=label, engine=engine_label,
        energy_j=float(e_tot) + s.cold_j + s.wan_j, makespan_s=float(c_max),
        transfer_j=float(transfer_j), scheduling_s=s.scheduling_s,
        sim_makespan_s=float(sim.stream_clock), attributed_j=s.attributed_j,
        windows=s.windows, tasks=s.tasks,
        per_endpoint_j=per_endpoint_energy(eng.state),
        placements=placements, assignments=assignments,
        carbon_g=carbon_g, deferred=s.deferred,
        cp_speedup=cp_bound / float(c_max) if c_max > 0 else None,
        deadline_misses=missed, deadline_total=total,
        faulty=bool(faults) or spec_factor is not None,
        goodput=s.goodput, failures=s.failures, retries=s.retries,
        reexec_j=s.wasted_j + s.spec_wasted_j,
        cold_starts=s.cold_starts, cold_j=s.cold_j,
        spec_launched=s.spec_launched, spec_wins=s.spec_wins,
        mean_recovery_s=s.mean_recovery_s,
        users=len(um),
        jain_index=jain_index(user_edps) if len(um) > 1 else None,
        user_edp_cov=dispersion_cov(user_edps) if len(um) > 1 else None,
        shed=s.shed, admission_deferred=s.admission_deferred,
        regions=s.regions, wan_j=s.wan_j, egress_bytes=s.egress_bytes,
        region_tasks=dict(eng.region_tasks),
    )
    if return_windows:
        return run, windows
    return run


def evaluate_trace(
    trace: WorkloadTrace,
    policies: Sequence[str] = ("mhra", "cluster_mhra", "round_robin"),
    include_single_sites: bool = True,
    engine: str = "delta",
    alpha: float = 0.5,
    seed: int = 0,
    carbon: CarbonIntensitySignal | None = None,
    defer_horizon_s: float = 0.0,
    **run_kwargs,
) -> EvalResult:
    """Run the trace under every policy plus per-endpoint single-site
    baselines and annotate GPS-UP ratios against the **best single-site
    baseline by EDP** (the strongest non-federated competitor — beating
    it is the paper's bar).  Without single sites, the first policy row
    becomes the baseline.

    ``carbon`` annotates every row with its time-integrated gCO2;
    ``defer_horizon_s`` arms temporal shifting for the carbon-aware
    ``carbon_mhra`` policy only, so carbon-blind rows stay bit-identical
    to a carbon-free evaluation.  When an ``mhra`` row is present, every
    row additionally gets ``edp_vs_mhra`` — its EDP relative to the
    myopic greedy, the lookahead-vs-myopic comparison column."""
    rows: list[PolicyRun] = []
    if include_single_sites:
        for ep in trace.endpoints:
            rows.append(run_policy(
                trace, "single_site", site=ep.name, alpha=alpha, seed=seed,
                carbon=carbon, **run_kwargs,
            ))
    for p in policies:
        rows.append(run_policy(
            trace, p, engine=engine, alpha=alpha, seed=seed, carbon=carbon,
            defer_horizon_s=defer_horizon_s if p == "carbon_mhra" else 0.0,
            **run_kwargs,
        ))
    sites = [r for r in rows if r.policy.startswith("site:")]
    base = min(sites, key=lambda r: r.edp) if sites else rows[0]
    myopic = next((r for r in rows if r.policy == "mhra"), None)
    for r in rows:
        g, s, u = gpsup(base.energy_j, base.makespan_s, r.energy_j, r.makespan_s)
        r.greenup, r.speedup, r.powerup = g, s, u
        if myopic is not None and myopic.edp > 0:
            r.edp_vs_mhra = r.edp / myopic.edp
    return EvalResult(
        workload=trace.name, n_tasks=len(trace), alpha=alpha,
        rows=rows, baseline=base.policy,
    )
