"""Data-transfer time + energy models (paper §III-E).

Energy per transfer n1 -> n2:
    E = sum_h  s * E_inc,   E_inc = P_max / B  per hop
Transfer time: online linear regression on (n_files, total_bytes), batched
per destination to amortize per-transfer overheads (Globus limits analogue).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.endpoint import EndpointSpec

# Typical network-device specs (core/edge routers + switches on the path).
# E_inc = P_max / B, in J/byte (8 bits/byte folded in).
HOP_PMAX_W = 4000.0
HOP_BW_BPS = 100e9  # 100 Gb/s
E_INC_J_PER_BYTE = HOP_PMAX_W / HOP_BW_BPS * 8.0  # 3.2e-7 J/B per hop
FS_DTN_EXTRA_HOPS = 2  # shared-FS data servers + DTN, when applicable


@dataclasses.dataclass(frozen=True)
class TransferRequest:
    src: str
    dst: str
    n_files: int
    total_bytes: float
    shared: bool = False  # cacheable across tasks on an endpoint


class TransferModel:
    def __init__(self, endpoints: list[EndpointSpec]):
        self.eps = {e.name: e for e in endpoints}
        # time regression t = a + b*n_files + c*bytes
        self._xtx = np.eye(3) * 1e-6
        self._xty = np.zeros(3)
        # sane prior: 2 s setup, 5 ms/file, 10 GB/s effective
        self.observe(n_files=1, total_bytes=1e9, seconds=2.105)
        self.observe(n_files=100, total_bytes=1e10, seconds=3.5)
        self._cache: set[tuple[str, str]] = set()  # (endpoint, file-group key)

    # --- time -------------------------------------------------------------
    def observe(self, n_files: int, total_bytes: float, seconds: float) -> None:
        x = np.array([1.0, n_files, total_bytes / 1e9])
        self._xtx += np.outer(x, x)
        self._xty += x * seconds
        self._coef = None  # refit lazily on next prediction

    def predict_seconds(self, n_files: int, total_bytes: float) -> float:
        if n_files == 0 or total_bytes <= 0:
            return 0.0
        if self._coef is None:
            self._coef = [float(c) for c in np.linalg.solve(self._xtx, self._xty)]
        c0, c1, c2 = self._coef
        t = c0 + c1 * n_files + c2 * (total_bytes / 1e9)
        return t if t > 0.0 else 0.0

    # --- energy -----------------------------------------------------------
    def hops(self, src: str, dst: str) -> int:
        if src == dst:
            return 0
        h = self.eps[src].hop_count(dst)
        extra = 0
        if self.eps[src].has_batch_scheduler:
            extra += FS_DTN_EXTRA_HOPS
        if self.eps[dst].has_batch_scheduler:
            extra += FS_DTN_EXTRA_HOPS
        return h + extra

    def energy_j(self, req: TransferRequest) -> float:
        if req.src == req.dst:
            return 0.0
        if req.shared and (req.dst, f"{req.src}:{req.n_files}:{req.total_bytes}") in self._cache:
            return 0.0
        return self.hops(req.src, req.dst) * req.total_bytes * E_INC_J_PER_BYTE

    def mark_cached(self, req: TransferRequest) -> None:
        if req.shared:
            self._cache.add((req.dst, f"{req.src}:{req.n_files}:{req.total_bytes}"))

    # --- batching (paper: transfers batched before execution) -------------
    def batch_cost(
        self, reqs: list[TransferRequest]
    ) -> tuple[float, float]:
        """(seconds, joules) for a batched set of transfers, grouped by
        (src, dst) pair; batches to a destination run concurrently."""
        by_pair: dict[tuple[str, str], list[TransferRequest]] = {}
        for r in reqs:
            if r.src != r.dst:
                by_pair.setdefault((r.src, r.dst), []).append(r)
        total_j, max_s = 0.0, 0.0
        for (src, dst), rs in by_pair.items():
            nf = sum(r.n_files for r in rs)
            nb = sum(r.total_bytes for r in rs)
            max_s = max(max_s, self.predict_seconds(nf, nb))
            total_j += sum(self.energy_j(r) for r in rs)
        return max_s, total_j
