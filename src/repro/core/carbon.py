"""Grid carbon-intensity signals: joules are not emissions.

GreenFaaS compares per-endpoint *energy*; this module supplies the
time-varying grid carbon intensity (gCO2 per kWh) that turns endpoint
joules into grams of CO2 — the "Greenup as carbon-adjusted energy"
adaptation.  Each endpoint (or the region it lives in) carries a
**piecewise-linear** intensity trace; everything downstream is exact
arithmetic on those segments:

- :class:`CarbonTrace` — one region's trace: sorted breakpoint times (s)
  and gCO2/kWh values, linearly interpolated, optionally periodic (a
  compressed "day" that repeats).  Point lookups, exact integrals, and
  interval means all stay closed-form.
- :class:`CarbonIntensitySignal` — a fleet-level bundle of traces with an
  endpoint→region map, seeded synthetic constructors (:meth:`diurnal`,
  :meth:`step`) and a real-trace JSON loader (:meth:`from_json`).
- :class:`CarbonWeights` — the per-endpoint g/J snapshot the scheduling
  engines consume: rates aligned with the engine's endpoint order plus
  the objective weight ``gamma`` (see ``scheduler.mhra(carbon=...)``).

Units: times are seconds, intensities gCO2/kWh for human I/O; the
scheduling/attribution surface converts once to g/J (``/ 3.6e6``) so
``grams = joules × rate`` everywhere downstream.  All constructors are
seeded — same seed, same signal, bit for bit.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Mapping, Sequence

import numpy as np

#: joules per kilowatt-hour — converts gCO2/kWh into gCO2/J.
J_PER_KWH = 3.6e6


@dataclasses.dataclass
class CarbonTrace:
    """One region's piecewise-linear gCO2/kWh trace.

    ``times`` are sorted breakpoints in seconds; between breakpoints the
    intensity is linear, outside them it clamps to the edge values.  With
    ``period_s`` set the trace repeats (breakpoints must lie in
    ``[0, period_s]``, and the wrap segment interpolates last→first), so a
    compressed synthetic "day" covers arbitrarily long workloads.
    """

    times: np.ndarray
    gco2_per_kwh: np.ndarray
    period_s: float | None = None

    def __post_init__(self) -> None:
        self.times = np.asarray(self.times, dtype=float)
        self.gco2_per_kwh = np.asarray(self.gco2_per_kwh, dtype=float)
        if self.times.ndim != 1 or self.times.shape != self.gco2_per_kwh.shape:
            raise ValueError(
                f"times {self.times.shape} and gco2_per_kwh "
                f"{self.gco2_per_kwh.shape} must be equal-length 1-D arrays"
            )
        if self.times.size == 0:
            raise ValueError("trace needs at least one breakpoint")
        if np.any(np.diff(self.times) < 0):
            raise ValueError("trace times must be sorted")
        if np.any(self.gco2_per_kwh < 0):
            raise ValueError("carbon intensity cannot be negative")
        if self.period_s is not None:
            if self.period_s <= 0:
                raise ValueError(f"period_s must be positive, got {self.period_s}")
            if self.times[0] < 0 or self.times[-1] > self.period_s:
                raise ValueError(
                    f"periodic trace breakpoints must lie in [0, {self.period_s}]"
                )

    # -- point lookups -----------------------------------------------------
    def at(self, t) -> float | np.ndarray:
        """Intensity (gCO2/kWh) at time(s) ``t``; scalar in, scalar out."""
        if self.period_s is not None:
            out = np.interp(t, self.times, self.gco2_per_kwh,
                            period=self.period_s)
        else:
            out = np.interp(t, self.times, self.gco2_per_kwh)
        return float(out) if np.isscalar(t) or np.ndim(t) == 0 else out

    def rate(self, t) -> float | np.ndarray:
        """Intensity as gCO2 per *joule* at time(s) ``t``."""
        return self.at(t) / J_PER_KWH

    # -- exact piecewise integrals -----------------------------------------
    def _knots_within(self, t0: float, t1: float) -> np.ndarray:
        """All breakpoint times strictly inside (t0, t1), unwrapped for
        periodic traces."""
        if self.period_s is None:
            k = self.times
            return k[(k > t0) & (k < t1)]
        p = self.period_s
        n0 = int(np.floor(t0 / p)) - 1
        n1 = int(np.floor(t1 / p)) + 1
        shifts = np.arange(n0, n1 + 1, dtype=float) * p
        k = (self.times[None, :] + shifts[:, None]).ravel()
        return np.unique(k[(k > t0) & (k < t1)])

    def integral(self, t0: float, t1: float) -> float:
        """∫ intensity dt over [t0, t1] in gCO2·s/kWh — exact (trapezoid
        over every linear segment)."""
        if t1 < t0:
            raise ValueError(f"integral needs t0 <= t1, got [{t0}, {t1}]")
        if t1 == t0:
            return 0.0
        pts = np.concatenate(([t0], self._knots_within(t0, t1), [t1]))
        return float(np.trapezoid(self.at(pts), pts))

    def mean(self, t0: float, t1: float) -> float:
        """Mean intensity (gCO2/kWh) over [t0, t1]; point value if t0==t1."""
        if t1 == t0:
            return float(self.at(t0))
        return self.integral(t0, t1) / (t1 - t0)

    def integral_rate(self, t0: float, t1: float) -> float:
        """∫ rate dt in gCO2·s/J — multiply by watts for idle-power grams."""
        return self.integral(t0, t1) / J_PER_KWH

    def mean_rate(self, t0: float, t1: float) -> float:
        """Mean gCO2/J over [t0, t1] — multiply by joules for task grams."""
        return self.mean(t0, t1) / J_PER_KWH

    def to_payload(self) -> dict:
        return {
            "times_s": self.times.tolist(),
            "gco2_per_kwh": self.gco2_per_kwh.tolist(),
            "period_s": self.period_s,
        }

    @classmethod
    def from_payload(cls, d: Mapping) -> "CarbonTrace":
        return cls(
            times=np.asarray(d["times_s"], dtype=float),
            gco2_per_kwh=np.asarray(d["gco2_per_kwh"], dtype=float),
            period_s=d.get("period_s"),
        )


class CarbonIntensitySignal:
    """Per-endpoint/region carbon-intensity traces behind one lookup.

    ``traces`` is keyed by region name; ``regions`` maps endpoint names to
    regions (an endpoint whose name is itself a trace key needs no entry;
    a ``"default"`` trace, if present, catches everything else).  All
    queries take an endpoint name and resolve the trace internally, so
    schedulers and the evaluation harness never handle regions directly.
    """

    #: Relative forecast-noise width this signal was built with (see
    #: :meth:`with_forecast_noise`).  0 for ground-truth signals.
    #: Decision layers use it to discount the signal — e.g. the online
    #: engine widens its deferral margin by ``defer_sigma_k * sigma`` so
    #: noisy forecasts defer less aggressively.
    forecast_sigma: float = 0.0

    def __init__(self, traces: Mapping[str, CarbonTrace],
                 regions: Mapping[str, str] | None = None):
        if not traces:
            raise ValueError("signal needs at least one trace")
        self.traces = dict(traces)
        self.regions = dict(regions or {})
        for ep, region in self.regions.items():
            if region not in self.traces:
                raise ValueError(
                    f"endpoint {ep!r} maps to unknown region {region!r}; "
                    f"traces: {sorted(self.traces)}"
                )

    def trace_for(self, endpoint: str) -> CarbonTrace:
        region = self.regions.get(endpoint, endpoint)
        t = self.traces.get(region)
        if t is None:
            t = self.traces.get("default")
        if t is None:
            raise KeyError(
                f"no carbon trace for endpoint {endpoint!r} (region "
                f"{region!r}) and no 'default' trace"
            )
        return t

    # -- per-endpoint queries ----------------------------------------------
    def intensity(self, endpoint: str, t: float) -> float:
        """gCO2/kWh on ``endpoint``'s grid at time ``t``."""
        return float(self.trace_for(endpoint).at(t))

    def rate_g_per_j(self, endpoint: str, t: float) -> float:
        return self.trace_for(endpoint).rate(t)

    def mean_rate(self, endpoint: str, t0: float, t1: float) -> float:
        return self.trace_for(endpoint).mean_rate(t0, t1)

    def integral_rate(self, endpoint: str, t0: float, t1: float) -> float:
        return self.trace_for(endpoint).integral_rate(t0, t1)

    def grams(self, endpoint: str, energy_j: float, t0: float, t1: float
              ) -> float:
        """gCO2 for ``energy_j`` joules spread uniformly over [t0, t1]."""
        return energy_j * self.mean_rate(endpoint, t0, t1)

    # -- fleet-level queries (temporal shifting) ----------------------------
    def rates_at(self, endpoints: Sequence[str], t: float) -> np.ndarray:
        """Per-endpoint g/J snapshot at time ``t`` (engine weight vector)."""
        return np.array([self.rate_g_per_j(n, t) for n in endpoints])

    def fleet_mean_intensity(self, endpoints: Sequence[str], t: float) -> float:
        return float(np.mean([self.intensity(n, t) for n in endpoints]))

    def argmin_fleet_mean(self, endpoints: Sequence[str], t0: float, t1: float
                          ) -> tuple[float, float]:
        """(t_best, intensity) minimizing the fleet-mean intensity over
        [t0, t1].  The fleet mean of piecewise-linear traces is itself
        piecewise linear, so the exact minimum sits on a breakpoint or an
        interval edge — no sampling grid, no tolerance."""
        if t1 < t0:
            raise ValueError(f"need t0 <= t1, got [{t0}, {t1}]")
        names = list(endpoints)
        cands = [np.array([t0, t1])]
        distinct = {id(tr): tr for tr in (self.trace_for(n) for n in names)}
        for tr in distinct.values():
            cands.append(tr._knots_within(t0, t1))
        pts = np.unique(np.concatenate(cands))
        means = np.zeros_like(pts)
        for n in names:
            means += np.asarray(self.trace_for(n).at(pts), dtype=float)
        means /= len(names)
        k = int(np.argmin(means))
        return float(pts[k]), float(means[k])

    # -- constructors -------------------------------------------------------
    @classmethod
    def diurnal(
        cls,
        endpoints: Sequence[str],
        period_s: float = 86_400.0,
        base_range: tuple[float, float] = (200.0, 450.0),
        swing_range: tuple[float, float] = (0.25, 0.6),
        seed: int = 0,
        n_knots: int = 49,
        regions: Mapping[str, str] | None = None,
    ) -> "CarbonIntensitySignal":
        """Seeded synthetic day/night sinusoids, one trace per name in
        ``endpoints`` (region names if ``regions`` maps endpoints onto
        them).  Each region draws a mean intensity from ``base_range``, a
        relative swing from ``swing_range``, and a phase — so regions peak
        at *different* times, which is what makes both spatial and
        temporal carbon shifting non-trivial."""
        rng = np.random.default_rng(seed)
        ts = np.linspace(0.0, period_s, n_knots)
        traces = {}
        for name in endpoints:
            mean = rng.uniform(*base_range)
            swing = rng.uniform(*swing_range)
            phase = rng.uniform(0.0, 2.0 * np.pi)
            vals = mean * (1.0 + swing * np.sin(
                2.0 * np.pi * ts / period_s + phase))
            traces[name] = CarbonTrace(ts, np.maximum(vals, 1.0),
                                       period_s=period_s)
        return cls(traces, regions=regions)

    @classmethod
    def step(
        cls,
        endpoints: Sequence[str],
        period_s: float = 86_400.0,
        low_range: tuple[float, float] = (80.0, 160.0),
        high_range: tuple[float, float] = (400.0, 700.0),
        seed: int = 0,
        regions: Mapping[str, str] | None = None,
    ) -> "CarbonIntensitySignal":
        """Seeded synthetic step profiles: a flat low-carbon floor with one
        high-carbon plateau per period (gas peaker hours).  Steps are
        narrow linear ramps (1e-3 of the period) so the trace stays
        piecewise linear and integrals stay exact."""
        rng = np.random.default_rng(seed)
        w = period_s * 1e-3
        traces = {}
        for name in endpoints:
            low = rng.uniform(*low_range)
            high = rng.uniform(*high_range)
            on = rng.uniform(0.1, 0.4) * period_s
            off = on + rng.uniform(0.2, 0.5) * period_s
            ts = np.array([0.0, on, on + w, off, off + w, period_s])
            vals = np.array([low, low, high, high, low, low])
            traces[name] = CarbonTrace(ts, vals, period_s=period_s)
        return cls(traces, regions=regions)

    def with_forecast_noise(self, sigma: float, seed: int = 0
                            ) -> "CarbonIntensitySignal":
        """The signal as a *forecast* would see it: every breakpoint's
        intensity perturbed by seeded multiplicative Gaussian noise of
        relative width ``sigma`` (floored at 1 gCO2/kWh so traces stay
        valid).  Decision layers (placement snapshots, the deferral
        queue's trough search) should consume the noisy view while
        billing (``evaluate.carbon_footprint_g``) integrates the true
        signal — the gap between signal-at-decision and signal-at-billing
        is exactly the forecast error.  ``sigma=0`` returns ``self``
        unchanged; traces are perturbed in sorted-name order, so the same
        ``(sigma, seed)`` always yields the same forecast.  The returned
        signal records ``sigma`` in :attr:`forecast_sigma` so consumers
        can hedge against their own uncertainty (the engine's deferral
        margin widens with it)."""
        if sigma < 0:
            raise ValueError(f"sigma must be non-negative, got {sigma}")
        if sigma == 0.0:
            return self
        rng = np.random.default_rng(seed)
        traces = {}
        for name in sorted(self.traces):
            t = self.traces[name]
            noisy = t.gco2_per_kwh * rng.normal(
                1.0, sigma, t.gco2_per_kwh.shape
            )
            traces[name] = CarbonTrace(
                t.times.copy(), np.maximum(noisy, 1.0), t.period_s
            )
        out = CarbonIntensitySignal(traces, regions=self.regions)
        out.forecast_sigma = sigma
        return out

    # -- persistence ---------------------------------------------------------
    def to_payload(self) -> dict:
        return {
            "traces": {k: t.to_payload() for k, t in self.traces.items()},
            "regions": dict(self.regions),
        }

    def to_json(self, path: str) -> dict:
        payload = self.to_payload()
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(payload, indent=2, sort_keys=True))
        return payload

    @classmethod
    def from_payload(cls, d: Mapping) -> "CarbonIntensitySignal":
        return cls(
            {k: CarbonTrace.from_payload(t) for k, t in d["traces"].items()},
            regions=d.get("regions") or {},
        )

    @classmethod
    def from_json(cls, path: str) -> "CarbonIntensitySignal":
        """Load a real-trace JSON file: ``{"traces": {region: {"times_s":
        [...], "gco2_per_kwh": [...], "period_s": null|float}},
        "regions": {endpoint: region}}`` (the format :meth:`to_json`
        writes — export your grid-API pull into it once)."""
        return cls.from_payload(json.loads(pathlib.Path(path).read_text()))


@dataclasses.dataclass(frozen=True)
class CarbonWeights:
    """One placement call's carbon view: per-endpoint g/J rates (aligned
    with the engine's endpoint order) frozen at the arrival-window open
    time, plus the objective weight ``gamma`` on the normalized carbon
    term.  A snapshot — not the signal — so the greedy engines' run
    memoization and vectorized scoring survive unchanged; the
    time-resolved gCO2 accounting lives in ``evaluate.carbon_footprint_g``.
    """

    rates: tuple[float, ...]
    gamma: float = 1.0

    def __post_init__(self) -> None:
        if not self.rates:
            raise ValueError("CarbonWeights needs at least one endpoint rate")
        if any(r < 0 for r in self.rates):
            raise ValueError("carbon rates cannot be negative")
        if self.gamma < 0:
            raise ValueError(f"gamma must be non-negative, got {self.gamma}")

    @classmethod
    def from_signal(cls, signal: CarbonIntensitySignal, endpoints, t: float,
                    gamma: float = 1.0) -> "CarbonWeights":
        names = [e if isinstance(e, str) else e.name for e in endpoints]
        return cls(tuple(signal.rates_at(names, t).tolist()), gamma)
