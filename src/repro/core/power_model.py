"""Online linear power model + per-task attribution (paper §III-D).

    P_node(t) ~= W . X_total(t) + B          (B ~ idle power, fitted)
    P_i       = W . X_i                      (per-process estimate)
    P_hat_i   = P_dyn_meas / (W . X_total) * P_i   (correction factor)

Energy per task = integral of the worker process's corrected power over
[t_start, t_end], linear interpolation between samples.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.counters import (  # noqa: F401 — integrate_windows re-exported
    CounterSample,
    PowerSample,
    TaskRecord,
    integrate_windows,
)


class LinearPowerModel:
    """Ridge regression with incremental sufficient statistics."""

    def __init__(self, n_features: int = 4, ridge: float = 1e-3):
        self.k = n_features
        self.ridge = ridge
        # augmented with intercept column
        self._xtx = np.zeros((n_features + 1, n_features + 1))
        self._xty = np.zeros(n_features + 1)
        self._n = 0
        self._wb: np.ndarray | None = None

    def observe(self, x: np.ndarray, p_watts: float) -> None:
        xa = np.concatenate([np.asarray(x, float), [1.0]])
        self._xtx += np.outer(xa, xa)
        self._xty += xa * p_watts
        self._n += 1
        self._wb = None

    def observe_batch(self, X: np.ndarray, P: np.ndarray) -> None:
        Xa = np.concatenate([X, np.ones((len(X), 1))], axis=1)
        self._xtx += Xa.T @ Xa
        self._xty += Xa.T @ P
        self._n += len(X)
        self._wb = None

    @property
    def n_obs(self) -> int:
        return self._n

    def _solve(self) -> np.ndarray:
        if self._wb is None:
            A = self._xtx + self.ridge * np.eye(self.k + 1)
            self._wb = np.linalg.solve(A, self._xty)
        return self._wb

    @property
    def weights(self) -> np.ndarray:
        return self._solve()[: self.k]

    @property
    def idle_b(self) -> float:
        return float(self._solve()[self.k])

    def predict_node(self, x_total: np.ndarray) -> float:
        return float(self.weights @ x_total + self.idle_b)

    def attribute(
        self, p_meas: float, proc_counters: dict[int, np.ndarray]
    ) -> dict[int, float]:
        """Decompose measured node power into per-process watts with the
        proportional correction factor (paper eq. for P_hat)."""
        w = self.weights
        est = {pid: max(float(w @ x), 0.0) for pid, x in proc_counters.items()}
        est_total = sum(est.values())
        p_dyn = max(p_meas - self.idle_b, 0.0)
        if est_total <= 1e-9:
            return {pid: 0.0 for pid in proc_counters}
        factor = p_dyn / est_total
        return {pid: factor * e for pid, e in est.items()}


@dataclasses.dataclass
class AttributionResult:
    energy_j: float
    node_energy_j: float


class EnergyAttributor:
    """Aggregates monitor streams for one node and attributes task energy."""

    def __init__(self, model: LinearPowerModel):
        self.model = model
        self.counter_samples: list[CounterSample] = []
        self.power_samples: list[PowerSample] = []

    def add_counters(self, s: CounterSample) -> None:
        self.counter_samples.append(s)

    def add_power(self, s: PowerSample) -> None:
        self.power_samples.append(s)

    def train_from_stream(self) -> None:
        """Fit the model from aligned (counters, power) samples."""
        pi = {round(s.t, 3): s.watts for s in self.power_samples}
        for cs in self.counter_samples:
            p = pi.get(round(cs.t, 3))
            if p is None:
                continue
            x_total = (
                np.sum(list(cs.procs.values()), axis=0)
                if cs.procs
                else np.zeros(self.model.k)
            )
            self.model.observe(x_total, p)

    def _power_series(self, pid: int) -> list[tuple[float, float, float]]:
        """(t, attributed_watts, node_watts) per aligned sample."""
        pi = {round(s.t, 3): s.watts for s in self.power_samples}
        out = []
        for cs in self.counter_samples:
            p = pi.get(round(cs.t, 3))
            if p is None:
                continue
            attr = self.model.attribute(p, cs.procs)
            out.append((cs.t, attr.get(pid, 0.0), p))
        return out

    def attribute_task(self, rec: TaskRecord) -> AttributionResult:
        """Integrate attributed power over [t_start, t_end] w/ interpolation."""
        series = self._power_series(rec.worker_pid)
        return AttributionResult(
            energy_j=_integrate(series, 1, rec.t_start, rec.t_end),
            node_energy_j=_integrate(series, 2, rec.t_start, rec.t_end),
        )


def _integrate(series, col: int, t0: float, t1: float) -> float:
    if not series or t1 <= t0:
        return 0.0
    ts = np.array([s[0] for s in series])
    vs = np.array([s[col] for s in series])
    if len(ts) == 1:
        return float(vs[0] * (t1 - t0))
    # clip window to sample span, linear interpolation at the edges
    grid = np.unique(np.concatenate([ts[(ts > t0) & (ts < t1)], [t0, t1]]))
    vals = np.interp(grid, ts, vs)
    return float(np.trapezoid(vals, grid))


def attribute_node_power(
    model: LinearPowerModel, watts: np.ndarray, rates: np.ndarray
) -> np.ndarray:
    """Vectorized correction-factor attribution for a whole node trace.

    ``watts`` is the (n,) measured node power, ``rates`` the (n, P, k)
    per-process counter-rate matrix (zero rows where a process is idle).
    Returns the (n, P) attributed per-process watts — the batched
    equivalent of calling :meth:`LinearPowerModel.attribute` per sample.
    """
    w = model.weights
    est = rates @ w                       # (n, P) per-process estimates
    np.clip(est, 0.0, None, out=est)
    est_tot = est.sum(axis=1)
    p_dyn = np.clip(watts - model.idle_b, 0.0, None)
    factor = np.divide(
        p_dyn, est_tot, out=np.zeros_like(p_dyn), where=est_tot > 1e-9
    )
    return est * factor[:, None]
