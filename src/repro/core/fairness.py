"""Multi-tenant fairness: per-user budget ledgers and the weighted-fair
placement term (ROADMAP item "scheduler -> service" gap).

The paper's scheduler assumes one cooperative user; a shared deployment
needs energy (and optionally carbon) *budgeted per principal*.  This
module supplies the three pieces the engine stack consumes:

- :class:`FairShare` — the frozen budget policy: joules (and optionally
  gCO2) granted per replenish window per unit weight, plus the fairness
  pressure ``mu`` the objective term applies.
- :class:`FairnessLedger` — a deficit-counter ledger over a user
  population.  Accounts settle *lazily* (per-user ``(credit,
  last_epoch)``, O(1) per access), so a Zipf population of 10k-1M
  simulated users costs memory proportional to the users actually seen,
  not the universe.  A user's **debt** is how many replenish windows of
  budget they are behind, capped at ``debt_cap``.
- :class:`FairnessWeights` — the frozen per-placement-call snapshot the
  schedulers consume, exactly the pattern ``CarbonWeights`` /
  ``WarmWeights`` established: :meth:`FairnessWeights.from_ledger`
  returns ``None`` when every submitting user is debt-free, so the
  default path stays bitwise-untouched.

The objective term is an **advantage tax**, not a flat surcharge: an
indebted user's task is charged ``mu * debt`` times the *advantage* a
candidate endpoint offers over the fleet-mean prediction (energy under
``alpha``, runtime under ``1-alpha``, both SF-normalized like the base
objective).  Taxing the advantage — ``relu(mean - predicted)`` — steers
over-budget users off premium endpoints toward fleet-average ones,
yielding the fast/efficient capacity to paid-up users, while a
zero-debt task's score is unchanged and an identical-endpoints fleet
makes the term vanish entirely.  (Taxing raw cost instead would
*reward* debtors with the most efficient endpoints — anti-fair.)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class FairShare:
    """Frozen per-user budget policy.

    ``budget_j`` joules are granted per ``window_s`` seconds per unit
    weight (``weights`` maps user -> share weight, default 1.0 — a user
    with weight 2 earns twice the budget).  ``budget_g`` optionally adds
    a carbon budget in gCO2 per window.  Unused credit banks up to
    ``bank_windows`` windows' worth; debt accrues unbounded but is
    *reported* capped at ``debt_cap`` windows so one pathological user
    cannot blow up the objective term.  ``mu`` scales the advantage-tax
    placement term (0 disables it while keeping admission accounting).
    """

    budget_j: float
    window_s: float = 60.0
    mu: float = 1.0
    weights: Mapping[str, float] | None = None
    budget_g: float | None = None
    debt_cap: float = 8.0
    bank_windows: float = 1.0

    def __post_init__(self) -> None:
        if self.budget_j <= 0.0:
            raise ValueError(f"budget_j must be positive, got {self.budget_j}")
        if self.window_s <= 0.0:
            raise ValueError(f"window_s must be positive, got {self.window_s}")
        if self.mu < 0.0:
            raise ValueError(f"mu must be non-negative, got {self.mu}")
        if self.budget_g is not None and self.budget_g <= 0.0:
            raise ValueError(f"budget_g must be positive, got {self.budget_g}")
        if self.debt_cap <= 0.0:
            raise ValueError(f"debt_cap must be positive, got {self.debt_cap}")
        if self.bank_windows < 0.0:
            raise ValueError(
                f"bank_windows must be non-negative, got {self.bank_windows}"
            )
        if self.weights is not None:
            bad = {u: w for u, w in self.weights.items() if w <= 0.0}
            if bad:
                raise ValueError(f"share weights must be positive: {bad}")

    def ledger(self) -> "FairnessLedger":
        return FairnessLedger(self)


class FairnessLedger:
    """Deficit-counter energy/carbon ledger over a user population.

    Accounting is in *epochs*: :meth:`advance` maps wall-clock seconds to
    ``floor(now / window_s)`` and only moves forward.  Each account is a
    ``[credit_j, credit_g, last_epoch]`` triple settled lazily on access:
    elapsed epochs credit one quantum each (``budget * weight``), capped
    at the bank, then charges subtract.  A never-seen user settles to a
    full bank — new tenants start paid-up.

    :meth:`debt` converts a negative balance into "windows behind"
    (``-credit / quantum``), summing the energy and carbon components and
    clamping to ``share.debt_cap``; it is the dimensionless weight the
    advantage-tax term and the admission threshold both consume.
    """

    def __init__(self, share: FairShare):
        self.share = share
        self._epoch = 0
        self._w = dict(share.weights) if share.weights else {}
        # user -> [credit_j, credit_g, last_settled_epoch]
        self._acct: dict[str, list] = {}

    # -- time ----------------------------------------------------------
    def advance(self, now: float) -> int:
        """Advance the replenish epoch to ``floor(now / window_s)``
        (monotone — a stale ``now`` never rolls credit back).  Returns
        the current epoch."""
        ep = int(math.floor(now / self.share.window_s))
        if ep > self._epoch:
            self._epoch = ep
        return self._epoch

    def next_replenish(self, now: float) -> float:
        """Wall-clock time of the next budget replenish after ``now`` —
        the release time admission control defers over-budget work to."""
        w = self.share.window_s
        return (math.floor(now / w) + 1.0) * w

    # -- accounts ------------------------------------------------------
    def _quanta(self, user: str) -> tuple[float, float]:
        w = self._w.get(user, 1.0)
        qg = (self.share.budget_g or 0.0) * w
        return self.share.budget_j * w, qg

    def _settle(self, user: str) -> list:
        qj, qg = self._quanta(user)
        bank = self.share.bank_windows
        acct = self._acct.get(user)
        if acct is None:
            acct = self._acct[user] = [bank * qj, bank * qg, self._epoch]
            return acct
        lag = self._epoch - acct[2]
        if lag > 0:
            acct[0] = min(acct[0] + lag * qj, bank * qj)
            if qg:
                acct[1] = min(acct[1] + lag * qg, bank * qg)
            acct[2] = self._epoch
        return acct

    def charge(self, user: str, energy_j: float, carbon_g: float = 0.0) -> None:
        """Debit ``energy_j`` joules (and optionally ``carbon_g`` grams)
        against ``user``'s account."""
        acct = self._settle(user)
        acct[0] -= energy_j
        if carbon_g:
            acct[1] -= carbon_g

    def credit_j(self, user: str) -> float:
        """Current energy balance in joules (negative = in debt)."""
        return self._settle(user)[0]

    def debt(self, user: str) -> float:
        """How many replenish windows of budget ``user`` is behind
        (0.0 when in credit), capped at ``share.debt_cap``."""
        acct = self._settle(user)
        qj, qg = self._quanta(user)
        d = -acct[0] / qj if acct[0] < 0.0 else 0.0
        if qg and acct[1] < 0.0:
            d += -acct[1] / qg
        cap = self.share.debt_cap
        return d if d < cap else cap

    @property
    def tracks_carbon(self) -> bool:
        return self.share.budget_g is not None

    def users(self) -> list[str]:
        """Users with an opened account (charged or queried at least
        once) — NOT the simulated universe, which is never materialized."""
        return sorted(self._acct)


@dataclasses.dataclass(frozen=True)
class FairnessWeights:
    """Frozen per-placement-call fairness snapshot (the
    ``CarbonWeights``/``WarmWeights`` pattern): ``debt`` maps user ->
    positive windows-behind weight, ``mu`` scales the advantage-tax
    objective term.  Only indebted users appear — schedulers read
    ``debt.get(task.user, 0.0)`` and a miss keeps that task's candidate
    scores bitwise-unchanged.  On the SoA engine the per-task debt joins
    the run-memoization key, so runs never mix tasks taxed differently.
    """

    debt: Mapping[str, float]
    mu: float = 1.0

    def __post_init__(self) -> None:
        if self.mu < 0.0:
            raise ValueError(f"mu must be non-negative, got {self.mu}")
        bad = {u: d for u, d in self.debt.items() if d <= 0.0}
        if bad:
            raise ValueError(f"fairness debts must be positive: {bad}")

    @classmethod
    def from_ledger(
        cls, ledger: FairnessLedger, tasks: Sequence, mu: float | None = None
    ) -> "FairnessWeights | None":
        """Snapshot the debts of every user submitting in ``tasks``.
        Returns None when all of them are debt-free (or ``mu`` resolves
        to 0), keeping the engines on the unmodified hot path."""
        eff_mu = ledger.share.mu if mu is None else mu
        if eff_mu == 0.0:
            return None
        debt: dict[str, float] = {}
        seen: set[str] = set()
        for t in tasks:
            u = t.user
            if u in seen:
                continue
            seen.add(u)
            d = ledger.debt(u)
            if d > 0.0:
                debt[u] = d
        if not debt:
            return None
        return cls(debt, eff_mu)
