"""GreenFaaS task/energy database (the 'cloud-hosted DB' of §III-C).

In-memory with JSONL persistence; the report/bookmarklet layer queries it.

Aggregates (per-endpoint / per-user / per-function energy) are maintained
incrementally on ``add``/``extend`` instead of rescanning every record on
each query, so report queries stay O(distinct keys) as the record count
grows into the millions.  ``save()`` appends only records written since
the last save as JSON lines rather than rewriting the whole blob (legacy
JSON-array files are still readable and are upgraded on first save).

Aggregates reflect each record's values *at insertion time* — the
attribution pipeline fills ``energy_j``/``node_energy_j`` before adding.
If records are mutated afterwards, call :meth:`reindex`.

Units: record energies are joules, times are seconds since the workload
clock's origin (the report layer converts to kJ / kJ*s).  ``add`` keeps a
reference to the record, not a copy.  The DB itself is deterministic and
seed-free; ordering follows insertion order.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import warnings
from collections import defaultdict

from repro.core.counters import TaskRecord


class TaskDB:
    """Task/energy record store with O(distinct-keys) report queries:
    per-endpoint / per-user / per-function energy (J), busy spans and
    makespan (s), maintained incrementally on ``add``; JSONL persistence
    via ``save``/``load``.  Records are stored by reference and indexed
    at insertion time — call :meth:`reindex` after mutating them.
    """

    def __init__(self, path: str | None = None,
                 max_records: int | None = None):
        """``max_records`` caps the retained record list to a rolling
        window of the most recent records (None = keep all).  Aggregates
        are *cumulative over everything ever added* either way — eviction
        compacts the raw rows into the already-maintained rolling
        summaries, so report queries stay exact while memory stays
        O(max_records) on unbounded streams.  With persistence enabled,
        call :meth:`save` at least every ``max_records`` adds or evicted
        rows are gone before they hit disk."""
        if max_records is not None and max_records <= 0:
            raise ValueError(f"max_records must be positive, got {max_records}")
        self.path = pathlib.Path(path) if path else None
        self.max_records = max_records
        self.records: list[TaskRecord] = []
        self._reset_aggregates()
        self._added = 0            # records ever added (monotone)
        self._saved = 0            # records ever persisted to self.path
        self._legacy_file = False  # loaded from a JSON-array blob
        self._truncated = 0        # half-written JSONL lines skipped on load
        if self.path and self.path.exists():
            self.load()

    # --- ingest -------------------------------------------------------------
    def _reset_aggregates(self) -> None:
        self._energy_by_ep: dict[str, float] = defaultdict(float)
        self._node_by_ep: dict[str, float] = defaultdict(float)
        self._user_by_ep: dict[str, dict[str, float]] = defaultdict(
            lambda: defaultdict(float)
        )
        self._fn_sum: dict[str, dict[str, float]] = defaultdict(
            lambda: defaultdict(float)
        )
        self._fn_cnt: dict[str, dict[str, int]] = defaultdict(
            lambda: defaultdict(int)
        )
        self._span_by_ep: dict[str, tuple[float, float]] = {}
        # per-user rollups for the fairness ledger / eval columns:
        # energy sum, busy-seconds sum, task count, (first start, last end)
        self._user_energy: dict[str, float] = defaultdict(float)
        self._user_busy_s: dict[str, float] = defaultdict(float)
        self._user_cnt: dict[str, int] = defaultdict(int)
        self._user_span: dict[str, tuple[float, float]] = {}

    def _index(self, r: TaskRecord) -> None:
        self._energy_by_ep[r.endpoint] += r.energy_j or 0.0
        self._node_by_ep[r.endpoint] += r.node_energy_j or 0.0
        self._user_by_ep[r.user][r.endpoint] += r.energy_j or 0.0
        if r.energy_j is not None:
            self._fn_sum[r.fn][r.endpoint] += r.energy_j
            self._fn_cnt[r.fn][r.endpoint] += 1
        span = self._span_by_ep.get(r.endpoint)
        if span is None:
            self._span_by_ep[r.endpoint] = (r.t_start, r.t_end)
        else:
            self._span_by_ep[r.endpoint] = (
                min(span[0], r.t_start), max(span[1], r.t_end)
            )
        self._user_energy[r.user] += r.energy_j or 0.0
        self._user_busy_s[r.user] += r.t_end - r.t_start
        self._user_cnt[r.user] += 1
        uspan = self._user_span.get(r.user)
        if uspan is None:
            self._user_span[r.user] = (r.t_start, r.t_end)
        else:
            self._user_span[r.user] = (
                min(uspan[0], r.t_start), max(uspan[1], r.t_end)
            )

    def add(self, rec: TaskRecord) -> None:
        self.records.append(rec)
        self._added += 1
        self._index(rec)
        if (self.max_records is not None
                and len(self.records) > self.max_records):
            del self.records[:len(self.records) - self.max_records]

    def extend(self, recs) -> None:
        for r in recs:
            self.add(r)

    @property
    def evicted(self) -> int:
        """Records compacted out of the rolling window so far."""
        return self._added - len(self.records)

    @property
    def truncated(self) -> int:
        """Half-written trailing JSONL lines skipped by :meth:`load` — a
        crash mid-append leaves one; nonzero means the previous process
        died while persisting."""
        return self._truncated

    def reindex(self) -> None:
        """Rebuild aggregates from scratch (after in-place record edits).
        Under ``max_records`` this only sees the retained window —
        evicted rows' contributions are rebuilt from nothing, so reindex
        is for the unbounded configuration (or right after load)."""
        self._reset_aggregates()
        for r in self.records:
            self._index(r)

    # --- queries used by the web report ------------------------------------
    def energy_by_endpoint(self) -> dict[str, float]:
        return dict(self._energy_by_ep)

    def energy_by_user(self, user: str) -> dict[str, float]:
        return dict(self._user_by_ep.get(user, {}))

    def node_energy_by_endpoint(self) -> dict[str, float]:
        return dict(self._node_by_ep)

    def by_function(self) -> dict[str, dict[str, float]]:
        return {
            fn: {ep: s / self._fn_cnt[fn][ep] for ep, s in eps.items()}
            for fn, eps in self._fn_sum.items()
        }

    def span_by_endpoint(self) -> dict[str, tuple[float, float]]:
        """Per-endpoint (first task start, last task end) seconds."""
        return dict(self._span_by_ep)

    def users(self) -> list[str]:
        """Every user that ever contributed a record, sorted.  Incremental
        (compaction-safe): users whose raw rows were evicted under
        ``max_records`` still appear."""
        return sorted(self._user_cnt)

    def span_by_user(self) -> dict[str, tuple[float, float]]:
        """Per-user (first task start, last task end) seconds."""
        return dict(self._user_span)

    def edp_by_user(self) -> dict[str, float]:
        """Per-user EDP proxy: total attributed energy (J) times the
        user's wall span (last end - first start, s).  Incremental and
        compaction-safe like every other aggregate."""
        return {
            u: self._user_energy[u] * (s[1] - s[0])
            for u, s in self._user_span.items()
        }

    def user_stats(self) -> dict[str, dict[str, float]]:
        """Per-user rollup: ``energy_j`` (sum), ``busy_s`` (sum of record
        runtimes), ``tasks`` (count), ``span_s`` (wall span), ``edp``
        (energy * span) — the fairness eval columns' raw inputs."""
        out: dict[str, dict[str, float]] = {}
        for u in self.users():
            t0, t1 = self._user_span[u]
            e = self._user_energy[u]
            out[u] = {
                "energy_j": e,
                "busy_s": self._user_busy_s[u],
                "tasks": float(self._user_cnt[u]),
                "span_s": t1 - t0,
                "edp": e * (t1 - t0),
            }
        return out

    def makespan(self) -> float:
        """Last task end minus first task start over all records (s)."""
        if not self._span_by_ep:
            return 0.0
        t0 = min(s for s, _ in self._span_by_ep.values())
        t1 = max(e for _, e in self._span_by_ep.values())
        return t1 - t0

    # --- persistence --------------------------------------------------------
    def save(self) -> None:
        assert self.path is not None
        self.path.parent.mkdir(parents=True, exist_ok=True)
        if self._legacy_file or not self.path.exists():
            # fresh file, or upgrading a legacy JSON-array blob: write all
            with self.path.open("w") as f:
                for r in self.records:
                    f.write(json.dumps(dataclasses.asdict(r)) + "\n")
            self._legacy_file = False
        elif self._saved < self._added:
            # the unsaved tail is the last (_added - _saved) retained rows;
            # anything evicted before this save never reaches disk
            tail = self.records[max(0, len(self.records)
                                    - (self._added - self._saved)):]
            with self.path.open("a") as f:
                for r in tail:
                    f.write(json.dumps(dataclasses.asdict(r)) + "\n")
        self._saved = self._added

    def load(self) -> None:
        text = self.path.read_text()
        head = text.lstrip()[:1]
        if head == "[":
            # legacy whole-blob JSON array
            data = json.loads(text)
            self._legacy_file = True
        else:
            self._legacy_file = False
            lines = [ln for ln in text.splitlines() if ln.strip()]
            data = []
            for i, ln in enumerate(lines):
                try:
                    data.append(json.loads(ln))
                except json.JSONDecodeError:
                    if i != len(lines) - 1:
                        raise    # corruption mid-file: not a crash artifact
                    # a crash mid-append leaves exactly one half-written
                    # tail line; the record never fully landed — skip it,
                    # count it, and rewrite the file clean on next save
                    self._truncated += 1
                    self._legacy_file = True
                    warnings.warn(
                        f"{self.path}: dropped truncated trailing JSONL "
                        f"line ({len(ln)} bytes); file will be rewritten "
                        f"on next save",
                        RuntimeWarning,
                    )
        self.records = [TaskRecord(**d) for d in data]
        self._added = self._saved = len(self.records)
        self.reindex()      # aggregates over *everything* in the file...
        if (self.max_records is not None
                and len(self.records) > self.max_records):
            # ...then compact the raw rows down to the rolling window
            del self.records[:len(self.records) - self.max_records]
