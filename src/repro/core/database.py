"""GreenFaaS task/energy database (the 'cloud-hosted DB' of §III-C).

In-memory with JSON persistence; the report/bookmarklet layer queries it.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from collections import defaultdict

from repro.core.counters import TaskRecord


class TaskDB:
    def __init__(self, path: str | None = None):
        self.path = pathlib.Path(path) if path else None
        self.records: list[TaskRecord] = []
        if self.path and self.path.exists():
            self.load()

    def add(self, rec: TaskRecord) -> None:
        self.records.append(rec)

    def extend(self, recs) -> None:
        self.records.extend(recs)

    # --- queries used by the web report ------------------------------------
    def energy_by_endpoint(self) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for r in self.records:
            out[r.endpoint] += r.energy_j or 0.0
        return dict(out)

    def energy_by_user(self, user: str) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for r in self.records:
            if r.user == user:
                out[r.endpoint] += r.energy_j or 0.0
        return dict(out)

    def node_energy_by_endpoint(self) -> dict[str, float]:
        out: dict[str, float] = defaultdict(float)
        for r in self.records:
            out[r.endpoint] += r.node_energy_j or 0.0
        return dict(out)

    def by_function(self) -> dict[str, dict[str, float]]:
        out: dict[str, dict[str, float]] = defaultdict(lambda: defaultdict(float))
        cnt: dict[str, dict[str, int]] = defaultdict(lambda: defaultdict(int))
        for r in self.records:
            if r.energy_j is not None:
                out[r.fn][r.endpoint] += r.energy_j
                cnt[r.fn][r.endpoint] += 1
        return {
            fn: {ep: e / cnt[fn][ep] for ep, e in eps.items()}
            for fn, eps in out.items()
        }

    # --- persistence --------------------------------------------------------
    def save(self) -> None:
        assert self.path is not None
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(json.dumps(
            [dataclasses.asdict(r) for r in self.records]
        ))

    def load(self) -> None:
        data = json.loads(self.path.read_text())
        self.records = [TaskRecord(**d) for d in data]
