"""GreenFaaS executor: submit -> predict -> schedule -> dispatch ->
monitor -> attribute -> learn (the full paper pipeline, §III).

The backend is pluggable: TestbedSim (paper-fidelity) or a fleet backend.
Placement is delegated to a registered :class:`PlacementPolicy` — pass
``strategy="cluster_mhra"`` (or any name in ``available_policies()``), or
an already-constructed policy instance.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Literal

import numpy as np

from repro.core import scheduler as sched
from repro.core.database import TaskDB
from repro.core.endpoint import EndpointSpec
from repro.core.policy import PlacementPolicy, PolicyContext, get_policy
from repro.core.power_model import (
    LinearPowerModel,
    attribute_node_power,
    integrate_windows,
)
from repro.core.predictor import TaskProfileStore
from repro.core.testbed import SimResult, TestbedSim
from repro.core.transfer import TransferModel

Strategy = Literal["cluster_mhra", "mhra", "round_robin", "single_site"]


@dataclasses.dataclass
class BatchResult:
    schedule: sched.Schedule
    sim: SimResult
    measured_energy_j: float     # monitor-integrated node energy (+idle spans)
    attributed_energy_j: float   # sum of per-task attributed dynamic energy
    makespan_s: float
    scheduling_s: float
    transfer_j: float

    def edp(self) -> float:
        return self.measured_energy_j * self.makespan_s

    def w_ed2p(self) -> float:
        return self.measured_energy_j * self.makespan_s ** 2


def attribute_window(
    sim: SimResult,
    models: dict[str, LinearPowerModel],
    store: TaskProfileStore,
    db: TaskDB | None = None,
) -> tuple[dict[str, tuple[float, float]], float]:
    """Train per-endpoint power models on a SimResult's monitor streams and
    attribute per-task dynamic energy (paper §III-D), feeding the profile
    store (and DB).  Shared by batch runs and the online engine's windows.

    Returns ``({endpoint: (node_energy_j, trace_end_s)}, attributed_total)``
    where node_energy_j is the trapezoid-integrated measured node energy
    over the trace span.

    Vectorized: the model trains on the trace's (samples x counters)
    matrix in one batched update, per-process watts come from one
    correction-factor pass over the whole (samples x pids) matrix, and
    every task's energy integral is evaluated against one cumulative
    trapezoid of its pid's attributed-power column — O(samples·pids +
    tasks·log samples) per node instead of the per-task rescans of the
    sample-object pipeline.

    Units are joules and seconds throughout.  Mutates its arguments:
    ``models[ep]`` accumulate training statistics, ``store`` gains one
    observation per record, ``db`` (if given) gains every record, and the
    ``sim.records`` themselves get ``energy_j``/``node_energy_j`` filled
    in.  Deterministic given the sim result — any randomness lives in the
    simulated monitor streams, not here.
    """
    recs_by_ep: dict[str, list] = {}
    for r in sim.records:
        recs_by_ep.setdefault(r.endpoint, []).append(r)
    node: dict[str, tuple[float, float]] = {}
    attributed = 0.0
    for ep_name, trace in sim.traces.items():
        model = models[ep_name]
        ts, watts, rates = trace.ts, trace.watts, trace.rates
        if len(ts) == 0:
            node[ep_name] = (0.0, 0.0)
            continue
        # train on the full stream in one sufficient-statistics update;
        # rates rows are zero while a process is idle, so summing over the
        # pid axis reproduces the per-sample X_total vectors exactly
        model.observe_batch(rates.sum(axis=1), watts)
        node[ep_name] = (float(np.trapezoid(watts, ts)), float(ts[-1]))
        recs = recs_by_ep.get(ep_name, [])
        if not recs:
            continue
        watts_attr = attribute_node_power(model, watts, rates)
        col = {pid: j for j, pid in enumerate(trace.pids)}
        t0s = np.array([r.t_start for r in recs])
        t1s = np.array([r.t_end for r in recs])
        node_j = integrate_windows(ts, watts, t0s, t1s)
        # batch the per-task integrals pid by pid (each pid's attributed-
        # power column is shared by all of that worker's tasks)
        recs_by_pid: dict[int, list[int]] = {}
        for i, rec in enumerate(recs):
            recs_by_pid.setdefault(rec.worker_pid, []).append(i)
        task_j = np.zeros(len(recs))
        for pid, idxs in recs_by_pid.items():
            j = col.get(pid)
            if j is None:
                continue
            task_j[idxs] = integrate_windows(
                ts, watts_attr[:, j], t0s[idxs], t1s[idxs]
            )
        for i, rec in enumerate(recs):
            rec.energy_j = float(task_j[i])
            rec.node_energy_j = float(node_j[i])
            attributed += rec.energy_j
            if not rec.failed:
                # killed executions are billed + logged but never enter the
                # profile store: a truncated runtime is not an observation
                store.record(rec.fn, ep_name, rec.runtime, rec.energy_j)
            if db is not None:
                db.add(rec)
    return node, attributed


class GreenFaaSExecutor:
    def __init__(
        self,
        endpoints: list[EndpointSpec],
        backend: TestbedSim,
        alpha: float = 0.5,
        strategy: Strategy | str = "cluster_mhra",
        site: str | None = None,
        db: TaskDB | None = None,
        monitoring: bool = True,
        policy: PlacementPolicy | None = None,
    ):
        self.endpoints = endpoints
        self.backend = backend
        self.alpha = alpha
        self.strategy = strategy
        self.site = site
        if policy is not None:
            self.policy = policy
        elif strategy == "single_site":
            names = [e.name for e in endpoints]
            if site not in names:
                raise ValueError(
                    f"strategy='single_site' requires site= one of {names}, "
                    f"got {site!r}"
                )
            self.policy = get_policy(strategy, site=site)
        else:
            self.policy = get_policy(strategy)
        self.store = TaskProfileStore(endpoints)
        self.transfer = TransferModel(endpoints)
        self.db = db or TaskDB()
        self.models = {e.name: LinearPowerModel() for e in endpoints}
        self.monitoring = monitoring

    # ------------------------------------------------------------------
    def _ctx(self) -> PolicyContext:
        return PolicyContext(self.endpoints, self.store, self.transfer, self.alpha)

    def schedule(self, tasks) -> tuple[sched.Schedule, float]:
        dep_tasks = [t.id for t in tasks if t.deps]
        if dep_tasks:
            raise ValueError(
                "GreenFaaSExecutor.run_batch places one flat batch and "
                "cannot order DAG dependencies; submit dependent tasks "
                f"through repro.core.engine.OnlineEngine instead (got deps "
                f"on {dep_tasks[:5]})"
            )
        t0 = time.perf_counter()
        s = self.policy.place(tasks, self._ctx())
        return s, time.perf_counter() - t0

    # ------------------------------------------------------------------
    def run_batch(self, tasks) -> BatchResult:
        schedule, sched_s = self.schedule(tasks)
        sim = self.backend.execute(schedule, tasks)

        measured = 0.0
        attributed = 0.0
        if self.monitoring:
            node, attributed = attribute_window(sim, self.models, self.store, self.db)
            for ep_name in sim.traces:
                node_j, t_last = node[ep_name]
                ep = next(e for e in self.endpoints if e.name == ep_name)
                if ep.has_batch_scheduler:
                    measured += node_j
                else:  # always-on: idle charged over the whole workflow span
                    measured += (node_j - ep.idle_power_w * t_last
                                 + ep.idle_power_w * sim.makespan_s)
            # endpoints never used still idle (always-on ones)
            for ep in self.endpoints:
                if ep.name not in sim.traces and ep.always_on:
                    measured += ep.idle_power_w * sim.makespan_s
        else:
            measured = sim.true_energy_j
            for rec in sim.records:
                rt, w, _ = self.backend.task_truth(rec.fn, rec.endpoint)
                self.store.record(rec.fn, rec.endpoint, rec.runtime, rec.runtime * w)

        return BatchResult(
            schedule=schedule, sim=sim, measured_energy_j=measured,
            attributed_energy_j=attributed, makespan_s=sim.makespan_s,
            scheduling_s=sched_s, transfer_j=schedule.transfer_j,
        )

    # ------------------------------------------------------------------
    def warmup(self, fns: list[str], per_endpoint: int = 3) -> None:
        """Seed online profiles by probing each fn on each endpoint
        (the paper builds profiles from prior monitoring runs)."""
        tasks = []
        i = 0
        for ep in self.endpoints:
            for fn in fns:
                for _ in range(per_endpoint):
                    tasks.append(sched.TaskSpec(id=f"warm{i}", fn=fn))
                    i += 1
        # force round-robin-by-endpoint placement for coverage
        names = []
        for ep in self.endpoints:
            names += [ep.name] * (len(fns) * per_endpoint)
        schedule = sched.fixed_assignment(
            tasks, self.endpoints, self.store, self.transfer,
            lambda idx, t: names[idx],
        )
        sim = self.backend.execute(schedule, tasks)
        attribute_window(sim, self.models, self.store)
