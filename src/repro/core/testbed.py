"""Discrete-event simulator of the paper's testbed (Tables I & II).

The CPU container has no power rails, so the *measurement source* is this
simulator; everything downstream (resource monitor, linear power model,
correction-factor attribution, profile store, scheduler) is the real
GreenFaaS pipeline consuming the simulated RAPL/Cray streams.

Per-(function, machine) base profiles are calibrated so the all-on-one-site
rows reproduce Table V magnitudes:
  desktop 640 s / 33.5 kJ - theta 656 s / 103 kJ - ic 340 s / 79.3 kJ -
  faster 209 s / 66.1 kJ   (1792-task workload, 7 SeBS functions)
and the qualitative findings of Figs. 1-3 hold: FASTER runs pagerank ~200x
faster / ~75x cheaper than IC; dna is the energy-heavy inversion on IC; no
machine is best at everything.  (Fig. 2's 18x dna/pagerank anecdote is not
jointly satisfiable with Table V totals; we keep totals and a ~6x inversion
— see EXPERIMENTS.md §Paper-fidelity.)
"""
from __future__ import annotations

import dataclasses
import heapq
import zlib

import numpy as np

from repro.core.counters import CounterSample, PowerSample, TaskRecord
from repro.core.endpoint import EndpointSpec, table1_testbed
from repro.core.faults import FaultTrace
from repro.core.monitor import CallbackMonitor
from repro.core.scheduler import Schedule, TaskSpec

SEBS_FUNCTIONS = (
    "graph_bfs", "graph_mst", "graph_pagerank",
    "compression", "dna_visualization", "thumbnail", "video_processing",
)

#    fn -> machine -> (runtime_s, dynamic_watts)
BASE_PROFILES: dict[str, dict[str, tuple[float, float]]] = {
    "graph_bfs":         {"desktop": (4.0, 2.0),  "theta": (16.0, 0.8),  "ic": (6.0, 1.0),   "faster": (4.0, 1.0)},
    "graph_mst":         {"desktop": (5.0, 2.0),  "theta": (18.0, 0.8),  "ic": (7.0, 1.0),   "faster": (5.0, 1.0)},
    "graph_pagerank":    {"desktop": (4.0, 2.5),  "theta": (20.0, 0.6),  "ic": (20.0, 0.5),  "faster": (0.1, 1.33)},
    "compression":       {"desktop": (8.0, 1.5),  "theta": (30.0, 0.5),  "ic": (6.0, 1.0),   "faster": (12.0, 1.5)},
    "dna_visualization": {"desktop": (6.0, 8.0),  "theta": (20.0, 2.5),  "ic": (10.0, 6.0),  "faster": (8.0, 6.0)},
    "thumbnail":         {"desktop": (5.0, 2.0),  "theta": (22.0, 0.5),  "ic": (4.2, 2.0),   "faster": (6.0, 1.5)},
    "video_processing":  {"desktop": (8.0, 2.06), "theta": (30.0, 0.64), "ic": (6.0, 7.4),   "faster": (11.65, 2.1)},
}

# Counter signatures per function (relative rates of
# [LLC_MISSES, INSTRUCTIONS_RETIRED, CPU_CYCLES, REF_CYCLES]); the sim
# scales them so true power is exactly linear in counters per machine.
FN_SIGNATURES = {
    "graph_bfs": np.array([3.0, 1.0, 1.2, 1.0]),
    "graph_mst": np.array([2.5, 1.2, 1.2, 1.0]),
    "graph_pagerank": np.array([4.0, 0.8, 1.1, 1.0]),
    "compression": np.array([1.0, 2.0, 1.3, 1.0]),
    "dna_visualization": np.array([6.0, 3.0, 1.4, 1.0]),
    "thumbnail": np.array([0.8, 1.5, 1.0, 1.0]),
    "video_processing": np.array([1.5, 3.5, 1.5, 1.0]),
}

# Machines' true (hidden) power coefficients; the pipeline re-learns these.
MACHINE_COEFS = {
    "desktop": np.array([0.5, 0.3, 0.15, 0.05]),
    "theta": np.array([0.3, 0.4, 0.2, 0.1]),
    "ic": np.array([0.6, 0.2, 0.15, 0.05]),
    "faster": np.array([0.4, 0.35, 0.15, 0.1]),
}

DISPATCH_OVERHEAD_S = 0.109  # Globus Compute warm invocation overhead
SAMPLE_PERIOD_S = 1.0


@dataclasses.dataclass
class NodeTrace:
    """One node's monitor streams for a window, in matrix form.

    ``rates[i, j]`` is pid ``pids[j]``'s counter-rate vector at ``ts[i]``
    (zero rows while the process is idle).  The attribution pipeline
    consumes the matrices directly; the legacy per-tick sample-object
    views are derived on demand for tooling/tests that still want them.
    """
    endpoint: str
    alloc_span: tuple[float, float]  # (alloc_t, release_t)
    true_node_energy_j: float
    ts: np.ndarray                   # (n,) sample times
    watts: np.ndarray                # (n,) measured node power
    pids: list[int]                  # column order of `rates`
    rates: np.ndarray                # (n, P, k) per-process counter rates

    @property
    def power_samples(self) -> list[PowerSample]:
        return [PowerSample(t=float(t), watts=float(w))
                for t, w in zip(self.ts, self.watts)]

    @property
    def counter_samples(self) -> list[CounterSample]:
        active = self.rates.any(axis=2)
        return [
            CounterSample(t=float(t), procs={
                pid: self.rates[i, j]
                for j, pid in enumerate(self.pids) if active[i, j]
            })
            for i, t in enumerate(self.ts)
        ]


@dataclasses.dataclass
class SimResult:
    records: list[TaskRecord]
    traces: dict[str, NodeTrace]
    makespan_s: float
    true_energy_j: float          # ground truth incl. idle while allocated
    true_dyn_energy_j: dict[str, float]
    # fault/warm-pool telemetry (streaming path; zero on fault-free runs)
    killed: int = 0               # tasks cut short by endpoint churn
    cold_starts: int = 0          # cold worker spin-ups this window
    cold_j: float = 0.0           # startup energy billed for them (J)


class TestbedSim:
    def __init__(
        self,
        endpoints: list[EndpointSpec] | None = None,
        profiles: dict | None = None,
        signatures: dict | None = None,
        coefs: dict | None = None,
        seed: int = 0,
        runtime_noise: float = 0.05,
        faults: FaultTrace | None = None,
    ):
        self.endpoints = endpoints or table1_testbed()
        self.by_name = {e.name: e for e in self.endpoints}
        self.profiles = profiles or BASE_PROFILES
        self.signatures = signatures or FN_SIGNATURES
        self.coefs = coefs or MACHINE_COEFS
        self.rng = np.random.default_rng(seed)
        self.noise = runtime_noise
        # an empty trace is normalized to None so fault-free runs take the
        # exact pre-fault code path (bitwise no-op gate); straggler draws
        # are hashed per task id, never from self.rng, so enabling faults
        # cannot perturb the per-task runtime-noise stream either
        self.faults = faults if faults else None
        self._stream: dict | None = None

    def task_truth(self, fn: str, machine: str) -> tuple[float, float, np.ndarray]:
        """(runtime, dyn_watts, counter_rates) — counters chosen so that
        machine_coefs @ rates == dyn_watts exactly (linear ground truth)."""
        rt, w = self.profiles[fn][machine]
        sig = self.signatures.get(fn, np.ones(4))
        coef = self.coefs.get(machine, np.ones(4) * 0.25)
        rates = sig * (w / float(coef @ sig))
        return rt, w, rates

    def _sample_trace(self, ep, intervals, t_lo, release_t, seed):
        """(ts, watts, pids, rates): 1 Hz monitor matrices over
        ``[t_lo, release_t]`` — the batched equivalent of the legacy
        per-tick sampling loops.  The monitor-noise and counter-jitter
        draws consume the generators in exactly the per-tick order, so
        seeded runs produce the same streams the scalar loops did.
        """
        tgrid = np.arange(t_lo, release_t + SAMPLE_PERIOD_S, SAMPLE_PERIOD_S)
        n = len(tgrid)
        mon = CallbackMonitor(lambda t: 0.0, seed=seed)
        if not intervals:
            watts = mon.read_noisy(np.full(n, float(ep.idle_power_w)))
            return tgrid, watts, [], np.zeros((n, 0, 0))
        starts = np.array([iv[0] for iv in intervals])
        ends = np.array([iv[1] for iv in intervals])
        ws = np.array([iv[2] for iv in intervals])
        pid_arr = np.array([iv[3] for iv in intervals])
        rates_iv = np.array([iv[4] for iv in intervals], dtype=float)
        active = (starts <= tgrid[:, None]) & (tgrid[:, None] < ends)
        watts = mon.read_noisy(ep.idle_power_w + active @ ws)
        pids_arr = np.unique(pid_arr)
        cols_of_iv = np.searchsorted(pids_arr, pid_arr)
        k = rates_iv.shape[1]
        rates = np.zeros((n, len(pids_arr), k))
        tidx, iidx = np.nonzero(active)
        if len(tidx):
            jitter = self.rng.normal(1.0, 0.02, size=(len(tidx), k))
            rates[tidx, cols_of_iv[iidx]] = rates_iv[iidx] * jitter
        return tgrid, watts, [int(p) for p in pids_arr], rates

    def execute(self, schedule: Schedule, tasks: list[TaskSpec]) -> SimResult:
        """Run the schedule: per-endpoint FIFO worker pools, queue delays,
        1 Hz power+counter sampling, ground-truth energy bookkeeping.

        Batch mode is fault-free by design: churn/cold-start/straggler
        faults only make sense against the streaming clock, so ``faults``
        is consumed exclusively by :meth:`execute_window` (the batch
        executor has no retry path to recover a killed task)."""
        by_ep: dict[str, list[TaskSpec]] = {}
        for t in tasks:
            by_ep.setdefault(schedule.assignments[t.id], []).append(t)

        records: list[TaskRecord] = []
        traces: dict[str, NodeTrace] = {}
        true_dyn: dict[str, float] = {}
        makespan = 0.0
        total_true = 0.0

        for ep_name, ep_tasks in by_ep.items():
            ep = self.by_name[ep_name]
            ready = ep.queue_delay_s if ep.has_batch_scheduler else 0.0
            slots = [ready] * ep.cores
            heapq.heapify(slots)
            intervals = []  # (start, end, dyn_w, pid, rates, task)
            pid_of_slot = {i: 1000 + i for i in range(ep.cores)}
            slot_free = list(slots)
            for t in ep_tasks:
                rt, w, rates = self.task_truth(t.fn, ep_name)
                rt = rt * float(
                    np.clip(self.rng.normal(1.0, self.noise), 0.7, 1.3)
                )
                popped = heapq.heappop(slots)
                start = max(popped, t.not_before) + DISPATCH_OVERHEAD_S
                end = start + rt
                heapq.heappush(slots, end)
                # pick a stable pid per concurrent slot (match the unclamped
                # pop value: a not_before clamp must not grab a busy slot)
                slot_id = int(np.argmin([abs(sf - popped) for sf in slot_free]))
                slot_free[slot_id] = end
                pid = pid_of_slot[slot_id]
                intervals.append((start, end, w, pid, rates, t))
                records.append(TaskRecord(
                    task_id=t.id, fn=t.fn, endpoint=ep_name,
                    worker_pid=pid, t_start=start, t_end=end, user=t.user,
                ))
            alloc_t = 0.0
            release_t = max(end for _, end, *_ in intervals) + 2.0
            makespan = max(makespan, release_t)

            sample_ivs = [(s, e, w, pid, rates)
                          for s, e, w, pid, rates, _ in intervals]
            ts, watts, pids, rates_m = self._sample_trace(
                ep, sample_ivs, 0.0, release_t, abs(hash(ep_name)) % 2**31
            )
            dyn = sum((e - s) * w for s, e, w, *_ in intervals)
            true_dyn[ep_name] = dyn
            node_true = ep.idle_power_w * (release_t - alloc_t) + dyn
            if not ep.has_batch_scheduler:
                node_true = dyn  # idle accounted over global span below
            total_true += node_true
            traces[ep_name] = NodeTrace(
                endpoint=ep_name, alloc_span=(alloc_t, release_t),
                true_node_energy_j=node_true,
                ts=ts, watts=watts, pids=pids, rates=rates_m,
            )

        # always-on endpoints idle through the whole workflow
        for ep in self.endpoints:
            if not ep.has_batch_scheduler:
                total_true += ep.idle_power_w * makespan
        return SimResult(
            records=records, traces=traces, makespan_s=makespan,
            true_energy_j=total_true, true_dyn_energy_j=true_dyn,
        )

    # ------------------------------------------------------------------
    # Incremental (streaming) execution for the online engine
    # ------------------------------------------------------------------

    def begin_stream(self) -> None:
        """Reset incremental execution: endpoint worker pools, pending
        intervals, and the stream clock persist across execute_window calls."""
        self._stream = {
            "slots": {},        # ep -> min-heap of slot-free times
            "slot_free": {},    # ep -> per-slot busy-until (pid mapping)
            "pid_of_slot": {},  # ep -> slot index -> pid
            "slot_last": {},    # ep -> per-slot last task end (None = unused)
            "intervals": {},    # ep -> [(start, end, w, pid, rates)]
            "clock": 0.0,       # latest release time seen so far
        }

    @property
    def stream_clock(self) -> float:
        return self._stream["clock"] if self._stream else 0.0

    def execute_window(
        self,
        assignments: dict[str, str],
        tasks: list[TaskSpec],
        now: float = 0.0,
    ) -> SimResult:
        """Execute one arrival window against the persistent stream state.

        Endpoint worker pools (slot heaps) carry over from earlier windows:
        a task submitted at ``now`` starts no earlier than ``now`` and no
        earlier than a free slot.  Batch-scheduler endpoints pay their queue
        delay once, on first use of the stream.  Monitoring traces cover
        this window's span and include node power from still-running tasks
        of earlier windows, so attribution sees true node power.

        Fault semantics (``faults=`` on the constructor; see
        ``core/faults.py``): a task whose ``[start, end)`` span overlaps a
        down interval of its endpoint is killed at the outage start — its
        record comes back with ``failed=True`` and the partial span, so
        the wasted energy is billed truthfully; stragglers get their true
        runtime inflated by the trace's hash-drawn factor.  Warm-pool
        dynamics (``EndpointSpec.cold_start_s/_j``/``keepalive_s``): a
        task landing on a worker slot that was never used, idled past the
        keep-alive, or lost its worker to an outage pays the cold-start
        latency, and the startup energy is billed to the node (counted in
        ``SimResult.cold_starts``/``cold_j``).
        """
        if self._stream is None:
            self.begin_stream()
        st = self._stream
        flt = self.faults
        by_ep: dict[str, list[TaskSpec]] = {}
        for t in tasks:
            by_ep.setdefault(assignments[t.id], []).append(t)

        records: list[TaskRecord] = []
        traces: dict[str, NodeTrace] = {}
        true_dyn: dict[str, float] = {}
        makespan = st["clock"]
        total_true = 0.0
        killed = 0
        cold_starts = 0
        cold_j_total = 0.0

        for ep_name, ep_tasks in by_ep.items():
            ep = self.by_name[ep_name]
            if ep_name not in st["slots"]:
                ready = now + (ep.queue_delay_s if ep.has_batch_scheduler else 0.0)
                slots = [ready] * ep.cores
                heapq.heapify(slots)
                st["slots"][ep_name] = slots
                st["slot_free"][ep_name] = list(slots)
                st["pid_of_slot"][ep_name] = {i: 1000 + i for i in range(ep.cores)}
                st["slot_last"][ep_name] = [None] * ep.cores
                st["intervals"][ep_name] = []
            slots = st["slots"][ep_name]
            slot_free = st["slot_free"][ep_name]
            pid_of_slot = st["pid_of_slot"][ep_name]
            slot_last = st["slot_last"][ep_name]
            # drop intervals that ended before this window opens
            st["intervals"][ep_name] = [
                iv for iv in st["intervals"][ep_name] if iv[1] > now
            ]
            intervals = st["intervals"][ep_name]
            cold_j_ep = 0.0
            new_intervals = []
            for t in ep_tasks:
                rt, w, rates = self.task_truth(t.fn, ep_name)
                # the noise draw consumes self.rng per task in submission
                # order; fault paths below must never touch this stream
                rt = rt * float(
                    np.clip(self.rng.normal(1.0, self.noise), 0.7, 1.3)
                )
                if flt is not None:
                    sfac = flt.straggle_factor(t.id)
                    if sfac != 1.0:
                        rt = rt * sfac
                popped = heapq.heappop(slots)
                start = max(popped, now, t.not_before) + DISPATCH_OVERHEAD_S
                # match the freed slot on the *unclamped* pop value — clamping
                # to `now` first could pick a still-busy slot and reuse its pid
                slot_id = int(np.argmin([abs(sf - popped) for sf in slot_free]))
                if ep.cold_start_s > 0.0 or ep.cold_start_j > 0.0:
                    prev = slot_last[slot_id]
                    cold = (
                        prev is None
                        or start - prev > ep.keepalive_s
                        or (flt is not None and prev < start
                            and flt.down_overlap(ep_name, prev, start)
                            is not None)
                    )
                    if cold:
                        start = start + ep.cold_start_s
                        cold_starts += 1
                        cold_j_ep += ep.cold_start_j
                end = start + rt
                failed = False
                if flt is not None:
                    ov = flt.down_overlap(ep_name, start, end)
                    if ov is not None:
                        # killed at the outage start (or at dispatch if the
                        # endpoint was already down); partial span billed
                        end = max(start, ov[0])
                        failed = True
                        killed += 1
                heapq.heappush(slots, end)
                slot_free[slot_id] = end
                slot_last[slot_id] = end
                pid = pid_of_slot[slot_id]
                iv = (start, end, w, pid, rates)
                intervals.append(iv)
                new_intervals.append(iv)
                records.append(TaskRecord(
                    task_id=t.id, fn=t.fn, endpoint=ep_name,
                    worker_pid=pid, t_start=start, t_end=end, user=t.user,
                    failed=failed,
                ))
            release_t = max(end for _, end, *_ in new_intervals) + 2.0
            makespan = max(makespan, release_t)

            # crc32, not hash(): str hashing is randomized per process
            # (PYTHONHASHSEED) and would make online runs irreproducible
            ts, watts, pids, rates_m = self._sample_trace(
                ep, intervals, now, release_t,
                zlib.crc32(ep_name.encode()) % 2**31,
            )
            dyn = sum((e - s) * wv for s, e, wv, *_ in new_intervals)
            true_dyn[ep_name] = dyn
            node_true = dyn + (
                ep.idle_power_w * (release_t - now) if ep.has_batch_scheduler else 0.0
            )
            if cold_j_ep:
                node_true += cold_j_ep
                cold_j_total += cold_j_ep
            total_true += node_true
            traces[ep_name] = NodeTrace(
                endpoint=ep_name, alloc_span=(now, release_t),
                true_node_energy_j=node_true,
                ts=ts, watts=watts, pids=pids, rates=rates_m,
            )

        st["clock"] = makespan
        # always-on endpoints idle through the window span regardless of use
        for ep in self.endpoints:
            if ep.always_on:
                total_true += ep.idle_power_w * max(makespan - now, 0.0)
        return SimResult(
            records=records, traces=traces, makespan_s=makespan,
            true_energy_j=total_true, true_dyn_energy_j=true_dyn,
            killed=killed, cold_starts=cold_starts, cold_j=cold_j_total,
        )
