"""Endpoints: the machines/pods GreenFaaS schedules onto.

Covers both the paper's Table-I testbed (CPU machines behind Globus
Compute endpoints) and TPU pod/slice endpoints for the fleet integration.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class EndpointSpec:
    name: str
    cores: int                       # concurrent task slots (workers / pods)
    idle_power_w: float              # node idle draw while allocated
    tdp_w: float                     # max sustained draw
    queue_delay_s: float             # batch-scheduler queue time (0 = always on)
    has_batch_scheduler: bool = True # desktop-style endpoints: False
    perf_scale: float = 1.0          # relative per-core speed (sim only)
    hops: Mapping[str, int] = dataclasses.field(default_factory=dict)
    # --- warm-pool dynamics (defaults = always-warm: exact no-op) ---
    cold_start_s: float = 0.0        # latency of spinning up a cold worker
    cold_start_j: float = 0.0        # startup energy of a cold worker
    keepalive_s: float = float("inf")  # idle gap after which a worker goes cold
    # --- TPU-fleet extras (unused by the CPU testbed) ---
    chips: int = 0
    peak_flops: float = 0.0          # per chip, FLOP/s (bf16)
    hbm_bw: float = 0.0              # per chip, B/s
    ici_bw: float = 0.0              # per link, B/s

    @property
    def always_on(self) -> bool:
        """Desktop-style endpoint: no batch scheduler, draws idle power over
        the whole workflow span whether or not tasks run (paper §III-F)."""
        return not self.has_batch_scheduler

    @property
    def startup_energy_j(self) -> float:
        """Energy burned bringing a node online for this workload: the node
        idles through provisioning/queue + teardown.  Desktop-style endpoints
        pay idle power regardless, so their startup cost is ~0 (paper §III-F)."""
        if not self.has_batch_scheduler:
            return 0.0
        return self.idle_power_w * (self.queue_delay_s + RELEASE_OVERHEAD_S)

    def hop_count(self, other: "EndpointSpec | str") -> int:
        name = other if isinstance(other, str) else other.name
        if name == self.name:
            return 0
        return self.hops.get(name, DEFAULT_HOPS)


RELEASE_OVERHEAD_S = 10.0
DEFAULT_HOPS = 8


# ---------------------------------------------------------------------------
# Paper Table I testbed
# ---------------------------------------------------------------------------

def table1_testbed() -> list[EndpointSpec]:
    hops = lambda **kw: kw  # noqa: E731
    return [
        EndpointSpec(
            "desktop", cores=16, idle_power_w=6.51, tdp_w=65.0,
            queue_delay_s=0.0, has_batch_scheduler=False, perf_scale=1.0,
            hops=hops(theta=10, ic=6, faster=12),
        ),
        EndpointSpec(
            "theta", cores=64, idle_power_w=110.0, tdp_w=215.0,
            queue_delay_s=32.0, perf_scale=0.6,
            hops=hops(desktop=10, ic=9, faster=14),
        ),
        EndpointSpec(
            "ic", cores=48, idle_power_w=136.0, tdp_w=2 * 205.0,
            queue_delay_s=24.0, perf_scale=1.1,
            hops=hops(desktop=6, theta=9, faster=11),
        ),
        EndpointSpec(
            "faster", cores=64, idle_power_w=205.0, tdp_w=2 * 205.0,
            queue_delay_s=22.0, perf_scale=1.6,
            hops=hops(desktop=12, theta=14, ic=11),
        ),
    ]


def scaled_testbed(replicas: int) -> list[EndpointSpec]:
    """The Table-I testbed replicated ``replicas`` times into a federated
    fleet (4·replicas endpoints) for scale benchmarks.

    Replicas are deliberately *heterogeneous* — idle power, queue delay,
    and relative speed drift a few percent per generation, the way no two
    racks of a real federation are identical.  (Exact spec duplicates
    would also create exactly-tied placement scores, which different
    engines may legitimately break differently.)  Replica k of machine m
    is named ``{m}_{k}``; inter-site hop counts fall back to
    ``DEFAULT_HOPS``.
    """
    base = table1_testbed()
    if replicas <= 1:
        return base
    eps = []
    for k in range(replicas):
        for e in base:
            eps.append(dataclasses.replace(
                e,
                name=f"{e.name}_{k}",
                idle_power_w=e.idle_power_w * (1.0 + 0.03 * k),
                queue_delay_s=e.queue_delay_s * (1.0 + 0.05 * k),
                perf_scale=e.perf_scale * (1.0 + 0.02 * k),
                hops={},
            ))
    return eps


# ---------------------------------------------------------------------------
# TPU fleet endpoints (v5e constants per brief; power figures are config)
# ---------------------------------------------------------------------------

V5E_PEAK_FLOPS = 197e12
V5E_HBM_BW = 819e9
V5E_ICI_BW = 50e9
V5E_IDLE_W = 80.0
V5E_PEAK_W = 250.0


def tpu_fleet(pods: int = 2, chips_per_pod: int = 256) -> list[EndpointSpec]:
    """A heterogeneous fleet: big pods + an always-on small slice (the
    'desktop' analogue) + an older-generation pod (the 'theta' analogue)."""
    eps = []
    for i in range(pods):
        eps.append(EndpointSpec(
            f"pod{i}", cores=chips_per_pod, idle_power_w=V5E_IDLE_W * chips_per_pod,
            tdp_w=V5E_PEAK_W * chips_per_pod, queue_delay_s=120.0,
            chips=chips_per_pod, peak_flops=V5E_PEAK_FLOPS,
            hbm_bw=V5E_HBM_BW, ici_bw=V5E_ICI_BW,
            hops={f"pod{j}": 4 for j in range(pods) if j != i} | {"slice0": 6, "oldpod": 8},
        ))
    eps.append(EndpointSpec(
        "slice0", cores=16, idle_power_w=V5E_IDLE_W * 16,
        tdp_w=V5E_PEAK_W * 16, queue_delay_s=0.0, has_batch_scheduler=False,
        chips=16, peak_flops=V5E_PEAK_FLOPS, hbm_bw=V5E_HBM_BW, ici_bw=V5E_ICI_BW,
        hops={f"pod{j}": 6 for j in range(pods)} | {"oldpod": 8},
    ))
    eps.append(EndpointSpec(
        "oldpod", cores=128, idle_power_w=100.0 * 128, tdp_w=320.0 * 128,
        queue_delay_s=300.0, chips=128, peak_flops=123e12, hbm_bw=409e9,
        ici_bw=25e9, perf_scale=0.6,
        hops={f"pod{j}": 8 for j in range(pods)} | {"slice0": 8},
    ))
    return eps
