"""Composable energy monitors (paper §III-C).

The paper stacks per-device monitors (RAPL CPU, Cray HSS, NVML GPU) into a
node monitor.  The abstraction is identical here; concrete sources are the
testbed simulator (CPU container has no power rails) and the TPU-counter
model.  Monitors return instantaneous watts; the attribution pipeline
integrates.
"""
from __future__ import annotations

import abc
from typing import Sequence

import numpy as np


class EnergyMonitor(abc.ABC):
    """Reads node/device power at a point in (sim or wall) time."""

    name = "abstract"

    @abc.abstractmethod
    def read_watts(self, t: float) -> float:
        ...


class StackedMonitor(EnergyMonitor):
    """Compose arbitrary monitors: total node power = sum of devices."""

    name = "stacked"

    def __init__(self, monitors: Sequence[EnergyMonitor]):
        self.monitors = list(monitors)

    def read_watts(self, t: float) -> float:
        return sum(m.read_watts(t) for m in self.monitors)


class CallbackMonitor(EnergyMonitor):
    """Adapts any power function — the testbed sim node uses this with
    RAPL-like gaussian read noise."""

    def __init__(self, fn, name: str = "rapl", noise_frac: float = 0.01, seed: int = 0):
        self.fn = fn
        self.name = name
        self.noise = noise_frac
        self._rng = np.random.default_rng(seed)

    def read_watts(self, t: float) -> float:
        p = float(self.fn(t))
        return max(p * (1.0 + self._rng.normal(0.0, self.noise)), 0.0)

    def read_noisy(self, base: np.ndarray) -> np.ndarray:
        """Apply this monitor's read noise to a whole vector of base-power
        samples at once.  One batched draw consumes the generator exactly
        like per-sample :meth:`read_watts` calls, so seeded streams are
        reproducible either way."""
        p = base * (1.0 + self._rng.normal(0.0, self.noise, size=len(base)))
        return np.maximum(p, 0.0)


class ConstantMonitor(EnergyMonitor):
    """Idle/baseboard draw that performance counters never explain."""

    def __init__(self, watts: float, name: str = "bmc-base"):
        self.watts = watts
        self.name = name

    def read_watts(self, t: float) -> float:
        return self.watts


class TPUCounterMonitor(EnergyMonitor):
    """TPU-fleet power source: maps utilization-counter rates to watts via
    a device coefficient model (the simulator's 'ground truth'; the GreenFaaS
    pipeline re-learns its own linear fit from the stream, same as RAPL)."""

    name = "tpu"

    def __init__(self, idle_w: float, peak_w: float, util_fn):
        self.idle_w = idle_w
        self.peak_w = peak_w
        self.util_fn = util_fn  # t -> (flops_frac, hbm_frac, ici_frac)

    def read_watts(self, t: float) -> float:
        f, h, i = self.util_fn(t)
        dyn = self.peak_w - self.idle_w
        return self.idle_w + dyn * min(0.6 * f + 0.3 * h + 0.1 * i, 1.0)
