"""Planning graph over the online engine's DAG: what a policy may *see*
beyond the flat batch it is placing.

GreenFaaS's online engine resolves dependencies before a policy runs, so
until now policies scored one arrival window at a time with no knowledge
of the downstream DAG.  :class:`DAGView` closes that gap: the engine
registers every submitted task (including ones still parked in the
ready-set) and every completion, and the view derives the planning
quantities lookahead policies score with:

- **upward rank** ``up_rank(t)`` — critical-path time from ``t`` through
  its deepest descendant chain (HEFT's rank_u over fleet-mean runtimes),
  and ``up_rest(t) = up_rank(t) - rt(t)``, the critical work *below* t.
- **downward rank** ``down_rank(t)`` — longest-path time from any source
  to ``t``'s earliest possible start.
- **descendant dep-bytes mass** ``desc_bytes(t)`` — total edge payload
  reachable from ``t`` (path-weighted: a diamond's shared descendant is
  pulled once per incoming path, which is exactly how many transfers its
  parents' placements influence).
- **per-edge producer endpoints** ``producer(t)`` — where a completed
  task's output physically lives, recorded at completion time.

Ranks are recomputed lazily (one Kahn pass over the retained graph)
whenever the graph or the runtime estimates were invalidated, so engines
that never query the view pay only dict appends per submission.

**Live-state pruning.**  With ``prune=True`` (the default) the view
retires every node *at the moment it completes*, so a rank refresh costs
O(live) — the uncompleted tasks — instead of O(total-ever-submitted).
Immediate retirement is safe because a completed node can never be a
**descendant** of a live one (a child only completes after its parents),
and every live-node planning quantity reads downward: ``up_rank`` and
``out_bytes``/``desc_bytes`` walk children only, ``rank_scale`` is the
max ``up_rank`` over live nodes, and ``down_rank``/``live_depth`` are
defined over *uncompleted* parents in both modes (a completed parent's
output already exists, so it imposes no future wait).  Producer
endpoints are kept forever — transfer billing for late-arriving children
still resolves — but retired nodes no longer carry ranks or mass.
Pruning is therefore *placement-parity-safe*: :class:`LookaheadWeights`
snapshots — and every engine's assignments — are identical with pruning
on or off (``tests/test_live_state.py``).  Rank/mass queries on
*completed* nodes are the only thing pruning may change (they fall back
to 0 once the node retires).

**Failure semantics.**  A task killed by endpoint churn never calls
``complete()`` — the engine re-enters it into the pending stream instead
— so a failed task stays *live* in the view (it keeps its ranks and
mass, and its children keep waiting) until some retry actually finishes.
Retirement pruning composes with the retry path for free: only genuine
completions retire nodes, so a re-entered task is still un-retired and a
pruned view scores its re-placement identically to an unpruned one
(``tests/test_faults.py`` locks this under mid-stream churn).
Speculative ``@spec`` backups never enter the DAG at all — the engine
completes the *base* task id once a winner is known.

:class:`LookaheadWeights` is the per-placement-call snapshot the greedy
engines consume (the :class:`~repro.core.carbon.CarbonWeights` analogue):
per-task rank weights and outbound-payload energies plus per-endpoint
mean hop distances, frozen so engine run-memoization stays valid.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping, Sequence

from repro.core.transfer import E_INC_J_PER_BYTE


class DAGView:
    """Incrementally built view of everything submitted to the engine.

    ``runtime`` maps a function name to its fleet-mean predicted runtime
    in seconds (the engine wires its profile store in); rank computations
    cache one value per function per refresh.  ``add_task`` is idempotent
    per task id; edges to parents that were never registered are kept and
    become live once the parent arrives (the trace validator guarantees
    topological submission, so in practice parents always precede).

    ``prune`` controls the live-state lifecycle (see module docstring):
    ``True`` retires each node the moment it completes, so refreshes stay
    O(live); ``False`` keeps every node forever (the pre-pruning
    behaviour, used by the parity tests as the reference).
    """

    def __init__(self, runtime: Callable[[str], float] | None = None,
                 prune: bool = True):
        self._runtime = runtime or (lambda fn: 1.0)
        self._prune = prune
        self._fn: dict[str, str] = {}
        self._parents: dict[str, tuple[str, ...]] = {}
        self._children: dict[str, list[tuple[str, float]]] = {}
        self._producers: dict[str, tuple[str, float]] = {}
        self._edges = 0          # retained edges (all edges when prune=False)
        self._retired = 0        # nodes dropped from the rank graph so far
        self._retired_buf: list[str] = []   # drained by the engine (timeline GC)
        self._dirty = True
        self._up: dict[str, float] = {}
        self._down: dict[str, float] = {}
        self._mass: dict[str, float] = {}
        self._out_bytes: dict[str, float] = {}
        self._rt: dict[str, float] = {}
        self._rank_scale = 1.0
        self._live_depth = 0
        self._live_width = 0
        # rank-refresh stall accounting (the latency benchmark's metric)
        self._refreshes = 0
        self._last_refresh_s = 0.0
        self._max_refresh_s = 0.0

    # -- construction (engine side) ----------------------------------------
    def add_task(self, task) -> None:
        """Register a :class:`~repro.core.scheduler.TaskSpec` node and its
        parent edges (child pulls ``task.dep_bytes`` from *each* parent)."""
        if task.id in self._fn:
            return
        self._fn[task.id] = task.fn
        self._parents[task.id] = tuple(task.deps)
        self._children.setdefault(task.id, [])
        for p in task.deps:
            if p in self._producers and p not in self._fn:
                # parent already retired: the edge can never influence a
                # live rank (the child resolves its transfer inputs from
                # the retained producer record instead)
                continue
            self._children.setdefault(p, []).append((task.id, task.dep_bytes))
            self._edges += 1
        self._dirty = True

    def complete(self, task_id: str, endpoint: str, t_end: float) -> None:
        """Record where a finished task's output lives (producer endpoint)
        and when it materialized; with pruning on, retire the node from
        the rank graph immediately (see module docstring)."""
        self._producers[task_id] = (endpoint, t_end)
        if task_id in self._fn:
            # the live set shrank: live-only rank aggregates (rank_scale,
            # depth/width) are stale in BOTH modes — identical refresh
            # cadence is what keeps pruned/unpruned placements bitwise
            # equal (unpruned just pays the refresh over every node ever
            # submitted, which is the cost pruning exists to bound)
            self._dirty = True
            if self._prune:
                self._retire(task_id)

    def _retire(self, task_id: str) -> None:
        """Drop a just-completed node from the rank graph.  Its outgoing
        edges all point at retained (live) children, so the retained-edge
        counter drops by the child-list length; its incoming edges were
        already released when each parent retired at *its* completion —
        except edges from parents that were never registered, which the
        child releases (and unlinks) here."""
        parents = self._parents.pop(task_id, ())
        del self._fn[task_id]
        self._edges -= len(self._children.pop(task_id, ()))
        for p in parents:
            if p not in self._fn and p not in self._producers:
                kids = self._children.get(p)
                if kids:
                    self._children[p] = [e for e in kids if e[0] != task_id]
                    self._edges -= len(kids) - len(self._children[p])
        self._retired += 1
        self._retired_buf.append(task_id)

    def invalidate(self) -> None:
        """Force a rank recompute on next query (the engine calls this
        after profile updates shift the runtime estimates)."""
        self._dirty = True

    # -- queries (policy side) ---------------------------------------------
    def __len__(self) -> int:
        """Retained (rank-graph) nodes — O(live) under pruning."""
        return len(self._fn)

    def __contains__(self, task_id: str) -> bool:
        return task_id in self._fn

    @property
    def n_edges(self) -> int:
        return self._edges

    @property
    def retired(self) -> int:
        """Nodes retired from the rank graph so far (0 when prune=False)."""
        return self._retired

    def drain_retired(self) -> list[str]:
        """Task ids retired since the last drain — the engine drops their
        live-state timeline entries (scoring never reads them)."""
        out, self._retired_buf = self._retired_buf, []
        return out

    def has_edges(self) -> bool:
        return self._edges > 0

    def children(self, task_id: str) -> tuple[tuple[str, float], ...]:
        """((child id, edge bytes), ...) — the task's direct consumers."""
        return tuple(self._children.get(task_id, ()))

    def fn(self, task_id: str) -> str | None:
        """Function name of a live (retained) task, else None."""
        return self._fn.get(task_id)

    def parents(self, task_id: str) -> tuple[str, ...]:
        return self._parents.get(task_id, ())

    def producer(self, task_id: str) -> tuple[str, float] | None:
        """(endpoint, t_end) for a completed task, else None."""
        return self._producers.get(task_id)

    def up_rank(self, task_id: str) -> float:
        """Critical-path seconds from this task to its deepest descendant,
        including the task's own fleet-mean runtime (HEFT rank_u)."""
        self._refresh()
        return self._up.get(task_id, 0.0)

    def up_rest(self, task_id: str) -> float:
        """Critical-path seconds strictly *below* this task — 0 for sinks."""
        self._refresh()
        up = self._up.get(task_id)
        if up is None:
            return 0.0
        return up - self._rt[self._fn[task_id]]

    def down_rank(self, task_id: str) -> float:
        """Longest-path seconds of *remaining upstream work* before this
        task can start: the max over uncompleted parents of their
        ``down_rank + runtime`` (a completed parent's output already
        exists, so it contributes no future wait — and, equivalently, the
        value is identical with pruning on or off)."""
        self._refresh()
        return self._down.get(task_id, 0.0)

    def desc_bytes(self, task_id: str) -> float:
        """Path-weighted dep-bytes mass of the task's descendant subgraph:
        ``sum over child edges (edge bytes + desc_bytes(child))``."""
        self._refresh()
        return self._mass.get(task_id, 0.0)

    def out_bytes(self, task_id: str) -> float:
        """Bytes the task's direct children will pull from wherever this
        task lands — the data-gravity payload."""
        self._refresh()
        return self._out_bytes.get(task_id, 0.0)

    @property
    def rank_scale(self) -> float:
        """max up_rank over the *live* (uncompleted) nodes; rank weights
        are normalized by it so the lookahead term stays O(makespan).
        Restricting the max to live nodes keeps the normalizer identical
        with pruning on or off — completed roots would otherwise pin it
        to the campaign-wide max in one mode only."""
        self._refresh()
        return self._rank_scale

    @property
    def live_depth(self) -> int:
        """Longest live chain, in nodes (0 when nothing is live)."""
        self._refresh()
        return self._live_depth

    @property
    def live_width(self) -> int:
        """Widest live level (max antichain by depth level; 0 when empty)."""
        self._refresh()
        return self._live_width

    def refresh_stats(self) -> dict[str, float]:
        """Rank-refresh stall accounting: number of refreshes plus the
        last/worst wall-clock seconds one cost — the latency benchmark's
        "max rank-refresh stall" comes from ``max_s``."""
        return {
            "refreshes": float(self._refreshes),
            "last_s": self._last_refresh_s,
            "max_s": self._max_refresh_s,
        }

    # -- one-pass recompute -------------------------------------------------
    def _refresh(self) -> None:
        if not self._dirty:
            return
        t0 = time.perf_counter()
        fns = self._fn
        rt = {fn: float(self._runtime(fn)) for fn in set(fns.values())}
        # Kahn topological order over the retained nodes (edges to unknown
        # or retired parents are ignored)
        indeg = {
            tid: sum(1 for p in self._parents[tid] if p in fns)
            for tid in fns
        }
        order = [tid for tid, d in indeg.items() if d == 0]
        head = 0
        while head < len(order):
            tid = order[head]
            head += 1
            for child, _ in self._children.get(tid, ()):  # noqa: B007
                if child in indeg:
                    indeg[child] -= 1
                    if indeg[child] == 0:
                        order.append(child)
        # a cycle leaves its members out of `order`; they simply get no
        # ranks (downstream .get() defaults apply) — the engine's drain
        # deadlock check is where cycles actually get diagnosed
        up: dict[str, float] = {}
        mass: dict[str, float] = {}
        out_b: dict[str, float] = {}
        for tid in reversed(order):
            best = 0.0
            m = 0.0
            ob = 0.0
            for child, nbytes in self._children.get(tid, ()):
                cu = up.get(child)
                if cu is not None and cu > best:
                    best = cu
                m += nbytes + mass.get(child, 0.0)
                ob += nbytes
            up[tid] = rt[fns[tid]] + best
            mass[tid] = m
            out_b[tid] = ob
        down: dict[str, float] = {}
        producers = self._producers
        # live structure: depth levels over uncompleted nodes only (a
        # completed parent contributes level 0 — its children are live
        # roots), plus the widest level.  Identical with pruning on or
        # off: live nodes and live-live edges are the same set.
        level: dict[str, int] = {}
        width_at: dict[int, int] = {}
        depth = 0
        scale = 0.0
        for tid in order:
            best = 0.0
            for p in self._parents[tid]:
                # uncompleted parents only: completed upstream work waits
                # for nothing, and pruning may already have dropped it
                if p in fns and p not in producers:
                    d = down[p] + rt[fns[p]]
                    if d > best:
                        best = d
            down[tid] = best
            if tid not in producers:
                lvl = 1
                for p in self._parents[tid]:
                    pl = level.get(p)
                    if pl is not None and pl + 1 > lvl:
                        lvl = pl + 1
                level[tid] = lvl
                width_at[lvl] = width_at.get(lvl, 0) + 1
                if lvl > depth:
                    depth = lvl
                u = up[tid]
                if u > scale:
                    scale = u
        self._up, self._down, self._mass, self._out_bytes = up, down, mass, out_b
        self._rt = rt
        self._rank_scale = max(scale if level else 1.0, 1e-9)
        self._live_depth = depth
        self._live_width = max(width_at.values(), default=0)
        self._dirty = False
        dt = time.perf_counter() - t0
        self._refreshes += 1
        self._last_refresh_s = dt
        if dt > self._max_refresh_s:
            self._max_refresh_s = dt


def structure_scale(depth: int, width: int) -> float:
    """Lookahead steering strength warranted by the live planning graph:
    ``min(1, (depth-1)/2) * min(1, width/2)``.

    A 2-node chain (depth 2, width 1) gets 0.25 — there is almost no
    downstream structure to steer for, and full-strength ``lam`` was
    measured to over-steer such batches (PR 5 follow-on).  Any graph at
    least 3 levels deep and 2 wide (a diamond, every paper workload)
    scales by exactly 1.0, so headline placements are unchanged."""
    if depth <= 1:
        return 0.0
    d = (depth - 1) / 2.0
    w = width / 2.0
    return min(1.0, d) * min(1.0, w)


@dataclasses.dataclass(frozen=True)
class LookaheadWeights:
    """One placement call's lookahead view, frozen like ``CarbonWeights``.

    ``tail_w`` maps task id -> normalized downstream criticality
    (``up_rest / rank_scale``, 0 for sinks); ``out_j`` maps task id ->
    the joules-per-hop cost of shipping its outputs to its children
    (``out_bytes * E_INC_J_PER_BYTE``); ``hops_mean`` is the fleet-mean
    hop distance *from* each endpoint (engine endpoint order) — the
    expected per-byte escape cost of parking data there.  ``lam`` scales
    the whole lookahead term; the greedy engines add

        lam * ( alpha * (out_j_sum * hops_mean[e]) / SF1
                + (1 - alpha) * sum_t tail_w[t] * end_t / SF2 )

    to every candidate score, so critical tasks chase early finishes and
    heavy producers park their outputs where children can pull cheaply.

    ``hops_task`` (producer-aware mode) maps a producer task id to a
    per-endpoint hop vector: the *byte-weighted* hop distance from each
    candidate endpoint to the **predicted endpoints of that task's
    children** (argmin-energy per child function), replacing the fleet
    mean in the gravity term for exactly those tasks.  ``None`` (the
    default) leaves every engine's float sequence bitwise-identical to
    the fleet-mean build.
    """

    tail_w: Mapping[str, float]
    out_j: Mapping[str, float]
    hops_mean: tuple[float, ...]
    lam: float = 1.0
    hops_task: Mapping[str, tuple[float, ...]] | None = None

    def __post_init__(self) -> None:
        if self.lam < 0:
            raise ValueError(f"lam must be non-negative, got {self.lam}")

    @classmethod
    def from_dag(
        cls,
        dag: DAGView,
        tasks: Sequence,
        endpoints: Sequence,
        transfer,
        lam: float = 1.0,
        store=None,
        producer_aware: bool = False,
    ) -> "LookaheadWeights | None":
        """Snapshot the lookahead terms for one batch; returns ``None``
        when no task in the batch has downstream structure (every weight
        zero), so the caller can fall back to the bit-identical myopic
        path.

        The effective ``lam`` is scaled by :func:`structure_scale` of the
        live graph's depth/width, so near-structureless DAGs (a 2-node
        chain) are steered proportionally less — full-strength shaping on
        a tiny graph was measured to over-steer placements.  The scale is
        1.0 for every graph at least 3 levels deep and 2 wide.

        With ``producer_aware=True`` (and a profile ``store``), each
        batch task with registered children also gets a ``hops_task``
        vector: instead of pricing its outputs' escape cost at the fleet
        *mean* hop distance, every child edge's bytes are weighted by the
        hop distance to the child's **predicted** endpoint — the
        argmin-energy endpoint for the child's function under the current
        profiles (first index on ties, cached per function).  Tasks
        without registered children keep the fleet-mean vector (their
        gravity weight is zero anyway)."""
        if not dag.has_edges():
            return None
        sscale = structure_scale(dag.live_depth, dag.live_width)
        if sscale == 0.0 or lam == 0.0:
            return None
        scale = dag.rank_scale
        tail_w: dict[str, float] = {}
        out_j: dict[str, float] = {}
        any_weight = False
        for t in tasks:
            tw = dag.up_rest(t.id) / scale if t.id in dag else 0.0
            oj = dag.out_bytes(t.id) * E_INC_J_PER_BYTE if t.id in dag else 0.0
            tail_w[t.id] = tw
            out_j[t.id] = oj
            if tw > 0.0 or oj > 0.0:
                any_weight = True
        if not any_weight:
            return None
        names = [e.name for e in endpoints]
        hm = []
        for a in names:
            others = [transfer.hops(a, b) for b in names if b != a]
            hm.append(sum(others) / len(others) if others else 0.0)
        hops_task = None
        if producer_aware and store is not None:
            pred_i: dict[str, int] = {}

            def _child_ep(fn: str) -> int:
                i = pred_i.get(fn)
                if i is None:
                    best = None
                    i = 0
                    for j, nm in enumerate(names):
                        e_j = store.predict(fn, nm).energy_j
                        if best is None or e_j < best:   # first-index ties
                            best, i = e_j, j
                    pred_i[fn] = i
                return i

            ht: dict[str, tuple[float, ...]] = {}
            for t in tasks:
                if t.id not in dag:
                    continue
                ob = 0.0
                acc = [0.0] * len(names)
                for child, nbytes in dag.children(t.id):
                    cfn = dag.fn(child)
                    if cfn is None or nbytes <= 0.0:
                        continue
                    dst = names[_child_ep(cfn)]
                    for ai, a in enumerate(names):
                        acc[ai] += nbytes * transfer.hops(a, dst)
                    ob += nbytes
                if ob > 0.0:
                    ht[t.id] = tuple(v / ob for v in acc)
            hops_task = ht or None
        return cls(tail_w, out_j, tuple(hm), lam * sscale, hops_task)
