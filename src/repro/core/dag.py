"""Planning graph over the online engine's DAG: what a policy may *see*
beyond the flat batch it is placing.

GreenFaaS's online engine resolves dependencies before a policy runs, so
until now policies scored one arrival window at a time with no knowledge
of the downstream DAG.  :class:`DAGView` closes that gap: the engine
registers every submitted task (including ones still parked in the
ready-set) and every completion, and the view derives the planning
quantities lookahead policies score with:

- **upward rank** ``up_rank(t)`` — critical-path time from ``t`` through
  its deepest descendant chain (HEFT's rank_u over fleet-mean runtimes),
  and ``up_rest(t) = up_rank(t) - rt(t)``, the critical work *below* t.
- **downward rank** ``down_rank(t)`` — longest-path time from any source
  to ``t``'s earliest possible start.
- **descendant dep-bytes mass** ``desc_bytes(t)`` — total edge payload
  reachable from ``t`` (path-weighted: a diamond's shared descendant is
  pulled once per incoming path, which is exactly how many transfers its
  parents' placements influence).
- **per-edge producer endpoints** ``producer(t)`` — where a completed
  task's output physically lives, recorded at completion time.

Ranks are recomputed lazily (one Kahn pass over the known graph) whenever
the graph or the runtime estimates were invalidated, so engines that
never query the view pay only dict appends per submission.

:class:`LookaheadWeights` is the per-placement-call snapshot the greedy
engines consume (the :class:`~repro.core.carbon.CarbonWeights` analogue):
per-task rank weights and outbound-payload energies plus per-endpoint
mean hop distances, frozen so engine run-memoization stays valid.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from repro.core.transfer import E_INC_J_PER_BYTE


class DAGView:
    """Incrementally built view of everything submitted to the engine.

    ``runtime`` maps a function name to its fleet-mean predicted runtime
    in seconds (the engine wires its profile store in); rank computations
    cache one value per function per refresh.  ``add_task`` is idempotent
    per task id; edges to parents that were never registered are kept and
    become live once the parent arrives (the trace validator guarantees
    topological submission, so in practice parents always precede).

    Completed tasks stay in the graph (their producer endpoints remain
    queryable and ``rank_scale`` keeps the campaign-wide normalizer
    stable), so a rank refresh is O(total submitted); pruning finished
    subgraphs for very long streaming campaigns is a ROADMAP follow-on.
    """

    def __init__(self, runtime: Callable[[str], float] | None = None):
        self._runtime = runtime or (lambda fn: 1.0)
        self._fn: dict[str, str] = {}
        self._parents: dict[str, tuple[str, ...]] = {}
        self._children: dict[str, list[tuple[str, float]]] = {}
        self._producers: dict[str, tuple[str, float]] = {}
        self._edges = 0
        self._dirty = True
        self._up: dict[str, float] = {}
        self._down: dict[str, float] = {}
        self._mass: dict[str, float] = {}
        self._out_bytes: dict[str, float] = {}
        self._rt: dict[str, float] = {}
        self._rank_scale = 1.0

    # -- construction (engine side) ----------------------------------------
    def add_task(self, task) -> None:
        """Register a :class:`~repro.core.scheduler.TaskSpec` node and its
        parent edges (child pulls ``task.dep_bytes`` from *each* parent)."""
        if task.id in self._fn:
            return
        self._fn[task.id] = task.fn
        self._parents[task.id] = tuple(task.deps)
        self._children.setdefault(task.id, [])
        for p in task.deps:
            self._children.setdefault(p, []).append((task.id, task.dep_bytes))
            self._edges += 1
        self._dirty = True

    def complete(self, task_id: str, endpoint: str, t_end: float) -> None:
        """Record where a finished task's output lives (producer endpoint)
        and when it materialized."""
        self._producers[task_id] = (endpoint, t_end)

    def invalidate(self) -> None:
        """Force a rank recompute on next query (the engine calls this
        after profile updates shift the runtime estimates)."""
        self._dirty = True

    # -- queries (policy side) ---------------------------------------------
    def __len__(self) -> int:
        return len(self._fn)

    def __contains__(self, task_id: str) -> bool:
        return task_id in self._fn

    @property
    def n_edges(self) -> int:
        return self._edges

    def has_edges(self) -> bool:
        return self._edges > 0

    def children(self, task_id: str) -> tuple[tuple[str, float], ...]:
        """((child id, edge bytes), ...) — the task's direct consumers."""
        return tuple(self._children.get(task_id, ()))

    def parents(self, task_id: str) -> tuple[str, ...]:
        return self._parents.get(task_id, ())

    def producer(self, task_id: str) -> tuple[str, float] | None:
        """(endpoint, t_end) for a completed task, else None."""
        return self._producers.get(task_id)

    def up_rank(self, task_id: str) -> float:
        """Critical-path seconds from this task to its deepest descendant,
        including the task's own fleet-mean runtime (HEFT rank_u)."""
        self._refresh()
        return self._up.get(task_id, 0.0)

    def up_rest(self, task_id: str) -> float:
        """Critical-path seconds strictly *below* this task — 0 for sinks."""
        self._refresh()
        up = self._up.get(task_id)
        if up is None:
            return 0.0
        return up - self._rt[self._fn[task_id]]

    def down_rank(self, task_id: str) -> float:
        """Longest-path seconds from any source to this task's start."""
        self._refresh()
        return self._down.get(task_id, 0.0)

    def desc_bytes(self, task_id: str) -> float:
        """Path-weighted dep-bytes mass of the task's descendant subgraph:
        ``sum over child edges (edge bytes + desc_bytes(child))``."""
        self._refresh()
        return self._mass.get(task_id, 0.0)

    def out_bytes(self, task_id: str) -> float:
        """Bytes the task's direct children will pull from wherever this
        task lands — the data-gravity payload."""
        self._refresh()
        return self._out_bytes.get(task_id, 0.0)

    @property
    def rank_scale(self) -> float:
        """max up_rank over the graph (>= its longest chain); rank weights
        are normalized by it so the lookahead term stays O(makespan)."""
        self._refresh()
        return self._rank_scale

    # -- one-pass recompute -------------------------------------------------
    def _refresh(self) -> None:
        if not self._dirty:
            return
        fns = self._fn
        rt = {fn: float(self._runtime(fn)) for fn in set(fns.values())}
        # Kahn topological order over the known nodes (edges to unknown
        # parents are ignored until the parent is registered)
        indeg = {
            tid: sum(1 for p in self._parents[tid] if p in fns)
            for tid in fns
        }
        order = [tid for tid, d in indeg.items() if d == 0]
        head = 0
        while head < len(order):
            tid = order[head]
            head += 1
            for child, _ in self._children.get(tid, ()):  # noqa: B007
                indeg[child] -= 1
                if indeg[child] == 0:
                    order.append(child)
        # a cycle leaves its members out of `order`; they simply get no
        # ranks (downstream .get() defaults apply) — the engine's drain
        # deadlock check is where cycles actually get diagnosed
        up: dict[str, float] = {}
        mass: dict[str, float] = {}
        out_b: dict[str, float] = {}
        for tid in reversed(order):
            best = 0.0
            m = 0.0
            ob = 0.0
            for child, nbytes in self._children.get(tid, ()):
                cu = up.get(child)
                if cu is not None and cu > best:
                    best = cu
                m += nbytes + mass.get(child, 0.0)
                ob += nbytes
            up[tid] = rt[fns[tid]] + best
            mass[tid] = m
            out_b[tid] = ob
        down: dict[str, float] = {}
        for tid in order:
            best = 0.0
            for p in self._parents[tid]:
                if p in fns:
                    d = down[p] + rt[fns[p]]
                    if d > best:
                        best = d
            down[tid] = best
        self._up, self._down, self._mass, self._out_bytes = up, down, mass, out_b
        self._rt = rt
        self._rank_scale = max(max(up.values(), default=1.0), 1e-9)
        self._dirty = False


@dataclasses.dataclass(frozen=True)
class LookaheadWeights:
    """One placement call's lookahead view, frozen like ``CarbonWeights``.

    ``tail_w`` maps task id -> normalized downstream criticality
    (``up_rest / rank_scale``, 0 for sinks); ``out_j`` maps task id ->
    the joules-per-hop cost of shipping its outputs to its children
    (``out_bytes * E_INC_J_PER_BYTE``); ``hops_mean`` is the fleet-mean
    hop distance *from* each endpoint (engine endpoint order) — the
    expected per-byte escape cost of parking data there.  ``lam`` scales
    the whole lookahead term; the greedy engines add

        lam * ( alpha * (out_j_sum * hops_mean[e]) / SF1
                + (1 - alpha) * sum_t tail_w[t] * end_t / SF2 )

    to every candidate score, so critical tasks chase early finishes and
    heavy producers park their outputs where children can pull cheaply.
    """

    tail_w: Mapping[str, float]
    out_j: Mapping[str, float]
    hops_mean: tuple[float, ...]
    lam: float = 1.0

    def __post_init__(self) -> None:
        if self.lam < 0:
            raise ValueError(f"lam must be non-negative, got {self.lam}")

    @classmethod
    def from_dag(
        cls,
        dag: DAGView,
        tasks: Sequence,
        endpoints: Sequence,
        transfer,
        lam: float = 1.0,
    ) -> "LookaheadWeights | None":
        """Snapshot the lookahead terms for one batch; returns ``None``
        when no task in the batch has downstream structure (every weight
        zero), so the caller can fall back to the bit-identical myopic
        path."""
        if not dag.has_edges():
            return None
        scale = dag.rank_scale
        tail_w: dict[str, float] = {}
        out_j: dict[str, float] = {}
        any_weight = False
        for t in tasks:
            tw = dag.up_rest(t.id) / scale if t.id in dag else 0.0
            oj = dag.out_bytes(t.id) * E_INC_J_PER_BYTE if t.id in dag else 0.0
            tail_w[t.id] = tw
            out_j[t.id] = oj
            if tw > 0.0 or oj > 0.0:
                any_weight = True
        if not any_weight:
            return None
        names = [e.name for e in endpoints]
        hm = []
        for a in names:
            others = [transfer.hops(a, b) for b in names if b != a]
            hm.append(sum(others) / len(others) if others else 0.0)
        return cls(tail_w, out_j, tuple(hm), lam)
