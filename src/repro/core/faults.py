"""Fault model for the online engine and testbed: endpoint churn
(fail/recover and join/leave), straggler runtime inflation, and the
warm-pool scoring weights threaded into the MHRA objective.

A :class:`FaultTrace` is a *seeded, immutable script* of fleet
misbehavior, shared by the simulator (which kills in-flight tasks and
inflates straggler runtimes) and the engine (which masks dead endpoints
from candidate scoring when ``fault_aware``).  Both sides read the same
trace, so detection is deterministic and reproducible.

Design constraints inherited from the parity-locked schedulers:

* An **empty trace is a bitwise no-op** on every path.  Straggler draws
  come from a crc32 hash of ``(seed, task_id)`` — never from the
  testbed's noise RNG — so adding faults cannot perturb the existing
  per-task noise stream.
* Down intervals are half-open ``[d0, d1)`` seconds, sorted and
  non-overlapping per endpoint.  Elastic join/leave is expressed in the
  same vocabulary: an endpoint joining at ``t_j`` is down over
  ``[0, t_j)``; one leaving at ``t_l`` is down over ``[t_l, inf)``.
* :class:`WarmWeights` is a frozen per-placement-call snapshot (like
  ``CarbonWeights``/``LookaheadWeights``), so the SoA run-memoization
  key does not need to change: the weights are constant for the whole
  greedy call.

Units: seconds and joules throughout.
"""
from __future__ import annotations

import bisect
import dataclasses
import zlib
from typing import Mapping, Sequence

INF = float("inf")


def _hash_unit(seed: int, key: str) -> float:
    """Deterministic uniform draw in [0, 1) from (seed, key) — independent
    of every RNG stream in the simulator."""
    return zlib.crc32(f"{seed}:{key}".encode()) / 2 ** 32


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """Seeded script of endpoint down intervals + straggler faults.

    ``down`` maps endpoint name -> sorted non-overlapping half-open
    ``[d0, d1)`` intervals (seconds) during which the endpoint is dead:
    tasks overlapping a down interval are killed at the interval start
    (partial energy billed), and a fault-aware engine masks the endpoint
    from candidate scoring while it is down.  Endpoints absent from the
    mapping are always up.

    ``straggler_p`` / ``straggler_factor``: each task straggles with
    probability ``straggler_p`` (hash-drawn from ``(seed, task_id)``),
    multiplying its true runtime by ``straggler_factor``.
    """

    down: Mapping[str, tuple[tuple[float, float], ...]] = dataclasses.field(
        default_factory=dict
    )
    straggler_p: float = 0.0
    straggler_factor: float = 3.0
    seed: int = 0

    def __post_init__(self):
        norm = {}
        for name, ivs in dict(self.down).items():
            ivs = tuple(sorted((float(a), float(b)) for a, b in ivs))
            prev_end = -INF
            for a, b in ivs:
                if not a < b:
                    raise ValueError(
                        f"down interval for {name!r} must have d0 < d1, "
                        f"got [{a}, {b})"
                    )
                if a < prev_end:
                    raise ValueError(
                        f"down intervals for {name!r} overlap at [{a}, {b})"
                    )
                prev_end = b
            if ivs:
                norm[name] = ivs
        object.__setattr__(self, "down", norm)
        if not 0.0 <= self.straggler_p <= 1.0:
            raise ValueError(
                f"straggler_p must be in [0, 1], got {self.straggler_p}"
            )
        if self.straggler_factor < 1.0:
            raise ValueError(
                f"straggler_factor must be >= 1, got {self.straggler_factor}"
            )
        # bisect keys: per-endpoint interval start times
        object.__setattr__(
            self, "_starts", {n: [a for a, _ in ivs] for n, ivs in norm.items()}
        )

    @classmethod
    def empty(cls) -> "FaultTrace":
        return cls()

    def __bool__(self) -> bool:
        return bool(self.down) or self.straggler_p > 0.0

    # -- churn queries ------------------------------------------------------
    def is_up(self, name: str, t: float) -> bool:
        """Is ``name`` up at time ``t``? (half-open: up at exactly d1)."""
        ivs = self.down.get(name)
        if not ivs:
            return True
        i = bisect.bisect_right(self._starts[name], t) - 1
        return i < 0 or t >= ivs[i][1]

    def down_overlap(
        self, name: str, start: float, end: float
    ) -> tuple[float, float] | None:
        """First down interval overlapping ``[start, end)``, or None.
        A task spanning the returned interval dies at
        ``max(start, d0)``."""
        ivs = self.down.get(name)
        if not ivs:
            return None
        # candidate: the interval containing `start`, else the next one
        i = max(bisect.bisect_right(self._starts[name], start) - 1, 0)
        for a, b in ivs[i:]:
            if a >= end:
                return None
            if b > start:
                return (a, b)
        return None

    def next_up(self, name: str, t: float) -> float:
        """Earliest time >= ``t`` at which ``name`` is up (``t`` itself if
        already up; ``inf`` if it left the fleet for good)."""
        ivs = self.down.get(name)
        if not ivs:
            return t
        i = bisect.bisect_right(self._starts[name], t) - 1
        up = t
        for a, b in ivs[max(i, 0):]:
            if a <= up < b:
                up = b
            elif a > up:
                break
        return up

    # -- straggler draws ----------------------------------------------------
    def straggle_factor(self, task_id: str) -> float:
        """Runtime multiplier for ``task_id``: ``straggler_factor`` with
        probability ``straggler_p``, else 1.0.  Pure hash of
        ``(seed, task_id)`` — the same task straggles (or not)
        identically across runs, engines, and retries."""
        if self.straggler_p <= 0.0:
            return 1.0
        if _hash_unit(self.seed, task_id) < self.straggler_p:
            return self.straggler_factor
        return 1.0


@dataclasses.dataclass(frozen=True)
class WarmWeights:
    """Per-endpoint expected cold-start penalty added to every candidate
    score for the duration of one greedy call (frozen snapshot, like
    ``CarbonWeights``): ``cold_j[i]`` joules of expected startup energy
    and ``cold_s[i]`` seconds of expected cold-start latency for placing
    the next task on endpoint ``i``.  The scheduler folds these into the
    objective as ``alpha * cold_j/SF1 + (1-alpha) * cold_s/SF2`` — one
    extra vector register on the SoA path.  All-zero weights are never
    constructed (:meth:`from_state` returns None instead) so the default
    fleet stays on the unmodified hot path.
    """

    cold_j: tuple[float, ...]
    cold_s: tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "cold_j", tuple(float(x) for x in self.cold_j))
        object.__setattr__(self, "cold_s", tuple(float(x) for x in self.cold_s))
        if len(self.cold_j) != len(self.cold_s):
            raise ValueError(
                f"cold_j/cold_s length mismatch: "
                f"{len(self.cold_j)} vs {len(self.cold_s)}"
            )

    @classmethod
    def from_state(
        cls,
        endpoints: Sequence,
        state,
        now: float,
        faults: FaultTrace | None = None,
    ) -> "WarmWeights | None":
        """Snapshot expected cold-start penalties from the live scheduling
        state *before* ``advance_to(now)`` erases idle-gap information.

        A worker slot is cold if its endpoint was never used, if it has
        been idle past the endpoint's keep-alive, or if the endpoint went
        down since the slot last ran (the fault killed its warm workers).
        The expected penalty is ``cold_fraction * cold_start_{j,s}``.
        Returns None when every penalty is zero (default endpoints have no
        cold-start cost) so callers keep the bitwise-unchanged hot path.
        """
        cold_j, cold_s = [], []
        any_nonzero = False
        for ei, ep in enumerate(endpoints):
            if ep.cold_start_j == 0.0 and ep.cold_start_s == 0.0:
                cold_j.append(0.0)
                cold_s.append(0.0)
                continue
            if hasattr(state, "slots"):          # heap-backed SchedulerState
                slots = state.slots[ep.name]
                never_used = state.first_start[ep.name] is None
            else:                                # SoAState
                slots = state.slot_view(ei).tolist()
                never_used = float(state.first[ei]) == INF
            n_cold = 0
            for f in slots:
                if never_used:
                    n_cold += 1
                elif now - f > ep.keepalive_s:
                    n_cold += 1
                elif faults is not None and f < now \
                        and faults.down_overlap(ep.name, f, now) is not None:
                    n_cold += 1
            frac = n_cold / max(len(slots), 1)
            cj = frac * ep.cold_start_j
            cs = frac * ep.cold_start_s
            cold_j.append(cj)
            cold_s.append(cs)
            if cj != 0.0 or cs != 0.0:
                any_nonzero = True
        if not any_nonzero:
            return None
        return cls(cold_j=tuple(cold_j), cold_s=tuple(cold_s))
