"""Energy report — the web-bookmarklet analogue (§III-G).

Renders per-endpoint / per-user energy usage from the TaskDB as HTML (the
bookmarklet injected the same numbers into the Globus web app) and as a
terminal table.
"""
from __future__ import annotations

import pathlib

from repro.core.database import TaskDB


def text_report(db: TaskDB, user: str | None = None) -> str:
    lines = ["GreenFaaS energy report", "=" * 48]
    by_ep = db.energy_by_endpoint()
    node = db.node_energy_by_endpoint()
    lines.append(f"{'endpoint':<12}{'tasks kJ':>12}{'node kJ':>12}")
    for ep in sorted(by_ep):
        lines.append(
            f"{ep:<12}{by_ep[ep] / 1e3:>12.2f}{node.get(ep, 0.0) / 1e3:>12.2f}"
        )
    if user:
        lines.append(f"\nuser {user}:")
        for ep, e in sorted(db.energy_by_user(user).items()):
            lines.append(f"  {ep:<12}{e / 1e3:>10.2f} kJ")
    lines.append("\nper-function mean attributed J (by endpoint):")
    for fn, eps in sorted(db.by_function().items()):
        row = "  ".join(f"{ep}={e:.1f}" for ep, e in sorted(eps.items()))
        lines.append(f"  {fn:<20}{row}")
    return "\n".join(lines)


def html_report(db: TaskDB, path: str, user: str | None = None) -> str:
    by_ep = db.energy_by_endpoint()
    node = db.node_energy_by_endpoint()
    rows = "".join(
        f"<tr><td>{ep}</td><td>{by_ep[ep]/1e3:.2f}</td>"
        f"<td>{node.get(ep, 0.0)/1e3:.2f}</td></tr>"
        for ep in sorted(by_ep)
    )
    fn_rows = "".join(
        f"<tr><td>{fn}</td>" + "".join(
            f"<td>{e:.1f}</td>" for _, e in sorted(eps.items())
        ) + "</tr>"
        for fn, eps in sorted(db.by_function().items())
    )
    html = f"""<!doctype html><html><head><title>GreenFaaS energy</title>
<style>body{{font-family:sans-serif}}table{{border-collapse:collapse}}
td,th{{border:1px solid #999;padding:4px 10px}}</style></head><body>
<h2>GreenFaaS endpoint energy usage</h2>
<table><tr><th>endpoint</th><th>task energy (kJ)</th><th>node energy (kJ)</th></tr>
{rows}</table>
<h3>mean attributed energy per function (J)</h3>
<table>{fn_rows}</table>
</body></html>"""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(html)
    return html
