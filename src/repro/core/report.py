"""Energy report — the web-bookmarklet analogue (§III-G) plus the
evaluation-harness rendering layer.

Two report families share this module:

- **TaskDB reports** (:func:`text_report` / :func:`html_report`): per
  endpoint / user / function energy from attributed task records, now
  with EDP (energy-delay product, kJ*s: node energy x busy span) beside
  every kJ column.  All endpoint/user/function names are HTML-escaped in
  the HTML rendering — they come from user-controlled task submissions.
- **Evaluation reports** (:func:`eval_text_report` /
  :func:`eval_html_report` / :func:`write_bench_json`): the
  policy-comparison tables produced by :mod:`repro.core.evaluate`
  (EDP + Greenup/Speedup/Powerup per policy), persisted to
  ``BENCH_eval.json`` for CI artifacts and trend tracking.

Units: the DB stores joules and seconds; reports print kJ, s, and kJ*s.
"""
from __future__ import annotations

import html as _html
import json
import pathlib

from repro.core.database import TaskDB


def _edp_by_endpoint(db: TaskDB) -> dict[str, float]:
    """Per-endpoint EDP in J*s: node energy x (last end - first start)."""
    node = db.node_energy_by_endpoint()
    spans = db.span_by_endpoint()
    return {
        ep: node.get(ep, 0.0) * max(t1 - t0, 0.0)
        for ep, (t0, t1) in spans.items()
    }


def summary_metrics(db: TaskDB) -> dict[str, float]:
    """Headline numbers for a DB of attributed records: total attributed
    task energy (J), total node energy (J), makespan (s), and the EDPs
    (J*s) both energy totals imply."""
    task_j = sum(db.energy_by_endpoint().values())
    node_j = sum(db.node_energy_by_endpoint().values())
    makespan = db.makespan()
    return {
        "task_energy_j": task_j,
        "node_energy_j": node_j,
        "makespan_s": makespan,
        "task_edp_js": task_j * makespan,
        "node_edp_js": node_j * makespan,
    }


def text_report(db: TaskDB, user: str | None = None) -> str:
    lines = ["GreenFaaS energy report", "=" * 60]
    by_ep = db.energy_by_endpoint()
    node = db.node_energy_by_endpoint()
    edp = _edp_by_endpoint(db)
    lines.append(
        f"{'endpoint':<12}{'tasks kJ':>12}{'node kJ':>12}{'EDP kJ*s':>12}"
    )
    for ep in sorted(by_ep):
        lines.append(
            f"{ep:<12}{by_ep[ep] / 1e3:>12.2f}{node.get(ep, 0.0) / 1e3:>12.2f}"
            f"{edp.get(ep, 0.0) / 1e3:>12.1f}"
        )
    m = summary_metrics(db)
    lines.append(
        f"{'total':<12}{m['task_energy_j'] / 1e3:>12.2f}"
        f"{m['node_energy_j'] / 1e3:>12.2f}{m['node_edp_js'] / 1e3:>12.1f}"
    )
    lines.append(f"makespan: {m['makespan_s']:.1f} s")
    if user:
        lines.append(f"\nuser {user}:")
        for ep, e in sorted(db.energy_by_user(user).items()):
            lines.append(f"  {ep:<12}{e / 1e3:>10.2f} kJ")
    lines.append("\nper-function mean attributed J (by endpoint):")
    for fn, eps in sorted(db.by_function().items()):
        row = "  ".join(f"{ep}={e:.1f}" for ep, e in sorted(eps.items()))
        lines.append(f"  {fn:<20}{row}")
    return "\n".join(lines)


def html_report(db: TaskDB, path: str, user: str | None = None) -> str:
    esc = _html.escape
    by_ep = db.energy_by_endpoint()
    node = db.node_energy_by_endpoint()
    edp = _edp_by_endpoint(db)
    rows = "".join(
        f"<tr><td>{esc(ep)}</td><td>{by_ep[ep]/1e3:.2f}</td>"
        f"<td>{node.get(ep, 0.0)/1e3:.2f}</td>"
        f"<td>{edp.get(ep, 0.0)/1e3:.1f}</td></tr>"
        for ep in sorted(by_ep)
    )
    m = summary_metrics(db)
    rows += (
        f"<tr><th>total</th><th>{m['task_energy_j']/1e3:.2f}</th>"
        f"<th>{m['node_energy_j']/1e3:.2f}</th>"
        f"<th>{m['node_edp_js']/1e3:.1f}</th></tr>"
    )
    user_block = ""
    if user:
        user_rows = "".join(
            f"<tr><td>{esc(ep)}</td><td>{e/1e3:.2f}</td></tr>"
            for ep, e in sorted(db.energy_by_user(user).items())
        )
        user_block = (
            f"<h3>user {esc(user)}</h3>"
            f"<table><tr><th>endpoint</th><th>kJ</th></tr>{user_rows}</table>"
        )
    fn_rows = "".join(
        f"<tr><td>{esc(fn)}</td>" + "".join(
            f"<td>{esc(ep)}={e:.1f}</td>" for ep, e in sorted(eps.items())
        ) + "</tr>"
        for fn, eps in sorted(db.by_function().items())
    )
    html = f"""<!doctype html><html><head><title>GreenFaaS energy</title>
<style>body{{font-family:sans-serif}}table{{border-collapse:collapse}}
td,th{{border:1px solid #999;padding:4px 10px}}</style></head><body>
<h2>GreenFaaS endpoint energy usage</h2>
<p>makespan: {m['makespan_s']:.1f} s &middot; EDP = node energy &times; busy span</p>
<table><tr><th>endpoint</th><th>task energy (kJ)</th><th>node energy (kJ)</th><th>EDP (kJ&middot;s)</th></tr>
{rows}</table>
{user_block}
<h3>mean attributed energy per function (J)</h3>
<table>{fn_rows}</table>
</body></html>"""
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(html)
    return html


# ---------------------------------------------------------------------------
# Evaluation-harness rendering (repro.core.evaluate results)
# ---------------------------------------------------------------------------

_EVAL_COLS = (
    ("policy", "{policy:<16}", "<16"),
    ("energy kJ", "{energy_kj:>11.1f}", ">11"),
    ("makespan s", "{makespan_s:>11.1f}", ">11"),
    ("EDP kJ*s", "{edp_kjs:>11.1f}", ">11"),
    ("greenup", "{greenup:>8.2f}", ">8"),
    ("speedup", "{speedup:>8.2f}", ">8"),
    ("powerup", "{powerup:>8.2f}", ">8"),
)

# appended when any row carries a carbon footprint (carbon-aware evals)
_EVAL_CARBON_COLS = (
    ("gCO2", "{carbon_g:>10.1f}", ">10"),
    ("CDP kg*s", "{cdp_kgs:>10.2f}", ">10"),
)

# appended when rows carry the DAG/deadline evaluation annotations:
# cp-su    — critical-path speedup, CP lower bound / makespan (<= 1,
#            1.0 = the schedule hit the theoretical floor)
# EDP/mhra — this row's EDP relative to the myopic mhra row (the
#            lookahead-vs-myopic comparison; < 1 beats the greedy)
# miss%    — share of finite-deadline tasks that completed late
_EVAL_CP_COL = (("cp-su", "{cp_su:>7.2f}", ">7"),)
_EVAL_VS_MHRA_COL = (("EDP/mhra", "{edp_vs_mhra:>9.3f}", ">9"),)
_EVAL_MISS_COL = (("miss%", "{miss_pct:>7.1f}", ">7"),)

# appended when rows carry multi-tenant fairness annotations (the
# --multiuser evaluation):
# users    — distinct task owners whose tasks completed in this run
# jain     — Jain's fairness index over per-user EDP (1.0 = even)
# EDP-cov  — coefficient of variation of per-user EDP (lower = fairer)
# shed     — submissions rejected by admission control (recorded, not
#            silently dropped)
# adm-d    — submissions deferred at least once by admission control
_EVAL_FAIR_COLS = (
    ("users", "{users:>7d}", ">7"),
    ("jain", "{jain:>7.3f}", ">7"),
    ("EDP-cov", "{user_edp_cov:>8.3f}", ">8"),
    ("shed", "{shed:>6d}", ">6"),
    ("adm-d", "{admission_deferred:>6d}", ">6"),
)

# appended when any row ran under a multi-region router (the --geo
# evaluation):
# rgn       — regions in the router (0 rows never show these columns)
# WAN kJ    — WAN transfer energy billed to cross-region routes
# egress GB — bytes that crossed a region boundary
_EVAL_GEO_COLS = (
    ("rgn", "{regions:>5d}", ">5"),
    ("WAN kJ", "{wan_kj:>9.3f}", ">9"),
    ("egress GB", "{egress_gb:>11.3f}", ">11"),
)

# appended when any row ran under a fault trace (chaos evaluations):
# goodput  — completed / submitted task ids (1.0 = nothing lost)
# gp/MJ    — goodput per megajoule, the chaos headline metric
# reexec%  — share of E_tot wasted on killed partials + losing copies
# cold     — cold worker spin-ups billed by the sim
# recov s  — mean first-kill -> completion time of recovered tasks
_EVAL_FAULT_COLS = (
    ("goodput", "{goodput:>8.3f}", ">8"),
    ("gp/MJ", "{goodput_per_mj:>8.2f}", ">8"),
    ("reexec%", "{reexec_pct:>8.2f}", ">8"),
    ("cold", "{cold_starts:>6d}", ">6"),
    ("recov s", "{recovery_s:>8.1f}", ">8"),
)


def _eval_cols(result) -> tuple:
    cols = _EVAL_COLS
    if any(r.carbon_g is not None for r in result.rows):
        cols = cols + _EVAL_CARBON_COLS
    if any(r.cp_speedup is not None for r in result.rows):
        cols = cols + _EVAL_CP_COL
    if any(r.edp_vs_mhra is not None for r in result.rows):
        cols = cols + _EVAL_VS_MHRA_COL
    if any(r.deadline_total > 0 for r in result.rows):
        cols = cols + _EVAL_MISS_COL
    if any(_row_has_fairness(r) for r in result.rows):
        cols = cols + _EVAL_FAIR_COLS
    if any(r.regions > 0 for r in result.rows):
        cols = cols + _EVAL_GEO_COLS
    if any(r.faulty for r in result.rows):
        cols = cols + _EVAL_FAULT_COLS
    return cols


def _row_has_fairness(r) -> bool:
    return (r.jain_index is not None or r.user_edp_cov is not None
            or r.shed > 0 or r.admission_deferred > 0)


def _eval_row_values(r) -> dict:
    nan = float("nan")
    miss = r.deadline_miss_rate
    return {
        "policy": r.policy,
        "energy_kj": r.energy_j / 1e3,
        "makespan_s": r.makespan_s,
        "edp_kjs": r.edp / 1e3,
        "greenup": r.greenup if r.greenup is not None else nan,
        "speedup": r.speedup if r.speedup is not None else nan,
        "powerup": r.powerup if r.powerup is not None else nan,
        "carbon_g": r.carbon_g if r.carbon_g is not None else nan,
        "cdp_kgs": r.cdp / 1e3 if r.cdp is not None else nan,
        "cp_su": r.cp_speedup if r.cp_speedup is not None else nan,
        "edp_vs_mhra": r.edp_vs_mhra if r.edp_vs_mhra is not None else nan,
        "miss_pct": miss * 100.0 if miss is not None else nan,
        "users": r.users,
        "jain": r.jain_index if r.jain_index is not None else nan,
        "user_edp_cov": (
            r.user_edp_cov if r.user_edp_cov is not None else nan
        ),
        "shed": r.shed,
        "admission_deferred": r.admission_deferred,
        "regions": r.regions,
        "wan_kj": r.wan_j / 1e3,
        "egress_gb": r.egress_bytes / 1e9,
        "goodput": r.goodput,
        "goodput_per_mj": r.goodput_per_mj,
        "reexec_pct": r.reexec_overhead * 100.0,
        "cold_starts": r.cold_starts,
        "recovery_s": (
            r.mean_recovery_s if r.mean_recovery_s is not None else nan
        ),
    }


def eval_text_report(result) -> str:
    """Paper-style comparison table for one :class:`EvalResult`; carbon
    evaluations grow gCO2 and carbon-delay-product columns."""
    cols = _eval_cols(result)
    head = "".join(f"{name:{align}}" for name, _, align in cols)
    lines = [
        f"workload: {result.workload}  "
        f"({result.n_tasks} tasks, alpha={result.alpha})",
        f"GPS-UP baseline: {result.baseline} (best single-site by EDP)",
        head,
        "-" * len(head),
    ]
    for r in result.rows:
        vals = _eval_row_values(r)
        lines.append("".join(fmt.format(**vals) for _, fmt, _ in cols))
    return "\n".join(lines)


def eval_html_report(results, path: str) -> str:
    """Render one or more EvalResults as a standalone HTML page."""
    esc = _html.escape
    if not isinstance(results, (list, tuple)):
        results = [results]
    blocks = []
    for res in results:
        with_carbon = any(r.carbon_g is not None for r in res.rows)
        with_cp = any(r.cp_speedup is not None for r in res.rows)
        with_vs = any(r.edp_vs_mhra is not None for r in res.rows)
        with_miss = any(r.deadline_total > 0 for r in res.rows)
        with_fair = any(_row_has_fairness(r) for r in res.rows)
        with_geo = any(r.regions > 0 for r in res.rows)
        with_faults = any(r.faulty for r in res.rows)
        nan = float("nan")

        def _vals(r):
            out = [r.policy, r.energy_j / 1e3, r.makespan_s, r.edp / 1e3,
                   r.greenup if r.greenup is not None else nan,
                   r.speedup if r.speedup is not None else nan,
                   r.powerup if r.powerup is not None else nan]
            if with_carbon:
                out += [r.carbon_g if r.carbon_g is not None else nan,
                        r.cdp / 1e3 if r.cdp is not None else nan]
            if with_cp:
                out.append(r.cp_speedup if r.cp_speedup is not None else nan)
            if with_vs:
                out.append(r.edp_vs_mhra if r.edp_vs_mhra is not None else nan)
            if with_miss:
                m = r.deadline_miss_rate
                out.append(m * 100.0 if m is not None else nan)
            if with_fair:
                out += [float(r.users),
                        r.jain_index if r.jain_index is not None else nan,
                        r.user_edp_cov
                        if r.user_edp_cov is not None else nan,
                        float(r.shed), float(r.admission_deferred)]
            if with_geo:
                out += [float(r.regions), r.wan_j / 1e3,
                        r.egress_bytes / 1e9]
            if with_faults:
                out += [r.goodput, r.goodput_per_mj,
                        r.reexec_overhead * 100.0, float(r.cold_starts),
                        r.mean_recovery_s
                        if r.mean_recovery_s is not None else nan]
            return out

        rows = "".join(
            "<tr>" + "".join(
                f"<td>{esc(v) if isinstance(v, str) else format(v, '.2f')}</td>"
                for v in _vals(r)
            ) + "</tr>"
            for r in res.rows
        )
        extra_head = (
            ("<th>gCO2</th><th>CDP (kg&middot;s)</th>" if with_carbon else "")
            + ("<th>cp-su</th>" if with_cp else "")
            + ("<th>EDP/mhra</th>" if with_vs else "")
            + ("<th>miss%</th>" if with_miss else "")
            + ("<th>users</th><th>jain</th><th>EDP-cov</th>"
               "<th>shed</th><th>adm-d</th>" if with_fair else "")
            + ("<th>rgn</th><th>WAN (kJ)</th><th>egress (GB)</th>"
               if with_geo else "")
            + ("<th>goodput</th><th>gp/MJ</th><th>reexec%</th>"
               "<th>cold</th><th>recov s</th>" if with_faults else "")
        )
        blocks.append(
            f"<h2>{esc(res.workload)}</h2>"
            f"<p>{res.n_tasks} tasks &middot; alpha={res.alpha} &middot; "
            f"GPS-UP baseline: {esc(res.baseline)}</p>"
            "<table><tr><th>policy</th><th>energy (kJ)</th><th>makespan (s)</th>"
            "<th>EDP (kJ&middot;s)</th><th>greenup</th><th>speedup</th>"
            f"<th>powerup</th>{extra_head}</tr>{rows}</table>"
        )
    html = (
        "<!doctype html><html><head><title>GreenFaaS evaluation</title>"
        "<style>body{font-family:sans-serif}table{border-collapse:collapse}"
        "td,th{border:1px solid #999;padding:4px 10px}</style></head><body>"
        "<h1>GreenFaaS policy evaluation</h1>"
        + "".join(blocks) + "</body></html>"
    )
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(html)
    return html


def write_bench_json(results, path: str = "BENCH_eval.json",
                     extra: dict | None = None) -> dict:
    """Persist EvalResult(s) (+ optional harness metadata) as one JSON
    payload; returns the payload written."""
    if not isinstance(results, (list, tuple)):
        results = [results]
    payload = {
        "suite": "paper_eval",
        "workloads": [r.to_payload() for r in results],
    }
    if extra:
        payload.update(extra)
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(payload, indent=2, sort_keys=True))
    return payload
