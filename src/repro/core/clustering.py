"""Agglomerative task clustering for Cluster MHRA (paper §III-F).

Tasks are represented by their (runtime, energy) prediction vectors across
endpoints; average-linkage agglomerative merging proceeds until a cluster's
predicted energy exceeds the node-startup energy (amortization point).
Identical prediction rows (same function) are pre-bucketed so the pairwise
stage runs on bucket centroids — same result, ~O(B^2) instead of O(n^2).
A size cap keeps clusters in the 12–40-task band the paper reports.
"""
from __future__ import annotations

import numpy as np


def agglomerative_cluster(
    features: np.ndarray,       # (n, k) prediction vectors
    energies: np.ndarray,       # (n,) mean predicted energy per task
    energy_cap: float,          # node startup energy
    distance_threshold: float = 0.5,
    max_cluster_size: int = 40,
) -> list[list[int]]:
    n = len(features)
    if n == 0:
        return []
    feats = np.asarray(features, float)
    scale = feats.std(axis=0)
    scale[scale < 1e-12] = 1.0
    norm = feats / scale

    # ---- bucket identical (rounded) rows ----------------------------------
    keys = [tuple(np.round(row, 6)) for row in norm]
    buckets: dict[tuple, list[int]] = {}
    for i, key in enumerate(keys):
        buckets.setdefault(key, []).append(i)

    clusters: list[dict] = []
    for idxs in buckets.values():
        clusters.append({
            "idx": list(idxs),
            "centroid": norm[idxs].mean(axis=0),
            "energy": float(energies[idxs].sum()),
        })

    # ---- average-linkage merging on bucket centroids -----------------------
    def eligible(a, b):
        if a["energy"] + b["energy"] > energy_cap:
            return False
        if len(a["idx"]) + len(b["idx"]) > max_cluster_size:
            return False
        return True

    merged = True
    while merged and len(clusters) > 1:
        merged = False
        best = (None, None, np.inf)
        for i in range(len(clusters)):
            for j in range(i + 1, len(clusters)):
                if not eligible(clusters[i], clusters[j]):
                    continue
                d = float(np.linalg.norm(
                    clusters[i]["centroid"] - clusters[j]["centroid"]
                ))
                if d < best[2]:
                    best = (i, j, d)
        i, j, d = best
        if i is not None and d <= distance_threshold:
            a, b = clusters[i], clusters[j]
            na, nb = len(a["idx"]), len(b["idx"])
            a["centroid"] = (a["centroid"] * na + b["centroid"] * nb) / (na + nb)
            a["idx"] += b["idx"]
            a["energy"] += b["energy"]
            del clusters[j]
            merged = True

    # ---- split oversized clusters so each fits the caps ---------------------
    out: list[list[int]] = []
    for c in clusters:
        idxs = c["idx"]
        if not idxs:
            continue
        chunk: list[int] = []
        e_sum = 0.0
        for i in idxs:
            e_i = float(energies[i])
            if chunk and (e_sum + e_i > energy_cap or len(chunk) >= max_cluster_size):
                out.append(chunk)
                chunk, e_sum = [], 0.0
            chunk.append(i)
            e_sum += e_i
        if chunk:
            out.append(chunk)
    return out
