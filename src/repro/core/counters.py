"""Counter / task-record abstractions (the perf-counter layer of §III-C/D).

On the CPU testbed the counter vector mirrors the paper's perfmon set:
    [LLC_MISSES, INSTRUCTIONS_RETIRED, CPU_CYCLES, REF_CYCLES]
On TPU endpoints the analogous dynamic-power features are HLO-derived:
    [FLOPs_executed, HBM_bytes, ICI_bytes, duty_cycle]
Both are just per-process/per-job vectors X fed to the linear power model.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

CPU_COUNTERS = ("LLC_MISSES", "INSTRUCTIONS_RETIRED", "CPU_CYCLES", "REF_CYCLES")
TPU_COUNTERS = ("FLOPS", "HBM_BYTES", "ICI_BYTES", "DUTY")


@dataclasses.dataclass
class CounterSample:
    """One resource-monitor poll: per-process counter rates at time t."""
    t: float
    # process id -> counter vector (rates, i.e. per-second deltas)
    procs: dict[int, np.ndarray]


@dataclasses.dataclass
class PowerSample:
    """One energy-monitor poll of a node (RAPL/Cray/NVML/BMC analogue)."""
    t: float
    watts: float


@dataclasses.dataclass
class TaskRecord:
    """What the wrapper around every task reports back (paper §III-C) plus
    the attribution results filled in by the pipeline (§III-D)."""
    task_id: str
    fn: str
    endpoint: str
    worker_pid: int
    t_start: float
    t_end: float
    energy_j: float | None = None      # attributed dynamic energy
    node_energy_j: float | None = None # incl. idle share
    transfer_j: float = 0.0
    user: str = "user0"

    @property
    def runtime(self) -> float:
        return self.t_end - self.t_start


def merge_counter_windows(
    samples: Sequence[CounterSample], pid: int, t0: float, t1: float
) -> np.ndarray:
    """Total counters for process pid over [t0, t1], trapezoidal on rates."""
    pts = [(s.t, s.procs.get(pid)) for s in samples if s.procs.get(pid) is not None]
    pts = [(t, v) for t, v in pts if t0 - 2.0 <= t <= t1 + 2.0]
    if not pts:
        return np.zeros(4)
    if len(pts) == 1:
        return pts[0][1] * (t1 - t0)
    total = np.zeros_like(pts[0][1], dtype=float)
    for (ta, va), (tb, vb) in zip(pts, pts[1:]):
        lo, hi = max(ta, t0), min(tb, t1)
        if hi <= lo:
            continue
        # linear interpolation of rates inside the overlap
        fa = (lo - ta) / (tb - ta)
        fb = (hi - ta) / (tb - ta)
        va_i = va + (vb - va) * fa
        vb_i = va + (vb - va) * fb
        total += 0.5 * (va_i + vb_i) * (hi - lo)
    return total
