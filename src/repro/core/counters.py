"""Counter / task-record abstractions (the perf-counter layer of §III-C/D).

On the CPU testbed the counter vector mirrors the paper's perfmon set:
    [LLC_MISSES, INSTRUCTIONS_RETIRED, CPU_CYCLES, REF_CYCLES]
On TPU endpoints the analogous dynamic-power features are HLO-derived:
    [FLOPs_executed, HBM_bytes, ICI_bytes, duty_cycle]
Both are just per-process/per-job vectors X fed to the linear power model.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

CPU_COUNTERS = ("LLC_MISSES", "INSTRUCTIONS_RETIRED", "CPU_CYCLES", "REF_CYCLES")
TPU_COUNTERS = ("FLOPS", "HBM_BYTES", "ICI_BYTES", "DUTY")


@dataclasses.dataclass
class CounterSample:
    """One resource-monitor poll: per-process counter rates at time t."""
    t: float
    # process id -> counter vector (rates, i.e. per-second deltas)
    procs: dict[int, np.ndarray]


@dataclasses.dataclass
class PowerSample:
    """One energy-monitor poll of a node (RAPL/Cray/NVML/BMC analogue)."""
    t: float
    watts: float


@dataclasses.dataclass
class TaskRecord:
    """What the wrapper around every task reports back (paper §III-C) plus
    the attribution results filled in by the pipeline (§III-D)."""
    task_id: str
    fn: str
    endpoint: str
    worker_pid: int
    t_start: float
    t_end: float
    energy_j: float | None = None      # attributed dynamic energy
    node_energy_j: float | None = None # incl. idle share
    transfer_j: float = 0.0
    user: str = "user0"
    failed: bool = False               # killed by endpoint churn (partial span)

    @property
    def runtime(self) -> float:
        return self.t_end - self.t_start


def counter_width(samples: Sequence[CounterSample]) -> int:
    """Length of the counter vectors carried by ``samples`` (0 if no
    process was ever observed).  The CPU testbed uses 4-wide perfmon
    vectors, but TPU/extended counter sets may differ — callers must not
    assume a width."""
    for s in samples:
        for v in s.procs.values():
            return len(v)
    return 0


def merge_counter_windows(
    samples: Sequence[CounterSample], pid: int, t0: float, t1: float
) -> np.ndarray:
    """Total counters for process pid over [t0, t1], trapezoidal on rates.

    Vectorized: the per-segment overlap/interpolation loop is one
    broadcast pass over the pid's rate series.  The counter-vector width
    is inferred from the samples (the empty case used to hard-code 4,
    which breaks for any non-4-wide counter set).  Samples more than 2 s
    outside the window are ignored (legacy monitor-jitter margin).
    """
    ts_l, vs_l = [], []
    lo_t, hi_t = t0 - 2.0, t1 + 2.0
    for s in samples:
        v = s.procs.get(pid)
        if v is not None and lo_t <= s.t <= hi_t:
            ts_l.append(s.t)
            vs_l.append(v)
    if not ts_l:
        return np.zeros(counter_width(samples))
    vs = np.asarray(vs_l, dtype=float)
    if len(ts_l) == 1:
        return vs[0] * (t1 - t0)
    ts = np.asarray(ts_l)
    ta, tb = ts[:-1], ts[1:]
    va, vb = vs[:-1], vs[1:]
    lo = np.maximum(ta, t0)
    hi = np.minimum(tb, t1)
    w = hi - lo
    m = w > 0.0
    if not m.any():
        return np.zeros(vs.shape[1])
    ta, tb, w = ta[m], tb[m], w[m]
    va, vb, lo, hi = va[m], vb[m], lo[m], hi[m]
    dt = tb - ta
    dv = vb - va
    # linear interpolation of rates at the overlap edges
    va_i = va + dv * ((lo - ta) / dt)[:, None]
    vb_i = va + dv * ((hi - ta) / dt)[:, None]
    return (0.5 * (va_i + vb_i) * w[:, None]).sum(axis=0)


def integrate_windows(
    ts: np.ndarray, vals: np.ndarray, t0s: np.ndarray, t1s: np.ndarray
) -> np.ndarray:
    """Integrals of a sampled series over many windows in one pass.

    Linear interpolation between samples, edge values extrapolated as
    constants outside the span (``np.interp`` clamping — the batched
    equivalent of ``power_model._integrate``), windows with ``t1 <= t0``
    integrate to 0.  One cumulative-trapezoid pass, then an exact
    piecewise-quadratic antiderivative evaluation per window endpoint:
    O(samples + windows·log samples).

    ``vals`` may be (n,) or (n, k); the result is (q,) or (q, k).
    """
    t0s = np.asarray(t0s, dtype=float)
    t1s = np.asarray(t1s, dtype=float)
    ts = np.asarray(ts, dtype=float)
    vals = np.asarray(vals, dtype=float)
    scalar_series = vals.ndim == 1
    if scalar_series:
        vals = vals[:, None]
    out = np.zeros((len(t0s), vals.shape[1]))
    valid = t1s > t0s
    if len(ts) == 0 or not valid.any():
        return out[:, 0] if scalar_series else out
    if len(ts) == 1:
        out[valid] = vals[0] * (t1s - t0s)[valid, None]
        return out[:, 0] if scalar_series else out
    cum = np.zeros_like(vals)
    np.cumsum(
        0.5 * (vals[1:] + vals[:-1]) * (ts[1:] - ts[:-1])[:, None],
        axis=0, out=cum[1:],
    )

    def anti(t):
        tc = np.clip(t, ts[0], ts[-1])
        j = np.clip(np.searchsorted(ts, tc, side="right") - 1, 0, len(ts) - 2)
        dt = tc - ts[j]
        seg = ts[j + 1] - ts[j]
        frac = np.divide(dt, seg, out=np.zeros_like(dt), where=seg > 0)
        return cum[j] + (
            dt[:, None] * vals[j]
            + 0.5 * (dt * frac)[:, None] * (vals[j + 1] - vals[j])
        )

    a, b = t0s[valid], t1s[valid]
    inner = anti(b) - anti(a)
    # constant extrapolation outside the sampled span (np.interp clamps)
    left = np.maximum(np.minimum(b, ts[0]) - a, 0.0)
    right = np.maximum(b - np.maximum(a, ts[-1]), 0.0)
    out[valid] = inner + left[:, None] * vals[0] + right[:, None] * vals[-1]
    return out[:, 0] if scalar_series else out


def merge_counter_windows_batch(
    samples: Sequence[CounterSample],
    queries: Sequence[tuple[int, float, float]],
) -> np.ndarray:
    """Totals for many ``(pid, t0, t1)`` windows in one pass: (n_q, k).

    One sweep over the samples builds each pid's rate series; the
    queries then go through :func:`integrate_windows` with their windows
    clipped to the series span, so nothing integrates outside it (merge
    semantics: zero beyond the samples, unlike the power-integral's edge
    extrapolation).  O(samples·procs + queries·log samples) instead of
    the per-task rescans of calling :func:`merge_counter_windows` in a
    loop.

    Unlike the scalar API this integrates the full series (no ±2 s
    margin); on gap-free monitor streams the two agree to float
    round-off.
    """
    queries = list(queries)
    k = counter_width(samples)
    out = np.zeros((len(queries), k))
    if k == 0 or not queries:
        return out
    by_pid: dict[int, tuple[list, list]] = {}
    for s in samples:
        for pid, v in s.procs.items():
            ts_l, vs_l = by_pid.setdefault(pid, ([], []))
            ts_l.append(s.t)
            vs_l.append(v)
    q_by_pid: dict[int, list[int]] = {}
    for qi, (pid, _, _) in enumerate(queries):
        q_by_pid.setdefault(pid, []).append(qi)
    for pid, q_idx in q_by_pid.items():
        series = by_pid.get(pid)
        if series is None:
            continue
        ts = np.asarray(series[0])
        vs = np.asarray(series[1], dtype=float)
        t0s = np.array([queries[qi][1] for qi in q_idx])
        t1s = np.array([queries[qi][2] for qi in q_idx])
        if len(ts) == 1:
            out[q_idx] = vs[0] * (t1s - t0s)[:, None]
            continue
        out[q_idx] = integrate_windows(
            ts, vs, np.clip(t0s, ts[0], ts[-1]), np.clip(t1s, ts[0], ts[-1])
        )
    return out
