"""Geo-distributed region layer: a router above the endpoint fleet.

GreenFaaS places tasks on the least-energy *machine*; the Function
Delivery Network line of work (PAPERS.md) shows the next win is placing
across *regions* — per-region carbon signals, WAN egress costs, and
caller locality.  This module adds that two-level split without touching
the parity-locked MHRA engines:

- :class:`RegionSpec` — one region: its endpoint subset, per-destination
  WAN bandwidth / latency / energy-per-byte, the callers homed there,
  and an optional capacity override.
- :class:`RegionRouter` — the region-level decision.  Three modes
  reproduce the A/B/C evaluation protocol from SNIPPETS.md:
  ``"fixed"`` (scenario A: everything to one home region),
  ``"caller"`` (scenario B: every task to its caller's region), and
  ``"agent"`` (scenario C: score each candidate region by
  carbon-at-decision x (compute estimate + WAN transfer joules) x a
  queue-depth congestion penalty, pick the minimum).

The router only *narrows* the fleet: the winning region's endpoint
subset reaches the existing engines as a :class:`PolicyContext` alive
mask, so endpoint-level placement — and its clone/delta/soa parity — is
untouched.  ``endpoint_mask`` collapses an all-``True`` mask to ``None``
(the same lever the fault mask uses), which is what makes a
single-region router bitwise-inert: one region covering the whole fleet
produces ``None`` masks, zero WAN events, and the exact placement call
sequence of a region-free engine.

Units: bandwidths B/s, latencies s, WAN energy J/B, carbon rates g/J.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from repro.core.carbon import CarbonIntensitySignal
from repro.core.endpoint import EndpointSpec
from repro.core.scheduler import TaskSpec

#: WAN link defaults for region pairs the spec doesn't list explicitly.
DEFAULT_WAN_BW_BPS = 1.25e9       # 10 Gbit/s inter-region path
DEFAULT_WAN_LATENCY_S = 0.1
DEFAULT_WAN_J_PER_BYTE = 1.2e-7   # core+edge network energy per byte

#: Baseline per-invocation payload (request + result) billed on every
#: cross-region dispatch, on top of the task's declared input bytes.
INVOKE_BYTES = 16e3

ROUTER_MODES = ("fixed", "caller", "agent")


@dataclasses.dataclass(frozen=True)
class RegionSpec:
    """One region of the federation: an endpoint subset plus its WAN
    links and caller-locality map.

    ``wan_bw_bps`` / ``wan_latency_s`` / ``wan_j_per_byte`` are keyed by
    *destination region* name; pairs not listed fall back to the module
    defaults, and same-region transfers are free by construction.
    ``callers`` are the user names homed in this region (the caller
    locality the ``"caller"`` routing mode and WAN egress billing use);
    a user listed nowhere is homed in the router's ``home`` region.
    ``capacity`` overrides the region's concurrency normalizer for the
    congestion penalty (0 = derive from the member endpoints' cores).
    """

    name: str
    endpoints: tuple[str, ...]
    wan_bw_bps: Mapping[str, float] = dataclasses.field(default_factory=dict)
    wan_latency_s: Mapping[str, float] = dataclasses.field(default_factory=dict)
    wan_j_per_byte: Mapping[str, float] = dataclasses.field(default_factory=dict)
    callers: tuple[str, ...] = ()
    capacity: int = 0

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("RegionSpec needs a name")
        if not self.endpoints:
            raise ValueError(f"region {self.name!r} has no endpoints")
        if len(set(self.endpoints)) != len(self.endpoints):
            raise ValueError(f"region {self.name!r} lists duplicate endpoints")
        if self.capacity < 0:
            raise ValueError(
                f"region {self.name!r}: capacity must be >= 0, "
                f"got {self.capacity}"
            )
        for m, label in ((self.wan_bw_bps, "wan_bw_bps"),
                         (self.wan_latency_s, "wan_latency_s"),
                         (self.wan_j_per_byte, "wan_j_per_byte")):
            for dst, v in m.items():
                if v < 0 or (label == "wan_bw_bps" and v == 0):
                    raise ValueError(
                        f"region {self.name!r}: {label}[{dst!r}] must be "
                        f"positive, got {v}"
                    )

    # -- WAN link model ----------------------------------------------------
    def wan_delay_s(self, dst: str, nbytes: float) -> float:
        """Seconds to move ``nbytes`` to region ``dst``: one-way latency
        plus serialization at the link bandwidth.  0 for ``dst == self``."""
        if dst == self.name:
            return 0.0
        bw = self.wan_bw_bps.get(dst, DEFAULT_WAN_BW_BPS)
        lat = self.wan_latency_s.get(dst, DEFAULT_WAN_LATENCY_S)
        return lat + nbytes / bw

    def wan_joules(self, dst: str, nbytes: float) -> float:
        """WAN transfer energy (J) for ``nbytes`` to region ``dst``;
        0 for ``dst == self``."""
        if dst == self.name:
            return 0.0
        return nbytes * self.wan_j_per_byte.get(dst, DEFAULT_WAN_J_PER_BYTE)


def task_payload_bytes(task: TaskSpec) -> float:
    """Bytes a cross-region dispatch of ``task`` must move *besides*
    shared datasets: the invocation payload plus every private input.
    Shared inputs are billed separately by the router's per-destination
    WAN cache (they cross the WAN once per region, like the endpoint
    transfer model's per-destination cache)."""
    return INVOKE_BYTES + sum(
        b for (_, _, b, shared) in task.inputs if not shared
    )


def task_shared_inputs(task: TaskSpec) -> list[tuple[str, float]]:
    """(source key, bytes) of the task's shared dataset inputs — the WAN
    cache keys (dataset identity = declared source endpoint + size)."""
    return [(src, b) for (src, _, b, shared) in task.inputs if shared]


class RegionRouter:
    """Region-level placement: caller -> source region, task -> winning
    destination region.

    ``mode`` selects the decision rule (the A/B/C protocol):

    - ``"fixed"``  — scenario A: every task to ``home``, wherever the
      caller sits (the single-cloud-region deployment).
    - ``"caller"`` — scenario B: every task to its caller's home region
      (pure locality, zero WAN, no carbon awareness).
    - ``"agent"``  — scenario C: score every region and take the
      minimum.  The score for routing a task from source region Q to
      candidate region R at time t is::

          (E_est(R) + WAN_J(Q, R)) * g(R, t) * (1 + beta * congestion(R))

      where ``E_est`` is the caller-supplied compute-energy estimate
      (J), ``WAN_J`` the transfer joules of the task's payload,
      ``g(R, t)`` the region's carbon intensity in g/J from ``carbon``
      (uniform 1.0 without a signal — the score then degrades to
      energy-plus-congestion load balancing), and ``congestion`` the
      caller-supplied queue-depth penalty (committed backlog seconds /
      ``rt_scale`` + work already routed this batch / capacity).  Ties
      break toward the earlier region in construction order (strict
      ``<`` scan), so routing is deterministic.

    The router is stateless: backlog and energy estimates are snapshots
    supplied per call by the engine, so the same inputs always produce
    the same route.
    """

    def __init__(
        self,
        regions: Sequence[RegionSpec],
        mode: str = "agent",
        home: str | None = None,
        carbon: CarbonIntensitySignal | None = None,
        beta_queue: float = 1.0,
        rt_scale: float = 60.0,
    ):
        regions = list(regions)
        if not regions:
            raise ValueError("RegionRouter needs at least one region")
        if mode not in ROUTER_MODES:
            raise ValueError(
                f"unknown router mode {mode!r}; available: {ROUTER_MODES}"
            )
        if beta_queue < 0:
            raise ValueError(
                f"beta_queue must be non-negative, got {beta_queue}"
            )
        if rt_scale <= 0:
            raise ValueError(f"rt_scale must be positive, got {rt_scale}")
        self.regions: dict[str, RegionSpec] = {}
        seen_eps: dict[str, str] = {}
        seen_callers: dict[str, str] = {}
        for r in regions:
            if r.name in self.regions:
                raise ValueError(f"duplicate region name {r.name!r}")
            self.regions[r.name] = r
            for ep in r.endpoints:
                if ep in seen_eps:
                    raise ValueError(
                        f"endpoint {ep!r} is in both {seen_eps[ep]!r} "
                        f"and {r.name!r}"
                    )
                seen_eps[ep] = r.name
            for c in r.callers:
                if c in seen_callers:
                    raise ValueError(
                        f"caller {c!r} is homed in both "
                        f"{seen_callers[c]!r} and {r.name!r}"
                    )
                seen_callers[c] = r.name
        self.names: list[str] = [r.name for r in regions]
        self.mode = mode
        self.home = home if home is not None else self.names[0]
        if self.home not in self.regions:
            raise ValueError(
                f"home region {self.home!r} is not one of {self.names}"
            )
        self.carbon = carbon
        self.beta_queue = beta_queue
        self.rt_scale = rt_scale
        self._caller_home = seen_callers
        self._region_of_ep = seen_eps

    # -- locality ----------------------------------------------------------
    def caller_region(self, user: str) -> str:
        """The region ``user`` is homed in (``home`` when unlisted)."""
        return self._caller_home.get(user, self.home)

    def region_of(self, endpoint: str) -> str:
        """The region owning ``endpoint`` (KeyError if unassigned)."""
        return self._region_of_ep[endpoint]

    # -- scoring -----------------------------------------------------------
    def rate(self, region: str, now: float) -> float:
        """Carbon intensity of ``region``'s grid at ``now`` in g/J
        (uniform 1.0 without a signal, so scores stay comparable)."""
        if self.carbon is None:
            return 1.0
        return self.carbon.rate_g_per_j(region, now)

    def score(self, src: str, dst: str, nbytes: float, energy_j: float,
              now: float, congestion: float = 0.0) -> float:
        """The agent-mode objective for routing one task (see class
        docs).  Grams-at-decision units: (compute + WAN joules) x g/J,
        inflated by the congestion penalty."""
        wan = self.regions[src].wan_joules(dst, nbytes)
        return (energy_j + wan) * self.rate(dst, now) * (
            1.0 + self.beta_queue * congestion
        )

    def route(
        self,
        user: str,
        nbytes: float,
        now: float,
        energy: Mapping[str, float] | None = None,
        congestion: Mapping[str, float] | None = None,
    ) -> tuple[str, str]:
        """(source region, destination region) for one task.

        ``energy`` maps region -> estimated compute joules for the task
        there; ``congestion`` maps region -> queue-depth penalty.  Both
        are only consulted in ``"agent"`` mode and default to 0."""
        src = self.caller_region(user)
        if len(self.names) == 1:
            # one candidate — nothing to score (and a single-region
            # fleet must stay inert even without a carbon trace)
            return src, self.names[0]
        if self.mode == "fixed":
            return src, self.home
        if self.mode == "caller":
            return src, src
        best_name = self.names[0]
        best = None
        for r in self.names:
            s = self.score(
                src, r, nbytes,
                energy.get(r, 0.0) if energy else 0.0,
                now,
                congestion.get(r, 0.0) if congestion else 0.0,
            )
            if best is None or s < best:
                best, best_name = s, r
        return src, best_name

    # -- fleet narrowing ---------------------------------------------------
    def endpoint_mask(self, region: str,
                      endpoints: Sequence[EndpointSpec | str],
                      ) -> tuple[bool, ...] | None:
        """Per-endpoint membership mask for ``region`` over the engine's
        endpoint order — the alive-mask shape the MHRA engines consume.
        Collapses to ``None`` when every endpoint is a member (the
        single-region case), which keeps all three engines on their
        exact unmasked scoring paths: bitwise inertness by construction.
        """
        members = set(self.regions[region].endpoints)
        mask = tuple(
            (e if isinstance(e, str) else e.name) in members
            for e in endpoints
        )
        if all(mask):
            return None
        return mask

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"<RegionRouter mode={self.mode!r} home={self.home!r} "
                f"regions={self.names}>")
