"""Checkpointing: atomic, async-capable, elastic-reshard-friendly.

Leaves are gathered to host and written as one .npz per step with a JSON
treedef sidecar.  Restore is mesh-agnostic: arrays are re-placed with
whatever shardings the *target* mesh dictates, so a checkpoint written on
a 16x16 mesh restores onto 8x16 / 2x16x16 / 1 device unchanged — this is
the elastic-scaling path (fleet/elastic.py drives it).

Writes are atomic (tmp + rename); `AsyncCheckpointer` overlaps the host
write with the next train step (double-buffered thread).
"""
from __future__ import annotations

import json
import pathlib
import threading
from typing import Any

import jax
import numpy as np


def _flatten(state: Any):
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save_checkpoint(state: Any, directory: str | pathlib.Path, step: int) -> pathlib.Path:
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(state)

    def to_np(x):
        a = np.asarray(jax.device_get(x))
        if a.dtype.kind not in "fiub":  # ml_dtypes (bf16 etc): store as f32
            a = a.astype(np.float32)
        return a

    arrays = {f"leaf_{i}": to_np(x) for i, x in enumerate(leaves)}
    tmp = directory / f".tmp_step_{step}.npz"
    final = directory / f"step_{step:08d}.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    (directory / f"step_{step:08d}.treedef.json").write_text(
        json.dumps({"n_leaves": len(leaves), "treedef": str(treedef), "step": step})
    )
    tmp.rename(final)  # atomic publish
    return final


def latest_step(directory: str | pathlib.Path) -> int | None:
    directory = pathlib.Path(directory)
    if not directory.exists():
        return None
    steps = sorted(
        int(p.stem.split("_")[1]) for p in directory.glob("step_*.npz")
    )
    return steps[-1] if steps else None


def restore_checkpoint(
    template: Any, directory: str | pathlib.Path, step: int | None = None,
    shardings: Any = None,
) -> Any:
    """Restore into the structure of `template`; if `shardings` given (a
    matching tree of NamedSharding), device_put each leaf accordingly —
    this is how elastic re-meshing works."""
    directory = pathlib.Path(directory)
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints under {directory}"
    data = np.load(directory / f"step_{step:08d}.npz")
    leaves, treedef = _flatten(template)
    assert len(leaves) == len(data.files), (len(leaves), len(data.files))
    import jax.numpy as jnp

    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    new_leaves = [
        jnp.asarray(a).astype(t.dtype) if hasattr(t, "dtype") else a
        for a, t in zip(new_leaves, leaves)
    ]
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        new_leaves = [jax.device_put(a, s) for a, s in zip(new_leaves, sh_leaves)]
    return treedef.unflatten(new_leaves)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (one in flight)."""

    def __init__(self, directory: str | pathlib.Path):
        self.directory = pathlib.Path(directory)
        self._thread: threading.Thread | None = None

    def save(self, state: Any, step: int) -> None:
        self.wait()
        # snapshot to host synchronously (cheap vs write), write in thread
        leaves, treedef = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        snapshot = treedef.unflatten(host)
        self._thread = threading.Thread(
            target=save_checkpoint, args=(snapshot, self.directory, step)
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
