"""Deterministic synthetic token pipeline, shardable across hosts.

Every (seed, step, shard) triple yields the same batch on every process —
no data server needed; restart-safe (resume from any step).  A real
deployment swaps `SyntheticTokens` for a file-backed source behind the
same iterator protocol.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    shard: int = 0          # this host's shard index
    num_shards: int = 1
    structured: bool = True  # markov-ish stream so loss can actually drop

    def __post_init__(self):
        assert self.global_batch % self.num_shards == 0
        self.local_batch = self.global_batch // self.num_shards

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.uint64(self.seed) * np.uint64(1_000_003)
            + np.uint64(step) * np.uint64(9_973)
            + np.uint64(self.shard)
        )
        if self.structured:
            # tokens follow t' = (a*t + b) mod V with noise: learnable structure
            a = 31 + (step % 7)
            start = rng.integers(0, self.vocab, size=(self.local_batch, 1))
            idx = np.arange(self.seq_len + 1)
            toks = (start + idx * a) % self.vocab
            noise = rng.random((self.local_batch, self.seq_len + 1)) < 0.05
            toks = np.where(noise, rng.integers(0, self.vocab, toks.shape), toks)
        else:
            toks = rng.integers(0, self.vocab, (self.local_batch, self.seq_len + 1))
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
