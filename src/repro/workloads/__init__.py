"""Reproducible workload generators for the evaluation harness.

- :mod:`repro.workloads.arrivals` — Poisson / bursty / diurnal arrival
  processes (seeded, sorted arrival-time arrays).
- :mod:`repro.workloads.synthetic` — the paper's synthetic EDP workload
  (mixed compute/memory/IO function classes over the Table-I testbed).
- :mod:`repro.workloads.multiuser` — the multi-tenant variant: Zipf user
  populations with bursty per-user submission campaigns.
- :mod:`repro.workloads.geo` — the geo-distributed variant: per-region
  fleets, WAN links, regional carbon grids, and caller locality (the
  A/B/C routing evaluation's input).
- :mod:`repro.workloads.moldesign` — the molecular-design DAG workload
  (dock → simulate → train → infer with data dependencies).
- :mod:`repro.workloads.carbon_traces` — per-endpoint grid
  carbon-intensity signals (seeded synthetic + real-trace JSON I/O).
- :mod:`repro.workloads.faults` — seeded endpoint-churn chaos scripts
  and warm-pool fleet variants for the fault-tolerance evaluation.
- :mod:`repro.workloads.wfcommons` — WfCommons/Pegasus JSON importer for
  published workflow DAGs (+ a committed Montage-shaped sample).
- :mod:`repro.workloads.trace` — the :class:`WorkloadTrace` container +
  replay helper every generator returns (and the deadline-distribution
  helper :func:`~repro.workloads.trace.apply_deadline_slack`).
"""
from repro.workloads.arrivals import (
    ARRIVAL_PROCESSES,
    bursty_arrivals,
    diurnal_arrivals,
    make_arrivals,
    poisson_arrivals,
)
from repro.workloads.carbon_traces import (
    load_carbon_signal,
    table1_carbon_signal,
    write_carbon_signal,
)
from repro.workloads.faults import add_failover, churn_fault_trace, with_warm_pool
from repro.workloads.geo import (
    GEO_REGIONS,
    geo_carbon_signal,
    geo_edp_workload,
    geo_region_specs,
    geo_testbed,
)
from repro.workloads.moldesign import (
    MOLDESIGN_DAG_PROFILES,
    moldesign_dag_workload,
    moldesign_endpoints,
)
from repro.workloads.multiuser import multiuser_edp_workload, zipf_user_ranks
from repro.workloads.synthetic import FUNCTION_CLASSES, synthetic_edp_workload
from repro.workloads.trace import WorkloadTrace, apply_deadline_slack
from repro.workloads.wfcommons import load_wfcommons, load_wfcommons_sample

__all__ = [
    "ARRIVAL_PROCESSES",
    "FUNCTION_CLASSES",
    "GEO_REGIONS",
    "MOLDESIGN_DAG_PROFILES",
    "WorkloadTrace",
    "add_failover",
    "apply_deadline_slack",
    "bursty_arrivals",
    "churn_fault_trace",
    "diurnal_arrivals",
    "geo_carbon_signal",
    "geo_edp_workload",
    "geo_region_specs",
    "geo_testbed",
    "load_carbon_signal",
    "load_wfcommons",
    "load_wfcommons_sample",
    "make_arrivals",
    "moldesign_dag_workload",
    "moldesign_endpoints",
    "multiuser_edp_workload",
    "poisson_arrivals",
    "synthetic_edp_workload",
    "table1_carbon_signal",
    "with_warm_pool",
    "write_carbon_signal",
    "zipf_user_ranks",
]
