"""Minimal WfCommons / Pegasus workflow-trace importer.

`WfCommons <https://wfcommons.org>`_ publishes execution traces of real
scientific workflows (Montage, Epigenomics, SoyKB, ...) in a JSON "wfformat".
This module imports the subset the scheduling harness needs — task
identities, dependency edges, measured runtimes, and file payloads — into
a :class:`~repro.workloads.trace.WorkloadTrace`, so the online engine's
ready-set and the lookahead policies run against *published* DAG shapes
instead of only the generated molecular-design pipeline.

Supported input (both the 1.x ``jobs`` and newer ``tasks`` spellings):

.. code-block:: json

    {"workflow": {"tasks": [
        {"name": "mProject_00000001", "category": "mProject",
         "runtimeInSeconds": 12.3, "parents": ["..."],
         "files": [{"link": "input", "sizeInBytes": 4000000,
                    "name": "region.fits"}]}
    ]}}

Import model (deliberately minimal, documented over clever):

- **function identity**: the task's ``category`` field, else its name
  with one trailing ``_<digits>``/``_ID...`` instance suffix stripped —
  instances of one workflow stage share profiles.
- **runtime profiles**: per-function mean of the recorded runtimes,
  mapped onto each endpoint as ``mean / perf_scale`` (faster machines run
  it proportionally faster) with dynamic watts ``0.5 * tdp / cores``.
- **dependency payloads**: a child's ``dep_bytes`` (bytes pulled from
  *each* parent) is the total size of its input files that appear among
  its parents' outputs, divided by the parent count; edges whose traces
  carry no file data at all (no child inputs or no parent outputs
  recorded) fall back to ``default_dep_bytes``, while recorded-but-
  unmatched file sets stay at their true zero (control-only edges are
  free).
- **submission order**: Kahn topological order, stable in file order, at
  a seeded Poisson ``submit_rate_hz`` — the whole campaign is declared up
  front and the engine's ready-set serializes the waves, exactly like the
  molecular-design generator.

A small hand-written Montage-shaped sample ships at
``repro/workloads/data/wfcommons_montage_sample.json`` so the import path
is exercised offline (``load_wfcommons_sample``).
"""
from __future__ import annotations

import json
import pathlib
import re
from typing import Sequence

import numpy as np

from repro.core.endpoint import EndpointSpec, table1_testbed
from repro.core.scheduler import TaskSpec
from repro.workloads.arrivals import poisson_arrivals
from repro.workloads.trace import WorkloadTrace, apply_deadline_slack

SAMPLE_PATH = (
    pathlib.Path(__file__).parent / "data" / "wfcommons_montage_sample.json"
)

_INSTANCE_SUFFIX = re.compile(r"_(ID)?\d+$")


def _category(task: dict) -> str:
    cat = task.get("category")
    if cat:
        return cat
    return _INSTANCE_SUFFIX.sub("", task["name"]) or task["name"]


def _runtime(task: dict) -> float:
    rt = task.get("runtimeInSeconds", task.get("runtime"))
    if rt is None:
        raise ValueError(
            f"task {task.get('name')!r} has no runtime/runtimeInSeconds"
        )
    return float(rt)


def _file_size(f: dict) -> float:
    return float(f.get("sizeInBytes", f.get("size", 0.0)))


def load_wfcommons(
    path: str | pathlib.Path,
    endpoints: Sequence[EndpointSpec] | None = None,
    submit_rate_hz: float = 32.0,
    runtime_scale: float = 1.0,
    default_dep_bytes: float = 1e6,
    seed: int = 0,
    name: str | None = None,
    deadline_slack: tuple[float, float] | None = None,
) -> WorkloadTrace:
    """Import one WfCommons/Pegasus JSON trace as a replayable workload.

    ``runtime_scale`` rescales every recorded runtime (published traces
    can span hours; scale them into simulation-friendly seconds without
    changing the DAG's relative shape).  ``deadline_slack`` threads
    through :func:`~repro.workloads.trace.apply_deadline_slack`.
    """
    path = pathlib.Path(path)
    data = json.loads(path.read_text())
    wf = data.get("workflow", data)
    raw = wf.get("tasks") or wf.get("jobs")
    if not raw:
        raise ValueError(f"{path}: no workflow.tasks / workflow.jobs array")
    eps = list(endpoints) if endpoints is not None else table1_testbed()

    by_name = {t["name"]: t for t in raw}
    if len(by_name) != len(raw):
        raise ValueError(f"{path}: duplicate task names")
    parents: dict[str, list[str]] = {t["name"]: [] for t in raw}
    for t in raw:
        ps = t.get("parents")
        if ps is not None:
            parents[t["name"]] = [p for p in ps if p in by_name]
    # derive missing parent lists from children (some 1.x traces only
    # record the downward edges)
    for t in raw:
        for c in t.get("children", ()):
            if c in parents and t["name"] not in parents[c]:
                parents[c].append(t["name"])

    # Kahn topological order, stable in file order
    order: list[str] = []
    indeg = {n: len(ps) for n, ps in parents.items()}
    frontier = [t["name"] for t in raw if indeg[t["name"]] == 0]
    children: dict[str, list[str]] = {n: [] for n in by_name}
    for n, ps in parents.items():
        for p in ps:
            children[p].append(n)
    head = 0
    while head < len(frontier):
        n = frontier[head]
        head += 1
        order.append(n)
        for c in children[n]:
            indeg[c] -= 1
            if indeg[c] == 0:
                frontier.append(c)
    if len(order) != len(raw):
        cyclic = sorted(n for n, d in indeg.items() if d > 0)
        raise ValueError(f"{path}: dependency cycle through {cyclic[:5]}")

    # per-function mean runtime -> per-endpoint profiles
    cat_rt: dict[str, list[float]] = {}
    for t in raw:
        cat_rt.setdefault(_category(t), []).append(_runtime(t))
    profiles = {
        fn: {
            ep.name: (
                float(np.mean(rts)) * runtime_scale / ep.perf_scale,
                0.5 * ep.tdp_w / ep.cores,
            )
            for ep in eps
        }
        for fn, rts in cat_rt.items()
    }
    signatures = {
        fn: np.array([1.0 + (i % 4), 2.0 - (i % 3) * 0.25,
                      1.0 + (i % 2) * 0.5, 1.0])
        for i, fn in enumerate(sorted(cat_rt))
    }

    tasks: list[TaskSpec] = []
    for n in order:
        t = by_name[n]
        deps = tuple(parents[n])
        dep_bytes = 0.0
        if deps:
            produced = {
                f.get("name"): _file_size(f)
                for p in deps
                for f in by_name[p].get("files", ())
                if f.get("link") == "output"
            }
            inputs = [f for f in t.get("files", ())
                      if f.get("link") == "input"]
            if not inputs or not produced:
                # trace carries no file data for this edge: fall back
                dep_bytes = default_dep_bytes
            else:
                # recorded data, possibly legitimately zero parent bytes
                # (control-only edges stay free)
                dep_bytes = sum(
                    _file_size(f) for f in inputs
                    if f.get("name") in produced
                ) / len(deps)
        tasks.append(TaskSpec(id=n, fn=_category(t), deps=deps,
                              dep_bytes=dep_bytes))

    arrivals = poisson_arrivals(len(tasks), submit_rate_hz, seed=seed)
    if deadline_slack is not None:
        tasks = apply_deadline_slack(tasks, arrivals, profiles,
                                     deadline_slack, seed=seed + 3)
    return WorkloadTrace(
        name=name or f"wfcommons_{data.get('name', path.stem)}",
        tasks=tasks,
        arrivals=arrivals,
        endpoints=eps,
        profiles=profiles,
        signatures=signatures,
        meta={
            "source": str(path),
            "schema": data.get("schemaVersion", "unknown"),
            "functions": sorted(cat_rt),
            "seed": seed,
        },
    )


def load_wfcommons_sample(**kwargs) -> WorkloadTrace:
    """The committed Montage-shaped sample trace (19 tasks, 4 stages of
    fan-out/fan-in) through :func:`load_wfcommons`."""
    return load_wfcommons(SAMPLE_PATH, **kwargs)
