"""The paper's synthetic EDP workload (§IV-B.1, Table V): a mixed batch of
compute-, memory-, and IO-bound SeBS-style functions streamed at a
configurable arrival process over the Table-I testbed.

Function classes map onto the calibrated testbed profiles:

- **compute**: graph algorithms (bfs / mst / pagerank) — cycle-bound,
  large cross-machine speed spreads (pagerank is FASTER's 200x win).
- **memory**: dna_visualization / thumbnail — LLC-miss heavy signatures,
  the energy-expensive inversions of Fig. 2.
- **io**: compression / video_processing — data-staged: each task reads a
  payload from the ``home`` endpoint, a slice of it from a *shared*
  dataset cached per destination after first transfer.

The default 1792-task size and 7-function mix reproduce the paper's
synthetic workload; smaller ``n_tasks`` keep the same class mix for smoke
runs.
"""
from __future__ import annotations

import numpy as np

from repro.core.endpoint import table1_testbed
from repro.core.scheduler import TaskSpec
from repro.core.testbed import BASE_PROFILES, FN_SIGNATURES
from repro.workloads.arrivals import make_arrivals
from repro.workloads.trace import WorkloadTrace, apply_deadline_slack

FUNCTION_CLASSES = {
    "compute": ("graph_bfs", "graph_mst", "graph_pagerank"),
    "memory": ("dna_visualization", "thumbnail"),
    "io": ("compression", "video_processing"),
}

# per-task IO payload: (n_files, bytes) private + a shared dataset slice
IO_PRIVATE_BYTES = 8e6
IO_SHARED_BYTES = 256e6
IO_SHARED_FILES = 16


def synthetic_edp_workload(
    n_tasks: int = 1792,
    arrival: str = "poisson",
    seed: int = 0,
    class_mix: tuple[float, float, float] = (0.45, 0.25, 0.30),
    home: str = "desktop",
    user: str = "user0",
    deadline_slack: tuple[float, float] | None = None,
    **arrival_kwargs,
) -> WorkloadTrace:
    """Build the synthetic EDP trace.

    ``class_mix`` weights (compute, memory, io); within a class, functions
    round-robin.  ``arrival`` picks the process from
    :mod:`repro.workloads.arrivals` (extra kwargs pass through; the
    default Poisson rate targets ~8 tasks/s so the paper-size trace spans
    a few minutes of simulated submissions).  Same ``(n_tasks, arrival,
    seed, class_mix)``, same trace — task order, ids, inputs, arrivals
    are all derived from one seeded generator.

    ``deadline_slack=(lo, hi)`` draws per-task deadline distributions
    (see :func:`~repro.workloads.trace.apply_deadline_slack`): deadline =
    arrival + (1 + U(lo, hi)) x fleet-mean runtime.  Deadlines bound the
    carbon deferral queue and feed the miss-rate evaluation column; they
    never change placement, so a trace with deadlines replays
    identically to one without.
    """
    if n_tasks <= 0:
        raise ValueError(f"n_tasks must be positive, got {n_tasks}")
    mix = np.asarray(class_mix, dtype=float)
    if mix.shape != (3,) or (mix < 0).any() or mix.sum() <= 0:
        raise ValueError(f"class_mix must be 3 non-negative weights, got {class_mix}")
    rng = np.random.default_rng(seed)
    classes = list(FUNCTION_CLASSES)
    draw = rng.choice(len(classes), size=n_tasks, p=mix / mix.sum())

    counters = dict.fromkeys(FUNCTION_CLASSES, 0)
    tasks: list[TaskSpec] = []
    for i, ci in enumerate(draw):
        cls = classes[int(ci)]
        fns = FUNCTION_CLASSES[cls]
        fn = fns[counters[cls] % len(fns)]
        counters[cls] += 1
        inputs: tuple = ()
        if cls == "io":
            inputs = (
                (home, 1, IO_PRIVATE_BYTES, False),
                (home, IO_SHARED_FILES, IO_SHARED_BYTES, True),
            )
        tasks.append(TaskSpec(id=f"syn{i}", fn=fn, inputs=inputs, user=user))

    if arrival == "poisson":
        arrival_kwargs.setdefault("rate_hz", 8.0)
    arrivals = make_arrivals(arrival, n_tasks, seed=seed + 1, **arrival_kwargs)
    endpoints = table1_testbed()
    if home not in {e.name for e in endpoints}:
        raise ValueError(f"home={home!r} is not a Table-I endpoint")
    if deadline_slack is not None:
        tasks = apply_deadline_slack(
            tasks, arrivals, BASE_PROFILES, deadline_slack, seed=seed + 2
        )
    return WorkloadTrace(
        name=f"synthetic_edp_{n_tasks}_{arrival}",
        tasks=tasks,
        arrivals=arrivals,
        endpoints=endpoints,
        profiles=BASE_PROFILES,
        signatures=FN_SIGNATURES,
        meta={
            "classes": {cls: counters[cls] for cls in classes},
            "arrival": arrival,
            "seed": seed,
        },
    )
