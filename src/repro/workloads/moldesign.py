"""Molecular-design DAG workload (paper §IV-B.2 / Fig. 9) with explicit
data dependencies — the pipeline the paper reports 21% energy / 63%
runtime savings on.

Per wave::

    dock_{w,j} ──> simulate_{w,j} ──┐
    dock_{w,j+1} ─> simulate_{w,j+1} ─┼──> train_w ──> infer_{w,k}
    ...                             ──┘                    │
    dock_{w+1,j}  <────────(candidates ranked by)──────────┘

- **dock**: cheap geometry screening, one per candidate.
- **simulate**: the expensive "quantum chemistry" stage; simulate j
  consumes dock j's pose (``dep_bytes`` from dock's endpoint).
- **train**: surrogate-model update; fan-in over *all* of the wave's
  simulations.
- **infer**: batched surrogate inference; each infer task pulls the
  trained weights.  The next wave's docks depend on this wave's infer
  output (round-robin over the infer batch), so waves serialize through
  the DAG instead of through the submitting client.

All stage profiles run on the paper's {desktop, ic, faster} subset (theta
offline, as in §IV-B.2): simulation/inference parallel-friendly and
fastest on FASTER, training faster *and* cheaper on desktop — the split
Cluster MHRA discovers.
"""
from __future__ import annotations

import numpy as np

from repro.core.endpoint import table1_testbed
from repro.core.scheduler import TaskSpec
from repro.workloads.arrivals import poisson_arrivals
from repro.workloads.trace import WorkloadTrace, apply_deadline_slack

#    fn -> machine -> (runtime_s, dynamic_watts)
MOLDESIGN_DAG_PROFILES = {
    "dock":     {"desktop": (3.0, 2.5), "ic": (1.2, 4.0), "faster": (0.8, 3.5)},
    "simulate": {"desktop": (20.0, 4.0), "ic": (5.0, 6.0), "faster": (2.5, 5.0)},
    "train":    {"desktop": (8.0, 5.0), "ic": (18.0, 30.0), "faster": (22.0, 40.0)},
    "infer":    {"desktop": (4.0, 2.0), "ic": (1.5, 3.0), "faster": (0.6, 2.5)},
}
MOLDESIGN_SIGS = {
    "dock": np.array([1.5, 2.5, 1.1, 1.0]),
    "simulate": np.array([2.0, 3.0, 1.2, 1.0]),
    "train": np.array([4.0, 1.0, 1.5, 1.0]),
    "infer": np.array([1.0, 2.0, 1.0, 1.0]),
}

# DAG-edge payloads (bytes moved from the producing endpoint)
POSE_BYTES = 1e6       # dock -> simulate
RESULT_BYTES = 4e6     # simulate -> train
WEIGHTS_BYTES = 8e6    # train -> infer
RANKING_BYTES = 2e6    # infer -> next wave's dock


def moldesign_endpoints():
    """{desktop, ic, faster} — theta is offline for this app (paper)."""
    return [e for e in table1_testbed() if e.name in ("desktop", "ic", "faster")]


def moldesign_dag_workload(
    waves: int = 4,
    docks_per_wave: int = 48,
    sims_per_wave: int = 48,
    infers_per_wave: int = 96,
    seed: int = 0,
    submit_rate_hz: float = 64.0,
    deadline_slack: tuple[float, float] | None = None,
) -> WorkloadTrace:
    """Build the molecular-design DAG trace.

    The whole DAG is submitted up front at ``submit_rate_hz`` (the client
    declares the campaign; the engine's ready-set holds each task until
    its parents complete), in topological order: wave by wave, dock →
    simulate → train → infer.  ``meta['wave_ids']`` lists each wave's
    task ids for callers that interleave application logic (e.g. the real
    JAX surrogate in ``examples/molecular_design.py``).

    ``deadline_slack=(lo, hi)`` assigns seeded per-task deadlines via
    :func:`~repro.workloads.trace.apply_deadline_slack`; the ancestor
    chain estimate means wave-3 tasks get wave-3-feasible deadlines.
    """
    if waves <= 0 or docks_per_wave <= 0 or sims_per_wave <= 0 or infers_per_wave <= 0:
        raise ValueError("waves and per-wave stage sizes must be positive")
    tasks: list[TaskSpec] = []
    wave_ids: list[list[str]] = []
    prev_infer: list[str] = []
    for w in range(waves):
        ids: list[str] = []
        docks = []
        for j in range(docks_per_wave):
            deps = (prev_infer[j % len(prev_infer)],) if prev_infer else ()
            t = TaskSpec(
                id=f"d{w}_{j}", fn="dock", deps=deps,
                dep_bytes=RANKING_BYTES if deps else 0.0,
            )
            docks.append(t.id)
            tasks.append(t)
            ids.append(t.id)
        sims = []
        for j in range(sims_per_wave):
            t = TaskSpec(
                id=f"s{w}_{j}", fn="simulate",
                deps=(docks[j % len(docks)],), dep_bytes=POSE_BYTES,
            )
            sims.append(t.id)
            tasks.append(t)
            ids.append(t.id)
        train = TaskSpec(
            id=f"t{w}", fn="train", deps=tuple(sims), dep_bytes=RESULT_BYTES,
        )
        tasks.append(train)
        ids.append(train.id)
        prev_infer = []
        for k in range(infers_per_wave):
            t = TaskSpec(
                id=f"i{w}_{k}", fn="infer", deps=(train.id,),
                dep_bytes=WEIGHTS_BYTES,
            )
            prev_infer.append(t.id)
            tasks.append(t)
            ids.append(t.id)
        wave_ids.append(ids)

    arrivals = poisson_arrivals(len(tasks), submit_rate_hz, seed=seed)
    if deadline_slack is not None:
        tasks = apply_deadline_slack(
            tasks, arrivals, MOLDESIGN_DAG_PROFILES, deadline_slack,
            seed=seed + 3,
        )
    return WorkloadTrace(
        name=f"moldesign_dag_{waves}w",
        tasks=tasks,
        arrivals=arrivals,
        endpoints=moldesign_endpoints(),
        profiles=MOLDESIGN_DAG_PROFILES,
        signatures=MOLDESIGN_SIGS,
        meta={"wave_ids": wave_ids, "waves": waves, "seed": seed},
    )
