"""Chaos scripts for the fault-tolerance evaluation: seeded endpoint
churn traces and warm-pool variants of a fleet.

A churn trace alternates per-endpoint up/down intervals drawn from
exponential distributions whose duty cycle hits a target ``churn``
fraction (expected share of the horizon each unprotected endpoint is
dead).  Everything derives from one seed + the endpoint name, so the
same arguments always script the same outages — the chaos suite is as
reproducible as the workloads it breaks.

Units: seconds and joules, matching the rest of the harness.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Sequence

import numpy as np

from repro.core.endpoint import EndpointSpec
from repro.core.faults import FaultTrace


def churn_fault_trace(
    names: Sequence[str],
    horizon_s: float,
    churn: float = 0.10,
    mttr_s: float = 120.0,
    seed: int = 0,
    protect: Sequence[str] = ("desktop",),
    straggler_p: float = 0.0,
    straggler_factor: float = 3.0,
) -> FaultTrace:
    """Seeded endpoint-churn script over ``[0, horizon_s)``.

    Each endpoint in ``names`` (minus ``protect``) alternates up/down:
    down durations are Exp(``mttr_s``) floored at ``mttr_s / 2`` and
    capped at ``4 * mttr_s`` (so a bounded retry budget always outlasts
    an outage), up durations are Exp(``mttr_s * (1 - churn) / churn``),
    giving roughly a ``churn`` dead fraction on long horizons.  The
    first outage is guaranteed to land *mid-stream* — its start is drawn
    uniformly from ``[0.05, 0.45) * horizon_s`` — so every churned
    endpoint fails at least once while work is in flight (a chaos suite
    whose outages can all miss the busy span tests nothing).
    ``protect`` lists endpoints that never fail (default: the always-on
    desktop, so the fleet is never fully dark).  Straggler parameters
    pass through to the :class:`~repro.core.faults.FaultTrace`.
    """
    if horizon_s <= 0:
        raise ValueError(f"horizon_s must be positive, got {horizon_s}")
    if not 0.0 <= churn < 1.0:
        raise ValueError(f"churn must be in [0, 1), got {churn}")
    if mttr_s <= 0:
        raise ValueError(f"mttr_s must be positive, got {mttr_s}")
    protected = set(protect)
    down: dict[str, list[tuple[float, float]]] = {}
    if churn > 0.0:
        up_mean = mttr_s * (1.0 - churn) / churn
        for name in names:
            if name in protected:
                continue
            rng = np.random.default_rng(
                (seed * 0x9E3779B1 + zlib.crc32(name.encode())) % 2 ** 32
            )
            ivs: list[tuple[float, float]] = []
            # first outage guaranteed inside the busy span
            t = float(rng.uniform(0.05, 0.45)) * horizon_s
            while t < horizon_s:
                d = min(max(float(rng.exponential(mttr_s)), 0.5 * mttr_s),
                        4.0 * mttr_s)
                ivs.append((t, t + d))
                t += d + float(rng.exponential(up_mean))
            if ivs:
                down[name] = ivs
    return FaultTrace(
        down={n: tuple(iv) for n, iv in down.items()},
        straggler_p=straggler_p,
        straggler_factor=straggler_factor,
        seed=seed,
    )


def add_failover(
    endpoints: Sequence[EndpointSpec],
    profiles: dict[str, dict[str, tuple[float, float]]],
    clone_of: str = "desktop",
    name: str = "login",
    rt_factor: float = 1.08,
    idle_factor: float = 1.25,
) -> tuple[list[EndpointSpec], dict[str, dict[str, tuple[float, float]]]]:
    """Extend a fleet with a failover twin of ``clone_of`` (default: a
    second always-on login-class node next to the desktop).

    The twin is strictly dominated while the original is alive —
    ``rt_factor`` slower at equal watts, ``idle_factor`` hungrier at
    idle — so fault-free placement never prefers it and adding it leaves
    a fault-free comparison qualitatively unchanged.  Its value is as a
    *live* alternative when the original is scripted down: a fault-aware
    policy fails over to it for a small premium instead of re-dispatching
    into the outage.  Returns ``(endpoints + twin, profiles with a twin
    column per function)`` — both fresh containers, inputs untouched.
    """
    by_name = {e.name: e for e in endpoints}
    if clone_of not in by_name:
        raise ValueError(f"unknown endpoint {clone_of!r}")
    if name in by_name:
        raise ValueError(f"endpoint {name!r} already exists")
    if rt_factor < 1.0 or idle_factor < 1.0:
        raise ValueError("a failover twin must not dominate the original")
    src = by_name[clone_of]
    twin = dataclasses.replace(
        src, name=name,
        idle_power_w=src.idle_power_w * idle_factor,
        hops={**dict(src.hops), clone_of: 1},
    )
    prof = {}
    for fn, per_machine in profiles.items():
        col = dict(per_machine)
        if clone_of in col:
            rt, w = col[clone_of]
            col[name] = (rt * rt_factor, w)
        prof[fn] = col
    return list(endpoints) + [twin], prof


def with_warm_pool(
    endpoints: Sequence[EndpointSpec],
    cold_start_s: float = 2.0,
    cold_start_j: float = 50.0,
    keepalive_s: float = 60.0,
    only: Sequence[str] | None = None,
) -> list[EndpointSpec]:
    """Copy a fleet with warm-pool dynamics enabled: workers go cold after
    ``keepalive_s`` idle (or when their endpoint dies) and each cold
    dispatch pays ``cold_start_s`` latency + ``cold_start_j`` startup
    energy.  ``only`` restricts the change to the named endpoints
    (default: all)."""
    sel = None if only is None else set(only)
    out = []
    for e in endpoints:
        if sel is not None and e.name not in sel:
            out.append(e)
            continue
        out.append(dataclasses.replace(
            e, cold_start_s=cold_start_s, cold_start_j=cold_start_j,
            keepalive_s=keepalive_s,
        ))
    return out
