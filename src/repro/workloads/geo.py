"""Geo-distributed evaluation workload: three regional fleets, regional
carbon grids, and caller locality — the A/B/C routing protocol's input.

The Function Delivery Network line of work evaluates region placement by
replaying one trace under three dispatch modes (fixed region / caller
region / carbon-aware agent).  This module builds everything that
comparison needs on top of the Table-I testbed:

- :func:`geo_testbed` — the testbed replicated per region as
  ``{machine}@{region}`` with a few percent of per-region spec drift (no
  two real deployments are identical, and exact ties would let engines
  legitimately diverge), intra-region hop counts far below cross-region
  ones (so caller locality matters to endpoint-level transfer billing).
- :func:`geo_profiles` — the calibrated function profiles re-keyed by
  replica name with matching runtime/power drift (the simulator reads
  truths per endpoint name).
- :func:`geo_region_specs` — one :class:`~repro.core.region.RegionSpec`
  per region: member endpoints, measured-style WAN links (bandwidth,
  latency, energy-per-byte), and the callers homed there.
- :func:`geo_carbon_signal` — per-region diurnal grids with distinct
  phases (regions peak at different times — the spatial-shifting win).
- :func:`geo_edp_workload` — the mixed SeBS-style task stream with
  callers spread uniformly across regions; each io task stages data from
  its caller's regional desktop.  ``meta`` carries the region specs and
  carbon signal so the evaluation harness replays all three modes on the
  *same* trace objects.

Same ``(n_tasks, seed, regions)``, same trace — bit for bit.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import CarbonIntensitySignal
from repro.core.endpoint import EndpointSpec, table1_testbed
from repro.core.region import RegionSpec
from repro.core.scheduler import TaskSpec
from repro.workloads.arrivals import make_arrivals
from repro.workloads.synthetic import (
    FUNCTION_CLASSES, IO_PRIVATE_BYTES, IO_SHARED_BYTES, IO_SHARED_FILES,
)
from repro.core.testbed import BASE_PROFILES, FN_SIGNATURES
from repro.workloads.trace import WorkloadTrace, apply_deadline_slack

import dataclasses

#: Default federation: three regions on three grids.
GEO_REGIONS = ("us-east", "eu-west", "ap-south")

#: Symmetric WAN links (bandwidth B/s, one-way latency s, energy J/B) for
#: the default regions; unlisted pairs use the region-module defaults.
GEO_WAN_LINKS = {
    ("us-east", "eu-west"): (1.25e9, 0.08, 9.0e-8),
    ("us-east", "ap-south"): (6.25e8, 0.22, 1.5e-7),
    ("eu-west", "ap-south"): (6.25e8, 0.15, 1.2e-7),
}

#: Hop counts endpoint-level transfers see: staying inside a region is
#: much cheaper than crossing it, so caller locality has teeth.
INTRA_REGION_HOPS = 3
CROSS_REGION_HOPS = 12

#: Per-region spec/profile drift per region index (same idiom as
#: ``scaled_testbed``): real regional deployments differ a few percent,
#: and exact ties would let different engines break them differently.
IDLE_DRIFT = 0.03
QUEUE_DRIFT = 0.05
PERF_DRIFT = 0.02
RUNTIME_DRIFT = 0.04
POWER_DRIFT = 0.02


def geo_testbed(regions=GEO_REGIONS) -> list[EndpointSpec]:
    """The Table-I testbed replicated once per region, named
    ``{machine}@{region}``.  Region k's replicas drift: idle power
    ``x(1 + 0.03k)``, queue delay ``x(1 + 0.05k)``, perf ``x(1 + 0.02k)``.
    Hops: :data:`INTRA_REGION_HOPS` within a region,
    :data:`CROSS_REGION_HOPS` across."""
    base = table1_testbed()
    names = [
        f"{e.name}@{r}" for r in regions for e in base
    ]
    eps = []
    for k, r in enumerate(regions):
        for e in base:
            me = f"{e.name}@{r}"
            hops = {
                n: (INTRA_REGION_HOPS if n.endswith(f"@{r}")
                    else CROSS_REGION_HOPS)
                for n in names if n != me
            }
            eps.append(dataclasses.replace(
                e,
                name=me,
                idle_power_w=e.idle_power_w * (1.0 + IDLE_DRIFT * k),
                queue_delay_s=e.queue_delay_s * (1.0 + QUEUE_DRIFT * k),
                perf_scale=e.perf_scale * (1.0 + PERF_DRIFT * k),
                hops=hops,
            ))
    return eps


def geo_profiles(regions=GEO_REGIONS) -> dict:
    """Calibrated profiles re-keyed by replica endpoint name, with
    region-k drift (runtime ``x(1 + 0.04k)``, power ``x(1 + 0.02k)``)
    matching the testbed's spec drift — the simulator reads truths per
    endpoint name, so every replica needs its own row."""
    out: dict[str, dict[str, tuple[float, float]]] = {}
    for fn, by_machine in BASE_PROFILES.items():
        row = {}
        for k, r in enumerate(regions):
            for m, (rt, w) in by_machine.items():
                row[f"{m}@{r}"] = (
                    rt * (1.0 + RUNTIME_DRIFT * k),
                    w * (1.0 + POWER_DRIFT * k),
                )
        out[fn] = row
    return out


def geo_region_specs(regions=GEO_REGIONS, callers_per_region: int = 2
                     ) -> list[RegionSpec]:
    """One :class:`RegionSpec` per region: the replicated machines as
    members, WAN links from :data:`GEO_WAN_LINKS` (defaults for unlisted
    pairs), and callers ``{region}/u0..`` homed locally."""
    machines = [e.name for e in table1_testbed()]
    specs = []
    for r in regions:
        bw, lat, jpb = {}, {}, {}
        for o in regions:
            if o == r:
                continue
            link = GEO_WAN_LINKS.get((r, o)) or GEO_WAN_LINKS.get((o, r))
            if link is not None:
                bw[o], lat[o], jpb[o] = link
        specs.append(RegionSpec(
            name=r,
            endpoints=tuple(f"{m}@{r}" for m in machines),
            wan_bw_bps=bw,
            wan_latency_s=lat,
            wan_j_per_byte=jpb,
            callers=tuple(
                f"{r}/u{i}" for i in range(callers_per_region)
            ),
        ))
    return specs


def geo_carbon_signal(regions=GEO_REGIONS, period_s: float = 600.0,
                      seed: int = 0, kind: str = "diurnal"
                      ) -> CarbonIntensitySignal:
    """Per-region grids with distinct means/swings/phases, plus the
    endpoint→region map so both endpoint names (billing) and bare region
    names (routing, WAN billing) resolve to the right trace."""
    machines = [e.name for e in table1_testbed()]
    ep_map = {
        f"{m}@{r}": r for r in regions for m in machines
    }
    ctor = {
        "diurnal": CarbonIntensitySignal.diurnal,
        "step": CarbonIntensitySignal.step,
    }.get(kind)
    if ctor is None:
        raise ValueError(
            f"unknown carbon signal kind {kind!r} (diurnal or step)"
        )
    return ctor(list(regions), period_s=period_s, seed=seed, regions=ep_map)


def geo_edp_workload(
    n_tasks: int = 448,
    arrival: str = "diurnal",
    seed: int = 0,
    regions=GEO_REGIONS,
    period_s: float = 600.0,
    callers_per_region: int = 2,
    class_mix: tuple[float, float, float] = (0.45, 0.25, 0.30),
    deadline_slack: tuple[float, float] | None = None,
    carbon_kind: str = "diurnal",
    **arrival_kwargs,
) -> WorkloadTrace:
    """The synthetic EDP mix streamed at a geo-distributed federation.

    Tasks draw a caller uniformly from ``callers_per_region`` users per
    region; io tasks stage their payload from the *caller's* regional
    desktop, so locality-blind routing pays real cross-region transfer.
    ``meta`` carries ``region_specs`` (for ``OnlineEngine(regions=...)``)
    and ``carbon_signal`` (period ``period_s``, one grid per region), so
    an A/B/C comparison replays the identical trace under all three
    router modes.
    """
    if n_tasks <= 0:
        raise ValueError(f"n_tasks must be positive, got {n_tasks}")
    regions = tuple(regions)
    if len(regions) < 2:
        raise ValueError(f"need at least 2 regions, got {regions!r}")
    mix = np.asarray(class_mix, dtype=float)
    if mix.shape != (3,) or (mix < 0).any() or mix.sum() <= 0:
        raise ValueError(
            f"class_mix must be 3 non-negative weights, got {class_mix}"
        )
    rng = np.random.default_rng(seed)
    classes = list(FUNCTION_CLASSES)
    draw = rng.choice(len(classes), size=n_tasks, p=mix / mix.sum())
    callers = [
        f"{r}/u{i}" for r in regions for i in range(callers_per_region)
    ]
    caller_draw = rng.integers(0, len(callers), size=n_tasks)

    counters = dict.fromkeys(FUNCTION_CLASSES, 0)
    tasks: list[TaskSpec] = []
    for i, ci in enumerate(draw):
        cls = classes[int(ci)]
        fns = FUNCTION_CLASSES[cls]
        fn = fns[counters[cls] % len(fns)]
        counters[cls] += 1
        user = callers[int(caller_draw[i])]
        home = f"desktop@{user.split('/')[0]}"
        inputs: tuple = ()
        if cls == "io":
            inputs = (
                (home, 1, IO_PRIVATE_BYTES, False),
                (home, IO_SHARED_FILES, IO_SHARED_BYTES, True),
            )
        tasks.append(TaskSpec(id=f"geo{i}", fn=fn, inputs=inputs, user=user))

    if arrival == "diurnal":
        arrival_kwargs.setdefault("period_s", period_s)
        # moderate load: the federation keeps up with the stream, so
        # makespan stays arrival-dominated and the A/B/C comparison
        # isolates *where* work runs (carbon, WAN) from queueing
        arrival_kwargs.setdefault("peak_rate_hz", 4.0)
        arrival_kwargs.setdefault("trough_rate_hz", 0.5)
    elif arrival == "poisson":
        arrival_kwargs.setdefault("rate_hz", 8.0)
    arrivals = make_arrivals(arrival, n_tasks, seed=seed + 1,
                             **arrival_kwargs)
    endpoints = geo_testbed(regions)
    profiles = geo_profiles(regions)
    if deadline_slack is not None:
        tasks = apply_deadline_slack(
            tasks, arrivals, profiles, deadline_slack, seed=seed + 2
        )
    specs = geo_region_specs(regions, callers_per_region)
    signal = geo_carbon_signal(regions, period_s=period_s, seed=seed + 3,
                               kind=carbon_kind)
    return WorkloadTrace(
        name=f"geo_edp_{n_tasks}_{len(regions)}r",
        tasks=tasks,
        arrivals=arrivals,
        endpoints=endpoints,
        profiles=profiles,
        signatures=FN_SIGNATURES,
        meta={
            "classes": {cls: counters[cls] for cls in classes},
            "arrival": arrival,
            "seed": seed,
            "regions": list(regions),
            "callers_per_region": callers_per_region,
            "region_specs": specs,
            "carbon_signal": signal,
            "period_s": period_s,
        },
    )
