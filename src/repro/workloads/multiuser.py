"""Multi-tenant synthetic workload: a Zipf-skewed user population
submitting bursty per-user campaigns over the Table-I testbed.

Real FaaS tenancy is heavy-tailed: a handful of power users submit most
of the work while a long tail of occasional users submits a task or two.
This generator draws each task's owner from a Zipf distribution over a
simulated universe of ``n_users`` principals (10k-1M is the realistic
band; only users that actually draw a task ever materialize, so the
universe size costs nothing), then gives every *active* user its own
bursty submission campaign — the grant-deadline pattern under which one
tenant's burst can starve everyone else and the fairness ledger earns
its keep.

The function mix, IO staging, and testbed reuse
:mod:`repro.workloads.synthetic` exactly, so single-tenant and
multi-tenant traces are directly comparable: same classes, same
functions, same simulator truth — only ownership and arrival structure
differ.
"""
from __future__ import annotations

import numpy as np

from repro.core.endpoint import table1_testbed
from repro.core.scheduler import TaskSpec
from repro.core.testbed import BASE_PROFILES, FN_SIGNATURES
from repro.workloads.arrivals import bursty_arrivals
from repro.workloads.synthetic import (
    FUNCTION_CLASSES,
    IO_PRIVATE_BYTES,
    IO_SHARED_BYTES,
    IO_SHARED_FILES,
)
from repro.workloads.trace import WorkloadTrace, apply_deadline_slack


def zipf_user_ranks(
    n_tasks: int, n_users: int, zipf_s: float, rng: np.random.Generator
) -> np.ndarray:
    """Draw ``n_tasks`` owner ranks from Zipf(``zipf_s``) truncated to
    ``[1, n_users]`` by rejection (rank 1 = heaviest user).  Rejection
    keeps the distribution exact — clipping would pile the tail's mass
    onto the last rank — and the tail mass beyond 10k+ users is tiny, so
    it converges in a couple of rounds."""
    if zipf_s <= 1.0:
        raise ValueError(f"zipf_s must be > 1 (Zipf support), got {zipf_s}")
    ranks = np.empty(n_tasks, dtype=np.int64)
    filled = 0
    while filled < n_tasks:
        draw = rng.zipf(zipf_s, size=2 * (n_tasks - filled) + 8)
        draw = draw[draw <= n_users][: n_tasks - filled]
        ranks[filled:filled + len(draw)] = draw
        filled += len(draw)
    return ranks


def multiuser_edp_workload(
    n_tasks: int = 1792,
    n_users: int = 100_000,
    zipf_s: float = 1.3,
    seed: int = 0,
    class_mix: tuple[float, float, float] = (0.45, 0.25, 0.30),
    home: str = "desktop",
    burst_size: int = 16,
    burst_rate_hz: float = 50.0,
    gap_s: float = 30.0,
    campaign_span_s: float = 120.0,
    deadline_slack: tuple[float, float] | None = None,
) -> WorkloadTrace:
    """Build the multi-tenant EDP trace.

    Each task's owner rank is Zipf(``zipf_s``)-distributed over a
    ``n_users`` universe; each active user's tasks arrive as a bursty
    campaign (:func:`~repro.workloads.arrivals.bursty_arrivals` with
    ``burst_size``/``burst_rate_hz``/``gap_s``) whose start is uniform
    over ``campaign_span_s`` seconds, so heavy users' bursts overlap the
    tail's trickle.  Function classes, IO inputs, and the testbed follow
    :func:`~repro.workloads.synthetic.synthetic_edp_workload`.  Same
    ``(n_tasks, n_users, zipf_s, seed, ...)``, same trace — ownership,
    order, and arrivals all derive from one seeded generator.

    ``meta`` reports the realized tenancy shape: ``users_active``
    (distinct owners drawn), ``top_user_share`` (heaviest owner's task
    fraction — the number the fairness gate pushes against), and the
    per-class counts.
    """
    if n_tasks <= 0:
        raise ValueError(f"n_tasks must be positive, got {n_tasks}")
    if n_users < 2:
        raise ValueError(f"n_users must be >= 2, got {n_users}")
    if campaign_span_s < 0.0:
        raise ValueError(
            f"campaign_span_s must be non-negative, got {campaign_span_s}"
        )
    mix = np.asarray(class_mix, dtype=float)
    if mix.shape != (3,) or (mix < 0).any() or mix.sum() <= 0:
        raise ValueError(
            f"class_mix must be 3 non-negative weights, got {class_mix}"
        )
    rng = np.random.default_rng(seed)
    ranks = zipf_user_ranks(n_tasks, n_users, zipf_s, rng)

    classes = list(FUNCTION_CLASSES)
    draw = rng.choice(len(classes), size=n_tasks, p=mix / mix.sum())
    counters = dict.fromkeys(FUNCTION_CLASSES, 0)
    protos: list[tuple[str, tuple, str]] = []   # (fn, inputs, user)
    for ci, rank in zip(draw, ranks):
        cls = classes[int(ci)]
        fns = FUNCTION_CLASSES[cls]
        fn = fns[counters[cls] % len(fns)]
        counters[cls] += 1
        inputs: tuple = ()
        if cls == "io":
            inputs = (
                (home, 1, IO_PRIVATE_BYTES, False),
                (home, IO_SHARED_FILES, IO_SHARED_BYTES, True),
            )
        protos.append((fn, inputs, f"user{int(rank)}"))

    # per-user bursty campaigns, merged into one submission stream
    by_user: dict[int, list[int]] = {}
    for i, rank in enumerate(ranks):
        by_user.setdefault(int(rank), []).append(i)
    pairs: list[tuple[float, int]] = []
    for rank in sorted(by_user):
        idxs = by_user[rank]
        start = float(rng.uniform(0.0, campaign_span_s))
        arr = bursty_arrivals(
            len(idxs), burst_size=burst_size, burst_rate_hz=burst_rate_hz,
            gap_s=gap_s, seed=rng, start=start,
        )
        pairs.extend(zip(arr.tolist(), idxs))
    pairs.sort()

    tasks = [
        TaskSpec(id=f"mu{k}", fn=protos[i][0], inputs=protos[i][1],
                 user=protos[i][2])
        for k, (_, i) in enumerate(pairs)
    ]
    arrivals = np.array([a for a, _ in pairs])
    endpoints = table1_testbed()
    if home not in {e.name for e in endpoints}:
        raise ValueError(f"home={home!r} is not a Table-I endpoint")
    if deadline_slack is not None:
        tasks = apply_deadline_slack(
            tasks, arrivals, BASE_PROFILES, deadline_slack, seed=seed + 2
        )
    counts = np.array([len(v) for v in by_user.values()])
    return WorkloadTrace(
        name=f"multiuser_edp_{n_tasks}_z{zipf_s}",
        tasks=tasks,
        arrivals=arrivals,
        endpoints=endpoints,
        profiles=BASE_PROFILES,
        signatures=FN_SIGNATURES,
        meta={
            "classes": {cls: counters[cls] for cls in classes},
            "users_universe": n_users,
            "users_active": len(by_user),
            "top_user_share": float(counts.max()) / n_tasks,
            "zipf_s": zipf_s,
            "seed": seed,
        },
    )
