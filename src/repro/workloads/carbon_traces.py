"""Carbon-intensity traces for the evaluation workloads.

The paper's Table-I endpoints live at different institutions on
different grids; this module gives each one a seeded synthetic
grid-intensity trace matched to the evaluation harness's compressed
time scale (``period_s`` defaults to the diurnal arrival process's
600 s "day", so grid swings and arrival swings interact within one
benchmark run).  Same ``(seed, period_s)``, same signal — the
(generator, seed) pair is the trace identity, exactly like the task
workload generators.

For real data, export a grid-API pull into the JSON schema
``CarbonIntensitySignal.to_json`` writes and load it with
:func:`load_carbon_signal`.
"""
from __future__ import annotations

from repro.core.carbon import CarbonIntensitySignal
from repro.core.endpoint import table1_testbed


def table1_carbon_signal(
    seed: int = 0,
    period_s: float = 600.0,
    kind: str = "diurnal",
) -> CarbonIntensitySignal:
    """One trace per Table-I endpoint (desktop/theta/ic/faster), each with
    its own mean, swing, and phase so neither the cleanest endpoint nor
    the cleanest hour is constant — the setting where carbon-aware
    placement has to keep re-deciding.  ``kind`` is ``"diurnal"``
    (sinusoidal day/night) or ``"step"`` (flat floor + peaker plateau).
    """
    names = [e.name for e in table1_testbed()]
    if kind == "diurnal":
        return CarbonIntensitySignal.diurnal(
            names, period_s=period_s, seed=seed
        )
    if kind == "step":
        return CarbonIntensitySignal.step(names, period_s=period_s, seed=seed)
    raise ValueError(f"unknown carbon trace kind {kind!r}; "
                     f"available: ['diurnal', 'step']")


def write_carbon_signal(signal: CarbonIntensitySignal, path: str) -> dict:
    """Persist a signal to the real-trace JSON schema; returns the payload."""
    return signal.to_json(path)


def load_carbon_signal(path: str) -> CarbonIntensitySignal:
    """Load a real-trace JSON file (the :func:`write_carbon_signal` schema)."""
    return CarbonIntensitySignal.from_json(path)
